"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free RNN with
data-dependent per-channel decay.

Per layer:
  * time mixing — r/k/v/g projections of token-shift-lerped inputs; the WKV
    recurrence per head (state S in R^{dh x dh}):
        out_t = r_t . (diag(u) k_t^T v_t + S_{t-1})
        S_t   = diag(w_t) S_{t-1} + k_t^T v_t
    with decay w_t = exp(-exp(w0 + lora(x_t))) (data-dependent, the RWKV6
    novelty) and per-head bonus u.
  * channel mixing — token-shifted squared-ReLU MLP with sigmoid receptance.

Training uses the *chunked-parallel* WKV form (flash-linear-attention style):
within a chunk of C tokens the recurrence becomes two matmuls with
cumulative-decay-scaled r/k, and only one (dh x dh) state is carried between
chunks — this is the formulation the Pallas kernel (kernels/rwkv6_wkv)
implements on TPU; here it runs in plain jnp so the dry-run lowers it.
A step-by-step `wkv_scan_ref` is kept as the correctness oracle.

Decode carries (state S, shift token) per layer — O(1) per token, which is
why this arch runs the long_500k shape natively.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Param
from repro.sharding.context import constrain

__all__ = [
    "RWKV6Config",
    "schema",
    "init",
    "forward",
    "init_cache",
    "decode_step",
    "wkv_chunked",
    "wkv_scan_ref",
]

# Per-step log-decay floor.  exp(-4.6) ~ 0.01/step: a channel at the floor
# forgets 4 orders of magnitude in two steps, so the truncation is ~1e-4
# relative.  The floor bounds the factored chunk form's exponent range to
# chunk*4.6/2 = 73.6 (chunk 32) after mid-point normalization — inside
# float32 (exp(73.6) ~ 1e32 << 3.4e38).
LOG_DECAY_MIN = -4.6


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    head_size: int = 64
    decay_lora: int = 64
    wkv_chunk: int = 32
    use_kernel: bool = False   # route WKV through the Pallas kernel
                               # (interpret mode on CPU; native on TPU)
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True

    @property
    def family(self) -> str:
        return "ssm"

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_size


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


def layer_schema(cfg: RWKV6Config) -> Dict[str, Any]:
    d, h, k = cfg.d_model, cfg.n_heads, cfg.head_size
    return {
        "time": {
            "mu_r": Param((d,), (None,), init="zeros"),
            "mu_k": Param((d,), (None,), init="zeros"),
            "mu_v": Param((d,), (None,), init="zeros"),
            "mu_w": Param((d,), (None,), init="zeros"),
            "mu_g": Param((d,), (None,), init="zeros"),
            "w0": Param((h, k), ("heads", None), init="zeros"),
            "w_lora_a": Param((d, cfg.decay_lora), ("embed", None)),
            "w_lora_b": Param((cfg.decay_lora, h, k), (None, "heads", None)),
            "u": Param((h, k), ("heads", None), init="zeros"),
            "w_r": Param((d, h, k), ("embed", "heads", None)),
            "w_k": Param((d, h, k), ("embed", "heads", None)),
            "w_v": Param((d, h, k), ("embed", "heads", None)),
            "w_g": Param((d, h, k), ("embed", "heads", None)),
            "w_o": Param((h, k, d), ("heads", None, "embed")),
            "ln_x": Param((h, k), ("heads", None), init="ones"),
        },
        "chan": {
            "mu_ck": Param((d,), (None,), init="zeros"),
            "mu_cr": Param((d,), (None,), init="zeros"),
            "w_ck": Param((d, cfg.d_ff), ("embed", "ff")),
            "w_cv": Param((cfg.d_ff, d), ("ff", "embed")),
            "w_cr": Param((d, d), ("embed", None)),
        },
        "time_norm": Param((d,), (None,), init="ones"),
        "chan_norm": Param((d,), (None,), init="ones"),
    }


def schema(cfg: RWKV6Config) -> Dict[str, Any]:
    return {
        "embed": Param((cfg.vocab, cfg.d_model), ("vocab", None), init="embed"),
        "layers": common.stacked(layer_schema(cfg), cfg.n_layers),
        "final_norm": Param((cfg.d_model,), (None,), init="ones"),
        "lm_head": Param((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }


def init(rng: jax.Array, cfg: RWKV6Config):
    return common.init_from_schema(rng, schema(cfg), cfg.param_dtype)


# ---------------------------------------------------------------------------
# WKV recurrence
# ---------------------------------------------------------------------------


def wkv_scan_ref(
    r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array, u: jax.Array,
    s0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Step-by-step oracle.  r/k/v/log_w: (B,T,H,K); u: (H,K).
    Returns (out (B,T,H,K), final state (B,H,K,K))."""
    b, t, h, kk = r.shape
    s = jnp.zeros((b, h, kk, kk), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, lw_t = inp  # (B,H,K)
        kv = k_t[..., :, None].astype(jnp.float32) * v_t[..., None, :].astype(jnp.float32)
        out = jnp.einsum(
            "bhk,bhkv->bhv", r_t.astype(jnp.float32), u[None, :, :, None] * kv + s
        )
        s = jnp.exp(lw_t.astype(jnp.float32))[..., None] * s + kv
        return s, out

    xs = (
        r.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        log_w.transpose(1, 0, 2, 3),
    )
    s, outs = jax.lax.scan(step, s, xs)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), s


def wkv_chunked(
    r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array, u: jax.Array,
    *, chunk: int = 64, s0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked-parallel WKV.  Shapes as in `wkv_scan_ref`.

    Within a chunk, with L_t = sum_{j<=t} log w_j (inclusive cumsum):
      intra(t,s) = sum_d r_t[d] k_s[d] exp(L_{t-1} - L_s)   for s < t
                 = (r .* exp(L_prev)) @ (k .* exp(-L))^T    — two scaled GEMMs
      out_t      = intra @ v + (r_t . u . k_t) v_t + (r .* exp(L_prev)) S0
      S_end      = exp(L_C) . S0 + (k .* exp(L_C - L))^T V
    log-decays are clamped to [LOG_DECAY_MIN, 0] for the exp(-L) stability of
    the scaled-GEMM form (same clamp as the Pallas kernel).
    """
    b, t, h, kk = r.shape
    c = min(chunk, t)
    pad = (-t) % c
    if pad:
        z = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tp = t + pad
    nc = tp // c

    def reshape(x):
        return x.reshape(b, nc, c, h, kk).transpose(1, 0, 3, 2, 4)  # (nc,B,H,C,K)

    rc, kc, vc = reshape(r).astype(jnp.float32), reshape(k).astype(jnp.float32), reshape(v).astype(jnp.float32)
    lw = jnp.clip(reshape(log_w).astype(jnp.float32), LOG_DECAY_MIN, 0.0)

    s_init = (
        jnp.zeros((b, h, kk, kk), jnp.float32) if s0 is None else s0.astype(jnp.float32)
    )

    def chunk_body(s, inp):
        r_b, k_b, v_b, lw_b = inp  # (B,H,C,K)
        l_inc = jnp.cumsum(lw_b, axis=2)            # L_t inclusive
        l_prev = l_inc - lw_b                        # L_{t-1}
        l_end = l_inc[:, :, -1:, :]                  # L_C
        # Mid-point normalization: score(t,s) = exp(L_{t-1}-L_s) factors into
        # exp(L_{t-1}-L_mid) * exp(L_mid-L_s); each exponent is bounded by
        # |L_end|/2 <= chunk*|LOG_DECAY_MIN|/2, keeping the two GEMM factors
        # finite in float32 (the unnormalized form overflows exp(-L)).
        l_mid = 0.5 * l_end
        rr = r_b * jnp.exp(l_prev - l_mid)
        kk_ = k_b * jnp.exp(l_mid - l_inc)
        scores = jnp.einsum("bhtd,bhsd->bhts", rr, kk_)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        diag = jnp.einsum("bhtd,bhtd->bht", r_b * u[None, :, None, :], k_b)
        out = jnp.einsum("bhts,bhsv->bhtv", scores, v_b)
        out = out + diag[..., None] * v_b
        # Inter-chunk term needs the unnormalized r * exp(L_prev); exp(L_prev)
        # only decays (<= 1) so underflow-to-zero is the correct limit.
        out = out + jnp.einsum("bhtd,bhdv->bhtv", rr * jnp.exp(l_mid), s)
        k_dec = k_b * jnp.exp(l_end - l_inc)
        s_new = jnp.exp(l_end[:, :, 0, :])[..., None] * s + jnp.einsum(
            "bhtd,bhtv->bhdv", k_dec, v_b
        )
        return s_new, out

    body = jax.checkpoint(chunk_body)
    s_final, outs = jax.lax.scan(body, s_init, (rc, kc, vc, lw))
    # (nc,B,H,C,K) -> (B,T,H,K)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, tp, h, kk)[:, :t]
    return out.astype(r.dtype), s_final


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def _shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """Token shift: returns previous token's features (zeros/`prev` at t=0)."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _decay(tp: Dict[str, Any], xw: jax.Array, cfg: RWKV6Config) -> jax.Array:
    """Data-dependent log-decay, (B,T,H,K)."""
    lora = jnp.einsum(
        "btd,dl->btl", xw, tp["w_lora_a"].astype(jnp.float32)
    )
    lora = jnp.einsum("btl,lhk->bthk", jnp.tanh(lora), tp["w_lora_b"].astype(jnp.float32))
    log_w = -jnp.exp(tp["w0"].astype(jnp.float32)[None, None] + lora)
    return jnp.clip(log_w, LOG_DECAY_MIN, 0.0)


def _time_mix(
    tp: Dict[str, Any],
    x: jax.Array,
    cfg: RWKV6Config,
    *,
    shift_prev: Optional[jax.Array] = None,
    state: Optional[jax.Array] = None,
    chunked: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    b, t, d = x.shape
    h, kk = cfg.n_heads, cfg.head_size
    xs = _shift(x, shift_prev)

    def mix(mu):
        return x + (xs - x) * mu[None, None]

    hd = ("batch", None, "heads", None)
    r = constrain(jnp.einsum("btd,dhk->bthk", mix(tp["mu_r"]), tp["w_r"]), hd)
    k = constrain(jnp.einsum("btd,dhk->bthk", mix(tp["mu_k"]), tp["w_k"]), hd)
    v = constrain(jnp.einsum("btd,dhk->bthk", mix(tp["mu_v"]), tp["w_v"]), hd)
    g = jax.nn.silu(jnp.einsum("btd,dhk->bthk", mix(tp["mu_g"]), tp["w_g"]))
    log_w = _decay(tp, mix(tp["mu_w"]).astype(jnp.float32), cfg)
    u = tp["u"].astype(jnp.float32)

    if cfg.use_kernel and t > 1 and state is None:
        from repro.kernels.rwkv6_wkv import wkv as wkv_kernel_op

        out, s_new = wkv_kernel_op(
            r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            log_w, u, chunk=cfg.wkv_chunk,
        )
        out = out.astype(cfg.compute_dtype)
    elif chunked and t > 1:
        out, s_new = wkv_chunked(r, k, v, log_w, u, chunk=cfg.wkv_chunk, s0=state)
    else:
        out, s_new = wkv_scan_ref(r, k, v, log_w, u, s0=state)
    # Per-head LayerNorm (GroupNorm equivalent), then gate and project.
    out = common.layer_norm(out.astype(jnp.float32)) * tp["ln_x"].astype(jnp.float32)[None, None]
    out = (out.astype(cfg.compute_dtype) * g)
    return jnp.einsum("bthk,hkd->btd", out, tp["w_o"]), s_new


def _chan_mix(
    cp: Dict[str, Any], x: jax.Array, *, shift_prev: Optional[jax.Array] = None
) -> jax.Array:
    xs = _shift(x, shift_prev)
    xk = x + (xs - x) * cp["mu_ck"][None, None]
    xr = x + (xs - x) * cp["mu_cr"][None, None]
    k = common.relu2(jnp.einsum("btd,df->btf", xk, cp["w_ck"]))
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, cp["w_cr"]))
    return r * jnp.einsum("btf,fd->btd", k, cp["w_cv"])


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def forward(params: Dict[str, Any], cfg: RWKV6Config, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = common.constrain(x, ("batch", None, None))

    def body(x, lp):
        h = common.rms_norm(x, lp["time_norm"])
        t_out, _ = _time_mix(lp["time"], h, cfg)
        x = x + t_out
        h = common.rms_norm(x, lp["chan_norm"])
        x = x + _chan_mix(lp["chan"], h)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    x = common.rms_norm(x, params["final_norm"])
    return jnp.einsum(
        "btd,dv->btv", x, params["lm_head"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)


def init_cache(cfg: RWKV6Config, batch: int, seq_len: int, dtype=None):
    """O(1) state: WKV matrix + the two token-shift registers per layer."""
    if dtype is None:
        dtype = cfg.compute_dtype  # cache dtype must match decode K/V
    h, kk, d, L = cfg.n_heads, cfg.head_size, cfg.d_model, cfg.n_layers
    return {
        "wkv": jnp.zeros((L, batch, h, kk, kk), jnp.float32),
        "time_shift": jnp.zeros((L, batch, d), dtype),
        "chan_shift": jnp.zeros((L, batch, d), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(
    params: Dict[str, Any],
    cfg: RWKV6Config,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,
    pos: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)  # (B,1,d)

    def body(x, layer):
        lp, s_wkv, t_shift, c_shift = layer
        h = common.rms_norm(x, lp["time_norm"])
        new_t_shift = h[:, 0]
        t_out, s_new = _time_mix(
            lp["time"], h, cfg, shift_prev=t_shift, state=s_wkv, chunked=False
        )
        x = x + t_out
        h = common.rms_norm(x, lp["chan_norm"])
        new_c_shift = h[:, 0]
        x = x + _chan_mix(lp["chan"], h, shift_prev=c_shift)
        return x, (s_new, new_t_shift, new_c_shift)

    x, (wkv, t_shift, c_shift) = jax.lax.scan(
        body, x, (params["layers"], cache["wkv"], cache["time_shift"], cache["chan_shift"])
    )
    x = common.rms_norm(x, params["final_norm"])
    logits = jnp.einsum(
        "btd,dv->btv", x, params["lm_head"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)
    return logits, {
        "wkv": wkv,
        "time_shift": t_shift.astype(cache["time_shift"].dtype),
        "chan_shift": c_shift.astype(cache["chan_shift"].dtype),
        "pos": pos + 1,
    }

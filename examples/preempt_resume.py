"""Real-backend cluster runtime with checkpointed preemption/resume.

    python examples/preempt_resume.py --backend real --epochs 2

Submits one job whose :class:`JobSpec` names the ``real`` execution backend
(real JAX gradients of a shrunk olmo-1b on this host, heterogeneous timing
simulated) to the event-driven ``ClusterRuntime``, trains ``--epochs``
epochs, injects a ``Preemption`` (the runtime checkpoints params/opt-state/
GNS state to ``<workdir>/<job>.ckpt.npz``), clobbers the live state to prove
the file matters, resumes via a fresh ``JobArrival``, and trains ``--epochs``
more.  Asserts that the checkpoint file was written and that resume restored
the exact pre-preemption state, so CI can run it as an end-to-end smoke.
Exits nonzero if any invariant breaks.
"""
import argparse
import math
import os
import tempfile

import _common  # noqa: F401  (sys.path bootstrap)

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="real", choices=["sim", "real"])
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--total-batch", type=int, default=12)
    args = ap.parse_args()

    from repro.core.perf_model import CommModel
    from repro.core.scheduler import JobSpec
    from repro.core.simulator import GPU_CATALOG
    from repro.runtime import ClusterRuntime, JobState, RealBackendConfig

    spec = JobSpec(
        name="job",
        node_models=tuple(
            GPU_CATALOG[n].model() for n in ("a100", "v100", "rtx6000")
        ),
        comm=CommModel(t_o=0.04, t_u=0.008, gamma=0.15),
        total_batch=args.total_batch,
        b_noise=500.0,
        ref_batch=args.total_batch,
        backend=args.backend,
    )

    with tempfile.TemporaryDirectory() as workdir:
        rt = ClusterRuntime(
            3,
            policy="cannikin",
            seed=0,
            real_backend=RealBackendConfig(arch=args.arch, seq_len=16, lr=0.3),
            checkpoint_dir=workdir,
        )
        handle = rt.submit(spec, at=0.0)
        rt.run()
        rt.advance(epochs=args.epochs, steps=args.steps)
        for r in handle.records:
            loss = "nan" if math.isnan(r.mean_loss) else f"{r.mean_loss:.4f}"
            print(f"epoch {r.epoch} [{r.phase:9s}] backend={r.backend} "
                  f"B={r.total_batch} split={list(r.batches)} loss={loss}")

        real = args.backend == "real"
        if real:
            pre_params = [np.asarray(x) for x in _leaves(handle.backend.params)]
            pre_steps = handle.backend.steps_done

        print("\n-- injecting Preemption --")
        rt.preempt(spec.name, at=10.0)
        rt.run()
        assert handle.state == JobState.PREEMPTED, handle.state
        if real:
            assert handle.checkpoint_path is not None, "no checkpoint path"
            assert os.path.exists(handle.checkpoint_path), "checkpoint not written"
            size = os.path.getsize(handle.checkpoint_path) / 1e6
            print(f"checkpoint written: {handle.checkpoint_path} ({size:.1f} MB)")
            # Clobber the live state: only a real restore can fix this.
            import jax

            handle.backend.params = jax.tree_util.tree_map(
                lambda x: x * 0.0, handle.backend.params
            )
            handle.backend.steps_done = 0

        print("-- resuming (JobArrival) --")
        rt.submit(spec, at=11.0)
        rt.run()
        assert handle.state == JobState.RUNNING, handle.state
        if real:
            post_params = [np.asarray(x) for x in _leaves(handle.backend.params)]
            for a, b in zip(pre_params, post_params):
                np.testing.assert_array_equal(a, b)
            assert handle.backend.steps_done == pre_steps
            print("restore verified: params + stream counters bit-exact")

        rt.advance(epochs=args.epochs, steps=args.steps)
        assert handle.epochs_run == 2 * args.epochs
        if real:
            assert all(
                np.isfinite(r.mean_loss) for r in handle.records
            ), "non-finite loss"
        print(f"\nepochs={handle.epochs_run} preemptions={handle.preemptions} "
              f"sim_time={handle.sim_time:.2f}s — all invariants OK")


def _leaves(tree):
    import jax

    return jax.tree_util.tree_leaves(tree)


if __name__ == "__main__":
    main()

"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (BH, S, D)
    k: jax.Array,  # (BH, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    bh, s, d = q.shape
    t = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    scores = jnp.einsum(
        "bsd,btd->bst", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    scores = jnp.where(mask[None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    return jnp.einsum("bst,btd->bsd", probs, v.astype(jnp.float32)).astype(q.dtype)

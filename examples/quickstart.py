"""Quickstart: train a reduced llama3-family model on synthetic data, then
drive the cluster-runtime front door in ~10 lines.

    python examples/quickstart.py

Part 1 uses the single-job public API end to end: config -> ModelApi ->
train step -> loss curve -> checkpoint save/restore -> greedy decode with
the KV cache.  Part 2 submits two jobs to the event-driven
``repro.runtime.ClusterRuntime`` and lets the Cannikin policy partition an
8-node heterogeneous cluster between them.
"""
import os
import tempfile

import _common  # noqa: F401  (sys.path bootstrap)

import jax
import jax.numpy as jnp

from repro.configs import get_api
from repro.data import SyntheticLM
from repro.optim import adamw, cosine_schedule
from repro.train import restore, save
from repro.train.step import build_train_step


def main():
    api = get_api("llama3-8b", reduced=True)
    print(f"arch={api.arch_id} (reduced) params={api.param_count():,}")

    opt = adamw(cosine_schedule(3e-3, warmup_steps=5, total_steps=60))
    step = jax.jit(build_train_step(api, opt))
    params = api.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    data = SyntheticLM(vocab=api.cfg.vocab, seq_len=32, seed=0)

    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i, 16).items()}
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % 10 == 0:
            print(f"step {i:3d} loss={float(metrics['loss']):.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f}")

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save(path, params)
        params = restore(path, params)
        print(f"checkpoint round-trip OK ({os.path.getsize(path)/1e6:.1f} MB)")

    # Greedy decode 16 tokens from the trained model.
    decode = jax.jit(api.decode_step)
    cache = api.init_cache(batch=1, seq_len=32)
    tok = jnp.zeros((1, 1), jnp.int32)
    out = []
    for pos in range(16):
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy decode:", out)
    # The synthetic rule is next = rule[prev]; a trained model should follow
    # it for at least a few steps.
    hits = sum(out[i + 1] == int(data.rule[out[i]]) for i in range(len(out) - 1))
    print(f"rule-following transitions: {hits}/{len(out)-1}")

    # Part 2: the multi-job cluster runtime in ~10 lines.  Two jobs arrive
    # one after the other; each event incrementally re-partitions the
    # 8-node cluster, and advance() steps the running jobs' epoch loops
    # (bootstrap -> model fit -> OptPerf partition) on the simulator.
    from repro.core.scheduler import random_jobs
    from repro.runtime import ClusterRuntime

    rt = ClusterRuntime(8, policy="cannikin")
    for i, job in enumerate(random_jobs(2, 8, seed=0)):
        rt.submit(job, at=float(i))
    rt.run()
    rt.advance(epochs=3, steps=2)
    print("\ncluster runtime:")
    for h in rt.jobs("running"):
        print(f"  {h.name}: nodes={h.nodes} epochs={h.epochs_run} "
              f"phase={h.last_plan.phase}")
    print(f"  aggregate goodput={rt.allocation.aggregate_goodput:.1f} "
          f"(fraction {rt.allocation.aggregate_fraction:.3f})")


if __name__ == "__main__":
    main()

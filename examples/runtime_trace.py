"""Multi-job churn through the ClusterRuntime front door (Pollux/Sia-style
cluster simulation).

    python examples/runtime_trace.py

Replays one synthetic 3-job trace — staggered arrivals, one departure, one
node failure — under all three allocation policies (cannikin / static /
fair-share) with two simulated training epochs between events, then prints
one comparable summary.  Exits nonzero if any invariant breaks, so CI can
run it as an end-to-end smoke.
"""
import _common  # noqa: F401  (sys.path bootstrap)

from repro.runtime import compare_policies, format_summary, synthetic_trace

N_NODES = 12


def main():
    trace, jobs = synthetic_trace(3, N_NODES, seed=0)
    print(f"trace: {len(trace)} events over {N_NODES} nodes, "
          f"jobs={[j.name for j in jobs]}")
    reports = compare_policies(trace, N_NODES, epochs_per_event=2, steps=2)

    print("\n=== per-event reconcile log (cannikin) ===")
    for rec in reports["cannikin"].records:
        assigned = {k: len(v) for k, v in rec.allocation.assignment.items() if v}
        print(f"  t={rec.time:4.1f} {rec.label:<18} nodes/job={assigned} "
              f"agg_goodput={rec.aggregate_goodput:8.1f}")

    print("\n=== policy comparison (same trace) ===")
    print(format_summary(reports))
    counters = reports["cannikin"].runtime.counters()
    print(f"\ncannikin scheduler reuse: {counters}")

    # End-to-end invariants (CI smoke gate) --------------------------------
    for name, rep in reports.items():
        assert rep.aggregate_goodput > 0, f"{name}: no goodput produced"
        assert rep.job_states[jobs[0].name] == "done", f"{name}: departure lost"
        for handle in rep.runtime.jobs("running"):
            assert handle.epochs_run > 0, f"{name}: {handle.name} never trained"
            assert handle.last_plan is not None
        down = rep.runtime.down_nodes
        for ids in rep.runtime.allocation.assignment.values():
            assert not down & set(ids), f"{name}: assigned a down node"
    # Incremental scheduling really was incremental: rows were replayed from
    # cache and later rounds warm-started instead of re-solving cold.
    assert counters["cached_rows"] > 0 and counters["warm_rounds"] > 0
    print("\nall invariants OK")


if __name__ == "__main__":
    main()

"""The serving runtime: a step-driven event loop over the serving subsystem.

:class:`ServingRuntime` wires the four layers together —

* :mod:`repro.serving.request`   (what arrives, when, with what deadline)
* :mod:`repro.serving.queue`     (who decodes where, at what batch)
* :mod:`repro.serving.allocator` (how many slots each node deserves)
* :mod:`repro.serving.engines`   (what a tick costs / which tokens come out)

— under one deterministic event loop.  Events are ``(time, seq, kind)``
heap entries where ``seq`` is a monotone tie-breaker, so two same-seed runs
process the identical event sequence and the metrics fingerprint matches
bit-for-bit (the determinism gate in ``benchmarks/bench_serving.py``).

A node's life is a chain of *ticks*: admit waiting requests into free
water-fill slots, prefill the newcomers, run one decode step over the whole
active batch, complete finished requests, schedule the next tick at
``now + measured tick time``.  Tick times feed the allocator's refit
telemetry; every ``resolve_every`` seconds the allocator refits and
re-solves, and the scheduler reconciles allocations (evicting the newest
actives where a node shrank — tokens kept, no work lost).

Cluster churn speaks the trainer's event alphabet
(:class:`repro.runtime.events.NodeJoin` / :class:`~repro.runtime.events.
NodeLeave` via :meth:`ServingRuntime.post`): a leaving or quarantined node's
in-flight requests requeue at the queue *front* with their generated tokens,
re-prefill elsewhere (caches rebuilt), and finish — a mid-stream NodeLeave
completes every request with zero drops, which the serving-smoke CI lane
asserts end to end.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

from repro.runtime.events import Event, NodeJoin, NodeLeave
from repro.serving.allocator import ServingAllocator
from repro.serving.engines import ServingEngine
from repro.serving.metrics import ServingMetrics
from repro.serving.queue import ActiveRequest, BatchScheduler
from repro.serving.request import Workload

__all__ = ["ServingConfig", "ServingReport", "ServingRuntime"]


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Loop policy knobs (all deterministic given the same inputs)."""

    total_slots: int = 16
    resolve_every: float = 0.0       # 0 disables periodic refit+re-solve
    max_time: float = math.inf       # hard stop (pending requests -> dropped)
    quarantine_factor: Optional[float] = None  # tick > factor*predicted ...
    quarantine_patience: int = 3               # ... this many times in a row
    rejoin_after: float = 5.0        # quarantined node re-joins after this


@dataclasses.dataclass(frozen=True)
class ServingReport:
    """What a run produced: the summary, the determinism fingerprint, and
    the final cluster shape."""

    summary: Dict[str, object]
    fingerprint: str
    allocations: Dict[int, int]
    counters: Dict[str, int]
    clock: float

    @property
    def sustained_req_s(self) -> float:
        return float(self.summary["sustained_req_s"])

    @property
    def goodput_req_s(self) -> float:
        return float(self.summary["goodput_req_s"])


class ServingRuntime:
    """Deterministic continuous-batching serving loop over one engine."""

    def __init__(
        self,
        engine: ServingEngine,
        allocator: ServingAllocator,
        workload: Workload,
        nodes: List[int],
        config: ServingConfig = ServingConfig(),
        metrics: Optional[ServingMetrics] = None,
    ):
        self.engine = engine
        self.allocator = allocator
        self.config = config
        self.metrics = metrics if metrics is not None else ServingMetrics()
        self.clock = 0.0
        self._arrivals = sorted(workload, key=lambda r: (r.arrival, r.rid))
        self._next_arrival = 0
        self._available: Dict[int, bool] = {int(n): True for n in nodes}
        self._tick_seq: Dict[int, int] = {int(n): 0 for n in nodes}
        self._busy_until: Dict[int, float] = {int(n): 0.0 for n in nodes}
        self._slow_ticks: Dict[int, int] = {int(n): 0 for n in nodes}
        self._heap: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self.events = {"leaves": 0, "joins": 0, "quarantines": 0, "resolves": 0}
        self.scheduler = BatchScheduler(self.allocator.solve(self._avail()))
        if config.resolve_every > 0:
            self._push(config.resolve_every, "resolve", None)

    # -- event plumbing ----------------------------------------------------

    def _push(self, time: float, kind: str, payload) -> None:
        heapq.heappush(self._heap, (float(time), self._seq, kind, payload))
        self._seq += 1

    def _avail(self) -> List[int]:
        return sorted(n for n, up in self._available.items() if up)

    def post(self, event: Event) -> None:
        """Inject a cluster-churn event (trainer alphabet): NodeJoin/NodeLeave."""
        if isinstance(event, NodeLeave):
            for node in event.nodes:
                self._push(event.time, "leave", int(node))
        elif isinstance(event, NodeJoin):
            for node in event.nodes:
                self._push(event.time, "join", int(node))
        else:
            raise TypeError(
                f"serving runtime only speaks NodeJoin/NodeLeave, got {type(event).__name__}"
            )

    # -- the loop ----------------------------------------------------------

    def run(self) -> ServingReport:
        """Process every arrival and event until the system drains (or
        ``max_time`` / total node loss strands the remainder as dropped)."""
        while True:
            ta = (
                self._arrivals[self._next_arrival].arrival
                if self._next_arrival < len(self._arrivals)
                else math.inf
            )
            te = self._heap[0][0] if self._heap else math.inf
            t = min(ta, te)
            if math.isinf(t) or t > self.config.max_time:
                break
            if ta <= te:
                self._on_arrival(self._arrivals[self._next_arrival])
            else:
                _, _, kind, payload = heapq.heappop(self._heap)
                self.clock = max(self.clock, te)
                if kind == "ready":
                    node, seq = payload
                    if self._available.get(node) and self._tick_seq[node] == seq:
                        self._tick(node)
                elif kind == "leave":
                    self._on_leave(payload)
                elif kind == "join":
                    self._on_join(payload)
                elif kind == "resolve":
                    self._on_resolve()
            if self._drained():
                break
        return self.report()

    def _drained(self) -> bool:
        if self._next_arrival < len(self._arrivals):
            return False
        if not self.scheduler.all_done():
            # Stranded only if nothing can ever make progress again: no
            # events pending and no available node to kick.
            return not self._heap and not self._avail()
        # Work done; churn events may remain but cannot create requests.
        return not any(k == "ready" for _, _, k, _ in self._heap)

    def _on_arrival(self, req) -> None:
        self.clock = max(self.clock, req.arrival)
        self._next_arrival += 1
        self.metrics.on_arrival(
            req.rid, req.arrival, req.deadline, req.prompt_len, req.gen_len
        )
        self.scheduler.enqueue(req)
        self.metrics.on_queue_sample(self.scheduler.queue_depth())
        self._kick_idle()

    def _kick_idle(self) -> None:
        for node in self._avail():
            if self._busy_until[node] <= self.clock:
                self._tick(node)

    def _tick(self, node: int) -> None:
        """One continuous-batching tick on ``node`` at ``self.clock``."""
        now = self.clock
        admitted = self.scheduler.admit(node, now)
        dt_prefill = self.engine.prefill(node, admitted) if admitted else 0.0
        actives = self.scheduler.active(node)
        if not actives:
            return  # idle: next arrival or requeue will kick us again
        decode_list = [ar for ar in actives if not ar.done]
        dt_decode = self.engine.decode(node, decode_list) if decode_list else 0.0
        t_prefill = now + dt_prefill
        t_end = t_prefill + dt_decode
        for ar in admitted:
            self.metrics.on_admit(ar.rid, now)
            self.metrics.on_token(ar.rid, t_prefill)
            if ar.first_token is None:
                ar.first_token = t_prefill
        for ar in decode_list:
            self.metrics.on_token(ar.rid, t_end)
        if decode_list:
            self.allocator.observe(node, len(decode_list), dt_decode)
            self._watch_quarantine(node, len(decode_list), dt_decode, t_end)
        for ar in [a for a in self.scheduler.active(node) if a.done]:
            self.scheduler.complete(ar)
            self.engine.release(ar)
            self.metrics.on_complete(ar.rid, t_end, node, ar.requeues)
        self.metrics.on_node_busy(node, dt_prefill + dt_decode)
        self._busy_until[node] = t_end
        self._tick_seq[node] += 1
        if not self.scheduler.all_done() or self._next_arrival < len(self._arrivals):
            self._push(t_end, "ready", (node, self._tick_seq[node]))

    def _watch_quarantine(self, node: int, batch: int, dt: float, now: float) -> None:
        factor = self.config.quarantine_factor
        if factor is None:
            return
        predicted = self.allocator.predicted_tick(node, batch)
        if predicted > 0 and dt > factor * predicted:
            self._slow_ticks[node] += 1
        else:
            self._slow_ticks[node] = 0
        if self._slow_ticks[node] >= self.config.quarantine_patience:
            self._slow_ticks[node] = 0
            self.events["quarantines"] += 1
            self._push(now, "leave", node)
            self._push(now + self.config.rejoin_after, "join", node)

    # -- churn -------------------------------------------------------------

    def _on_leave(self, node: int) -> None:
        if not self._available.get(node, False):
            return  # idempotent, like the trainer's runtime
        self._available[node] = False
        self._tick_seq[node] += 1  # invalidate any in-flight ready event
        victims = self.scheduler.drain_node(node)
        for ar in victims:
            self.engine.release(ar)
        self.events["leaves"] += 1
        self._reconcile()

    def _on_join(self, node: int) -> None:
        if self._available.get(node, False):
            return
        self._available[node] = True
        self._tick_seq.setdefault(node, 0)
        self._busy_until[node] = self.clock
        self._slow_ticks[node] = 0
        if node not in self.scheduler.nodes():
            self.scheduler.join_node(node, 0)
        self.events["joins"] += 1
        self._reconcile()

    def _on_resolve(self) -> None:
        self.events["resolves"] += 1
        self.allocator.refit()
        self._reconcile()
        work_left = (
            self._next_arrival < len(self._arrivals)
            or not self.scheduler.all_done()
        )
        # A re-solve can only matter if some node is (or will become) alive.
        alive = bool(self._avail()) or any(
            k == "join" for _, _, k, _ in self._heap
        )
        if work_left and alive:
            self._push(self.clock + self.config.resolve_every, "resolve", None)

    def _reconcile(self) -> None:
        """Re-solve over the available nodes and apply the new water-fill."""
        avail = self._avail()
        if not avail:
            return
        alloc = self.allocator.solve(avail)
        evicted = self.scheduler.set_allocations(alloc)
        for ar in evicted:
            self.engine.release(ar)
        self.metrics.on_queue_sample(self.scheduler.queue_depth())
        self._kick_idle()

    # -- results -----------------------------------------------------------

    def report(self) -> ServingReport:
        counters = dict(self.scheduler.counters)
        counters.update(self.events)
        counters["refits"] = self.allocator.refits
        counters["solves"] = self.allocator.solves
        return ServingReport(
            summary=self.metrics.summary(),
            fingerprint=self.metrics.fingerprint(),
            allocations={n: self.scheduler.allocation(n) for n in self.scheduler.nodes()},
            counters=counters,
            clock=self.clock,
        )

"""Deterministic fault injection for the cluster runtime.

Real heterogeneous clusters straggle, crash, flap, and lie about their
timings — Poplar treats tolerating slow workers as inseparable from
heterogeneous efficiency, and the paper's per-node coefficients (Eqs. 2–6)
only describe hardware that is actually healthy.  This module is the
*injection* third of the fault-tolerance layer (detection lives in
:mod:`repro.runtime.health`, recovery in the
:class:`~repro.runtime.runtime.ClusterRuntime` reconcile loop):

* :class:`FaultPlan` — a frozen, seeded schedule of faults over global
  epoch indices.  Same seed ⇒ bit-identical schedule; composable with any
  trace (:func:`~repro.runtime.trace.synthetic_trace` included) because it
  addresses nodes by global id and time by the runtime's epoch counter.
* :class:`FaultInjector` — applies the plan to ``SimBackend`` executions:
  it perturbs the :class:`~repro.core.simulator.StepMeasurement` stream
  *after* the simulated cluster ran, so the cluster's own RNG stream is
  never consumed or reordered — a replay with no active fault is
  bit-identical to a run with no injector at all.
* :class:`FlakyCheckpointIO` — the injectable I/O seam of
  :func:`repro.train.checkpoint.save`: fails the first N checkpoint write
  attempts with ``OSError`` so the runtime's retry/fallback path is
  exercised deterministically.

Fault kinds:

* :class:`NodeCrash` — silent stop (NOT a polite NodeLeave): from
  ``at_epoch`` the node produces no observations (``None`` in the
  measurement stream) while jobs still hold it, and every epoch that waits
  on it stalls by ``stall``x.  Detection must come from the *absence* of
  telemetry.
* :class:`Straggler` — transient throughput degradation: the node's
  observed a-part/backprop/comm times are multiplied by ``slowdown`` for
  ``duration`` epochs from ``at_epoch`` (cluster batch time follows, since
  the synchronous step waits for the slowest node).  Two windows on the
  same node model a *flapping* node.
* :class:`NoiseSpike` — a measurement-noise burst: per-step multiplicative
  lognormal jitter of scale ``scale`` on the node's observed times for the
  window.  Zero-mean in log-space, so a well-tuned detector should ride it
  out rather than quarantine.
* :class:`FlakyCheckpoints` — the first ``failures`` checkpoint writes
  raise ``OSError`` through the I/O seam.

Real-path integrity faults (the ``RealBackend`` seam, PR 7):

* :class:`GradientPoison` — a node's per-node gradient contribution goes
  NaN/Inf (or is scaled by a huge factor) for a seeded epoch window.  The
  backend multiplies each node's gradient by the injector's
  :meth:`FaultInjector.poison_factors` vector inside the jitted step; the
  factor is exactly ``1.0`` outside the window, and ``g * 1.0`` is
  IEEE-exact, so no-fault replays stay bit-identical.
* :class:`CheckpointCorruption` — bytes flipped (seeded offsets) in the
  Nth successfully written checkpoint payload, after the atomic rename —
  the on-disk rot that sha256 verification and generation rollback exist
  to survive.
* :class:`SolverStall` — a seeded artificial delay on the first OptPerf
  solve of each window epoch, tripping the deadline watchdog into the
  engine-degradation / last-known-good chain.

All random factors are drawn from *stateless* generators keyed by
``(plan seed, epoch, node)``, so the schedule is bit-identical no matter
how many jobs execute, in what order, or how often a trace is replayed.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.simulator import StepMeasurement

__all__ = [
    "NodeCrash",
    "Straggler",
    "NoiseSpike",
    "FlakyCheckpoints",
    "GradientPoison",
    "CheckpointCorruption",
    "SolverStall",
    "FaultPlan",
    "FaultInjector",
    "FlakyCheckpointIO",
    "FAULT_PLANS",
    "make_fault_plan",
]


# ---------------------------------------------------------------------------
# fault kinds
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NodeCrash:
    """Silent node death at ``at_epoch``: no observations, ``stall``x epoch
    stall for every job still holding the node.  Permanent — recovery is
    the runtime's job (detect, drain, checkpoint-restore), not the fault's."""

    node: int
    at_epoch: int
    stall: float = 2.0


@dataclasses.dataclass(frozen=True)
class Straggler:
    """Transient ``slowdown``x degradation of one node's observed times for
    ``duration`` epochs starting at ``at_epoch``."""

    node: int
    at_epoch: int
    duration: int
    slowdown: float = 3.0


@dataclasses.dataclass(frozen=True)
class NoiseSpike:
    """Measurement-noise burst: per-step lognormal jitter of scale
    ``scale`` on one node's observed times for ``duration`` epochs."""

    node: int
    at_epoch: int
    duration: int
    scale: float = 0.3


@dataclasses.dataclass(frozen=True)
class FlakyCheckpoints:
    """The first ``failures`` checkpoint write attempts raise OSError."""

    failures: int = 1


@dataclasses.dataclass(frozen=True)
class GradientPoison:
    """One node's gradient contribution is poisoned for ``duration`` epochs
    from ``at_epoch``: ``mode="nan"``/``"inf"`` makes it non-finite,
    ``mode="scale"`` multiplies it by ``factor`` (a gross norm outlier).
    The anomaly guard must exclude it before Eq. (9) aggregation."""

    node: int
    at_epoch: int
    duration: int
    mode: str = "nan"          # "nan" | "inf" | "scale"
    factor: float = 1e6        # used by mode="scale"

    def factor_value(self) -> float:
        if self.mode == "nan":
            return float("nan")
        if self.mode == "inf":
            return float("inf")
        if self.mode == "scale":
            return float(self.factor)
        raise ValueError(f"unknown GradientPoison mode {self.mode!r}")


@dataclasses.dataclass(frozen=True)
class CheckpointCorruption:
    """Flip ``n_bytes`` seeded bytes inside the ``write_index``-th (1-based)
    successfully written checkpoint file — after the atomic rename, so the
    archive exists and passes the torn-write defense but fails sha256
    verification (or outright unzipping) on load."""

    write_index: int = 1
    n_bytes: int = 24


@dataclasses.dataclass(frozen=True)
class SolverStall:
    """The first OptPerf solve of each epoch in the window stalls by
    ``delay`` real seconds — long enough to trip the deadline watchdog,
    which degrades the solver engine instead of hanging the reconcile."""

    at_epoch: int
    duration: int = 1
    delay: float = 0.05


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, immutable fault schedule (the chaos experiment's input).

    ``seed`` keys every stochastic draw (noise-spike jitter); the fault
    tuples are explicit, so the schedule is bit-identical by construction
    and printable for the trace log.
    """

    seed: int = 0
    crashes: Tuple[NodeCrash, ...] = ()
    stragglers: Tuple[Straggler, ...] = ()
    spikes: Tuple[NoiseSpike, ...] = ()
    flaky_checkpoints: Optional[FlakyCheckpoints] = None
    poisons: Tuple[GradientPoison, ...] = ()
    corruptions: Tuple[CheckpointCorruption, ...] = ()
    solver_stalls: Tuple[SolverStall, ...] = ()

    @classmethod
    def chaos(cls, n_nodes: int, seed: int = 0) -> "FaultPlan":
        """The default chaos plan over a >= 4-node cluster: one crash, one
        transient straggler, one flapping node (two straggler windows, so
        the quarantine backoff doubles), one noise spike, and one flaky
        checkpoint write.  Nodes are drawn from the seeded RNG, excluding
        the highest id (synthetic traces take that node down themselves)."""
        if n_nodes < 4:
            raise ValueError("chaos plan needs >= 4 nodes")
        rng = np.random.default_rng(seed)
        straggler, flapper, crash, spiky = (
            int(i) for i in rng.choice(n_nodes - 1, size=4, replace=False)
        )
        return cls(
            seed=seed,
            crashes=(NodeCrash(node=crash, at_epoch=10, stall=2.0),),
            stragglers=(
                Straggler(node=straggler, at_epoch=4, duration=3, slowdown=3.0),
                # The flapping node: degrades, gets quarantined, recovers,
                # then degrades again after re-admission (backoff doubles).
                Straggler(node=flapper, at_epoch=8, duration=2, slowdown=4.0),
                Straggler(node=flapper, at_epoch=14, duration=3, slowdown=4.0),
            ),
            spikes=(NoiseSpike(node=spiky, at_epoch=6, duration=3, scale=0.2),),
            flaky_checkpoints=FlakyCheckpoints(failures=1),
        )

    @classmethod
    def chaos_small(cls, n_nodes: int, seed: int = 0) -> "FaultPlan":
        """CI-sized chaos: the same fault mix compressed into fewer epochs
        (crash + straggler + flapping node inside a ~16-epoch replay)."""
        if n_nodes < 4:
            raise ValueError("chaos plan needs >= 4 nodes")
        rng = np.random.default_rng(seed)
        straggler, flapper, crash, spiky = (
            int(i) for i in rng.choice(n_nodes - 1, size=4, replace=False)
        )
        return cls(
            seed=seed,
            crashes=(NodeCrash(node=crash, at_epoch=8, stall=2.0),),
            stragglers=(
                Straggler(node=straggler, at_epoch=3, duration=3, slowdown=3.0),
                Straggler(node=flapper, at_epoch=6, duration=2, slowdown=4.0),
                Straggler(node=flapper, at_epoch=11, duration=2, slowdown=4.0),
            ),
            spikes=(NoiseSpike(node=spiky, at_epoch=5, duration=2, scale=0.2),),
            flaky_checkpoints=FlakyCheckpoints(failures=1),
        )

    @classmethod
    def chaos_real(cls, n_nodes: int, seed: int = 0) -> "FaultPlan":
        """The real-path integrity chaos plan over a >= 2-node cluster: one
        node emits NaN gradients for a window (the anomaly guard + numeric
        health channel must contain it), the second successful checkpoint
        write is corrupted on disk (generation rollback must recover), and
        one OptPerf solve stalls (the deadline watchdog must degrade the
        engine).  The poisoned node is drawn from the seeded RNG, excluding
        the highest id (synthetic traces take that node down themselves)."""
        if n_nodes < 2:
            raise ValueError("chaos-real plan needs >= 2 nodes")
        rng = np.random.default_rng(seed)
        poisoned = int(rng.integers(0, max(n_nodes - 1, 1)))
        return cls(
            seed=seed,
            poisons=(
                GradientPoison(node=poisoned, at_epoch=1, duration=2, mode="nan"),
            ),
            corruptions=(CheckpointCorruption(write_index=2, n_bytes=24),),
            solver_stalls=(SolverStall(at_epoch=0, duration=1, delay=0.05),),
        )

    def describe(self) -> List[str]:
        """One line per scheduled fault (trace logs)."""
        out = [
            f"crash(node={c.node}, epoch={c.at_epoch}, stall={c.stall}x)"
            for c in self.crashes
        ]
        out += [
            f"straggler(node={s.node}, epochs={s.at_epoch}..{s.at_epoch + s.duration - 1}, "
            f"{s.slowdown}x)"
            for s in self.stragglers
        ]
        out += [
            f"noise-spike(node={s.node}, epochs={s.at_epoch}..{s.at_epoch + s.duration - 1}, "
            f"scale={s.scale})"
            for s in self.spikes
        ]
        if self.flaky_checkpoints is not None:
            out.append(f"flaky-checkpoints(failures={self.flaky_checkpoints.failures})")
        out += [
            f"gradient-poison(node={p.node}, "
            f"epochs={p.at_epoch}..{p.at_epoch + p.duration - 1}, mode={p.mode})"
            for p in self.poisons
        ]
        out += [
            f"checkpoint-corruption(write={c.write_index}, bytes={c.n_bytes})"
            for c in self.corruptions
        ]
        out += [
            f"solver-stall(epochs={s.at_epoch}..{s.at_epoch + s.duration - 1}, "
            f"delay={s.delay}s)"
            for s in self.solver_stalls
        ]
        return out

    def counts(self) -> Dict[str, int]:
        return {
            "crashes": len(self.crashes),
            "stragglers": len(self.stragglers),
            "noise_spikes": len(self.spikes),
            "flaky_checkpoint_writes": (
                self.flaky_checkpoints.failures if self.flaky_checkpoints else 0
            ),
            "gradient_poisons": len(self.poisons),
            "checkpoint_corruptions": len(self.corruptions),
            "solver_stalls": len(self.solver_stalls),
        }


FAULT_PLANS = ("none", "chaos", "chaos-small", "chaos-real")


def make_fault_plan(name: str, n_nodes: int, seed: int = 0) -> Optional[FaultPlan]:
    """Build a named fault plan (the ``--faults`` CLI vocabulary)."""
    if name in ("none", ""):
        return None
    if name == "chaos":
        return FaultPlan.chaos(n_nodes, seed)
    if name == "chaos-small":
        return FaultPlan.chaos_small(n_nodes, seed)
    if name == "chaos-real":
        return FaultPlan.chaos_real(n_nodes, seed)
    raise ValueError(f"unknown fault plan {name!r}; choose from {FAULT_PLANS}")


# ---------------------------------------------------------------------------
# the injectable checkpoint I/O seam
# ---------------------------------------------------------------------------


class FlakyCheckpointIO:
    """Checkpoint I/O (the ``io`` seam of :func:`repro.train.checkpoint.save`)
    that raises ``OSError`` on the first ``failures`` write attempts, then
    behaves normally.  ``attempts``/``failed`` counters make the retry path
    observable."""

    def __init__(self, failures: int) -> None:
        self.failures = int(failures)
        self.attempts = 0
        self.failed = 0

    def open(self, path: str, mode: str):
        self.attempts += 1
        if self.failed < self.failures:
            self.failed += 1
            raise OSError(f"injected checkpoint write failure #{self.failed}")
        return open(path, mode)

    def replace(self, src: str, dst: str) -> None:
        os.replace(src, dst)


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------


class FaultInjector:
    """Applies a :class:`FaultPlan` to backend executions.

    One injector is shared by every job of a runtime; the runtime advances
    its global epoch counter (``begin_epoch``) and each job's ``SimBackend``
    calls :meth:`perturb` after its simulated epoch ran.  Perturbation is a
    pure post-transform of the measurement stream — the simulated cluster's
    RNG is untouched, so a no-fault epoch is bit-identical to an
    injector-free run (the layer is invisible until it fires).

    ``injected`` records each fault instance the first epoch it actually
    affected an execution (``{"kind", "node", "epoch"}``) — the telemetry
    the detection-latency / MTTR accounting matches against.
    """

    def __init__(self, plan: FaultPlan) -> None:
        self.plan = plan
        self.epoch = 0
        self.injected: List[Dict[str, object]] = []
        self._seen: set = set()
        self.checkpoint_io: Optional[FlakyCheckpointIO] = (
            FlakyCheckpointIO(plan.flaky_checkpoints.failures)
            if plan.flaky_checkpoints is not None
            else None
        )
        self.checkpoint_writes = 0        # successful writes seen (corruption clock)
        self.corrupted_paths: List[str] = []
        self._stalls_consumed: set = set()

    # -- schedule queries ------------------------------------------------

    def begin_epoch(self, epoch: int) -> None:
        self.epoch = int(epoch)

    def crashed(self, node: int) -> bool:
        return any(c.node == node and self.epoch >= c.at_epoch for c in self.plan.crashes)

    def slowdown(self, node: int) -> float:
        s = 1.0
        for w in self.plan.stragglers:
            if w.node == node and w.at_epoch <= self.epoch < w.at_epoch + w.duration:
                s *= w.slowdown
        return s

    def spike_scale(self, node: int) -> float:
        s = 0.0
        for w in self.plan.spikes:
            if w.node == node and w.at_epoch <= self.epoch < w.at_epoch + w.duration:
                s = max(s, w.scale)
        return s

    # -- real-backend integrity seams ------------------------------------

    def poison_factors(self, node_ids: Sequence[int]) -> np.ndarray:
        """Per-node gradient multipliers for the current epoch: exactly
        ``1.0`` for healthy nodes (``g * 1.0`` is IEEE-exact, so the seam
        is bit-transparent), NaN/Inf/huge inside a poison window."""
        out = np.ones(len(node_ids), dtype=np.float32)
        for i, nid in enumerate(node_ids):
            for p in self.plan.poisons:
                if p.node == int(nid) and p.at_epoch <= self.epoch < p.at_epoch + p.duration:
                    out[i] = np.float32(p.factor_value())
                    self._record(
                        "gradient-poison", int(nid), p.at_epoch,
                        ("poison", int(nid), p.at_epoch, p.duration),
                    )
        return out

    def corrupt_checkpoint(self, path: str) -> bool:
        """Called after each *successful* checkpoint write.  Counts the
        write; when its 1-based index matches a scheduled corruption, flips
        seeded bytes inside ``path``'s payload (past the zip local header)
        and returns True."""
        self.checkpoint_writes += 1
        hits = [
            c for c in self.plan.corruptions
            if c.write_index == self.checkpoint_writes
        ]
        if not hits:
            return False
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            for c in hits:
                rng = np.random.default_rng(
                    [max(self.plan.seed, 0), 202, self.checkpoint_writes]
                )
                lo = min(256, max(size // 2, 1))
                offsets = rng.integers(low=lo, high=size, size=c.n_bytes)
                for off in offsets:
                    f.seek(int(off))
                    b = f.read(1)
                    f.seek(int(off))
                    f.write(bytes([b[0] ^ 0xFF]))
                self._record(
                    "checkpoint-corruption", None, self.epoch,
                    ("corrupt", c.write_index),
                )
        self.corrupted_paths.append(path)
        return True

    def solver_stall(self) -> float:
        """Seconds the next OptPerf solve should stall — consumed once per
        (stall window, epoch), so the watchdog-triggered degradation retry
        is not re-stalled and makes progress."""
        for s in self.plan.solver_stalls:
            if s.at_epoch <= self.epoch < s.at_epoch + s.duration:
                key = ("stall", s.at_epoch, s.duration, self.epoch)
                if key in self._stalls_consumed:
                    continue
                self._stalls_consumed.add(key)
                self._record(
                    "solver-stall", None, s.at_epoch,
                    ("solver-stall", s.at_epoch, s.duration),
                )
                return float(s.delay)
        return 0.0

    # -- telemetry -------------------------------------------------------

    def _record(self, kind: str, node: int, onset: int, key: object) -> None:
        if key in self._seen:
            return
        self._seen.add(key)
        self.injected.append({"kind": kind, "node": node, "epoch": onset})

    def counts(self) -> Dict[str, int]:
        out = self.plan.counts()
        out["fired"] = len(self.injected)
        if self.checkpoint_io is not None:
            out["checkpoint_writes_failed"] = self.checkpoint_io.failed
        if self.corrupted_paths:
            out["checkpoints_corrupted"] = len(self.corrupted_paths)
        return out

    # -- the perturbation ------------------------------------------------

    def perturb(
        self,
        job: str,
        node_ids: Sequence[int],
        epoch_seconds: float,
        measurements: List[StepMeasurement],
    ) -> Tuple[float, List[StepMeasurement]]:
        """Transform one epoch's measurement stream per the active faults.

        ``node_ids`` maps measurement index -> global node id.  Crashed
        nodes report ``None`` observations (silent stop) and stall the
        synchronous step; stragglers/spikes scale the affected node's
        observed times (cluster batch time follows the slowest node).
        Returns the stream unchanged (same objects) when no fault touches
        this job's nodes — the bit-identity guarantee.
        """
        del job
        n = len(node_ids)
        crashed = [self.crashed(nid) for nid in node_ids]
        slows = [self.slowdown(nid) for nid in node_ids]
        scales = [self.spike_scale(nid) for nid in node_ids]
        if not any(crashed) and all(s == 1.0 for s in slows) and all(
            s == 0.0 for s in scales
        ):
            return epoch_seconds, measurements

        steps = len(measurements)
        # Stateless per-(seed, epoch, node) spike factors: bit-identical no
        # matter how many jobs run or in what order.
        spike_factors = np.ones((n, steps), dtype=np.float64)
        for i, scale in enumerate(scales):
            if scale > 0.0:
                rng = np.random.default_rng(
                    [max(self.plan.seed, 0), 101, self.epoch, int(node_ids[i])]
                )
                spike_factors[i] = np.exp(rng.normal(0.0, scale, size=steps))

        stall = 1.0
        for i, nid in enumerate(node_ids):
            if crashed[i]:
                for c in self.plan.crashes:
                    if c.node == nid and self.epoch >= c.at_epoch:
                        stall = max(stall, c.stall)
                        self._record("crash", nid, c.at_epoch, ("crash", nid, c.at_epoch))
            if slows[i] != 1.0:
                for w in self.plan.stragglers:
                    if w.node == nid and w.at_epoch <= self.epoch < w.at_epoch + w.duration:
                        self._record(
                            "straggler", nid, w.at_epoch,
                            ("straggler", nid, w.at_epoch, w.duration),
                        )
            if scales[i] > 0.0:
                for w in self.plan.spikes:
                    if w.node == nid and w.at_epoch <= self.epoch < w.at_epoch + w.duration:
                        self._record(
                            "noise-spike", nid, w.at_epoch,
                            ("noise-spike", nid, w.at_epoch, w.duration),
                        )

        out: List[StepMeasurement] = []
        total = 0.0
        for s, m in enumerate(measurements):
            obs_out = []
            slowest = 0.0
            for i, obs in enumerate(m.observations):
                if crashed[i] or obs is None:
                    obs_out.append(None)
                    continue
                factor = slows[i] * float(spike_factors[i, s])
                if factor != 1.0:
                    obs = dataclasses.replace(
                        obs,
                        a_time=obs.a_time * factor,
                        backprop_time=obs.backprop_time * factor,
                        comm_time=obs.comm_time * factor,
                    )
                obs_out.append(obs)
                slowest = max(slowest, obs.a_time + obs.backprop_time)
            # The synchronous step waits for the slowest surviving node and
            # stalls on dead ones (timeout semantics, not a clean exit).
            batch_time = max(m.batch_time, slowest) * stall
            total += batch_time
            out.append(
                StepMeasurement(
                    batch_time=batch_time,
                    node_times=(batch_time,) * n,
                    observations=tuple(obs_out),
                )
            )
        return total, out

"""Multi-job heterogeneity-aware scheduler + elastic controller tests
(paper §6 future-work items, implemented as beyond-paper extensions)."""
import numpy as np
import pytest

from repro.core.controller import CannikinController
from repro.core.perf_model import CommModel
from repro.core.scheduler import (
    Allocation,
    JobSpec,
    Scheduler,
    allocate,
    random_jobs,
)
from repro.core.simulator import GPU_CATALOG, SimulatedCluster, cluster_B


def make_job(name, node_names, total_batch, b_noise, scale=1.0, min_nodes=1):
    models = tuple(
        GPU_CATALOG[n].scaled(1.0 / scale).model() for n in node_names
    )
    return JobSpec(
        name=name,
        node_models=models,
        comm=CommModel(t_o=0.04 * scale, t_u=0.008 * scale, gamma=0.15),
        total_batch=total_batch,
        b_noise=b_noise,
        ref_batch=64,
        min_nodes=min_nodes,
    )


NODES = ["a100"] * 4 + ["v100"] * 4 + ["rtx6000"] * 8


def test_allocation_covers_cluster_and_jobs():
    jobs = [
        make_job("big", NODES, total_batch=1024, b_noise=2000.0, scale=2.0),
        make_job("small", NODES, total_batch=128, b_noise=200.0, scale=0.2),
    ]
    alloc = allocate(jobs, len(NODES))
    assigned = [n for ids in alloc.assignment.values() for n in ids]
    assert sorted(assigned) == sorted(set(assigned))  # disjoint
    assert all(len(ids) >= 1 for ids in alloc.assignment.values())
    assert all(g > 0 for g in alloc.goodputs.values())
    assert 0 < alloc.aggregate_fraction <= 2.0 + 1e-9


def test_big_job_gets_more_nodes():
    jobs = [
        make_job("big", NODES, total_batch=2048, b_noise=5000.0, scale=2.0),
        make_job("tiny", NODES, total_batch=64, b_noise=100.0, scale=0.1),
    ]
    alloc = allocate(jobs, len(NODES))
    assert len(alloc.assignment["big"]) > len(alloc.assignment["tiny"])


def test_greedy_beats_random_split():
    rng = np.random.default_rng(0)
    jobs = [
        make_job("a", NODES, total_batch=512, b_noise=1500.0, scale=1.0),
        make_job("b", NODES, total_batch=512, b_noise=1500.0, scale=1.0),
    ]
    alloc = allocate(jobs, len(NODES))
    greedy = alloc.aggregate_fraction
    # random disjoint splits
    worst_gap = 0.0
    for _ in range(10):
        perm = rng.permutation(len(NODES))
        half = len(NODES) // 2
        f = (
            jobs[0].goodput(tuple(perm[:half])) / max(jobs[0].solo_goodput(), 1e-12)
            + jobs[1].goodput(tuple(perm[half:])) / max(jobs[1].solo_goodput(), 1e-12)
        )
        assert greedy >= f - 1e-6


def test_min_nodes_respected():
    jobs = [
        make_job("needs4", NODES, total_batch=512, b_noise=1000.0, min_nodes=4),
        make_job("any", NODES, total_batch=256, b_noise=500.0),
    ]
    alloc = allocate(jobs, len(NODES))
    # min_nodes gates goodput to zero below the floor, so the greedy loop
    # keeps feeding the job until it produces goodput.
    assert len(alloc.assignment["needs4"]) >= 4 or alloc.goodputs["needs4"] == 0.0


# ---------------------------------------------------------------------------
# batched (stacked) allocation engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_batched_allocate_matches_scalar(seed):
    """The stacked per-round marginal evaluation emits the same assignment
    and the same goodputs as the per-(job, node) scalar loop (the job mix is
    the same seeded generator the benchmark gates use)."""
    jobs = random_jobs(4, 12, seed)
    a_b = allocate(jobs, 12, engine="batched")
    a_s = allocate(jobs, 12, engine="scalar")
    assert a_b.assignment == a_s.assignment
    for name in a_b.goodputs:
        assert a_b.goodputs[name] == pytest.approx(a_s.goodputs[name], rel=1e-12)
    assert a_b.aggregate_fraction == pytest.approx(a_s.aggregate_fraction, rel=1e-12)


def test_batched_allocate_min_nodes_and_identical_nodes():
    """Exact-tie rows (identical node models) and min_nodes floors break the
    same way in both engines."""
    jobs = [
        make_job("needs4", NODES, total_batch=512, b_noise=1000.0, min_nodes=4),
        make_job("any", NODES, total_batch=256, b_noise=500.0),
    ]
    a_b = allocate(jobs, len(NODES), engine="batched")
    a_s = allocate(jobs, len(NODES), engine="scalar")
    assert a_b.assignment == a_s.assignment


def test_nan_fit_job_degrades_gracefully_in_both_engines():
    """A job whose OLS fit produced a NaN coefficient must score goodput 0.0
    (validation rejects it with ValueError) — not crash the round — in the
    batched engine exactly like the scalar one."""
    from repro.core.perf_model import NodePerfModel as NPM

    bad_node_sets = {
        "nan-q": tuple(NPM(q=float("nan"), s=0.0, k=1e-3, m=0.0) for _ in range(4)),
        # k <= 0 with alpha = q + k still positive: only a k-specific check
        # catches it, exactly like the per-node NodePerfModel validation.
        "neg-k": tuple(NPM(q=1e-2, s=0.0, k=-1e-4, m=0.0) for _ in range(4)),
        # q < 0 with alpha and beta still positive: only the q-specific
        # (alpha - k >= 0) check catches it.
        "neg-q": tuple(NPM(q=-5e-3, s=0.0, k=1e-1, m=0.0) for _ in range(4)),
    }
    ok_job = make_job("ok", ["a100"] * 4, total_batch=128, b_noise=500.0)
    for label, models in bad_node_sets.items():
        bad_job = JobSpec(
            name="broken",
            node_models=models,
            comm=CommModel(t_o=0.02, t_u=0.005, gamma=0.1),
            total_batch=128,
            b_noise=500.0,
            ref_batch=64,
        )
        allocs = {
            engine: allocate([bad_job, ok_job], 4, engine=engine)
            for engine in ("batched", "scalar")
        }
        for engine, alloc in allocs.items():
            assert alloc.goodputs["broken"] == 0.0, (label, engine)
            assert alloc.goodputs["ok"] > 0.0, (label, engine)
        assert allocs["batched"].assignment == allocs["scalar"].assignment, label


def test_allocate_unknown_engine_raises():
    with pytest.raises(ValueError):
        allocate([], 4, engine="vectorised")


def test_allocate_empty_jobs():
    assert allocate([], 8).assignment == {}


# ---------------------------------------------------------------------------
# incremental Scheduler (add/remove/update_job)
# ---------------------------------------------------------------------------


def _goodputs_equal(a: Allocation, b: Allocation) -> None:
    assert a.assignment == b.assignment
    for name in b.goodputs:
        assert a.goodputs[name] == pytest.approx(b.goodputs[name], rel=1e-12)


@pytest.mark.parametrize("seed", range(4))
def test_scheduler_add_job_matches_full_reallocation(seed):
    """Incremental arrival: the warm/cached re-run emits the same assignment
    and the same (scalar-path) goodputs as a cold full allocate."""
    jobs = random_jobs(5, 14, seed)
    sched = Scheduler(14)
    for job in jobs[:4]:
        sched.add_job(job)
    _goodputs_equal(sched.allocation, allocate(jobs[:4], 14))
    inc = sched.add_job(jobs[4])
    _goodputs_equal(inc, allocate(jobs, 14))


@pytest.mark.parametrize("seed", range(4))
def test_scheduler_remove_job_matches_full_reallocation(seed):
    jobs = random_jobs(5, 14, 100 + seed)
    sched = Scheduler(14)
    for job in jobs:
        sched.add_job(job)
    gone = jobs[seed % len(jobs)].name
    inc = sched.remove_job(gone)
    kept = [j for j in jobs if j.name != gone]
    _goodputs_equal(inc, allocate(kept, 14))
    assert gone not in inc.assignment


def test_scheduler_incremental_reuses_cached_rows():
    """A replayed trajectory hits the per-(job, node-set) row cache: the
    second identical reallocate solves (almost) nothing, and an arrival
    re-solves far fewer rows than the full run did."""
    jobs = random_jobs(4, 12, 11)
    sched = Scheduler(12)
    for job in jobs[:3]:
        sched.add_job(job)
    solved_before = sched.solved_rows
    sched.reallocate()  # identical job set: full cache replay
    assert sched.solved_rows == solved_before
    assert sched.cached_rows > 0
    sched.add_job(jobs[3])
    arrival_solved = sched.solved_rows - solved_before
    assert 0 < arrival_solved < solved_before
    assert sched.warm_rounds > 0  # diverged rounds re-solve warm-seeded


def test_scheduler_update_job_invalidates_stale_caches():
    """Satellite regression: a coefficient refresh (per-epoch OLS refit)
    must invalidate the refreshed job's cached rows/goodputs — serving the
    old-regime values would emit a stale allocation."""
    jobs = random_jobs(3, 10, 21)
    sched = Scheduler(10)
    for job in jobs:
        sched.add_job(job)
    # Refit job0 4x slower: same name, refreshed coefficients.
    slow = JobSpec(
        name=jobs[0].name,
        node_models=tuple(
            type(m)(q=m.q * 4, s=m.s * 4, k=m.k * 4, m=m.m * 4)
            for m in jobs[0].node_models
        ),
        comm=jobs[0].comm,
        total_batch=jobs[0].total_batch,
        b_noise=jobs[0].b_noise,
        ref_batch=jobs[0].ref_batch,
        min_nodes=jobs[0].min_nodes,
    )
    updated = sched.update_job(slow)
    _goodputs_equal(updated, allocate([slow, jobs[1], jobs[2]], 10))
    # The refresh really changed the outcome vs the stale spec.
    stale = allocate(jobs, 10)
    assert (
        updated.assignment != stale.assignment
        or updated.goodputs[slow.name] != pytest.approx(stale.goodputs[slow.name])
    )


def test_scheduler_update_unknown_or_duplicate_job_raises():
    jobs = random_jobs(2, 6, 31)
    sched = Scheduler(6)
    sched.add_job(jobs[0])
    with pytest.raises(ValueError):
        sched.add_job(jobs[0])
    with pytest.raises(KeyError):
        sched.update_job(jobs[1])
    with pytest.raises(KeyError):
        sched.remove_job("nope")


def test_scheduler_empty_and_scalar_engine():
    sched = Scheduler(8, engine="scalar")
    assert sched.reallocate().assignment == {}
    jobs = random_jobs(2, 8, 41)
    for job in jobs:
        sched.add_job(job)
    _goodputs_equal(sched.allocation, allocate(jobs, 8, engine="scalar"))
    with pytest.raises(ValueError):
        Scheduler(8, engine="vectorised")


def test_allocate_rejects_duplicate_job_names():
    jobs = random_jobs(2, 6, 51)
    dup = [jobs[0], jobs[0]]
    with pytest.raises(ValueError):
        allocate(dup, 6)


# ---------------------------------------------------------------------------
# aggregate guards (empty / zero-node / NaN allocations)
# ---------------------------------------------------------------------------


def test_aggregate_guards_empty_and_nan():
    """Empty and NaN-poisoned allocations aggregate to finite values, never
    NaN — a zero-node or garbage-normalized job must not break the trace
    summary arithmetic."""
    empty = Allocation({}, {}, {})
    assert empty.aggregate_fraction == 0.0
    assert empty.aggregate_goodput == 0.0

    nan = float("nan")
    poisoned = Allocation(
        assignment={"ok": (0, 1), "broken": ()},
        goodputs={"ok": 10.0, "broken": nan},
        fractions={"ok": 0.5, "broken": nan},
    )
    assert poisoned.aggregate_fraction == pytest.approx(0.5)
    assert poisoned.aggregate_goodput == pytest.approx(10.0)
    from repro.core.scheduler import aggregate_goodput

    assert aggregate_goodput([], poisoned) == pytest.approx(10.0)
    assert aggregate_goodput([], empty) == 0.0


# ---------------------------------------------------------------------------
# node availability (down/drained nodes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["batched", "scalar"])
def test_allocate_unavailable_nodes_excluded(engine):
    jobs = random_jobs(3, 10, seed=61)
    alloc = allocate(jobs, 10, engine=engine, unavailable=[0, 9])
    assigned = {n for ids in alloc.assignment.values() for n in ids}
    assert not assigned & {0, 9}
    # Equivalent to the same jobs on the available sub-pool: masked engines
    # still agree with each other.
    other = allocate(jobs, 10, engine="scalar" if engine == "batched" else "batched",
                     unavailable=[0, 9])
    assert alloc.assignment == other.assignment
    # Out-of-range ids must raise identically in every engine (negative ids
    # would alias real rows in the array engine but not the scalar one).
    for bad in ([-1], [10]):
        with pytest.raises(ValueError):
            allocate(jobs, 10, engine=engine, unavailable=bad)


def test_scheduler_node_leave_join_incremental_and_correct():
    """node_leave/node_join re-allocate incrementally (row layout and caches
    preserved) and match a cold allocate with the same availability."""
    jobs = random_jobs(3, 8, seed=71)
    sched = Scheduler(8)
    for job in jobs:
        sched.add_job(job)
    solved_before = sched.solved_rows

    left = sched.node_leave([7])
    assert all(7 not in ids for ids in left.assignment.values())
    assert sched.down_nodes == (7,)
    assert sched.available_nodes == 7
    _goodputs_equal(left, allocate(jobs, 8, unavailable=[7]))
    # Incremental: the leave re-run cost less than the three arrivals did.
    assert sched.solved_rows - solved_before < solved_before
    assert sched.cached_rows > 0

    back = sched.node_join([7])
    assert sched.down_nodes == ()
    _goodputs_equal(back, allocate(jobs, 8))
    with pytest.raises(ValueError):
        sched.node_leave([8])


# ---------------------------------------------------------------------------
# bounded per-job cache eviction (FIFO) under long churn
# ---------------------------------------------------------------------------


def test_scheduler_cache_eviction_fifo_under_long_churn():
    """Long arrival/departure sequences overflow the bounded per-job caches;
    FIFO eviction must keep every cache at/under its limit while allocations
    stay identical to a cold reallocate() — evicted entries are a perf
    matter, never a correctness one."""
    n_nodes, limit = 6, 4
    pool = random_jobs(7, n_nodes, seed=81)
    sched = Scheduler(n_nodes, cache_limit=limit)
    live = {}
    saw_full_cache = False
    for step in range(40):
        job = pool[step % len(pool)]
        if job.name in live:
            sched.remove_job(job.name)
            del live[job.name]
        else:
            sched.add_job(job)
            live[job.name] = job
        for cache in list(sched._gain_cache.values()) + list(sched._take_cache.values()):
            assert len(cache) <= limit
            saw_full_cache = saw_full_cache or len(cache) == limit
        if live and step % 5 == 0:
            cold = allocate(list(live.values()), n_nodes)
            assert sched.allocation.assignment == cold.assignment
            for name in cold.goodputs:
                assert sched.allocation.goodputs[name] == pytest.approx(
                    cold.goodputs[name], rel=1e-12
                )
    # The churn really overflowed the bound (otherwise this test proves
    # nothing about eviction).
    assert saw_full_cache
    assert live
    final_cold = allocate(list(live.values()), n_nodes)
    assert sched.reallocate().assignment == final_cold.assignment
    with pytest.raises(ValueError):
        Scheduler(4, cache_limit=0)


def test_scheduler_cache_fifo_evicts_oldest_first():
    """The bounded insert is FIFO: once a per-job cache is full, the oldest
    trajectory key is the one dropped.  A solo job's greedy run inserts its
    trajectory prefixes in take order — (), (a), (a, b), ... — so with a
    limit of 2 only the two *longest* prefixes may survive."""
    jobs = random_jobs(1, 6, seed=91)
    sched = Scheduler(6, cache_limit=2)
    sched.add_job(jobs[0])
    cache = sched._gain_cache[jobs[0].name]
    assert len(cache) == 2
    lens = sorted(len(key) for key in cache)
    assert lens[1] == lens[0] + 1   # the two most recent prefixes, in order
    assert () not in cache          # the oldest (empty-set) key went first


# ---------------------------------------------------------------------------
# elastic controller
# ---------------------------------------------------------------------------


def _learn(ctrl, sim, epochs=3, steps=4):
    for _ in range(epochs):
        plan = ctrl.plan_epoch()
        _, ms = sim.run_epoch(list(plan.batches), steps)
        ctrl.observe_epoch(ms)
    return plan


def test_remove_nodes_keeps_models():
    profiles, comm = cluster_B()
    sim = SimulatedCluster(profiles, comm, noise=0.005, seed=0)
    ctrl = CannikinController(sim.n, batch_candidates=[256], ref_batch=256,
                             adaptive=False)
    _learn(ctrl, sim, epochs=4)
    assert ctrl.last_plan.phase == "optperf"
    # Scheduler takes the 8 rtx nodes away.
    ctrl.remove_nodes(list(range(8, 16)))
    plan = ctrl.plan_epoch()
    assert plan.phase == "optperf"  # no re-bootstrap: models retained
    assert len(plan.batches) == 8
    assert sum(plan.batches) == 256
    # Remaining nodes are the (faster) a100/v100s: predicted time must beat
    # the LB-BSP-style even split over them.
    sub = SimulatedCluster(profiles[:8], comm, noise=0.0, seed=0)
    even = sub.run_batch([32] * 8).batch_time
    opt = sub.run_batch(list(plan.batches)).batch_time
    assert opt <= even * 1.02


def test_add_nodes_triggers_bootstrap():
    profiles, comm = cluster_B()
    sim = SimulatedCluster(profiles, comm, noise=0.005, seed=0)
    ctrl = CannikinController(sim.n, batch_candidates=[256], ref_batch=256,
                             adaptive=False)
    _learn(ctrl, sim, epochs=4)
    ctrl.add_nodes(2)
    plan = ctrl.plan_epoch()
    assert plan.phase == "bootstrap"  # two re-learning epochs (paper §6)
    assert len(plan.batches) == 18
    # After the new nodes see two distinct batch sizes, optperf resumes.
    profiles2 = list(profiles) + [profiles[0], profiles[1]]
    sim2 = SimulatedCluster(profiles2, comm, noise=0.005, seed=1)
    for _ in range(3):
        _, ms = sim2.run_epoch(list(plan.batches), 4)
        ctrl.observe_epoch(ms)
        plan = ctrl.plan_epoch()
    assert plan.phase == "optperf"
    assert len(plan.batches) == 18


@pytest.mark.parametrize("change", ["remove", "add"])
def test_membership_change_evicts_device_coeff_export(change):
    """Satellite regression: add_nodes/remove_nodes must evict the current
    model's cached device-coefficient export — the orphaned membership's
    stack must neither stay pinned on the device nor be reusable."""
    pytest.importorskip("jax")
    from repro.core import optperf_jax

    if not optperf_jax.HAS_JAX:
        pytest.skip("jax unavailable")
    profiles, comm = cluster_B()
    sim = SimulatedCluster(profiles, comm, noise=0.005, seed=0)
    ctrl = CannikinController(
        sim.n, batch_candidates=[256], ref_batch=256, adaptive=False,
        sweep_engine="jax",
    )
    _learn(ctrl, sim, epochs=3)
    model = ctrl.cluster_model()  # prefetches the device export (jax engine)
    assert any(key[0] == model for key in optperf_jax._DEVICE_COEFFS)
    if change == "remove":
        ctrl.remove_nodes([sim.n - 1])
    else:
        ctrl.add_nodes(1)
    assert not any(key[0] == model for key in optperf_jax._DEVICE_COEFFS)

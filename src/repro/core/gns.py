"""Gradient noise scale (GNS) estimation in heterogeneous clusters (§4.4, App. B).

The GNS  B_noise = tr(Sigma) / |G|^2  drives adaptive batch sizing
(McCandlish et al. 2018).  With *unequal* local batch sizes b_i the classic
homogeneous estimators are biased / suboptimal; the paper constructs, per
node i,

    G_i = (B |g|^2 - b_i |g_i|^2) / (B - b_i)          (unbiased for |G|^2)
    S_i = b_i B (|g_i|^2 - |g|^2) / (B - b_i)          (unbiased for tr(Sigma))

and combines them with the *minimum-variance unbiased linear* weights of
Theorem 4.1:

    w = 1^T A^{-1} / (1^T A^{-1} 1)

where A_G / A_S are the (scaled) covariance matrices of the local estimators
with closed-form entries:

    a_G(i,i) = (B + 2 b_i) / (B^2 - B b_i)
    a_G(i,j) = (B^2 - b_i^2 - b_j^2) / (B (B - b_i)(B - b_j))
    a_S(i,i) = B b_i / (B - b_i)
    a_S(i,j) = b_i b_j (B - b_i - b_j) / ((B - b_i)(B - b_j))

Everything here is JAX-traceable so it can run inside a jitted train step;
numpy entry points are provided for the controller.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "local_estimates",
    "gns_weights",
    "estimate_gns",
    "GNSState",
    "gns_update",
    "homogeneous_gns",
]


def local_estimates(
    local_sqnorms: jnp.ndarray,
    global_sqnorm: jnp.ndarray,
    batches: jnp.ndarray,
    total_batch: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. (10): per-node unbiased estimates (G_i, S_i) of |G|^2 and tr(Sigma).

    Args:
      local_sqnorms: ``|g_i|^2`` per node, shape (n,).
      global_sqnorm: ``|g|^2`` of the weighted global gradient, scalar.
      batches: local batch sizes b_i, shape (n,).
      total_batch: B = sum(b_i), scalar.
    """
    b = batches.astype(jnp.float64) if batches.dtype != jnp.float32 else batches
    B = total_batch
    g_i = (B * global_sqnorm - b * local_sqnorms) / (B - b)
    s_i = (b * B) / (B - b) * (local_sqnorms - global_sqnorm)
    return g_i, s_i


def _a_g_matrix(batches: np.ndarray, total_batch: float) -> np.ndarray:
    b = np.asarray(batches, dtype=np.float64)
    B = float(total_batch)
    n = b.size
    bi = b[:, None]
    bj = b[None, :]
    off = (B**2 - bi**2 - bj**2) / (B * (B - bi) * (B - bj))
    diag = (B + 2 * b) / (B**2 - B * b)
    a = off
    a[np.arange(n), np.arange(n)] = diag
    return a


def _a_s_matrix(batches: np.ndarray, total_batch: float) -> np.ndarray:
    b = np.asarray(batches, dtype=np.float64)
    B = float(total_batch)
    n = b.size
    bi = b[:, None]
    bj = b[None, :]
    off = (bi * bj * (B - bi - bj)) / ((B - bi) * (B - bj))
    diag = (B * b) / (B - b)
    a = off
    a[np.arange(n), np.arange(n)] = diag
    return a


def _a_g_matrix_corrected(batches: np.ndarray, total_batch: float) -> np.ndarray:
    """Corrected covariance of G_i (beyond-paper; see DESIGN.md §9 and
    EXPERIMENTS.md).  The paper's Lemma B.5 decomposes |g|^2 into per-node
    squared terms and drops the cross terms g_j . g_l; keeping them yields
    Cov(|g|^2, |g_i|^2) = 4|G|^2 tr(Sigma)/B  (batch-independent), giving

        a'_G(i,i) = 1/(B - b_i)
        a'_G(i,j) = (B - b_i - b_j)/((B - b_i)(B - b_j))

    (common factor 4|G|^2 tr(Sigma) dropped).  Monte-Carlo covariance of the
    estimators matches these entries, not the paper's (tests/test_gns.py)."""
    b = np.asarray(batches, dtype=np.float64)
    B = float(total_batch)
    n = b.size
    bi = b[:, None]
    bj = b[None, :]
    a = (B - bi - bj) / ((B - bi) * (B - bj))
    a[np.arange(n), np.arange(n)] = 1.0 / (B - b)
    return a


def _a_s_matrix_corrected(batches: np.ndarray, total_batch: float) -> np.ndarray:
    """Corrected covariance of S_i: the diagonal agrees with the paper,
    the off-diagonal is *negative*:

        a'_S(i,i) = B b_i / (B - b_i)
        a'_S(i,j) = - B b_i b_j / ((B - b_i)(B - b_j))
    """
    b = np.asarray(batches, dtype=np.float64)
    B = float(total_batch)
    n = b.size
    bi = b[:, None]
    bj = b[None, :]
    a = -(B * bi * bj) / ((B - bi) * (B - bj))
    a[np.arange(n), np.arange(n)] = (B * b) / (B - b)
    return a


def _min_variance_weights(a: np.ndarray) -> np.ndarray:
    """w = 1^T A^-1 / (1^T A^-1 1), robust to singular A.

    The corrected A_S is *exactly* singular for equal batches (rows sum to
    zero: the plain average has zero leading-order variance there), so we
    use lstsq and fall back to equal weights when the normalizer vanishes
    or the solution blows up."""
    n = a.shape[0]
    ones = np.ones(n)
    sol, *_ = np.linalg.lstsq(a, ones, rcond=None)
    denom = ones @ sol
    scale = np.abs(sol).max()
    if (
        not np.isfinite(denom)
        or not np.all(np.isfinite(sol))
        or abs(denom) < 1e-9 * max(scale, 1e-30)
    ):
        return ones / n
    w = sol / denom
    if np.abs(w).max() > 1e4:
        return ones / n
    return w


def gns_weights(
    batches: Sequence[float], total_batch: float, *, corrected: bool = True
) -> Tuple[np.ndarray, np.ndarray]:
    """Theorem 4.1 optimal weights (w_G, w_S) for the local estimators.

    ``corrected=False`` uses the paper's printed A_G/A_S entries verbatim
    (the paper-faithful baseline); ``corrected=True`` (default) uses the
    cross-term-corrected covariances, which empirically achieve the
    minimum-variance property Theorem 4.1 claims (see tests/test_gns.py and
    EXPERIMENTS.md §Reproduction-notes).

    Weights sum to one (unbiasedness); computed in float64 numpy — they only
    change when the batch partition changes, so the controller caches them.
    """
    b = np.asarray(batches, dtype=np.float64)
    if np.any(b <= 0):
        raise ValueError("local batches must be positive")
    if np.any(b >= total_batch):
        raise ValueError("each local batch must be < total batch")
    if corrected:
        # Closed form (beyond-paper; see EXPERIMENTS.md §Reproduction-notes):
        # v_i = B - b_i satisfies  A'_G v = (n-1) 1  and  A'_S v = 0 exactly,
        # so w_i = (B - b_i)/((n-1) B) is the minimum-variance unbiased
        # combination for BOTH estimators — and for S it cancels the
        # leading-order |G|-noise entirely:
        #   S = [sum_i b_i |g_i|^2 - B |g|^2] / (n - 1).
        n = b.size
        w = (total_batch - b) / ((n - 1) * total_batch)
        return w.copy(), w.copy()
    w_g = _min_variance_weights(_a_g_matrix(b, total_batch))
    w_s = _min_variance_weights(_a_s_matrix(b, total_batch))
    return w_g, w_s


def estimate_gns(
    local_sqnorms: Sequence[float],
    global_sqnorm: float,
    batches: Sequence[float],
    *,
    weights: Optional[Tuple[np.ndarray, np.ndarray]] = None,
) -> Tuple[float, float, float]:
    """One-shot heterogeneous GNS estimate.

    Returns ``(B_noise, G, S)`` where G estimates |G|^2 and S estimates
    tr(Sigma).  Individual draws can be negative (the estimators are unbiased,
    not positive); the EMA wrapper below is what production code uses.
    """
    b = np.asarray(batches, dtype=np.float64)
    B = float(b.sum())
    if weights is None:
        weights = gns_weights(b, B)
    w_g, w_s = weights
    sq = np.asarray(local_sqnorms, dtype=np.float64)
    g_i = (B * global_sqnorm - b * sq) / (B - b)
    s_i = (b * B) / (B - b) * (sq - global_sqnorm)
    g = float(np.asarray(w_g) @ g_i)
    s = float(np.asarray(w_s) @ s_i)
    b_noise = s / g if g != 0 else float("inf")
    return b_noise, g, s


def homogeneous_gns(
    local_sqnorms: Sequence[float], global_sqnorm: float, batches: Sequence[float]
) -> Tuple[float, float, float]:
    """Plain-average aggregation (the homogeneous-cluster baseline used by
    AdaptDL/Pollux).  Correct only when all b_i are equal; kept as the
    comparison target for the variance experiments."""
    n = len(local_sqnorms)
    w = np.ones(n) / n
    b = np.asarray(batches, dtype=np.float64)
    B = float(b.sum())
    sq = np.asarray(local_sqnorms, dtype=np.float64)
    g_i = (B * global_sqnorm - b * sq) / (B - b)
    s_i = (b * B) / (B - b) * (sq - global_sqnorm)
    g = float(w @ g_i)
    s = float(w @ s_i)
    return (s / g if g != 0 else float("inf")), g, s


# ---------------------------------------------------------------------------
# Running (EMA) estimator — what the training loop uses
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GNSState:
    """Exponential moving averages of S and G (Pollux-style: smooth the
    numerator and denominator separately, then take the ratio — the ratio of
    EMAs is far less biased than the EMA of ratios)."""

    ema_g: float = 0.0
    ema_s: float = 0.0
    count: int = 0

    @property
    def b_noise(self) -> float:
        if self.count == 0 or self.ema_g <= 0:
            return float("inf")
        return max(self.ema_s / self.ema_g, 0.0)

    def efficiency(self, batch_size: float) -> float:
        """Pollux statistical efficiency at total batch B:
        E(B) = (B_noise + B0) / (B_noise + B) evaluated with B0 -> per-sample
        normalization; we use the standard McCandlish form
        E(B) = 1 / (1 + B_noise / B) — the expected per-sample progress."""
        bn = self.b_noise
        if not np.isfinite(bn):
            return 1.0
        return 1.0 / (1.0 + bn / batch_size)


def gns_update(
    state: GNSState, g: float, s: float, *, decay: float = 0.9
) -> GNSState:
    """Bias-corrected EMA update with one observation of (G, S)."""
    count = state.count + 1
    # Standard Adam-style bias correction via counting.
    ema_g = decay * state.ema_g + (1.0 - decay) * g
    ema_s = decay * state.ema_s + (1.0 - decay) * s
    return GNSState(ema_g=ema_g, ema_s=ema_s, count=count)

"""JAX on-device OptPerf engine: jax-vs-batched-vs-scalar equivalence across
cluster sizes, warm-seeded device brackets, device-coefficient caching, and
selector/controller integration."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX-compiling; excluded from the fast lane

jax = pytest.importorskip("jax")
from jax.experimental import enable_x64  # noqa: E402

from repro.core.goodput import BatchSizeSelector  # noqa: E402
from repro.core.optperf import (  # noqa: E402
    solve_optperf_batch,
    solve_optperf_waterfill,
)
from repro.core.optperf_jax import (  # noqa: E402
    HAS_JAX,
    device_coeffs,
    solve_optperf_batch_jax,
)
from repro.core.perf_model import ClusterPerfModel, CommModel, NodePerfModel  # noqa: E402


def random_model(rng: np.random.Generator, n: int) -> ClusterPerfModel:
    nodes = tuple(
        NodePerfModel(
            q=float(rng.uniform(1e-4, 8e-3)),
            s=float(rng.uniform(0.0, 0.02)),
            k=float(rng.uniform(1e-4, 8e-3)),
            m=float(rng.uniform(0.0, 0.02)),
        )
        for _ in range(n)
    )
    comm = CommModel(
        t_o=float(10.0 ** rng.uniform(-4, -1)),
        t_u=float(rng.uniform(0.0, 0.02)),
        gamma=float(rng.uniform(0.02, 0.6)),
    )
    return ClusterPerfModel(nodes=nodes, comm=comm)


def test_has_jax_in_test_image():
    assert HAS_JAX


@pytest.mark.parametrize("n", [2, 16, 64, 256])
def test_jax_vs_batched_vs_scalar_equivalence_x64(n):
    """Acceptance: under x64 the on-device sweep, the NumPy batched engine,
    and the scalar water-fill oracle agree within 1e-6 relative opt_perf
    (they actually agree to ~1e-9) for n in {2, 16, 64, 256}."""
    with enable_x64():
        for seed in range(5):
            rng = np.random.default_rng(1000 * n + seed)
            model = random_model(rng, n)
            cands = np.unique(np.round(rng.uniform(8, 8192, size=5)))
            jx = solve_optperf_batch_jax(model, cands)
            batched = solve_optperf_batch(model, cands)
            for j, b in enumerate(cands):
                wf = solve_optperf_waterfill(model, float(b))
                assert jx.opt_perfs[j] == pytest.approx(wf.opt_perf, rel=1e-6)
                assert jx.opt_perfs[j] == pytest.approx(
                    float(batched.opt_perfs[j]), rel=1e-6
                )
                assert jx.batches[j].sum() == pytest.approx(b, rel=1e-9)
                assert jx.batches[j].min() >= 0.0


@pytest.mark.parametrize("n", [2, 16, 64])
def test_jax_float32_within_1e5_of_oracle(n):
    """The default (float32) device sweep stays within the 1e-5 gate."""
    for seed in range(5):
        rng = np.random.default_rng(2000 * n + seed)
        model = random_model(rng, n)
        cands = np.unique(np.round(rng.uniform(8, 8192, size=5)))
        jx = solve_optperf_batch_jax(model, cands)
        for j, b in enumerate(cands):
            wf = solve_optperf_waterfill(model, float(b))
            assert jx.opt_perfs[j] == pytest.approx(wf.opt_perf, rel=1e-5)


def test_jax_warm_start_matches_cold():
    rng = np.random.default_rng(5)
    model = random_model(rng, 32)
    cands = np.unique(np.round(np.geomspace(64, 16384, 16)))
    cold = solve_optperf_batch_jax(model, cands)
    warm = solve_optperf_batch_jax(model, cands, warm_start=cold.t_stars)
    np.testing.assert_allclose(warm.opt_perfs, cold.opt_perfs, rtol=1e-5)
    assert warm.method == "waterfill/jax+warm"
    # Stale/garbage warm seeds are re-validated: lows reset on device, highs
    # clamped to the single-node optimum ceiling, and the warm while_loop
    # keeps halving until converged — so even wild seeds stay correct.
    for garbage in (np.zeros(cands.shape), np.full(cands.shape, 1e9)):
        stale = solve_optperf_batch_jax(model, cands, warm_start=garbage)
        np.testing.assert_allclose(stale.opt_perfs, cold.opt_perfs, rtol=1e-4)


def test_jax_warm_start_shape_mismatch_raises():
    rng = np.random.default_rng(6)
    model = random_model(rng, 4)
    with pytest.raises(ValueError):
        solve_optperf_batch_jax(model, [64.0, 128.0], warm_start=np.zeros(3))


def test_device_coeffs_cached_and_dtype_keyed():
    rng = np.random.default_rng(7)
    model = random_model(rng, 6)
    a = device_coeffs(model)
    b = device_coeffs(model)
    assert a is b                       # cached per (model, dtype)
    assert a.alphas.shape == (6,)
    assert a.alphas.dtype == np.float32 or a.alphas.dtype == np.float64
    with enable_x64():
        c = device_coeffs(model)
    assert c.alphas.dtype == np.float64  # x64 flip gets its own cache entry
    np.testing.assert_allclose(
        np.asarray(c.alphas), model.coeffs.alphas, rtol=1e-12
    )


def test_selector_jax_engine_matches_batched_plans():
    rng = np.random.default_rng(23)
    for trial in range(5):
        model = random_model(rng, int(rng.integers(2, 24)))
        cands = tuple(int(b) for b in (64, 128, 256, 512, 1024))
        b_noise = float(rng.uniform(50, 5000))
        sel_j = BatchSizeSelector(candidates=cands, ref_batch=64, engine="jax")
        sel_b = BatchSizeSelector(candidates=cands, ref_batch=64, engine="batched")
        got_j = sel_j.select(model, b_noise)
        got_b = sel_b.select(model, b_noise)
        # Winners are re-solved scalar, so plans are engine-identical.
        assert got_j[0] == got_b[0]
        assert got_j[1].batches == got_b[1].batches
        assert got_j[2] == pytest.approx(got_b[2], rel=1e-9)


def test_degenerate_beta_node_on_device():
    """q = 0, gamma = 0 makes beta = 0 (syncStart flat in b): the device
    kernel's +-inf handling must match the host engine."""
    model = ClusterPerfModel(
        nodes=(
            NodePerfModel(q=0.0, s=0.01, k=2e-3, m=0.01),
            NodePerfModel(q=1e-3, s=0.0, k=1e-3, m=0.0),
        ),
        comm=CommModel(t_o=0.02, t_u=0.005, gamma=0.0),
    )
    cands = np.asarray([64.0, 512.0])
    jx = solve_optperf_batch_jax(model, cands)
    ref = solve_optperf_batch(model, cands)
    np.testing.assert_allclose(jx.opt_perfs, ref.opt_perfs, rtol=1e-5)


def test_inplace_refresh_trips_model_stamp():
    """Regression (stale-cache fix): a model whose node coefficients were
    refitted in place -- bypassing the frozen-dataclass contract, as an
    online refit over persistent node objects does -- must not be served
    the device export recorded before the refresh, even when
    ``evict_device_coeffs`` was forgotten.  The content stamp recorded at
    export time is re-checked on every solve and trips the rebuild."""
    rng = np.random.default_rng(11)
    model = random_model(rng, 5)
    cands = np.asarray([64.0, 256.0, 1024.0])
    before = solve_optperf_batch_jax(model, cands)
    stale = device_coeffs(model)

    # In-place refit: every node 2x slower.  The refit refreshes the
    # memoized host views (pops the cached slots) but "forgets" to
    # invalidate the device export.
    for node in model.nodes:
        object.__setattr__(node, "q", node.q * 2.0)
        object.__setattr__(node, "k", node.k * 2.0)
    for slot in ("coeffs", "_optperf_problem", "_validated"):
        model.__dict__.pop(slot, None)

    after = solve_optperf_batch_jax(model, cands)
    assert device_coeffs(model) is not stale   # stamp forced a re-export
    oracle = solve_optperf_batch(model, cands)
    np.testing.assert_allclose(after.opt_perfs, oracle.opt_perfs, rtol=1e-5)
    # The refresh really changed the answers (a stale export would not).
    assert float(np.min(after.opt_perfs / before.opt_perfs)) > 1.3


def test_warm_sweep_no_recompile_across_epochs():
    """The donated-bracket warm sweep compiles once, then re-drives the same
    executable for 10 drifting-model epochs at fixed (C, n) shapes with zero
    jit cache misses -- the controller's epoch-over-epoch resolve (and the
    fused epoch program built on the same kernels) relies on this."""
    from repro.core import optperf_jax

    optperf_jax._device_sweep.cache_clear()
    cands = np.linspace(64.0, 2048.0, 8)
    t_seed = solve_optperf_batch_jax(
        random_model(np.random.default_rng(500), 6), cands
    ).t_stars
    fn = optperf_jax._device_sweep(64, True)
    assert fn._cache_size() == 0
    for epoch in range(10):
        model = random_model(np.random.default_rng(501 + epoch), 6)
        sol = solve_optperf_batch_jax(model, cands, warm_start=t_seed)
        t_seed = sol.t_stars
    assert fn._cache_size() == 1  # one trace, ten warm epochs

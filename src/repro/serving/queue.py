"""Admission + continuous-batching scheduler.

The :class:`BatchScheduler` owns the request lifecycle between arrival and
completion:

    queued --admit--> active(node) --complete--> done
       ^                  |
       +----requeue-------+   (NodeLeave / quarantine / allocation shrink)

Per-node *decode batches* are continuous (Orca-style): a slot freed by a
completing request is refilled from the admission queue at the next tick
boundary, and newly admitted requests prefill between decode ticks.  The
number of slots a node may fill is its **water-fill allocation** — the
integer per-node batch the OptPerf solve assigns
(:class:`repro.serving.allocator.ServingAllocator`) — so the invariant
``len(active[i]) <= allocation[i]`` is the serving twin of the trainer's
per-node batch partition.

Every transition is checked against a single authoritative state map, so a
request can never be dropped, double-scheduled, or resurrected — the
property tests in ``tests/test_serving.py`` drive random interleavings of
admit/complete/drain/shrink against exactly these checks.

Requeued requests keep the tokens they already generated: on re-admission
the engine re-prefills prompt + generated-so-far (cache rebuilt) and
generation continues, which is what makes a mid-stream NodeLeave complete
every in-flight request with zero drops.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

from repro.serving.request import Request

__all__ = ["ActiveRequest", "BatchScheduler", "SchedulingError"]


class SchedulingError(RuntimeError):
    """A lifecycle invariant was violated (drop / double-schedule / overfill)."""


@dataclasses.dataclass
class ActiveRequest:
    """A request occupying one decode slot on one node.

    ``tokens`` is the generated-so-far list (survives requeues); ``admitted``
    and ``first_token`` are stamped by the runtime for the latency metrics.
    """

    request: Request
    node: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    admitted: float = 0.0
    first_token: Optional[float] = None
    requeues: int = 0

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.request.gen_len

    @property
    def remaining(self) -> int:
        return self.request.gen_len - len(self.tokens)

    @property
    def context_len(self) -> int:
        """Prompt + generated-so-far — what a rebuild must re-prefill."""
        return self.request.prompt_len + len(self.tokens)


_QUEUED, _DONE = "queued", "done"


class BatchScheduler:
    """FIFO admission queue + per-node continuous decode batches."""

    def __init__(self, allocations: Dict[int, int]):
        self._alloc: Dict[int, int] = {}
        self._active: Dict[int, List[ActiveRequest]] = {}
        self._queue: Deque[ActiveRequest] = deque()
        self._state: Dict[int, object] = {}  # rid -> _QUEUED | node | _DONE
        self.counters = {
            "enqueued": 0,
            "admitted": 0,
            "completed": 0,
            "requeued": 0,
            "evicted": 0,
        }
        for node, cap in allocations.items():
            self._add_node(node, cap)

    # -- node membership -------------------------------------------------

    def _add_node(self, node: int, cap: int) -> None:
        if cap < 0:
            raise ValueError(f"negative allocation for node {node}")
        self._alloc[node] = int(cap)
        self._active.setdefault(node, [])

    def nodes(self) -> List[int]:
        return sorted(self._alloc)

    def allocation(self, node: int) -> int:
        return self._alloc.get(node, 0)

    def active(self, node: int) -> List[ActiveRequest]:
        return list(self._active.get(node, ()))

    def active_count(self, node: int) -> int:
        return len(self._active.get(node, ()))

    def free_slots(self, node: int) -> int:
        return max(self._alloc.get(node, 0) - self.active_count(node), 0)

    # -- lifecycle transitions --------------------------------------------

    def enqueue(self, request: Request) -> None:
        """A fresh arrival enters the admission queue."""
        if request.rid in self._state:
            raise SchedulingError(f"request {request.rid} enqueued twice")
        self._state[request.rid] = _QUEUED
        self._queue.append(ActiveRequest(request=request, node=-1))
        self.counters["enqueued"] += 1

    def admit(self, node: int, now: float, limit: Optional[int] = None) -> List[ActiveRequest]:
        """Fill ``node``'s free slots from the queue head (FIFO).

        Returns the newly admitted requests (the runtime prefills them).
        ``limit`` optionally admits fewer than the free-slot count (e.g. to
        bound prefill work per tick).
        """
        if node not in self._alloc:
            raise SchedulingError(f"admit on unknown node {node}")
        n = self.free_slots(node)
        if limit is not None:
            n = min(n, max(limit, 0))
        out: List[ActiveRequest] = []
        while n > 0 and self._queue:
            ar = self._queue.popleft()
            if self._state.get(ar.rid) is not _QUEUED:
                raise SchedulingError(
                    f"request {ar.rid} in queue but state is {self._state.get(ar.rid)!r}"
                )
            ar.node = node
            ar.admitted = now
            self._state[ar.rid] = node
            self._active[node].append(ar)
            out.append(ar)
            n -= 1
        self.counters["admitted"] += len(out)
        if len(self._active[node]) > self._alloc[node]:
            raise SchedulingError(
                f"node {node} overfilled: {len(self._active[node])} > {self._alloc[node]}"
            )
        return out

    def complete(self, ar: ActiveRequest) -> None:
        """A request finished generation; its slot frees for reuse."""
        self._check_active(ar)
        self._active[ar.node].remove(ar)
        self._state[ar.rid] = _DONE
        self.counters["completed"] += 1

    def _requeue(self, ar: ActiveRequest) -> None:
        ar.node = -1
        ar.requeues += 1
        self._state[ar.rid] = _QUEUED
        # Requeues go to the FRONT (oldest arrivals first among them): a
        # victim of node churn should not pay the whole queue again.
        self._queue.appendleft(ar)
        self.counters["requeued"] += 1

    def drain_node(self, node: int) -> List[ActiveRequest]:
        """NodeLeave/quarantine: requeue every in-flight request of ``node``
        (generated tokens kept; caches rebuilt on re-admission) and remove
        the node from the allocatable set.  Zero requests are dropped."""
        if node not in self._alloc:
            raise SchedulingError(f"drain on unknown node {node}")
        victims = self._active.pop(node)
        del self._alloc[node]
        # Reverse order so appendleft restores arrival order at the front.
        for ar in reversed(victims):
            self._check_state(ar, node)
            self._requeue(ar)
        return victims

    def join_node(self, node: int, cap: int = 0) -> None:
        """NodeJoin: (re-)add a node with allocation ``cap``."""
        if node in self._alloc:
            raise SchedulingError(f"node {node} joined twice")
        self._add_node(node, cap)

    def set_allocations(self, allocations: Dict[int, int]) -> List[ActiveRequest]:
        """Apply a fresh water-fill solve.

        Nodes keep their in-flight requests; where the new allocation is
        *smaller* than the current active count, the newest actives are
        evicted (requeued, tokens kept) so the ``active <= allocation``
        invariant holds unconditionally.  Returns the evicted requests.
        """
        unknown = set(allocations) - set(self._alloc)
        if unknown:
            raise SchedulingError(f"allocation for unknown nodes {sorted(unknown)}")
        evicted: List[ActiveRequest] = []
        for node, cap in allocations.items():
            self._alloc[node] = int(cap)
            active = self._active[node]
            while len(active) > self._alloc[node]:
                ar = active.pop()  # newest first: least progress lost
                self._check_state(ar, node)
                self._requeue(ar)
                evicted.append(ar)
                self.counters["evicted"] += 1
                self.counters["requeued"] -= 1  # counted as eviction, not churn
        return evicted

    # -- introspection ----------------------------------------------------

    def queue_depth(self) -> int:
        return len(self._queue)

    def in_flight(self) -> int:
        return sum(len(v) for v in self._active.values())

    def pending(self) -> int:
        """Requests not yet done (queued + active)."""
        return self.queue_depth() + self.in_flight()

    def all_done(self) -> bool:
        return self.pending() == 0

    def check_invariants(self) -> None:
        """Full structural sweep (the property tests call this after every
        transition): states partition exactly into queue/active/done, no
        rid appears twice, and no node exceeds its allocation."""
        seen: Dict[int, str] = {}
        for ar in self._queue:
            if ar.rid in seen:
                raise SchedulingError(f"rid {ar.rid} appears twice (queue)")
            seen[ar.rid] = "queue"
            if self._state.get(ar.rid) is not _QUEUED:
                raise SchedulingError(f"rid {ar.rid} queued but state mismatch")
        for node, actives in self._active.items():
            if len(actives) > self._alloc[node]:
                raise SchedulingError(f"node {node} over allocation")
            for ar in actives:
                if ar.rid in seen:
                    raise SchedulingError(f"rid {ar.rid} appears twice (active)")
                seen[ar.rid] = "active"
                if self._state.get(ar.rid) != node or ar.node != node:
                    raise SchedulingError(f"rid {ar.rid} active but state mismatch")
        for rid, state in self._state.items():
            if rid not in seen and state is not _DONE:
                raise SchedulingError(f"rid {rid} lost (state {state!r})")
        if self.counters["enqueued"] != len(self._state):
            raise SchedulingError("enqueue counter drifted from state map")

    # -- internals ---------------------------------------------------------

    def _check_active(self, ar: ActiveRequest) -> None:
        self._check_state(ar, ar.node)
        if ar not in self._active.get(ar.node, ()):
            raise SchedulingError(f"request {ar.rid} not active on node {ar.node}")

    def _check_state(self, ar: ActiveRequest, node: int) -> None:
        if self._state.get(ar.rid) != node:
            raise SchedulingError(
                f"request {ar.rid} state {self._state.get(ar.rid)!r} != node {node}"
            )

"""Oracle for the WKV kernel: the step-by-step scan from models/rwkv6.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.rwkv6 import wkv_scan_ref


def wkv_ref(r, k, v, log_w, u):
    """r/k/v/log_w: (BH, T, K); u: (BH, K).  Returns (out, final_state)."""
    bh, t, kk = r.shape
    # wkv_scan_ref expects (B, T, H, K) with u (H, K); use B=1, H=BH and a
    # per-"head" u (valid because heads are independent).
    resh = lambda x: x.transpose(1, 0, 2)[None]  # (1, T, BH, K)
    lw = jnp.clip(log_w.astype(jnp.float32), -4.6, 0.0)
    out, s = wkv_scan_ref(resh(r), resh(k), resh(v), resh(lw), u)
    return out[0].transpose(1, 0, 2), s[0]

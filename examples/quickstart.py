"""Quickstart: train a reduced llama3-family model on synthetic data.

    PYTHONPATH=src python examples/quickstart.py

Uses the public API end to end: config -> ModelApi -> train step -> loss
curve -> checkpoint save/restore -> greedy decode with the KV cache.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_api
from repro.data import SyntheticLM
from repro.optim import adamw, cosine_schedule
from repro.train import restore, save
from repro.train.step import build_train_step


def main():
    api = get_api("llama3-8b", reduced=True)
    print(f"arch={api.arch_id} (reduced) params={api.param_count():,}")

    opt = adamw(cosine_schedule(3e-3, warmup_steps=5, total_steps=60))
    step = jax.jit(build_train_step(api, opt))
    params = api.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    data = SyntheticLM(vocab=api.cfg.vocab, seq_len=32, seed=0)

    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i, 16).items()}
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % 10 == 0:
            print(f"step {i:3d} loss={float(metrics['loss']):.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f}")

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save(path, params)
        params = restore(path, params)
        print(f"checkpoint round-trip OK ({os.path.getsize(path)/1e6:.1f} MB)")

    # Greedy decode 16 tokens from the trained model.
    decode = jax.jit(api.decode_step)
    cache = api.init_cache(batch=1, seq_len=32)
    tok = jnp.zeros((1, 1), jnp.int32)
    out = []
    for pos in range(16):
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy decode:", out)
    # The synthetic rule is next = rule[prev]; a trained model should follow
    # it for at least a few steps.
    hits = sum(out[i + 1] == int(data.rule[out[i]]) for i in range(len(out) - 1))
    print(f"rule-following transitions: {hits}/{len(out)-1}")


if __name__ == "__main__":
    main()

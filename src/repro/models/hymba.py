"""Hymba (arXiv:2411.13676) — hybrid-head architecture: every layer runs
attention heads and Mamba (selective-SSM) heads *in parallel* on the same
input, then fuses the two normalized branch outputs.

Assigned config: 32L, d_model 1600, 25 attention heads (head_dim 64, GQA
kv=5), d_ff 5504, ssm_state 16, vocab 32001.

* Attention: sliding-window in all layers except {first, middle, last}
  (global layers), per the source paper.
* Mamba branch: in-proj to (x, z) of d_inner = 2*d_model, short causal
  depthwise conv, selective scan over state dim 16 (chunked
  associative-scan so full sequences never materialize (B,T,d_inner,16)),
  silu(z) gating, out-proj.
* Fusion: mean of per-branch RMS-normalized outputs (learnable scales).
* Meta tokens (learnable prefix) are supported for full-sequence forward
  (``n_meta_tokens``); the assigned config keeps 0 so train/decode shapes
  stay uniform — noted in DESIGN.md.

Decode state: ring KV cache (window) for SWA layers + full cache for global
layers (we allocate full length only when seq fits, else window; global
layers fall back to window in long_500k — noted), SSM state (d_inner, 16),
conv tail, O(1) per token.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Param
from repro.sharding.context import constrain

__all__ = [
    "HymbaConfig",
    "schema",
    "init",
    "forward",
    "init_cache",
    "decode_step",
    "selective_scan",
    "selective_scan_ref",
]


@dataclasses.dataclass(frozen=True)
class HymbaConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 64
    ssm_state: int = 16
    d_inner: Optional[int] = None      # default 2*d_model
    conv_kernel: int = 4
    dt_rank: Optional[int] = None      # default ceil(d_model/16)
    window: int = 1024
    rope_theta: float = 10000.0
    n_meta_tokens: int = 0
    ssm_chunk: int = 64
    use_kernel: bool = False   # route the selective scan through Pallas
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    kv_chunk: int = 2048

    @property
    def family(self) -> str:
        return "hybrid"

    @property
    def inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def dtr(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    def global_layers(self) -> Tuple[int, ...]:
        return (0, self.n_layers // 2, self.n_layers - 1)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


def layer_schema(cfg: HymbaConfig) -> Dict[str, Any]:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    di, n, dtr = cfg.inner, cfg.ssm_state, cfg.dtr
    return {
        "attn": {
            "wq": Param((d, h, dh), ("embed", "heads", None)),
            "wk": Param((d, kv, dh), ("embed", "kv_heads", None)),
            "wv": Param((d, kv, dh), ("embed", "kv_heads", None)),
            "wo": Param((h, dh, d), ("heads", None, "embed")),
        },
        "ssm": {
            "w_in": Param((d, 2 * di), ("embed", "ssm_inner")),
            "conv_w": Param((cfg.conv_kernel, di), (None, "ssm_inner")),
            "conv_b": Param((di,), ("ssm_inner",), init="zeros"),
            "w_dt_in": Param((di, dtr), ("ssm_inner", None)),
            "w_dt_out": Param((dtr, di), (None, "ssm_inner")),
            "dt_bias": Param((di,), ("ssm_inner",), init="zeros"),
            "w_bc": Param((di, 2 * n), ("ssm_inner", None)),
            "log_a": Param((di, n), ("ssm_inner", None), init="zeros"),
            "d_skip": Param((di,), ("ssm_inner",), init="ones"),
            "w_out": Param((di, d), ("ssm_inner", "embed")),
        },
        "attn_scale": Param((d,), (None,), init="ones"),
        "ssm_scale": Param((d,), (None,), init="ones"),
        "in_norm": Param((d,), (None,), init="ones"),
        "mlp_norm": Param((d,), (None,), init="ones"),
        "mlp": {
            "w_gate": Param((d, cfg.d_ff), ("embed", "ff")),
            "w_up": Param((d, cfg.d_ff), ("embed", "ff")),
            "w_down": Param((cfg.d_ff, d), ("ff", "embed")),
        },
    }


def schema(cfg: HymbaConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = {
        "embed": Param((cfg.vocab, cfg.d_model), ("vocab", None), init="embed"),
        "layers": common.stacked(layer_schema(cfg), cfg.n_layers),
        "final_norm": Param((cfg.d_model,), (None,), init="ones"),
        "lm_head": Param((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }
    if cfg.n_meta_tokens:
        s["meta_tokens"] = Param(
            (cfg.n_meta_tokens, cfg.d_model), (None, None), init="embed"
        )
    return s


def init(rng: jax.Array, cfg: HymbaConfig):
    return common.init_from_schema(rng, schema(cfg), cfg.param_dtype)


# ---------------------------------------------------------------------------
# Selective scan (Mamba-style SSM)
# ---------------------------------------------------------------------------


def selective_scan_ref(
    u: jax.Array, dt: jax.Array, log_a: jax.Array, b_t: jax.Array, c_t: jax.Array,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Step-by-step oracle.  u/dt: (B,T,di); log_a: (di,n); b_t/c_t: (B,T,n).
    h_t = exp(dt_t*A) h_{t-1} + dt_t * B_t * u_t;  y_t = C_t . h_t.
    Returns (y (B,T,di), h_final (B,di,n))."""
    bsz, t, di = u.shape
    n = b_t.shape[-1]
    a = -jnp.exp(log_a.astype(jnp.float32))  # A < 0
    h = jnp.zeros((bsz, di, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        u_, dt_, b_, c_ = inp
        decay = jnp.exp(dt_.astype(jnp.float32)[..., None] * a[None])
        h = decay * h + (dt_ * u_).astype(jnp.float32)[..., None] * b_[:, None, :].astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, c_.astype(jnp.float32))
        return h, y

    xs = (
        u.transpose(1, 0, 2), dt.transpose(1, 0, 2),
        b_t.transpose(1, 0, 2), c_t.transpose(1, 0, 2),
    )
    h, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2).astype(u.dtype), h


def selective_scan(
    u: jax.Array, dt: jax.Array, log_a: jax.Array, b_t: jax.Array, c_t: jax.Array,
    *, chunk: int = 64, h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked selective scan: outer lax.scan over chunks carrying (B,di,n)
    state; inner associative_scan within each chunk, so the (B,T,di,n)
    tensors exist only chunk-sized."""
    bsz, t, di = u.shape
    n = b_t.shape[-1]
    c = min(chunk, t)
    pad = (-t) % c
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_t = jnp.pad(b_t, ((0, 0), (0, pad), (0, 0)))
        c_t = jnp.pad(c_t, ((0, 0), (0, pad), (0, 0)))
    tp = t + pad
    nc = tp // c
    a = -jnp.exp(log_a.astype(jnp.float32))

    resh = lambda x: x.reshape(bsz, nc, c, x.shape[-1]).transpose(1, 0, 2, 3)
    uc, dtc, bc, cc = resh(u), resh(dt), resh(b_t), resh(c_t)

    h_init = jnp.zeros((bsz, di, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def chunk_body(h, inp):
        u_b, dt_b, b_b, c_b = inp  # (B,C,di)/(B,C,n)
        dtf = dt_b.astype(jnp.float32)
        decay = jnp.exp(dtf[..., None] * a[None, None])            # (B,C,di,n)
        inject = (dtf * u_b.astype(jnp.float32))[..., None] * b_b.astype(jnp.float32)[:, :, None, :]

        def combine(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, b1 * a2 + b2

        acc_a, acc_b = jax.lax.associative_scan(combine, (decay, inject), axis=1)
        h_t = acc_a * h[:, None] + acc_b                            # (B,C,di,n)
        y = jnp.einsum("bcdn,bcn->bcd", h_t, c_b.astype(jnp.float32))
        return h_t[:, -1], y

    body = jax.checkpoint(chunk_body)
    h_final, ys = jax.lax.scan(body, h_init, (uc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, tp, di)[:, :t]
    return y.astype(u.dtype), h_final


# ---------------------------------------------------------------------------
# Branches
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, tail: Optional[jax.Array] = None):
    """Depthwise causal conv.  x (B,T,di); w (K,di).  ``tail`` (B,K-1,di)
    supplies left context for decode; returns (y, new_tail)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    segs = [
        jax.lax.dynamic_slice_in_dim(xp, i, x.shape[1], axis=1) * w[i][None, None]
        for i in range(k)
    ]
    y = sum(segs) + b[None, None]
    new_tail = xp[:, -(k - 1) :] if k > 1 else tail
    return y, new_tail


def _ssm_branch(
    sp: Dict[str, Any],
    x: jax.Array,
    cfg: HymbaConfig,
    *,
    h0: Optional[jax.Array] = None,
    conv_tail: Optional[jax.Array] = None,
    single_step: bool = False,
):
    di, n = cfg.inner, cfg.ssm_state
    xz = jnp.einsum("btd,de->bte", x, sp["w_in"])
    u, z = xz[..., :di], xz[..., di:]
    u = constrain(u, ("batch", None, "ssm_inner"))
    z = constrain(z, ("batch", None, "ssm_inner"))
    u, new_tail = _causal_conv(u, sp["conv_w"], sp["conv_b"], conv_tail)
    u = jax.nn.silu(u)
    dt = jnp.einsum("btd,dr->btr", u, sp["w_dt_in"])
    dt = jax.nn.softplus(jnp.einsum("btr,rd->btd", dt, sp["w_dt_out"]) + sp["dt_bias"][None, None])
    bc = jnp.einsum("btd,dn->btn", u, sp["w_bc"])
    b_t, c_t = bc[..., :n], bc[..., n:]
    if single_step:
        y, h = selective_scan_ref(u, dt, sp["log_a"], b_t, c_t, h0=h0)
    elif cfg.use_kernel and h0 is None:
        from repro.kernels.ssm_scan import ssm_scan as ssm_kernel_op

        d_block = di if di <= 512 else 512
        y, h = ssm_kernel_op(
            u.astype(jnp.float32), dt.astype(jnp.float32),
            b_t.astype(jnp.float32), c_t.astype(jnp.float32),
            sp["log_a"].astype(jnp.float32),
            chunk=cfg.ssm_chunk, d_block=d_block,
        )
        y = y.astype(cfg.compute_dtype)
    else:
        y, h = selective_scan(u, dt, sp["log_a"], b_t, c_t, chunk=cfg.ssm_chunk, h0=h0)
    y = y + sp["d_skip"][None, None] * u
    y = y * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", y, sp["w_out"]), h, new_tail


def _attn_branch(
    ap: Dict[str, Any],
    x: jax.Array,
    positions: jax.Array,
    cfg: HymbaConfig,
    *,
    is_global: bool,
):
    q = jnp.einsum("bsd,dhk->bshk", x, ap["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, ap["wv"])
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    if is_global:
        attn = common.full_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk)
    else:
        attn = common.local_window_attention(q, k, v, window=cfg.window)
    return jnp.einsum("bshk,hkd->bsd", attn, ap["wo"])


def _fuse(lp: Dict[str, Any], attn_out: jax.Array, ssm_out: jax.Array) -> jax.Array:
    return 0.5 * (
        common.rms_norm(attn_out, lp["attn_scale"])
        + common.rms_norm(ssm_out, lp["ssm_scale"])
    )


def _mlp(lp, x):
    g = jnp.einsum("bsd,df->bsf", x, lp["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, lp["w_up"])
    return jnp.einsum("bsf,fd->bsd", common.swiglu(g, u), lp["w_down"])


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def forward(params: Dict[str, Any], cfg: HymbaConfig, tokens: jax.Array) -> jax.Array:
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.n_meta_tokens:
        meta = jnp.broadcast_to(
            params["meta_tokens"][None].astype(cfg.compute_dtype),
            (b, cfg.n_meta_tokens, cfg.d_model),
        )
        x = jnp.concatenate([meta, x], axis=1)
    s_tot = x.shape[1]
    positions = jnp.arange(s_tot)
    glob = jnp.zeros((cfg.n_layers,), bool).at[jnp.array(cfg.global_layers())].set(True)

    def body(x, layer):
        lp, is_global = layer
        h = common.rms_norm(x, lp["in_norm"])
        # Both window paths are lowered and selected at trace time via cond
        # on the per-layer flag (static shapes identical).
        attn_out = jax.lax.cond(
            is_global,
            lambda h: _attn_branch(lp["attn"], h, positions, cfg, is_global=True),
            lambda h: _attn_branch(lp["attn"], h, positions, cfg, is_global=False),
            h,
        )
        ssm_out, _, _ = _ssm_branch(lp["ssm"], h, cfg)
        x = x + _fuse(lp, attn_out, ssm_out)
        h = common.rms_norm(x, lp["mlp_norm"])
        x = x + _mlp(lp["mlp"], h)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, (params["layers"], glob))
    if cfg.n_meta_tokens:
        x = x[:, cfg.n_meta_tokens :]
    x = common.rms_norm(x, params["final_norm"])
    return jnp.einsum(
        "btd,dv->btv", x, params["lm_head"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)


def init_cache(cfg: HymbaConfig, batch: int, seq_len: int, dtype=None):
    """Ring KV cache of window size for every layer (global layers fall back
    to windowed context in decode — recorded in DESIGN.md), plus SSM state
    and conv tail."""
    if dtype is None:
        dtype = cfg.compute_dtype  # cache dtype must match decode K/V
    length = min(cfg.window, seq_len)
    kv = common.make_kv_cache(
        cfg.n_layers, batch, length, cfg.n_kv_heads, cfg.head_dim, dtype
    )
    return {
        "k": kv["k"],
        "v": kv["v"],
        "ssm": jnp.zeros((cfg.n_layers, batch, cfg.inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1, cfg.inner), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(
    params: Dict[str, Any],
    cfg: HymbaConfig,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,
    pos: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    positions = jnp.full((1,), pos, jnp.int32)
    length = cache["k"].shape[2]

    def body(x, layer):
        lp, k_c, v_c, h_ssm, conv_tail = layer
        h = common.rms_norm(x, lp["in_norm"])
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
        k_c, v_c = common.cache_update(k_c, v_c, k, v, pos % length)
        attn = common.decode_attention(q, k_c, v_c, pos=pos, window=None)
        attn_out = jnp.einsum("bshk,hkd->bsd", attn, lp["attn"]["wo"])
        ssm_out, h_new, tail = _ssm_branch(
            lp["ssm"], h, cfg, h0=h_ssm, conv_tail=conv_tail, single_step=True
        )
        x = x + _fuse(lp, attn_out, ssm_out)
        h = common.rms_norm(x, lp["mlp_norm"])
        x = x + _mlp(lp["mlp"], h)
        return x, (k_c, v_c, h_new, tail)

    x, (k_c, v_c, ssm, conv) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], cache["ssm"], cache["conv"])
    )
    x = common.rms_norm(x, params["final_norm"])
    logits = jnp.einsum(
        "btd,dv->btv", x, params["lm_head"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)
    return logits, {"k": k_c, "v": v_c, "ssm": ssm, "conv": conv, "pos": pos + 1}

"""Batched chosen-set re-solves (scheduler satellite): the stacked
multi-subset water-fill must be *bit-identical* to the scalar per-subset
oracle, and `allocate` must emit oracle-bit-identical goodputs while doing
one stacked chosen-set call per distinct set size instead of one scalar
solve per greedy round."""
import numpy as np
import pytest

from repro.core.optperf import (
    solve_optperf_waterfill_subset,
    solve_optperf_waterfill_subsets,
)
from repro.core.perf_model import CommModel, NodePerfModel
from repro.core.scheduler import (
    JobSpec,
    _chosen_goodput_batch,
    allocate,
    random_jobs,
)


@pytest.mark.parametrize("seed", range(4))
def test_subsets_bit_identical_to_scalar(seed):
    """Mixed sizes, mixed models (each row carries its own comm model):
    every field of every row equals the solo scalar solve exactly — no
    tolerance."""
    rng = np.random.default_rng(seed)
    jobs = random_jobs(5, 16, seed=100 + seed)
    models, sets, totals = [], [], []
    for _ in range(120):
        job = jobs[int(rng.integers(len(jobs)))]
        m = int(rng.integers(1, 17))
        ids = tuple(sorted(rng.choice(16, size=m, replace=False).tolist()))
        models.append(job.full_model)
        sets.append(ids)
        totals.append(float(rng.choice([32, 64, 256, 1024, 4096])))
    batch = solve_optperf_waterfill_subsets(models, sets, totals)
    assert len(batch) == len(sets)
    for i in range(len(sets)):
        solo = solve_optperf_waterfill_subset(models[i], sets[i], totals[i])
        assert batch[i].opt_perf == solo.opt_perf, i
        assert batch[i].batches == solo.batches, i
        assert batch[i].bottleneck == solo.bottleneck, i
        assert batch[i].total_batch == solo.total_batch, i


def test_subsets_validation_matches_scalar():
    jobs = random_jobs(1, 4, seed=7)
    model = jobs[0].full_model
    with pytest.raises(ValueError):
        solve_optperf_waterfill_subsets([model], [()], [64.0])
    with pytest.raises(ValueError):
        solve_optperf_waterfill_subsets([model], [(0, 1)], [0.0])
    with pytest.raises(ValueError):
        solve_optperf_waterfill_subsets([model], [(0,)], [64.0, 128.0])
    bad = JobSpec(
        name="bad",
        node_models=tuple(
            NodePerfModel(q=float("nan"), s=0.0, k=1e-3, m=0.0) for _ in range(4)
        ),
        comm=CommModel(t_o=0.02, t_u=0.005, gamma=0.1),
        total_batch=64,
        b_noise=100.0,
        ref_batch=64,
    )
    with pytest.raises(ValueError):
        solve_optperf_waterfill_subsets([bad.full_model], [(0, 1)], [64.0])


@pytest.mark.parametrize("seed", range(6))
def test_allocate_emits_goodputs_bit_identical_to_scalar_oracle(seed):
    """The deferred+batched chosen-set path must keep the oracle-parity
    contract *bit-for-bit*: same assignments as the scalar oracle, and
    emitted goodputs exactly equal to the scalar chosen-set re-solve of the
    emitted (sorted) node sets — what every pre-batching release emitted.
    (The scalar engine's own emissions evaluate sets in take order, which
    differs in the last bits; the existing cross-engine test covers that at
    rel=1e-12.)"""
    jobs = random_jobs(4, 12, seed)
    a_b = allocate(jobs, 12, engine="batched")
    a_s = allocate(jobs, 12, engine="scalar")
    assert a_b.assignment == a_s.assignment
    by_name = {j.name: j for j in jobs}
    for name, ids in a_b.assignment.items():
        expected = by_name[name].goodput(ids) if ids else 0.0
        assert a_b.goodputs[name] == expected, name


def test_chosen_goodput_batch_matches_jobspec_goodput():
    """The scheduler-side helper replicates JobSpec.goodput semantics
    exactly: min_nodes floors and ill-posed models yield 0.0, everything
    else is the bit-identical subset solve times efficiency."""
    jobs = random_jobs(3, 8, seed=17)
    floor = JobSpec(
        name="floor",
        node_models=jobs[0].node_models,
        comm=jobs[0].comm,
        total_batch=jobs[0].total_batch,
        b_noise=jobs[0].b_noise,
        ref_batch=jobs[0].ref_batch,
        min_nodes=4,
    )
    broken = JobSpec(
        name="broken",
        node_models=tuple(
            NodePerfModel(q=-5e-3, s=0.0, k=1e-1, m=0.0) for _ in range(8)
        ),
        comm=CommModel(t_o=0.02, t_u=0.005, gamma=0.1),
        total_batch=128,
        b_noise=500.0,
        ref_batch=64,
    )
    pairs = [
        (jobs[0], (0, 1, 2)),
        (floor, (0, 1)),          # below min_nodes -> 0.0
        (jobs[1], (3, 4, 5, 6)),
        (broken, (0, 1)),         # ill-posed -> 0.0 (per-pair fallback)
        (jobs[2], (7,)),
    ]
    values = _chosen_goodput_batch(pairs)
    for (job, ids), value in zip(pairs, values):
        assert value == job.goodput(ids), job.name
    assert values[1] == 0.0 and values[3] == 0.0

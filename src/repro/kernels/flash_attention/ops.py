"""Public entry for the flash-attention kernel.

Accepts model-layout tensors (B, S, H, D) with GQA kv heads, folds
batch*heads, pads sequences to block multiples, and dispatches to the
Pallas kernel.  ``interpret`` defaults to True because this container's
backend is CPU; on TPU pass interpret=False (same kernel body).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_Q,
    flash_attention_kernel,
)


def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, KV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    if h % kv:
        raise ValueError("q heads must be a multiple of kv heads")
    rep = h // kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], d)

    qf, kf, vf = fold(q), fold(k), fold(v)

    block_q_eff = min(block_q, s) if s < block_q else block_q
    block_k_eff = min(block_k, t) if t < block_k else block_k
    pad_q = (-s) % block_q_eff
    pad_k = (-t) % block_k_eff
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))

    out = flash_attention_kernel(
        qf,
        kf,
        vf,
        causal=causal,
        window=window,
        softmax_scale=softmax_scale,
        block_q=block_q_eff,
        block_k=block_k_eff,
        interpret=interpret,
        kv_len=t,
    )
    out = out[:, :s]
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)

"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 (per
expert) vocab=32000, 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""
from repro.models.moe import MixtralConfig

ARCH_ID = "mixtral-8x7b"


def config() -> MixtralConfig:
    return MixtralConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=32000,
        n_experts=8,
        top_k=2,
        head_dim=128,
        rope_theta=1000000.0,
        window=4096,
        decode_window=4096,
    )


def reduced() -> MixtralConfig:
    return MixtralConfig(
        name=ARCH_ID + "-reduced",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        n_experts=4,
        top_k=2,
        head_dim=32,
        window=32,
        decode_window=32,
        capacity_factor=8.0,  # dropless at smoke scale: decode == forward
        remat=False,
    )

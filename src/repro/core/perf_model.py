"""Per-node performance models for heterogeneous data-parallel training.

Implements §3.2 of the Cannikin paper:

  * computing time of node i at local batch b:
        a_i(b) = q_i * b + s_i          (data load + forward + param update)
        P_i(b) = k_i * b + m_i          (backpropagation)
        t_compute_i(b) = a_i(b) + P_i(b)
  * communication:  T_comm = T_o + T_u  (batch-size independent constant)
  * overlap:        syncStart_i(b) = a_i(b) + gamma * P_i(b)
  * node batch time:
        T_node(b) = t_compute + T_u            if (1-gamma) P_i >= T_o
                  = syncStart + T_comm         otherwise
    which is equivalent to max(t_compute + T_u, syncStart + T_comm).

Parameter learning (§4.5):

  * (q_i, s_i) and (k_i, m_i) fitted by ordinary least squares over the
    (local batch size, measured time) observations of each node; at least
    two distinct batch sizes are required (the controller guarantees this
    via the Eq. (8) bootstrap partitioner).
  * gamma is measured per node per epoch; the cluster-level gamma uses
    inverse-variance weighting (Eq. 12).
  * T_comm uses the min over node reports (§4.5): the straggler that waits
    for nobody reports the true communication time.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "NodePerfModel",
    "CommModel",
    "ClusterCoeffs",
    "ClusterPerfModel",
    "StackedClusterModel",
    "NodeObservation",
    "OnlineNodeFitter",
    "GammaAggregator",
    "fit_linear",
    "inverse_variance_weight",
    "bootstrap_partition",
]


# ---------------------------------------------------------------------------
# Model containers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NodePerfModel:
    """Linear compute-time model of one node (one DP worker / node group).

    ``a(b) = q*b + s`` and ``P(b) = k*b + m``; all times in seconds, batch in
    samples.  q, k must be positive for a well-posed OptPerf problem.
    """

    q: float
    s: float
    k: float
    m: float

    def a(self, b) -> float:
        return self.q * b + self.s

    def backprop(self, b) -> float:
        return self.k * b + self.m

    def t_compute(self, b) -> float:
        return self.a(b) + self.backprop(b)

    def sync_start(self, b, gamma: float) -> float:
        return self.a(b) + gamma * self.backprop(b)

    # Coefficients used by the closed-form solvers -------------------------
    @property
    def alpha(self) -> float:
        """Slope of t_compute in b."""
        return self.q + self.k

    @property
    def c(self) -> float:
        """Intercept of t_compute."""
        return self.s + self.m

    def beta(self, gamma: float) -> float:
        """Slope of syncStart in b."""
        return self.q + gamma * self.k

    def d(self, gamma: float) -> float:
        """Intercept of syncStart."""
        return self.s + gamma * self.m

    def validate(self) -> None:
        if not (self.q >= 0 and self.k > 0):
            raise ValueError(f"ill-posed node model q={self.q} k={self.k}")


@dataclasses.dataclass(frozen=True)
class CommModel:
    """Cluster communication model: ring all-reduce split into overlapped
    part ``T_o`` and the un-overlappable last bucket ``T_u``; plus the
    overlap ratio ``gamma`` (fraction of backprop before the first bucket
    is ready)."""

    t_o: float
    t_u: float
    gamma: float

    @property
    def t_comm(self) -> float:
        return self.t_o + self.t_u

    def validate(self) -> None:
        if self.t_o < 0 or self.t_u < 0:
            raise ValueError("negative communication time")
        if not (0.0 <= self.gamma <= 1.0):
            raise ValueError(f"gamma out of range: {self.gamma}")


@dataclasses.dataclass(frozen=True)
class ClusterCoeffs:
    """Array-form coefficient view of a cluster: one entry per node.

    Everything the vectorized solvers need, precomputed once:
    ``t_compute_i(b) = alphas[i]*b + cs[i]`` and
    ``syncStart_i(b) = betas[i]*b + ds[i]`` (betas/ds already include the
    cluster gamma).  ``ks``/``ms`` are the raw backprop coefficients used by
    the overlap-state criterion ``(1-gamma)*(ks*b + ms) >= T_o``.

    All arrays are float64, read-only, shape ``(n,)``.
    """

    alphas: np.ndarray
    cs: np.ndarray
    betas: np.ndarray
    ds: np.ndarray
    ks: np.ndarray
    ms: np.ndarray

    @property
    def n(self) -> int:
        return int(self.alphas.shape[0])


@dataclasses.dataclass(frozen=True)
class ClusterPerfModel:
    """Everything the OptPerf solver needs for one cluster."""

    nodes: Tuple[NodePerfModel, ...]
    comm: CommModel

    @property
    def n(self) -> int:
        return len(self.nodes)

    @functools.cached_property
    def coeffs(self) -> ClusterCoeffs:
        """Cached array view of the per-node coefficients.

        The dataclass is frozen, so the view can never go stale; computing it
        once means no solver ever touches per-node Python attributes inside a
        loop.  (``cached_property`` writes straight to ``__dict__`` and thus
        works on frozen dataclasses.)
        """
        gamma = self.comm.gamma
        qs = np.fromiter((nd.q for nd in self.nodes), dtype=np.float64, count=self.n)
        ss = np.fromiter((nd.s for nd in self.nodes), dtype=np.float64, count=self.n)
        ks = np.fromiter((nd.k for nd in self.nodes), dtype=np.float64, count=self.n)
        ms = np.fromiter((nd.m for nd in self.nodes), dtype=np.float64, count=self.n)
        arrays = dict(
            alphas=qs + ks,
            cs=ss + ms,
            betas=qs + gamma * ks,
            ds=ss + gamma * ms,
            ks=ks,
            ms=ms,
        )
        for arr in arrays.values():
            arr.flags.writeable = False
        return ClusterCoeffs(**arrays)

    def node_time(self, i: int, b: float) -> float:
        """Batch time of node i at local batch b (max-form, §3.2.3)."""
        node = self.nodes[i]
        compute_path = node.t_compute(b) + self.comm.t_u
        comm_path = node.sync_start(b, self.comm.gamma) + self.comm.t_comm
        return max(compute_path, comm_path)

    def node_times(self, batches) -> np.ndarray:
        """Vectorized node batch times for a ``(..., n)`` batch array."""
        c = self.coeffs
        b = np.asarray(batches, dtype=np.float64)
        compute_path = c.alphas * b + c.cs + self.comm.t_u
        comm_path = c.betas * b + c.ds + self.comm.t_comm
        return np.maximum(compute_path, comm_path)

    def cluster_time(self, batches: Sequence[float]) -> float:
        """Cluster batch time = max over nodes (synchronous DP)."""
        if len(batches) != self.n:
            raise ValueError("batch vector length mismatch")
        return float(self.node_times(batches).max())

    def is_compute_bottleneck(self, i: int, b: float) -> bool:
        node = self.nodes[i]
        return (1.0 - self.comm.gamma) * node.backprop(b) >= self.comm.t_o

    def compute_bottleneck_mask(self, batches) -> np.ndarray:
        """Vectorized overlap-state criterion for a ``(..., n)`` batch array:
        True where a node is compute-bottleneck (``(1-gamma) P_i >= T_o``)."""
        c = self.coeffs
        b = np.asarray(batches, dtype=np.float64)
        return (1.0 - self.comm.gamma) * (c.ks * b + c.ms) >= self.comm.t_o

    def validate(self) -> None:
        # Hot path (every solver call revalidates): one vectorized pass over
        # the cached coefficient view, memoized — the dataclass is frozen so
        # a model that validated once can never become invalid.
        if self.__dict__.get("_validated", False):
            return
        self.comm.validate()
        c = self.coeffs
        # q = alphas - ks is float-safe: fl(q + k) >= k for q >= 0, so the
        # vectorized check matches the per-node q >= 0, k > 0 semantics.
        # Negated-all form so NaN coefficients fail validation (NaN makes
        # any comparison False) exactly like the per-node checks do.
        if not (bool(np.all(c.ks > 0)) and bool(np.all(c.alphas - c.ks >= 0))):
            for node in self.nodes:
                node.validate()  # per-node pass for a precise error message
            raise ValueError("ill-posed node model")
        self.__dict__["_validated"] = True


@dataclasses.dataclass(frozen=True)
class StackedClusterModel:
    """A batch of *independent* OptPerf problem rows padded to one width.

    Row ``r`` is its own cluster: its own node subset (coefficient row
    ``[r, :]`` with ``mask[r, :]`` marking real slots) and its own
    communication model (``t_o[r]``/``t_u[r]``/``gamma[r]``).  This is the
    input format of :func:`repro.core.optperf.solve_optperf_stacked`, which
    water-fills every row simultaneously — the multi-job scheduler builds one
    stack per greedy round covering all (job, candidate-node) pairs instead
    of solving each pair with a scalar water-fill.

    Coefficient semantics match :class:`ClusterCoeffs`:
    ``t_compute = alphas*b + cs``, ``syncStart = betas*b + ds`` (betas/ds
    include the row gamma); ``ks``/``ms`` are raw backprop coefficients for
    the overlap-state criterion.  Padding slots must carry inert values
    (``alphas = betas = ks = 1``, offsets 0) so broadcast arithmetic stays
    finite; they are excluded from every reduction via ``mask``.
    """

    alphas: np.ndarray   # (C, n_max)
    cs: np.ndarray       # (C, n_max)
    betas: np.ndarray    # (C, n_max)
    ds: np.ndarray       # (C, n_max)
    ks: np.ndarray       # (C, n_max)
    ms: np.ndarray       # (C, n_max)
    t_o: np.ndarray      # (C,)
    t_u: np.ndarray      # (C,)
    gamma: np.ndarray    # (C,)
    mask: np.ndarray     # (C, n_max) bool; False = padding slot

    @property
    def shape(self) -> Tuple[int, int]:
        return tuple(self.alphas.shape)  # type: ignore[return-value]

    @property
    def t_comm(self) -> np.ndarray:
        return self.t_o + self.t_u

    # -- derived-view caches -------------------------------------------
    #
    # Two expensive exports are memoized per stack instance: the solver's
    # `_Problem` array view (repro.core.optperf) and the jax engine's
    # device-array export (repro.core.optperf_jax `device_coeffs`).  Both
    # key off this instance, so a stack whose coefficient arrays are
    # refreshed IN PLACE (the scheduler's per-epoch OLS refit path) must
    # call :meth:`invalidate_device_cache` — otherwise the solvers keep
    # reading the old-regime coefficients from the stale export and emit
    # brackets for a cluster that no longer exists.

    def device_cache(self) -> Dict[str, object]:
        """Per-instance slot for the jax engine's cached device exports
        (keyed by dtype name; populated by ``optperf_jax.stacked_device_coeffs``
        so this module never imports jax)."""
        return self.__dict__.setdefault("_device_coeffs", {})

    def invalidate_device_cache(self) -> None:
        """Drop every derived view cached on this stack: the memoized
        `_Problem` solver view, the jax device-coefficient export, and the
        validation memo.  Required after any in-place coefficient refresh
        (OLS refit)."""
        self.__dict__.pop("_device_coeffs", None)
        self.__dict__.pop("_optperf_problem", None)
        self.__dict__.pop("_validated", None)

    def validate(self) -> None:
        # Hot path (the scheduler solves the same stack block every round):
        # memoized like ClusterPerfModel.validate; in-place refreshes route
        # through invalidate_device_cache which drops the memo.
        if self.__dict__.get("_validated", False):
            return
        c, n = self.alphas.shape
        for name in ("cs", "betas", "ds", "ks", "ms", "mask"):
            if getattr(self, name).shape != (c, n):
                raise ValueError(f"{name} shape mismatch")
        for name in ("t_o", "t_u", "gamma"):
            if getattr(self, name).shape != (c,):
                raise ValueError(f"{name} shape mismatch")
        # Negated-all form throughout so NaN coefficients fail validation
        # (NaN comparisons are False) — the batched scheduler relies on a
        # ValueError here to degrade a garbage-fit job to goodput 0.0 the
        # same way the scalar path does.
        if not self.mask.any(axis=1).all():
            raise ValueError("every row needs at least one valid node slot")
        if not np.all(np.where(self.mask, self.alphas, 1.0) > 0):
            raise ValueError("non-positive alpha on a valid slot")
        # Same k > 0 and q >= 0 (alpha - k >= 0) requirements as the
        # per-node NodePerfModel check: the batched scheduler must reject
        # exactly the models the scalar oracle rejects, or the engines emit
        # different allocations.
        if not np.all(np.where(self.mask, self.ks, 1.0) > 0):
            raise ValueError("non-positive backprop slope on a valid slot")
        if not np.all(np.where(self.mask, self.alphas - self.ks, 0.0) >= 0):
            raise ValueError("negative q slope on a valid slot")
        if not np.all(np.where(self.mask, self.betas, 0.0) >= 0):
            raise ValueError("negative beta on a valid slot")
        if not (np.all(self.t_o >= 0) and np.all(self.t_u >= 0)):
            raise ValueError("negative communication time")
        if not np.all((self.gamma >= 0) & (self.gamma <= 1)):
            raise ValueError("gamma out of range")
        self.__dict__["_validated"] = True

    @classmethod
    def from_models(cls, models: Sequence["ClusterPerfModel"]) -> "StackedClusterModel":
        """Pad and stack heterogeneous-width clusters into one solve batch."""
        if not models:
            raise ValueError("need at least one model")
        c = len(models)
        n_max = max(m.n for m in models)
        arrays = {
            name: np.full((c, n_max), fill, dtype=np.float64)
            for name, fill in (
                ("alphas", 1.0), ("cs", 0.0), ("betas", 1.0),
                ("ds", 0.0), ("ks", 1.0), ("ms", 0.0),
            )
        }
        mask = np.zeros((c, n_max), dtype=bool)
        t_o = np.empty(c)
        t_u = np.empty(c)
        gamma = np.empty(c)
        for r, m in enumerate(models):
            co = m.coeffs
            for name in arrays:
                arrays[name][r, : m.n] = getattr(co, name)
            mask[r, : m.n] = True
            t_o[r] = m.comm.t_o
            t_u[r] = m.comm.t_u
            gamma[r] = m.comm.gamma
        out = cls(t_o=t_o, t_u=t_u, gamma=gamma, mask=mask, **arrays)
        for arr in (*arrays.values(), t_o, t_u, gamma, mask):
            arr.flags.writeable = False
        return out

    def row_model(self, r: int) -> "ClusterPerfModel":
        """Reconstruct row ``r`` as a scalar :class:`ClusterPerfModel`
        (cross-check oracle path; q = alpha - k, s = c - m)."""
        valid = np.flatnonzero(self.mask[r])
        nodes = tuple(
            NodePerfModel(
                q=float(self.alphas[r, i] - self.ks[r, i]),
                s=float(self.cs[r, i] - self.ms[r, i]),
                k=float(self.ks[r, i]),
                m=float(self.ms[r, i]),
            )
            for i in valid
        )
        comm = CommModel(
            t_o=float(self.t_o[r]), t_u=float(self.t_u[r]), gamma=float(self.gamma[r])
        )
        return ClusterPerfModel(nodes=nodes, comm=comm)


# ---------------------------------------------------------------------------
# Online parameter learning
# ---------------------------------------------------------------------------


def fit_linear(xs: Sequence[float], ys: Sequence[float]) -> Tuple[float, float]:
    """OLS fit ``y = slope*x + intercept``. Needs >=2 distinct x values."""
    xs_arr = np.asarray(xs, dtype=np.float64)
    ys_arr = np.asarray(ys, dtype=np.float64)
    if xs_arr.size < 2 or np.ptp(xs_arr) == 0:
        raise ValueError("need at least two distinct batch sizes to fit")
    x_mean = xs_arr.mean()
    y_mean = ys_arr.mean()
    denom = float(((xs_arr - x_mean) ** 2).sum())
    slope = float(((xs_arr - x_mean) * (ys_arr - y_mean)).sum() / denom)
    intercept = float(y_mean - slope * x_mean)
    return slope, intercept


@dataclasses.dataclass
class NodeObservation:
    """One epoch-level measurement from a node."""

    batch_size: float
    a_time: float          # data load + forward + param update
    backprop_time: float
    gamma: float           # measured overlap ratio this epoch
    comm_time: float       # this node's reported T_comm (includes waiting)


class OnlineNodeFitter:
    """Accumulates observations for one node and refits (q,s,k,m).

    The paper refits after every epoch; more distinct batch sizes refine the
    model (§4.5 "Parameter learning").
    """

    def __init__(self) -> None:
        self._obs: List[NodeObservation] = []

    def add(self, obs: NodeObservation) -> None:
        if obs.batch_size <= 0:
            raise ValueError("batch size must be positive")
        self._obs.append(obs)

    @property
    def num_observations(self) -> int:
        return len(self._obs)

    @property
    def num_distinct_batches(self) -> int:
        return len({o.batch_size for o in self._obs})

    def can_fit(self) -> bool:
        return self.num_distinct_batches >= 2

    def per_sample_time(self) -> float:
        """t_sample of the most recent epoch — used by the Eq. (8) bootstrap."""
        if not self._obs:
            raise ValueError("no observations")
        last = self._obs[-1]
        return (last.a_time + last.backprop_time) / last.batch_size

    def fit(self) -> NodePerfModel:
        if not self.can_fit():
            raise ValueError("need two distinct local batch sizes (Eq. 8 bootstrap)")
        bs = [o.batch_size for o in self._obs]
        q, s = fit_linear(bs, [o.a_time for o in self._obs])
        k, m = fit_linear(bs, [o.backprop_time for o in self._obs])
        # Clamp tiny negative intercepts caused by measurement noise; a
        # negative slope would mean "bigger batches are faster" which is a
        # measurement failure we surface instead of hiding.
        if q < 0 or k <= 0:
            raise ValueError(f"non-physical fit q={q:.3g} k={k:.3g}")
        return NodePerfModel(q=q, s=max(s, 0.0), k=k, m=max(m, 0.0))

    def gamma_stats(self) -> Tuple[float, float]:
        """Mean and sample variance of this node's gamma measurements."""
        gs = np.asarray([o.gamma for o in self._obs], dtype=np.float64)
        if gs.size == 0:
            raise ValueError("no gamma observations")
        mean = float(gs.mean())
        var = float(gs.var(ddof=1)) if gs.size > 1 else float("inf")
        return mean, var

    def min_comm_time(self) -> float:
        return min(o.comm_time for o in self._obs)


def inverse_variance_weight(
    means: Sequence[float], variances: Sequence[float]
) -> float:
    """Eq. (12): inverse-variance weighted combination of per-node estimates.

    Nodes with unknown variance (single sample, var=inf) receive zero weight
    unless *all* variances are infinite, in which case we fall back to the
    plain mean (every node equally untrusted).
    """
    means_arr = np.asarray(means, dtype=np.float64)
    var_arr = np.asarray(variances, dtype=np.float64)
    if means_arr.size == 0:
        raise ValueError("no estimates")
    if means_arr.shape != var_arr.shape:
        raise ValueError("means/variances shape mismatch")
    if np.any(var_arr < 0):
        raise ValueError("negative variance")
    finite = np.isfinite(var_arr) & (var_arr > 0)
    exact = np.isfinite(var_arr) & (var_arr == 0)
    if np.any(exact):
        # Zero-variance observations dominate: average those.
        return float(means_arr[exact].mean())
    if not np.any(finite):
        return float(means_arr.mean())
    w = np.zeros_like(var_arr)
    w[finite] = 1.0 / var_arr[finite]
    w /= w.sum()
    return float((w * means_arr).sum())


class GammaAggregator:
    """Cluster-level gamma and T_comm estimation (§4.5)."""

    def __init__(self, fitters: Mapping[int, OnlineNodeFitter]):
        self._fitters = dict(fitters)

    def gamma(self) -> float:
        means, variances = [], []
        for fitter in self._fitters.values():
            mean, var = fitter.gamma_stats()
            means.append(mean)
            variances.append(var)
        g = inverse_variance_weight(means, variances)
        return min(max(g, 0.0), 1.0)

    def t_comm(self) -> float:
        """min over nodes of the node-min report (§4.5)."""
        return min(f.min_comm_time() for f in self._fitters.values())


# ---------------------------------------------------------------------------
# Eq. (8) bootstrap partition — used before performance models exist
# ---------------------------------------------------------------------------


def bootstrap_partition(
    per_sample_times: Sequence[float], total_batch: float
) -> List[float]:
    """Eq. (8): assign local batches inversely proportional to per-sample time.

    b_i = (Sum_t / t_i) / (Sum_j Sum_t / t_j) * B

    Used in the first two epochs to (a) roughly balance load and (b) make every
    node observe >=2 distinct local batch sizes so the linear models become
    fittable.
    """
    ts = np.asarray(per_sample_times, dtype=np.float64)
    if np.any(ts <= 0):
        raise ValueError("per-sample times must be positive")
    inv = 1.0 / ts
    ratios = inv / inv.sum()
    return [float(r * total_batch) for r in ratios]

"""repro.runtime — the event-driven cluster runtime: one front door over
controller, scheduler, simulator, and trainer.

The paper's Cannikin system is a *runtime* loop: observe steps, refit the
performance model, re-plan batch sizes, reallocate nodes as jobs and
hardware come and go.  This package exposes that loop as one API:

* :class:`ClusterRuntime` — deterministic reconcile loop over an event
  queue (:class:`JobArrival`, :class:`JobCompletion`, :class:`NodeJoin`/
  :class:`NodeLeave`, :class:`ModelRefit`, :class:`Preemption`), wrapping
  the incremental :class:`~repro.core.scheduler.Scheduler` so allocations
  are recomputed incrementally per event, never cold.
* :class:`JobHandle` — per-job lifecycle (pending → running ⇄ preempted →
  done) owning a :class:`~repro.core.controller.CannikinController`;
  surfaces :class:`~repro.core.controller.EpochPlan`s and
  :class:`~repro.core.controller.ControllerStats`.
* :class:`ExecutionBackend` — the plan → execute → observe engine behind
  ``JobHandle.advance``: :class:`SimBackend` (timing simulator) and
  :class:`RealBackend` (real JAX gradients + Theorem-4.1 GNS tracking,
  preemption checkpoint/restore) are swappable per :class:`JobSpec`;
  :class:`EpochLoop`/:func:`run_backend_epoch` are the same loop
  standalone, surfacing unified :class:`EpochRecord` telemetry.
* :class:`Policy` — pluggable allocation policies: ``cannikin`` (the
  paper-derived allocator), ``static``, and ``fair-share`` baselines, all
  scored on the same goodput scale.
* :class:`Trace` / :func:`replay` / :func:`compare_policies` — synthetic
  multi-job churn workloads over :class:`~repro.core.simulator.
  SimulatedCluster` (the Pollux/Sia-style cluster simulation).
* :class:`FaultPlan` / :class:`FaultInjector` / :class:`HealthMonitor` —
  the fault-tolerance layer: seeded deterministic fault injection
  (crashes, stragglers, noise spikes, flaky checkpoint I/O — plus the
  real-path integrity faults :class:`GradientPoison`,
  :class:`CheckpointCorruption`, :class:`SolverStall`), telemetry-
  driven detection (EWMA residuals, the gradient anomaly guard's
  numerical-health channel, quarantine with exponential-backoff
  re-admission), and self-healing recovery through the reconcile loop
  (``replay(..., faults=FaultPlan.chaos(n))``).
* :class:`Watchdog` / :class:`RuntimeInvariantChecker` — integrity
  hardening: deadline guards on OptPerf solves (timeouts enter the
  engine-degradation chain) and backend epochs, and a debug-mode
  structural validator run after every reconciled event
  (``ClusterRuntime(..., invariants=True)``).  Checksummed checkpoint
  generations with rollback live in :mod:`repro.train.checkpoint`.
* :func:`make_partition_policy` / :func:`drive_partition_policy` — the
  single-job batch-partition factory + epoch-driving loop shared by the
  launch CLI, examples, and benchmarks.

Quick start::

    from repro.core.scheduler import random_jobs
    from repro.runtime import ClusterRuntime

    rt = ClusterRuntime(n_nodes=8, policy="cannikin")
    for i, job in enumerate(random_jobs(2, 8, seed=0)):
        rt.submit(job, at=float(i))
    rt.run()                 # reconcile queued events
    rt.advance(epochs=3)     # step the running jobs' training loops
    print(rt.allocation.aggregate_goodput, rt.counters())
"""
from repro.runtime.backend import (
    BACKENDS,
    EpochLoop,
    EpochRecord,
    ExecutionBackend,
    ExecutionResult,
    GradObservation,
    RealBackend,
    RealBackendConfig,
    SimBackend,
    make_backend,
    run_backend_epoch,
)
from repro.runtime.events import (
    Event,
    JobArrival,
    JobCompletion,
    ModelRefit,
    NodeJoin,
    NodeLeave,
    Preemption,
    describe,
)
from repro.runtime.faults import (
    FAULT_PLANS,
    CheckpointCorruption,
    FaultInjector,
    FaultPlan,
    FlakyCheckpointIO,
    FlakyCheckpoints,
    GradientPoison,
    NodeCrash,
    NoiseSpike,
    SolverStall,
    Straggler,
    make_fault_plan,
)
from repro.runtime.health import (
    CrashDetected,
    HealthAction,
    HealthConfig,
    HealthMonitor,
    NodeState,
    QuarantineNode,
    ReadmitNode,
    RefitRequested,
)
from repro.runtime.invariants import InvariantViolation, RuntimeInvariantChecker
from repro.runtime.policy import (
    POLICIES,
    CannikinPolicy,
    FairSharePolicy,
    Policy,
    StaticPolicy,
    drive_partition_policy,
    make_partition_policy,
    make_policy,
)
from repro.runtime.runtime import (
    ClusterRuntime,
    JobHandle,
    JobState,
    ReconcileRecord,
    drift_spec,
)
from repro.runtime.trace import (
    Trace,
    TraceReport,
    compare_policies,
    format_batch_policy_summary,
    format_summary,
    rank_batch_policies,
    replay,
    synthetic_trace,
)
from repro.runtime.watchdog import DeadlineExceeded, Watchdog

__all__ = [
    "BACKENDS",
    "EpochLoop",
    "EpochRecord",
    "ExecutionBackend",
    "ExecutionResult",
    "GradObservation",
    "RealBackend",
    "RealBackendConfig",
    "SimBackend",
    "make_backend",
    "run_backend_epoch",
    "Event",
    "JobArrival",
    "JobCompletion",
    "ModelRefit",
    "NodeJoin",
    "NodeLeave",
    "Preemption",
    "describe",
    "Policy",
    "POLICIES",
    "CannikinPolicy",
    "StaticPolicy",
    "FairSharePolicy",
    "make_policy",
    "make_partition_policy",
    "drive_partition_policy",
    "ClusterRuntime",
    "JobHandle",
    "JobState",
    "ReconcileRecord",
    "drift_spec",
    "Trace",
    "TraceReport",
    "replay",
    "compare_policies",
    "synthetic_trace",
    "format_summary",
    "rank_batch_policies",
    "format_batch_policy_summary",
    "FAULT_PLANS",
    "FaultPlan",
    "FaultInjector",
    "FlakyCheckpointIO",
    "FlakyCheckpoints",
    "NodeCrash",
    "NoiseSpike",
    "Straggler",
    "GradientPoison",
    "CheckpointCorruption",
    "SolverStall",
    "make_fault_plan",
    "Watchdog",
    "DeadlineExceeded",
    "RuntimeInvariantChecker",
    "InvariantViolation",
    "HealthAction",
    "HealthConfig",
    "HealthMonitor",
    "NodeState",
    "CrashDetected",
    "QuarantineNode",
    "ReadmitNode",
    "RefitRequested",
]

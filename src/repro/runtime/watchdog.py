"""Deadline watchdog for OptPerf solves and backend epoch execution.

A hung solver (or a pathologically slow epoch) must not hang the reconcile
loop.  The watchdog is deliberately *single-threaded*: it measures each
guarded call after the fact and raises :class:`DeadlineExceeded` when the
solve deadline was breached — a thread-based kill would race with the
scheduler's in-place cache/allocation mutation, trading a hang for
corruption.  The stall the chaos plan injects (:class:`~repro.runtime.
faults.SolverStall`) is a bounded real-time sleep, so "detect after the
fact" and "abort" coincide deterministically.

* ``guard_solve`` wraps one scheduler entry point.  An injected stall (the
  ``stall_hook`` seam, wired to :meth:`FaultInjector.solver_stall`) sleeps
  before the solve; if total elapsed time exceeds ``solve_deadline`` the
  watchdog counts a timeout and raises :class:`DeadlineExceeded`, which
  :class:`~repro.runtime.policy.CannikinPolicy` catches in its existing
  engine-degradation chain (jax → batched → scalar → last-known-good).
  The injector consumes each stall once per epoch, so the degradation
  retry solves cleanly.
* ``guard_execute`` wraps one backend epoch.  Execution deadlines are
  *soft*: a breach is counted (``execute_deadline_misses``) but the
  epoch's results are kept — aborting a finished training step would
  throw away real gradient work and desync the data stream.

With no deadlines configured the guards are pass-throughs, so golden-path
runs are unchanged.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

__all__ = ["DeadlineExceeded", "Watchdog"]


class DeadlineExceeded(RuntimeError):
    """A guarded call ran past its deadline."""

    def __init__(self, kind: str, elapsed: float, deadline: float) -> None:
        super().__init__(
            f"{kind} exceeded deadline: {elapsed:.3f}s > {deadline:.3f}s"
        )
        self.kind = kind
        self.elapsed = elapsed
        self.deadline = deadline


class Watchdog:
    """After-the-fact deadline checks with telemetry counters.

    ``solve_deadline``/``execute_deadline`` are real seconds (None disables
    the respective guard).  ``stall_hook`` is the injector's
    :meth:`~repro.runtime.faults.FaultInjector.solver_stall` seam — it
    returns the seconds the next solve should artificially stall (0.0 when
    no stall is scheduled).
    """

    def __init__(
        self,
        *,
        solve_deadline: Optional[float] = None,
        execute_deadline: Optional[float] = None,
        stall_hook: Optional[Callable[[], float]] = None,
    ) -> None:
        self.solve_deadline = solve_deadline
        self.execute_deadline = execute_deadline
        self.stall_hook = stall_hook
        self.solver_timeouts = 0
        self.execute_deadline_misses = 0
        self.stalled_seconds = 0.0

    def guard_solve(self, fn: Callable[[], Any]) -> Any:
        t0 = time.perf_counter()
        if self.stall_hook is not None:
            delay = float(self.stall_hook())
            if delay > 0.0:
                self.stalled_seconds += delay
                time.sleep(delay)
        out = fn()
        elapsed = time.perf_counter() - t0
        if self.solve_deadline is not None and elapsed > self.solve_deadline:
            self.solver_timeouts += 1
            raise DeadlineExceeded("optperf-solve", elapsed, self.solve_deadline)
        return out

    def guard_execute(self, fn: Callable[[], Any]) -> Any:
        t0 = time.perf_counter()
        out = fn()
        elapsed = time.perf_counter() - t0
        if self.execute_deadline is not None and elapsed > self.execute_deadline:
            self.execute_deadline_misses += 1
        return out

    def counters(self) -> Dict[str, float]:
        return {
            "solver_timeouts": self.solver_timeouts,
            "execute_deadline_misses": self.execute_deadline_misses,
            "stalled_seconds": self.stalled_seconds,
        }

"""Pallas TPU kernels for the compute hot spots of the model zoo.

Each kernel ships: <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit-ready public wrapper), ref.py (pure-jnp oracle).  Validated in
interpret=True mode on CPU; identical kernel bodies target the TPU MXU/VPU.

The paper's own contribution (Cannikin) is a scheduling/estimation layer —
it has no kernel; these cover the substrate it trains (DESIGN.md §6).
"""
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.rwkv6_wkv import wkv, wkv_ref
from repro.kernels.ssm_scan import ssm_ref, ssm_scan

__all__ = [
    "flash_attention",
    "attention_ref",
    "wkv",
    "wkv_ref",
    "ssm_scan",
    "ssm_ref",
]

"""The batch-size-policy zoo: every registered adaptation law on one
heterogeneous trace.

    python examples/policy_zoo.py

Cannikin's GNS-driven selection is one point in the batch-adaptation
design space; the :mod:`repro.core.batch_policy` registry holds the rest —
the AdaBatch/adadamp damper family (loss-ratio, linear-ramp, geometric
schedule) and the fixed baseline.  This example stamps one synthetic 3-job
trace with each registered policy, replays them under the same cannikin
allocator, and prints one ranking on goodput = sample throughput ×
statistical efficiency.

Because the dampers are schedule-driven (no gradient telemetry needed),
adaptive batch sizes are live even on the sim backend — watch geodamp's
mean total batch ramp while cannikin-gns, which needs real gradients,
collapses to the fixed baseline here.  Exits nonzero if any invariant
breaks, so CI runs it as an end-to-end smoke.
"""
import _common  # noqa: F401  (sys.path bootstrap)

from repro.core.batch_policy import BATCH_POLICIES, policy_requirements
from repro.runtime import (
    compare_policies,
    format_batch_policy_summary,
    rank_batch_policies,
    synthetic_trace,
)

N_JOBS, N_NODES, SEED = 3, 12, 0


def main() -> None:
    trace, jobs = synthetic_trace(N_JOBS, N_NODES, seed=SEED)
    print(f"# trace: {len(trace)} events, jobs={[j.name for j in jobs]}, "
          f"nodes={N_NODES}")
    print(f"# registry: "
          f"{ {n: sorted(policy_requirements(n)) for n in sorted(BATCH_POLICIES)} }")

    reports = compare_policies(
        trace, N_NODES, batch_policies=(), epochs_per_event=2, steps=2,
        noise=0.01, seed=SEED,
    )
    print(format_batch_policy_summary(reports))

    ranking = rank_batch_policies(reports)
    by_name = {row["batch_policy"]: row for row in ranking}

    # One report per registered policy, ranked strictly by goodput.
    assert len(ranking) == len(BATCH_POLICIES) >= 5
    goodputs = [row["policy_goodput"] for row in ranking]
    assert goodputs == sorted(goodputs, reverse=True)
    for row in ranking:
        assert 0.0 < row["statistical_efficiency"] <= 1.0, row
        assert row["sample_throughput"] > 0.0, row
        assert row["epochs"] > 0, row

    # GNS-driven selection has no gradients on the sim backend, so it runs
    # the fixed-batch mode — identical replay, identical numbers.
    assert by_name["cannikin-gns"]["policy_goodput"] == by_name["fixed"]["policy_goodput"]

    # The dampers DO adapt here: the geometric schedule ramped the batch.
    assert (
        by_name["geodamp"]["mean_total_batch"]
        > by_name["adadamp"]["mean_total_batch"]
    ), "geodamp never moved on the sim backend"

    best = ranking[0]
    print(f"# winner: {best['batch_policy']} "
          f"(goodput={best['policy_goodput']:.1f}, "
          f"eff={best['statistical_efficiency']:.3f}, "
          f"mean B={best['mean_total_batch']:.1f})")
    print("ok")


if __name__ == "__main__":
    main()

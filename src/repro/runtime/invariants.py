"""RuntimeInvariantChecker: debug-mode structural validation of the runtime.

Chaos testing is only as strong as what it asserts.  This module is the
assertion layer: after every reconciled event the checker sweeps the
runtime's cross-object state — allocation, job handles, scheduler caches,
health state machine — for structural corruption that individual unit
tests cannot see (they each hold one object).  Violations are collected,
never raised: a chaos run completes and then reports, so one broken
invariant cannot mask the others.

Checked invariants:

* **Disjoint assignment** — no node assigned to two jobs; every assigned
  node id is in range and not currently down.
* **Conserved allocation fractions** — every per-job goodput/fraction is
  finite and non-negative, and the total number of assigned nodes never
  exceeds the nodes actually available (n_nodes minus down).
* **Bounded caches** — the incremental scheduler's per-job gain/take
  caches respect their eviction limit (``cache_limit``, default
  ``8 * n_nodes``) — the fleet-scale memory guarantee.
* **Quarantine liveness** — every quarantined node has a pending
  re-admission (``release_epoch``), and every crashed node has a crash
  detection record: no node can be silently lost forever.
* **Handle/state coherence** — RUNNING handles hold >= 1 node; DONE and
  PREEMPTED handles hold none.

Enable with ``ClusterRuntime(..., invariants=True)`` (the chaos CI lanes
do); ``runtime.invariant_violations`` and the fault-telemetry block
surface the findings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List

from repro.runtime.health import NodeState

__all__ = ["InvariantViolation", "RuntimeInvariantChecker"]


@dataclasses.dataclass(frozen=True)
class InvariantViolation:
    """One broken invariant at one reconcile point."""

    invariant: str
    detail: str
    event: str
    epoch: int

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return f"[{self.invariant}] {self.detail} (after {self.event}, epoch {self.epoch})"


class RuntimeInvariantChecker:
    """Sweeps a :class:`~repro.runtime.runtime.ClusterRuntime` for
    structural corruption.  ``check`` is called by the runtime after every
    reconciled event (trace-driven and recovery-synthesized alike);
    ``violations`` accumulates across the run."""

    def __init__(self, runtime: Any) -> None:
        self.runtime = runtime
        self.violations: List[InvariantViolation] = []
        self.checks_run = 0

    def check(self, event_label: str = "?") -> List[InvariantViolation]:
        rt = self.runtime
        found: List[InvariantViolation] = []

        def violate(invariant: str, detail: str) -> None:
            found.append(
                InvariantViolation(
                    invariant=invariant,
                    detail=detail,
                    event=event_label,
                    epoch=int(rt.epoch_index),
                )
            )

        alloc = rt.allocation
        # -- disjoint assignment ------------------------------------------
        owner: dict = {}
        assigned_total = 0
        for job, ids in alloc.assignment.items():
            for nid in ids:
                nid = int(nid)
                assigned_total += 1
                if nid in owner:
                    violate(
                        "disjoint-assignment",
                        f"node {nid} assigned to both {owner[nid]!r} and {job!r}",
                    )
                owner[nid] = job
                if not 0 <= nid < rt.n_nodes:
                    violate(
                        "node-range", f"assigned node {nid} outside [0, {rt.n_nodes})"
                    )
                if nid in rt.down_nodes:
                    violate(
                        "down-node-assigned",
                        f"node {nid} assigned to {job!r} while down",
                    )

        # -- conserved fractions / finite scores --------------------------
        available = rt.n_nodes - len(rt.down_nodes)
        if assigned_total > available:
            violate(
                "capacity",
                f"{assigned_total} nodes assigned but only {available} available",
            )
        for job, g in alloc.goodputs.items():
            if not math.isfinite(g) or g < 0.0:
                violate("finite-goodput", f"job {job!r} goodput {g!r}")
        for job, frac in alloc.fractions.items():
            if not math.isfinite(frac) or frac < -1e-9:
                violate("finite-fraction", f"job {job!r} fraction {frac!r}")

        # -- bounded scheduler caches -------------------------------------
        sched = getattr(rt.policy, "scheduler", None)
        if sched is not None:
            limit = sched.cache_limit
            if limit is None:
                limit = 8 * max(sched.n_nodes, 1)
            for label, cache in (
                ("gain", getattr(sched, "_gain_cache", {})),
                ("take", getattr(sched, "_take_cache", {})),
            ):
                for job, per_job in cache.items():
                    if len(per_job) > limit:
                        violate(
                            "cache-bound",
                            f"{label} cache for {job!r} holds {len(per_job)} "
                            f"entries > limit {limit}",
                        )

        # -- quarantine liveness ------------------------------------------
        if rt.health is not None:
            crash_nodes = {
                d["node"] for d in rt.health.detections if d["kind"] == "crash"
            }
            for nid, h in rt.health.nodes.items():
                if h.state == NodeState.QUARANTINED and h.release_epoch is None:
                    violate(
                        "quarantine-liveness",
                        f"node {nid} quarantined with no pending re-admission",
                    )
                if h.state == NodeState.CRASHED and nid not in crash_nodes:
                    violate(
                        "crash-record",
                        f"node {nid} CRASHED with no crash detection record",
                    )
                if h.backoff > rt.health.config.backoff_max:
                    violate(
                        "backoff-cap",
                        f"node {nid} backoff {h.backoff} exceeds cap "
                        f"{rt.health.config.backoff_max}",
                    )

        # -- handle/state coherence ---------------------------------------
        from repro.runtime.runtime import JobState

        for name, handle in rt.handles.items():
            if handle.state == JobState.RUNNING and not handle.nodes:
                violate("handle-state", f"RUNNING job {name!r} holds no nodes")
            if handle.state in (JobState.DONE, JobState.PREEMPTED) and handle.nodes:
                violate(
                    "handle-state",
                    f"{handle.state} job {name!r} still holds nodes {handle.nodes}",
                )

        self.checks_run += 1
        self.violations.extend(found)
        return found

    def assert_clean(self) -> None:
        """Raise AssertionError listing every violation (CI convenience)."""
        if self.violations:
            lines = "\n".join(f"  {v}" for v in self.violations)
            raise AssertionError(
                f"{len(self.violations)} runtime invariant violation(s):\n{lines}"
            )

"""Fault-recovery overhead lane: chaos replay vs its fault-free twin.

    PYTHONPATH=src:. python -m benchmarks.bench_faults [--smoke]

Replays the standard 3-job synthetic trace under the default seeded chaos
plan (one crash, one transient straggler, one flapping node, one noise
spike — see ``FaultPlan.chaos``) with the HealthMonitor detecting and the
runtime self-healing, then measures what the faults cost:

* ``goodput_retention`` — fault-free sim-time / faulted sim-time (gate:
  >= 0.8 — detection plus recovery must keep at least 80% of throughput);
* ``detection_latency_epochs`` / ``mttr_epochs`` — how fast faults are
  caught and repaired;
* wall-clock replay overhead of the fault-tolerance layer itself on a
  fault-free trace (injector + monitor present but idle).

Results merge into ``artifacts/bench/sweep.json`` under the ``"faults"``
key so the sweep artifact stays the one-stop perf record.
"""
import argparse
import json
import os
import tempfile
import time

from benchmarks.common import ARTIFACTS, Row, save_json

from repro.runtime import FaultPlan, replay, synthetic_trace

N_JOBS, N_NODES, SEED = 3, 12, 0
EPOCHS_PER_EVENT, STEPS, NOISE = 6, 2, 0.01
RETENTION_GATE = 0.8


def _replay(faults=None, health=None, checkpoint_dir=None):
    trace, _ = synthetic_trace(N_JOBS, N_NODES, seed=SEED)
    return replay(
        trace, N_NODES, policy="cannikin", epochs_per_event=EPOCHS_PER_EVENT,
        steps=STEPS, noise=NOISE, seed=SEED, faults=faults, health=health,
        checkpoint_dir=checkpoint_dir,
    )


def run(smoke: bool = False):
    rows = []
    plan = FaultPlan.chaos(N_NODES, seed=SEED)

    # Chaos lane: the default plan on the standard trace -----------------
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        rep = _replay(faults=plan, checkpoint_dir=ckpt_dir)
    chaos_s = time.perf_counter() - t0
    telemetry = rep.runtime.fault_telemetry()
    assert telemetry is not None
    retention = rep.goodput_retention
    assert retention is not None

    # Overhead lane: injector + monitor present but idle (no faults) -----
    t0 = time.perf_counter()
    base = _replay()
    plain_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    monitored = _replay(health=True)
    monitored_s = time.perf_counter() - t0
    overhead = (monitored_s - plain_s) / plain_s if plain_s > 0 else 0.0
    # The observation-only guarantee: an idle monitor changes nothing.
    assert monitored.runtime.allocation.assignment == base.runtime.allocation.assignment

    record = {
        "n_jobs": N_JOBS,
        "n_nodes": N_NODES,
        "seed": SEED,
        "epochs_per_event": EPOCHS_PER_EVENT,
        "plan": plan.describe(),
        "goodput_retention": retention,
        "retention_gate": RETENTION_GATE,
        "detection_latency_epochs": telemetry["detection_latency_epochs"],
        "mttr_epochs": telemetry["mttr_epochs"],
        "mttr_sim_seconds": telemetry["mttr_sim_seconds"],
        "detected": telemetry["detected"],
        "recoveries": telemetry["recoveries"],
        "faulted_sim_time": rep.total_sim_time,
        "fault_free_sim_time": rep.baseline.total_sim_time,
        "chaos_replay_s": chaos_s,
        "monitor_overhead_frac": overhead,
    }
    rows.append(
        Row(
            f"faults/chaos/j{N_JOBS}xn{N_NODES}",
            chaos_s * 1e6,
            f"retention={retention:.3f};lat={telemetry['detection_latency_epochs']}ep;"
            f"mttr={telemetry['mttr_epochs']}ep",
        )
    )
    rows.append(
        Row(
            f"faults/monitor_idle/j{N_JOBS}xn{N_NODES}",
            monitored_s * 1e6,
            f"overhead={overhead * 100:.1f}%",
        )
    )

    # Gate: detection + recovery must retain >= 80% of fault-free
    # throughput on the standard trace under the default chaos plan.
    # The replay is deterministic, so the gate holds in smoke runs too.
    del smoke
    assert retention >= RETENTION_GATE, (
        f"goodput retention {retention:.3f} below gate {RETENTION_GATE}"
    )

    # Merge into the sweep artifact (keep every other lane's record).
    sweep_path = os.path.join(ARTIFACTS, "bench", "sweep.json")
    payload = {}
    if os.path.exists(sweep_path):
        try:
            with open(sweep_path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    payload["faults"] = record
    save_json("sweep", payload)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for lane-runner symmetry (already CI-sized)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        print(row.csv(), flush=True)


if __name__ == "__main__":
    main()

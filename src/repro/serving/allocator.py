"""OptPerf water-fill adapter for serving: telemetry -> refit -> re-solve.

Serving and training share one allocation engine.  A decode tick on node
``i`` with batch ``b`` (its active slot count) costs

    t_i(b) = alpha_i * b + c_i        (seconds per generated token per slot)

— exactly the linear per-node cost law of §3.2, with no all-reduce
(``T_o = T_u = 0``), so the OptPerf water-fill
(:func:`repro.core.optperf.solve_optperf_batch`) over the serving
:class:`~repro.core.perf_model.ClusterPerfModel` minimizes the *max* per-node
tick time at a fixed total slot budget ``B``: every node emits tokens at the
same cadence, which is simultaneously the token-latency optimum and (for
affine costs with positive intercepts) a strictly better sustained-goodput
point than the uniform split the bench baselines against.

The linear coefficients are refitted online from observed ``(batch,
tick_time)`` pairs per node (:class:`NodeTickFitter`, the serving twin of
:class:`~repro.core.perf_model.OnlineNodeFitter`), so allocations track
drifting capacity; until a node has two distinct observed batch sizes its
row falls back to the bootstrap model the allocator was constructed with.

The (alpha, c) pair maps onto :class:`~repro.core.perf_model.NodePerfModel`
as ``q = k = alpha/2``, ``s = m = c/2`` — with a zero comm model the solver
sees ``t_compute = alpha*b + c`` and the ``k > 0`` well-posedness check
holds whenever the node does any work at all.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.optperf import round_batches, solve_optperf_batch
from repro.core.perf_model import (
    ClusterPerfModel,
    CommModel,
    NodePerfModel,
    fit_linear,
)

__all__ = [
    "serving_node_model",
    "serving_cluster_model",
    "NodeTickFitter",
    "ServingAllocator",
    "uniform_split",
]

_SERVING_COMM = CommModel(t_o=0.0, t_u=0.0, gamma=0.0)


def serving_node_model(alpha: float, c: float) -> NodePerfModel:
    """A serving node's linear tick-cost law as a NodePerfModel."""
    if alpha <= 0:
        raise ValueError("tick-cost slope must be positive")
    return NodePerfModel(q=alpha / 2.0, s=max(c, 0.0) / 2.0,
                         k=alpha / 2.0, m=max(c, 0.0) / 2.0)


def serving_cluster_model(coeffs: Sequence[Tuple[float, float]]) -> ClusterPerfModel:
    """Cluster model over ``[(alpha_i, c_i), ...]`` with a zero comm model."""
    return ClusterPerfModel(
        nodes=tuple(serving_node_model(a, c) for a, c in coeffs),
        comm=_SERVING_COMM,
    )


def uniform_split(total_slots: int, nodes: Sequence[int]) -> Dict[int, int]:
    """The heterogeneity-blind baseline: ``B/n`` slots each (remainder to the
    lowest node ids, deterministically)."""
    if not nodes:
        raise ValueError("no nodes to split over")
    n = len(nodes)
    base, rem = divmod(int(total_slots), n)
    return {
        node: base + (1 if i < rem else 0)
        for i, node in enumerate(sorted(nodes))
    }


class NodeTickFitter:
    """Bounded-window OLS fit of one node's (batch, tick time) law."""

    def __init__(self, window: int = 64):
        self._bs: List[float] = []
        self._ts: List[float] = []
        self.window = int(window)

    def observe(self, batch: float, tick_time: float) -> None:
        if batch <= 0 or tick_time <= 0:
            return  # idle ticks carry no signal
        self._bs.append(float(batch))
        self._ts.append(float(tick_time))
        if len(self._bs) > self.window:
            del self._bs[0], self._ts[0]

    @property
    def num_observations(self) -> int:
        return len(self._bs)

    def can_fit(self) -> bool:
        return len(set(self._bs)) >= 2

    def fit(self) -> Optional[Tuple[float, float]]:
        """(alpha, c) or None when unfittable / non-physical (a node whose
        measured times say "bigger batches are faster" keeps its old row —
        measurement noise must not poison the solve)."""
        if not self.can_fit():
            return None
        alpha, c = fit_linear(self._bs, self._ts)
        if alpha <= 0:
            return None
        return alpha, max(c, 0.0)

    def throughput(self) -> Optional[float]:
        """Most recent observed tokens/sec (telemetry surface)."""
        if not self._bs:
            return None
        return self._bs[-1] / self._ts[-1]


class ServingAllocator:
    """Maps serving telemetry into ClusterPerfModel refits + OptPerf solves.

    ``mode="optperf"`` water-fills the slot budget; ``mode="uniform"`` is the
    baseline even split (the bench's comparison arm) — telemetry is ingested
    either way so the two arms differ only in the solve.
    """

    def __init__(
        self,
        coeffs: Dict[int, Tuple[float, float]],
        total_slots: int,
        *,
        mode: str = "optperf",
        fit_window: int = 64,
        min_slots: int = 0,
    ):
        if total_slots <= 0:
            raise ValueError("total_slots must be positive")
        if mode not in ("optperf", "uniform"):
            raise ValueError(f"unknown allocator mode {mode!r}")
        self.total_slots = int(total_slots)
        self.mode = mode
        self.min_slots = int(min_slots)
        self._coeffs: Dict[int, Tuple[float, float]] = {
            int(node): (float(a), float(c)) for node, (a, c) in coeffs.items()
        }
        self._fitters: Dict[int, NodeTickFitter] = {
            node: NodeTickFitter(fit_window) for node in self._coeffs
        }
        self.refits = 0
        self.solves = 0

    # -- telemetry ---------------------------------------------------------

    def observe(self, node: int, batch: float, tick_time: float) -> None:
        """One decode-tick observation (batch = active slots this tick)."""
        self._fitters.setdefault(node, NodeTickFitter()).observe(batch, tick_time)

    def refit(self) -> int:
        """Fold fitted rows over the bootstrap coefficients; returns how many
        node rows changed."""
        changed = 0
        for node, fitter in self._fitters.items():
            fit = fitter.fit()
            if fit is not None and fit != self._coeffs.get(node):
                self._coeffs[node] = fit
                changed += 1
        if changed:
            self.refits += 1
        return changed

    def coeffs(self, node: int) -> Tuple[float, float]:
        return self._coeffs[node]

    def predicted_tick(self, node: int, batch: int) -> float:
        a, c = self._coeffs[node]
        return a * batch + c

    def observed_throughput(self) -> Dict[int, float]:
        out = {}
        for node, f in self._fitters.items():
            tp = f.throughput()
            if tp is not None:
                out[node] = tp
        return out

    # -- solve -------------------------------------------------------------

    def model(self, nodes: Sequence[int]) -> ClusterPerfModel:
        missing = [n for n in nodes if n not in self._coeffs]
        if missing:
            raise KeyError(f"no coefficients for nodes {missing}")
        return serving_cluster_model([self._coeffs[n] for n in nodes])

    def solve(self, nodes: Sequence[int]) -> Dict[int, int]:
        """Per-node slot allocation over the *available* node set."""
        nodes = sorted(nodes)
        if not nodes:
            return {}
        self.solves += 1
        if self.mode == "uniform":
            return uniform_split(self.total_slots, nodes)
        model = self.model(nodes)
        sol = solve_optperf_batch(model, [float(self.total_slots)])
        slots = round_batches(list(sol.batches[0]), self.total_slots)
        alloc = {node: int(b) for node, b in zip(nodes, slots)}
        if self.min_slots > 0:
            alloc = self._apply_floor(alloc, nodes)
        return alloc

    def _apply_floor(self, alloc: Dict[int, int], nodes: Sequence[int]) -> Dict[int, int]:
        """Raise starved nodes to ``min_slots``, taking slots from the
        largest allocations (keeps the total exactly ``total_slots``)."""
        floor = min(self.min_slots, self.total_slots // max(len(nodes), 1))
        for node in nodes:
            while alloc[node] < floor:
                donor = max(alloc, key=lambda n: (alloc[n], -n))
                if alloc[donor] <= floor:
                    break
                alloc[donor] -= 1
                alloc[node] += 1
        return alloc

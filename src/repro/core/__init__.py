"""Cannikin core: the paper's contribution as a composable library.

Modules:
  perf_model   — §3.2 per-node linear compute model + comm/overlap model,
                 online fitting, gamma inverse-variance weighting (Eq. 12)
  optperf      — §3.3/§4.2 OptPerf solvers (Algorithm 1 + water-fill oracle
                 + the batched all-candidates array engine)
  gns          — §4.4 heterogeneous gradient-noise-scale (Theorem 4.1)
  aggregation  — §4.3 weighted gradient aggregation (Eq. 9)
  goodput      — Pollux-style goodput + batch-size selection with caching
  batch_policy — pluggable total-batch adaptation laws (cannikin-gns,
                 adadamp/padadamp/geodamp dampers, fixed) behind one
                 registry + protocol
  simulator    — §3.2-exact heterogeneous cluster timing simulator
  controller   — §4.1/§4.5 Cannikin epoch controller
  scheduler    — beyond-paper multi-job heterogeneity-aware allocator
                 (greedy marginal goodput over stacked OptPerf rows, with
                 incremental re-allocation on job arrival/departure and
                 node availability masking for churn)
  baselines    — DDP-even / AdaptDL-even / LB-BSP comparison policies

The event-driven front door over these pieces — ClusterRuntime, JobHandle,
allocation policies, trace replay — lives in :mod:`repro.runtime`.
"""
from repro.core.aggregation import ratios, sample_weights, weighted_aggregate
from repro.core.batch_policy import (
    BATCH_POLICIES,
    BatchBounds,
    BatchProposal,
    BatchSizePolicy,
    PolicyTelemetry,
    lr_scale_for,
    make_batch_policy,
    policy_requirements,
    register_batch_policy,
)
from repro.core.controller import CannikinController, EpochPlan
from repro.core.gns import GNSState, estimate_gns, gns_update, gns_weights
from repro.core.goodput import (
    BatchSizeSelector,
    GoodputCurve,
    goodput,
    goodput_curve,
    statistical_efficiency,
)
from repro.core.optperf import (
    BatchedOptPerfSolution,
    OptPerfSolution,
    round_batches,
    solve_optperf,
    solve_optperf_algorithm1,
    solve_optperf_batch,
    solve_optperf_stacked,
    solve_optperf_waterfill,
)
from repro.core.scheduler import (
    Allocation,
    JobSpec,
    Scheduler,
    aggregate_goodput,
    allocate,
)
from repro.core.perf_model import (
    ClusterCoeffs,
    ClusterPerfModel,
    CommModel,
    NodeObservation,
    NodePerfModel,
    OnlineNodeFitter,
    StackedClusterModel,
    bootstrap_partition,
    inverse_variance_weight,
)
from repro.core.simulator import (
    GPU_CATALOG,
    NodeProfile,
    SimulatedCluster,
    cluster_A,
    cluster_B,
    cluster_C,
    make_cluster,
)

__all__ = [
    "CannikinController",
    "EpochPlan",
    "BATCH_POLICIES",
    "BatchBounds",
    "BatchProposal",
    "BatchSizePolicy",
    "PolicyTelemetry",
    "lr_scale_for",
    "make_batch_policy",
    "policy_requirements",
    "register_batch_policy",
    "ClusterPerfModel",
    "CommModel",
    "NodePerfModel",
    "NodeObservation",
    "OnlineNodeFitter",
    "OptPerfSolution",
    "BatchedOptPerfSolution",
    "ClusterCoeffs",
    "GNSState",
    "BatchSizeSelector",
    "GoodputCurve",
    "SimulatedCluster",
    "NodeProfile",
    "GPU_CATALOG",
    "solve_optperf",
    "solve_optperf_algorithm1",
    "solve_optperf_batch",
    "solve_optperf_stacked",
    "solve_optperf_waterfill",
    "StackedClusterModel",
    "Allocation",
    "JobSpec",
    "Scheduler",
    "aggregate_goodput",
    "allocate",
    "round_batches",
    "goodput_curve",
    "estimate_gns",
    "gns_update",
    "gns_weights",
    "goodput",
    "statistical_efficiency",
    "ratios",
    "sample_weights",
    "weighted_aggregate",
    "bootstrap_partition",
    "inverse_variance_weight",
    "cluster_A",
    "cluster_B",
    "cluster_C",
    "make_cluster",
]

"""Kernel micro-benchmarks: interpret-mode wall time (correctness-path cost
on CPU — NOT TPU performance) + allclose deltas vs the jnp oracles."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, save_json, time_call
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.rwkv6_wkv import wkv, wkv_ref
from repro.kernels.ssm_scan import ssm_ref, ssm_scan


def run() -> List[Row]:
    rows: List[Row] = []
    rng = jax.random.PRNGKey(0)
    payload = {}

    # flash attention
    B, S, H, D = 1, 256, 4, 64
    q = jax.random.normal(rng, (B, S, H, D))
    t_kernel = time_call(
        lambda: jax.block_until_ready(
            flash_attention(q, q, q, block_q=64, block_k=64)
        )
    )
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    t_ref = time_call(lambda: jax.block_until_ready(attention_ref(fold(q), fold(q), fold(q))))
    out = flash_attention(q, q, q, block_q=64, block_k=64)
    ref = attention_ref(fold(q), fold(q), fold(q)).reshape(B, H, S, D).transpose(0, 2, 1, 3)
    err = float(jnp.abs(out - ref).max())
    rows.append(Row("kernel/flash_attention/interpret", t_kernel, f"err={err:.1e}"))
    rows.append(Row("kernel/flash_attention/jnp_ref", t_ref, ""))
    payload["flash_attention"] = {"err": err}

    # wkv
    T, Hh, K = 128, 4, 64
    ks = jax.random.split(rng, 5)
    r = jax.random.normal(ks[0], (1, T, Hh, K)) * 0.5
    k = jax.random.normal(ks[1], (1, T, Hh, K)) * 0.5
    v = jax.random.normal(ks[2], (1, T, Hh, K)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (1, T, Hh, K)))
    u = jax.random.normal(ks[4], (Hh, K)) * 0.2
    t_kernel = time_call(lambda: jax.block_until_ready(wkv(r, k, v, lw, u)[0]))
    rows.append(Row("kernel/rwkv6_wkv/interpret", t_kernel, ""))

    # ssm scan
    Dd, N = 256, 16
    u_in = jax.random.normal(ks[0], (1, T, Dd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, T, Dd)))
    bt = jax.random.normal(ks[2], (1, T, N))
    ct = jax.random.normal(ks[3], (1, T, N))
    la = jax.random.normal(ks[4], (Dd, N)) * 0.5
    t_kernel = time_call(
        lambda: jax.block_until_ready(ssm_scan(u_in, dt, bt, ct, la, d_block=128)[0])
    )
    rows.append(Row("kernel/ssm_scan/interpret", t_kernel, ""))

    save_json("kernels", payload)
    return rows

"""The four assigned input shapes + reduced variants for smoke tests.

  train_4k     seq_len=  4,096  global_batch=256   (training)
  prefill_32k  seq_len= 32,768  global_batch= 32   (inference-prefill)
  decode_32k   seq_len= 32,768  global_batch=128   (inference-decode)
  long_500k    seq_len=524,288  global_batch=  1   (long-context-decode)

Decode shapes lower `serve_step` — ONE new token against a KV cache of
seq_len.  Prefill lowers the full forward (no loss/grad).  train_4k lowers
`train_step` (fwd+bwd+optimizer update).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

__all__ = ["InputShape", "SHAPES", "REDUCED_SHAPES"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# CPU-runnable variants for smoke tests (same kind, tiny extents).
REDUCED_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k-reduced", 32, 4, "train"),
    "prefill_32k": InputShape("prefill_32k-reduced", 64, 2, "prefill"),
    "decode_32k": InputShape("decode_32k-reduced", 64, 4, "decode"),
    "long_500k": InputShape("long_500k-reduced", 128, 1, "decode"),
}

"""Heterogeneous-cluster timing simulator.

This container has one CPU device, so the heterogeneous cluster of the paper
(mixed NVIDIA SKUs / shared GPUs) is *simulated*: each node's batch timing
follows the paper's §3.2 semantics exactly —

  * linear compute time  t_compute(b) = (q + k) b + (s + m)
  * constant ring-all-reduce time T_comm = T_o + T_u
  * bucketed overlap: node batch time =
        max(t_compute + T_u, a + gamma * P + T_comm)

with optional multiplicative measurement noise, so the *learning* pipeline
(OLS fits, gamma IVW, T_comm min-aggregation) is exercised under realistic
error — this is what §5.3's prediction-error experiment needs.

The simulator returns per-node *measurements* in the same shape the real
runtime produces (``NodeObservation``), so the controller code is identical
whether driven by simulation or by wall-clock timing of real steps.

A small catalog of GPU-like node profiles (derived from the paper's Table 1/2/3
relative speeds) provides ready-made clusters A, B, and the sharing-induced
cluster C.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.perf_model import (
    ClusterPerfModel,
    CommModel,
    NodeObservation,
    NodePerfModel,
)

__all__ = [
    "NodeProfile",
    "GPU_CATALOG",
    "make_cluster",
    "cluster_A",
    "cluster_B",
    "cluster_C",
    "SimulatedCluster",
    "StepMeasurement",
    "drift_model",
]


def _lognormal_drift(rng: np.random.Generator, rel: float, x: float) -> float:
    """One multiplicative lognormal drift draw — THE per-coefficient drift
    definition shared by :func:`drift_model` and
    :meth:`SimulatedCluster.perturbed` (keep it in one place so benchmarks
    and tests can never exercise diverging drift semantics)."""
    return float(x * math.exp(rng.normal(0.0, rel))) if rel > 0 else float(x)


def drift_model(model: ClusterPerfModel, rel: float, seed: int) -> ClusterPerfModel:
    """Epoch-over-epoch performance drift applied to a fitted model.

    Independent multiplicative lognormal jitter of scale ``rel`` on every
    node coefficient — the single source of truth for the drift scenario the
    warm-started OptPerf re-solve targets (benchmarks and tests share it)."""
    if rel < 0:
        raise ValueError("rel must be >= 0")
    rng = np.random.default_rng(seed)
    nodes = tuple(
        NodePerfModel(
            q=_lognormal_drift(rng, rel, n.q),
            s=_lognormal_drift(rng, rel, n.s),
            k=_lognormal_drift(rng, rel, n.k),
            m=_lognormal_drift(rng, rel, n.m),
        )
        for n in model.nodes
    )
    return ClusterPerfModel(nodes=nodes, comm=model.comm)


@dataclasses.dataclass(frozen=True)
class NodeProfile:
    """Ground-truth node timing profile (what the simulator knows and the
    learner must discover)."""

    name: str
    q: float  # s/sample: data load + forward + update slope
    s: float  # s: fixed overhead of the a-part
    k: float  # s/sample: backprop slope
    m: float  # s: fixed backprop overhead

    def model(self) -> NodePerfModel:
        return NodePerfModel(q=self.q, s=self.s, k=self.k, m=self.m)

    def scaled(self, speed: float, name: Optional[str] = None) -> "NodeProfile":
        """A node `speed`x faster (slopes and overheads divided)."""
        return NodeProfile(
            name=name or f"{self.name}x{speed:.2f}",
            q=self.q / speed,
            s=self.s / speed,
            k=self.k / speed,
            m=self.m / speed,
        )


# Relative FP16 speeds follow the paper's Table 1 and §6 ("A100 ~3.42x
# RTX6000").  Absolute scale is per-workload; these defaults approximate
# ResNet-50/ImageNet per-sample times.  The a-part (data loading + forward +
# update) vs backprop balance differs per node type because the host CPUs
# differ (Tables 2/3: Platinum 8380 vs Gold 6126 vs W-2102) — this is what
# separates the equal-compute fixed point (LB-BSP) from the
# equal-syncStart/mixed OptPerf configuration.
GPU_CATALOG: Dict[str, NodeProfile] = {
    "a100": NodeProfile("a100", q=0.50e-3, s=5e-3, k=1.25e-3, m=8e-3),
    "v100": NodeProfile("v100", q=1.60e-3, s=8e-3, k=2.75e-3, m=9e-3),
    "rtx6000": NodeProfile("rtx6000", q=2.80e-3, s=13e-3, k=3.18e-3, m=6e-3),
    "a5000": NodeProfile("a5000", q=1.70e-3, s=7e-3, k=3.20e-3, m=9e-3),
    "a4000": NodeProfile("a4000", q=3.40e-3, s=12e-3, k=4.45e-3, m=7e-3),
    "p4000": NodeProfile("p4000", q=8.50e-3, s=16e-3, k=9.83e-3, m=8e-3),
}


def make_cluster(
    node_names: Sequence[str],
    *,
    gamma: float = 0.15,
    t_o: float = 45e-3,
    t_u: float = 9e-3,
    workload_scale: float = 1.0,
) -> Tuple[List[NodeProfile], CommModel]:
    """Build (profiles, comm model) from catalog names. ``workload_scale``
    multiplies all compute coefficients (bigger model => bigger scale)."""
    profiles = []
    for name in node_names:
        base = GPU_CATALOG[name]
        profiles.append(
            NodeProfile(
                name=base.name,
                q=base.q * workload_scale,
                s=base.s * workload_scale,
                k=base.k * workload_scale,
                m=base.m * workload_scale,
            )
        )
    return profiles, CommModel(t_o=t_o, t_u=t_u, gamma=gamma)


def cluster_A(**kw) -> Tuple[List[NodeProfile], CommModel]:
    """Paper Table 2: a5000 + a4000 + p4000 (3 nodes)."""
    return make_cluster(["a5000", "a4000", "p4000"], **kw)


def cluster_B(**kw) -> Tuple[List[NodeProfile], CommModel]:
    """Paper Table 3: 4x A100 + 4x V100 + 8x RTX6000 (16 GPUs, GPU=node)."""
    return make_cluster(["a100"] * 4 + ["v100"] * 4 + ["rtx6000"] * 8, **kw)


def cluster_C(n: int = 16, **kw) -> Tuple[List[NodeProfile], CommModel]:
    """Paper §6: sharing-induced heterogeneity — homogeneous RTX6000s whose
    effective speed is evenly spread between 1.0 (full GPU) and 0.25 (quarter
    GPU), mimicking the dummy-workload construction."""
    profiles, comm = make_cluster(["rtx6000"] * n, **kw)
    speeds = np.linspace(1.0, 0.25, n)
    profiles = [p.scaled(sp, name=f"rtx6000@{sp:.2f}") for p, sp in zip(profiles, speeds)]
    return profiles, comm


@dataclasses.dataclass(frozen=True)
class StepMeasurement:
    """What one simulated batch produces, per node."""

    batch_time: float                      # cluster batch time (max over nodes)
    node_times: Tuple[float, ...]          # per-node batch times
    observations: Tuple[NodeObservation, ...]


class SimulatedCluster:
    """Executes the paper's timing semantics with measurement noise.

    ``noise``: multiplicative stddev on every measured quantity (the paper's
    Figure 6 shows gamma measurement noise varies per GPU; we give each node a
    distinct noise level drawn once, so inverse-variance weighting has signal
    to exploit).
    """

    def __init__(
        self,
        profiles: Sequence[NodeProfile],
        comm: CommModel,
        *,
        noise: float = 0.0,
        per_node_gamma_noise: Optional[Sequence[float]] = None,
        seed: int = 0,
    ) -> None:
        self.profiles = list(profiles)
        self.comm = comm
        self.noise = noise
        self._rng = np.random.default_rng(seed)
        if per_node_gamma_noise is None:
            # Heteroscedastic gamma noise in [0.3, 2.2]x of base noise.
            per_node_gamma_noise = self._rng.uniform(0.3, 2.2, len(self.profiles)) * max(
                noise, 1e-12
            )
        self.gamma_noise = np.asarray(per_node_gamma_noise, dtype=np.float64)
        # Coefficient arrays for the vectorized timing path (profiles are
        # frozen dataclasses and the list is never mutated after init).
        self._qs = np.array([p.q for p in self.profiles], dtype=np.float64)
        self._ss = np.array([p.s for p in self.profiles], dtype=np.float64)
        self._ks = np.array([p.k for p in self.profiles], dtype=np.float64)
        self._ms = np.array([p.m for p in self.profiles], dtype=np.float64)

    @property
    def n(self) -> int:
        return len(self.profiles)

    def true_model(self) -> ClusterPerfModel:
        return ClusterPerfModel(
            nodes=tuple(p.model() for p in self.profiles), comm=self.comm
        )

    def _jitter(self, value: float, scale: Optional[float] = None) -> float:
        s = self.noise if scale is None else scale
        if s <= 0:
            return value
        return float(value * math.exp(self._rng.normal(0.0, s)))

    def run_batch(self, batches: Sequence[int]) -> StepMeasurement:
        """Simulate one synchronous batch with local batch sizes ``batches``.

        Per-node reported T_comm includes the wait-for-others term the paper
        describes (fast nodes observe inflated communication time), so the
        min-aggregation in the learner is actually exercised.
        """
        if len(batches) != self.n:
            raise ValueError("batch vector length mismatch")
        comm, gamma = self.comm, self.comm.gamma
        b = np.asarray(batches, dtype=np.float64)
        a_times = self._qs * b + self._ss
        p_times = self._ks * b + self._ms
        if self.noise > 0:
            # One vectorized draw consumes the bit stream exactly like the
            # historical per-node (a, p) interleaved scalar draws.
            eps = self._rng.normal(0.0, self.noise, size=(self.n, 2))
            a_times = a_times * np.exp(eps[:, 0])
            p_times = p_times * np.exp(eps[:, 1])
        sync_starts = a_times + gamma * p_times

        # Ring all-reduce is collective: the last bucket cannot complete
        # before every node reaches its own syncStart + remaining compute.
        # Node batch time per §3.2.3 (max form), with the *cluster-wide*
        # all-reduce gating: every node ends at the same sync-finish time for
        # the final bucket, but local compute may extend past it.
        last_sync_finish = float(
            max(
                (sync_starts + comm.t_comm).max(),
                (a_times + p_times + comm.t_u).max(),
            )
        )
        node_times = (last_sync_finish,) * self.n  # synchronous: all end together

        # Measurement jitter, preserving the historical draw order
        # [gamma_0, comm_0, gamma_1, comm_1, ...] with zero-scale draws
        # skipped (matching the scalar _jitter early-return).
        scales = np.empty((self.n, 2), dtype=np.float64)
        scales[:, 0] = self.gamma_noise
        scales[:, 1] = self.noise
        flat = scales.reshape(-1)
        factors = np.ones(2 * self.n, dtype=np.float64)
        drawn = flat > 0
        if drawn.any():
            factors[drawn] = np.exp(self._rng.normal(0.0, flat[drawn]))
        factors = factors.reshape(self.n, 2)
        measured_gammas = np.clip(gamma * factors[:, 0], 0.0, 1.0)
        # Reported comm time = true T_comm + waiting (nodes that reach
        # syncStart early observe a longer "communication" phase).
        wait = last_sync_finish - (sync_starts + comm.t_comm)
        reported_comm = (comm.t_comm + np.maximum(wait, 0.0)) * factors[:, 1]

        observations = tuple(
            NodeObservation(
                batch_size=float(bi),
                a_time=float(a_times[i]),
                backprop_time=float(p_times[i]),
                gamma=float(measured_gammas[i]),
                comm_time=float(reported_comm[i]),
            )
            for i, bi in enumerate(batches)
        )
        return StepMeasurement(
            batch_time=last_sync_finish,
            node_times=node_times,
            observations=observations,
        )

    def run_epoch(
        self, batches: Sequence[int], steps: int
    ) -> Tuple[float, List[StepMeasurement]]:
        """Simulate ``steps`` batches; returns (epoch seconds, measurements)."""
        measurements = [self.run_batch(batches) for _ in range(steps)]
        return sum(m.batch_time for m in measurements), measurements

    def perturbed(
        self,
        rel: float,
        *,
        seed: int = 0,
        perturb_comm: bool = False,
    ) -> "SimulatedCluster":
        """A cluster whose ground-truth coefficients drifted by ~``rel``.

        Models epoch-to-epoch performance drift (thermal throttling, shared
        hosts, background load) as independent multiplicative lognormal
        jitter on every node coefficient — the scenario the warm-started
        OptPerf re-solve is built for.  ``perturb_comm`` additionally drifts
        T_o/T_u.  Measurement-noise settings and the per-node gamma noise
        profile carry over; the RNG is freshly seeded so drifted clusters
        are reproducible.
        """
        if rel < 0:
            raise ValueError("rel must be >= 0")
        rng = np.random.default_rng(seed)
        profiles = [
            NodeProfile(
                name=p.name,
                q=_lognormal_drift(rng, rel, p.q),
                s=_lognormal_drift(rng, rel, p.s),
                k=_lognormal_drift(rng, rel, p.k),
                m=_lognormal_drift(rng, rel, p.m),
            )
            for p in self.profiles
        ]
        comm = self.comm
        if perturb_comm:
            comm = CommModel(
                t_o=_lognormal_drift(rng, rel, comm.t_o),
                t_u=_lognormal_drift(rng, rel, comm.t_u),
                gamma=comm.gamma,
            )
        return SimulatedCluster(
            profiles,
            comm,
            noise=self.noise,
            per_node_gamma_noise=self.gamma_noise,
            seed=seed + 1,
        )

"""Fig. 7/8 reproduction: normalized convergence time across workloads and
policies on cluster B, and Fig. 5 (chosen batch sizes + statistical parity).

Statistical behaviour follows the McCandlish/Pollux model: reaching the
target requires a fixed *effective sample budget* E_total = sum over epochs
of B_epoch * efficiency(B_epoch); system behaviour (epoch wall-clock) comes
from the §3.2 simulator.  Cannikin and AdaptDL share the same GNS engine
(identical statistics — the paper's Fig. 5b parity); they differ in the
partition (OptPerf vs even) and in throughput-aware batch selection.
Policies:
  cannikin     — OptPerf partition + goodput-optimal total batch
  adaptdl      — even partition + goodput-optimal total batch (homog. model)
  pytorch-ddp  — even partition, fixed total batch
  lb-bsp       — converged compute-balanced partition, fixed total batch
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import Row, save_json
from repro.core.goodput import goodput_curve, statistical_efficiency
from repro.core.simulator import SimulatedCluster, cluster_B
from benchmarks.bench_batchtime import WORKLOADS, lbbsp_converged

# Per-workload gradient-noise scale at convergence-relevant scale and the
# sample budget to target (arbitrary units; ratios drive the comparison).
GNS = {
    "resnet50-imagenet": 6000.0,
    "resnet18-cifar10": 900.0,
    "deepspeech2-librispeech": 3000.0,
    "bert-squad": 1500.0,
    "neumf-movielens": 400.0,
}
BUDGET_EPOCH_SAMPLES = 80_000  # samples per "epoch" of the simulation
TARGET_BUDGET = 1_600_000     # effective samples to reach target metric


def _policy_epoch(policy, truth, b_noise, ref_batch, candidates):
    """Return (total batch, partition) for one epoch under a policy."""
    if policy == "cannikin":
        # The whole candidate sweep is one batched OptPerf array pass.
        curve = goodput_curve(truth, [float(B) for B in candidates], b_noise, ref_batch)
        best, sol, _ = curve.best()
        return int(best), list(sol.batches)
    if policy == "adaptdl":
        n = len(truth.nodes)
        cands = np.asarray(candidates, dtype=np.float64)
        even = np.repeat(cands[:, None] / n, n, axis=1)       # (C, n) even shards
        times = truth.node_times(even).max(axis=-1)
        gps = (cands / times) * statistical_efficiency(b_noise, cands, ref_batch)
        best = int(cands[int(np.argmax(gps))])
        return best, [best / n] * n
    if policy == "pytorch-ddp":
        return ref_batch, [ref_batch / len(truth.nodes)] * len(truth.nodes)
    if policy == "lb-bsp":
        return ref_batch, lbbsp_converged(truth, ref_batch)
    raise ValueError(policy)


def run() -> List[Row]:
    rows: List[Row] = []
    payload: Dict = {}
    for wl, (cscale, mscale) in WORKLOADS.items():
        profiles, comm = cluster_B(
            workload_scale=cscale, t_o=0.045 * mscale, t_u=0.009 * mscale
        )
        truth = SimulatedCluster(profiles, comm, noise=0.0).true_model()
        b_noise = GNS[wl]
        ref_batch = 128
        candidates = [128, 256, 512, 1024, 2048, 4096]
        results = {}
        chosen_batches = {}
        for policy in ("cannikin", "adaptdl", "pytorch-ddp", "lb-bsp"):
            effective = 0.0
            wall = 0.0
            epochs = 0
            picks = []
            while effective < TARGET_BUDGET and epochs < 500:
                B, split = _policy_epoch(policy, truth, b_noise, ref_batch, candidates)
                picks.append(B)
                steps = max(int(BUDGET_EPOCH_SAMPLES // B), 1)
                wall += steps * truth.cluster_time(split)
                effective += steps * B * statistical_efficiency(b_noise, B, ref_batch)
                epochs += 1
            results[policy] = wall
            chosen_batches[policy] = picks[:5]
        norm = {k: v / results["cannikin"] for k, v in results.items()}
        payload[wl] = {
            "wall_seconds": results,
            "normalized": norm,
            "first_batches": chosen_batches,
            "reduction_vs_ddp": 1 - results["cannikin"] / results["pytorch-ddp"],
            "reduction_vs_adaptdl": 1 - results["cannikin"] / results["adaptdl"],
            "reduction_vs_lbbsp": 1 - results["cannikin"] / results["lb-bsp"],
        }
        rows.append(
            Row(
                f"fig8/{wl}",
                0.0,
                (
                    f"vs_ddp={payload[wl]['reduction_vs_ddp']:.1%};"
                    f"vs_adaptdl={payload[wl]['reduction_vs_adaptdl']:.1%};"
                    f"vs_lbbsp={payload[wl]['reduction_vs_lbbsp']:.1%}"
                ),
            )
        )
    # Fig. 5 analogue: Cannikin picks batch sizes >= AdaptDL's (throughput-
    # aware goodput peaks later), with identical statistical model.
    cb = payload["resnet18-cifar10"]["first_batches"]
    rows.append(
        Row(
            "fig5/batch_choice",
            0.0,
            f"cannikin={cb['cannikin'][0]};adaptdl={cb['adaptdl'][0]}",
        )
    )
    save_json("convergence_fig8", payload)
    return rows

"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536 (per routed
expert) vocab=102400; MLA kv_lora=512; 2 shared + 160 routed experts, top-6
[arXiv:2405.04434].

Layer 0 is a dense SwiGLU FFN (hidden 12288) per the source paper; the
remaining 59 layers are MoE.  Decode uses the absorbed MLA form against the
576-float/token latent cache (qualifies long_500k — DESIGN.md §5).
"""
from repro.models.deepseek import DeepSeekConfig

ARCH_ID = "deepseek-v2-236b"


def config() -> DeepSeekConfig:
    return DeepSeekConfig(
        name=ARCH_ID,
        n_layers=60,
        d_model=5120,
        n_heads=128,
        d_ff_expert=1536,
        d_ff_dense=12288,
        vocab=102400,
        n_experts=160,
        top_k=6,
        n_shared_experts=2,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    )


def reduced() -> DeepSeekConfig:
    return DeepSeekConfig(
        name=ARCH_ID + "-reduced",
        n_layers=2,
        d_model=128,
        n_heads=4,
        d_ff_expert=64,
        d_ff_dense=256,
        vocab=512,
        n_experts=4,
        top_k=2,
        n_shared_experts=1,
        q_lora_rank=48,
        kv_lora_rank=32,
        qk_nope_dim=32,
        qk_rope_dim=16,
        v_head_dim=32,
        capacity_factor=8.0,  # dropless at smoke scale: decode == forward
        remat=False,
    )

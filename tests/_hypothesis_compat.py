"""Optional-hypothesis shim: ``from _hypothesis_compat import hypothesis, st``.

When hypothesis is installed this re-exports the real modules.  When it is
not, a stand-in stub makes every ``@hypothesis.given(...)``-decorated test
collect as a *skipped* test (reason: hypothesis not installed), so the suite
degrades instead of erroring at collection — the deterministic tests in the
same module still run.
"""
import functools

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    class _SkipStub:
        """Absorbs any attribute access / strategy construction; decorating a
        test function with it yields a skip-marked replacement."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            decorating = (
                len(args) == 1
                and not kwargs
                and callable(args[0])
                and not isinstance(args[0], _SkipStub)
            )
            if decorating:
                fn = args[0]

                # skip mark outermost: functools.wraps copies fn.__dict__
                # (which may hold fn's own pytestmark) and must not be able
                # to overwrite the skip.
                @pytest.mark.skip(reason="hypothesis not installed")
                @functools.wraps(fn)
                def replacement(*a, **k):
                    # Reached only when called as a strategy factory (e.g. a
                    # stubbed @st.composite function); never as a test body.
                    return _SkipStub()

                return replacement
            return _SkipStub()

    hypothesis = st = _SkipStub()

__all__ = ["hypothesis", "st"]

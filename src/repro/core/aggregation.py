"""Weighted gradient aggregation for unequal local batch sizes (§4.3, Eq. 9).

With heterogeneous local batches, averaging local gradients over-weights
samples on small-batch nodes.  The unbiased aggregate is

    g = sum_i r_i g_i,     r_i = b_i / B,

which gives every sample identical weight — exactly the gradient a single
worker would compute over the union batch.

Two executable forms are provided:

1. :func:`weighted_aggregate` — explicit pytree combination (controller /
   simulator / per-node shard_map view).
2. :func:`sample_weights` — the per-sample weight vector that makes a single
   pjit'd *weighted-mean loss* over the padded global batch reproduce Eq. (9)
   bit-for-bit.  This is the GSPMD-native realization: pad every node's shard
   to ``b_max``, weight pads 0 and real samples 1/B, and let XLA's psum do the
   ring all-reduce.  tests/test_aggregation.py asserts the equivalence.

:func:`guard_weights` hardens Eq. (9) against integrity faults: a node whose
gradient contribution is non-finite (NaN/Inf) or a gross norm outlier is
excluded from the aggregate *before* it can pollute the global update, with
the surviving weights renormalized.  The guard is jit-traceable and exactly
transparent when every contribution is healthy (the all-valid branch selects
the original ``r`` vector bitwise), so fault-free runs stay bit-identical.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ratios",
    "weighted_aggregate",
    "sample_weights",
    "padded_batch_layout",
    "guard_weights",
    "ANOMALY_OUTLIER_FACTOR",
]

# A per-node gradient norm this many times the (finite) median norm counts
# as an anomaly.  Healthy per-node gradients over same-distribution shards
# differ by small factors (batch noise); a poisoned node is off by orders of
# magnitude, so the default leaves a wide safety margin in both directions.
ANOMALY_OUTLIER_FACTOR = 100.0


def ratios(batches: Sequence[int]) -> np.ndarray:
    """r_i = b_i / B."""
    b = np.asarray(batches, dtype=np.float64)
    if np.any(b < 0) or b.sum() <= 0:
        raise ValueError("invalid batch vector")
    return b / b.sum()


def weighted_aggregate(local_grads: Sequence, batches: Sequence[int]):
    """Eq. (9): g = sum_i r_i g_i over arbitrary gradient pytrees."""
    r = ratios(batches)
    if len(local_grads) != len(r):
        raise ValueError("gradient/batch count mismatch")

    def combine(*leaves):
        out = leaves[0] * r[0]
        for w, leaf in zip(r[1:], leaves[1:]):
            out = out + w * leaf
        return out

    return jax.tree_util.tree_map(combine, *local_grads)


def guard_weights(
    sq_norms,
    weights,
    *,
    outlier_factor: float = ANOMALY_OUTLIER_FACTOR,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Anomaly-guarded Eq. (9) weights from per-node |g_i|^2.

    Returns ``(guarded_weights, valid)`` where ``valid[i]`` is False when
    node i's squared gradient norm is non-finite or exceeds
    ``outlier_factor**2`` times the median of the finite norms.  Invalid
    nodes get weight 0 and the surviving weights are renormalized to sum
    to 1; when *every* node is valid the original ``weights`` vector is
    returned unchanged (bitwise — the no-fault transparency guarantee).
    When every node is invalid all weights are 0: the aggregate vanishes
    and the update is a no-op rather than a poisoned step.

    Pure ``jnp`` — safe to call inside a jitted train step.
    """
    sq = jnp.asarray(sq_norms)
    r = jnp.asarray(weights)
    finite = jnp.isfinite(sq)
    # Median of the finite norms; NaN when nothing is finite (then the
    # outlier comparison is False and validity reduces to finiteness).
    med = jnp.nanmedian(jnp.where(finite, sq, jnp.nan))
    outlier = sq > (outlier_factor ** 2) * jnp.maximum(med, 1e-30)
    valid = finite & ~outlier
    masked = jnp.where(valid, r, 0.0)
    total = jnp.sum(masked)
    renorm = jnp.where(total > 0.0, masked / jnp.maximum(total, 1e-30), masked)
    guarded = jnp.where(jnp.all(valid), r, renorm)
    return guarded, valid


def padded_batch_layout(batches: Sequence[int]) -> Tuple[int, np.ndarray]:
    """Given per-node batches, return (b_max, mask) where mask has shape
    (n, b_max) with 1 for real samples and 0 for pads."""
    b = np.asarray(batches, dtype=np.int64)
    b_max = int(b.max())
    n = b.size
    mask = (np.arange(b_max)[None, :] < b[:, None]).astype(np.float32)
    return b_max, mask


def sample_weights(batches: Sequence[int]) -> np.ndarray:
    """Per-sample weights over the padded (n, b_max) layout such that a
    weighted-SUM loss  L = sum_j w_j * l_j  has gradient identical to Eq. (9)
    where each l_j is the per-sample loss.

    Each real sample gets 1/B; pads get 0.  Then
        grad = sum_i sum_{j in node i} (1/B) grad_j
             = sum_i (b_i/B) * (1/b_i) sum_j grad_j = sum_i r_i g_i.
    """
    b = np.asarray(batches, dtype=np.int64)
    total = int(b.sum())
    if total <= 0:
        raise ValueError("empty batch")
    _, mask = padded_batch_layout(batches)
    return mask / float(total)

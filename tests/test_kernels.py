"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs the pure-jnp
oracles (interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX-compiling; excluded from the fast lane

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.rwkv6_wkv import wkv, wkv_ref
from repro.kernels.ssm_scan import ssm_ref, ssm_scan

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=0.05, atol=0.05)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,t,h,kv,d,causal,window",
    [
        (2, 128, 128, 4, 4, 64, True, None),
        (1, 256, 256, 4, 2, 32, True, None),
        (2, 100, 100, 2, 2, 64, True, None),    # non-block-multiple (padding)
        (1, 256, 256, 4, 4, 64, True, 64),      # sliding window
        (2, 64, 192, 2, 2, 32, False, None),    # cross-attention lengths
        (1, 128, 128, 8, 2, 128, True, None),   # GQA rep 4, MXU-width head
    ],
)
def test_flash_attention_sweep(b, s, t, h, kv, d, causal, window, dtype):
    rng = jax.random.PRNGKey(42)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, t, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, t, kv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, block_q=64, block_k=64)
    rep = h // kv
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], d)
    ref = attention_ref(fold(q), fold(kr), fold(vr), causal=causal, window=window)
    ref = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,t,h,k,chunk",
    [(2, 128, 4, 64, 32), (1, 96, 2, 32, 32), (2, 64, 4, 64, 16), (1, 40, 2, 64, 32)],
)
def test_wkv_sweep(b, t, h, k, chunk, dtype):
    rng = jax.random.PRNGKey(7)
    ks = jax.random.split(rng, 5)
    r = (jax.random.normal(ks[0], (b, t, h, k)) * 0.5).astype(dtype)
    kk = (jax.random.normal(ks[1], (b, t, h, k)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (b, t, h, k)) * 0.5).astype(dtype)
    lw = (-jnp.exp(jax.random.normal(ks[3], (b, t, h, k)))).astype(jnp.float32)
    u = jax.random.normal(ks[4], (h, k), jnp.float32) * 0.2
    out, s = wkv(r, kk, v, lw, u, chunk=chunk)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, t, x.shape[-1])
    uu = jnp.broadcast_to(u[None], (b, h, k)).reshape(b * h, k)
    oref, sref = wkv_ref(
        fold(r).astype(jnp.float32),
        fold(kk).astype(jnp.float32),
        fold(v).astype(jnp.float32),
        fold(lw),
        uu,
    )
    oref = oref.reshape(b, h, t, k).transpose(0, 2, 1, 3)
    sref = sref.reshape(b, h, k, k)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(oref, np.float32), **TOL[dtype]
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(sref), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,t,d,n,chunk,d_block",
    [(2, 64, 128, 16, 32, 64), (1, 100, 64, 8, 32, 32), (2, 128, 256, 16, 64, 128)],
)
def test_ssm_scan_sweep(b, t, d, n, chunk, d_block, dtype):
    rng = jax.random.PRNGKey(3)
    ks = jax.random.split(rng, 5)
    u = jax.random.normal(ks[0], (b, t, d), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, d))).astype(dtype)
    bt = jax.random.normal(ks[2], (b, t, n), dtype)
    ct = jax.random.normal(ks[3], (b, t, n), dtype)
    la = (jax.random.normal(ks[4], (d, n)) * 0.5).astype(jnp.float32)
    y, h = ssm_scan(u, dt, bt, ct, la, chunk=chunk, d_block=d_block)
    yr, hr = ssm_ref(
        u.astype(jnp.float32), dt.astype(jnp.float32),
        bt.astype(jnp.float32), ct.astype(jnp.float32), la,
    )
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), **TOL[dtype]
    )
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-2, atol=2e-2)


def test_kernels_jit_compatible():
    """ops.py wrappers must be jittable (the production path)."""
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (1, 128, 2, 64))
    out = jax.jit(lambda q: flash_attention(q, q, q))(q)
    assert out.shape == q.shape


def test_kernel_model_paths_match_jnp():
    """use_kernel=True routes RWKV6/Hymba through the Pallas kernels; the
    model logits must match the jnp path (first-class kernel integration)."""
    import dataclasses

    from repro.configs import get_api

    for arch in ("rwkv6-7b", "hymba-1.5b"):
        api = get_api(arch, reduced=True)
        api_k = dataclasses.replace(
            api, cfg=dataclasses.replace(api.cfg, use_kernel=True)
        )
        params = api.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, api.cfg.vocab)
        base = api.logits(params, {"tokens": toks})
        kern = api_k.logits(params, {"tokens": toks})
        scale = max(float(jnp.abs(base).max()), 1.0)
        assert float(jnp.abs(base - kern).max()) / scale < 0.05

"""ClusterRuntime: the event-driven reconcile loop over scheduler,
controller, and simulator.

The paper's Cannikin system is a *runtime*: it observes steps, refits the
performance model, re-plans batch sizes, and reallocates nodes as jobs and
hardware come and go.  :class:`ClusterRuntime` is that loop as one object:

* events (:mod:`repro.runtime.events`) enter a single queue and are
  reconciled deterministically in ``(time, post-order)`` order;
* each event maps onto exactly one incremental entry point of the active
  allocation :class:`~repro.runtime.policy.Policy` (for ``cannikin``, the
  incremental :class:`~repro.core.scheduler.Scheduler` — cached rows and
  warm bracket seeds make every event an incremental re-allocation, never
  a cold solve);
* the resulting :class:`~repro.core.scheduler.Allocation` is pushed down
  to per-job :class:`JobHandle` lifecycle objects, each owning its own
  :class:`~repro.core.controller.CannikinController` (the paper's elastic
  ``add_nodes``/``remove_nodes`` reconfiguration runs on every node-set
  change) and a per-job
  :class:`~repro.runtime.backend.ExecutionBackend` — the timing simulator
  (``backend="sim"``, built from the job's own ground-truth node models)
  or real JAX gradients (``backend="real"``), whichever the
  :class:`JobSpec` names;
* :meth:`ClusterRuntime.advance` steps every running job's epoch loop
  (plan → execute → observe over its backend), so a replayed trace yields
  both allocation decisions *and* training behaviour (bootstrap → optperf,
  unified :class:`~repro.runtime.backend.EpochRecord` telemetry,
  ControllerStats);
* :class:`Preemption` is checkpointed for real backends: params/opt-state/
  GNS state are snapshotted (and written via :mod:`repro.train.checkpoint`
  when the runtime has a ``checkpoint_dir``) on preempt and restored
  bit-exactly on re-admission.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.batch_policy import policy_requirements
from repro.core.controller import CannikinController, ControllerStats, EpochPlan
from repro.core.scheduler import Allocation, JobSpec
from repro.core.simulator import drift_model
from repro.runtime.backend import (
    EpochRecord,
    ExecutionBackend,
    RealBackendConfig,
    make_backend,
    run_backend_epoch,
)
from repro.runtime.events import (
    Event,
    JobArrival,
    JobCompletion,
    ModelRefit,
    NodeJoin,
    NodeLeave,
    Preemption,
    describe,
)
from repro.runtime.faults import FaultInjector, FaultPlan
from repro.runtime.health import (
    CrashDetected,
    HealthConfig,
    HealthMonitor,
    QuarantineNode,
    ReadmitNode,
    RefitRequested,
)
from repro.runtime.invariants import RuntimeInvariantChecker
from repro.runtime.policy import Policy, make_policy
from repro.runtime.watchdog import Watchdog

__all__ = [
    "JobState",
    "JobHandle",
    "ReconcileRecord",
    "ClusterRuntime",
    "drift_spec",
]


class JobState:
    """Job lifecycle: submit → PENDING → RUNNING ⇄ PREEMPTED → DONE."""

    PENDING = "pending"       # submitted, currently holds no nodes
    RUNNING = "running"       # holds >= 1 node
    PREEMPTED = "preempted"   # pulled off the cluster; resumable
    DONE = "done"             # completed; terminal


def drift_spec(spec: JobSpec, rel: float, seed: int) -> JobSpec:
    """A job spec whose node coefficients drifted by ~``rel`` (the seeded
    lognormal jitter of :func:`repro.core.simulator.drift_model`) — the
    deterministic payload behind :class:`ModelRefit` events."""
    drifted = drift_model(spec.full_model, rel, seed)
    return dataclasses.replace(spec, node_models=drifted.nodes)


class JobHandle:
    """Lifecycle object for one submitted job.

    Owns the job's :class:`CannikinController` (created when the job first
    receives nodes; *kept* across preemption and node churn so learned
    models survive, exactly the paper's §6 elastic semantics) and the
    job's :class:`~repro.runtime.backend.ExecutionBackend` — whichever
    engine ``spec.backend`` names (``"sim"``: the job's own ground-truth
    node models as a timing simulator; ``"real"``: real JAX gradients).
    ``advance`` is one plan → execute → observe loop over that backend.
    Surfaces unified :class:`~repro.runtime.backend.EpochRecord` telemetry
    (``records``), :class:`EpochPlan`s, and :class:`ControllerStats`.

    Preemption checkpoints the backend's statistical state (params,
    opt-state, GNS state, stream counters for a real backend; nothing for
    the sim): in memory always, and to ``<checkpoint_dir>/<job>.ckpt.npz``
    when the runtime has a checkpoint directory.  Re-admission restores it
    — from the file when one exists (the cross-process semantics), else
    from the in-memory snapshot — before the first post-resume epoch.
    """

    def __init__(
        self,
        spec: JobSpec,
        *,
        submitted_at: float = 0.0,
        noise: float = 0.0,
        seed: int = 0,
        real_config: Optional[RealBackendConfig] = None,
        checkpoint_dir: Optional[str] = None,
        injector: Optional[FaultInjector] = None,
    ) -> None:
        self.spec = spec
        self.state = JobState.PENDING
        self.nodes: Tuple[int, ...] = ()
        self.controller: Optional[CannikinController] = None
        self.backend: Optional[ExecutionBackend] = None
        self.submitted_at = submitted_at
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.epochs_run = 0
        self.sim_time = 0.0
        self.reallocations = 0
        self.preemptions = 0
        self.ckpt_write_failures = 0
        self.ckpt_fallbacks = 0
        self.ckpt_rollbacks = 0
        self.restores = 0
        self.records: List[EpochRecord] = []
        self.last_result = None  # the most recent epoch's ExecutionResult
        self.checkpoint_path: Optional[str] = None
        self._ctl_nodes: Tuple[int, ...] = ()  # node ids behind controller idx 0..n-1
        self._noise = noise
        self._seed = seed
        self._real_config = real_config
        self._ckpt_dir = checkpoint_dir
        self._injector = injector
        self._ckpt_manager = None  # lazy CheckpointManager (needs _ckpt_dir)
        self._snapshot: Optional[dict] = None
        self._resume_pending = False

    # -- observability ---------------------------------------------------

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def stats(self) -> Optional[ControllerStats]:
        return self.controller.stats if self.controller is not None else None

    @property
    def last_plan(self) -> Optional[EpochPlan]:
        return self.controller.last_plan if self.controller is not None else None

    @property
    def last_record(self) -> Optional[EpochRecord]:
        return self.records[-1] if self.records else None

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"JobHandle({self.name!r}, state={self.state}, nodes={self.nodes}, "
            f"epochs={self.epochs_run})"
        )

    # -- reconcile surface (driven by ClusterRuntime) --------------------

    def _new_controller(self, n: int) -> CannikinController:
        policy_name = getattr(self.spec, "batch_policy", None)
        if policy_name is not None:
            return self._policy_controller(n, policy_name)
        if self.spec.backend == "real":
            # Real gradients feed the GNS tracker, so total-batch adaptivity
            # is live: the controller sweeps {B, 2B} against the measured
            # gradient noise scale (§4.4).
            total = self.spec.total_batch
            return CannikinController(
                n,
                batch_candidates=sorted({total, 2 * total}),
                ref_batch=self.spec.ref_batch,
                adaptive=True,
            )
        # Sim-backend trace jobs train at the spec's fixed total batch: no
        # gradients exist, so the runtime optimizes the *split* (OptPerf
        # partition) and the allocation only.
        return CannikinController(
            n,
            batch_candidates=[self.spec.total_batch],
            ref_batch=self.spec.total_batch,
            adaptive=False,
        )

    def _policy_controller(self, n: int, name: str) -> CannikinController:
        """Build the controller for an explicit ``JobSpec.batch_policy``.

        GNS-driven policies need gradient telemetry, so on a gradient-free
        backend they collapse to the fixed-batch controller (b_noise would
        sit at inf and every proposal would degenerate to the reference
        batch anyway — this is the runtime-level mirror of the launch-layer
        guard).  Schedule-driven policies (empty ``requires``) run
        adaptively on *any* backend — the point of the damper family."""
        total = self.spec.total_batch
        needs_gns = "gns" in policy_requirements(name)
        if name == "fixed" or (needs_gns and self.spec.backend != "real"):
            return CannikinController(
                n,
                batch_candidates=[total],
                ref_batch=total,
                adaptive=False,
            )
        if needs_gns:
            return CannikinController(
                n,
                batch_candidates=sorted({total, 2 * total}),
                ref_batch=self.spec.ref_batch,
                adaptive=True,
                batch_policy=name,
            )
        # Gradient-free damper: candidates span the schedule's range so the
        # controller's bounds let the ramp actually move.
        return CannikinController(
            n,
            batch_candidates=sorted({self.spec.ref_batch, total, 2 * total}),
            ref_batch=self.spec.ref_batch,
            adaptive=True,
            batch_policy=name,
        )

    def set_nodes(self, nodes: Sequence[int], *, now: float = 0.0) -> None:
        """Apply a new node assignment, resizing the controller elastically.

        Node ids kept across the change keep their fitted models
        (``remove_nodes`` semantics); new ids bootstrap for two epochs
        (``add_nodes``).  Controller index ``i`` always corresponds to
        ``self._ctl_nodes[i]``; the per-job simulator follows that order.
        """
        nodes = tuple(int(n) for n in nodes)
        if nodes == self.nodes:
            return
        self.reallocations += 1
        self.nodes = nodes
        if not nodes:
            if self.state == JobState.RUNNING:
                self.state = JobState.PENDING
            return
        new_set = set(nodes)
        if self.controller is None:
            self.controller = self._new_controller(len(nodes))
            self._ctl_nodes = nodes
        else:
            old = self._ctl_nodes
            dropped_idx = [i for i, nid in enumerate(old) if nid not in new_set]
            if old and len(dropped_idx) == len(old):
                # Total replacement: nothing learned carries over.
                self.controller = self._new_controller(len(nodes))
                self._ctl_nodes = nodes
            else:
                if dropped_idx:
                    self.controller.remove_nodes(dropped_idx)
                kept = tuple(nid for nid in old if nid in new_set)
                added = tuple(nid for nid in nodes if nid not in set(old))
                if added:
                    self.controller.add_nodes(len(added))
                self._ctl_nodes = kept + added
        self._bind_backend()
        if self._resume_pending:
            self._restore_backend()
            self._resume_pending = False
        if self.state in (JobState.PENDING, JobState.PREEMPTED):
            self.state = JobState.RUNNING
            if self.started_at is None:
                self.started_at = now

    def _bind_backend(self) -> None:
        """(Build and) bind the spec's execution backend to the currently
        held nodes.  The backend object itself persists across node churn
        and preemption — only its timing cluster follows the node set — so
        learned statistical state (params, opt-state, GNS) survives.  A
        re-arrival whose spec names a *different* backend kind gets a fresh
        engine (its statistical state necessarily starts over)."""
        if self.backend is None or self.backend.kind != self.spec.backend:
            self.backend = make_backend(
                self.spec.backend,
                noise=self._noise,
                seed=self._seed,
                real_config=self._real_config,
                injector=self._injector,
            )
        self.backend.configure(
            self.spec, self._ctl_nodes, seed=self._seed + self.reallocations
        )

    def _checkpoint_manager(self):
        """The job's :class:`~repro.train.checkpoint.CheckpointManager`
        (generation files ``<dir>/<job>.genNNNNNN.ckpt.npz``); None when the
        runtime has no checkpoint directory."""
        if self._ckpt_dir is None:
            return None
        if self._ckpt_manager is None:
            from repro.train.checkpoint import CheckpointManager

            self._ckpt_manager = CheckpointManager(self._ckpt_dir, self.name)
        return self._ckpt_manager

    def _policy_state(self) -> dict:
        """The controller's batch-policy checkpoint payload ({} when there
        is no controller or the policy is stateless — e.g. the fixed policy
        of legacy sim jobs, whose snapshots must stay byte-identical)."""
        if self.controller is None:
            return {}
        return dict(self.controller.policy.state())

    def _snapshot_template(self) -> dict:
        """The restore template: the backend's snapshot shape, plus the
        batch-policy subtree exactly when the live policy would write one —
        so template and written-checkpoint structure always agree."""
        template = dict(self.backend.snapshot())
        pol = self._policy_state()
        if pol:
            template["batch_policy"] = pol
        return template

    def _load_state(self, state: dict) -> None:
        """Split a restored snapshot between its owners: the batch-policy
        subtree goes to the controller's policy, everything else to the
        execution backend."""
        state = dict(state)
        pol = state.pop("batch_policy", None)
        if pol is not None and self.controller is not None:
            self.controller.policy.load_state(pol)
        self.backend.load_snapshot(state)

    def _restore_backend(self) -> None:
        """Restore the preemption checkpoint into the backend (and the
        batch policy, whose adaptation state rides the same snapshot): from
        the newest *valid* checkpoint generation when any were written (the
        file is the source of truth — in a real cluster the preempted
        process died; a corrupt head generation rolls back to the newest
        one whose sha256 verifies, counted in ``ckpt_rollbacks``), else
        from the in-memory snapshot."""
        if self.backend is None:
            return
        manager = self._checkpoint_manager()
        if (
            manager is not None
            and self.checkpoint_path is not None
            and manager.generations()
        ):
            from repro.train.checkpoint import CheckpointCorruptError

            before = manager.rollbacks
            try:
                state, _gen, path = manager.restore(self._snapshot_template())
            except CheckpointCorruptError:
                # Every generation corrupt: fall back to the in-memory
                # snapshot (the in-process resume path) if there is one.
                self.ckpt_rollbacks += manager.rollbacks - before
                if self._snapshot is not None:
                    self._load_state(self._snapshot)
                    self.ckpt_fallbacks += 1
                    self.restores += 1
                return
            self.ckpt_rollbacks += manager.rollbacks - before
            self.checkpoint_path = path
            self._load_state(state)
            self.restores += 1
        elif self.checkpoint_path is not None and os.path.exists(self.checkpoint_path):
            from repro.train import checkpoint as ckpt

            self._load_state(
                ckpt.restore(self.checkpoint_path, self._snapshot_template())
            )
            self.restores += 1
        elif self._snapshot is not None:
            self._load_state(self._snapshot)
            self.restores += 1

    def apply_refit(self, spec: JobSpec) -> None:
        """Swap in a refreshed spec (ModelRefit): the ground truth drifts;
        the controller keeps its fitters and re-learns from the next
        epoch's measurements — the per-epoch OLS loop of §4.5."""
        if spec.name != self.name:
            raise ValueError(f"refit spec {spec.name!r} does not match {self.name!r}")
        self.spec = spec
        if self.nodes:
            self._bind_backend()

    def preempt(self) -> None:
        # Snapshot only on the RUNNING->PREEMPTED edge: a duplicate
        # Preemption event must not re-serialize post-preemption live state
        # over the only good checkpoint (the file models a process that
        # already died).  The preemptions counter still counts every event,
        # matching the reconcile loop's idempotent-event semantics.
        if self.backend is not None and self.state != JobState.PREEMPTED:
            snap = dict(self.backend.snapshot())
            pol = self._policy_state()
            if pol:
                # Batch-policy adaptation state (damper counters, loss
                # anchors, tracked b_noise) rides the same checkpoint as the
                # backend's statistical state; stateless policies add
                # nothing, keeping legacy snapshots byte-identical.
                snap["batch_policy"] = pol
            if snap:
                self._snapshot = snap
                manager = self._checkpoint_manager()
                if manager is not None:
                    io = self._injector.checkpoint_io if self._injector else None
                    # Flaky checkpoint I/O gets bounded retries (a failed
                    # attempt leaves no file, so the generation counter
                    # does not advance); if all attempts fail, resume falls
                    # back to the in-memory snapshot (checkpoint_path stays
                    # unset so restore never reads a file this preemption
                    # failed to write).
                    for _attempt in range(3):
                        try:
                            path = manager.save(snap, io=io)
                            self.checkpoint_path = path
                            if self._injector is not None:
                                # Disk-corruption fault seam: flips bytes in
                                # the just-written generation *after* the
                                # atomic rename — exactly the failure the
                                # checksummed rollback must absorb.
                                self._injector.corrupt_checkpoint(path)
                            break
                        except OSError:
                            self.ckpt_write_failures += 1
                    else:
                        self.checkpoint_path = None
                        self.ckpt_fallbacks += 1
                self._resume_pending = True
        self.state = JobState.PREEMPTED
        self.preemptions += 1
        self.nodes = ()

    def finish(self, now: float) -> None:
        self.state = JobState.DONE
        self.finished_at = now
        self.nodes = ()
        self._snapshot = None
        self._resume_pending = False

    # -- epoch loop ------------------------------------------------------

    def advance(self, epochs: int = 1, *, steps: int = 4) -> List[EpochRecord]:
        """Run ``epochs`` plan → execute → observe cycles over the job's
        backend on the held nodes.  No-op unless RUNNING."""
        if self.state != JobState.RUNNING or self.backend is None:
            return []
        assert self.controller is not None
        out: List[EpochRecord] = []
        for _ in range(epochs):
            record, result = run_backend_epoch(self.controller, self.backend, steps=steps)
            self.last_result = result
            self.sim_time += record.epoch_seconds
            self.epochs_run += 1
            self.records.append(record)
            out.append(record)
        return out


@dataclasses.dataclass(frozen=True)
class ReconcileRecord:
    """One reconcile step: the event, what the policy decided, and the
    aggregate score — the trace log's unit entry."""

    time: float
    event: Event
    allocation: Allocation

    @property
    def aggregate_goodput(self) -> float:
        return self.allocation.aggregate_goodput

    @property
    def aggregate_fraction(self) -> float:
        return self.allocation.aggregate_fraction

    @property
    def label(self) -> str:
        return describe(self.event)


class ClusterRuntime:
    """The single front door: an event-driven cluster runtime.

    >>> rt = ClusterRuntime(8, policy="cannikin")
    >>> handle = rt.submit(spec)            # JobArrival at rt.clock
    >>> rt.run()                            # reconcile queued events
    >>> rt.advance(epochs=2)                # step running jobs' epoch loops
    >>> rt.allocation.aggregate_goodput

    ``policy`` is an allocation-policy name (``cannikin`` / ``static`` /
    ``fair-share``) or a :class:`Policy` instance; ``engine`` selects the
    stacked-solver engine for the Cannikin policy.  ``noise``/``seed``
    configure the per-job measurement simulators.  ``real_backend`` is the
    :class:`~repro.runtime.backend.RealBackendConfig` recipe used for jobs
    whose spec names ``backend="real"``; ``checkpoint_dir`` enables on-disk
    preemption checkpoints (``<dir>/<job>.ckpt.npz``).
    """

    def __init__(
        self,
        n_nodes: int,
        *,
        policy: Union[str, Policy] = "cannikin",
        engine: str = "batched",
        noise: float = 0.0,
        seed: int = 0,
        real_backend: Optional[RealBackendConfig] = None,
        checkpoint_dir: Optional[str] = None,
        faults: Optional[FaultPlan] = None,
        health: Union[None, bool, HealthConfig, HealthMonitor] = None,
        watchdog: Union[None, bool, "Watchdog"] = None,
        invariants: bool = False,
    ) -> None:
        self.n_nodes = n_nodes
        self.policy: Policy = (
            make_policy(policy, n_nodes, engine=engine)
            if isinstance(policy, str)
            else policy
        )
        self.handles: Dict[str, JobHandle] = {}
        self.clock = 0.0
        self.allocation = Allocation({}, {}, {})
        self.records: List[ReconcileRecord] = []
        self.down_nodes: set = set()
        self._noise = noise
        self._seed = seed
        self._real_backend = real_backend
        self._checkpoint_dir = checkpoint_dir
        self._queue: List[Tuple[float, int, Event]] = []
        self._seq = itertools.count()
        # -- fault tolerance (PR 6): injection + detection + recovery ------
        self.injector: Optional[FaultInjector] = (
            FaultInjector(faults) if faults is not None else None
        )
        if health is None:
            health = faults is not None  # faults imply the monitor
        if isinstance(health, HealthMonitor):
            self.health: Optional[HealthMonitor] = health
        elif isinstance(health, HealthConfig):
            self.health = HealthMonitor(health)
        elif health:
            self.health = HealthMonitor()
        else:
            self.health = None
        self.epoch_index = 0           # global epoch counter (fault clock)
        self.sim_clock = 0.0           # simulated wall-clock across epochs
        self.noop_events = 0           # idempotent NodeLeave/NodeJoin no-ops
        self.recovery_log: List[Dict[str, object]] = []
        self._epoch_sim: List[float] = []  # per-epoch sim seconds (MTTR accounting)
        # -- integrity hardening (PR 7): watchdog + invariant checker ------
        self.watchdog: Optional[Watchdog] = self._build_watchdog(watchdog)
        if self.watchdog is not None and hasattr(self.policy, "watchdog"):
            self.policy.watchdog = self.watchdog
        self.invariant_checker: Optional[RuntimeInvariantChecker] = (
            RuntimeInvariantChecker(self) if invariants else None
        )

    def _build_watchdog(self, watchdog) -> "Optional[Watchdog]":
        """Resolve the watchdog argument.  ``True`` (or None while the fault
        plan schedules solver stalls) builds one wired to the injector's
        stall seam, with a solve deadline of half the shortest injected
        stall — tight enough that every stall trips the deadline, loose
        enough that real solves never do."""
        if isinstance(watchdog, Watchdog):
            if watchdog.stall_hook is None and self.injector is not None:
                watchdog.stall_hook = self.injector.solver_stall
            return watchdog
        stalls = (
            self.injector.plan.solver_stalls if self.injector is not None else ()
        )
        if watchdog is None:
            watchdog = bool(stalls)
        if not watchdog:
            return None
        deadline = min((s.delay for s in stalls), default=None)
        return Watchdog(
            solve_deadline=deadline / 2.0 if deadline else None,
            stall_hook=self.injector.solver_stall if self.injector else None,
        )

    @property
    def invariant_violations(self) -> List[object]:
        return self.invariant_checker.violations if self.invariant_checker else []

    def _check_invariants(self, event: Event) -> None:
        if self.invariant_checker is not None:
            self.invariant_checker.check(describe(event))

    # -- event intake ----------------------------------------------------

    def post(self, event: Event) -> None:
        """Enqueue an event; reconciled by :meth:`step`/:meth:`run` in
        ``(time, post-order)`` order."""
        heapq.heappush(self._queue, (event.time, next(self._seq), event))

    def _get_or_create_handle(self, spec: JobSpec, submitted_at: float) -> JobHandle:
        handle = self.handles.get(spec.name)
        if handle is None:
            handle = JobHandle(
                spec,
                submitted_at=submitted_at,
                noise=self._noise,
                seed=self._seed + len(self.handles),
                real_config=self._real_backend,
                checkpoint_dir=self._checkpoint_dir,
                injector=self.injector,
            )
            self.handles[spec.name] = handle
        return handle

    def submit(self, spec: JobSpec, *, at: Optional[float] = None) -> JobHandle:
        """Create (or fetch) the job's handle and post its arrival."""
        when = self.clock if at is None else at
        handle = self._get_or_create_handle(spec, when)
        self.post(JobArrival(time=when, spec=spec))
        return handle

    def complete(self, name: str, *, at: Optional[float] = None) -> None:
        self.post(JobCompletion(time=self.clock if at is None else at, job=name))

    def preempt(self, name: str, *, at: Optional[float] = None) -> None:
        self.post(Preemption(time=self.clock if at is None else at, job=name))

    def refit(
        self, name: str, *, rel: float = 0.1, seed: int = 0, at: Optional[float] = None
    ) -> None:
        self.post(
            ModelRefit(time=self.clock if at is None else at, job=name, rel=rel, seed=seed)
        )

    def node_leave(self, nodes: Sequence[int], *, at: Optional[float] = None) -> None:
        self.post(NodeLeave(time=self.clock if at is None else at, nodes=tuple(nodes)))

    def node_join(self, nodes: Sequence[int], *, at: Optional[float] = None) -> None:
        self.post(NodeJoin(time=self.clock if at is None else at, nodes=tuple(nodes)))

    @property
    def pending_events(self) -> int:
        return len(self._queue)

    # -- reconcile loop --------------------------------------------------

    def step(self) -> Optional[ReconcileRecord]:
        """Reconcile the earliest queued event; returns its record (None if
        the queue is empty)."""
        if not self._queue:
            return None
        t, _, event = heapq.heappop(self._queue)
        self.clock = max(self.clock, t)
        self.allocation = self._apply(event)
        self._apply_allocation(self.allocation)
        record = ReconcileRecord(time=self.clock, event=event, allocation=self.allocation)
        self.records.append(record)
        self._check_invariants(event)
        return record

    def run(self) -> List[ReconcileRecord]:
        """Drain the event queue; returns the records appended."""
        start = len(self.records)
        while self._queue:
            self.step()
        return self.records[start:]

    def advance(self, epochs: int = 1, *, steps: int = 4) -> None:
        """Step every RUNNING job's epoch loop ``epochs`` times.

        With no fault injector and no health monitor this is the exact
        PR-5 loop (bit-identical replays).  With either present, epochs
        advance in lockstep across jobs — the injector's fault clock and
        the monitor's detection windows are global epoch indices — and
        each epoch ends with detection + self-healing recovery.
        """
        if self.injector is None and self.health is None:
            for handle in self.handles.values():
                handle.advance(epochs, steps=steps)
            return
        for _ in range(epochs):
            self._advance_epoch(steps=steps)

    def _advance_epoch(self, *, steps: int) -> None:
        """One global epoch: inject → execute every running job → observe
        health → reconcile recovery actions as synthesized events."""
        e = self.epoch_index
        if self.injector is not None:
            self.injector.begin_epoch(e)
        epoch_sim = 0.0
        ran: List[JobHandle] = []
        for handle in list(self.handles.values()):
            if self.watchdog is not None:
                # Soft deadline: a slow epoch is counted, never discarded.
                recs = self.watchdog.guard_execute(
                    lambda h=handle: h.advance(1, steps=steps)
                )
            else:
                recs = handle.advance(1, steps=steps)
            if recs:
                ran.append(handle)
                epoch_sim = max(epoch_sim, recs[-1].epoch_seconds)
        self.sim_clock += epoch_sim
        self._epoch_sim.append(epoch_sim)
        if self.health is not None:
            for handle in ran:
                self._observe_health(handle, e)
            self.health.tick(e)
            self._apply_health_actions()
        self.epoch_index += 1

    def _observe_health(self, handle: JobHandle, epoch: int) -> None:
        """Feed one job's epoch telemetry to the monitor: per held node,
        observed mean compute time (a-part + backprop over the epoch's
        steps; ``None`` for a node that reported nothing) against the
        :class:`~repro.core.perf_model.NodePerfModel` prediction for the
        same local batch.

        The reference is the job spec's own coefficients — the exact model
        the scheduler scores goodput with — not the controller's learned
        fit: a freshly-reallocated controller's fit is biased while new
        nodes bootstrap, and detection against it flaps.  The premise of
        the whole system is that these coefficients faithfully describe
        healthy hardware (Eqs. 2–6); a fault is precisely a sustained
        deviation from them, and a ModelRefit updates the reference."""
        assert self.health is not None
        result = handle.last_result
        if result is None or not result.measurements:
            return
        node_ids = handle._ctl_nodes
        observed: List[Optional[float]] = []
        predicted: List[float] = []
        for i, nid in enumerate(node_ids):
            obs = [
                m.observations[i]
                for m in result.measurements
                if i < len(m.observations) and m.observations[i] is not None
            ]
            if not obs:
                observed.append(None)
                predicted.append(0.0)
                continue
            observed.append(
                sum(o.a_time + o.backprop_time for o in obs) / len(obs)
            )
            b = obs[0].batch_size
            nd = handle.spec.node_models[nid]
            predicted.append(max((nd.q + nd.k) * b + (nd.s + nd.m), 1e-9))
        self.health.observe_job(handle.name, epoch, node_ids, observed, predicted)
        # Numerical-health channel: per-node anomalous-gradient step counts
        # from the real backend's guard (empty for unguarded backends).  A
        # zero count is an explicit healthy signal (it resets the streak),
        # so the whole vector is fed, not just the breaches.
        anomalies = getattr(result, "grad_anomalies", ()) or ()
        if len(anomalies) == len(node_ids):
            self.health.observe_numerics(handle.name, epoch, node_ids, anomalies)

    def _reconcile_now(self, event: Event) -> ReconcileRecord:
        """Apply a synthesized (detection-driven) event immediately.  The
        shared heapq holds *future* trace events — draining it here would
        fast-forward the trace, so recovery bypasses the queue."""
        self.allocation = self._apply(event)
        self._apply_allocation(self.allocation)
        record = ReconcileRecord(
            time=self.clock, event=event, allocation=self.allocation
        )
        self.records.append(record)
        self._check_invariants(event)
        return record

    def _log_recovery(self, action: str, node: Optional[int], jobs, epoch: int) -> None:
        self.recovery_log.append(
            {
                "action": action,
                "node": node,
                "jobs": tuple(jobs),
                "epoch": epoch,
                "sim_time": self.sim_clock,
            }
        )

    def _apply_health_actions(self) -> None:
        """Self-healing: map drained monitor actions onto the existing
        event alphabet.  Crash → drain victims through the Preemption
        checkpoint path, mask the node, resubmit; quarantine/re-admission
        → the NodeLeave/NodeJoin availability masking (warm caches
        survive); sustained drift → a forced ModelRefit."""
        assert self.health is not None
        for action in self.health.poll():
            if isinstance(action, CrashDetected):
                victims = [
                    h.name
                    for h in self.handles.values()
                    if h.state == JobState.RUNNING and action.node in h.nodes
                ]
                for name in victims:
                    self._reconcile_now(Preemption(time=self.clock, job=name))
                self._reconcile_now(
                    NodeLeave(time=self.clock, nodes=(action.node,))
                )
                for name in victims:
                    self._reconcile_now(
                        JobArrival(time=self.clock, spec=self.handles[name].spec)
                    )
                self._log_recovery("crash_recover", action.node, victims, action.epoch)
            elif isinstance(action, QuarantineNode):
                self._reconcile_now(
                    NodeLeave(time=self.clock, nodes=(action.node,))
                )
                self._log_recovery("quarantine", action.node, (action.job,), action.epoch)
            elif isinstance(action, ReadmitNode):
                self._reconcile_now(
                    NodeJoin(time=self.clock, nodes=(action.node,))
                )
                self._log_recovery("readmit", action.node, (), action.epoch)
            elif isinstance(action, RefitRequested):
                handle = self.handles.get(action.job)
                if handle is not None and self._scheduled(handle):
                    self._reconcile_now(
                        ModelRefit(
                            time=self.clock, job=action.job, spec=handle.spec
                        )
                    )
                    self._log_recovery("refit", None, (action.job,), action.epoch)

    # -- event dispatch --------------------------------------------------

    def _handle(self, name: str) -> JobHandle:
        try:
            return self.handles[name]
        except KeyError:
            raise KeyError(f"unknown job {name!r}") from None

    @staticmethod
    def _scheduled(handle: JobHandle) -> bool:
        """Whether the handle's job is currently known to the allocation
        policy (arrival adds it; preemption/completion remove it)."""
        return handle.state in (JobState.PENDING, JobState.RUNNING)

    def _apply(self, event: Event) -> Allocation:
        if isinstance(event, JobArrival):
            spec = event.spec
            handle = self._get_or_create_handle(spec, self.clock)
            if handle.state == JobState.DONE:
                raise ValueError(f"job {spec.name!r} already completed")
            if handle.state == JobState.PREEMPTED:
                handle.state = JobState.PENDING  # resume
            handle.spec = spec
            return self.policy.add_job(spec)
        if isinstance(event, JobCompletion):
            handle = self._handle(event.job)
            # A preempted job holds no nodes and is unknown to the policy:
            # completing (cancelling) it only closes the handle.
            alloc = (
                self.policy.remove_job(event.job)
                if self._scheduled(handle)
                else self.allocation
            )
            handle.finish(self.clock)
            return alloc
        if isinstance(event, Preemption):
            handle = self._handle(event.job)
            alloc = (
                self.policy.remove_job(event.job)
                if self._scheduled(handle)
                else self.allocation  # idempotent: already off the cluster
            )
            handle.preempt()
            return alloc
        if isinstance(event, NodeLeave):
            # Idempotency guard: a duplicate leave for an already-down node
            # or a leave naming an unknown node must be a counted no-op —
            # the policy's availability mask only ever sees fresh, known
            # ids, so it can never be corrupted by event replay.
            fresh = tuple(
                int(n)
                for n in event.nodes
                if 0 <= int(n) < self.n_nodes and int(n) not in self.down_nodes
            )
            if len(fresh) < len(event.nodes):
                self.noop_events += 1
            if not fresh:
                return self.allocation
            self.down_nodes |= set(fresh)
            return self.policy.node_leave(fresh)
        if isinstance(event, NodeJoin):
            fresh = tuple(
                int(n)
                for n in event.nodes
                if 0 <= int(n) < self.n_nodes and int(n) in self.down_nodes
            )
            if len(fresh) < len(event.nodes):
                self.noop_events += 1
            if not fresh:
                return self.allocation
            self.down_nodes -= set(fresh)
            return self.policy.node_join(fresh)
        if isinstance(event, ModelRefit):
            handle = self._handle(event.job)
            new_spec = event.spec or drift_spec(handle.spec, event.rel, event.seed)
            # Policy first: if it rejects (e.g. unknown job), the handle
            # must not be left half-mutated.  A preempted job refits its
            # handle only — the refreshed spec takes effect on resume.
            alloc = (
                self.policy.update_job(new_spec)
                if self._scheduled(handle)
                else self.allocation
            )
            handle.apply_refit(new_spec)
            return alloc
        raise TypeError(f"unknown event type {type(event).__name__}")

    def _apply_allocation(self, alloc: Allocation) -> None:
        for name, handle in self.handles.items():
            if handle.state in (JobState.PENDING, JobState.RUNNING):
                handle.set_nodes(alloc.assignment.get(name, ()), now=self.clock)

    # -- observability ---------------------------------------------------

    def jobs(self, *states: str) -> List[JobHandle]:
        """Handles, optionally filtered by state(s)."""
        if not states:
            return list(self.handles.values())
        return [h for h in self.handles.values() if h.state in states]

    def counters(self) -> Dict[str, int]:
        """The allocation policy's solve/reuse counters ({} for policies
        without them)."""
        fn = getattr(self.policy, "counters", None)
        return fn() if callable(fn) else {}

    def fault_telemetry(self) -> Optional[Dict[str, object]]:
        """Fault-tolerance telemetry for the trace report: what was
        injected, what detection caught (and how fast), and what recovery
        did about it.  ``None`` when the runtime has neither an injector
        nor a monitor (so golden-path summaries are unchanged)."""
        if self.injector is None and self.health is None:
            return None
        detections = self.health.detections if self.health is not None else []
        crash_lat: List[int] = []
        quar_lat: List[int] = []
        mttr_ep: List[int] = []
        mttr_sim: List[float] = []
        if self.injector is not None:
            for c in self.injector.plan.crashes:
                det = next(
                    (
                        d
                        for d in detections
                        if d["kind"] == "crash" and d["node"] == c.node
                    ),
                    None,
                )
                if det is None:
                    continue
                crash_lat.append(int(det["epoch"]) - c.at_epoch)
                rec = next(
                    (
                        r
                        for r in self.recovery_log
                        if r["action"] == "crash_recover" and r["node"] == c.node
                    ),
                    None,
                )
                if rec is not None:
                    e0, e1 = c.at_epoch, int(rec["epoch"])
                    mttr_ep.append(e1 - e0)
                    mttr_sim.append(sum(self._epoch_sim[e0 : e1 + 1]))
            for s in self.injector.plan.stragglers:
                det = next(
                    (
                        d
                        for d in detections
                        if d["kind"] == "quarantine"
                        and d["node"] == s.node
                        and int(d["epoch"]) >= s.at_epoch
                    ),
                    None,
                )
                if det is not None:
                    quar_lat.append(int(det["epoch"]) - s.at_epoch)
            for p in self.injector.plan.poisons:
                det = next(
                    (
                        d
                        for d in detections
                        if d["kind"] == "numeric"
                        and d["node"] == p.node
                        and int(d["epoch"]) >= p.at_epoch
                    ),
                    None,
                )
                if det is not None:
                    quar_lat.append(int(det["epoch"]) - p.at_epoch)
        det_lat = crash_lat + quar_lat

        def _mean(xs):
            return (sum(xs) / len(xs)) if xs else None

        out: Dict[str, object] = {
            "injected": dict(self.injector.counts()) if self.injector else {},
            "detected": {
                kind: sum(1 for d in detections if d["kind"] == kind)
                for kind in ("crash", "quarantine", "drift", "numeric")
            },
            "recoveries": {
                act: sum(1 for r in self.recovery_log if r["action"] == act)
                for act in ("crash_recover", "quarantine", "readmit", "refit")
            },
            "noop_events": self.noop_events,
            "checkpoint_write_failures": sum(
                h.ckpt_write_failures for h in self.handles.values()
            ),
            "checkpoint_fallbacks": sum(
                h.ckpt_fallbacks for h in self.handles.values()
            ),
            "checkpoint_rollbacks": sum(
                h.ckpt_rollbacks for h in self.handles.values()
            ),
            "restores": sum(h.restores for h in self.handles.values()),
            "detection_latency_epochs": _mean(det_lat),
            "mttr_epochs": _mean(mttr_ep),
            "mttr_sim_seconds": _mean(mttr_sim),
            "epochs": self.epoch_index,
            "sim_time": self.sim_clock,
        }
        if self.watchdog is not None:
            out["watchdog"] = self.watchdog.counters()
        if self.invariant_checker is not None:
            out["invariants"] = {
                "checks": self.invariant_checker.checks_run,
                "violations": len(self.invariant_checker.violations),
            }
        return out

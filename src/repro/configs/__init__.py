"""Assigned-architecture configs.

``ARCHS`` maps arch id -> config module; `get_api(arch_id, reduced=False)`
returns a ready `ModelApi`.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs import (
    chameleon_34b,
    deepseek_v2_236b,
    hymba_1_5b,
    internlm2_20b,
    llama3_8b,
    minitron_4b,
    mixtral_8x7b,
    olmo_1b,
    rwkv6_7b,
    whisper_large_v3,
)
from repro.configs.shapes import REDUCED_SHAPES, SHAPES, InputShape
from repro.models.registry import ModelApi, build_api

_MODULES = [
    minitron_4b,
    deepseek_v2_236b,
    whisper_large_v3,
    hymba_1_5b,
    olmo_1b,
    chameleon_34b,
    rwkv6_7b,
    internlm2_20b,
    llama3_8b,
    mixtral_8x7b,
]

ARCHS: Dict[str, object] = {m.ARCH_ID: m for m in _MODULES}


def arch_ids() -> List[str]:
    return list(ARCHS.keys())


def get_api(arch_id: str, *, reduced: bool = False) -> ModelApi:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}")
    mod = ARCHS[arch_id]
    cfg = mod.reduced() if reduced else mod.config()
    return build_api(arch_id, cfg)


__all__ = ["ARCHS", "arch_ids", "get_api", "SHAPES", "REDUCED_SHAPES", "InputShape"]

"""Seeded deterministic request / load-generator layer.

Serving traffic is described the same way :func:`repro.runtime.trace.
synthetic_trace` describes training churn: every random draw comes from ONE
``np.random.default_rng(seed)`` stream, so a workload is a pure function of
its parameters — two runs with the same seed see byte-identical requests,
which is what makes the serving bit-identity gates assertable.

Laws:

* **Arrivals** — ``"poisson"`` (exponential inter-arrival times, rate
  ``rate`` req/s) or ``"bursty"`` (a Markov-modulated Poisson process:
  alternating ON/OFF phases with exponential durations; the ON phase runs at
  ``burst_factor`` x the base rate, the OFF phase at ``rate / burst_factor``
  — the flash-crowd shape real inference traffic shows).
* **Lengths** — prompt and generation lengths are log-normal (the
  heavy-tailed law of production prompt logs), clipped into
  ``[min, max]`` bounds so caches stay allocatable.
* **Deadlines** — per-request completion deadline
  ``arrival + ttft_slack + gen_len * token_budget``: a fixed
  time-to-first-token allowance plus a per-generated-token latency budget
  (the SLO the goodput accounting scores misses against).

Prompt *token values* are not drawn here: they are derived lazily per
request id (:meth:`Request.prompt_tokens`) or streamed from the training
data pipeline (:func:`prompts_from_stream`), so generating a million-request
workload costs O(n) scalars, not O(n * prompt_len) tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

__all__ = ["Request", "Workload", "generate_requests", "prompts_from_stream"]

ARRIVALS = ("poisson", "bursty")


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request: fixed at generation time, immutable after."""

    rid: int
    arrival: float          # seconds (simulated or wall-relative)
    prompt_len: int
    gen_len: int
    deadline: float         # absolute completion deadline
    seed: int = 0           # workload seed; with rid keys the token stream

    def prompt_tokens(self, vocab: int) -> np.ndarray:
        """Deterministic prompt tokens, keyed by (workload seed, rid)."""
        rng = np.random.default_rng((self.seed, self.rid))
        return rng.integers(0, vocab, size=self.prompt_len, dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class Workload:
    """A generated request set plus the law parameters that produced it."""

    requests: tuple
    seed: int
    arrival: str
    rate: float

    def __iter__(self):
        return iter(self.requests)

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def duration(self) -> float:
        """Arrival span (seconds) — offered load = len / duration."""
        if not self.requests:
            return 0.0
        return float(self.requests[-1].arrival)

    @property
    def offered_load(self) -> float:
        span = self.duration
        return len(self.requests) / span if span > 0 else float("inf")


def _clipped_lognormal(
    rng: np.random.Generator, mean: float, sigma: float, lo: int, hi: int
) -> int:
    """One clipped log-normal length draw around ``mean`` (the median)."""
    return int(np.clip(round(mean * float(rng.lognormal(0.0, sigma))), lo, hi))


def generate_requests(
    n_requests: int,
    *,
    seed: int = 0,
    rate: float = 10.0,
    arrival: str = "poisson",
    burst_factor: float = 4.0,
    burst_len: float = 2.0,
    idle_len: float = 4.0,
    prompt_mean: int = 24,
    prompt_sigma: float = 0.6,
    prompt_min: int = 4,
    prompt_max: int = 256,
    gen_mean: int = 12,
    gen_sigma: float = 0.6,
    gen_min: int = 1,
    gen_max: int = 128,
    ttft_slack: float = 2.0,
    token_budget: float = 0.5,
) -> Workload:
    """The canonical seeded serving workload (see module docstring).

    All draws come from one RNG in a fixed order (per request: inter-arrival
    gap, prompt length, generation length), so a workload is reproducible
    from ``(n_requests, seed, law parameters)`` alone.
    """
    if arrival not in ARRIVALS:
        raise ValueError(f"unknown arrival law {arrival!r}; one of {ARRIVALS}")
    if rate <= 0:
        raise ValueError("rate must be positive")
    if n_requests < 0:
        raise ValueError("n_requests must be >= 0")
    rng = np.random.default_rng(seed)
    requests: List[Request] = []
    t = 0.0
    # Bursty state: phase end time + current phase rate.  Phase lengths are
    # exponential so the process is Markov-modulated; the first phase is ON
    # (bursts lead, the worst case for admission).
    on = True
    phase_end = float(rng.exponential(burst_len)) if arrival == "bursty" else np.inf
    for rid in range(n_requests):
        if arrival == "poisson":
            gap = float(rng.exponential(1.0 / rate))
        else:
            cur_rate = rate * burst_factor if on else rate / burst_factor
            gap = float(rng.exponential(1.0 / cur_rate))
            # Phase switches consume the gap deterministically: cross as many
            # boundaries as the gap spans, re-drawing the residual at the new
            # phase's rate.
            while t + gap >= phase_end:
                carry = phase_end - t
                t = phase_end
                on = not on
                phase_end = t + float(
                    rng.exponential(burst_len if on else idle_len)
                )
                cur_rate = rate * burst_factor if on else rate / burst_factor
                gap = float(rng.exponential(1.0 / cur_rate))
                del carry  # boundary reached; residual re-drawn memorylessly
        t += gap
        p = _clipped_lognormal(rng, prompt_mean, prompt_sigma, prompt_min, prompt_max)
        g = _clipped_lognormal(rng, gen_mean, gen_sigma, gen_min, gen_max)
        requests.append(
            Request(
                rid=rid,
                arrival=t,
                prompt_len=p,
                gen_len=g,
                deadline=t + ttft_slack + g * token_budget,
                seed=seed,
            )
        )
    return Workload(
        requests=tuple(requests), seed=seed, arrival=arrival, rate=rate
    )


def prompts_from_stream(
    stream, requests, *, key: str = "tokens"
) -> Dict[int, np.ndarray]:
    """Draw prompt tokens for ``requests`` from a data-pipeline stream.

    ``stream`` is any iterator of batch dicts (e.g. the bounded-buffer
    :class:`repro.data.pipeline.BoundedStream` over a ``SyntheticLM``) —
    the serving request layer reuses the training pipeline's token source
    instead of inventing its own.  Rows are consumed in request order and
    truncated/tiled to each request's ``prompt_len``; returns
    ``{rid: (prompt_len,) int32 tokens}``.
    """
    out: Dict[int, np.ndarray] = {}
    it = iter(stream)
    batch: Optional[np.ndarray] = None
    row = 0
    for req in requests:
        if batch is None or row >= batch.shape[0]:
            batch = np.asarray(next(it)[key])
            row = 0
        toks = batch[row]
        row += 1
        if toks.shape[0] >= req.prompt_len:
            out[req.rid] = toks[: req.prompt_len].astype(np.int32)
        else:
            reps = -(-req.prompt_len // toks.shape[0])
            out[req.rid] = np.tile(toks, reps)[: req.prompt_len].astype(np.int32)
    return out

"""Checkpointing: pytree <-> .npz with keypath-string keys.

No orbax in this environment; .npz keeps things dependency-free and is
adequate for host-side checkpoints.  Arrays are gathered to host (works for
sharded arrays via np.asarray on addressable data in single-process runs).
bfloat16 has no numpy dtype — such leaves round-trip via a float32 view with
a dtype tag.
"""
from __future__ import annotations

import io
import json
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = ["save", "restore", "LocalIO"]

_DTYPE_TAG = "__dtypes__"


class LocalIO:
    """Default checkpoint I/O: the local filesystem.

    ``save`` goes through this seam so fault injection (see
    :class:`repro.runtime.faults.FlakyCheckpointIO`) can make writes fail
    without monkeypatching builtins.  Any object with ``open(path, mode)``
    and ``replace(src, dst)`` works.
    """

    def open(self, path: str, mode: str):
        return open(path, mode)

    def replace(self, src: str, dst: str) -> None:
        os.replace(src, dst)


def _key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(path: str, tree: PyTree, *, io: Any = None) -> None:
    """Atomically write ``tree`` to ``path``.

    The payload lands in ``<path>.tmp`` first and is renamed over ``path``
    only once fully written, so a crash (or injected failure) mid-write can
    never leave a truncated archive where a valid previous checkpoint was.
    """
    if io is None:
        io = LocalIO()
    flat = {}
    dtypes = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        k = _key(kp)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            dtypes[k] = "bfloat16"
            arr = arr.astype(np.float32)
        flat[k] = arr
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = f"{path}.tmp"
    try:
        with io.open(tmp, "wb") as f:
            np.savez(f, **flat, **{_DTYPE_TAG: np.frombuffer(
                json.dumps(dtypes).encode(), dtype=np.uint8
            )})
        io.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes validated)."""
    with np.load(path) as data:
        dtypes: Dict[str, str] = {}
        if _DTYPE_TAG in data:
            dtypes = json.loads(bytes(data[_DTYPE_TAG]).decode())
        leaves = []
        for kp, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
            k = _key(kp)
            if k not in data:
                raise KeyError(f"checkpoint missing leaf {k!r}")
            arr = data[k]
            want_shape = tuple(np.shape(leaf))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"shape mismatch for {k}: checkpoint {arr.shape} vs model {want_shape}"
                )
            if dtypes.get(k) == "bfloat16":
                leaves.append(jnp.asarray(arr, jnp.bfloat16))
                continue
            # Leaves that were not JAX arrays when saved (plain NumPy
            # scalars/arrays — e.g. the GNS EMAs and stream counters of a
            # backend snapshot) keep their saved dtype: jnp.asarray would
            # silently downcast float64 under the default x64-disabled
            # config and break bit-exact resume.
            leaves.append(jnp.asarray(arr) if isinstance(leaf, jax.Array) else arr)
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves)

"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000, squared-ReLU MLP (Nemotron lineage), pruned nemotron
[arXiv:2407.14679].

Note: 24 query heads are not divisible by the 16-way model axis — the
sharding rules replicate the head dim and keep TP on the (divisible) FFN and
vocab dims (DESIGN.md §5, recorded by MeshRules.fallbacks).
"""
from repro.models.dense import DenseConfig

ARCH_ID = "minitron-4b"


def config() -> DenseConfig:
    return DenseConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=9216,
        vocab=256000,
        head_dim=128,
        rope_theta=10000.0,
        act="relu2",
        norm="rmsnorm",
        decode_window=8192,
    )


def reduced() -> DenseConfig:
    return DenseConfig(
        name=ARCH_ID + "-reduced",
        n_layers=2,
        d_model=192,
        n_heads=6,
        n_kv_heads=2,
        d_ff=384,
        vocab=512,
        head_dim=32,
        act="relu2",
        decode_window=64,
        remat=False,
    )

"""Simulator semantics: §3.2 timing (max form), noise behaviour, catalogs."""
import numpy as np
import pytest

from repro.core.optperf import solve_optperf_waterfill
from repro.core.simulator import (
    GPU_CATALOG,
    SimulatedCluster,
    cluster_A,
    cluster_B,
    cluster_C,
)


def test_noise_free_matches_analytic_model():
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.0, seed=0)
    truth = sim.true_model()
    for batches in ([16, 16, 16], [40, 30, 10], [5, 80, 43]):
        m = sim.run_batch(batches)
        assert m.batch_time == pytest.approx(truth.cluster_time(batches), rel=1e-12)


def test_catalog_speed_ordering():
    # Table 1/3 ordering: a100 faster than v100 faster than rtx6000/p4000.
    b = 64
    t = {name: p.model().t_compute(b) for name, p in GPU_CATALOG.items()}
    assert t["a100"] < t["v100"] < t["rtx6000"] < t["p4000"]
    # §6: A100 ~3.4x RTX6000.
    assert 2.5 < t["rtx6000"] / t["a100"] < 4.5


def test_cluster_c_sharing_heterogeneity():
    profiles, _ = cluster_C(16)
    speeds = [p.model().t_compute(64) for p in profiles]
    assert speeds == sorted(speeds)  # monotonically slower
    assert 3.5 < speeds[-1] / speeds[0] < 4.5  # 1.0 -> 0.25 of a GPU


def test_measurement_noise_unbiased():
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.05, seed=0)
    truth = sim.true_model()
    batches = [30, 20, 14]
    times = [sim.run_batch(batches).batch_time for _ in range(300)]
    expected = truth.cluster_time(batches)
    # Multiplicative lognormal noise on a max(): small positive bias allowed.
    assert np.mean(times) == pytest.approx(expected, rel=0.1)


def test_fast_nodes_report_inflated_comm_time():
    """§4.5: only the straggler observes the true T_comm; min-aggregation
    across nodes recovers it (exactly so in the comm-bound regime)."""
    profiles, comm = cluster_B(t_o=0.8, t_u=0.05)  # strongly comm-bound
    sim = SimulatedCluster(profiles, comm, noise=0.0, seed=0)
    m = sim.run_batch([32] * sim.n)
    reported = [o.comm_time for o in m.observations]
    assert min(reported) == pytest.approx(comm.t_comm, rel=1e-9)
    assert max(reported) > comm.t_comm
    # Compute-bound regime: every report is >= the true T_comm, so the min
    # is still the least-biased estimate.
    profiles, comm = cluster_B()
    sim = SimulatedCluster(profiles, comm, noise=0.0, seed=0)
    m = sim.run_batch([64] * sim.n)
    assert min(o.comm_time for o in m.observations) >= comm.t_comm - 1e-12


def test_optimum_beats_even_split_under_simulator():
    profiles, comm = cluster_B()
    sim = SimulatedCluster(profiles, comm, noise=0.0, seed=0)
    truth = sim.true_model()
    B = 512
    sol = solve_optperf_waterfill(truth, B)
    from repro.core.optperf import round_batches

    t_opt = sim.run_batch(round_batches(list(sol.batches), B)).batch_time
    t_even = sim.run_batch([B // sim.n] * sim.n).batch_time
    assert t_opt < t_even

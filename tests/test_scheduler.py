"""Multi-job heterogeneity-aware scheduler + elastic controller tests
(paper §6 future-work items, implemented as beyond-paper extensions)."""
import numpy as np
import pytest

from repro.core.controller import CannikinController
from repro.core.perf_model import CommModel
from repro.core.scheduler import Allocation, JobSpec, allocate, random_jobs
from repro.core.simulator import GPU_CATALOG, SimulatedCluster, cluster_B


def make_job(name, node_names, total_batch, b_noise, scale=1.0, min_nodes=1):
    models = tuple(
        GPU_CATALOG[n].scaled(1.0 / scale).model() for n in node_names
    )
    return JobSpec(
        name=name,
        node_models=models,
        comm=CommModel(t_o=0.04 * scale, t_u=0.008 * scale, gamma=0.15),
        total_batch=total_batch,
        b_noise=b_noise,
        ref_batch=64,
        min_nodes=min_nodes,
    )


NODES = ["a100"] * 4 + ["v100"] * 4 + ["rtx6000"] * 8


def test_allocation_covers_cluster_and_jobs():
    jobs = [
        make_job("big", NODES, total_batch=1024, b_noise=2000.0, scale=2.0),
        make_job("small", NODES, total_batch=128, b_noise=200.0, scale=0.2),
    ]
    alloc = allocate(jobs, len(NODES))
    assigned = [n for ids in alloc.assignment.values() for n in ids]
    assert sorted(assigned) == sorted(set(assigned))  # disjoint
    assert all(len(ids) >= 1 for ids in alloc.assignment.values())
    assert all(g > 0 for g in alloc.goodputs.values())
    assert 0 < alloc.aggregate_fraction <= 2.0 + 1e-9


def test_big_job_gets_more_nodes():
    jobs = [
        make_job("big", NODES, total_batch=2048, b_noise=5000.0, scale=2.0),
        make_job("tiny", NODES, total_batch=64, b_noise=100.0, scale=0.1),
    ]
    alloc = allocate(jobs, len(NODES))
    assert len(alloc.assignment["big"]) > len(alloc.assignment["tiny"])


def test_greedy_beats_random_split():
    rng = np.random.default_rng(0)
    jobs = [
        make_job("a", NODES, total_batch=512, b_noise=1500.0, scale=1.0),
        make_job("b", NODES, total_batch=512, b_noise=1500.0, scale=1.0),
    ]
    alloc = allocate(jobs, len(NODES))
    greedy = alloc.aggregate_fraction
    # random disjoint splits
    worst_gap = 0.0
    for _ in range(10):
        perm = rng.permutation(len(NODES))
        half = len(NODES) // 2
        f = (
            jobs[0].goodput(tuple(perm[:half])) / max(jobs[0].solo_goodput(), 1e-12)
            + jobs[1].goodput(tuple(perm[half:])) / max(jobs[1].solo_goodput(), 1e-12)
        )
        assert greedy >= f - 1e-6


def test_min_nodes_respected():
    jobs = [
        make_job("needs4", NODES, total_batch=512, b_noise=1000.0, min_nodes=4),
        make_job("any", NODES, total_batch=256, b_noise=500.0),
    ]
    alloc = allocate(jobs, len(NODES))
    # min_nodes gates goodput to zero below the floor, so the greedy loop
    # keeps feeding the job until it produces goodput.
    assert len(alloc.assignment["needs4"]) >= 4 or alloc.goodputs["needs4"] == 0.0


# ---------------------------------------------------------------------------
# batched (stacked) allocation engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_batched_allocate_matches_scalar(seed):
    """The stacked per-round marginal evaluation emits the same assignment
    and the same goodputs as the per-(job, node) scalar loop (the job mix is
    the same seeded generator the benchmark gates use)."""
    jobs = random_jobs(4, 12, seed)
    a_b = allocate(jobs, 12, engine="batched")
    a_s = allocate(jobs, 12, engine="scalar")
    assert a_b.assignment == a_s.assignment
    for name in a_b.goodputs:
        assert a_b.goodputs[name] == pytest.approx(a_s.goodputs[name], rel=1e-12)
    assert a_b.aggregate_fraction == pytest.approx(a_s.aggregate_fraction, rel=1e-12)


def test_batched_allocate_min_nodes_and_identical_nodes():
    """Exact-tie rows (identical node models) and min_nodes floors break the
    same way in both engines."""
    jobs = [
        make_job("needs4", NODES, total_batch=512, b_noise=1000.0, min_nodes=4),
        make_job("any", NODES, total_batch=256, b_noise=500.0),
    ]
    a_b = allocate(jobs, len(NODES), engine="batched")
    a_s = allocate(jobs, len(NODES), engine="scalar")
    assert a_b.assignment == a_s.assignment


def test_nan_fit_job_degrades_gracefully_in_both_engines():
    """A job whose OLS fit produced a NaN coefficient must score goodput 0.0
    (validation rejects it with ValueError) — not crash the round — in the
    batched engine exactly like the scalar one."""
    from repro.core.perf_model import NodePerfModel as NPM

    bad_node_sets = {
        "nan-q": tuple(NPM(q=float("nan"), s=0.0, k=1e-3, m=0.0) for _ in range(4)),
        # k <= 0 with alpha = q + k still positive: only a k-specific check
        # catches it, exactly like the per-node NodePerfModel validation.
        "neg-k": tuple(NPM(q=1e-2, s=0.0, k=-1e-4, m=0.0) for _ in range(4)),
        # q < 0 with alpha and beta still positive: only the q-specific
        # (alpha - k >= 0) check catches it.
        "neg-q": tuple(NPM(q=-5e-3, s=0.0, k=1e-1, m=0.0) for _ in range(4)),
    }
    ok_job = make_job("ok", ["a100"] * 4, total_batch=128, b_noise=500.0)
    for label, models in bad_node_sets.items():
        bad_job = JobSpec(
            name="broken",
            node_models=models,
            comm=CommModel(t_o=0.02, t_u=0.005, gamma=0.1),
            total_batch=128,
            b_noise=500.0,
            ref_batch=64,
        )
        allocs = {
            engine: allocate([bad_job, ok_job], 4, engine=engine)
            for engine in ("batched", "scalar")
        }
        for engine, alloc in allocs.items():
            assert alloc.goodputs["broken"] == 0.0, (label, engine)
            assert alloc.goodputs["ok"] > 0.0, (label, engine)
        assert allocs["batched"].assignment == allocs["scalar"].assignment, label


def test_allocate_unknown_engine_raises():
    with pytest.raises(ValueError):
        allocate([], 4, engine="vectorised")


def test_allocate_empty_jobs():
    assert allocate([], 8).assignment == {}


# ---------------------------------------------------------------------------
# elastic controller
# ---------------------------------------------------------------------------


def _learn(ctrl, sim, epochs=3, steps=4):
    for _ in range(epochs):
        plan = ctrl.plan_epoch()
        _, ms = sim.run_epoch(list(plan.batches), steps)
        ctrl.observe_epoch(ms)
    return plan


def test_remove_nodes_keeps_models():
    profiles, comm = cluster_B()
    sim = SimulatedCluster(profiles, comm, noise=0.005, seed=0)
    ctrl = CannikinController(sim.n, batch_candidates=[256], ref_batch=256,
                             adaptive=False)
    _learn(ctrl, sim, epochs=4)
    assert ctrl.last_plan.phase == "optperf"
    # Scheduler takes the 8 rtx nodes away.
    ctrl.remove_nodes(list(range(8, 16)))
    plan = ctrl.plan_epoch()
    assert plan.phase == "optperf"  # no re-bootstrap: models retained
    assert len(plan.batches) == 8
    assert sum(plan.batches) == 256
    # Remaining nodes are the (faster) a100/v100s: predicted time must beat
    # the LB-BSP-style even split over them.
    sub = SimulatedCluster(profiles[:8], comm, noise=0.0, seed=0)
    even = sub.run_batch([32] * 8).batch_time
    opt = sub.run_batch(list(plan.batches)).batch_time
    assert opt <= even * 1.02


def test_add_nodes_triggers_bootstrap():
    profiles, comm = cluster_B()
    sim = SimulatedCluster(profiles, comm, noise=0.005, seed=0)
    ctrl = CannikinController(sim.n, batch_candidates=[256], ref_batch=256,
                             adaptive=False)
    _learn(ctrl, sim, epochs=4)
    ctrl.add_nodes(2)
    plan = ctrl.plan_epoch()
    assert plan.phase == "bootstrap"  # two re-learning epochs (paper §6)
    assert len(plan.batches) == 18
    # After the new nodes see two distinct batch sizes, optperf resumes.
    profiles2 = list(profiles) + [profiles[0], profiles[1]]
    sim2 = SimulatedCluster(profiles2, comm, noise=0.005, seed=1)
    for _ in range(3):
        _, ms = sim2.run_epoch(list(plan.batches), 4)
        ctrl.observe_epoch(ms)
        plan = ctrl.plan_epoch()
    assert plan.phase == "optperf"
    assert len(plan.batches) == 18

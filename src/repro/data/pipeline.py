"""Data pipeline: synthetic LM streams + the heterogeneous batch loader.

`SyntheticLM` produces deterministic pseudo-random token batches (seeded per
step) with a learnable structure (a hidden Markov-ish next-token rule) so
losses actually *decrease* during the example runs — pure-noise tokens would
make convergence-time comparisons meaningless.

`HeteroBatchPartitioner` is the HeteroDataLoader of the paper (§4.5): given
the controller's per-node batch sizes it emits, per node, a contiguous index
range of the global batch; for the single-pjit-step realization it emits the
padded (n, b_max) layout plus the per-sample weight vector of
core/aggregation.sample_weights, which makes one weighted-loss step
equivalent to Eq. (9).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import padded_batch_layout, sample_weights

__all__ = ["SyntheticLM", "HeteroBatchPartitioner", "NodeBatch"]


class SyntheticLM:
    """Deterministic synthetic token stream with learnable bigram structure."""

    def __init__(self, vocab: int, seq_len: int, seed: int = 0, order: int = 3):
        self.vocab = vocab
        self.seq_len = seq_len
        self.seed = seed
        rng = np.random.default_rng(seed)
        # A fixed permutation defines the "true" next token; corruption adds
        # irreducible entropy.
        self.rule = rng.permutation(vocab)
        self.noise = 0.3

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((batch_size, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch_size)
        for t in range(1, self.seq_len + 1):
            nxt = self.rule[toks[:, t - 1]]
            corrupt = rng.random(batch_size) < self.noise
            nxt = np.where(corrupt, rng.integers(0, self.vocab, batch_size), nxt)
            toks[:, t] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass(frozen=True)
class NodeBatch:
    """One node's share of a global batch."""

    node: int
    start: int              # global-batch row offset
    size: int               # b_i
    tokens: np.ndarray      # (b_i, S)
    labels: np.ndarray
    ratio: float            # r_i = b_i / B


class HeteroBatchPartitioner:
    """Splits a global batch into uneven per-node local batches.

    Two views:
      * `split(batch, sizes)` — list of NodeBatch (per-node runtime view,
        used by the simulator/examples).
      * `padded(batch, sizes)` — (stacked (n, b_max, S) arrays, per-sample
        weights (n, b_max)) — the single-pjit-step view; the weight vector
        makes a weighted-SUM loss equal to Eq. (9).
    """

    @staticmethod
    def split(batch: Dict[str, np.ndarray], sizes: Sequence[int]) -> List[NodeBatch]:
        total = int(sum(sizes))
        if total != batch["tokens"].shape[0]:
            raise ValueError(
                f"partition sizes sum {total} != global batch {batch['tokens'].shape[0]}"
            )
        out, ofs = [], 0
        for i, b in enumerate(sizes):
            out.append(
                NodeBatch(
                    node=i,
                    start=ofs,
                    size=int(b),
                    tokens=batch["tokens"][ofs : ofs + b],
                    labels=batch["labels"][ofs : ofs + b],
                    ratio=b / total,
                )
            )
            ofs += b
        return out

    @staticmethod
    def padded(
        batch: Dict[str, np.ndarray], sizes: Sequence[int]
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        total = int(sum(sizes))
        if total != batch["tokens"].shape[0]:
            raise ValueError("partition sizes do not sum to the global batch")
        b_max, mask = padded_batch_layout(sizes)
        n = len(sizes)
        seq = batch["tokens"].shape[1]
        tok = np.zeros((n, b_max, seq), np.int32)
        lab = np.zeros((n, b_max, seq), np.int32)
        ofs = 0
        for i, b in enumerate(sizes):
            tok[i, :b] = batch["tokens"][ofs : ofs + b]
            lab[i, :b] = batch["labels"][ofs : ofs + b]
            ofs += b
        weights = sample_weights(sizes)  # (n, b_max), rows sum to b_i/B
        return {"tokens": tok, "labels": lab}, weights

"""Solver micro-benchmark (supports Table 5 overhead claims): Algorithm 1 vs
the water-fill oracle across cluster sizes, plus the warm-start benefit."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, save_json, time_call
from repro.core.optperf import (
    solve_optperf_algorithm1,
    solve_optperf_batch,
    solve_optperf_waterfill,
)
from repro.core.perf_model import ClusterPerfModel, CommModel, NodePerfModel


def _random_model(n: int, seed: int = 0) -> ClusterPerfModel:
    rng = np.random.default_rng(seed)
    nodes = tuple(
        NodePerfModel(
            q=float(rng.uniform(1e-4, 5e-3)),
            s=float(rng.uniform(0, 0.02)),
            k=float(rng.uniform(1e-4, 8e-3)),
            m=float(rng.uniform(0, 0.02)),
        )
        for _ in range(n)
    )
    comm = CommModel(t_o=0.04, t_u=0.008, gamma=0.15)
    return ClusterPerfModel(nodes=nodes, comm=comm)


def run() -> List[Row]:
    rows: List[Row] = []
    payload = {}
    for n in (3, 16, 64, 256):
        model = _random_model(n)
        t1 = time_call(lambda: solve_optperf_algorithm1(model, 1024), repeats=9)
        t2 = time_call(lambda: solve_optperf_waterfill(model, 1024), repeats=9)
        s1 = solve_optperf_algorithm1(model, 1024)
        s2 = solve_optperf_waterfill(model, 1024)
        agree = abs(s1.opt_perf - s2.opt_perf) / s2.opt_perf
        # 32-candidate sweep: per-candidate scalar loop vs one batched pass.
        cands = np.geomspace(64, 65536, 32)
        t_loop = time_call(
            lambda: [solve_optperf_waterfill(model, float(b)) for b in cands], repeats=3
        )
        t_batch = time_call(lambda: solve_optperf_batch(model, cands), repeats=3)
        rows.append(Row(f"optperf/algorithm1/n{n}", t1, f"agree={agree:.2e}"))
        rows.append(Row(f"optperf/waterfill/n{n}", t2, ""))
        rows.append(
            Row(f"optperf/batch_sweep32/n{n}", t_batch, f"speedup={t_loop / t_batch:.1f}x")
        )
        payload[n] = {
            "alg1_us": t1,
            "waterfill_us": t2,
            "sweep32_loop_us": t_loop,
            "sweep32_batched_us": t_batch,
            "rel_gap": agree,
        }
    save_json("solver", payload)
    return rows

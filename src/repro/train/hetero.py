"""HeteroTrainer: end-to-end Cannikin training over a (simulated) hetero cluster.

Runs *real* JAX training of a model on this host while a `SimulatedCluster`
supplies the wall-clock the heterogeneous cluster would have taken — the
separation the paper itself makes between statistical behaviour (identical to
homogeneous training thanks to Eq. 9) and system behaviour (per-node timing).

Per step:
  1. partition the global batch by the active policy's local batch sizes,
  2. compute each node's local gradient g_i and |g_i|^2 (one vmapped
     backward over the padded (n, b_max) layout),
  3. aggregate g = sum r_i g_i (Eq. 9) and |g|^2, update params once,
  4. feed (|g_i|^2, |g|^2, b) to the GNS tracker (Theorem 4.1 weights),
  5. advance the simulated clock by the cluster's batch time.

After each epoch the controller refits performance models and plans the next
epoch (OptPerf partition + adaptive total batch).  Baseline policies
(even/LB-BSP) plug into the same loop.

Recompilation hygiene: the padded per-node width b_max is quantized to a
multiple of 8 so epoch-to-epoch repartitioning reuses compiled steps
(beyond-paper; noted in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import ratios
from repro.core.controller import CannikinController
from repro.core.simulator import SimulatedCluster
from repro.data.pipeline import HeteroBatchPartitioner, SyntheticLM
from repro.models.registry import ModelApi
from repro.optim.optimizers import Optimizer, global_norm

__all__ = ["HeteroTrainer", "EpochResult"]


@dataclasses.dataclass
class EpochResult:
    epoch: int
    total_batch: int
    batches: Tuple[int, ...]
    sim_seconds: float          # simulated cluster wall-clock for the epoch
    mean_loss: float
    predicted_batch_time: Optional[float]
    measured_batch_time: float
    b_noise: float
    lr_scale: float
    phase: str


def _quantize(b: int, q: int = 8) -> int:
    return max(q, ((b + q - 1) // q) * q)


class HeteroTrainer:
    def __init__(
        self,
        api: ModelApi,
        optimizer: Optimizer,
        cluster: SimulatedCluster,
        policy: Any,                       # CannikinController or baseline
        data: SyntheticLM,
        *,
        steps_per_epoch: int = 8,
        seed: int = 0,
    ) -> None:
        self.api = api
        self.optimizer = optimizer
        self.cluster = cluster
        self.policy = policy
        self.data = data
        self.steps_per_epoch = steps_per_epoch
        rng = jax.random.PRNGKey(seed)
        self.params = api.init(rng)
        self.opt_state = optimizer.init(self.params)
        self.sim_time = 0.0
        self.history: List[EpochResult] = []
        self._step_cache: Dict[int, Callable] = {}
        self._epoch = 0
        self._last_measurement = None

    # ------------------------------------------------------------------

    def _node_grad_fn(self, b_max: int) -> Callable:
        """Jitted: per-node grads + sq-norms + Eq.(9) aggregate + update."""
        if b_max in self._step_cache:
            return self._step_cache[b_max]
        api, optimizer = self.api, self.optimizer

        def node_loss(params, tokens, labels, mask):
            # mean over the node's real samples (pads weighted 0).
            loss, _ = api.loss(
                params,
                {"tokens": tokens, "labels": labels, "weights": mask},
            )
            return loss

        grad_fn = jax.grad(node_loss)

        def step(params, opt_state, tokens, labels, mask, r, lr_scale):
            # tokens/labels: (n, b_max, S); mask: (n, b_max); r: (n,)
            grads = jax.vmap(grad_fn, in_axes=(None, 0, 0, 0))(
                params, tokens, labels, mask
            )
            sq_i = jax.vmap(lambda g: global_norm(g) ** 2)(grads)
            agg = jax.tree_util.tree_map(
                lambda g: jnp.tensordot(r.astype(jnp.float32), g.astype(jnp.float32), axes=1).astype(g.dtype),
                grads,
            )
            sq_g = global_norm(agg) ** 2
            loss, _ = api.loss(
                params,
                {
                    "tokens": tokens.reshape((-1,) + tokens.shape[2:]),
                    "labels": labels.reshape((-1,) + labels.shape[2:]),
                    "weights": mask.reshape(-1),
                },
            )
            new_params, new_opt = optimizer.update(agg, opt_state, params, lr_scale)
            return new_params, new_opt, loss, sq_i, sq_g

        fn = jax.jit(step)
        self._step_cache[b_max] = fn
        return fn

    # ------------------------------------------------------------------

    def run_epoch(self) -> EpochResult:
        epoch = self._epoch
        self._epoch += 1

        # 1. plan
        if isinstance(self.policy, CannikinController):
            plan = self.policy.plan_epoch()
            batches = list(plan.batches)
            total = plan.total_batch
            lr_scale = plan.lr_scale
            predicted = plan.predicted_batch_time
            phase = plan.phase
        else:
            total = self.policy_total_batch()
            batches = self.policy.partition(total, epoch, self._last_measurement)
            lr_scale, predicted, phase = 1.0, None, self.policy.name

        # 2. run steps
        b_arr = np.asarray(batches, np.int64)
        b_max = _quantize(int(b_arr.max()))
        n = len(batches)
        r = jnp.asarray(ratios(batches), jnp.float32)
        step_fn = self._node_grad_fn(b_max)

        losses = []
        for s in range(self.steps_per_epoch):
            global_step = epoch * self.steps_per_epoch + s
            raw = self.data.batch(global_step, int(b_arr.sum()))
            padded, _ = HeteroBatchPartitioner.padded(raw, batches)
            seq = padded["tokens"].shape[-1]
            tok = np.zeros((n, b_max, seq), np.int32)
            lab = np.zeros((n, b_max, seq), np.int32)
            msk = np.zeros((n, b_max), np.float32)
            w = padded["tokens"].shape[1]
            tok[:, :w], lab[:, :w] = padded["tokens"], padded["labels"]
            for i, b in enumerate(batches):
                msk[i, :b] = 1.0
            self.params, self.opt_state, loss, sq_i, sq_g = step_fn(
                self.params,
                self.opt_state,
                jnp.asarray(tok),
                jnp.asarray(lab),
                jnp.asarray(msk),
                r,
                jnp.float32(lr_scale),
            )
            losses.append(float(loss))
            if isinstance(self.policy, CannikinController):
                self.policy.observe_gradients(
                    [float(x) for x in np.asarray(sq_i)], float(sq_g), batches
                )

        # 3. simulated timing
        sim_seconds, measurements = self.cluster.run_epoch(
            batches, self.steps_per_epoch
        )
        self.sim_time += sim_seconds
        self._last_measurement = measurements[-1]
        if isinstance(self.policy, CannikinController):
            self.policy.observe_epoch(measurements)

        result = EpochResult(
            epoch=epoch,
            total_batch=int(total),
            batches=tuple(int(b) for b in batches),
            sim_seconds=sim_seconds,
            mean_loss=float(np.mean(losses)),
            predicted_batch_time=predicted,
            measured_batch_time=sim_seconds / self.steps_per_epoch,
            b_noise=(
                self.policy.gns.b_noise
                if isinstance(self.policy, CannikinController)
                else float("nan")
            ),
            lr_scale=float(lr_scale),
            phase=phase,
        )
        self.history.append(result)
        return result

    def policy_total_batch(self) -> int:
        """Baselines run fixed total batch (the policy object's ref batch if
        present, else the data default)."""
        return getattr(self.policy, "total_batch", None) or getattr(
            self, "_fixed_total", 64
        )

    def set_fixed_total(self, total: int) -> None:
        self._fixed_total = total

    def run(self, epochs: int, *, target_loss: Optional[float] = None) -> List[EpochResult]:
        for _ in range(epochs):
            res = self.run_epoch()
            if target_loss is not None and res.mean_loss <= target_loss:
                break
        return self.history

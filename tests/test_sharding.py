"""Sharding-rules unit tests: divisibility fallbacks, duplicate-axis rule,
batch specs, constraint-context no-op, and mesh/microbatch helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX-compiling; excluded from the fast lane
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import train_microbatches
from repro.sharding.context import active_rules, constrain
from repro.sharding.rules import MeshRules


def rules(**kw):
    base = dict(mesh_axes={"data": 16, "model": 16}, batch_axes=("data",))
    base.update(kw)
    return MeshRules(**base)


def test_divisible_dims_shard():
    r = rules()
    assert r.spec(("embed", "ff"), (4096, 14336)) == P(None, "model")
    r2 = rules(fsdp_axis="data")
    assert r2.spec(("embed", "ff"), (4096, 14336)) == P("data", "model")


def test_indivisible_dim_falls_back_and_is_recorded():
    r = rules()
    # 24 heads on a 16-way axis -> replicated.
    spec = r.spec((None, "heads", None), (3072, 24, 128), path="wq")
    assert spec == P()
    assert any(f.path == "wq" and f.logical == "heads" for f in r.fallbacks)
    assert "wq" in r.fallback_report()


def test_duplicate_axis_earlier_dim_wins():
    r = rules(cache_seq_axes=("model",))
    # cache (L, B, T, KV, D): cache_seq takes "model"; kv_heads (16,
    # divisible) must fall back because the axis is taken.
    spec = r.spec(
        (None, "batch", "cache_seq", "kv_heads", None),
        (16, 128, 32768, 16, 128),
        path="cache/k",
    )
    assert spec == P(None, "data", "model")
    assert any(f.reason.startswith("mesh axis already used") for f in r.fallbacks)


def test_batch_one_replicates():
    r = rules()
    assert r.spec(("batch", None), (1, 1)) == P()


def test_multi_pod_batch_axes():
    r = MeshRules(
        mesh_axes={"pod": 2, "data": 16, "model": 16},
        batch_axes=("pod", "data"),
    )
    assert r.spec(("batch", None), (256, 4096)) == P(("pod", "data"))
    # 16 rows cannot shard over 32 -> replicated.
    assert r.spec(("batch", None), (16, 4096)) == P()


def test_experts_axis_option():
    r = rules(experts_axis="model")
    assert r.spec(("experts", None, "ff"), (160, 5120, 1536), path="w")[0] == "model"
    # ff also wants model -> duplicate -> replicated.
    assert r.spec(("experts", None, "ff"), (160, 5120, 1536))[2:] == ()


def test_constrain_noop_without_context():
    assert active_rules() is None
    x = jnp.ones((4, 8))
    y = constrain(x, ("batch", None))
    assert y is x  # untouched outside a sharding context


def test_constrain_rank_mismatch():
    from repro.sharding.context import sharding_context

    r = rules()
    mesh = jax.make_mesh((1,), ("data",)) if jax.device_count() == 1 else None
    if mesh is None:
        pytest.skip("needs exactly one device")
    with sharding_context(mesh, r):
        with pytest.raises(ValueError):
            constrain(jnp.ones((4, 8)), ("batch",))


def test_train_microbatches_capped_by_batch_extent():
    # single pod: data=16 -> 256/16 = 16 >= 8: keep 8
    assert train_microbatches("olmo-1b", global_batch=256, batch_extent=16) == 8
    # multi pod: 32-way batch -> cap 16 -> 8
    assert (
        train_microbatches("deepseek-v2-236b", global_batch=256, batch_extent=32) == 8
    )
    # tiny batch: never below 1
    assert train_microbatches("olmo-1b", global_batch=4, batch_extent=16) == 1

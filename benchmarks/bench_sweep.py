"""Goodput-sweep benchmark: the OptPerf engines head-to-head (the §4.1/§4.5
control-loop hot path behind the Table 5 overhead claims).

Lanes, at n nodes x C candidate total batch sizes:

  * scalar water-fill loop  — ``solve_optperf_waterfill`` per candidate
  * scalar Algorithm 1 loop — ``solve_optperf_algorithm1`` per candidate
    (with §4.5 boundary-hint chaining, as the old selector sweep did)
  * batched engine          — one ``solve_optperf_batch`` array pass
  * warm-started engine     — the same sweep re-solved after a small
    coefficient drift, brackets seeded from the previous ``t_stars``
  * jax engine              — ``solve_optperf_batch_jax``: the sweep
    jit-compiled on-device (cold and warm-seeded)
  * scheduler               — ``allocate`` at J jobs x N nodes: warm-started
    stacked rounds (NumPy and stacked-jax engines) vs the per-(job, node)
    scalar loop
  * incremental             — ``Scheduler.add_job`` on a single-job arrival
    vs a cold full re-allocation over the same job set

Hard gates (full mode):
  * batched engine <= 1e-6 relative opt_perf gap vs the scalar oracle and
    >= 10x over the scalar loop at 64x64,
  * warm-started sweep >= 5x over the cold batched sweep under small drift
    at 64x64 (and bit-equal results to ~1e-9),
  * jax engine <= 1e-5 relative gap vs the scalar oracle,
  * batched AND stacked-jax ``allocate`` >= 10x over the scalar loop at
    8 jobs x 64 nodes with assignments identical to the scalar oracle,
  * incremental ``Scheduler.add_job`` >= 3x over the cold full re-run at
    8 (+1 arriving) jobs x 64 nodes, emitting the identical allocation
    (the gate was 5x when cold ``allocate`` still paid one scalar
    chosen-set solve per greedy round; batching those into stacked
    ``solve_optperf_waterfill_subsets`` calls made the cold baseline
    ~1.7x faster, compressing the ratio — the incremental path itself
    reuses exactly as much as before, see the arrival counters).

Results land in ``artifacts/bench/sweep.json`` (uploaded per CI run so the
perf trajectory is tracked per PR).

Usage:
    PYTHONPATH=src:. python -m benchmarks.bench_sweep            # full (64x64)
    PYTHONPATH=src:. python -m benchmarks.bench_sweep --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

from benchmarks.common import Row, save_json, time_call
from repro.core.goodput import goodput_curve
from repro.core.optperf import (
    solve_optperf_algorithm1,
    solve_optperf_batch,
    solve_optperf_waterfill,
)
from repro.core.perf_model import ClusterPerfModel, CommModel, NodePerfModel
from repro.core.scheduler import Scheduler, allocate, random_jobs
from repro.core.simulator import drift_model


def _random_model(n: int, seed: int = 0) -> ClusterPerfModel:
    rng = np.random.default_rng(seed)
    nodes = tuple(
        NodePerfModel(
            q=float(rng.uniform(1e-4, 5e-3)),
            s=float(rng.uniform(0, 0.02)),
            k=float(rng.uniform(1e-4, 8e-3)),
            m=float(rng.uniform(0, 0.02)),
        )
        for _ in range(n)
    )
    comm = CommModel(t_o=0.04, t_u=0.008, gamma=0.15)
    return ClusterPerfModel(nodes=nodes, comm=comm)


def _candidates(count: int) -> np.ndarray:
    return np.unique(np.round(np.geomspace(64, 65536, count))).astype(np.float64)


def run_config(n: int, num_candidates: int, repeats: int) -> dict:
    model = _random_model(n)
    cands = _candidates(num_candidates)

    def scalar_waterfill():
        return [solve_optperf_waterfill(model, float(b)) for b in cands]

    def scalar_algorithm1():
        hint = None
        out = []
        for b in cands:
            sol = solve_optperf_algorithm1(model, float(b), boundary_hint=hint)
            hint = sum(1 for s in sol.bottleneck if s == "compute")
            out.append(sol)
        return out

    def batched():
        return solve_optperf_batch(model, cands)

    t_wf = time_call(scalar_waterfill, repeats=repeats)
    t_a1 = time_call(scalar_algorithm1, repeats=repeats)
    t_batch = time_call(batched, repeats=repeats)

    batch_sol = batched()
    scalar_sols = scalar_waterfill()
    gaps = [
        abs(batch_sol.opt_perfs[j] - s.opt_perf) / s.opt_perf
        for j, s in enumerate(scalar_sols)
    ]
    return {
        "n": n,
        "candidates": int(cands.size),
        "scalar_waterfill_us": t_wf,
        "scalar_algorithm1_us": t_a1,
        "batched_us": t_batch,
        "speedup_vs_waterfill_loop": t_wf / t_batch,
        "speedup_vs_algorithm1_loop": t_a1 / t_batch,
        "max_rel_gap_vs_oracle": float(max(gaps)),
    }


def run_warm(n: int, num_candidates: int, repeats: int, *, assert_gate: bool) -> dict:
    """Warm-start lane: re-solve after a small drift, seeded vs cold."""
    model = _random_model(n)
    cands = _candidates(num_candidates)
    base = solve_optperf_batch(model, cands)
    drifted = drift_model(model, rel=1e-4, seed=1)

    t_cold = time_call(lambda: solve_optperf_batch(drifted, cands), repeats=repeats)
    t_warm = time_call(
        lambda: solve_optperf_batch(drifted, cands, warm_start=base.t_stars),
        repeats=repeats,
    )
    cold_sol = solve_optperf_batch(drifted, cands)
    warm_sol = solve_optperf_batch(drifted, cands, warm_start=base.t_stars)
    gap = float(np.max(np.abs(warm_sol.opt_perfs - cold_sol.opt_perfs) / cold_sol.opt_perfs))
    rec = {
        "n": n,
        "candidates": int(cands.size),
        "drift_rel": 1e-4,
        "cold_us": t_cold,
        "warm_us": t_warm,
        "speedup_warm_vs_cold": t_cold / t_warm,
        "cold_evals": cold_sol.iterations,
        "warm_evals": warm_sol.iterations,
        "max_rel_gap_warm_vs_cold": gap,
    }
    if gap > 1e-9:
        raise AssertionError(f"warm-started sweep drifted from cold: {rec}")
    if assert_gate and rec["speedup_warm_vs_cold"] < 5.0:
        raise AssertionError(f"warm sweep under 5x at {n}x{num_candidates}: {rec}")
    return rec


def run_jax(n: int, num_candidates: int, repeats: int) -> dict:
    """JAX-engine lane: jit-compiled on-device sweep vs the scalar oracle."""
    from repro.core.optperf_jax import solve_optperf_batch_jax

    model = _random_model(n)
    cands = _candidates(num_candidates)
    # warmup inside time_call covers jit compilation.
    t_jax = time_call(lambda: solve_optperf_batch_jax(model, cands), repeats=repeats)
    base = solve_optperf_batch_jax(model, cands)
    t_jax_warm = time_call(
        lambda: solve_optperf_batch_jax(model, cands, warm_start=base.t_stars),
        repeats=repeats,
    )
    t_np = time_call(lambda: solve_optperf_batch(model, cands), repeats=repeats)
    gaps = []
    for j, b in enumerate(cands):
        wf = solve_optperf_waterfill(model, float(b))
        gaps.append(abs(base.opt_perfs[j] - wf.opt_perf) / wf.opt_perf)
    rec = {
        "n": n,
        "candidates": int(cands.size),
        "jax_us": t_jax,
        "jax_warm_us": t_jax_warm,
        "numpy_batched_us": t_np,
        "max_rel_gap_vs_oracle": float(max(gaps)),
    }
    if rec["max_rel_gap_vs_oracle"] > 1e-5:
        raise AssertionError(f"jax engine drifted from scalar oracle: {rec}")
    return rec


def run_scheduler(n_jobs: int, n_nodes: int, *, assert_gate: bool, jax_lane: bool) -> dict:
    """Scheduler lane: warm-started stacked allocation (NumPy batched and
    stacked-jax engines) vs the per-(job, node) scalar loop, one scalar
    baseline shared by both engine lanes."""
    jobs = random_jobs(n_jobs, n_nodes)

    def timed(engine: str, repeats: int) -> float:
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            allocate(jobs, n_nodes, engine=engine)
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    t_batched = timed("batched", repeats=3)
    t_scalar = timed("scalar", repeats=1)  # the slow baseline: once is enough
    a_s = allocate(jobs, n_nodes, engine="scalar")
    a_b = allocate(jobs, n_nodes, engine="batched")
    rec = {
        "jobs": n_jobs,
        "nodes": n_nodes,
        "batched_us": t_batched,
        "scalar_us": t_scalar,
        "speedup": t_scalar / t_batched,
        "assignments_equal": a_b.assignment == a_s.assignment,
        "aggregate_fraction": a_b.aggregate_fraction,
    }
    if jax_lane:
        allocate(jobs, n_nodes, engine="jax")  # jit warmup outside the clock
        rec["jax_us"] = timed("jax", repeats=3)
        rec["jax_speedup"] = t_scalar / rec["jax_us"]
        a_j = allocate(jobs, n_nodes, engine="jax")
        rec["jax_assignments_equal"] = a_j.assignment == a_s.assignment
        if not rec["jax_assignments_equal"]:
            raise AssertionError(f"jax allocate diverged from scalar: {rec}")
        if assert_gate and rec["jax_speedup"] < 10.0:
            raise AssertionError(
                f"stacked-jax allocate under 10x at {n_jobs}x{n_nodes}: {rec}"
            )
    if not rec["assignments_equal"]:
        raise AssertionError(f"batched allocate diverged from scalar: {rec}")
    if assert_gate and rec["speedup"] < 10.0:
        raise AssertionError(f"batched allocate under 10x at {n_jobs}x{n_nodes}: {rec}")
    return rec


def run_incremental(n_jobs: int, n_nodes: int, *, assert_gate: bool) -> dict:
    """Incremental lane: ``Scheduler.add_job`` on a single-job arrival vs a
    cold full re-allocation over the same (n_jobs + 1)-job set."""
    jobs = random_jobs(n_jobs, n_nodes)
    arriving = random_jobs(n_jobs + 1, n_nodes)[n_jobs]
    everyone = list(jobs) + [arriving]

    t_full = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        full = allocate(everyone, n_nodes)
        t_full = min(t_full, time.perf_counter() - t0)

    t_inc = float("inf")
    inc = None
    arrival = {}
    for _ in range(3):
        sched = Scheduler(n_nodes)
        for job in jobs:
            sched.add_job(job)
        before = (
            sched.warm_rounds, sched.cold_rounds,
            sched.solved_rows, sched.cached_rows,
        )
        t0 = time.perf_counter()
        inc = sched.add_job(arriving)
        t_inc = min(t_inc, time.perf_counter() - t0)
        # Counters for the arrival alone (the setup add_jobs excluded).
        arrival = dict(zip(
            ("warm_rounds", "cold_rounds", "solved_rows", "cached_rows"),
            (
                sched.warm_rounds - before[0], sched.cold_rounds - before[1],
                sched.solved_rows - before[2], sched.cached_rows - before[3],
            ),
        ))

    goodput_gap = max(
        abs(inc.goodputs[name] - full.goodputs[name])
        / max(full.goodputs[name], 1e-12)
        for name in full.goodputs
    )
    rec = {
        "jobs": n_jobs,
        "nodes": n_nodes,
        "full_us": t_full * 1e6,
        "incremental_us": t_inc * 1e6,
        "speedup": t_full / t_inc,
        "assignments_equal": inc.assignment == full.assignment,
        "max_rel_goodput_gap": float(goodput_gap),
        **arrival,
    }
    if not rec["assignments_equal"] or goodput_gap > 1e-12:
        raise AssertionError(f"incremental add_job diverged from full re-run: {rec}")
    if assert_gate and rec["speedup"] < 3.0:
        raise AssertionError(
            f"incremental add_job under 3x at {n_jobs}x{n_nodes}: {rec}"
        )
    return rec


def run(smoke: bool = False) -> List[Row]:
    configs = [(8, 8)] if smoke else [(16, 16), (64, 64), (256, 64)]
    repeats = 3 if smoke else 5
    rows: List[Row] = []
    payload = {}
    for n, c in configs:
        rec = run_config(n, c, repeats)
        payload[f"n{n}_c{c}"] = rec
        rows.append(
            Row(
                f"sweep/batched/n{n}xc{c}",
                rec["batched_us"],
                f"speedup={rec['speedup_vs_waterfill_loop']:.1f}x;"
                f"gap={rec['max_rel_gap_vs_oracle']:.2e}",
            )
        )
        rows.append(Row(f"sweep/scalar_waterfill/n{n}xc{c}", rec["scalar_waterfill_us"], ""))
        rows.append(Row(f"sweep/scalar_algorithm1/n{n}xc{c}", rec["scalar_algorithm1_us"], ""))
        # The acceptance gate: >= 10x over the per-candidate scalar loop and
        # <= 1e-6 relative opt_perf gap at the 64x64 configuration.
        if rec["max_rel_gap_vs_oracle"] > 1e-6:
            raise AssertionError(f"batched engine drifted from oracle: {rec}")
        if not smoke and (n, c) == (64, 64) and rec["speedup_vs_waterfill_loop"] < 10.0:
            raise AssertionError(f"batched sweep under 10x at 64x64: {rec}")

    # Warm-start lane (gate: >= 5x at the full 64x64 configuration).
    wn, wc = (8, 8) if smoke else (64, 64)
    rec = run_warm(wn, wc, repeats=max(repeats, 7), assert_gate=not smoke)
    payload["warm"] = rec
    rows.append(
        Row(
            f"sweep/warm/n{wn}xc{wc}",
            rec["warm_us"],
            f"speedup={rec['speedup_warm_vs_cold']:.1f}x;"
            f"evals={rec['warm_evals']}vs{rec['cold_evals']}",
        )
    )

    # JAX-engine lane (gate: <= 1e-5 vs the scalar oracle; CPU jit in CI).
    try:
        from repro.core.optperf_jax import HAS_JAX
    except ImportError:
        HAS_JAX = False
    if HAS_JAX:
        rec = run_jax(wn, wc, repeats)
        payload["jax"] = rec
        rows.append(
            Row(
                f"sweep/jax/n{wn}xc{wc}",
                rec["jax_us"],
                f"warm={rec['jax_warm_us']:.0f}us;"
                f"gap={rec['max_rel_gap_vs_oracle']:.2e}",
            )
        )
    else:
        payload["jax"] = {"skipped": "jax unavailable"}

    # Scheduler lanes (gates: batched and stacked-jax both >= 10x at
    # 8 jobs x 64 nodes, assignments identical to the scalar oracle).
    sj, sn = (3, 12) if smoke else (8, 64)
    rec = run_scheduler(sj, sn, assert_gate=not smoke, jax_lane=HAS_JAX)
    payload["scheduler"] = rec
    rows.append(
        Row(
            f"sweep/scheduler/j{sj}xn{sn}",
            rec["batched_us"],
            f"speedup={rec['speedup']:.1f}x",
        )
    )
    if "jax_us" in rec:
        rows.append(
            Row(
                f"sweep/scheduler_jax/j{sj}xn{sn}",
                rec["jax_us"],
                f"speedup={rec['jax_speedup']:.1f}x",
            )
        )

    # Incremental lane (gate: >= 3x over the cold full re-run on a
    # single-job arrival, identical allocation; was 5x before the cold
    # baseline's chosen-set solves were batched).
    rec = run_incremental(sj, sn, assert_gate=not smoke)
    payload["incremental"] = rec
    rows.append(
        Row(
            f"sweep/incremental/j{sj}xn{sn}",
            rec["incremental_us"],
            f"speedup={rec['speedup']:.1f}x;"
            f"rows={rec['solved_rows']}solved/{rec['cached_rows']}cached",
        )
    )

    # A goodput_curve smoke call so the end-to-end consumer path is timed too.
    model = _random_model(16)
    cands = _candidates(16)
    t_curve = time_call(lambda: goodput_curve(model, cands, 500.0, 128), repeats=repeats)
    rows.append(Row("sweep/goodput_curve/n16xc16", t_curve, ""))
    payload["goodput_curve_n16_c16_us"] = t_curve
    save_json("sweep", payload)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        print(row.csv(), flush=True)


if __name__ == "__main__":
    main()

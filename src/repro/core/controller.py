"""The Cannikin epoch controller (§4.1 workflow, §4.5 implementation).

Per-epoch loop:

  1. *Bootstrap* (epochs 0–1): no performance model exists yet; assign local
     batches inversely proportional to per-sample time (Eq. 8) — this both
     balances load roughly and guarantees each node sees >= 2 distinct local
     batch sizes so the linear fits become possible.
  2. *Model learning*: each node's fitter ingests NodeObservations; cluster
     gamma via inverse-variance weighting (Eq. 12), T_comm via min-aggregation,
     T_u from gamma-weighted split of the comm time.
  3. *Batch-size selection*: the adaptive engine enumerates total-batch
     candidates; goodput(B) = throughput(B) * efficiency(B) with throughput
     from OptPerf(B); the OptPerf_init cache avoids re-sweeping (§4.5).
  4. *Partition*: round Eq.-(9)-compatible optimal real batches to integers.

The controller is runtime-agnostic: it consumes measurements (from the
simulator or from wall-clock timing of real JAX steps) and produces the next
epoch's partition + learning-rate scale.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.batch_policy import (
    BatchBounds,
    BatchSizePolicy,
    PolicyTelemetry,
    lr_scale_for,
    make_batch_policy,
)
from repro.core.gns import GNSState, estimate_gns, gns_update, gns_weights
from repro.core.goodput import (
    BatchSizeSelector,
    adascale_gain,
    sqrt_lr_scale,
    statistical_efficiency,
)
from repro.core.optperf import (
    OptPerfSolution,
    round_batches,
    solve_optperf,
    solve_optperf_batch,
)
from repro.core.perf_model import (
    ClusterPerfModel,
    CommModel,
    GammaAggregator,
    NodeObservation,
    OnlineNodeFitter,
    bootstrap_partition,
)
from repro.core.simulator import StepMeasurement

__all__ = [
    "CannikinController",
    "EpochPlan",
    "ControllerStats",
    "FusedSweepContext",
    "FusedProposal",
    "FUSED_CERT_TOL",
]

# Relative tolerance for certifying an on-device (float32) fused-epoch plan
# against the host float64 two-program oracle — same bar the jax sweep
# engine's own certification uses.
FUSED_CERT_TOL = 1e-5


@dataclasses.dataclass(frozen=True)
class EpochPlan:
    """What the controller tells the runtime to do next epoch."""

    epoch: int
    total_batch: int
    batches: Tuple[int, ...]
    lr_scale: float
    predicted_batch_time: Optional[float]  # None during bootstrap
    phase: str                             # "bootstrap" | "optperf"
    solution: Optional[OptPerfSolution] = None
    # Provenance: which BatchSizePolicy proposed this total batch (None for
    # bootstrap plans — no policy is consulted before a model exists;
    # "<policy>+fused" when the plan consumed an on-device fused proposal).
    batch_policy: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class FusedSweepContext:
    """Everything a fused backend epoch needs to run the goodput sweep on
    device: the refit model's prefetched device coefficients, the candidate
    grid (device + host views), the water-fill lower bracket, and the
    reference batch for Eq. (6) efficiency.  ``model`` pins the exact host
    model the certification oracle must re-solve against."""

    model: ClusterPerfModel
    coeffs: Any                 # optperf_jax.DeviceCoeffs
    candidates: Any             # (C,) device array, coeffs dtype
    candidates_np: np.ndarray   # (C,) float64 host view
    lo0: float
    ref_batch: float


@dataclasses.dataclass(frozen=True)
class FusedProposal:
    """What the fused device program proposed for the next epoch: the
    goodput-argmax candidate, its water-filled partition, and the full
    sweep telemetry the host certification checks against the float64
    oracle."""

    best_index: int
    total_batch: float
    batches: np.ndarray         # (n,) real-valued water-fill partition
    t_star: float               # winner's cluster time
    t_stars: np.ndarray         # (C,) per-candidate cluster times
    goodputs: np.ndarray        # (C,) device goodput landscape
    b_noise: float              # epoch-final on-device GNS estimate
    sweep_iters: int


@dataclasses.dataclass
class ControllerStats:
    overhead_seconds: float = 0.0
    epochs_planned: int = 0
    full_sweeps: int = 0
    incremental_updates: int = 0
    # Incremental re-bracketing observability: how many full sweeps were
    # warm-started from the previous epoch's t_star vector vs solved cold
    # (cold = first sweep, membership change, or coefficient-regime change).
    warm_sweeps: int = 0
    cold_sweeps: int = 0
    # Fused-mode observability: plans consumed from on-device proposals,
    # host-float64 certifications run (off the critical path), certification
    # failures (any failure permanently falls back to the two-program path),
    # and the worst relative deviation a certification ever measured.
    fused_plans: int = 0
    fused_certifications: int = 0
    fused_cert_failures: int = 0
    fused_max_rel_err: float = 0.0

    def overhead_fraction(self, training_seconds: float) -> float:
        if training_seconds <= 0:
            return 0.0
        return self.overhead_seconds / training_seconds


class CannikinController:
    """Drives heterogeneous adaptive-batch-size training.

    Args:
      n_nodes: number of DP node groups.
      batch_candidates: total-batch-size candidates (adaptive engine range).
      ref_batch: user's initial/reference batch size B0.
      lr_rule: "adascale" (SGD workloads) or "sqrt" (Adam workloads).
      adaptive: if False, keeps total batch fixed at ``ref_batch`` (the
        fixed-batch evaluation mode of §5.2.2) but still optimizes the split.
      sweep_engine: "batched" (default) runs the candidate goodput sweep as
        one vectorized ``solve_optperf_batch`` pass; "jax" runs the same
        sweep jit-compiled on-device beside the training step (falls back to
        "batched" when JAX is unavailable); "scalar" keeps the per-candidate
        Algorithm-1 loop (cross-check oracle).  Plans are identical in every
        case — the winner is always re-solved scalar.  The array engines
        warm-start each epoch's brackets from the previous epoch's t_star
        vector (see BatchSizeSelector).
      warm_drift_limit: maximum relative coefficient drift under which the
        previous epoch's brackets are still trusted as warm seeds; larger
        drift (a regime change) falls back to cold brackets.
      min_local / max_local: per-node local batch bounds (memory limits, §6).
      batch_policy: name of a registered :mod:`repro.core.batch_policy`
        law (or a ready :class:`BatchSizePolicy` instance) deciding the
        total batch each epoch.  ``None`` keeps the historical behaviour:
        ``cannikin-gns`` when ``adaptive``, else ``fixed`` — both
        bit-identical to the pre-protocol controller.  ``adaptive=False``
        always forces ``fixed`` (the §5.2.2 evaluation mode wins).
      policy_kwargs: extra keyword arguments for the named policy's
        constructor (e.g. ``{"factor": 2.0, "delay": 4}`` for geodamp).
    """

    name = "cannikin"

    def __init__(
        self,
        n_nodes: int,
        *,
        batch_candidates: Sequence[int],
        ref_batch: int,
        lr_rule: str = "adascale",
        adaptive: bool = True,
        solver: str = "algorithm1",
        sweep_engine: str = "batched",
        warm_drift_limit: float = 0.25,
        gns_decay: float = 0.9,
        min_local: int = 1,
        max_local: Optional[int] = None,
        batch_policy: Union[str, BatchSizePolicy, None] = None,
        policy_kwargs: Optional[Dict] = None,
    ) -> None:
        self.n = n_nodes
        self.ref_batch = int(ref_batch)
        self.adaptive = adaptive
        self.lr_rule = lr_rule
        self.solver = solver
        self.min_local = min_local
        self.max_local = max_local
        self.fitters: Dict[int, OnlineNodeFitter] = {
            i: OnlineNodeFitter() for i in range(n_nodes)
        }
        self.selector = BatchSizeSelector(
            candidates=tuple(sorted(set(int(b) for b in batch_candidates))),
            ref_batch=int(ref_batch),
            solver=solver,
            engine=sweep_engine,
            warm_drift_limit=warm_drift_limit,
        )
        self.gns = GNSState()
        self.gns_decay = gns_decay
        self.stats = ControllerStats()
        self._epoch = 0
        self._last_plan: Optional[EpochPlan] = None
        self._model: Optional[ClusterPerfModel] = None
        self._last_loss = float("nan")
        self._fused_ctx: Optional[FusedSweepContext] = None
        self._fused_pending: Optional[Tuple[FusedSweepContext, FusedProposal]] = None
        self._fused_disabled = False
        if batch_policy is None or isinstance(batch_policy, str):
            if not adaptive:
                chosen = "fixed"
            elif batch_policy is None:
                chosen = "cannikin-gns"
            else:
                chosen = batch_policy
            kwargs = dict(policy_kwargs or {})
            if chosen in ("cannikin-gns", "fixed"):
                # These two reproduce the historical controller paths, where
                # the LR rule was the controller's own knob; dampers pin
                # their own rule unless policy_kwargs overrides it.
                kwargs.setdefault("lr_rule", lr_rule)
            self.policy: BatchSizePolicy = make_batch_policy(
                chosen,
                candidates=self.selector.candidates,
                ref_batch=self.ref_batch,
                selector=self.selector,
                **kwargs,
            )
        else:
            self.policy = batch_policy

    # ------------------------------------------------------------------
    # measurement ingestion
    # ------------------------------------------------------------------

    def observe_epoch(self, measurements: Sequence[StepMeasurement]) -> None:
        """Feed the epoch's step measurements (averaged per node)."""
        if not measurements:
            return
        n_steps = len(measurements)
        for i in range(self.n):
            # A crashed node reports nothing: its slot holds None and the
            # fitter simply sees no sample this epoch.
            obs = [m.observations[i] for m in measurements if m.observations[i] is not None]
            if not obs:
                continue
            self.fitters[i].add(
                NodeObservation(
                    batch_size=obs[0].batch_size,
                    a_time=float(np.mean([o.a_time for o in obs])),
                    backprop_time=float(np.mean([o.backprop_time for o in obs])),
                    gamma=float(np.mean([o.gamma for o in obs])),
                    comm_time=float(np.min([o.comm_time for o in obs])),
                )
            )
        self._model = None  # stale

    def observe_gradients(
        self,
        local_sqnorms: Sequence[float],
        global_sqnorm: float,
        batches: Sequence[float],
    ) -> None:
        """Feed per-node gradient square-norms for GNS tracking (§4.4)."""
        try:
            _, g, s = estimate_gns(local_sqnorms, global_sqnorm, batches)
        except (ValueError, np.linalg.LinAlgError):
            return
        self.gns = gns_update(self.gns, g, s, decay=self.gns_decay)

    def observe_execution(self, result) -> None:
        """Ingest one backend epoch's telemetry in the canonical order:
        per-step gradient square-norms (GNS tracking) first, then the
        epoch's timing measurements (performance-model fitting).

        ``result`` is duck-typed (any object with ``grad_observations`` —
        each carrying ``local_sqnorms``/``global_sqnorm``/``batches`` — and
        ``measurements``), so the controller stays runtime-agnostic: the
        :class:`~repro.runtime.backend.ExecutionResult` of either backend
        and hand-built test doubles all plumb through the same way.

        Steps where the backend's anomaly guard excluded a node
        (``obs.valid`` not all-true) are skipped for GNS tracking: their
        square-norms are poisoned and would corrupt the Theorem-4.1
        estimate."""
        for obs in getattr(result, "grad_observations", ()) or ():
            valid = getattr(obs, "valid", ())
            if valid and not all(valid):
                continue
            self.observe_gradients(obs.local_sqnorms, obs.global_sqnorm, obs.batches)
        loss = getattr(result, "mean_loss", None)
        self._last_loss = float(loss) if loss is not None else float("nan")
        self.observe_epoch(result.measurements)

    # ------------------------------------------------------------------
    # model assembly
    # ------------------------------------------------------------------

    def can_model(self) -> bool:
        return all(f.can_fit() for f in self.fitters.values())

    def cluster_model(self) -> ClusterPerfModel:
        if self._model is not None:
            return self._model
        if not self.can_model():
            raise RuntimeError("performance models not yet learnable")
        nodes = tuple(self.fitters[i].fit() for i in range(self.n))
        agg = GammaAggregator(self.fitters)
        gamma = agg.gamma()
        t_comm = agg.t_comm()
        # Split T_comm into overlappable T_o and last-bucket T_u.  The paper
        # measures buckets directly; behind XLA we apportion by bucket count
        # heuristic: T_u = T_comm / n_buckets with n_buckets ~ 1/(1-gamma)
        # clamped — tests cover robustness of OptPerf to this split.
        t_u = t_comm * min(0.2, max(0.02, 1.0 - gamma) * 0.2)
        t_o = t_comm - t_u
        self._model = ClusterPerfModel(
            nodes=nodes, comm=CommModel(t_o=t_o, t_u=t_u, gamma=gamma)
        )
        self._prefetch_device_coeffs(self._model)
        return self._model

    def _prefetch_device_coeffs(self, model: ClusterPerfModel) -> None:
        """Fuse the device-coefficient export with the per-epoch OLS refit.

        Under ``sweep_engine="jax"`` the freshly refit model's coefficient
        arrays are shipped to the device *here*, at refit time, instead of
        lazily inside the next sweep — so the on-device re-solve never
        blocks on a host export (the refit already paid the transfer), and
        a stale pre-refit export can never be what the sweep reads (the
        cache is keyed on the frozen model instance)."""
        if self.selector.engine != "jax":
            return
        try:
            from repro.core import optperf_jax

            if optperf_jax.HAS_JAX:
                optperf_jax.device_coeffs(model)
        except ImportError:  # pragma: no cover - jax present in CI image
            pass

    def _evict_device_export(self) -> None:
        """Drop the *current* model's cached device-coefficient export.

        Membership changes (`add_nodes`/`remove_nodes`) orphan `self._model`;
        its prefetched coefficient stack must be evicted — not merely
        dereferenced — so a stale export can never be reused (and never
        stays pinned in device memory) after the cluster changed shape."""
        if self._model is None:
            return
        try:
            from repro.core import optperf_jax

            if optperf_jax.HAS_JAX:
                optperf_jax.evict_device_coeffs(self._model)
        except ImportError:  # pragma: no cover - jax present in CI image
            pass

    def set_comm_split(self, t_o: float, t_u: float, gamma: float) -> None:
        """Override the comm model with directly measured values (used when the
        runtime can observe bucket boundaries, e.g. the simulator's oracle or
        a profiler hook)."""
        if not self.can_model():
            raise RuntimeError("performance models not yet learnable")
        nodes = tuple(self.fitters[i].fit() for i in range(self.n))
        self._model = ClusterPerfModel(
            nodes=nodes, comm=CommModel(t_o=t_o, t_u=t_u, gamma=gamma)
        )
        self._prefetch_device_coeffs(self._model)

    # ------------------------------------------------------------------
    # planning
    # ------------------------------------------------------------------

    @property
    def batch_bounds(self) -> BatchBounds:
        """Total-batch bounds the policy must stay within: the span of the
        candidate grid, always widened to include the reference batch."""
        grid = set(self.selector.candidates) | {self.ref_batch}
        return BatchBounds(min_total=min(grid), max_total=max(grid))

    def _apply_bounds(self, batches: List[int], total: int) -> List[int]:
        """Clamp local batches to [min_local, max_local] preserving the sum."""
        lo = self.min_local
        hi = self.max_local if self.max_local is not None else total
        b = np.clip(np.asarray(batches, dtype=np.int64), lo, hi)
        diff = total - int(b.sum())
        # Redistribute the clamping residue greedily to unclamped nodes.
        order = np.argsort(b) if diff > 0 else np.argsort(-b)
        idx = 0
        while diff != 0 and idx < 10 * self.n:
            i = int(order[idx % self.n])
            step = 1 if diff > 0 else -1
            if lo <= b[i] + step <= hi:
                b[i] += step
                diff -= step
            idx += 1
        return [int(x) for x in b]

    def plan_epoch(self, *, prefer_fused: bool = False) -> EpochPlan:
        """Produce the next epoch's configuration.

        The total-batch decision is delegated to ``self.policy`` (the
        :class:`BatchSizePolicy` seam): every planning round first feeds
        the policy one :class:`PolicyTelemetry` observation (previous
        epoch's plan, latest mean loss, current GNS estimate), then — once
        a performance model exists — asks it to ``propose`` the next total
        batch and LR scale.  Splitting the total across nodes stays the
        controller's job: OptPerf solve (reusing the policy's solution if
        it already ran the sweep), Eq.-(9) rounding, local-bound clamping.

        ``prefer_fused=True`` consumes a staged on-device fused proposal
        (see :meth:`stage_fused_proposal`) instead of asking the policy —
        identical plan structure, zero host solve on the critical path.
        With nothing staged (first fused epoch, bootstrap, certification
        fallback) the normal path runs.
        """
        t0 = time.perf_counter()
        epoch = self._epoch
        self._epoch += 1
        self.stats.epochs_planned += 1

        last = self._last_plan
        self.policy.observe(
            PolicyTelemetry(
                epoch=epoch,
                total_batch=last.total_batch if last is not None else 0,
                mean_loss=self._last_loss,
                b_noise=self.gns.b_noise,
                phase=last.phase if last is not None else "",
            )
        )

        model = None
        if self.can_model():
            try:
                model = self.cluster_model()
            except ValueError:
                # A non-physical fit (negative slope) means the window is
                # polluted — e.g. a straggler window straddling the fit.
                # Plan the bootstrap split and re-learn from the next
                # epochs' measurements instead of killing the job.
                model = None
        if model is None:
            # Any staged fused proposal was built by a model that no longer
            # exists for planning purposes; drop it rather than serve it
            # after a bootstrap interlude.
            self._fused_pending = None
            plan = self._bootstrap_plan(epoch)
            self._finish_plan(plan, t0)
            return plan
        plan = self._fused_plan(epoch) if prefer_fused else None
        if plan is None:
            proposal = self.policy.propose(model, self.batch_bounds)
            best_b = int(proposal.total_batch)
            sol = proposal.solution
            if sol is None:
                sol = solve_optperf(model, best_b, method=self.solver)
            batches = self._apply_bounds(
                round_batches(list(sol.batches), best_b), best_b
            )
            plan = EpochPlan(
                epoch=epoch,
                total_batch=best_b,
                batches=tuple(batches),
                lr_scale=float(proposal.lr_scale),
                predicted_batch_time=sol.opt_perf,
                phase="optperf",
                solution=sol,
                batch_policy=self.policy.name,
            )
        self._finish_plan(plan, t0)
        return plan

    def _finish_plan(self, plan: EpochPlan, t0: float) -> None:
        self.stats.overhead_seconds += time.perf_counter() - t0
        self.stats.full_sweeps = self.selector.full_sweeps
        self.stats.incremental_updates = self.selector.incremental_updates
        self.stats.warm_sweeps = self.selector.warm_sweeps
        self.stats.cold_sweeps = self.selector.cold_sweeps
        self._last_plan = plan

    # ------------------------------------------------------------------
    # fused on-device planning (sweep-in-jit)
    # ------------------------------------------------------------------

    def fused_context(self) -> Optional[FusedSweepContext]:
        """The device-side sweep inputs for a fused backend epoch, or None
        whenever fused mode cannot run (bootstrap, non-adaptive, a policy
        other than cannikin-gns, jax missing, or a past certification
        failure) — callers then take the two-program path unchanged."""
        if not self.adaptive or self._fused_disabled:
            return None
        if getattr(self.policy, "name", "") != "cannikin-gns":
            return None
        model = self._model
        if model is None:
            return None
        if self._fused_ctx is not None and self._fused_ctx.model is model:
            return self._fused_ctx
        try:
            from repro.core import optperf_jax
        except ImportError:  # pragma: no cover - jax present in CI image
            return None
        if not optperf_jax.HAS_JAX:
            return None
        import jax.numpy as jnp

        from repro.core.optperf import _problem_from_model  # noqa: SLF001

        coeffs = optperf_jax.device_coeffs(model)
        _, lo0 = _problem_from_model(model)
        cand_np = np.asarray(self.selector.candidates, np.float64)
        ctx = FusedSweepContext(
            model=model,
            coeffs=coeffs,
            candidates=jnp.asarray(cand_np, coeffs.alphas.dtype),
            candidates_np=cand_np,
            lo0=float(lo0),
            ref_batch=float(self.ref_batch),
        )
        self._fused_ctx = ctx
        return ctx

    def stage_fused_proposal(
        self, ctx: FusedSweepContext, proposal: FusedProposal
    ) -> None:
        """Certify an on-device proposal against the host float64 oracle
        and stage it for the next ``plan_epoch(prefer_fused=True)``.

        Runs *after* the epoch that produced the proposal — never between
        plan and execute, which is the whole point of fused mode.  A
        certification failure permanently disables fused planning (the
        two-program fallback is bit-compatible, so this is safe, and a
        float32 disagreement is systemic rather than transient)."""
        if self._fused_disabled:
            return
        self._certify_fused(ctx, proposal)
        if not self._fused_disabled:
            self._fused_pending = (ctx, proposal)

    def _certify_fused(self, ctx: FusedSweepContext, prop: FusedProposal) -> None:
        """Host float64 re-solve of the exact sweep the device ran: same
        model, same candidates, same (device-estimated) noise scale."""
        t0 = time.perf_counter()
        cands = ctx.candidates_np
        sols = solve_optperf_batch(ctx.model, [float(b) for b in cands])
        opt_perfs = np.asarray(sols.opt_perfs, np.float64)
        eff = statistical_efficiency(prop.b_noise, cands, ctx.ref_batch)
        goodputs = cands / opt_perfs * eff
        host_best = int(np.argmax(goodputs))
        rel = float(
            np.max(
                np.abs(np.asarray(prop.t_stars) - opt_perfs)
                / np.maximum(opt_perfs, 1e-12)
            )
        )
        ok = rel <= FUSED_CERT_TOL
        if ok and host_best != prop.best_index:
            # A genuine goodput near-tie may flip the argmax in float32;
            # only a materially better host winner is a failure.
            gap = abs(goodputs[host_best] - goodputs[prop.best_index])
            ok = gap <= FUSED_CERT_TOL * max(goodputs[host_best], 1e-12)
        if ok:
            host_batches = np.asarray(sols.batches[prop.best_index], np.float64)
            total = float(cands[prop.best_index])
            rel_b = float(
                np.max(np.abs(np.asarray(prop.batches) - host_batches))
                / max(total, 1e-12)
            )
            ok = rel_b <= FUSED_CERT_TOL
            rel = max(rel, rel_b)
        self.stats.fused_certifications += 1
        self.stats.fused_max_rel_err = max(self.stats.fused_max_rel_err, rel)
        if not ok:
            self.stats.fused_cert_failures += 1
            self._fused_disabled = True
            self._fused_pending = None
        self.stats.overhead_seconds += time.perf_counter() - t0

    def _fused_plan(self, epoch: int) -> Optional[EpochPlan]:
        """Turn the staged (certified) device proposal into an EpochPlan:
        integer rounding, local-bound clamping, and the policy's LR rule
        evaluated at the device-estimated noise scale."""
        pending, self._fused_pending = self._fused_pending, None
        if pending is None:
            return None
        ctx, prop = pending
        total = int(round(prop.total_batch))
        batches = self._apply_bounds(
            round_batches([float(b) for b in prop.batches], total), total
        )
        lr = lr_scale_for(
            self.lr_rule,
            batch=total,
            ref_batch=self.ref_batch,
            b_noise=prop.b_noise,
        )
        states = tuple(
            "compute" if c else "comm"
            for c in ctx.model.compute_bottleneck_mask(
                np.asarray(prop.batches, np.float64)
            )
        )
        sol = OptPerfSolution(
            total_batch=float(prop.total_batch),
            opt_perf=float(prop.t_star),
            batches=tuple(float(b) for b in prop.batches),
            bottleneck=states,
            method="waterfill/fused-device",
        )
        self.stats.fused_plans += 1
        return EpochPlan(
            epoch=epoch,
            total_batch=total,
            batches=tuple(batches),
            lr_scale=float(lr),
            predicted_batch_time=float(prop.t_star),
            phase="optperf",
            solution=sol,
            batch_policy=f"{self.policy.name}+fused",
        )

    def _bootstrap_plan(self, epoch: int) -> EpochPlan:
        total = self.ref_batch
        if epoch == 0 or not all(f.num_observations for f in self.fitters.values()):
            # Even split, first contact.
            batches = round_batches([total / self.n] * self.n, total)
        else:
            # Eq. (8): inverse per-sample-time proportional assignment.  If
            # this lands on the same batch a node already saw, nudge by one
            # sample so the fitter gets two distinct sizes.
            ts = [self.fitters[i].per_sample_time() for i in range(self.n)]
            raw = bootstrap_partition(ts, total)
            batches = self._nudge_distinct(round_batches(raw, total), total)
        batches = self._apply_bounds(batches, total)
        return EpochPlan(
            epoch=epoch,
            total_batch=total,
            batches=tuple(batches),
            lr_scale=1.0,
            predicted_batch_time=None,
            phase="bootstrap",
        )

    def _nudge_distinct(self, batches: List[int], total: int) -> List[int]:
        """Ensure each node's new batch differs from every one it has seen."""
        out = list(batches)
        for i in range(self.n):
            fitter = self.fitters[i]
            seen = {o.batch_size for o in fitter._obs}  # noqa: SLF001 (intra-package)
            if float(out[i]) in seen:
                j = max(range(self.n), key=lambda x: out[x])
                if j != i and out[j] > 1:
                    out[i] += 1
                    out[j] -= 1
                elif out[i] > 1:
                    out[i] -= 1
                    out[(i + 1) % self.n] += 1
        return out

    # ------------------------------------------------------------------
    # elastic reconfiguration (paper §6: dynamic resource allocation)
    # ------------------------------------------------------------------

    def remove_nodes(self, node_ids: Sequence[int]) -> None:
        """Drop nodes mid-training.  Learned models of the remaining nodes
        are kept (the paper: "easily use the learned computing models of
        remaining nodes"); the OptPerf cache is invalidated."""
        drop = set(node_ids)
        keep = [i for i in range(self.n) if i not in drop]
        if not keep:
            raise ValueError("cannot remove every node")
        self.fitters = {new: self.fitters[old] for new, old in enumerate(keep)}
        self.n = len(keep)
        self._evict_device_export()
        self._drop_fused_state()
        self._model = None
        # Cluster membership changed: cached solutions AND the warm-start
        # bracket state are both stale.
        self.selector.invalidate()
        self._invalidate_policy()

    def add_nodes(self, count: int = 1) -> None:
        """Add fresh nodes: their models are unknown, so the controller
        drops back to the bootstrap phase for two epochs (paper §6:
        "re-initialize the cluster for job J with two epochs")."""
        if count < 1:
            raise ValueError("count must be >= 1")
        for i in range(self.n, self.n + count):
            self.fitters[i] = OnlineNodeFitter()
        self.n += count
        self._evict_device_export()
        self._drop_fused_state()
        self._model = None
        self.selector.invalidate()
        self._invalidate_policy()

    def _drop_fused_state(self) -> None:
        """Membership changed: the staged proposal and cached context refer
        to a cluster that no longer exists.  ``_fused_disabled`` survives —
        a float32 certification failure is systemic, not shape-specific."""
        self._fused_pending = None
        self._fused_ctx = None

    def _invalidate_policy(self) -> None:
        """Tell the policy its cached cluster view is stale (cannikin-gns
        shares the controller's selector, whose caches were just dropped;
        a policy with its own caches hooks ``invalidate``)."""
        invalidate = getattr(self.policy, "invalidate", None)
        if invalidate is not None:
            invalidate()

    @property
    def last_plan(self) -> Optional[EpochPlan]:
        return self._last_plan

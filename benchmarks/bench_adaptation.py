"""Fig. 9 reproduction: epochs to reach OptPerf from an even split, given a
fixed total batch — Cannikin (2 learning epochs) vs LB-BSP (Δ=5/epoch).

Policies and the epoch-driving loop come from the runtime's shared factory
(``repro.runtime.make_partition_policy`` / ``drive_partition_policy``), so
the benchmark exercises exactly the protocol the launch CLI and examples
use."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, save_json, time_call
from repro.core.optperf import solve_optperf_algorithm1
from repro.core.simulator import SimulatedCluster, cluster_A
from repro.runtime import drive_partition_policy, make_partition_policy


def run() -> List[Row]:
    total = 128
    epochs = 14
    profiles, comm = cluster_A()
    curves = {}
    for name in ("cannikin", "lb-bsp", "even"):
        sim = SimulatedCluster(profiles, comm, noise=0.005, seed=0)
        policy = make_partition_policy(
            name, sim.n, candidates=[total], ref_batch=total, adaptive=False
        )
        curves[name] = drive_partition_policy(policy, sim, total, epochs)
    best = solve_optperf_algorithm1(
        SimulatedCluster(profiles, comm, noise=0.0).true_model(), total
    ).opt_perf

    def epochs_to_optperf(curve, tol=0.05):
        for i, t in enumerate(curve):
            if t <= best * (1 + tol):
                return i
        return len(curve)

    e_can = epochs_to_optperf(curves["cannikin"])
    e_lb = epochs_to_optperf(curves["lb-bsp"])
    save_json("adaptation_fig9", {"optperf_seconds": best, "curves": curves,
                                  "epochs_to_optperf": {"cannikin": e_can, "lb-bsp": e_lb}})
    rows = [
        Row("fig9/epochs_to_optperf/cannikin", 0.0, f"epochs={e_can}"),
        Row("fig9/epochs_to_optperf/lb-bsp", 0.0, f"epochs={e_lb}"),
        Row(
            "fig9/final_batch_time_ratio_even",
            0.0,
            f"{curves['even'][-1] / best:.3f}x_optperf",
        ),
    ]
    return rows

"""Mixture-of-Experts layer + Mixtral-8x7B model.

Dispatch is capacity-based (GShard-style, token-dropping) but realized with
*scatter/gather* instead of one-hot einsums so the compiled FLOPs reflect
activated compute (the einsum formulation costs T*E*C*d which dwarfs the
expert FFNs for large E — DeepSeek's 160 experts would be 10x overcounted).

Sharding: experts are kept on every device but each expert's matrices are
2D-sharded — d_model over the fsdp axis, d_ff over the model axis.  Tokens
stay batch-sharded; no all_to_all is required and the combine reduces over
the model axis like any TP FFN.  (An expert-parallel all_to_all layout is a
§Perf candidate; see EXPERIMENTS.md.)

Aux losses: switch-style load-balance loss and router z-loss, returned via
a stats dict so the train step can add them with configurable weights.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Param
from repro.sharding.context import active_rules, constrain

__all__ = [
    "MoEConfig",
    "moe_layer_schema",
    "moe_apply",
    "MixtralConfig",
    "schema",
    "init",
    "forward",
    "init_cache",
    "decode_step",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                     # per-expert hidden
    capacity_factor: float = 1.25
    n_shared_experts: int = 0     # DeepSeek-style always-on experts
    d_ff_shared: int = 0          # hidden of the fused shared expert
    router_dtype: Any = jnp.float32


def moe_layer_schema(cfg: MoEConfig) -> Dict[str, Any]:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    s: Dict[str, Any] = {
        "router": Param((d, e), (None, None), scale=0.02),
        "w_gate": Param((e, d, f), ("experts", "embed", "ff")),
        "w_up": Param((e, d, f), ("experts", "embed", "ff")),
        "w_down": Param((e, f, d), ("experts", "ff", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff_shared or cfg.d_ff * cfg.n_shared_experts
        s["shared"] = {
            "w_gate": Param((d, fs), ("embed", "ff")),
            "w_up": Param((d, fs), ("embed", "ff")),
            "w_down": Param((fs, d), ("ff", "embed")),
        }
    return s


# §Perf variant hook: when False, skip the expert-buffer sharding
# constraints and let GSPMD choose (better for small E where the capacity
# re-shard dominates).
CONSTRAIN_DISPATCH = True

# Tokens*top_k at or below this use the gather-based decode fast path:
# only the selected experts' weights are read from HBM (vs streaming all E)
# — the dominant memory term of MoE decode at tiny batch (§Perf pair 3).
DECODE_GATHER_MAX = 16


def capacity(cfg: MoEConfig, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    c = max(int(c), 4)
    if c >= 32:
        c = -(-c // 32) * 32  # round up: keeps the capacity dim shardable
    return c


def moe_apply(
    lp: Dict[str, Any], x: jax.Array, cfg: MoEConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B, S, d) -> (out (B, S, d), stats).

    Token-dropping capacity router: tokens beyond an expert's capacity are
    dropped (contribute zero from that expert), matching GShard/Switch
    semantics.  Gates are renormalized over the chosen top-k.
    """
    b, s, d = x.shape
    t = b * s
    cap = capacity(cfg, t)
    xf = x.reshape(t, d)

    logits = jnp.einsum(
        "td,de->te", xf.astype(cfg.router_dtype), lp["router"].astype(cfg.router_dtype)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    if t * cfg.top_k <= DECODE_GATHER_MAX:
        # Tiny-batch (decode) fast path: gather only the selected experts'
        # weights instead of streaming all E of them.  For long_500k
        # (B=1, top-6 of 160) this cuts per-layer HBM weight traffic ~20x.
        w_g = jnp.take(lp["w_gate"], expert_idx, axis=0)   # (T,k,d,f)
        w_u = jnp.take(lp["w_up"], expert_idx, axis=0)
        w_d = jnp.take(lp["w_down"], expert_idx, axis=0)   # (T,k,f,d)
        hg = jnp.einsum("td,tkdf->tkf", xf, w_g)
        hu = jnp.einsum("td,tkdf->tkf", xf, w_u)
        hh = common.swiglu(hg, hu)
        routed = jnp.einsum("tkf,tkfd->tkd", hh, w_d)
        combined = (routed * gate_vals[..., None].astype(routed.dtype)).sum(axis=1)
        out = combined
        if "shared" in lp:
            sp = lp["shared"]
            g = jnp.einsum("td,df->tf", xf, sp["w_gate"])
            u = jnp.einsum("td,df->tf", xf, sp["w_up"])
            out = out + jnp.einsum("tf,fd->td", common.swiglu(g, u), sp["w_down"])
        stats = {
            "lb_loss": jnp.float32(0.0),
            "z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2).astype(
                jnp.float32
            ),
            "drop_frac": jnp.float32(0.0),
        }
        return out.reshape(b, s, d), stats

    # Position of each (token, k) routing within its expert queue.
    flat_e = expert_idx.reshape(-1)                     # (T*k,)
    onehot = jax.nn.one_hot(flat_e, cfg.n_experts, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)
    pos_in_e = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < cap
    dest = jnp.where(keep, flat_e * cap + pos_in_e, cfg.n_experts * cap)  # drop slot

    # Scatter tokens into (E*cap+1, d) buffers (last row = dropped).
    src = jnp.repeat(xf, cfg.top_k, axis=0)            # (T*k, d)
    buf = jnp.zeros((cfg.n_experts * cap + 1, d), xf.dtype).at[dest].set(src)
    expert_in = buf[: cfg.n_experts * cap].reshape(cfg.n_experts, cap, d)
    # Capacity slots sharded over the data axis: each DP shard computes its
    # slice of every expert with TP-sharded expert weights (DESIGN.md §4).
    rules = active_rules()
    if CONSTRAIN_DISPATCH and rules is not None and rules.experts_axis:
        # Only pin the dispatch layout under expert parallelism; for small
        # E (< model axis) GSPMD's own choice is ~3x cheaper (§Perf log).
        expert_in = constrain(expert_in, ("experts", "batch", None))

    # Expert FFNs (SwiGLU), batched over experts.
    h_gate = jnp.einsum("ecd,edf->ecf", expert_in, lp["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", expert_in, lp["w_up"])
    h = common.swiglu(h_gate, h_up)
    expert_out = jnp.einsum("ecf,efd->ecd", h, lp["w_down"])
    if CONSTRAIN_DISPATCH and rules is not None and rules.experts_axis:
        expert_out = constrain(expert_out, ("experts", "batch", None))

    # Gather back and combine with gates.
    out_flat = expert_out.reshape(cfg.n_experts * cap, d)
    out_flat = jnp.concatenate([out_flat, jnp.zeros((1, d), out_flat.dtype)], axis=0)
    routed = out_flat[dest]                             # (T*k, d)
    gates = (gate_vals.reshape(-1) * keep).astype(routed.dtype)
    combined = (routed * gates[:, None]).reshape(t, cfg.top_k, d).sum(axis=1)

    # Aux losses: load-balance (Switch) and router z-loss.
    me = probs.mean(axis=0)                             # (E,)
    ce = jnp.zeros(cfg.n_experts, probs.dtype).at[flat_e].add(
        jnp.ones_like(flat_e, probs.dtype)
    ) / (t * cfg.top_k)
    lb_loss = cfg.n_experts * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    drop_frac = 1.0 - keep.mean()

    out = combined
    if "shared" in lp:
        sp = lp["shared"]
        g = jnp.einsum("td,df->tf", xf, sp["w_gate"])
        u = jnp.einsum("td,df->tf", xf, sp["w_up"])
        out = out + jnp.einsum("tf,fd->td", common.swiglu(g, u), sp["w_down"])

    stats = {
        "lb_loss": lb_loss.astype(jnp.float32),
        "z_loss": z_loss.astype(jnp.float32),
        "drop_frac": drop_frac.astype(jnp.float32),
    }
    return out.reshape(b, s, d), stats


# ---------------------------------------------------------------------------
# Mixtral-8x7B
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MixtralConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                  # per-expert hidden
    vocab: int
    n_experts: int = 8
    top_k: int = 2
    head_dim: int = 128
    rope_theta: float = 1e6
    window: Optional[int] = 4096   # Mixtral SWA
    decode_window: Optional[int] = 4096
    capacity_factor: float = 1.25
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    kv_chunk: int = 2048

    @property
    def family(self) -> str:
        return "moe"

    @property
    def moe(self) -> MoEConfig:
        return MoEConfig(
            n_experts=self.n_experts,
            top_k=self.top_k,
            d_model=self.d_model,
            d_ff=self.d_ff,
            capacity_factor=self.capacity_factor,
        )


def layer_schema(cfg: MixtralConfig) -> Dict[str, Any]:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "attn": {
            "wq": Param((d, h, dh), ("embed", "heads", None)),
            "wk": Param((d, kv, dh), ("embed", "kv_heads", None)),
            "wv": Param((d, kv, dh), ("embed", "kv_heads", None)),
            "wo": Param((h, dh, d), ("heads", None, "embed")),
        },
        "attn_norm": Param((d,), (None,), init="ones"),
        "mlp_norm": Param((d,), (None,), init="ones"),
        "moe": moe_layer_schema(cfg.moe),
    }


def schema(cfg: MixtralConfig) -> Dict[str, Any]:
    return {
        "embed": Param((cfg.vocab, cfg.d_model), ("vocab", None), init="embed"),
        "layers": common.stacked(layer_schema(cfg), cfg.n_layers),
        "final_norm": Param((cfg.d_model,), (None,), init="ones"),
        "lm_head": Param((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }


def init(rng: jax.Array, cfg: MixtralConfig):
    return common.init_from_schema(rng, schema(cfg), cfg.param_dtype)


def _attention(lp, x, positions, cfg: MixtralConfig, *, window_path: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"])
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    if window_path and cfg.window is not None:
        return common.local_window_attention(q, k, v, window=cfg.window)
    return common.full_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk)


def forward(
    params: Dict[str, Any], cfg: MixtralConfig, tokens: jax.Array
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (logits, moe_stats averaged over layers)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = common.constrain(x, ("batch", None, None))
    positions = jnp.arange(s)

    def body(x, lp):
        h = common.rms_norm(x, lp["attn_norm"])
        attn = _attention(lp["attn"], h, positions, cfg, window_path=True)
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["attn"]["wo"])
        h = common.rms_norm(x, lp["mlp_norm"])
        out, stats = moe_apply(lp["moe"], h, cfg.moe)
        return x + out, stats

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, stats = jax.lax.scan(body_fn, x, params["layers"])
    x = common.rms_norm(x, params["final_norm"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["lm_head"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)
    mean_stats = {k: v.mean() for k, v in stats.items()}
    return logits, mean_stats


def init_cache(cfg: MixtralConfig, batch: int, seq_len: int, dtype=None):
    if dtype is None:
        dtype = cfg.compute_dtype  # cache dtype must match decode K/V
    length = min(cfg.decode_window or seq_len, seq_len)
    return common.make_kv_cache(
        cfg.n_layers, batch, length, cfg.n_kv_heads, cfg.head_dim, dtype
    )


def decode_step(
    params: Dict[str, Any],
    cfg: MixtralConfig,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,
    pos: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    length = cache["k"].shape[2]
    ring = cfg.decode_window is not None and length == cfg.decode_window
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    positions = jnp.full((1,), pos, jnp.int32)

    def body(x, layer):
        lp, k_cache, v_cache = layer
        h = common.rms_norm(x, lp["attn_norm"])
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
        idx = pos % length if ring else pos
        k_cache, v_cache = common.cache_update(k_cache, v_cache, k, v, idx)
        attn = common.decode_attention(
            q, k_cache, v_cache, pos=pos, window=None if ring else cfg.window
        )
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["attn"]["wo"])
        h = common.rms_norm(x, lp["mlp_norm"])
        out, _ = moe_apply(lp["moe"], h, cfg.moe)
        return x + out, (k_cache, v_cache)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"])
    )
    x = common.rms_norm(x, params["final_norm"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["lm_head"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)
    return logits, {"k": new_k, "v": new_v, "pos": pos + 1}

"""Batched serving demo: prefill + decode with KV caches across the model
zoo's serving-relevant families (dense ring-cache, MLA latent cache, RWKV
O(1) state).

Prompt ingestion uses the fused full-sequence prefill where the family
supports it (``api.prefill``: one forward pass fills the KV cache) and the
stepped single-token decode loop otherwise — the same
:func:`repro.serving.engines.prefill_cache` helper the serving runtime's
real engine uses.

    python examples/serve_batched.py
"""
import time

import _common  # noqa: F401  (sys.path bootstrap)

import jax
import jax.numpy as jnp

from repro.configs import get_api
from repro.serving.engines import prefill_cache


def serve(arch: str, batch: int = 4, prompt_len: int = 24, gen: int = 12):
    api = get_api(arch, reduced=True)
    params = api.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (batch, prompt_len), 0, api.cfg.vocab)

    decode = jax.jit(api.decode_step)
    cache = api.init_cache(batch, prompt_len + gen)

    t0 = time.perf_counter()
    logits, cache = prefill_cache(api, params, cache, prompts, decode_fn=decode)
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)
    cache_desc = {k: tuple(v.shape) for k, v in cache.items() if hasattr(v, "shape") and v.ndim > 0}
    mode = "fused" if api.supports_prefill() else "stepped"
    print(f"{arch:18s} batch={batch} gen={gen}  {dt*1e3:7.1f}ms total  "
          f"(prefill {mode} {t_prefill*1e3:.1f}ms)  "
          f"first row: {list(map(int, toks[0]))[:8]}")
    for k, s in list(cache_desc.items())[:3]:
        print(f"{'':20s}cache[{k}] {s}")


def main():
    for arch in ("llama3-8b", "deepseek-v2-236b", "rwkv6-7b", "hymba-1.5b"):
        serve(arch)


if __name__ == "__main__":
    main()

"""Heterogeneous GNS tests: unbiasedness of the Eq. (10) estimators, the
Theorem 4.1 weights (paper and corrected), and the minimum-variance claim.

The Monte-Carlo setup follows the paper's regime of validity (delta method:
|G|^2 >> tr(Sigma)/b_i).  These tests document the reproduction finding that
the paper's printed covariance entries do NOT minimize variance (the Lemma
B.5 proof drops the g_j . g_l cross terms of |g|^2); the corrected entries
do.  See EXPERIMENTS.md §Reproduction-notes.
"""
import numpy as np
import pytest

from repro.core.gns import (
    GNSState,
    _a_g_matrix_corrected,
    _a_s_matrix_corrected,
    estimate_gns,
    gns_update,
    gns_weights,
    homogeneous_gns,
)


def simulate(rng, batches, n_trials, d=3000, g_norm=10.0, sigma=0.05):
    """Draw local/global gradient square-norms with known ground truth."""
    G = rng.normal(size=d)
    G *= g_norm / np.linalg.norm(G)
    B = float(sum(batches))
    out = []
    for _ in range(n_trials):
        gi = [G + rng.normal(size=d) * sigma / np.sqrt(b) for b in batches]
        g = sum((b / B) * x for b, x in zip(batches, gi))
        out.append(([float(x @ x) for x in gi], float(g @ g)))
    true_g2 = g_norm**2
    true_tr = d * sigma**2
    return out, true_g2, true_tr


BATCHES = [7, 13, 29, 51]


@pytest.fixture(scope="module")
def mc(rng):
    return simulate(rng, BATCHES, n_trials=1500)


def _estimates(mc_samples, weights):
    return np.array(
        [estimate_gns(sq, gsq, BATCHES, weights=weights)[1:] for sq, gsq in mc_samples]
    )


def test_weights_sum_to_one():
    for corrected in (True, False):
        w_g, w_s = gns_weights(BATCHES, sum(BATCHES), corrected=corrected)
        assert w_g.sum() == pytest.approx(1.0, abs=1e-9)
        assert w_s.sum() == pytest.approx(1.0, abs=1e-9)


def test_estimators_unbiased(mc):
    samples, true_g2, true_tr = mc
    w = gns_weights(BATCHES, sum(BATCHES), corrected=True)
    est = _estimates(samples, w)
    # Unbiasedness within Monte-Carlo error (3 sigma of the mean).
    se_g = est[:, 0].std() / np.sqrt(len(est))
    se_s = est[:, 1].std() / np.sqrt(len(est))
    assert abs(est[:, 0].mean() - true_g2) < 4 * se_g + 1e-3 * true_g2
    assert abs(est[:, 1].mean() - true_tr) < 4 * se_s + 1e-3 * true_tr


def test_corrected_weights_beat_plain_average(mc):
    """The minimum-variance property Theorem 4.1 claims — realized by the
    cross-term-corrected covariances."""
    samples, _, _ = mc
    w_corr = gns_weights(BATCHES, sum(BATCHES), corrected=True)
    est_corr = _estimates(samples, w_corr)
    est_hom = np.array(
        [homogeneous_gns(sq, gsq, BATCHES)[1:] for sq, gsq in samples]
    )
    assert est_corr[:, 1].var() < est_hom[:, 1].var() * 0.5  # S: large win
    assert est_corr[:, 0].var() < est_hom[:, 0].var() * 1.05  # G: no worse


def test_paper_weights_do_not_minimize_variance(mc):
    """Reproduction finding: the paper's printed A_S entries give HIGHER
    variance than plain averaging in the isotropic-noise Monte Carlo."""
    samples, _, _ = mc
    w_paper = gns_weights(BATCHES, sum(BATCHES), corrected=False)
    est_paper = _estimates(samples, w_paper)
    est_hom = np.array(
        [homogeneous_gns(sq, gsq, BATCHES)[1:] for sq, gsq in samples]
    )
    assert est_paper[:, 1].var() > est_hom[:, 1].var()


def test_corrected_covariance_matches_empirical(rng):
    """The corrected A_S/A_G entries match the empirical covariance of the
    local estimators (up to the common 4|G|^2 tr(Sigma) factor and the 1/d
    isotropy factor)."""
    d, g_norm, sigma = 4000, 10.0, 0.05
    batches = np.array(BATCHES, float)
    B = batches.sum()
    samples, _, _ = simulate(rng, BATCHES, n_trials=4000, d=d, g_norm=g_norm, sigma=sigma)
    gs, ss_ = [], []
    for sq, gsq in samples:
        sq = np.asarray(sq)
        gs.append((B * gsq - batches * sq) / (B - batches))
        ss_.append(batches * B / (B - batches) * (sq - gsq))
    unit = 4 * g_norm**2 * sigma**2  # = 4|G|^2 tr(Sigma)/d
    cov_s = np.cov(np.array(ss_).T) / unit
    a_s = _a_s_matrix_corrected(batches, B)
    # Diagonal within 15%, off-diagonal sign and magnitude.
    assert np.allclose(np.diag(cov_s), np.diag(a_s), rtol=0.15)
    off = ~np.eye(len(batches), dtype=bool)
    assert np.all(a_s[off] < 0)
    assert np.allclose(cov_s[off], a_s[off], rtol=0.5, atol=0.05 * np.abs(a_s[off]).max())


def test_corrected_weights_closed_form():
    """v_i = B - b_i is an exact null vector of the corrected A_S and maps
    to (n-1)*ones under the corrected A_G — so the optimal weights have the
    closed form w_i = (B-b_i)/((n-1)B) for both estimators."""
    b = np.array(BATCHES, float)
    B = b.sum()
    n = b.size
    v = B - b
    a_s = _a_s_matrix_corrected(b, B)
    a_g = _a_g_matrix_corrected(b, B)
    np.testing.assert_allclose(a_s @ v, 0.0, atol=1e-9)
    np.testing.assert_allclose(a_g @ v, (n - 1) * np.ones(n), rtol=1e-12)
    w_g, w_s = gns_weights(BATCHES, B, corrected=True)
    np.testing.assert_allclose(w_g, v / ((n - 1) * B))
    np.testing.assert_allclose(w_s, v / ((n - 1) * B))


def test_homogeneous_reduces_to_average():
    """Equal batches -> optimal weights are the plain average (paper §4.4)."""
    w_g, w_s = gns_weights([32, 32, 32, 32], 128, corrected=True)
    assert np.allclose(w_g, 0.25, atol=1e-9)
    assert np.allclose(w_s, 0.25, atol=1e-9)


def test_gns_state_ema_and_efficiency():
    state = GNSState()
    for _ in range(50):
        state = gns_update(state, g=4.0, s=400.0, decay=0.9)
    assert state.b_noise == pytest.approx(100.0, rel=1e-6)
    # efficiency decreasing in batch, 1 at B -> inf... relative form:
    e_small = state.efficiency(10)
    e_big = state.efficiency(1000)
    assert 0 < e_small < e_big <= 1.0


def test_gns_weights_validation():
    with pytest.raises(ValueError):
        gns_weights([0, 4], 4)
    with pytest.raises(ValueError):
        gns_weights([4, 4], 4)

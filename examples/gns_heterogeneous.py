"""Heterogeneous gradient-noise-scale estimation demo (§4.4 / Theorem 4.1).

    python examples/gns_heterogeneous.py

Draws synthetic per-node gradients with known |G|^2 and tr(Sigma), then
compares three aggregations of the Eq. (10) local estimators:
  * plain averaging (the homogeneous baseline AdaptDL/Pollux would use),
  * the paper's printed Theorem 4.1 weights,
  * the cross-term-corrected closed form w_i = (B - b_i)/((n-1)B)
    (this repo's correction — zero leading-order variance for tr(Sigma)).
"""
import _common  # noqa: F401  (sys.path bootstrap)

import numpy as np

from repro.core.gns import estimate_gns, gns_weights, homogeneous_gns

BATCHES = [7, 13, 29, 51]
TRIALS = 2000


def main():
    rng = np.random.default_rng(0)
    d, g_norm, sigma = 4000, 10.0, 0.05
    G = rng.normal(size=d)
    G *= g_norm / np.linalg.norm(G)
    B = float(sum(BATCHES))
    true_b_noise = (d * sigma**2) / g_norm**2

    w_corr = gns_weights(BATCHES, B, corrected=True)
    w_paper = gns_weights(BATCHES, B, corrected=False)
    print("corrected weights:", np.round(w_corr[1], 4))
    print("paper weights    :", np.round(w_paper[1], 4))

    rows = {"corrected": [], "paper": [], "average": []}
    for _ in range(TRIALS):
        gi = [G + rng.normal(size=d) * sigma / np.sqrt(b) for b in BATCHES]
        g = sum((b / B) * x for b, x in zip(BATCHES, gi))
        sq = [float(x @ x) for x in gi]
        gsq = float(g @ g)
        rows["corrected"].append(estimate_gns(sq, gsq, BATCHES, weights=w_corr))
        rows["paper"].append(estimate_gns(sq, gsq, BATCHES, weights=w_paper))
        rows["average"].append(homogeneous_gns(sq, gsq, BATCHES))

    print(f"\ntrue: |G|^2={g_norm**2:.2f}  tr(Sigma)={d*sigma**2:.2f}  "
          f"B_noise={true_b_noise:.4f}\n")
    print(f"{'method':10s} {'E[G]':>8s} {'E[S]':>8s} {'var(S)':>10s} {'E[B_noise]':>11s}")
    for name, vals in rows.items():
        arr = np.array([(g, s, bn) for bn, g, s in vals])
        print(f"{name:10s} {arr[:,0].mean():8.3f} {arr[:,1].mean():8.3f} "
              f"{arr[:,1].var():10.4f} {arr[:,2].mean():11.4f}")


if __name__ == "__main__":
    main()

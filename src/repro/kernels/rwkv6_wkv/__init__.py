from repro.kernels.rwkv6_wkv.ops import wkv
from repro.kernels.rwkv6_wkv.ref import wkv_ref

__all__ = ["wkv", "wkv_ref"]

"""BatchSizePolicy protocol: registry, damper laws, LR-rule coupling,
bit-identity of the cannikin-gns path with the pre-protocol controller,
per-job policy selection in the runtime, and policy state riding the
preemption checkpoint path bit-exactly."""
import dataclasses
import math

import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st
from repro.core.batch_policy import (
    BATCH_POLICIES,
    BatchBounds,
    BatchProposal,
    PolicyTelemetry,
    lr_scale_for,
    make_batch_policy,
    policy_requirements,
    register_batch_policy,
)
from repro.core.controller import CannikinController
from repro.core.goodput import BatchSizeSelector, adascale_gain, sqrt_lr_scale
from repro.core.optperf import round_batches
from repro.core.scheduler import random_jobs
from repro.core.simulator import SimulatedCluster, cluster_A
from repro.launch.train import hetero_adaptive
from repro.runtime import (
    ClusterRuntime,
    EpochLoop,
    JobState,
    SimBackend,
    compare_policies,
    make_partition_policy,
    rank_batch_policies,
    replay,
    synthetic_trace,
)


REGISTERED = ("cannikin-gns", "fixed", "adadamp", "padadamp", "geodamp")


def _telemetry(epoch=0, total=64, loss=float("nan"), b_noise=float("inf")):
    return PolicyTelemetry(
        epoch=epoch, total_batch=total, mean_loss=loss, b_noise=b_noise
    )


@pytest.fixture(scope="module")
def perf_model():
    """A learned ClusterPerfModel to propose against (cannikin-gns needs
    one for its selector sweep; dampers ignore it)."""
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.0, seed=0)
    return sim.true_model()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_ships_the_policy_zoo():
    assert set(REGISTERED) <= set(BATCH_POLICIES)
    assert len(BATCH_POLICIES) >= 4


def test_policy_requirements():
    assert policy_requirements("cannikin-gns") == frozenset({"gns"})
    assert policy_requirements("adadamp") == frozenset({"loss"})
    assert policy_requirements("geodamp") == frozenset()
    assert policy_requirements("padadamp") == frozenset()
    assert policy_requirements("fixed") == frozenset()
    with pytest.raises(ValueError):
        policy_requirements("nope")


def test_make_batch_policy_unknown_name():
    with pytest.raises(ValueError):
        make_batch_policy("nope", candidates=[64], ref_batch=64)


def test_make_batch_policy_rejects_unknown_kwargs():
    with pytest.raises(TypeError):
        make_batch_policy("geodamp", candidates=[64], ref_batch=64, bogus=1)


def test_make_batch_policy_drops_selector_for_damper():
    sel = BatchSizeSelector(candidates=(64,), ref_batch=64)
    pol = make_batch_policy("geodamp", candidates=[64], ref_batch=64, selector=sel)
    assert pol.name == "geodamp"


def test_register_batch_policy_is_the_extension_hook():
    @register_batch_policy
    class EchoPolicy:
        name = "test-echo"
        requires = frozenset()
        lr_rule = "none"

        def __init__(self, *, candidates, ref_batch):
            self.ref_batch = ref_batch

        def observe(self, telemetry):
            pass

        def propose(self, model, bounds):
            return BatchProposal(total_batch=self.ref_batch, lr_scale=1.0)

        def state(self):
            return {}

        def load_state(self, state):
            pass

    try:
        pol = make_batch_policy("test-echo", candidates=[32], ref_batch=32)
        assert pol.propose(None, BatchBounds(32, 32)).total_batch == 32
    finally:
        del BATCH_POLICIES["test-echo"]


# ---------------------------------------------------------------------------
# LR-rule coupling (satellite: explicit, per-policy, overridable)
# ---------------------------------------------------------------------------


def test_lr_scale_for_rules():
    assert lr_scale_for("adascale", batch=128, ref_batch=64) == adascale_gain(
        float("inf"), 128, 64
    )
    assert lr_scale_for(
        "adascale", batch=128, ref_batch=64, b_noise=100.0
    ) == adascale_gain(100.0, 128, 64)
    assert lr_scale_for("sqrt", batch=256, ref_batch=64) == sqrt_lr_scale(256, 64)
    assert lr_scale_for("linear", batch=128, ref_batch=64) == 2.0
    assert lr_scale_for("none", batch=4096, ref_batch=64) == 1.0
    with pytest.raises(ValueError):
        lr_scale_for("cosine", batch=64, ref_batch=64)


def test_each_policy_pins_its_own_lr_rule():
    defaults = {
        "cannikin-gns": "adascale",
        "fixed": "adascale",
        "adadamp": "none",
        "padadamp": "sqrt",
        "geodamp": "linear",
    }
    for name, rule in defaults.items():
        pol = make_batch_policy(name, candidates=[64, 128], ref_batch=64)
        assert pol.lr_rule == rule, name


def test_lr_rule_override_changes_the_proposal(perf_model):
    bounds = BatchBounds(64, 512)
    geo = make_batch_policy(
        "geodamp", candidates=[64, 512], ref_batch=64, delay=1, lr_rule="sqrt"
    )
    assert geo.lr_rule == "sqrt"
    for e in range(3):
        geo.observe(_telemetry(epoch=e))
    prop = geo.propose(perf_model, bounds)
    assert prop.lr_scale == sqrt_lr_scale(prop.total_batch, 64)


def test_invalid_lr_rule_rejected_at_construction():
    with pytest.raises(ValueError):
        make_batch_policy("geodamp", candidates=[64], ref_batch=64, lr_rule="cosine")


def test_proposal_lr_matches_declared_rule(perf_model):
    """The (total_batch, lr_scale) pair is internally consistent for every
    registered policy: lr_scale is exactly the declared rule applied to the
    proposed batch."""
    bounds = BatchBounds(32, 1024)
    for name in REGISTERED:
        pol = make_batch_policy(name, candidates=[32, 64, 128, 256], ref_batch=32)
        for e in range(4):
            pol.observe(_telemetry(epoch=e, loss=2.0, b_noise=500.0))
        prop = pol.propose(perf_model, bounds)
        expected = lr_scale_for(
            pol.lr_rule,
            batch=prop.total_batch,
            ref_batch=32,
            b_noise=getattr(pol, "b_noise", float("inf")),
        )
        assert prop.lr_scale == expected, name


# ---------------------------------------------------------------------------
# damper laws
# ---------------------------------------------------------------------------


def test_geodamp_law():
    pol = make_batch_policy(
        "geodamp", candidates=[64, 4096], ref_batch=64, factor=2.0, delay=2
    )
    bounds = BatchBounds(1, 4096)
    seen = []
    for e in range(6):
        pol.observe(_telemetry(epoch=e))
        seen.append(pol.propose(None, bounds).total_batch)
    # updates = 1..6 -> 64*2^(k//2) = 64, 128, 128, 256, 256, 512
    assert seen == [64, 128, 128, 256, 256, 512]


def test_padadamp_law():
    pol = make_batch_policy(
        "padadamp", candidates=[64, 4096], ref_batch=64, rate=10.0
    )
    bounds = BatchBounds(1, 4096)
    seen = []
    for e in range(4):
        pol.observe(_telemetry(epoch=e))
        seen.append(pol.propose(None, bounds).total_batch)
    # updates = 1..4 -> 64 + ceil(10k) = 74, 84, 94, 104
    assert seen == [74, 84, 94, 104]


def test_adadamp_law_tracks_loss_ratio():
    pol = make_batch_policy("adadamp", candidates=[64, 4096], ref_batch=64)
    bounds = BatchBounds(1, 4096)
    pol.observe(_telemetry(epoch=0, loss=4.0))
    assert pol.propose(None, bounds).total_batch == 64  # L0 == Lk
    pol.observe(_telemetry(epoch=1, loss=2.0))
    assert pol.propose(None, bounds).total_batch == 128  # ceil(64 * 4/2)
    pol.observe(_telemetry(epoch=2, loss=8.0))
    assert pol.propose(None, bounds).total_batch == 64  # loss rose: floor at start


def test_adadamp_degrades_gracefully_without_loss():
    """NaN losses (sim backend) hold the batch at start instead of blowing
    up — the 'requires loss' policy stays safe on the wrong backend."""
    pol = make_batch_policy("adadamp", candidates=[64, 4096], ref_batch=64)
    bounds = BatchBounds(1, 4096)
    for e in range(5):
        pol.observe(_telemetry(epoch=e, loss=float("nan")))
        assert pol.propose(None, bounds).total_batch == 64


def test_fixed_policy_is_stateless_and_proposes_ref():
    pol = make_batch_policy("fixed", candidates=[64, 128], ref_batch=128)
    assert pol.state() == {}  # keeps legacy sim preemption snapshots empty
    prop = pol.propose(None, BatchBounds(64, 128))
    assert prop.total_batch == 128
    assert prop.lr_scale == 1.0  # adascale_gain(B0, B0) == 1 always


# ---------------------------------------------------------------------------
# protocol invariants — deterministic sweep + hypothesis property tests
# ---------------------------------------------------------------------------


def _invariant_check(name, ref, hi, epochs, perf_model):
    candidates = sorted({ref, 2 * ref, hi})
    pol = make_batch_policy(name, candidates=candidates, ref_batch=ref)
    bounds = BatchBounds(min(candidates), max(candidates))
    monotone = name in ("geodamp", "padadamp")
    prev_total = None
    for e in range(epochs):
        pol.observe(_telemetry(epoch=e, loss=3.0 / (e + 1), b_noise=1000.0))
        prop = pol.propose(perf_model, bounds)
        # (1) proposals always inside [min, max]
        assert bounds.min_total <= prop.total_batch <= bounds.max_total, name
        # (2) monotone schedules never decrease
        if monotone and prev_total is not None:
            assert prop.total_batch >= prev_total, name
        prev_total = prop.total_batch
        # (3) lr_scale is finite and positive
        assert math.isfinite(prop.lr_scale) and prop.lr_scale > 0, name
    # (4) state()/load_state() round-trips bit-exactly (NaN-aware)
    saved = pol.state()
    twin = make_batch_policy(name, candidates=candidates, ref_batch=ref)
    twin.load_state(saved)
    reloaded = twin.state()
    assert set(reloaded) == set(saved), name
    for key in saved:
        np.testing.assert_array_equal(
            np.asarray(saved[key]), np.asarray(reloaded[key]), err_msg=f"{name}.{key}"
        )
        assert np.asarray(saved[key]).dtype == np.asarray(reloaded[key]).dtype
    # ...and the twin proposes exactly what the original would
    assert (
        twin.propose(perf_model, bounds).total_batch
        == pol.propose(perf_model, bounds).total_batch
    ), name


def test_every_registered_policy_respects_invariants(perf_model):
    for name in sorted(BATCH_POLICIES):
        _invariant_check(name, ref=64, hi=512, epochs=6, perf_model=perf_model)


@hypothesis.given(
    name=st.sampled_from(sorted(REGISTERED)),
    ref=st.integers(min_value=1, max_value=256),
    hi=st.integers(min_value=256, max_value=4096),
    epochs=st.integers(min_value=1, max_value=10),
)
@hypothesis.settings(max_examples=40, deadline=None)
def test_policy_invariants_property(name, ref, hi, epochs, perf_model):
    _invariant_check(name, ref=ref, hi=hi, epochs=epochs, perf_model=perf_model)


def test_controller_rounded_batches_sum_to_proposed_total():
    """Through the controller, every plan's rounded local batches sum to
    the policy's proposed total and respect the local bounds."""
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.0, seed=0)
    ctrl = CannikinController(
        sim.n,
        batch_candidates=[64, 128, 256, 512],
        ref_batch=64,
        batch_policy="geodamp",
        policy_kwargs={"delay": 1},
        min_local=2,
        max_local=400,
    )
    for _ in range(6):
        plan = ctrl.plan_epoch()
        assert sum(plan.batches) == plan.total_batch
        assert all(2 <= b <= 400 for b in plan.batches)
        _, ms = sim.run_epoch(list(plan.batches), 3)
        ctrl.observe_epoch(ms)


# ---------------------------------------------------------------------------
# cannikin-gns bit-identity with the pre-protocol controller path
# ---------------------------------------------------------------------------


def test_cannikin_gns_lockstep_with_legacy_selector_path():
    """Shadow-replicate the pre-protocol plan_epoch computation (its exact
    operation order: selector.select -> round_batches -> _apply_bounds ->
    adascale_gain) with an independent BatchSizeSelector, and assert the
    refactored controller's plans are bit-identical every epoch."""
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.005, seed=0)
    candidates = (64, 128, 256, 512)
    ctrl = CannikinController(
        sim.n, batch_candidates=list(candidates), ref_batch=64
    )
    assert ctrl.policy.name == "cannikin-gns"  # the default adaptive law
    shadow = BatchSizeSelector(
        candidates=candidates, ref_batch=64, solver="algorithm1",
        engine="batched", warm_drift_limit=0.25,
    )
    optperf_epochs = 0
    for _ in range(6):
        expected = None
        if ctrl.can_model():
            try:
                model = ctrl.cluster_model()
            except ValueError:
                model = None
            if model is not None:
                b_noise = ctrl.gns.b_noise
                best, sol, _ = shadow.select(model, b_noise)
                batches = ctrl._apply_bounds(
                    round_batches(list(sol.batches), best), best
                )
                expected = (
                    int(best),
                    tuple(batches),
                    adascale_gain(b_noise, best, 64),
                    sol.opt_perf,
                )
        plan = ctrl.plan_epoch()
        if expected is not None:
            assert plan.phase == "optperf"
            assert plan.batch_policy == "cannikin-gns"
            got = (
                plan.total_batch,
                plan.batches,
                plan.lr_scale,
                plan.predicted_batch_time,
            )
            assert got == expected  # bit-identical, not approximately
            optperf_epochs += 1
        _, ms = sim.run_epoch(list(plan.batches), 4)
        ctrl.observe_epoch(ms)
        ctrl.observe_gradients([10.0] * sim.n, 2.0, list(plan.batches))
    assert optperf_epochs >= 3  # the lockstep actually exercised optperf
    # ...and the shared-selector discipline held: the controller's stats
    # mirror its own selector, which saw exactly what the shadow saw.
    assert ctrl.stats.full_sweeps == shadow.full_sweeps
    assert ctrl.stats.warm_sweeps == shadow.warm_sweeps
    assert ctrl.stats.cold_sweeps == shadow.cold_sweeps


def test_non_adaptive_controller_uses_fixed_policy():
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.005, seed=0)
    ctrl = CannikinController(
        sim.n, batch_candidates=[128], ref_batch=128, adaptive=False
    )
    assert ctrl.policy.name == "fixed"
    for _ in range(4):
        plan = ctrl.plan_epoch()
        assert plan.total_batch == 128
        assert plan.lr_scale == 1.0
        _, ms = sim.run_epoch(list(plan.batches), 3)
        ctrl.observe_epoch(ms)
    assert ctrl.last_plan.phase == "optperf"
    assert ctrl.last_plan.batch_policy == "fixed"


def test_bootstrap_plan_has_no_policy_provenance():
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.0, seed=0)
    ctrl = CannikinController(sim.n, batch_candidates=[64], ref_batch=64)
    plan = ctrl.plan_epoch()
    assert plan.phase == "bootstrap"
    assert plan.batch_policy is None


# ---------------------------------------------------------------------------
# runtime: per-job policy selection via JobSpec.batch_policy
# ---------------------------------------------------------------------------


def test_runtime_gns_policy_on_sim_backend_forces_fixed():
    """GNS-driven policies need gradients; on the sim backend the runtime
    collapses them to the fixed controller (the satellite-1 guard at the
    runtime layer), so plans stay at the spec total."""
    spec = dataclasses.replace(random_jobs(1, 6, seed=2)[0], batch_policy="cannikin-gns")
    rt = ClusterRuntime(6, policy="cannikin")
    h = rt.submit(spec, at=0.0)
    rt.run()
    assert h.controller.adaptive is False
    assert h.controller.policy.name == "fixed"
    rt.advance(3, steps=2)
    assert all(rec.total_batch == spec.total_batch for rec in h.records)


def test_runtime_geodamp_adapts_on_sim_backend():
    """Schedule-driven dampers make adaptive batch sizes meaningful on
    SimBackend — totals actually ramp with zero gradient telemetry."""
    spec = dataclasses.replace(
        random_jobs(1, 6, seed=2)[0], batch_policy="geodamp"
    )
    rt = ClusterRuntime(6, policy="cannikin")
    h = rt.submit(spec, at=0.0)
    rt.run()
    assert h.controller.adaptive is True
    assert h.controller.policy.name == "geodamp"
    rt.advance(8, steps=2)
    totals = [rec.total_batch for rec in h.records]
    assert totals == sorted(totals)  # monotone ramp
    assert totals[-1] > totals[0]    # and it actually moved
    optperf = [rec for rec in h.records if rec.phase == "optperf"]
    assert optperf and all(rec.plan.batch_policy == "geodamp" for rec in optperf)


def test_runtime_default_spec_unchanged():
    """batch_policy=None keeps the historical per-backend defaults."""
    spec = random_jobs(1, 6, seed=2)[0]
    assert spec.batch_policy is None
    rt = ClusterRuntime(6, policy="cannikin")
    h = rt.submit(spec, at=0.0)
    rt.run()
    assert h.controller.adaptive is False
    assert h.controller.policy.name == "fixed"


# ---------------------------------------------------------------------------
# preemption: policy state rides the checkpoint path bit-exactly
# ---------------------------------------------------------------------------


def test_policy_state_survives_preemption_checkpoint(tmp_path):
    spec = dataclasses.replace(
        random_jobs(1, 6, seed=3)[0], batch_policy="geodamp"
    )
    rt = ClusterRuntime(6, policy="cannikin", checkpoint_dir=str(tmp_path))
    h = rt.submit(spec, at=0.0)
    rt.run()
    rt.advance(5, steps=2)
    saved = {k: np.asarray(v).copy() for k, v in h.controller.policy.state().items()}
    assert saved["updates"] > 0

    rt.preempt(spec.name, at=1.0)
    rt.run()
    assert h.state == JobState.PREEMPTED
    assert h.checkpoint_path is not None  # the damper state forced a write

    # Scramble the live policy: restore must rebuild it from the file.
    h.controller.policy.load_state({"updates": np.int64(9999)})
    rt.submit(spec, at=2.0)
    rt.run()
    assert h.state == JobState.RUNNING
    assert h.restores == 1
    restored = h.controller.policy.state()
    assert set(restored) == set(saved)
    for key in saved:
        np.testing.assert_array_equal(np.asarray(restored[key]), saved[key])

    # The schedule resumes where it left off, not from scratch.
    rt.advance(1, steps=2)
    assert int(h.controller.policy.state()["updates"]) == int(saved["updates"]) + 1


def test_preempt_resume_matches_unpreempted_twin():
    """In-memory snapshot path: a geodamp job preempted and resumed plans
    the same total-batch ramp as a twin that never lost its nodes."""
    spec = dataclasses.replace(random_jobs(1, 6, seed=3)[0], batch_policy="geodamp")

    rt_a = ClusterRuntime(6, policy="cannikin")
    h_a = rt_a.submit(spec, at=0.0)
    rt_a.run()
    rt_a.advance(4, steps=2)
    rt_a.preempt(spec.name, at=1.0)
    rt_a.run()
    rt_a.submit(spec, at=2.0)
    rt_a.run()
    rt_a.advance(4, steps=2)

    rt_b = ClusterRuntime(6, policy="cannikin")
    h_b = rt_b.submit(spec, at=0.0)
    rt_b.run()
    rt_b.advance(8, steps=2)

    totals_a = [rec.total_batch for rec in h_a.records]
    totals_b = [rec.total_batch for rec in h_b.records]
    assert totals_a == totals_b


def test_sim_fixed_policy_snapshot_stays_empty():
    """Legacy sim jobs (fixed policy, stateless) must write no snapshot on
    preemption — byte-identical to the pre-protocol runtime."""
    spec = random_jobs(1, 6, seed=3)[0]
    rt = ClusterRuntime(6, policy="cannikin")
    h = rt.submit(spec, at=0.0)
    rt.run()
    rt.advance(2, steps=2)
    rt.preempt(spec.name, at=1.0)
    rt.run()
    assert h._snapshot is None
    assert h.checkpoint_path is None


# ---------------------------------------------------------------------------
# launch guard (satellite 1) + partition-policy passthrough
# ---------------------------------------------------------------------------


def test_hetero_adaptive_guard_branches():
    # real backend: adaptive unless --fixed-batch
    assert hetero_adaptive("real", False, None) is True
    assert hetero_adaptive("real", False, "cannikin-gns") is True
    assert hetero_adaptive("real", True, None) is False
    # sim backend: GNS-dependent laws stay forced-fixed...
    assert hetero_adaptive("sim", False, None) is False
    assert hetero_adaptive("sim", False, "cannikin-gns") is False
    # ...but gradient-free dampers run adaptively
    assert hetero_adaptive("sim", False, "geodamp") is True
    assert hetero_adaptive("sim", False, "padadamp") is True
    assert hetero_adaptive("sim", False, "adadamp") is True
    # --fixed-batch always wins
    assert hetero_adaptive("sim", True, "geodamp") is False


def test_epoch_loop_sim_geodamp_adapts():
    """The full launch path: EpochLoop over SimBackend with a damper — the
    PR-5 restriction is lifted for gradient-free policies."""
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.01, seed=0)
    policy = make_partition_policy(
        "cannikin",
        sim.n,
        candidates=[64, 128, 256, 512],
        ref_batch=64,
        adaptive=hetero_adaptive("sim", False, "geodamp"),
        batch_policy="geodamp",
    )
    loop = EpochLoop(policy, SimBackend(cluster=sim), steps_per_epoch=3, fixed_total=64)
    for _ in range(8):
        loop.run_epoch()
    totals = [r.total_batch for r in loop.history]
    assert totals[-1] > totals[0]
    assert totals == sorted(totals)


# ---------------------------------------------------------------------------
# cross-policy trace report (the acceptance scenario)
# ---------------------------------------------------------------------------


def test_compare_policies_batch_policy_axis_ranks_the_zoo():
    trace, _jobs = synthetic_trace(2, 6, seed=0)
    reports = compare_policies(
        trace, 6, batch_policies=(), epochs_per_event=2, steps=2,
        noise=0.01, seed=0,
    )
    assert set(REGISTERED) <= set(reports)
    ranking = rank_batch_policies(reports)
    assert len(ranking) >= 4
    goodputs = [row["policy_goodput"] for row in ranking]
    assert goodputs == sorted(goodputs, reverse=True)
    for row in ranking:
        assert 0.0 < row["statistical_efficiency"] <= 1.0
        assert row["sample_throughput"] > 0.0
        assert row["epochs"] > 0
    # the ranking keys carry the goodput decomposition
    by_name = {row["batch_policy"]: row for row in ranking}
    # cannikin-gns collapses to fixed on the sim backend -> identical replays
    assert (
        by_name["cannikin-gns"]["policy_goodput"] == by_name["fixed"]["policy_goodput"]
    )
    # dampers actually moved the batch
    assert by_name["geodamp"]["mean_total_batch"] > by_name["adadamp"]["mean_total_batch"]


def test_batch_policy_summary_keys_are_conditional():
    trace, _jobs = synthetic_trace(1, 4, seed=0)
    plain = replay(trace, 4, epochs_per_event=1, steps=2, seed=0)
    stamped = replay(
        trace, 4, epochs_per_event=1, steps=2, seed=0, batch_policy="geodamp"
    )
    assert "batch_policy" not in plain.summary()  # golden summaries untouched
    s = stamped.summary()
    assert s["batch_policy"] == "geodamp"
    for key in ("sample_throughput", "statistical_efficiency",
                "policy_goodput", "mean_total_batch"):
        assert key in s

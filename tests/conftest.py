import numpy as np
import pytest

# NOTE: do NOT set XLA_FLAGS here — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py fakes 512 devices (in a
# separate process).


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

"""The paper's end-to-end scenario: adaptive-batch-size training on a
heterogeneous cluster — Cannikin vs PyTorch-DDP-even vs LB-BSP.

    python examples/hetero_cluster_training.py

Real JAX training of a reduced OLMo on synthetic data; per-node wall-clock
from the calibrated cluster-B simulator (4x A100 + 4x V100 + 8x RTX6000).
Policies come from the runtime's shared partition-policy factory
(``repro.runtime.make_partition_policy``).  Prints per-epoch partitions,
OptPerf predictions vs measurements, and the final simulated time-to-loss
comparison (Fig. 7/8 analogue).
"""
import _common  # noqa: F401  (sys.path bootstrap)

from repro.configs import get_api
from repro.core import SimulatedCluster, cluster_B
from repro.data import SyntheticLM
from repro.optim import constant_schedule, sgd
from repro.runtime import make_partition_policy
from repro.train import HeteroTrainer

TARGET_LOSS = 3.5
REF_BATCH = 64


def build(policy_name: str):
    api = get_api("olmo-1b", reduced=True)
    profiles, comm = cluster_B()
    sim = SimulatedCluster(profiles, comm, noise=0.01, seed=0)
    data = SyntheticLM(vocab=api.cfg.vocab, seq_len=24, seed=0)
    policy = make_partition_policy(
        policy_name,
        sim.n,
        candidates=[REF_BATCH, REF_BATCH * 2, REF_BATCH * 4],
        ref_batch=REF_BATCH,
    )
    tr = HeteroTrainer(api, sgd(constant_schedule(0.3)), sim, policy, data,
                       steps_per_epoch=4)
    tr.set_fixed_total(REF_BATCH)
    return tr


def main():
    wall = {}
    for name in ("cannikin", "even", "lb-bsp"):
        tr = build(name)
        print(f"\n=== policy: {name} ===")
        for _ in range(16):
            r = tr.run_epoch()
            pred = "-" if r.predicted_batch_time is None else f"{r.predicted_batch_time*1e3:6.1f}ms"
            print(f"  ep{r.epoch:2d} [{r.phase:9s}] B={r.total_batch:4d} "
                  f"split={list(r.batches)[:4]}... loss={r.mean_loss:.3f} "
                  f"t={r.measured_batch_time*1e3:6.1f}ms pred={pred}")
            if r.mean_loss <= TARGET_LOSS:
                break
        wall[name] = tr.sim_time
        print(f"  simulated wall-clock to loss<={TARGET_LOSS}: {tr.sim_time:.2f}s")

    base = wall["even"]
    print("\n=== time-to-target (normalized to DDP-even) ===")
    for name, t in wall.items():
        print(f"  {name:10s} {t:7.2f}s  ({t/base:5.1%})")


if __name__ == "__main__":
    main()

"""Trace building + replay: synthetic multi-job workloads over the runtime.

A :class:`Trace` is an ordered bag of events (built fluently or passed
in), replayable through a fresh :class:`ClusterRuntime` per policy — the
Pollux/Sia-style cluster simulation: job arrivals and departures, node
churn, model refits, preemptions, with simulated training epochs between
events.  :func:`compare_policies` replays one trace under every
allocation policy and returns comparable :class:`TraceReport`s.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batch_policy import BATCH_POLICIES
from repro.core.goodput import statistical_efficiency
from repro.core.scheduler import JobSpec, random_jobs
from repro.runtime.backend import RealBackendConfig
from repro.runtime.events import (
    Event,
    JobArrival,
    JobCompletion,
    ModelRefit,
    NodeJoin,
    NodeLeave,
    Preemption,
    describe,
)
from repro.runtime.runtime import ClusterRuntime, ReconcileRecord

__all__ = [
    "Trace",
    "TraceReport",
    "replay",
    "compare_policies",
    "synthetic_trace",
    "format_summary",
    "rank_batch_policies",
    "format_batch_policy_summary",
]


class Trace:
    """Fluent builder over the event alphabet.

    >>> trace = (Trace()
    ...          .arrive(spec_a, at=0.0)
    ...          .arrive(spec_b, at=1.0)
    ...          .complete("a", at=3.0)
    ...          .node_leave([7], at=4.0))

    Events are immutable and the builder holds no runtime state, so one
    trace replays under any number of runtimes/policies.
    """

    def __init__(self, events: Sequence[Event] = ()) -> None:
        self.events: List[Event] = list(events)

    def post(self, event: Event) -> "Trace":
        self.events.append(event)
        return self

    def arrive(self, spec: JobSpec, at: float = 0.0) -> "Trace":
        return self.post(JobArrival(time=at, spec=spec))

    def complete(self, job: str, at: float) -> "Trace":
        return self.post(JobCompletion(time=at, job=job))

    def preempt(self, job: str, at: float) -> "Trace":
        return self.post(Preemption(time=at, job=job))

    def refit(self, job: str, at: float, *, rel: float = 0.1, seed: int = 0) -> "Trace":
        return self.post(ModelRefit(time=at, job=job, rel=rel, seed=seed))

    def node_leave(self, nodes: Sequence[int], at: float) -> "Trace":
        return self.post(NodeLeave(time=at, nodes=tuple(nodes)))

    def node_join(self, nodes: Sequence[int], at: float) -> "Trace":
        return self.post(NodeJoin(time=at, nodes=tuple(nodes)))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)


@dataclasses.dataclass
class TraceReport:
    """What one policy did with one trace — the comparable unit.

    ``baseline`` (set only by fault-injecting replays) is the fault-free
    twin of the same trace, enabling :attr:`goodput_retention` — the
    cost of the faults plus recovery in retained training throughput.

    ``batch_policy`` (set by batch-policy-stamped replays) names the
    :mod:`repro.core.batch_policy` law every job ran under; it unlocks the
    cross-policy metrics — :attr:`sample_throughput`,
    :attr:`statistical_efficiency` and their product
    :attr:`policy_goodput` — that :func:`rank_batch_policies` sorts on.
    """

    policy: str
    records: List[ReconcileRecord]
    runtime: ClusterRuntime
    baseline: Optional["TraceReport"] = None
    batch_policy: Optional[str] = None

    @property
    def aggregate_goodput(self) -> float:
        return self.runtime.allocation.aggregate_goodput

    @property
    def aggregate_fraction(self) -> float:
        return self.runtime.allocation.aggregate_fraction

    @property
    def job_states(self) -> Dict[str, str]:
        return {name: h.state for name, h in self.runtime.handles.items()}

    @property
    def epochs(self) -> Dict[str, int]:
        return {name: h.epochs_run for name, h in self.runtime.handles.items()}

    @property
    def total_sim_time(self) -> float:
        """Simulated seconds of training across all jobs."""
        return sum(h.sim_time for h in self.runtime.handles.values())

    # -- batch-policy comparison metrics --------------------------------

    def _epoch_records(self):
        for handle in self.runtime.handles.values():
            for rec in handle.records:
                yield handle, rec

    @property
    def mean_total_batch(self) -> float:
        """Mean planned total batch over every executed epoch — shows
        whether (and how far) an adaptive policy actually moved the batch."""
        totals = [rec.total_batch for _, rec in self._epoch_records()]
        return float(np.mean(totals)) if totals else 0.0

    @property
    def sample_throughput(self) -> float:
        """Training samples processed per simulated second, across jobs:
        Σ (total_batch × steps_in_epoch) / total sim time."""
        samples = 0.0
        for _, rec in self._epoch_records():
            if rec.measured_batch_time > 0:
                steps = round(rec.epoch_seconds / rec.measured_batch_time)
                samples += rec.total_batch * steps
        sim_time = self.total_sim_time
        return float(samples / sim_time) if sim_time > 0 else 0.0

    @property
    def statistical_efficiency(self) -> float:
        """Mean per-epoch statistical efficiency E(B) (Pollux/§2 — how much
        of each sample's gradient signal the batch size preserves), using
        the epoch's measured gradient-noise scale when the backend tracked
        one and the spec's prior ``b_noise`` otherwise (sim traces), so the
        metric ranks policies on both backends."""
        effs = []
        for handle, rec in self._epoch_records():
            b_noise = rec.b_noise if math.isfinite(rec.b_noise) else handle.spec.b_noise
            effs.append(
                float(
                    statistical_efficiency(
                        b_noise, rec.total_batch, handle.spec.ref_batch
                    )
                )
            )
        return float(np.mean(effs)) if effs else 0.0

    @property
    def policy_goodput(self) -> float:
        """The paper's goodput decomposition applied to the whole replay:
        sample throughput × statistical efficiency — the scalar
        :func:`rank_batch_policies` orders policies by."""
        return self.sample_throughput * self.statistical_efficiency

    @property
    def goodput_retention(self) -> Optional[float]:
        """Fault-free sim-time over faulted sim-time for the same trace:
        1.0 means the faults cost nothing; 0.5 means epochs took twice as
        long end-to-end (stalls + slowdowns + recovery overhead).

        NaN-safe by construction: a degenerate twin (zero sim-time on
        either side — e.g. a trace whose jobs never advanced an epoch)
        yields a defined value with a warning instead of 0/0 = NaN
        poisoning downstream sweep aggregation."""
        if self.baseline is None:
            return None
        faulted = self.total_sim_time
        fault_free = self.baseline.total_sim_time
        if faulted <= 0.0:
            if fault_free <= 0.0:
                warnings.warn(
                    "goodput_retention: both faulted and fault-free replays "
                    "accumulated zero sim-time (no epochs advanced?); "
                    "reporting 1.0 instead of 0/0",
                    RuntimeWarning,
                    stacklevel=2,
                )
                return 1.0
            warnings.warn(
                "goodput_retention: faulted replay accumulated zero sim-time "
                "while the fault-free twin trained; reporting 0.0",
                RuntimeWarning,
                stacklevel=2,
            )
            return 0.0
        if fault_free <= 0.0:
            warnings.warn(
                "goodput_retention: fault-free twin accumulated zero sim-time "
                "while the faulted replay trained; reporting 0.0",
                RuntimeWarning,
                stacklevel=2,
            )
            return 0.0
        return fault_free / faulted

    def summary(self) -> Dict[str, object]:
        """JSON-able one-policy summary (assignment, scores, counters).
        Grows a ``faults`` block only for fault-tolerant runtimes, so
        golden-path summaries are byte-identical to earlier releases."""
        out: Dict[str, object] = {
            "policy": self.policy,
            "events": [describe(r.event) for r in self.records],
            "aggregate_goodput": self.aggregate_goodput,
            "aggregate_fraction": self.aggregate_fraction,
            "assignment": {
                k: list(v) for k, v in self.runtime.allocation.assignment.items()
            },
            "job_states": self.job_states,
            "epochs": self.epochs,
            "counters": self.runtime.counters(),
        }
        telemetry = self.runtime.fault_telemetry()
        if telemetry is not None:
            telemetry = dict(telemetry)
            telemetry["goodput_retention"] = self.goodput_retention
            telemetry["total_sim_time"] = self.total_sim_time
            telemetry["recovery_log"] = [dict(r) for r in self.runtime.recovery_log]
            out["faults"] = telemetry
        if self.batch_policy is not None:
            # Batch-policy metrics appear only on stamped replays, so
            # golden-path summaries stay byte-identical to earlier releases.
            out["batch_policy"] = self.batch_policy
            out["sample_throughput"] = self.sample_throughput
            out["statistical_efficiency"] = self.statistical_efficiency
            out["policy_goodput"] = self.policy_goodput
            out["mean_total_batch"] = self.mean_total_batch
        return out


def _stamp_batch_policy(trace: Trace, name: str) -> Trace:
    """A copy of ``trace`` whose every arriving job runs under the named
    batch policy (the same stamping idiom :func:`synthetic_trace` uses for
    backends — events are immutable, so the original trace is untouched)."""
    events: List[Event] = []
    for event in trace:
        spec = getattr(event, "spec", None)
        if spec is not None:
            event = dataclasses.replace(
                event, spec=dataclasses.replace(spec, batch_policy=name)
            )
        events.append(event)
    return Trace(events)


def replay(
    trace: Trace,
    n_nodes: int,
    *,
    policy: str = "cannikin",
    engine: str = "batched",
    epochs_per_event: int = 0,
    steps: int = 4,
    noise: float = 0.0,
    seed: int = 0,
    real_backend: Optional[RealBackendConfig] = None,
    checkpoint_dir: Optional[str] = None,
    faults=None,
    health=None,
    invariants: bool = False,
    batch_policy: Optional[str] = None,
) -> TraceReport:
    """Replay ``trace`` through a fresh :class:`ClusterRuntime`.

    Events reconcile in time order; with ``epochs_per_event > 0`` every
    running job additionally advances that many training epochs after each
    event (plan → execute → observe over each job's execution backend — so
    controllers learn, bootstrap, and reach the optperf phase mid-trace).
    ``real_backend``/``checkpoint_dir`` plumb through to the runtime for
    traces whose specs name ``backend="real"``.

    ``faults`` (a :class:`~repro.runtime.faults.FaultPlan`) injects the
    plan's schedule into the replay; the report then carries a fault-free
    twin of the same replay as ``report.baseline`` so goodput retention is
    measurable.  ``health`` enables/configures the
    :class:`~repro.runtime.health.HealthMonitor` (on by default whenever
    faults are injected).  ``invariants`` enables the debug-mode
    :class:`~repro.runtime.invariants.RuntimeInvariantChecker` after every
    reconciled event (chaos CI runs with it on).

    ``batch_policy`` stamps a :mod:`repro.core.batch_policy` law onto every
    arriving job (and the fault-free twin) before replaying, and marks the
    report so its cross-policy metrics activate."""
    if batch_policy is not None:
        trace = _stamp_batch_policy(trace, batch_policy)
    if faults is not None:
        baseline = replay(
            trace, n_nodes, policy=policy, engine=engine,
            epochs_per_event=epochs_per_event, steps=steps, noise=noise,
            seed=seed, real_backend=real_backend, checkpoint_dir=None,
            batch_policy=batch_policy,
        )
    else:
        baseline = None
    rt = ClusterRuntime(
        n_nodes, policy=policy, engine=engine, noise=noise, seed=seed,
        real_backend=real_backend, checkpoint_dir=checkpoint_dir,
        faults=faults, health=health, invariants=invariants,
    )
    for event in trace:
        rt.post(event)
    records: List[ReconcileRecord] = []
    while rt.pending_events:
        record = rt.step()
        assert record is not None
        if epochs_per_event:
            rt.advance(epochs_per_event, steps=steps)
        records.append(record)
    return TraceReport(
        policy=policy,
        records=records,
        runtime=rt,
        baseline=baseline,
        batch_policy=batch_policy,
    )


def compare_policies(
    trace: Trace,
    n_nodes: int,
    *,
    policies: Sequence[str] = ("cannikin", "static", "fair-share"),
    engine: str = "batched",
    epochs_per_event: int = 0,
    steps: int = 4,
    noise: float = 0.0,
    seed: int = 0,
    real_backend: Optional[RealBackendConfig] = None,
    checkpoint_dir: Optional[str] = None,
    faults=None,
    health=None,
    invariants: bool = False,
    batch_policies: Optional[Sequence[str]] = None,
) -> Dict[str, TraceReport]:
    """Replay one trace under several policies (fresh runtime each) and
    return the per-policy reports.

    Two comparison axes share this entry point:

    * default — one replay per *allocation* policy in ``policies``
      (baselines and Cannikin become comparable in one run);
    * ``batch_policies`` given — one replay per *batch-size* policy, all
      under the first allocation policy in ``policies``; the returned dict
      is keyed by batch-policy name and each report carries the
      cross-policy metrics (:func:`rank_batch_policies` consumes it).
      ``batch_policies=()`` means every registered policy.
    """
    if batch_policies is not None:
        names = tuple(batch_policies) or tuple(sorted(BATCH_POLICIES))
        return {
            name: replay(
                trace,
                n_nodes,
                policy=policies[0],
                engine=engine,
                epochs_per_event=epochs_per_event,
                steps=steps,
                noise=noise,
                seed=seed,
                real_backend=real_backend,
                checkpoint_dir=checkpoint_dir,
                faults=faults,
                health=health,
                invariants=invariants,
                batch_policy=name,
            )
            for name in names
        }
    return {
        name: replay(
            trace,
            n_nodes,
            policy=name,
            engine=engine,
            epochs_per_event=epochs_per_event,
            steps=steps,
            noise=noise,
            seed=seed,
            real_backend=real_backend,
            checkpoint_dir=checkpoint_dir,
            faults=faults,
            health=health,
            invariants=invariants,
        )
        for name in policies
    }


def synthetic_trace(
    n_jobs: int = 3,
    n_nodes: int = 12,
    seed: int = 0,
    *,
    arrival_spacing: float = 1.0,
    departure: bool = True,
    node_leave: bool = True,
    refit: bool = False,
    arrival: str = "fixed",
    size_dist: str = "fixed",
    size_sigma: float = 1.0,
    backend: Optional[str] = None,
    total_batch: Optional[int] = None,
) -> Tuple[Trace, List[JobSpec]]:
    """The canonical churn scenario over the seeded random job mix.

    Jobs arrive one after another; optionally the first job departs after
    the last arrival, one node fails after that, and the last job's model
    is refit at the end — i.e. the acceptance scenario (arrivals, one
    departure, one node leave) in one call.  Returns ``(trace, jobs)`` so
    callers can also drive the same jobs by hand.

    ``arrival`` selects the arrival process: ``"fixed"`` (the default —
    exactly ``arrival_spacing`` apart, unchanged from earlier releases) or
    ``"poisson"`` (exponential inter-arrival times with mean
    ``arrival_spacing``, i.e. a Poisson process of rate
    ``1/arrival_spacing``).  ``size_dist`` selects the job-size law:
    ``"fixed"`` keeps :func:`random_jobs`'s categorical total batches;
    ``"lognormal"`` multiplies each job's total batch by a heavy-tailed
    ``exp(N(0, size_sigma))`` draw (the log-normal job-size skew real
    cluster traces show), floored at the job's reference batch.  Both draws
    come from one RNG seeded by ``seed``, so traces stay reproducible.

    ``backend`` (``"sim"``/``"real"``) stamps every job's execution
    backend; ``total_batch`` overrides every job's total batch (useful to
    shrink real-backend traces to CPU-sized batches).
    """
    if arrival not in ("fixed", "poisson"):
        raise ValueError(f"unknown arrival process {arrival!r}")
    if size_dist not in ("fixed", "lognormal"):
        raise ValueError(f"unknown job-size distribution {size_dist!r}")
    jobs = random_jobs(n_jobs, n_nodes, seed)
    rng = np.random.default_rng(seed)
    stamped = []
    for job in jobs:
        changes: Dict[str, object] = {}
        if total_batch is not None:
            changes["total_batch"] = int(total_batch)
        elif size_dist == "lognormal":
            factor = float(rng.lognormal(mean=0.0, sigma=size_sigma))
            changes["total_batch"] = max(
                job.ref_batch, int(round(job.total_batch * factor))
            )
        if backend is not None:
            changes["backend"] = backend
        stamped.append(dataclasses.replace(job, **changes) if changes else job)
    jobs = stamped
    trace = Trace()
    t = 0.0

    def gap() -> float:
        if arrival == "poisson":
            return float(rng.exponential(arrival_spacing))
        return arrival_spacing

    for job in jobs:
        trace.arrive(job, at=t)
        t += gap()
    if departure and n_jobs > 1:
        trace.complete(jobs[0].name, at=t)
        t += gap()
    if node_leave and n_nodes > 1:
        trace.node_leave([n_nodes - 1], at=t)
        t += gap()
    if refit:
        trace.refit(jobs[-1].name, at=t, rel=0.2, seed=seed + 1)
    return trace, jobs


def rank_batch_policies(reports: Dict[str, TraceReport]) -> List[Dict[str, object]]:
    """Order :func:`compare_policies(..., batch_policies=...)` output by
    :attr:`TraceReport.policy_goodput` (descending) into one ranking —
    goodput *and* its throughput/efficiency decomposition per policy."""
    rows = []
    for name, rep in reports.items():
        rows.append(
            {
                "batch_policy": rep.batch_policy or name,
                "policy_goodput": rep.policy_goodput,
                "sample_throughput": rep.sample_throughput,
                "statistical_efficiency": rep.statistical_efficiency,
                "mean_total_batch": rep.mean_total_batch,
                "aggregate_goodput": rep.aggregate_goodput,
                "epochs": int(sum(rep.epochs.values())),
                "total_sim_time": rep.total_sim_time,
            }
        )
    rows.sort(key=lambda r: r["policy_goodput"], reverse=True)
    return rows


def format_batch_policy_summary(reports: Dict[str, TraceReport]) -> str:
    """Fixed-width ranking table over the batch-policy comparison axis."""
    lines = [
        f"{'batch policy':<14} {'goodput':>10} {'samples/s':>10} "
        f"{'stat eff':>9} {'mean B':>8} {'epochs':>7}"
    ]
    for row in rank_batch_policies(reports):
        lines.append(
            f"{row['batch_policy']:<14} {row['policy_goodput']:>10.1f} "
            f"{row['sample_throughput']:>10.1f} "
            f"{row['statistical_efficiency']:>9.3f} "
            f"{row['mean_total_batch']:>8.1f} {row['epochs']:>7}"
        )
    return "\n".join(lines)


def format_summary(reports: Dict[str, TraceReport]) -> str:
    """Fixed-width comparison table over :func:`compare_policies` output."""
    lines = [
        f"{'policy':<11} {'agg goodput':>12} {'agg fraction':>13} "
        f"{'jobs':>5}  states"
    ]
    for name, rep in reports.items():
        states = ",".join(
            f"{job}:{state}" for job, state in sorted(rep.job_states.items())
        )
        lines.append(
            f"{name:<11} {rep.aggregate_goodput:>12.1f} "
            f"{rep.aggregate_fraction:>13.3f} {len(rep.job_states):>5}  {states}"
        )
    return "\n".join(lines)

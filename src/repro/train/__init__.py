from repro.train.checkpoint import restore, save
from repro.train.hetero import EpochResult, HeteroTrainer
from repro.train.step import build_prefill_step, build_serve_step, build_train_step

__all__ = [
    "build_train_step",
    "build_serve_step",
    "build_prefill_step",
    "HeteroTrainer",
    "EpochResult",
    "save",
    "restore",
]

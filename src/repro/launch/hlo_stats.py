"""Trip-count-corrected statistics from optimized HLO text.

XLA's `compiled.cost_analysis()` counts a `while` body ONCE, not
times-trip-count — for scan-over-layers models that undercounts FLOPs,
bytes and collective traffic by ~L (and by the microbatch count, and by
inner attention/WKV chunk scans).  tests/test_hlo_stats.py demonstrates the
raw undercount and validates this module's correction.

This analyzer parses the optimized HLO, builds the computation graph, and
aggregates per-computation statistics recursively, multiplying `while`
bodies by their `known_trip_count` backend config (emitted by XLA whenever
the trip count is static — always true for lax.scan):

  * matmul FLOPs: every `dot` op — 2 * prod(result) * prod(contracted)
  * elementwise/reduce FLOPs: 1 flop per output (inputs for reductions)
  * HBM bytes: per top-level op, operand bytes + result bytes.  Optimized
    HLO is mostly fusions; a fusion's operands/results ARE its HBM traffic
    (internal reuse stays in registers/VMEM), so this is the right
    granularity.  Fusion bodies are descended only for FLOPs.
  * collective bytes: result sizes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, per kind.

All numbers are per-device (the partitioned module is the per-device
program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloStats", "analyze_hlo", "raw_cost_analysis"]


def raw_cost_analysis(compiled) -> Dict[str, float]:
    """Version-portable ``compiled.cost_analysis()``.

    Older jax (< 0.5) returns a one-element *list* of dicts; newer releases
    return the dict directly (and may return ``None`` when the backend has
    no cost model).  Callers comparing the raw XLA numbers against the
    trip-count-corrected :func:`analyze_hlo` should use this accessor so
    the comparison works across jax versions.
    """
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)

DTYPE_BYTES = {
    "pred": 0.125, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_OP_LINE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\("
)
_TRIP = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"?(\d+)')
_CALLED = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}
ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "compare", "select", "and", "or", "xor", "not", "clamp", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "cosine", "sine", "logistic",
    "exponential-minus-one", "log-plus-one", "atan2", "remainder", "cbrt",
    "erf",
}
SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "conditional",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "async-done", "async-start", "async-update", "get-dimension-size",
}


def _type_bytes(type_str: str) -> int:
    total = 0.0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return int(total)


def _type_numel(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype in ("token", "opaque"):
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # remainder of the line after the opcode's "("


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op] = dataclasses.field(default_factory=list)
    symbols: Dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    matmul_flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_whiles: int = 0

    def add(self, other: "HloStats", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.matmul_flops += other.matmul_flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = self.collective_by_kind.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) + v * mult
        self.unknown_trip_whiles += other.unknown_trip_whiles

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "matmul_flops": self.matmul_flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": dict(self.collective_by_kind),
            "collective_counts": dict(self.collective_counts),
            "unknown_trip_whiles": self.unknown_trip_whiles,
        }


def _parse_computations(text: str) -> Tuple[Dict[str, _Computation], Optional[str]]:
    comps: Dict[str, _Computation] = {}
    entry: Optional[str] = None
    current: Optional[_Computation] = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_HEADER.match(line)
            if m:
                current = _Computation(m.group(1))
                if line.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.startswith("}"):
            comps[current.name] = current
            current = None
            continue
        m = _OP_LINE.match(line)
        if m:
            name, type_str, opcode = m.group(1), m.group(2), m.group(3)
            rest = line[m.end():]
            current.ops.append(_Op(name, type_str, opcode, rest))
            current.symbols[name] = type_str
    if current is not None:
        comps[current.name] = current
    return comps, entry


def _dot_flops(op: _Op, comp: _Computation, comps: Dict[str, _Computation]) -> float:
    """2 * prod(result dims) * prod(lhs contracting dims)."""
    result = _first_shape_dims(op.type_str)
    operands = _OPERAND.findall(op.rest)
    m = _CONTRACT.search(op.rest)
    if not operands:
        return 0.0
    lhs_type = comp.symbols.get(operands[0])
    if lhs_type is None:
        for c in comps.values():
            if operands[0] in c.symbols:
                lhs_type = c.symbols[operands[0]]
                break
    if lhs_type is None:
        return 2.0 * max(_type_numel(op.type_str), 1)
    lhs_dims = _first_shape_dims(lhs_type)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                contract *= lhs_dims[di]
    res = 1
    for d in result:
        res *= d
    return 2.0 * res * contract


def _analyze_comp(
    name: str,
    comps: Dict[str, _Computation],
    cache: Dict[Tuple[str, bool], HloStats],
    stack: Tuple[str, ...] = (),
    *,
    count_bytes: bool = True,
) -> HloStats:
    key = (name, count_bytes)
    if key in cache:
        return cache[key]
    if name in stack or name not in comps:
        return HloStats()
    comp = comps[name]
    stats = HloStats()
    for op in comp.ops:
        oc = op.opcode
        if oc == "while":
            m = _TRIP.search(op.rest)
            trip = float(m.group(1)) if m else 1.0
            if not m:
                stats.unknown_trip_whiles += 1
            called = _CALLED.search(op.rest)
            if called:
                body = _analyze_comp(
                    called.group(1), comps, cache, stack + (name,),
                    count_bytes=count_bytes,
                )
                stats.add(body, trip)
            continue
        if oc == "conditional":
            m = _BRANCHES.search(op.rest)
            branches = []
            if m:
                branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
            else:
                branches = _CALLED.findall(op.rest)
            if branches:
                subs = [
                    _analyze_comp(b, comps, cache, stack + (name,), count_bytes=count_bytes)
                    for b in branches
                ]
                best = max(subs, key=lambda s: s.flops + s.bytes_accessed)
                stats.add(best)
            continue
        if oc in ("call", "fusion", "async-start"):
            called = _CALLED.search(op.rest)
            if called:
                # Descend for FLOPs only; fusion HBM traffic is its
                # top-level operands + result, counted below.
                sub = _analyze_comp(
                    called.group(1), comps, cache, stack + (name,), count_bytes=False
                )
                stats.flops += sub.flops
                stats.matmul_flops += sub.matmul_flops
                stats.collective_bytes += sub.collective_bytes
                for k, v in sub.collective_by_kind.items():
                    stats.collective_by_kind[k] = stats.collective_by_kind.get(k, 0) + v
        if oc == "dot":
            f = _dot_flops(op, comp, comps)
            stats.flops += f
            stats.matmul_flops += f
        elif oc in ELEMENTWISE:
            stats.flops += _type_numel(op.type_str)
        elif oc in ("reduce", "reduce-window"):
            operands = _OPERAND.findall(op.rest)
            if operands and operands[0] in comp.symbols:
                stats.flops += _type_numel(comp.symbols[operands[0]])
            else:
                stats.flops += _type_numel(op.type_str)
        elif oc == "convolution":
            # No conv-using arch in the zoo (frontends stubbed); coarse count.
            stats.flops += 2.0 * _type_numel(op.type_str)

        base = oc.replace("-start", "")
        if base in {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                    "collective-permute", "ragged-all-to-all"}:
            b = _type_bytes(op.type_str)
            stats.collective_bytes += b
            stats.collective_by_kind[base] = stats.collective_by_kind.get(base, 0.0) + b
            stats.collective_counts[base] = stats.collective_counts.get(base, 0.0) + 1

        if count_bytes and oc not in SKIP_BYTES:
            if oc in ("dynamic-slice", "gather"):
                # Reads only the selected window, not the whole operand.
                b = 2 * _type_bytes(op.type_str)
            elif oc in ("dynamic-update-slice", "scatter"):
                # In-place (XLA aliases the buffer): traffic ~ the update
                # operand read+write, not the full result buffer.
                operands = _OPERAND.findall(op.rest)
                upd = comp.symbols.get(operands[1]) if len(operands) > 1 else None
                b = 2 * _type_bytes(upd) if upd else _type_bytes(op.type_str)
            else:
                b = _type_bytes(op.type_str)
                for operand in _OPERAND.findall(op.rest):
                    t = comp.symbols.get(operand)
                    if t:
                        b += _type_bytes(t)
            stats.bytes_accessed += b
    cache[key] = stats
    return stats


def analyze_hlo(text: str) -> HloStats:
    comps, entry = _parse_computations(text)
    if entry is None:
        # Fall back: largest computation.
        entry = max(comps, key=lambda n: len(comps[n].ops)) if comps else ""
    cache: Dict[Tuple[str, bool], HloStats] = {}
    return _analyze_comp(entry, comps, cache)

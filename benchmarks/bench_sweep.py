"""Goodput-sweep benchmark: batched OptPerf engine vs per-candidate scalar
loops (the §4.1/§4.5 control-loop hot path behind the Table 5 overhead
claims).

Measures, at n nodes x C candidate total batch sizes:

  * scalar water-fill loop  — ``solve_optperf_waterfill`` per candidate
  * scalar Algorithm 1 loop — ``solve_optperf_algorithm1`` per candidate
    (with §4.5 boundary-hint chaining, as the old selector sweep did)
  * batched engine          — one ``solve_optperf_batch`` array pass

and verifies the batched opt_perf values against the scalar water-fill
oracle (max relative gap must be <= 1e-6).

Usage:
    PYTHONPATH=src:. python -m benchmarks.bench_sweep            # full (64x64)
    PYTHONPATH=src:. python -m benchmarks.bench_sweep --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
from typing import List

import numpy as np

from benchmarks.common import Row, save_json, time_call
from repro.core.goodput import goodput_curve
from repro.core.optperf import (
    solve_optperf_algorithm1,
    solve_optperf_batch,
    solve_optperf_waterfill,
)
from repro.core.perf_model import ClusterPerfModel, CommModel, NodePerfModel


def _random_model(n: int, seed: int = 0) -> ClusterPerfModel:
    rng = np.random.default_rng(seed)
    nodes = tuple(
        NodePerfModel(
            q=float(rng.uniform(1e-4, 5e-3)),
            s=float(rng.uniform(0, 0.02)),
            k=float(rng.uniform(1e-4, 8e-3)),
            m=float(rng.uniform(0, 0.02)),
        )
        for _ in range(n)
    )
    comm = CommModel(t_o=0.04, t_u=0.008, gamma=0.15)
    return ClusterPerfModel(nodes=nodes, comm=comm)


def _candidates(count: int) -> np.ndarray:
    return np.unique(np.round(np.geomspace(64, 65536, count))).astype(np.float64)


def run_config(n: int, num_candidates: int, repeats: int) -> dict:
    model = _random_model(n)
    cands = _candidates(num_candidates)

    def scalar_waterfill():
        return [solve_optperf_waterfill(model, float(b)) for b in cands]

    def scalar_algorithm1():
        hint = None
        out = []
        for b in cands:
            sol = solve_optperf_algorithm1(model, float(b), boundary_hint=hint)
            hint = sum(1 for s in sol.bottleneck if s == "compute")
            out.append(sol)
        return out

    def batched():
        return solve_optperf_batch(model, cands)

    t_wf = time_call(scalar_waterfill, repeats=repeats)
    t_a1 = time_call(scalar_algorithm1, repeats=repeats)
    t_batch = time_call(batched, repeats=repeats)

    batch_sol = batched()
    scalar_sols = scalar_waterfill()
    gaps = [
        abs(batch_sol.opt_perfs[j] - s.opt_perf) / s.opt_perf
        for j, s in enumerate(scalar_sols)
    ]
    return {
        "n": n,
        "candidates": int(cands.size),
        "scalar_waterfill_us": t_wf,
        "scalar_algorithm1_us": t_a1,
        "batched_us": t_batch,
        "speedup_vs_waterfill_loop": t_wf / t_batch,
        "speedup_vs_algorithm1_loop": t_a1 / t_batch,
        "max_rel_gap_vs_oracle": float(max(gaps)),
    }


def run(smoke: bool = False) -> List[Row]:
    configs = [(8, 8)] if smoke else [(16, 16), (64, 64), (256, 64)]
    repeats = 3 if smoke else 5
    rows: List[Row] = []
    payload = {}
    for n, c in configs:
        rec = run_config(n, c, repeats)
        payload[f"n{n}_c{c}"] = rec
        rows.append(
            Row(
                f"sweep/batched/n{n}xc{c}",
                rec["batched_us"],
                f"speedup={rec['speedup_vs_waterfill_loop']:.1f}x;"
                f"gap={rec['max_rel_gap_vs_oracle']:.2e}",
            )
        )
        rows.append(Row(f"sweep/scalar_waterfill/n{n}xc{c}", rec["scalar_waterfill_us"], ""))
        rows.append(Row(f"sweep/scalar_algorithm1/n{n}xc{c}", rec["scalar_algorithm1_us"], ""))
        # The acceptance gate: >= 10x over the per-candidate scalar loop and
        # <= 1e-6 relative opt_perf gap at the 64x64 configuration.
        if rec["max_rel_gap_vs_oracle"] > 1e-6:
            raise AssertionError(f"batched engine drifted from oracle: {rec}")
        if not smoke and (n, c) == (64, 64) and rec["speedup_vs_waterfill_loop"] < 10.0:
            raise AssertionError(f"batched sweep under 10x at 64x64: {rec}")
    # A goodput_curve smoke call so the end-to-end consumer path is timed too.
    model = _random_model(16)
    cands = _candidates(16)
    t_curve = time_call(lambda: goodput_curve(model, cands, 500.0, 128), repeats=repeats)
    rows.append(Row("sweep/goodput_curve/n16xc16", t_curve, ""))
    payload["goodput_curve_n16_c16_us"] = t_curve
    save_json("sweep", payload)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        print(row.csv(), flush=True)


if __name__ == "__main__":
    main()

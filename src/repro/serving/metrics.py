"""Latency/throughput accounting shared by the serving runtime and bench.

All statistics derive from per-request :class:`RequestRecord` rows and
per-token timestamps, computed with plain NumPy so the same-seed serving
runs the determinism gates compare are bit-identical all the way through the
summary — :meth:`ServingMetrics.fingerprint` hashes the canonical record
stream for exactly that purpose.

Vocabulary:

* **sustained req/s** — completed requests / elapsed time;
* **token latency** — the gap between consecutive generated tokens of one
  request (the decode-tick time a request experiences); p50/p95/p99 are
  reported over all gaps of all requests;
* **TTFT** — arrival -> first generated token;
* **goodput** — completed requests that met their deadline, per second (the
  serving analogue of the trainer's statistically-efficient throughput);
* **utilization** — per-node busy time / elapsed.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["RequestRecord", "ServingMetrics", "percentiles"]


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle timestamps of one request (simulated or wall seconds)."""

    rid: int
    arrival: float
    deadline: float
    gen_len: int
    prompt_len: int
    admitted: Optional[float] = None
    first_token: Optional[float] = None
    finished: Optional[float] = None
    node: int = -1                # node that completed it
    requeues: int = 0
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def completed(self) -> bool:
        return self.finished is not None

    @property
    def met_deadline(self) -> bool:
        return self.completed and self.finished <= self.deadline

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    def token_gaps(self) -> List[float]:
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]


def percentiles(values: Sequence[float], qs=(50.0, 95.0, 99.0)) -> Dict[str, float]:
    """Deterministic linear-interpolation percentiles; NaN on empty input."""
    if len(values) == 0:
        return {f"p{q:g}": float("nan") for q in qs}
    arr = np.asarray(sorted(values), dtype=np.float64)
    return {f"p{q:g}": float(np.percentile(arr, q)) for q in qs}


class ServingMetrics:
    """Accumulates request lifecycles, queue-depth samples, node busy time."""

    def __init__(self) -> None:
        self._records: Dict[int, RequestRecord] = {}
        self._queue_samples: List[int] = []
        self._busy: Dict[int, float] = {}
        self.started_at = 0.0
        self.finished_at = 0.0

    # -- recording ---------------------------------------------------------

    def on_arrival(self, rid: int, arrival: float, deadline: float,
                   prompt_len: int, gen_len: int) -> None:
        if rid in self._records:
            raise ValueError(f"request {rid} recorded twice")
        self._records[rid] = RequestRecord(
            rid=rid, arrival=arrival, deadline=deadline,
            gen_len=gen_len, prompt_len=prompt_len,
        )

    def on_admit(self, rid: int, now: float) -> None:
        rec = self._records[rid]
        if rec.admitted is None:  # first admission only; requeues re-admit
            rec.admitted = now

    def on_token(self, rid: int, now: float) -> None:
        rec = self._records[rid]
        if rec.first_token is None:
            rec.first_token = now
        rec.token_times.append(now)

    def on_complete(self, rid: int, now: float, node: int, requeues: int) -> None:
        rec = self._records[rid]
        if rec.finished is not None:
            raise ValueError(f"request {rid} completed twice")
        rec.finished = now
        rec.node = node
        rec.requeues = requeues
        self.finished_at = max(self.finished_at, now)

    def on_queue_sample(self, depth: int) -> None:
        self._queue_samples.append(int(depth))

    def on_node_busy(self, node: int, seconds: float) -> None:
        self._busy[node] = self._busy.get(node, 0.0) + float(seconds)

    # -- views -------------------------------------------------------------

    def records(self) -> List[RequestRecord]:
        return [self._records[rid] for rid in sorted(self._records)]

    @property
    def completed(self) -> int:
        return sum(1 for r in self._records.values() if r.completed)

    @property
    def total(self) -> int:
        return len(self._records)

    def elapsed(self) -> float:
        return max(self.finished_at - self.started_at, 0.0)

    def summary(self, elapsed: Optional[float] = None) -> Dict[str, object]:
        recs = self.records()
        done = [r for r in recs if r.completed]
        span = float(elapsed) if elapsed is not None else self.elapsed()
        span = max(span, 1e-12)
        gaps: List[float] = []
        ttfts: List[float] = []
        for r in done:
            gaps.extend(r.token_gaps())
            if r.ttft is not None:
                ttfts.append(r.ttft)
        tokens = sum(len(r.token_times) for r in recs)
        misses = sum(1 for r in done if not r.met_deadline)
        out: Dict[str, object] = {
            "requests": len(recs),
            "completed": len(done),
            "dropped": len(recs) - len(done),
            "elapsed_s": span,
            "sustained_req_s": len(done) / span,
            "goodput_req_s": sum(1 for r in done if r.met_deadline) / span,
            "token_throughput_s": tokens / span,
            "deadline_misses": misses,
            "deadline_miss_rate": misses / len(done) if done else float("nan"),
            "requeues": sum(r.requeues for r in recs),
            "mean_queue_depth": (
                float(np.mean(self._queue_samples)) if self._queue_samples else 0.0
            ),
            "max_queue_depth": max(self._queue_samples, default=0),
            "node_utilization": {
                node: self._busy[node] / span for node in sorted(self._busy)
            },
        }
        out["token_latency"] = percentiles(gaps)
        out["ttft"] = percentiles(ttfts)
        return out

    def fingerprint(self) -> str:
        """sha256 over the canonical per-request record stream — two serving
        runs are bit-identical iff their fingerprints match."""
        h = hashlib.sha256()
        for r in self.records():
            h.update(
                repr((
                    r.rid, r.arrival, r.deadline, r.prompt_len, r.gen_len,
                    r.admitted, r.first_token, r.finished, r.node,
                    r.requeues, tuple(r.token_times),
                )).encode()
            )
        h.update(repr(tuple(self._queue_samples)).encode())
        h.update(repr(sorted(self._busy.items())).encode())
        return h.hexdigest()

"""Heterogeneity-aware multi-job scheduler (beyond-paper; the paper's §6
"Adapt to schedulers for heterogeneous clusters" future-work item).

Existing schedulers (Pollux, Optimus) allocate homogeneous slices per job;
Sia is heterogeneity-aware across jobs but keeps each job's allocation
homogeneous.  With Cannikin, a job runs *optimally on any heterogeneous
subset* — its goodput for an arbitrary node set is computable from the
per-node performance models.  That turns scheduling into: partition the
cluster's (heterogeneous) nodes among jobs to maximize aggregate
goodput-fraction.

`allocate` uses greedy marginal-gain assignment (submodular-style):
repeatedly give the next node to the job whose *relative* goodput gains the
most from it.  Each job's goodput for a candidate node set comes from the
OptPerf solver over that subset — the same machinery the controller uses,
so scheduler decisions and runtime behaviour cannot diverge.

The array engines (``engine="batched"`` NumPy, ``engine="jax"`` on-device)
keep one *fixed-layout* stacked problem per allocation run: row
``ji * N + c`` is job ``ji``'s current node set plus candidate node ``c``,
padded to a power-of-two width.  Between greedy rounds only the winning
job's rows change (one appended node), so each round

  * re-solves exactly those N rows — one :func:`solve_optperf_stacked` /
    :func:`~repro.core.optperf_jax.solve_optperf_stacked_jax` call —
  * **warm-started** from the same rows' previous ``t_stars`` (the problems
    differ by one appended node, so the safeguarded-Newton refinement
    certifies in a handful of array passes instead of ~50 cold bisections),
  * and reuses every other job's marginal goodputs unchanged (their sets
    and candidates did not move — the values are exact, not approximate).

``engine="scalar"`` keeps the original per-(job, node) loop as the
cross-check oracle; the final chosen sets are re-solved through the
*bit-identical* stacked subset solver (one
:func:`~repro.core.optperf.solve_optperf_waterfill_subsets` call per
distinct set size per allocation run, replacing the old one-scalar-solve-
per-greedy-round serial tail), so emitted allocations carry
engine-identical numbers.

:class:`Scheduler` wraps the greedy core with *incremental re-allocation*:
``add_job``/``remove_job``/``update_job`` re-run the greedy loop but reuse
everything the arrival/departure did not touch — cached solo goodputs,
cached per-(job, node-set) marginal rows from the previous run (exact
hits while the greedy trajectory replays), and warm bracket seeds once it
diverges — so only the affected rows pay full solves.  ``update_job`` (the
per-epoch OLS refit path) invalidates that job's cached rows and the stack
device caches; see :meth:`~repro.core.perf_model.StackedClusterModel.invalidate_device_cache`.

This is intentionally a library (allocation policy + simulation harness),
not a daemon: launch integration would wrap `allocate`/`Scheduler` in a
reconcile loop.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.goodput import statistical_efficiency
from repro.core.optperf import (
    solve_optperf_stacked,
    solve_optperf_waterfill_subset,
    solve_optperf_waterfill_subsets,
)
from repro.core.perf_model import (
    ClusterPerfModel,
    CommModel,
    NodePerfModel,
    StackedClusterModel,
)

__all__ = [
    "JobSpec",
    "Allocation",
    "Scheduler",
    "allocate",
    "aggregate_goodput",
    "random_jobs",
]


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """A job's statistical state + per-node performance models.

    ``node_models[i]`` is THIS job's fitted model for cluster node i (compute
    coefficients are job-dependent; §4.2).  ``comm`` is the job's fitted
    communication model.  ``backend`` names the execution engine the runtime
    drives the job's epochs through (``"sim"`` — timing simulator only, or
    ``"real"`` — real JAX gradients via
    :class:`~repro.runtime.backend.RealBackend`).  ``batch_policy`` names a
    registered :mod:`repro.core.batch_policy` adaptation law for the job's
    controller (``None`` keeps the historical per-backend default: GNS-driven
    adaptive on ``"real"``, fixed-batch on ``"sim"``).
    """

    name: str
    node_models: Tuple[NodePerfModel, ...]   # indexed by cluster node id
    comm: CommModel
    total_batch: int
    b_noise: float
    ref_batch: int
    min_nodes: int = 1
    backend: str = "sim"
    batch_policy: Optional[str] = None

    @functools.cached_property
    def full_model(self) -> ClusterPerfModel:
        """This job's model over the whole cluster; its cached ``coeffs`` are
        the gather source for the batched scheduler rows."""
        return ClusterPerfModel(nodes=self.node_models, comm=self.comm)

    @functools.cached_property
    def efficiency(self) -> float:
        return statistical_efficiency(self.b_noise, self.total_batch, self.ref_batch)

    def goodput(self, node_ids: Sequence[int]) -> float:
        if len(node_ids) < self.min_nodes:
            return 0.0
        try:
            sol = solve_optperf_waterfill_subset(
                self.full_model, node_ids, self.total_batch
            )
        except (ValueError, RuntimeError):
            return 0.0
        thr = self.total_batch / sol.opt_perf
        return thr * self.efficiency

    def solo_goodput(self) -> float:
        """Goodput with the whole cluster — the normalizer for fairness."""
        return self.goodput(tuple(range(len(self.node_models))))


def _finite_sum(values: Iterable[float]) -> float:
    """Sum treating non-finite entries as 0.0 — an empty or zero-node
    allocation (or a garbage-fit job whose solo normalizer degenerated to
    NaN) must aggregate to 0.0, never poison the total with NaN."""
    return float(sum(v for v in values if math.isfinite(v)))


@dataclasses.dataclass(frozen=True)
class Allocation:
    assignment: Dict[str, Tuple[int, ...]]   # job -> node ids
    goodputs: Dict[str, float]
    fractions: Dict[str, float]              # goodput / solo goodput

    @property
    def aggregate_fraction(self) -> float:
        return _finite_sum(self.fractions.values())

    @property
    def aggregate_goodput(self) -> float:
        return _finite_sum(self.goodputs.values())


def _chosen_goodput_batch(
    pairs: Sequence[Tuple[JobSpec, Tuple[int, ...]]]
) -> List[float]:
    """:meth:`JobSpec.goodput` for many (job, chosen node set) pairs, solved
    as stacked subset water-fills — one
    :func:`~repro.core.optperf.solve_optperf_waterfill_subsets` call per
    distinct set size instead of one scalar solve per pair.  Values are
    bit-identical to ``job.goodput(ids)`` (the stacked path freezes each
    row's bisection at its solo convergence point), so the oracle-parity
    contract on emitted goodputs is preserved exactly.  A degenerate row
    falls the whole batch back to the per-pair scalar path, which carries
    the graceful-0.0 semantics row by row."""
    values = [0.0] * len(pairs)
    models, sets, totals, idx = [], [], [], []
    for i, (job, ids) in enumerate(pairs):
        if len(ids) < job.min_nodes:
            continue
        models.append(job.full_model)
        sets.append(ids)
        totals.append(job.total_batch)
        idx.append(i)
    if not idx:
        return values
    try:
        sols = solve_optperf_waterfill_subsets(models, sets, totals)
    except (ValueError, RuntimeError):
        for i in idx:
            job, ids = pairs[i]
            values[i] = job.goodput(ids)
        return values
    for i, sol in zip(idx, sols):
        job = pairs[i][0]
        thr = job.total_batch / sol.opt_perf
        values[i] = thr * job.efficiency
    return values


def _stacked_solver(engine: str):
    """The stacked-row solver for an array engine: the jit on-device kernel
    for ``engine == "jax"`` (silently falling back to the NumPy stacked
    engine when JAX is unavailable), else the NumPy stacked engine."""
    if engine == "jax":
        try:
            from repro.core import optperf_jax

            if optperf_jax.HAS_JAX:
                return optperf_jax.solve_optperf_stacked_jax
        except ImportError:  # pragma: no cover - jax present in CI image
            pass
    return solve_optperf_stacked


def _model_ok(job: JobSpec) -> bool:
    try:
        job.full_model.validate()
        return True
    except ValueError:
        return False


_INERT_FILL = (
    ("alphas", 1.0), ("cs", 0.0), ("betas", 1.0),
    ("ds", 0.0), ("ks", 1.0), ("ms", 0.0),
)


class _GreedyState:
    """Fixed-layout stacked-problem state for one array-engine run.

    Row ``ji * N + c`` is job ``ji``'s current node set (columns ``0..m-1``)
    plus candidate node ``c`` (column ``m``), padded to a power-of-two
    column capacity so the jax engine re-specializes on a handful of shapes
    instead of one per round.  ``take`` updates exactly the winner's N rows
    in place (chosen node written into column ``m``, the candidate column
    moves to ``m+1``) and invalidates the cached device/solver views of the
    mutated block — the warm seeds (`t_stars`) and marginal goodputs of
    every other job carry over unchanged.
    """

    def __init__(self, jobs: Sequence[JobSpec], n_nodes: int, healthy: Sequence[bool]):
        self.jobs = list(jobs)
        self.healthy = list(healthy)
        self.n = n_nodes
        self.j = len(jobs)
        self.rows = self.j * n_nodes
        self.width = 1
        self.m = [0] * self.j
        self.assign: List[List[int]] = [[] for _ in jobs]
        self.goodputs = np.zeros((self.j, n_nodes))
        self.t_stars = np.full((self.j, n_nodes), np.nan)
        self.dirty = set(range(self.j))
        self.t_o = np.zeros(self.rows)
        self.t_u = np.zeros(self.rows)
        self.gamma = np.zeros(self.rows)
        self.totals = np.empty(self.rows)
        self._alloc_arrays()
        cand = np.arange(n_nodes, dtype=np.intp)
        for ji, job in enumerate(jobs):
            sl = self._block(ji)
            self.totals[sl] = job.total_batch
            if not self.healthy[ji]:
                # Garbage-fit job (bad node fit or bad comm model): inert
                # unit rows — mask True and zeroed comm keep the stack valid
                # — with goodput forced to 0, same as JobSpec.goodput's
                # graceful degradation.
                self.mask[sl, 0] = True
                continue
            self.t_o[sl] = job.comm.t_o
            self.t_u[sl] = job.comm.t_u
            self.gamma[sl] = job.comm.gamma
            co = job.full_model.coeffs
            for name, _ in _INERT_FILL:
                self.arrays[name][sl, 0] = getattr(co, name)[cand]
            self.mask[sl, 0] = True

    def _alloc_arrays(self) -> None:
        """(Re)allocate the width-dependent coefficient arrays (the row
        vectors — comm/totals — are width-independent and allocated once)."""
        self.arrays = {
            name: np.full((self.rows, self.width), fill) for name, fill in _INERT_FILL
        }
        self.mask = np.zeros((self.rows, self.width), dtype=bool)
        self._stacks: Dict[int, StackedClusterModel] = {}

    def _block(self, ji: int) -> slice:
        return slice(ji * self.n, (ji + 1) * self.n)

    def _grow(self) -> None:
        old, old_mask, w = self.arrays, self.mask, self.width
        self.width = w * 2
        self._alloc_arrays()
        for name in old:
            self.arrays[name][:, :w] = old[name]
        self.mask[:, :w] = old_mask

    def _stack_for(self, ji: int) -> StackedClusterModel:
        """Stacked view of one job block, cached per block so repeated
        solves of unchanged rows reuse the memoized `_Problem` view and the
        jax device export (``take`` invalidates the mutated block's)."""
        stack = self._stacks.get(ji)
        if stack is None:
            sl = self._block(ji)
            stack = StackedClusterModel(
                t_o=self.t_o[sl], t_u=self.t_u[sl], gamma=self.gamma[sl],
                mask=self.mask[sl],
                **{name: arr[sl] for name, arr in self.arrays.items()},
            )
            self._stacks[ji] = stack
        return stack

    def take(self, ji: int, node: int) -> None:
        """Append ``node`` to job ``ji``'s set, updating its rows in place."""
        m = self.m[ji]
        if m + 2 > self.width:
            self._grow()
        self.assign[ji].append(node)
        self.m[ji] = m + 1
        self.dirty.add(ji)
        if self.healthy[ji]:
            sl = self._block(ji)
            co = self.jobs[ji].full_model.coeffs
            cand = np.arange(self.n, dtype=np.intp)
            for name, _ in _INERT_FILL:
                arr = self.arrays[name]
                arr[sl, m] = getattr(co, name)[node]
                arr[sl, m + 1] = getattr(co, name)[cand]
            self.mask[sl, m + 1] = True
            # The block's arrays changed under any cached views: the memoized
            # `_Problem` derived arrays and the jax device export are stale.
            stack = self._stacks.get(ji)
            if stack is not None:
                stack.invalidate_device_cache()
                # The new columns are gathers from this job's already-
                # validated full model, so the validity memo may be kept —
                # re-validating every round is pure overhead.
                stack.__dict__["_validated"] = True

    def _viable(self, ji: int) -> bool:
        return self.healthy[ji] and (self.m[ji] + 1) >= self.jobs[ji].min_nodes

    def _solve_rows(
        self, ji: int, solver, warm: Optional[np.ndarray]
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(goodput row incl. efficiency, t_star row) for one job block."""
        stack = self._stack_for(ji)
        sol = solver(stack, self.totals[self._block(ji)], warm_start=warm)
        good = np.zeros(self.n)
        if self._viable(ji):
            good = self.jobs[ji].total_batch / sol.opt_perfs * self.jobs[ji].efficiency
        return good, np.asarray(sol.t_stars)

    def _scalar_rows(self, ji: int) -> np.ndarray:
        job = self.jobs[ji]
        base = self.assign[ji]
        return np.asarray(
            [job.goodput(tuple(base + [c])) for c in range(self.n)]
        )


def _allocate_arrays(
    jobs: Sequence[JobSpec],
    n_nodes: int,
    engine: str,
    *,
    solo: Dict[str, float],
    round_scalar: bool = False,
    gain_cache: Optional[Dict[str, Dict[Tuple[int, ...], Tuple[np.ndarray, np.ndarray]]]] = None,
    take_cache: Optional[Dict[str, Dict[Tuple[int, ...], float]]] = None,
    counters: Optional["Scheduler"] = None,
    unavailable: Sequence[int] = (),
    cache_limit: Optional[int] = None,
) -> Allocation:
    """Greedy marginal-gain assignment on the fixed-layout stacked state.

    ``round_scalar=False`` (the default mode: plain :func:`allocate` and the
    incremental :class:`Scheduler`) reads the chosen row's certified stacked
    value during the rounds — within solver tolerance (~1e-10 relative) of a
    scalar re-solve — and re-solves only the *final* chosen sets, batched
    through the bit-identical stacked subset solver (one
    :func:`~repro.core.optperf.solve_optperf_waterfill_subsets` call per
    distinct set size per run), so emitted goodputs match the scalar
    oracle's bit-for-bit while the rounds themselves stay array-only.
    ``round_scalar=True`` is the debug/cross-check mode that re-solves the
    chosen set with the scalar path after *every* round, making the
    intermediate ``current`` values oracle-bit-identical too.  The two modes
    pick identical assignments unless some round has two competing gains
    closer than the round solver's resolution without being exactly tied
    (exact ties — e.g. identical node models — break identically in both):
    ~1e-10 relative for the NumPy engine, ~1e-7 for the float32 stacked-jax
    engine.  Real clusters sit far from that degeneracy.
    """
    solver = _stacked_solver(engine)
    healthy = [_model_ok(j) for j in jobs]
    state = _GreedyState(jobs, n_nodes, healthy)
    current = [0.0] * len(jobs)
    # Down nodes are pre-marked taken: the fixed row layout (and with it
    # every cached marginal row and warm bracket seed) is preserved across
    # node churn — the greedy loop simply never assigns a masked node.
    taken = np.zeros(n_nodes, dtype=bool)
    for nid in unavailable:
        taken[int(nid)] = True
    remaining = n_nodes - int(taken.sum())

    # Long-lived Schedulers reconcile indefinitely; every distinct greedy
    # trajectory adds cache keys, so each per-job cache is bounded (oldest
    # entries evicted first — dicts preserve insertion order) instead of
    # growing with the number of reallocations.
    if cache_limit is None:
        cache_limit = 8 * max(n_nodes, 1)

    def bounded_insert(cache: Dict, key, value) -> None:
        cache.pop(key, None)
        cache[key] = value
        while len(cache) > cache_limit:
            cache.pop(next(iter(cache)))

    def job_cache(ji: int):
        if gain_cache is None:
            return None
        return gain_cache.setdefault(jobs[ji].name, {})

    def solve_dirty() -> None:
        """Refresh the marginal rows of every dirty job: cached exact rows
        when the (job, node-set) was solved before, one warm-seeded stacked
        call per block otherwise."""
        pending = sorted(state.dirty)
        state.dirty.clear()
        for ji in pending:
            cache = job_cache(ji)
            key = tuple(state.assign[ji])
            if cache is not None and key in cache:
                state.goodputs[ji], state.t_stars[ji] = cache[key]
                if counters is not None:
                    counters.cached_rows += state.n
                continue
            seeds = state.t_stars[ji]
            warm = seeds.copy() if np.isfinite(seeds).all() else None
            try:
                good, t_star = state._solve_rows(ji, solver, warm)
                state.goodputs[ji], state.t_stars[ji] = good, t_star
            except (ValueError, RuntimeError):
                # Degenerate block: fall back to the scalar oracle for these
                # rows (graceful 0.0 semantics included); no warm seeds.
                state.goodputs[ji] = state._scalar_rows(ji)
                state.t_stars[ji] = np.nan
            if counters is not None:
                counters.solved_rows += state.n
                if warm is None:
                    counters.cold_rounds += 1
                else:
                    counters.warm_rounds += 1
            if cache is not None:
                bounded_insert(
                    cache, key, (state.goodputs[ji].copy(), state.t_stars[ji].copy())
                )

    def gains() -> np.ndarray:
        cur = np.asarray(current)[:, None]
        solo_v = np.asarray([solo[j.name] for j in jobs])[:, None]
        g = (state.goodputs - cur) / solo_v
        return np.where(taken[None, :], -np.inf, g)

    def chosen_goodput(ji: int) -> float:
        # Chosen sets are always re-solved by the scalar path so emitted
        # goodputs are engine-identical (cached across incremental runs —
        # the set, not the order, determines the value).
        ids = tuple(sorted(state.assign[ji]))
        if take_cache is None:
            return jobs[ji].goodput(ids)
        cache = take_cache.setdefault(jobs[ji].name, {})
        if ids not in cache:
            bounded_insert(cache, ids, jobs[ji].goodput(ids))
        return cache[ids]

    def take(ji: int, node: int) -> None:
        nonlocal remaining
        value = float(state.goodputs[ji, node])
        state.take(ji, node)
        taken[node] = True
        remaining -= 1
        current[ji] = chosen_goodput(ji) if round_scalar else value

    if remaining > 0 and jobs:
        solve_dirty()
        # Seed round: each job (in order of scarcity) takes its best node.
        for ji in sorted(range(len(jobs)), key=lambda x: -jobs[x].min_nodes):
            if remaining == 0:
                break
            solve_dirty()
            take(ji, int(np.argmax(gains()[ji])))
        # Greedy rounds: only the previous winner's rows are re-solved.
        while remaining:
            solve_dirty()
            g = gains()
            flat = int(np.argmax(g))
            ji, node = divmod(flat, n_nodes)
            if g[ji, node] <= 0:
                break  # nobody benefits (comm-bound saturation)
            take(ji, node)

    if not round_scalar:
        # Emit oracle-path values for the final sets (cached across runs):
        # the same sets re-solved through the bit-identical stacked subset
        # solver — one call per distinct set size per allocate, instead of
        # one scalar solve per greedy round — so the emitted numbers are
        # engine- and mode-identical to the scalar oracle's.
        pending: List[Tuple[int, Tuple[int, ...]]] = []
        for ji in range(len(jobs)):
            if not state.assign[ji]:
                continue
            ids = tuple(sorted(state.assign[ji]))
            if take_cache is not None:
                cache = take_cache.setdefault(jobs[ji].name, {})
                if ids in cache:
                    current[ji] = cache[ids]
                    continue
            pending.append((ji, ids))
        if pending:
            values = _chosen_goodput_batch(
                [(jobs[ji], ids) for ji, ids in pending]
            )
            for (ji, ids), value in zip(pending, values):
                if take_cache is not None:
                    bounded_insert(
                        take_cache.setdefault(jobs[ji].name, {}), ids, value
                    )
                current[ji] = value
    goodputs = {j.name: current[ji] for ji, j in enumerate(jobs)}
    fractions = {j.name: goodputs[j.name] / solo[j.name] for j in jobs}
    return Allocation(
        assignment={
            j.name: tuple(sorted(state.assign[ji])) for ji, j in enumerate(jobs)
        },
        goodputs=goodputs,
        fractions=fractions,
    )


def _allocate_scalar(
    jobs: Sequence[JobSpec],
    n_nodes: int,
    solo: Dict[str, float],
    unavailable: Sequence[int] = (),
) -> Allocation:
    """The per-(job, candidate-node) scalar loop — the cross-check oracle.
    Candidates iterate in ascending node id and jobs in caller order, so
    tie-breaking matches the array engines' fixed row layout."""
    remaining = set(range(n_nodes)) - {int(i) for i in unavailable}
    assign: Dict[str, List[int]] = {j.name: [] for j in jobs}
    current = {j.name: 0.0 for j in jobs}

    def scalar_gain(job: JobSpec, node: int) -> float:
        g = job.goodput(tuple(assign[job.name] + [node]))
        return (g - current[job.name]) / solo[job.name]

    def take(job: JobSpec, nid: int) -> None:
        assign[job.name].append(nid)
        current[job.name] = job.goodput(tuple(assign[job.name]))
        remaining.discard(nid)

    for job in sorted(jobs, key=lambda j: -j.min_nodes):
        if not remaining:
            break
        candidates = sorted(remaining)
        gains = [scalar_gain(job, nid) for nid in candidates]
        take(job, candidates[int(np.argmax(gains))])

    while remaining:
        candidates = sorted(remaining)
        gains = np.array(
            [[scalar_gain(j, nid) for nid in candidates] for j in jobs]
        )
        flat = int(np.argmax(gains))
        ji, r = divmod(flat, len(candidates))
        if gains[ji, r] <= 0:
            break
        take(jobs[ji], candidates[r])

    goodputs = {name: current[name] for name in assign}
    fractions = {name: goodputs[name] / solo[name] for name in assign}
    return Allocation(
        assignment={k: tuple(sorted(v)) for k, v in assign.items()},
        goodputs=goodputs,
        fractions=fractions,
    )


_ENGINES = ("batched", "jax", "scalar")


def allocate(
    jobs: Sequence[JobSpec],
    n_nodes: int,
    *,
    engine: str = "batched",
    unavailable: Sequence[int] = (),
) -> Allocation:
    """Greedy marginal-gain node assignment.

    Seeds every job with its single best node (by marginal goodput), then
    assigns remaining nodes to the job with the largest *normalized*
    marginal gain (gain / solo goodput) — normalization prevents one large
    job from starving small ones (the same normalization Pollux's fair
    goodput objective uses).

    ``engine="batched"`` (default) keeps one fixed-layout stacked problem
    and re-solves only the rows each round changed, warm-started from the
    previous round's ``t_stars``; ``engine="jax"`` runs those stacked
    solves jit-compiled on-device; ``engine="scalar"`` is the per-pair loop
    oracle.  All engines iterate candidates in ascending node id and jobs
    in caller order, so tie-breaking matches across engines.

    ``unavailable`` lists node ids that must not be assigned (down/drained
    nodes).  The stacked row layout is unchanged — masked nodes are simply
    pre-marked taken — so warm seeds and cached rows survive node churn.
    """
    if engine not in _ENGINES:
        raise ValueError(f"unknown allocate engine {engine!r}")
    bad = [i for i in unavailable if not 0 <= int(i) < n_nodes]
    if bad:
        # Without this check the engines would diverge: negative ids alias
        # real rows in the array engine but are ignored by the scalar one.
        raise ValueError(f"unavailable node ids out of range: {sorted(bad)}")
    if not jobs:
        return Allocation({}, {}, {})
    if len({j.name for j in jobs}) != len(jobs):
        raise ValueError("job names must be unique")
    solo = {j.name: max(j.solo_goodput(), 1e-12) for j in jobs}
    if engine == "scalar":
        return _allocate_scalar(jobs, n_nodes, solo, unavailable)
    return _allocate_arrays(
        jobs, n_nodes, engine, solo=solo, round_scalar=False,
        unavailable=unavailable,
    )


class Scheduler:
    """Stateful cluster allocator with incremental re-allocation.

    Holds the live job set and the caches that make re-allocation on job
    arrival/departure cheap: solo goodputs, per-(job, node-set) marginal
    rows from previous runs (exact reuse while the greedy trajectory
    replays), and chosen-set scalar goodputs.  ``add_job``/``remove_job``
    re-run the greedy loop against those caches so only the affected rows
    are actually solved; the emitted allocation matches a cold
    :func:`allocate` over the same job set (exactly, barring rounds whose
    competing gains differ by less than the round solver's resolution —
    see ``_allocate_arrays``).

    ``update_job`` is the per-epoch OLS-refit entry point: the refreshed
    job's cached rows (and the stacked device exports behind them) are
    invalidated before re-allocating — reusing them would solve the old
    coefficient regime (see
    :meth:`~repro.core.perf_model.StackedClusterModel.invalidate_device_cache`).

    Observability: ``warm_rounds``/``cold_rounds`` count block solves by
    bracket seeding, ``solved_rows``/``cached_rows`` count marginal rows
    actually solved vs reused from cache.
    """

    def __init__(
        self,
        n_nodes: int,
        *,
        engine: str = "batched",
        cache_limit: Optional[int] = None,
    ):
        if engine not in _ENGINES:
            raise ValueError(f"unknown allocate engine {engine!r}")
        if cache_limit is not None and cache_limit < 1:
            raise ValueError("cache_limit must be >= 1")
        self.n_nodes = n_nodes
        self.engine = engine
        # Per-job bound on cached marginal rows / chosen-set goodputs (FIFO
        # eviction); None = the 8*n_nodes default of `_allocate_arrays`.
        self.cache_limit = cache_limit
        self.allocation: Optional[Allocation] = None
        self._jobs: Dict[str, JobSpec] = {}
        self._down: Set[int] = set()
        self._solo: Dict[str, float] = {}
        self._gain_cache: Dict[str, Dict[Tuple[int, ...], Tuple[np.ndarray, np.ndarray]]] = {}
        self._take_cache: Dict[str, Dict[Tuple[int, ...], float]] = {}
        self.warm_rounds = 0
        self.cold_rounds = 0
        self.solved_rows = 0
        self.cached_rows = 0
        self.allocations = 0

    @property
    def jobs(self) -> Tuple[JobSpec, ...]:
        return tuple(self._jobs.values())

    def add_job(self, job: JobSpec) -> Allocation:
        if job.name in self._jobs:
            raise ValueError(f"job {job.name!r} already scheduled")
        self._jobs[job.name] = job
        return self.reallocate()

    def remove_job(self, name: str) -> Allocation:
        if name not in self._jobs:
            raise KeyError(name)
        del self._jobs[name]
        self._drop_job_state(name)
        return self.reallocate()

    def update_job(self, job: JobSpec) -> Allocation:
        """Replace a job's spec after a coefficient refresh (OLS refit).

        The refreshed job's cached marginal rows, chosen-set goodputs, and
        solo normalizer are all stale for the new coefficient regime and
        are dropped before re-allocating; warm bracket seeds for the other
        jobs stay valid (their problems did not change)."""
        if job.name not in self._jobs:
            raise KeyError(job.name)
        self._jobs[job.name] = job
        self._drop_job_state(job.name)
        return self.reallocate()

    def node_leave(self, node_ids: Sequence[int]) -> Allocation:
        """Mark nodes unavailable (failure/drain) and re-allocate.

        The stacked row layout is indexed by the *full* cluster, so a down
        node does not shift any rows: cached marginal rows and warm bracket
        seeds (which depend only on (job, node set), never on availability)
        replay exactly — node churn costs an incremental re-run, not a cold
        one.  Down nodes are simply never assigned."""
        ids = {int(i) for i in node_ids}
        bad = [i for i in ids if not 0 <= i < self.n_nodes]
        if bad:
            raise ValueError(f"node ids out of range: {sorted(bad)}")
        self._down |= ids
        return self.reallocate()

    def node_join(self, node_ids: Sequence[int]) -> Allocation:
        """Mark previously-down nodes available again and re-allocate."""
        self._down -= {int(i) for i in node_ids}
        return self.reallocate()

    @property
    def down_nodes(self) -> Tuple[int, ...]:
        return tuple(sorted(self._down))

    @property
    def available_nodes(self) -> int:
        return self.n_nodes - len(self._down)

    def invalidate(self) -> None:
        """Drop every cache (cluster-membership or bulk-refresh changes)."""
        self._solo.clear()
        self._gain_cache.clear()
        self._take_cache.clear()

    def _drop_job_state(self, name: str) -> None:
        self._solo.pop(name, None)
        self._gain_cache.pop(name, None)
        self._take_cache.pop(name, None)

    def reallocate(self) -> Allocation:
        """Re-run the greedy loop against the incremental caches."""
        jobs = self.jobs
        self.allocations += 1
        if not jobs:
            self.allocation = Allocation({}, {}, {})
            return self.allocation
        for job in jobs:
            if job.name not in self._solo:
                self._solo[job.name] = max(job.solo_goodput(), 1e-12)
        solo = {j.name: self._solo[j.name] for j in jobs}
        down = tuple(sorted(self._down))
        if self.engine == "scalar":
            self.allocation = _allocate_scalar(jobs, self.n_nodes, solo, down)
        else:
            self.allocation = _allocate_arrays(
                jobs, self.n_nodes, self.engine, solo=solo, round_scalar=False,
                gain_cache=self._gain_cache, take_cache=self._take_cache,
                counters=self, unavailable=down, cache_limit=self.cache_limit,
            )
        return self.allocation


def aggregate_goodput(jobs: Sequence[JobSpec], allocation: Allocation) -> float:
    """Sum of per-job goodputs, with non-finite entries treated as 0.0 (a
    zero-node or garbage-fit job must not poison the aggregate with NaN)."""
    return allocation.aggregate_goodput


def random_jobs(n_jobs: int, n_nodes: int, seed: int = 42) -> List[JobSpec]:
    """Seeded random job mix over the GPU catalog — the shared scenario
    generator for the scheduler benchmark gates and the engine-parity tests
    (one source so both always exercise the same distribution)."""
    from repro.core.simulator import GPU_CATALOG  # local: keep import graph lean

    rng = np.random.default_rng(seed)
    names = list(GPU_CATALOG)
    jobs = []
    for j in range(n_jobs):
        models = tuple(
            GPU_CATALOG[names[int(rng.integers(len(names)))]]
            .scaled(float(rng.uniform(0.5, 2.0)))
            .model()
            for _ in range(n_nodes)
        )
        jobs.append(
            JobSpec(
                name=f"job{j}",
                node_models=models,
                comm=CommModel(
                    t_o=float(rng.uniform(0.01, 0.08)),
                    t_u=float(rng.uniform(0.002, 0.02)),
                    gamma=float(rng.uniform(0.05, 0.4)),
                ),
                total_batch=int(rng.choice([256, 512, 1024, 2048])),
                b_noise=float(rng.uniform(100, 5000)),
                ref_batch=64,
                min_nodes=int(rng.integers(1, 3)),
            )
        )
    return jobs

"""Roofline analysis from the dry-run artifacts (deliverable g).

For every (arch x shape) on the single-pod mesh (256 x TPU v5e):

  compute term    = per-device matmul+vector FLOPs / 197 TFLOP/s (bf16)
  memory term     = per-device HBM bytes accessed / 819 GB/s
  collective term = per-device collective bytes / 50 GB/s (per-link ICI)

All per-device numbers are trip-count-corrected from the optimized HLO
(launch/hlo_stats.py) — XLA's cost_analysis counts while bodies once, which
undercounts scan-over-layers programs by ~L x microbatches (documented in
EXPERIMENTS.md).  MODEL_FLOPS uses 6*N_active*D for training (2x fwd + 4x
bwd), 2*N_active*D for prefill/decode forward-only, giving the
useful-compute ratio (remat + attention + dispatch overhead show up here).

Output: a markdown + json table and, per pair, the dominant bottleneck and a
one-line "what would move it" note.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import ARTIFACTS, Row, save_json

PEAK_FLOPS = 197e12     # TPU v5e bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

_ACTIVE_CACHE: Dict[str, int] = {}


def _active_params(arch: str) -> int:
    if arch not in _ACTIVE_CACHE:
        from repro.configs import get_api

        _ACTIVE_CACHE[arch] = get_api(arch).active_param_count()
    return _ACTIVE_CACHE[arch]


def model_flops(arch: str, shape_name: str, kind: str, seq: int, batch: int) -> float:
    n = _active_params(arch)
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * batch


SHAPE_META = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}


def _advice(dominant: str, rec: Dict) -> str:
    kind = rec["kind"]
    by_kind = rec["hlo"].get("collective_by_kind", {})
    biggest_coll = max(by_kind, key=by_kind.get) if by_kind else "none"
    if dominant == "collective":
        return (
            f"dominated by {biggest_coll}; reduce TP activation traffic "
            "(reduce-scatter/sequence-sharding instead of all-reduce, or a "
            "narrower model axis for this size)"
        )
    if dominant == "memory":
        if kind == "decode":
            return "HBM-bound on KV/state streaming: shrink cache dtype or shard cache wider"
        return "HBM-bound: increase arithmetic intensity (larger microbatch, fuse optimizer)"
    return "compute-bound: already MXU-limited; gains only from removing redundant FLOPs (remat policy, causal-skip attention)"


def analyze(record: Dict) -> Optional[Dict]:
    if record.get("status") != "ok":
        return None
    hlo = record["hlo"]
    kind, seq, batch = SHAPE_META[record["shape"]]
    chips = record["n_devices"]
    compute_t = hlo["flops"] / PEAK_FLOPS
    memory_t = hlo["bytes_accessed"] / HBM_BW
    collective_t = hlo["collective_bytes"] / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": collective_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops(record["arch"], record["shape"], kind, seq, batch)
    hlo_flops_global = hlo["matmul_flops"] * chips
    useful = mf / hlo_flops_global if hlo_flops_global else 0.0
    bound = max(terms.values())
    return {
        "arch": record["arch"],
        "shape": record["shape"],
        "mesh": record["mesh"],
        "kind": kind,
        "chips": chips,
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": collective_t,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": useful,
        "advice": _advice(dominant, record),
        "collective_by_kind": hlo.get("collective_by_kind", {}),
        "fallbacks": record.get("fallbacks", ""),
    }


def load_all(dryrun_dir: Optional[str] = None) -> List[Dict]:
    dryrun_dir = dryrun_dir or os.path.join(ARTIFACTS, "dryrun")
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze(rec)
        if row:
            out.append(row)
    return out


def markdown_table(rows: List[Dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful (6ND/HLO) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} |"
        )
    return "\n".join(lines)


def run() -> List[Row]:
    rows = load_all()
    if not rows:
        return [Row("roofline/missing", 0.0, "run launch/dryrun first")]
    save_json("roofline", rows)
    md = markdown_table(rows, "single")
    with open(os.path.join(ARTIFACTS, "roofline_single_pod.md"), "w") as f:
        f.write(md + "\n")
    out: List[Row] = []
    singles = [r for r in rows if r["mesh"] == "single"]
    by_dom = {}
    for r in singles:
        by_dom.setdefault(r["dominant"], []).append(r)
    for dom, group in sorted(by_dom.items()):
        out.append(Row(f"roofline/dominant/{dom}", 0.0, f"count={len(group)}"))
    worst = min(singles, key=lambda r: r["useful_ratio"])
    out.append(
        Row(
            "roofline/worst_useful_ratio",
            0.0,
            f"{worst['arch']}/{worst['shape']}={worst['useful_ratio']:.3f}",
        )
    )
    return out

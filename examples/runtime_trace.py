"""Multi-job churn through the ClusterRuntime front door (Pollux/Sia-style
cluster simulation).

    python examples/runtime_trace.py
    python examples/runtime_trace.py --faults chaos-small

Replays one synthetic 3-job trace — staggered arrivals, one departure, one
node failure — under all three allocation policies (cannikin / static /
fair-share) with two simulated training epochs between events, then prints
one comparable summary.  Exits nonzero if any invariant breaks, so CI can
run it as an end-to-end smoke.

With ``--faults chaos``/``chaos-small`` the cannikin replay additionally
runs under the named seeded :class:`~repro.runtime.faults.FaultPlan` —
a node crash (silent stop), a transient straggler, a flapping node, a
measurement-noise spike — with the HealthMonitor detecting from telemetry
and the runtime self-healing through its own event alphabet: no
human-scripted recovery events anywhere in the trace.
"""
import argparse
import tempfile

import _common  # noqa: F401  (sys.path bootstrap)

from repro.runtime import (
    FAULT_PLANS,
    compare_policies,
    format_summary,
    make_fault_plan,
    replay,
    synthetic_trace,
)

N_NODES = 12


def run_chaos(plan_name: str) -> None:
    """The chaos smoke: a faulted cannikin replay must self-heal."""
    trace, jobs = synthetic_trace(3, N_NODES, seed=0)
    plan = make_fault_plan(plan_name, N_NODES, seed=0)
    assert plan is not None
    print(f"\n=== chaos replay ({plan_name}) ===")
    for line in plan.describe():
        print(f"  inject: {line}")
    with tempfile.TemporaryDirectory() as ckpt_dir:
        rep = replay(
            trace, N_NODES, policy="cannikin", epochs_per_event=6, steps=2,
            noise=0.01, seed=0, faults=plan, checkpoint_dir=ckpt_dir,
        )
    rt = rep.runtime
    telemetry = rt.fault_telemetry()
    assert telemetry is not None
    for r in rt.recovery_log:
        print(f"  recover: epoch={r['epoch']:>3} {r['action']:<14} "
              f"node={r['node']} jobs={list(r['jobs'])}")
    print(f"  detected: {telemetry['detected']}  "
          f"latency={telemetry['detection_latency_epochs']} epochs  "
          f"mttr={telemetry['mttr_epochs']} epochs")
    print(f"  goodput retention vs fault-free replay: "
          f"{rep.goodput_retention:.3f}")

    # Chaos invariants (CI smoke gate) ------------------------------------
    # Every job still completes or trains — zero human-scripted recovery.
    for name, state in rep.job_states.items():
        assert state in ("done", "running"), f"{name} ended {state}"
    assert rep.job_states[jobs[0].name] == "done", "departure lost under chaos"
    for handle in rt.jobs("running"):
        assert handle.epochs_run > 0, f"{handle.name} never trained"
    # >= 1 crash detected, and recovered through the checkpoint-restore
    # (Preemption) path: the victim was preempted and resumed to RUNNING.
    assert telemetry["detected"]["crash"] >= 1, "crash went undetected"
    crash_recoveries = [
        r for r in rt.recovery_log if r["action"] == "crash_recover"
    ]
    assert crash_recoveries, "crash never recovered"
    for r in crash_recoveries:
        for victim in r["jobs"]:
            h = rt.handles[victim]
            assert h.preemptions >= 1, f"{victim}: no preemption checkpoint"
            assert h.state in ("running", "done"), f"{victim} not resumed"
    # The straggler was quarantined and re-admitted.
    assert telemetry["recoveries"]["quarantine"] >= 1, "no quarantine"
    assert telemetry["recoveries"]["readmit"] >= 1, "no re-admission"
    # Goodput retention is reported (the bench gates its level).
    assert rep.goodput_retention is not None and rep.goodput_retention > 0
    print("  chaos invariants OK")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    # chaos-real targets the real backend (gradient poison / checkpoint
    # corruption); this sim-only smoke asserts crash recovery, so it takes
    # the timing-fault plans only — see examples/preempt_resume.py for the
    # real-path chaos lane.
    ap.add_argument("--faults", default="none",
                    choices=[p for p in FAULT_PLANS if p != "chaos-real"],
                    help="seeded fault plan for an extra chaos replay")
    args = ap.parse_args()

    trace, jobs = synthetic_trace(3, N_NODES, seed=0)
    print(f"trace: {len(trace)} events over {N_NODES} nodes, "
          f"jobs={[j.name for j in jobs]}")
    reports = compare_policies(trace, N_NODES, epochs_per_event=2, steps=2)

    print("\n=== per-event reconcile log (cannikin) ===")
    for rec in reports["cannikin"].records:
        assigned = {k: len(v) for k, v in rec.allocation.assignment.items() if v}
        print(f"  t={rec.time:4.1f} {rec.label:<18} nodes/job={assigned} "
              f"agg_goodput={rec.aggregate_goodput:8.1f}")

    print("\n=== policy comparison (same trace) ===")
    print(format_summary(reports))
    counters = reports["cannikin"].runtime.counters()
    print(f"\ncannikin scheduler reuse: {counters}")

    # End-to-end invariants (CI smoke gate) --------------------------------
    for name, rep in reports.items():
        assert rep.aggregate_goodput > 0, f"{name}: no goodput produced"
        assert rep.job_states[jobs[0].name] == "done", f"{name}: departure lost"
        for handle in rep.runtime.jobs("running"):
            assert handle.epochs_run > 0, f"{name}: {handle.name} never trained"
            assert handle.last_plan is not None
        down = rep.runtime.down_nodes
        for ids in rep.runtime.allocation.assignment.values():
            assert not down & set(ids), f"{name}: assigned a down node"
    # Incremental scheduling really was incremental: rows were replayed from
    # cache and later rounds warm-started instead of re-solving cold.
    assert counters["cached_rows"] > 0 and counters["warm_rounds"] > 0
    print("\nall invariants OK")

    if args.faults != "none":
        run_chaos(args.faults)


if __name__ == "__main__":
    main()

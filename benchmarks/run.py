"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes JSON payloads under
artifacts/bench/.

  bench_solver       — Algorithm 1 / water-fill micro-bench (O((n+1)^3) claim)
  bench_sweep        — batched OptPerf engine vs per-candidate scalar sweeps
  bench_adaptation   — Fig. 9: epochs to reach OptPerf (Cannikin vs LB-BSP)
  bench_batchtime    — Fig. 10: batch time vs total batch size, 5 workloads
  bench_convergence  — Fig. 7/8 + Fig. 5: normalized convergence time
  bench_prediction   — §5.3: OptPerf prediction error, IVW vs plain gamma
  bench_overhead     — Table 5: controller overhead per epoch
  bench_kernels      — Pallas kernels (interpret-mode timing + allclose)
  roofline           — §Roofline terms from the dry-run artifacts
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_adaptation,
        bench_batchtime,
        bench_convergence,
        bench_kernels,
        bench_overhead,
        bench_prediction,
        bench_solver,
        bench_sweep,
        roofline,
    )

    modules = [
        ("solver", bench_solver),
        ("sweep", bench_sweep),
        ("adaptation", bench_adaptation),
        ("batchtime", bench_batchtime),
        ("convergence", bench_convergence),
        ("prediction", bench_prediction),
        ("overhead", bench_overhead),
        ("kernels", bench_kernels),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for row in mod.run():
                print(row.csv(), flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

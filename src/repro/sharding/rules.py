"""Logical-axis sharding rules.

Models annotate every parameter/activation dimension with a *logical* axis
name; this module resolves logical names to physical mesh axes with
divisibility checking (GSPMD/jax rejects uneven shardings at jit boundaries),
falling back to replication and *recording* every fallback so the roofline
report can explain replicated-attention archs (minitron 24H, whisper 20H,
hymba 25H on a 16-way model axis).

Logical axes:
  batch      — global batch dim                -> ("pod","data") / ("data",)
  heads      — attention query heads           -> "model" (TP)
  kv_heads   — GQA key/value heads             -> "model" if divisible else None
  embed      — d_model dim of weight matrices  -> fsdp axis if cfg.fsdp else None
  ff         — feed-forward hidden             -> "model"
  vocab      — vocabulary dim                  -> "model"
  experts    — MoE expert dim                  -> None (experts 2D-sharded via embed/ff)
  cache_seq  — KV-cache sequence dim in decode -> "model" (+ "data" for B=1 long ctx)
  seq        — activation sequence dim         -> None (no sequence parallelism v0)
  nodes      — heterogeneous-cluster node dim  -> node_axis (RealBackend shard_map)
  None       — replicated
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["MeshRules", "Fallback"]

AxisAssignment = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Fallback:
    """Record of a logical axis we could not shard as requested."""

    path: str
    dim: int
    logical: str
    wanted: AxisAssignment
    size: int
    reason: str


@dataclasses.dataclass
class MeshRules:
    """Resolves logical axis names to mesh axes for one (mesh, arch) pair."""

    mesh_axes: Dict[str, int]                 # physical axis name -> size
    batch_axes: Tuple[str, ...] = ("data",)   # ("pod","data") on multi-pod
    model_axis: str = "model"
    fsdp_axis: Optional[str] = None           # "data" to enable FSDP/ZeRO-3
    cache_seq_axes: Tuple[str, ...] = ("model",)
    experts_axis: Optional[str] = None        # "model" for expert parallelism
    node_axis: Optional[str] = None           # "nodes" on the RealBackend node mesh
    fallbacks: List[Fallback] = dataclasses.field(default_factory=list)

    def _assignment(self, logical: Optional[str]) -> AxisAssignment:
        if logical is None:
            return None
        table: Dict[str, AxisAssignment] = {
            "batch": self.batch_axes,
            "heads": self.model_axis,
            "kv_heads": self.model_axis,
            "embed": self.fsdp_axis,
            "ff": self.model_axis,
            "vocab": self.model_axis,
            "experts": self.experts_axis,
            "cache_seq": self.cache_seq_axes,
            "seq": None,
            "ssm_inner": self.model_axis,
            "nodes": self.node_axis,
        }
        if logical not in table:
            raise KeyError(f"unknown logical axis {logical!r}")
        return table[logical]

    def _axes_size(self, assignment: AxisAssignment) -> int:
        if assignment is None:
            return 1
        if isinstance(assignment, str):
            return self.mesh_axes[assignment]
        return int(np.prod([self.mesh_axes[a] for a in assignment]))

    def spec(
        self,
        logical_axes: Sequence[Optional[str]],
        shape: Optional[Sequence[int]] = None,
        *,
        path: str = "",
    ) -> P:
        """PartitionSpec for a tensor with given logical axes and shape.

        If ``shape`` is provided, every dim must be divisible by its mapped
        mesh extent or the dim falls back to replication (recorded).
        """
        parts: List[AxisAssignment] = []
        used: set = set()
        for i, logical in enumerate(logical_axes):
            assignment = self._assignment(logical)
            if assignment is not None:
                axes = (assignment,) if isinstance(assignment, str) else tuple(assignment)
                if used & set(axes):
                    # A mesh axis may appear once per spec; earlier dims win
                    # (e.g. decode caches: cache_seq takes "model", so a
                    # 16-divisible kv_heads dim falls back to replication).
                    self.fallbacks.append(
                        Fallback(
                            path=path, dim=i, logical=logical or "",
                            wanted=assignment, size=-1 if shape is None else shape[i],
                            reason="mesh axis already used by an earlier dim",
                        )
                    )
                    assignment = None
                elif shape is not None:
                    extent = self._axes_size(assignment)
                    if shape[i] % extent != 0:
                        self.fallbacks.append(
                            Fallback(
                                path=path,
                                dim=i,
                                logical=logical or "",
                                wanted=assignment,
                                size=shape[i],
                                reason=f"{shape[i]} % {extent} != 0",
                            )
                        )
                        assignment = None
                if assignment is not None:
                    used |= set(axes)
                    # Collapse singleton tuples to the bare axis name so
                    # configured tuple forms like ("data",) produce the same
                    # PartitionSpec as "data" (jax treats them equivalently
                    # but spec equality does not).
                    assignment = axes[0] if len(axes) == 1 else tuple(axes)
            parts.append(assignment)
        # Trim trailing Nones for tidier specs.
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def batch_spec(self, extra_dims: int = 1) -> P:
        """Spec for (batch, seq, ...) activations.  Single-axis batch meshes
        collapse to the bare axis name, matching what :meth:`spec` emits."""
        axes = self.batch_axes[0] if len(self.batch_axes) == 1 else tuple(self.batch_axes)
        return P(axes, *([None] * extra_dims))

    def fallback_report(self) -> str:
        if not self.fallbacks:
            return "no sharding fallbacks"
        lines = []
        seen = set()
        for f in self.fallbacks:
            key = (f.path, f.dim)
            if key in seen:
                continue
            seen.add(key)
            lines.append(
                f"{f.path} dim{f.dim} ({f.logical}={f.size}) -> replicated ({f.reason})"
            )
        return "\n".join(lines)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory/cost/collective statistics.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices let jax.make_mesh build the
16x16 (single-pod) and 2x16x16 (multi-pod) meshes; `.lower().compile()`
must succeed for every combination; `memory_analysis()` proves fit;
`cost_analysis()` + HLO collective parsing feed the §Roofline report.

Results are written incrementally to JSON (one file per combo) so reruns
skip finished work:  artifacts/dryrun/<arch>__<shape>__<mesh>.json

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, arch_ids, get_api
from repro.launch.hlo_stats import analyze_hlo, raw_cost_analysis
from repro.sharding.context import sharding_context
from repro.launch.mesh import (
    enter_mesh,
    make_production_mesh,
    make_rules,
    train_microbatches,
)
from repro.models import common
from repro.optim import adamw, constant_schedule
from repro.train.step import build_train_step

DTYPE_BYTES = {
    "pred": 0.125, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|((?:[a-z0-9]+)\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += int(n * DTYPE_BYTES[dtype])
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-device bytes moved by collectives, from the optimized HLO.

    Convention: a collective op's cost is the byte size of its (tuple)
    result — for all-gather that is the gathered buffer, for all-reduce the
    reduced buffer, for reduce-scatter the scattered shard (we add operand
    sizes would double-count fusions; the result-size convention is uniform
    and monotone in actual link traffic).
    """
    per_kind: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        tuple_body, single, kind = m.group(1), m.group(2), m.group(3)
        text = tuple_body if tuple_body is not None else single
        b = _shape_bytes(text or "")
        per_kind[kind] = per_kind.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {
        "bytes_by_kind": per_kind,
        "count_by_kind": counts,
        "total_bytes": int(sum(per_kind.values())),
    }


def _memory_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        if hasattr(ma, attr):
            out[attr] = int(getattr(ma, attr))
    if not out:
        out["repr"] = str(ma)
    return out


def _cost_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ca = raw_cost_analysis(compiled)
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    keep = ("flops", "transcendentals", "bytes accessed")
    return {
        k: float(v)
        for k, v in ca.items()
        if isinstance(v, (int, float)) and any(k.startswith(p) for p in keep)
        and "{" not in k.replace("{}", "")
    }


# ---------------------------------------------------------------------------
# step builders per shape kind
# ---------------------------------------------------------------------------


def build_dryrun(api, shape, mesh, rules):
    """Returns (fn, args_shapedtypes, in_shardings)."""
    arch_id = api.arch_id
    if shape.kind == "train":
        opt = adamw(constant_schedule(1e-4))
        batch_extent = int(np.prod([
            mesh.devices.shape[list(mesh.axis_names).index(a)]
            for a in rules.batch_axes
        ]))
        mb = train_microbatches(
            arch_id, global_batch=shape.global_batch, batch_extent=batch_extent
        )
        batch_specs = api.train_batch_specs(shape.global_batch, shape.seq_len)
        mb_shardings = {
            name: NamedSharding(
                mesh, rules.batch_spec(extra_dims=len(sds.shape) - 1)
            )
            for name, sds in batch_specs.items()
        }
        step = build_train_step(
            api,
            opt,
            microbatches=mb,
            with_metrics=False,
            microbatch_shardings=mb_shardings,
        )
        params_sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
        opt_sds = jax.eval_shape(opt.init, params_sds)
        pspecs = api.specs(rules)
        ospecs = _opt_specs(opt_sds, pspecs)
        bspecs = api.batch_sharding(rules, batch_specs)
        fn = lambda params, opt_state, batch: step(params, opt_state, batch)
        args = (params_sds, opt_sds, batch_specs)
        shardings = (
            _named(mesh, pspecs),
            _named(mesh, ospecs),
            _named(mesh, bspecs),
        )
        out_shardings = (_named(mesh, pspecs), _named(mesh, ospecs), None)
        return fn, args, shardings, out_shardings

    if shape.kind == "prefill":
        batch_specs = api.train_batch_specs(shape.global_batch, shape.seq_len)
        batch_specs.pop("labels", None)
        batch_specs.pop("weights", None)
        params_sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
        pspecs = api.specs(rules)
        bspecs = api.batch_sharding(rules, batch_specs)

        def fn(params, batch):
            logits = api.logits(params, batch)
            return logits[:, -1]  # next-token distribution

        args = (params_sds, batch_specs)
        shardings = (_named(mesh, pspecs), _named(mesh, bspecs))
        return fn, args, shardings, None

    # decode
    params_sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    cache_sds = jax.eval_shape(
        lambda: api.init_cache(shape.global_batch, shape.seq_len)
    )
    pspecs = api.specs(rules)
    cspecs = api.cache_specs(rules, shape.global_batch, shape.seq_len)
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    tok_spec = rules.spec(("batch", None), tok_sds.shape, path="tokens")

    def fn(params, cache, tokens, pos):
        return api.decode_step(params, cache, tokens, pos)

    args = (params_sds, cache_sds, tok_sds, pos_sds)
    shardings = (
        _named(mesh, pspecs),
        _named(mesh, cspecs),
        NamedSharding(mesh, tok_spec),
        NamedSharding(mesh, P()),
    )
    out_shardings = (None, _named(mesh, cspecs))
    return fn, args, shardings, out_shardings


def _named(mesh, specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _opt_specs(opt_sds, pspecs):
    """Optimizer moments inherit parameter specs; scalars replicated."""
    flat_p, _ = jax.tree_util.tree_flatten(pspecs, is_leaf=lambda x: isinstance(x, P))

    def match(sds_tree):
        flat_s, treedef = jax.tree_util.tree_flatten(sds_tree)
        # Moment trees mirror the params tree; step counters are scalars.
        out = []
        pi = 0
        for leaf in flat_s:
            if hasattr(leaf, "shape") and leaf.shape == ():
                out.append(P())
            else:
                out.append(flat_p[pi % len(flat_p)])
                pi += 1
        return jax.tree_util.tree_unflatten(treedef, out)

    return match(opt_sds)


# ---------------------------------------------------------------------------


def applicable(api, shape) -> bool:
    if shape.name == "long_500k" and not api.supports_long_context():
        return False
    return True


def run_one(arch_id: str, shape_name: str, mesh_kind: str, outdir: str, *, force=False) -> Dict:
    outpath = os.path.join(outdir, f"{arch_id}__{shape_name}__{mesh_kind}.json")
    if os.path.exists(outpath) and not force:
        with open(outpath) as f:
            return json.load(f)
    shape = SHAPES[shape_name]
    api = get_api(arch_id)
    record: Dict[str, Any] = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": shape.kind,
        "param_count": api.param_count(),
    }
    if not applicable(api, shape):
        record["status"] = "skipped"
        record["reason"] = "long_500k requires sub-quadratic decode (DESIGN.md §5)"
        _write(outpath, record)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = make_rules(
        mesh, arch_id, kind=shape.kind, global_batch=shape.global_batch
    )
    t0 = time.time()
    try:
        fn, args, in_shardings, out_shardings = build_dryrun(api, shape, mesh, rules)
        with enter_mesh(mesh), sharding_context(mesh, rules):
            jitted = jax.jit(
                fn,
                in_shardings=in_shardings,
                out_shardings=out_shardings,
            )
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        hlo_text = compiled.as_text()
        record.update(
            status="ok",
            lower_seconds=round(t_lower, 2),
            compile_seconds=round(t_compile, 2),
            n_devices=int(np.prod(mesh.devices.shape)),
            memory=_memory_analysis_dict(compiled),
            cost_raw=_cost_analysis_dict(compiled),
            collectives_raw=collective_bytes(hlo_text),
            # Trip-count-corrected per-device stats (launch/hlo_stats.py) —
            # the §Roofline source of truth (cost_raw counts while bodies
            # once; see EXPERIMENTS.md).
            hlo=analyze_hlo(hlo_text).as_dict(),
            fallbacks=rules.fallback_report(),
        )
    except Exception as e:
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    _write(outpath, record)
    return record


def _write(path: str, record: Dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = arch_ids() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_one(arch, shape, mesh_kind, args.out, force=args.force)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    flops = rec["hlo"].get("matmul_flops", 0)
                    cb = rec["hlo"].get("collective_bytes", 0)
                    extra = (
                        f"lower={rec['lower_seconds']}s compile={rec['compile_seconds']}s "
                        f"flops/dev={flops:.3g} coll={cb/1e6:.1f}MB"
                    )
                elif status == "error":
                    failures += 1
                    extra = rec["error"][:160]
                print(f"[{status:7s}] {arch:18s} {shape:12s} {mesh_kind:6s} {extra}",
                      flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

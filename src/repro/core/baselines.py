"""Baseline batch-partition policies the paper compares against (§5.1).

Every policy exposes the same interface as the Cannikin controller:
``partition(total_batch, epoch, last_measurement) -> List[int]`` so the
simulator / trainer can drive any of them interchangeably.

* :class:`EvenPartition`   — PyTorch DDP / AdaptDL: equal local batches.
  (AdaptDL additionally adapts the *total* batch size; in heterogeneous
  clusters its per-node split is still even — §5.2.2 notes its batch
  processing time equals DDP's.)
* :class:`LBBSPPartition`  — LB-BSP (Chen et al., SoCC'20): semi-dynamic
  load balancing; after each epoch moves ``delta`` samples from the slowest
  node to the fastest node (step size Δ=5 per the paper's evaluation).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.optperf import round_batches
from repro.core.simulator import StepMeasurement

__all__ = ["EvenPartition", "LBBSPPartition"]


class EvenPartition:
    """DDP / AdaptDL split: b_i = B / n (largest-remainder rounded)."""

    name = "even"

    def __init__(self, n_nodes: int) -> None:
        self.n = n_nodes

    def partition(
        self,
        total_batch: int,
        epoch: int,
        last: Optional[StepMeasurement] = None,
    ) -> List[int]:
        return round_batches([total_batch / self.n] * self.n, total_batch)


class LBBSPPartition:
    """LB-BSP-style iterative tuner.

    Each epoch: compute per-node sample throughput from the last measurement,
    then shift up to ``delta`` samples from the slowest (per-sample time) node
    to the fastest.  Converges to equal compute times but needs many epochs
    (paper Fig. 9: >10 epochs vs Cannikin's 3) and re-converges from scratch
    whenever the total batch size changes.
    """

    name = "lb-bsp"

    def __init__(self, n_nodes: int, delta: int = 5) -> None:
        self.n = n_nodes
        self.delta = delta
        self._batches: Optional[List[int]] = None
        self._last_total: Optional[int] = None

    def partition(
        self,
        total_batch: int,
        epoch: int,
        last: Optional[StepMeasurement] = None,
    ) -> List[int]:
        if self._batches is None or self._last_total != total_batch:
            # Restart from even on any total-batch change (LB-BSP's weakness
            # under adaptive batch sizing, §5.2.2 "With adaptive batch size").
            self._batches = round_batches(
                [total_batch / self.n] * self.n, total_batch
            )
            self._last_total = total_batch
            return list(self._batches)
        if last is not None:
            obs = last.observations
            per_sample = np.array(
                [
                    (o.a_time + o.backprop_time) / max(o.batch_size, 1.0)
                    for o in obs
                ]
            )
            # Straggler = largest *total* compute time; recipient = node that
            # would finish soonest with extra work.
            totals = np.array([o.a_time + o.backprop_time for o in obs])
            slow = int(np.argmax(totals))
            fast = int(np.argmin(per_sample))
            if slow != fast:
                move = min(self.delta, self._batches[slow] - 1)
                if move > 0:
                    self._batches[slow] -= move
                    self._batches[fast] += move
        return list(self._batches)

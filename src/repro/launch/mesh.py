"""Production meshes and per-(arch, shape) sharding rule assembly.

TPU v5e target: single pod = 16x16 = 256 chips (axes data x model);
multi-pod = 2 pods = 512 chips (pod x data x model).

Functions only — importing this module never touches jax device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.sharding.rules import MeshRules

__all__ = [
    "enter_mesh",
    "make_production_mesh",
    "make_node_mesh",
    "make_rules",
    "mesh_axis_sizes",
    "node_shard_count",
    "FSDP_ARCHS",
    "TRAIN_MICROBATCHES",
]


def enter_mesh(mesh):
    """Version-portable mesh context manager.

    ``jax.set_mesh`` (the context-manager form) only exists in newer jax
    releases; 0.5.x has ``jax.sharding.use_mesh``; on 0.4.x neither exists
    and the ``Mesh`` object itself is the context manager that activates
    the global mesh for jit/with_sharding_constraint resolution.  Returns a
    context manager for ``with enter_mesh(mesh): ...``.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh

# Archs whose parameter+optimizer state exceeds per-chip HBM under 16-way TP
# alone: shard the d_model dim of large matrices over the data axis (FSDP /
# ZeRO-3-style; XLA inserts the all-gathers).
FSDP_ARCHS = {
    "deepseek-v2-236b",
    "chameleon-34b",
    "internlm2-20b",
    "mixtral-8x7b",
}

# Gradient-accumulation microbatches for train_4k (global batch 256).
TRAIN_MICROBATCHES = {
    "default": 8,
    "llama3-8b": 4,
    "deepseek-v2-236b": 16,
    "chameleon-34b": 16,
    "internlm2-20b": 8,
}


def train_microbatches(arch_id: str, *, global_batch: int = 256,
                       batch_extent: int = 1) -> int:
    """Per-arch microbatch count, capped so each microbatch still fills the
    batch mesh axes (B/mb >= batch_extent) — otherwise the microbatch loses
    its batch sharding and activations replicate (observed 3.5x FLOPs/dev on
    the 2x16x16 mesh with mb=16: 256/16 = 16 rows < 32 shards)."""
    mb = TRAIN_MICROBATCHES.get(arch_id, TRAIN_MICROBATCHES["default"])
    max_mb = max(global_batch // max(batch_extent, 1), 1)
    return min(mb, max_mb)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def node_shard_count(n_nodes: int, device_count: Optional[int] = None) -> int:
    """Shard count for the RealBackend node axis: the largest divisor of
    ``n_nodes`` that fits the available local devices.

    Divisibility (rather than padding to the device count) is deliberate:
    padded zero-mask node rows would drag the nanmedian inside
    ``guard_weights`` toward zero and flag every real node as an outlier,
    so the node axis is never padded — shards just get n/D nodes each.
    """
    if n_nodes <= 0:
        raise ValueError("n_nodes must be positive")
    if device_count is None:
        device_count = jax.local_device_count()
    d = max(1, min(n_nodes, device_count))
    while n_nodes % d:
        d -= 1
    return d


def make_node_mesh(n_nodes: int, devices=None):
    """1-D ``("nodes",)`` mesh over local devices for the sharded RealBackend.

    Uses the first ``node_shard_count(n_nodes)`` local devices so the padded
    ``(n, b_max)`` node axis splits evenly across shards.
    """
    import numpy as np

    if devices is None:
        devices = jax.local_devices()
    d = node_shard_count(n_nodes, len(devices))
    return jax.sharding.Mesh(np.asarray(devices[:d]), ("nodes",))


def make_rules(
    mesh,
    arch_id: str,
    *,
    kind: str = "train",
    global_batch: Optional[int] = None,
) -> MeshRules:
    """MeshRules for one (mesh, arch, shape-kind) combination.

    Decode KV caches shard their sequence dim over the model axis
    (flash-decode style); when the batch is too small to occupy the data
    axis (long_500k: B=1) the cache sequence also spreads over data.
    """
    sizes = mesh_axis_sizes(mesh)
    multi = "pod" in sizes
    batch_axes: Tuple[str, ...] = ("pod", "data") if multi else ("data",)
    cache_seq: Tuple[str, ...] = ("model",)
    if kind == "decode" and global_batch is not None:
        data_extent = sizes["data"] * (sizes.get("pod", 1))
        if global_batch < data_extent:
            cache_seq = ("pod", "data", "model") if multi else ("data", "model")
    fsdp = "data" if arch_id in FSDP_ARCHS else None
    # Expert parallelism (experts sharded over the model axis) pays off when
    # E >= model-axis extent: deepseek's 160 experts (§Perf: 5.4x less
    # collective traffic than re-sharding capacity over data).
    experts_axis = "model" if arch_id == "deepseek-v2-236b" else None
    return MeshRules(
        mesh_axes=sizes,
        batch_axes=batch_axes,
        model_axis="model",
        fsdp_axis=fsdp,
        cache_seq_axes=cache_seq,
        experts_axis=experts_axis,
    )

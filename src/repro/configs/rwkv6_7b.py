"""rwkv6-7b [ssm] — Finch — 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536, data-dependent decay [arXiv:2404.05892].

64 WKV heads of size 64; O(1) decode state (per-head 64x64 matrix + shift
registers) — runs long_500k natively.
"""
from repro.models.rwkv6 import RWKV6Config

ARCH_ID = "rwkv6-7b"


def config() -> RWKV6Config:
    return RWKV6Config(
        name=ARCH_ID,
        n_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab=65536,
        head_size=64,
        decay_lora=64,
        wkv_chunk=32,
    )


def reduced() -> RWKV6Config:
    return RWKV6Config(
        name=ARCH_ID + "-reduced",
        n_layers=2,
        d_model=128,
        d_ff=256,
        vocab=512,
        head_size=32,
        decay_lora=16,
        wkv_chunk=16,
        remat=False,
    )

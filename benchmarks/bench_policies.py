"""Batch-size-policy lane: the registered policy zoo on one trace.

    PYTHONPATH=src:. python -m benchmarks.bench_policies [--smoke]

Two gates on the standard 3-job synthetic trace:

* **Bit-for-bit refactor gate** — a replay with every job stamped
  ``batch_policy="cannikin-gns"`` must match the legacy (unstamped) replay
  exactly: aggregate goodput, per-job epoch counts, per-job sim clocks,
  and the runtime's solver/cache counters.  The BatchSizePolicy protocol
  is a seam, not a behaviour change.
* **Adaptivity gate** — the schedule-driven dampers must actually move the
  total batch on the gradient-free sim backend (the point of the policy
  zoo): geodamp's mean total batch strictly above the fixed policy's
  starting batch... and every registered policy must produce a ranked row.

Then the lane times one ``compare_policies(batch_policies=all)`` sweep and
emits one row per policy with its goodput decomposition (sample
throughput × statistical efficiency).  Results merge into
``artifacts/bench/sweep.json`` under the ``"policies"`` key.
"""
import argparse
import json
import os
import time

from benchmarks.common import ARTIFACTS, Row, save_json

from repro.core.batch_policy import BATCH_POLICIES
from repro.runtime import (
    compare_policies,
    rank_batch_policies,
    replay,
    synthetic_trace,
)

N_JOBS, N_NODES, SEED = 3, 12, 0
EPOCHS_PER_EVENT, STEPS, NOISE = 2, 2, 0.01


def _trace():
    return synthetic_trace(N_JOBS, N_NODES, seed=SEED)[0]


def _replay(batch_policy=None):
    return replay(
        _trace(), N_NODES, policy="cannikin", epochs_per_event=EPOCHS_PER_EVENT,
        steps=STEPS, noise=NOISE, seed=SEED, batch_policy=batch_policy,
    )


def _fingerprint(rep):
    handles = rep.runtime.handles
    return {
        "aggregate_goodput": rep.aggregate_goodput,
        "aggregate_fraction": rep.aggregate_fraction,
        "epochs": rep.epochs,
        "sim_times": {name: h.sim_time for name, h in handles.items()},
        "counters": rep.runtime.counters(),
    }


def run(smoke: bool = False):
    rows = []

    # Gate 1: cannikin-gns through the protocol == the pre-refactor path,
    # bit for bit (plans, clocks, counters).  Deterministic, so it holds
    # in smoke runs too.
    del smoke
    t0 = time.perf_counter()
    legacy = _replay()
    legacy_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    gns = _replay(batch_policy="cannikin-gns")
    gns_s = time.perf_counter() - t0
    fp_legacy, fp_gns = _fingerprint(legacy), _fingerprint(gns)
    assert fp_legacy == fp_gns, (
        f"cannikin-gns diverged from the pre-refactor path:\n"
        f"legacy={fp_legacy}\nprotocol={fp_gns}"
    )
    rows.append(
        Row(
            f"policies/bitexact_gate/j{N_JOBS}xn{N_NODES}",
            gns_s * 1e6,
            f"agg_goodput={fp_gns['aggregate_goodput']:.6f};identical=1",
        )
    )

    # Policy sweep: every registered law on the same trace ----------------
    t0 = time.perf_counter()
    reports = compare_policies(
        _trace(), N_NODES, batch_policies=(), epochs_per_event=EPOCHS_PER_EVENT,
        steps=STEPS, noise=NOISE, seed=SEED,
    )
    sweep_s = time.perf_counter() - t0
    ranking = rank_batch_policies(reports)
    assert len(ranking) >= 4, f"only {len(ranking)} policies ranked"
    assert len(ranking) == len(BATCH_POLICIES)
    by_name = {row["batch_policy"]: row for row in ranking}

    # Gate 2: adaptivity is live on the sim backend — the geometric damper
    # moved the total batch above its fixed starting point.
    assert (
        by_name["geodamp"]["mean_total_batch"]
        > by_name["adadamp"]["mean_total_batch"]
    ), "geodamp never ramped on the sim backend"

    per_policy = sweep_s / max(1, len(ranking))
    for rank, row in enumerate(ranking, start=1):
        rows.append(
            Row(
                f"policies/{row['batch_policy']}/j{N_JOBS}xn{N_NODES}",
                per_policy * 1e6,
                f"rank={rank};goodput={row['policy_goodput']:.1f};"
                f"eff={row['statistical_efficiency']:.3f};"
                f"meanB={row['mean_total_batch']:.1f}",
            )
        )

    record = {
        "n_jobs": N_JOBS,
        "n_nodes": N_NODES,
        "seed": SEED,
        "epochs_per_event": EPOCHS_PER_EVENT,
        "bitexact_gate": {
            "aggregate_goodput": fp_gns["aggregate_goodput"],
            "legacy_replay_s": legacy_s,
            "protocol_replay_s": gns_s,
            "identical": True,
        },
        "ranking": ranking,
        "sweep_s": sweep_s,
    }

    # Merge into the sweep artifact (keep every other lane's record).
    sweep_path = os.path.join(ARTIFACTS, "bench", "sweep.json")
    payload = {}
    if os.path.exists(sweep_path):
        try:
            with open(sweep_path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    payload["policies"] = record
    save_json("sweep", payload)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for lane-runner symmetry (already CI-sized)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        print(row.csv(), flush=True)


if __name__ == "__main__":
    main()

"""Serving lane: the OptPerf water-fill under live inference traffic.

    PYTHONPATH=src:. python -m benchmarks.bench_serving [--smoke]

Three gated sub-lanes:

* **2-speed-class sim** — a seeded Poisson workload (fixed offered load)
  over 3 fast + 5 slow (8x) nodes with shared per-tick overhead.  Gates:
  OptPerf-driven slot allocation sustains >= 1.15x the uniform split's
  req/s at equal-or-better p99 token latency, both arms drop nothing, and
  same-seed runs are bit-identical (metrics fingerprint match).
* **Churn sim** — the same workload with one NodeLeave mid-stream and a
  later rejoin.  Gate: zero drops (in-flight work requeues and completes).
* **Real engine** — reduced olmo-1b decoding real tokens through the fused
  prefill + jitted decode path (warmed).  Gates: sustained req/s >= a
  pinned floor at the fixed offered load, p99 token latency bounded, zero
  drops.

All lanes are deterministic (seeded workloads; the sim clock is simulated),
so the gates hold in smoke runs too.  Results merge into
``artifacts/bench/sweep.json`` under the ``"serving"`` key.
"""
import argparse
import json
import os
import time

from benchmarks.common import ARTIFACTS, Row, save_json

from repro.runtime.events import NodeJoin, NodeLeave
from repro.serving import (
    ServingAllocator,
    ServingConfig,
    ServingRuntime,
    SimServingEngine,
    generate_requests,
)

# 2-speed-class cluster (see tests/test_serving.py: the same acceptance
# geometry): per-token cost 8x apart, per-tick dispatch overhead shared.
FAST_ALPHA, SLOW_ALPHA, INTERCEPT = 0.004, 0.032, 0.03
N_FAST, N_SLOW, SLOTS = 3, 5, 32
COEFFS = {i: (FAST_ALPHA, INTERCEPT) for i in range(N_FAST)}
COEFFS.update({i: (SLOW_ALPHA, INTERCEPT) for i in range(N_FAST, N_FAST + N_SLOW)})
WORKLOAD = dict(seed=7, rate=56.0, gen_mean=8, gen_max=64,
                token_budget=0.12, ttft_slack=1.0)
SIM_REQUESTS = 400

RATIO_GATE = 1.15

# Real lane: fixed offered load of 50 req/s on reduced olmo; the engine is
# warmed so the floor measures steady-state serving, not XLA compiles.
REAL_FLOOR_REQ_S = 20.0
REAL_P99_BOUND_S = 0.25
REAL_REQUESTS = 24


def _sim_run(mode, post=()):
    wl = generate_requests(SIM_REQUESTS, **WORKLOAD)
    rt = ServingRuntime(
        SimServingEngine(dict(COEFFS)),
        ServingAllocator(dict(COEFFS), total_slots=SLOTS, mode=mode),
        wl,
        nodes=list(range(N_FAST + N_SLOW)),
        config=ServingConfig(total_slots=SLOTS, resolve_every=1.0),
    )
    for ev in post:
        rt.post(ev)
    return rt.run()


def _summ(rep):
    s = rep.summary
    return {
        "sustained_req_s": rep.sustained_req_s,
        "goodput_req_s": rep.goodput_req_s,
        "p99_token_s": s["token_latency"]["p99"],
        "p50_token_s": s["token_latency"]["p50"],
        "deadline_miss_rate": s["deadline_miss_rate"],
        "dropped": s["dropped"],
        "requeues": s["requeues"],
        "allocations": {str(k): v for k, v in rep.allocations.items()},
    }


def _run_sim_lanes(rows):
    t0 = time.perf_counter()
    opt = _sim_run("optperf")
    uni = _sim_run("uniform")
    rerun = _sim_run("optperf")
    churn = _sim_run(
        "optperf",
        post=[NodeLeave(time=2.0, nodes=(0, 4)), NodeJoin(time=5.0, nodes=(0,))],
    )
    sim_s = time.perf_counter() - t0

    ratio = opt.sustained_req_s / uni.sustained_req_s
    goodput_ratio = opt.goodput_req_s / uni.goodput_req_s
    assert opt.summary["dropped"] == 0 and uni.summary["dropped"] == 0
    assert ratio >= RATIO_GATE, (
        f"water-fill sustained advantage {ratio:.3f} below gate {RATIO_GATE}"
    )
    assert (
        opt.summary["token_latency"]["p99"] <= uni.summary["token_latency"]["p99"]
    ), "water-fill regressed p99 token latency vs uniform"
    assert rerun.fingerprint == opt.fingerprint, "same-seed run not bit-identical"
    assert churn.summary["dropped"] == 0, "churn lane dropped requests"
    assert churn.counters["requeued"] > 0

    wl = generate_requests(SIM_REQUESTS, **WORKLOAD)
    record = {
        "cluster": {
            "fast_nodes": N_FAST, "slow_nodes": N_SLOW,
            "alpha_fast": FAST_ALPHA, "alpha_slow": SLOW_ALPHA,
            "intercept": INTERCEPT, "slots": SLOTS,
        },
        "offered_req_s": wl.offered_load,
        "requests": SIM_REQUESTS,
        "optperf": _summ(opt),
        "uniform": _summ(uni),
        "sustained_ratio": ratio,
        "goodput_ratio": goodput_ratio,
        "ratio_gate": RATIO_GATE,
        "fingerprint": opt.fingerprint,
        "bit_identical": True,
        "churn": {
            **_summ(churn),
            "leaves": churn.counters["leaves"],
            "joins": churn.counters["joins"],
        },
        "sim_wall_s": sim_s,
    }
    rows.append(Row(
        f"serving/sim_optperf/n{N_FAST + N_SLOW}xb{SLOTS}",
        sim_s / 4 * 1e6,
        f"sustained={opt.sustained_req_s:.2f}req/s;"
        f"ratio={ratio:.3f};p99={opt.summary['token_latency']['p99'] * 1e3:.0f}ms",
    ))
    rows.append(Row(
        f"serving/sim_uniform/n{N_FAST + N_SLOW}xb{SLOTS}",
        sim_s / 4 * 1e6,
        f"sustained={uni.sustained_req_s:.2f}req/s;"
        f"p99={uni.summary['token_latency']['p99'] * 1e3:.0f}ms",
    ))
    rows.append(Row(
        f"serving/sim_churn/n{N_FAST + N_SLOW}xb{SLOTS}",
        sim_s / 4 * 1e6,
        f"dropped={churn.summary['dropped']};requeued={churn.counters['requeued']}",
    ))
    return record


def _run_real_lane(rows, n_requests):
    import jax

    from repro.configs import get_api
    from repro.serving import RealServingEngine

    api = get_api("olmo-1b", reduced=True)
    assert api.supports_prefill(), "dense family must expose fused prefill"
    params = api.init(jax.random.PRNGKey(0))
    coeffs = {0: (0.01, 0.01), 1: (0.01, 0.01)}

    def run(n, engine, seed):
        wl = generate_requests(
            n, seed=seed, rate=50.0, prompt_min=16, prompt_max=16,
            gen_min=2, gen_max=8, gen_mean=4, token_budget=10.0,
        )
        rt = ServingRuntime(
            engine,
            ServingAllocator(dict(coeffs), total_slots=4),
            wl, nodes=[0, 1],
            config=ServingConfig(total_slots=4),
        )
        return rt.run()

    engine = RealServingEngine(api, params, max_len=32)
    run(4, engine, seed=99)  # warm: compile prefill(ctx=16) + decode
    t0 = time.perf_counter()
    rep = run(n_requests, engine, seed=5)
    wall = time.perf_counter() - t0

    p99 = rep.summary["token_latency"]["p99"]
    assert rep.summary["dropped"] == 0, "real lane dropped requests"
    assert rep.sustained_req_s >= REAL_FLOOR_REQ_S, (
        f"real sustained {rep.sustained_req_s:.2f} req/s below floor "
        f"{REAL_FLOOR_REQ_S}"
    )
    assert p99 <= REAL_P99_BOUND_S, (
        f"real p99 token latency {p99:.3f}s above bound {REAL_P99_BOUND_S}s"
    )

    record = {
        "arch": "olmo-1b (reduced)",
        "prefill": "fused",
        "requests": n_requests,
        "offered_req_s": 50.0,
        "sustained_req_s": rep.sustained_req_s,
        "floor_req_s": REAL_FLOOR_REQ_S,
        "p99_token_s": p99,
        "p99_bound_s": REAL_P99_BOUND_S,
        "dropped": rep.summary["dropped"],
        "wall_s": wall,
    }
    rows.append(Row(
        f"serving/real_olmo/r{n_requests}",
        wall * 1e6,
        f"sustained={rep.sustained_req_s:.2f}req/s;p99={p99 * 1e3:.1f}ms",
    ))
    return record


def run(smoke: bool = False):
    rows = []
    record = _run_sim_lanes(rows)
    record["real"] = _run_real_lane(
        rows, REAL_REQUESTS // 2 if smoke else REAL_REQUESTS
    )

    sweep_path = os.path.join(ARTIFACTS, "bench", "sweep.json")
    payload = {}
    if os.path.exists(sweep_path):
        try:
            with open(sweep_path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    payload["serving"] = record
    save_json("sweep", payload)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="halve the real-engine request count")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        print(row.csv(), flush=True)


if __name__ == "__main__":
    main()

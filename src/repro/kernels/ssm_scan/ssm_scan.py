"""Pallas TPU kernel for the Mamba-style selective scan (Hymba's SSM heads).

Recurrence per channel d and state n (d_state = 16):
    h_t[d, n] = exp(dt_t[d] * A[d, n]) * h_{t-1}[d, n] + dt_t[d] u_t[d] B_t[n]
    y_t[d]    = sum_n C_t[n] h_t[d, n]

TPU adaptation (vs. the CUDA selective-scan kernel, which maps channels to
threads and relies on warp shuffles): the grid is
(batch, d_inner blocks, time chunks) with time innermost; the (d_block, n)
state is VMEM scratch carried across chunk iterations; within a chunk a
`fori_loop` advances the recurrence on (d_block, n) vector tiles — the VPU
executes each step across the whole channel block at once, so there is no
per-channel serialization like on SMs.  d_state=16 rides in the minormost
dim (padded lane tile); d_block=512 channels x 16 states x 4 B = 32 KiB of
state per program.

VMEM per program (C=64, d_block=512, n=16):
  u/dt (64x512x4) x2 + b/c (64x16x4) x2 + y (64x512x4) + state 32 KiB
  ~ 420 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _ssm_kernel(u_ref, dt_ref, b_ref, c_ref, loga_ref, y_ref, h_final_ref, h_ref,
                *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = -jnp.exp(loga_ref[...].astype(jnp.float32))       # (D, N)
    u = u_ref[0].astype(jnp.float32)                      # (C, D)
    dt = dt_ref[0].astype(jnp.float32)                    # (C, D)
    b = b_ref[0].astype(jnp.float32)                      # (C, N)
    c = c_ref[0].astype(jnp.float32)                      # (C, N)

    def step(t, carry):
        h, y = carry
        decay = jnp.exp(dt[t][:, None] * a)               # (D, N)
        h = decay * h + (dt[t] * u[t])[:, None] * b[t][None, :]
        y = y.at[t].set(jnp.sum(h * c[t][None, :], axis=1))
        return h, y

    h0 = h_ref[...]
    y0 = jnp.zeros((chunk, u.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h0, y0))
    h_ref[...] = h
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def finalize():
        h_final_ref[0] = h_ref[...].astype(h_final_ref.dtype)


def ssm_scan_kernel(
    u: jax.Array,      # (B, T, D)
    dt: jax.Array,     # (B, T, D)
    b_t: jax.Array,    # (B, T, N)
    c_t: jax.Array,    # (B, T, N)
    log_a: jax.Array,  # (D, N)
    *,
    chunk: int = 64,
    d_block: int = 512,
    interpret: bool = True,
):
    """Returns (y (B, T, D), h_final (B, D, N))."""
    bsz, t, d = u.shape
    n = b_t.shape[-1]
    if t % chunk:
        raise ValueError(f"T={t} must be a multiple of chunk={chunk}")
    d_block = min(d_block, d)
    if d % d_block:
        raise ValueError(f"D={d} must be a multiple of d_block={d_block}")
    n_chunks = t // chunk
    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(bsz, d // d_block, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, chunk, d_block), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, chunk, n), lambda ib, id_, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, id_, ic: (ib, ic, 0)),
            pl.BlockSpec((d_block, n), lambda ib, id_, ic: (id_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, d_block, n), lambda ib, id_, ic: (ib, id_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, d), u.dtype),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, b_t, c_t, log_a)

"""Launchers: production mesh builders, multi-pod dry-run, training CLI.

NOTE: import `repro.launch.dryrun` only as a __main__ entry point — its
first two lines set XLA_FLAGS to fake 512 host devices, which must happen
before jax initializes.  `mesh` and `hlo_stats` are import-safe.
"""
from repro.launch.mesh import make_production_mesh, make_rules

__all__ = ["make_production_mesh", "make_rules"]

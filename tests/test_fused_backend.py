"""Fused on-device epoch + sharded RealBackend acceptance tests.

Covers the PR's tentpole contracts: fused-mode plans are bit-compatible
with the two-program path (and certified against the host float64 oracle
within 1e-5), the shard_map per-node backward matches the single-device
vmap backward, the fused epoch program compiles once, the fused path
eliminates the per-step host<->device transfer traffic, and a sharded
backend's checkpoint generations round-trip bit-exactly through
``CheckpointManager``.

The default test image has one CPU device (the sharded path then runs with
a size-1 mesh); the CI ``multi-device-smoke`` lane re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` where the node axis
is genuinely split.
"""
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX-compiling; excluded from the fast lane

jax = pytest.importorskip("jax")

from repro.core.controller import CannikinController, FUSED_CERT_TOL  # noqa: E402
from repro.core.perf_model import CommModel  # noqa: E402
from repro.core.scheduler import JobSpec  # noqa: E402
from repro.core.simulator import GPU_CATALOG  # noqa: E402
from repro.runtime import EpochLoop, RealBackendConfig  # noqa: E402

N_EPOCHS = 7
STEPS = 3


def _spec(n: int = 3, total_batch: int = 12) -> JobSpec:
    names = ("a100", "v100", "rtx6000", "a5000", "a4000", "p4000", "a100", "v100")
    models = tuple(GPU_CATALOG[name].model() for name in names[:n])
    return JobSpec(
        name="fused-job",
        node_models=models,
        comm=CommModel(t_o=0.04, t_u=0.008, gamma=0.15),
        total_batch=total_batch,
        b_noise=500.0,
        ref_batch=total_batch,
        backend="real",
    )


def _run_loop(*, fused: bool, sharded: bool = False, n: int = 3,
              total: int = 12, candidates=(12, 24, 36)):
    spec = _spec(n, total_batch=total)
    backend = RealBackendConfig(
        arch="olmo-1b", seq_len=16, lr=0.3, sharded=sharded
    ).build(noise=0.0, seed=0)
    backend.configure(spec, tuple(range(n)), seed=1)
    ctrl = CannikinController(
        n, batch_candidates=list(candidates), ref_batch=total, adaptive=True
    )
    loop = EpochLoop(ctrl, backend, steps_per_epoch=STEPS, fused=fused)
    loop.run(N_EPOCHS)
    return ctrl, backend, loop


def test_fused_plans_match_two_program_path():
    """Acceptance: a fused-mode loop produces the same plan sequence —
    total batch, per-node partition, lr scale — as the two-program loop on
    the same seeds, every on-device proposal certifies against the host
    float64 oracle within 1e-5, and the fused epoch program compiles once."""
    ctrl_two, backend_two, loop_two = _run_loop(fused=False)
    ctrl_fused, backend_fused, loop_fused = _run_loop(fused=True)
    rec_two, rec_fused = loop_two.history, loop_fused.history

    assert len(rec_two) == len(rec_fused) == N_EPOCHS
    for a, b in zip(rec_two, rec_fused):
        assert a.total_batch == b.total_batch
        assert a.batches == b.batches
        # The fused plan's LR rule is evaluated at the device-estimated
        # (float32 EMA) noise scale; the two-program plan at the host
        # float64 EMA — same rule, ~1e-8 relative drift.
        assert b.lr_scale == pytest.approx(a.lr_scale, rel=1e-6)
    # Fused mode actually engaged (after the bootstrap/first adaptive epoch)
    # and every staged proposal certified.
    s = ctrl_fused.stats
    assert s.fused_plans >= 1
    assert s.fused_certifications >= s.fused_plans
    assert s.fused_cert_failures == 0
    assert s.fused_max_rel_err <= FUSED_CERT_TOL
    assert not ctrl_fused._fused_disabled
    # Losses agree with the two-program path (same step body, scanned).
    la = np.asarray([r.mean_loss for r in rec_two])
    lb = np.asarray([r.mean_loss for r in rec_fused])
    np.testing.assert_allclose(lb, la, rtol=1e-5, atol=1e-6)
    # One fused epoch program for the whole run: a single (n, shard) cache
    # entry whose jit traced exactly once across all fused epochs.
    assert len(backend_fused._fused_cache) == 1
    (fn,) = backend_fused._fused_cache.values()
    assert fn._cache_size() == 1


def test_fused_epoch_cuts_transfers_per_epoch():
    """The fused program ships the epoch once and pulls one telemetry
    bundle: at least 2x fewer host<->device transfers per adaptive epoch
    than the two-program path (the bench gate, asserted here at test
    scale)."""
    _, backend_two, loop_two = _run_loop(fused=False)
    ctrl_fused, backend_fused, loop_fused = _run_loop(fused=True)
    assert ctrl_fused.stats.fused_plans >= 1

    # Marginal cost of one more adaptive (post-bootstrap) epoch per loop.
    # The two-program path pays per step (4 h2d + 4 d2h), the fused path a
    # flat ~25/epoch, so the gate needs a realistic step count to bind.
    loop_two.steps_per_epoch = loop_fused.steps_per_epoch = 16
    backend_two.transfers.reset()
    backend_fused.transfers.reset()
    loop_two.run_epoch()
    rec = loop_fused.run_epoch()
    assert rec.plan.batch_policy.endswith("+fused")
    two = backend_two.transfers.snapshot()
    fused = backend_fused.transfers.snapshot()
    assert fused["total"] * 2 <= two["total"]


def test_sharded_backward_matches_vmap_backward():
    """shard_map-vs-vmap parity: the sharded per-node backward (psum'd
    Eq. 9 aggregation, composed global loss) reproduces the single-device
    vmap backward — losses within 1e-6, final parameters and gradient
    telemetry matching — on the same seeds and plans."""
    n = 4
    spec = _spec(n, total_batch=16)
    plans = [[4, 4, 4, 4], [6, 4, 3, 3], [2, 6, 5, 3]]

    def drive(sharded: bool):
        backend = RealBackendConfig(
            arch="olmo-1b", seq_len=16, lr=0.3, sharded=sharded
        ).build(noise=0.0, seed=0)
        backend.configure(spec, tuple(range(n)), seed=1)
        results = [backend.execute(p, steps=2) for p in plans]
        return backend, results

    b_vmap, r_vmap = drive(sharded=False)
    b_shard, r_shard = drive(sharded=True)

    for rv, rs in zip(r_vmap, r_shard):
        np.testing.assert_allclose(
            np.asarray(rs.losses), np.asarray(rv.losses), rtol=1e-6
        )
    # Same learned parameters after three epochs of heterogeneous plans.
    for a, b in zip(
        jax.tree_util.tree_leaves(b_vmap.params),
        jax.tree_util.tree_leaves(b_shard.params),
    ):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6
        )
    # Theorem-4.1 telemetry matches: same noise-scale estimate.
    assert b_vmap.gns.count == b_shard.gns.count
    assert b_vmap.gns.b_noise == pytest.approx(b_shard.gns.b_noise, rel=1e-3)


def test_sharded_fused_loop_runs_and_certifies():
    """Sharded backend + fused mode together (the full tentpole): the loop
    runs, fused plans engage, and certification stays within tolerance.
    Under the CI 8-device lane the node axis is genuinely split."""
    ctrl, backend, loop = _run_loop(
        fused=True, sharded=True, n=4, total=16, candidates=(16, 32)
    )
    records = loop.history
    assert len(records) == N_EPOCHS
    assert all(np.isfinite(r.mean_loss) for r in records)
    s = ctrl.stats
    assert s.fused_plans >= 1
    assert s.fused_cert_failures == 0
    assert s.fused_max_rel_err <= FUSED_CERT_TOL
    assert backend._mesh is not None


def test_sharded_checkpoint_roundtrip_bit_exact(tmp_path):
    """A sharded backend's snapshot gathers to host numpy, so the PR-7
    checkpoint generations stay byte-stable: save -> scramble -> restore
    through ``CheckpointManager`` recovers params/opt-state/GNS/counters
    bit-exactly, and training resumes."""
    from repro.core.gns import GNSState
    from repro.train.checkpoint import CheckpointManager

    n = 4
    spec = _spec(n, total_batch=16)
    backend = RealBackendConfig(
        arch="olmo-1b", seq_len=16, lr=0.3, sharded=True
    ).build(noise=0.0, seed=0)
    backend.configure(spec, tuple(range(n)), seed=1)
    backend.execute([4, 4, 4, 4], steps=2)

    snap = backend.snapshot()
    # Byte-stability contract: every leaf is host numpy, no device arrays.
    for leaf in jax.tree_util.tree_leaves(snap):
        assert isinstance(leaf, (np.ndarray, np.generic)), type(leaf)
    mgr = CheckpointManager(str(tmp_path), "fused-job", keep=2)
    mgr.save(snap)
    want_params = [np.asarray(x) for x in jax.tree_util.tree_leaves(snap["params"])]
    want_gns, want_steps = backend.gns, backend.steps_done

    backend.params = jax.tree_util.tree_map(lambda x: x * 0.0, backend.params)
    backend.gns = GNSState()
    backend.steps_done = 0
    tree, gen, _ = mgr.restore(backend.snapshot())
    backend.load_snapshot(tree)
    assert gen == 1

    got_params = [
        np.asarray(x) for x in jax.tree_util.tree_leaves(backend.params)
    ]
    for a, b in zip(want_params, got_params):
        np.testing.assert_array_equal(a, b)
    assert backend.gns == want_gns
    assert backend.steps_done == want_steps
    # The restored sharded backend keeps training.
    result = backend.execute([4, 4, 4, 4], steps=1)
    assert np.isfinite(result.mean_loss)

"""ExecutionBackend: the plan → execute → observe engine behind JobHandle.

The paper's Cannikin system is a *runtime* that observes training steps,
refits performance models, and adapts batch sizes.  What produces the
observations — real JAX gradient steps or the calibrated timing simulator —
is an implementation detail the loop must not care about.  This module is
that seam:

* :class:`ExecutionBackend` — the protocol: ``configure`` (follow node-set
  changes), ``execute`` (run one epoch of ``steps`` batches with a given
  partition, returning :class:`ExecutionResult` — per-node
  ``NodeObservation`` measurements plus loss/GNS telemetry), and
  ``snapshot``/``load_snapshot`` (preemption checkpoint state).
* :class:`SimBackend` — :class:`~repro.core.simulator.SimulatedCluster`
  behind the protocol: timing measurements only, no gradients (losses are
  NaN).  The default for trace replay.
* :class:`RealBackend` — the gradient engine extracted from the old
  ``HeteroTrainer``: one vmapped per-node backward over the padded
  ``(n, b_max)`` layout, Eq. (9) weighted aggregation, a Theorem-4.1 GNS
  tracker, and a simulated cluster clock (the paper's own separation:
  statistical behaviour is real, per-node timing is simulated).  Its state
  (params / opt-state / GNS / stream counters) round-trips bit-exactly
  through :mod:`repro.train.checkpoint` for preemption/resume.
* :func:`run_backend_epoch` / :class:`EpochLoop` — the policy loop the
  runtime owns: plan (CannikinController or a baseline partition policy) →
  ``backend.execute`` → observe (measurements + gradient telemetry), each
  epoch surfacing one unified :class:`EpochRecord` (merging the old
  ``EpochResult``/``EpochPlan`` telemetry split).

``JobHandle.advance`` and ``HeteroTrainer`` are both thin shells over this
module, so scheduler decisions, simulated traces, and real training can
never diverge in protocol.
"""
from __future__ import annotations

import dataclasses
from typing import (
    Any,
    Callable,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    runtime_checkable,
)

import numpy as np

from repro.core.aggregation import ratios
from repro.core.controller import (
    CannikinController,
    EpochPlan,
    FusedProposal,
    FusedSweepContext,
)
from repro.core.gns import GNSState, estimate_gns, gns_update
from repro.core.scheduler import JobSpec
from repro.core.simulator import NodeProfile, SimulatedCluster, StepMeasurement
from repro.runtime.transfers import TransferCounter

__all__ = [
    "GradObservation",
    "ExecutionResult",
    "EpochRecord",
    "ExecutionBackend",
    "SimBackend",
    "RealBackend",
    "RealBackendConfig",
    "BACKENDS",
    "make_backend",
    "run_backend_epoch",
    "EpochLoop",
]


# ---------------------------------------------------------------------------
# telemetry records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GradObservation:
    """One step's gradient telemetry — the Theorem-4.1 GNS ingredients:
    per-node gradient square-norms |g_i|^2, the aggregated |g|^2, and the
    local batch sizes that produced them.  ``valid`` marks which nodes the
    anomaly guard kept in the Eq. (9) aggregate (empty tuple = unguarded
    legacy observation, treated as all-valid)."""

    local_sqnorms: Tuple[float, ...]
    global_sqnorm: float
    batches: Tuple[int, ...]
    valid: Tuple[bool, ...] = ()

    @property
    def all_valid(self) -> bool:
        return all(self.valid) if self.valid else True


@dataclasses.dataclass(frozen=True)
class ExecutionResult:
    """What one backend epoch produced.

    ``measurements`` carry the per-node :class:`NodeObservation` timing the
    performance-model fitters consume; ``losses``/``grad_observations`` are
    the statistical telemetry (empty on :class:`SimBackend`); ``b_noise``
    is the backend's own GNS tracker estimate after the epoch (NaN when the
    backend computes no gradients).
    """

    epoch_seconds: float
    measurements: Tuple[StepMeasurement, ...]
    losses: Tuple[float, ...]
    grad_observations: Tuple[GradObservation, ...]
    b_noise: float
    # Per-node counts of steps the gradient anomaly guard excluded the node
    # from aggregation this epoch (aligned with the configured node order;
    # empty for backends without a guard).  The runtime feeds this to
    # HealthMonitor.observe_numerics.
    grad_anomalies: Tuple[int, ...] = ()

    @property
    def mean_loss(self) -> float:
        return float(np.mean(self.losses)) if self.losses else float("nan")


@dataclasses.dataclass(frozen=True)
class EpochRecord:
    """Unified per-epoch telemetry: plan + execution in one record (the old
    ``EpochResult``/``EpochPlan`` split, merged).  ``mean_loss`` is NaN and
    ``b_noise`` non-finite for backends that compute no gradients."""

    epoch: int
    backend: str                           # ExecutionBackend.kind
    total_batch: int
    batches: Tuple[int, ...]
    lr_scale: float
    phase: str                             # "bootstrap" | "optperf" | baseline name
    predicted_batch_time: Optional[float]
    measured_batch_time: float
    epoch_seconds: float                   # simulated cluster wall-clock
    mean_loss: float
    b_noise: float
    plan: Optional[EpochPlan] = None


# ---------------------------------------------------------------------------
# the backend protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class ExecutionBackend(Protocol):
    """What the runtime's epoch loop needs from an execution engine.

    ``configure`` rebinds the backend to a job's current node set (called on
    every reallocation; the timing model follows the nodes, any learned
    statistical state — params, optimizer, GNS — survives).  ``execute``
    runs one epoch.  ``snapshot``/``load_snapshot`` expose the state that
    must survive preemption as a checkpointable pytree (``{}`` when there is
    nothing worth persisting).
    """

    kind: str

    def configure(
        self, spec: JobSpec, node_ids: Sequence[int], *, seed: int = 0
    ) -> None: ...

    def execute(
        self, batches: Sequence[int], steps: int, *, lr_scale: float = 1.0
    ) -> ExecutionResult: ...

    def snapshot(self) -> Dict[str, Any]: ...

    def load_snapshot(self, state: Dict[str, Any]) -> None: ...


def _profiles_for(spec: JobSpec, node_ids: Sequence[int]) -> List[NodeProfile]:
    """The job's own ground-truth node models over its held nodes, as timing
    profiles (identical to the old ``JobHandle._rebuild_sim`` construction)."""
    profiles = []
    for nid in node_ids:
        m = spec.node_models[nid]
        profiles.append(
            NodeProfile(name=f"{spec.name}:n{nid}", q=m.q, s=m.s, k=m.k, m=m.m)
        )
    return profiles


class SimBackend:
    """:class:`SimulatedCluster` behind the :class:`ExecutionBackend`
    protocol: per-node timing measurements with optional multiplicative
    noise, no gradients.  ``configure`` rebuilds the cluster from the job
    spec's own node models (per-job heterogeneity included), exactly as the
    pre-refactor ``JobHandle`` did — replayed traces are bit-identical."""

    kind = "sim"

    def __init__(
        self,
        cluster: Optional[SimulatedCluster] = None,
        *,
        noise: float = 0.0,
        injector: Any = None,
    ) -> None:
        self.cluster = cluster
        self.noise = noise
        self.injector = injector  # Optional[repro.runtime.faults.FaultInjector]
        self.sim_time = 0.0
        self.epochs_run = 0
        self._job: Optional[str] = None
        self._node_ids: Tuple[int, ...] = ()

    def configure(
        self, spec: JobSpec, node_ids: Sequence[int], *, seed: int = 0
    ) -> None:
        self.cluster = SimulatedCluster(
            _profiles_for(spec, node_ids), spec.comm, noise=self.noise, seed=seed
        )
        self._job = spec.name
        self._node_ids = tuple(int(n) for n in node_ids)

    def execute(
        self, batches: Sequence[int], steps: int, *, lr_scale: float = 1.0
    ) -> ExecutionResult:
        if self.cluster is None:
            raise RuntimeError("SimBackend not configured with a cluster")
        t, ms = self.cluster.run_epoch(list(batches), steps)
        if self.injector is not None:
            # Pure post-transform of the measurements: the cluster's RNG
            # stream is untouched, so the fault-free replay stays
            # bit-identical and faults compose deterministically on top.
            t, ms = self.injector.perturb(
                self._job or "?", self._node_ids, t, list(ms)
            )
        self.sim_time += t
        self.epochs_run += 1
        return ExecutionResult(
            epoch_seconds=t,
            measurements=tuple(ms),
            losses=(),
            grad_observations=(),
            b_noise=float("nan"),
        )

    def snapshot(self) -> Dict[str, Any]:
        return {}  # nothing statistical to persist: the sim is rebuilt on resume

    def load_snapshot(self, state: Dict[str, Any]) -> None:
        del state


def _quantize(b: int, q: int = 8) -> int:
    """Quantize the padded per-node width so epoch-to-epoch repartitioning
    reuses compiled steps (recompilation hygiene; EXPERIMENTS.md §Perf)."""
    return max(q, ((b + q - 1) // q) * q)


class RealBackend:
    """The real-gradient engine (extracted from the old ``HeteroTrainer``).

    Per step: partition the global batch by the planned local batch sizes,
    run one vmapped per-node backward over the padded ``(n, b_max)`` layout,
    aggregate g = sum r_i g_i (Eq. 9), update params once, feed
    (|g_i|^2, |g|^2, b) to the Theorem-4.1 GNS tracker, and advance the
    simulated cluster clock by the heterogeneous batch time.

    Only the *timing* is simulated (``cluster`` — rebound by ``configure``
    on every node-set change); params, optimizer state, GNS state, and the
    data-stream position are real and round-trip bit-exactly through
    ``snapshot``/``load_snapshot`` (and :meth:`checkpoint`/:meth:`restore`
    via :mod:`repro.train.checkpoint`) for preemption/resume.

    **Integrity hardening.**  The Eq. (9) aggregation runs behind the
    always-on anomaly guard (:func:`repro.core.aggregation.guard_weights`):
    a node whose per-step gradient is non-finite or a gross norm outlier is
    excluded from the aggregate (weights renormalized; the GNS tracker
    skips the step) and counted in ``ExecutionResult.grad_anomalies``.
    ``injector`` is the real-path fault seam: its ``poison_factors`` vector
    multiplies each node's gradient inside the jitted step (exactly 1.0
    when inactive — IEEE-exact, so no-fault replays stay bit-identical;
    the guard itself is always compiled in, so the program is the same
    with or without an injector), and its ``perturb`` post-transforms the
    timing measurement stream exactly as it does for :class:`SimBackend`
    (timing faults hit the real path too).
    """

    kind = "real"

    def __init__(
        self,
        api: Any,                        # ModelApi
        optimizer: Any,                  # repro.optim Optimizer
        data: Any,                       # SyntheticLM-compatible stream
        *,
        cluster: Optional[SimulatedCluster] = None,
        noise: float = 0.0,
        seed: int = 0,
        gns_decay: float = 0.9,
        injector: Any = None,            # Optional[FaultInjector]
        outlier_factor: Optional[float] = None,
        sharded: bool = False,
    ) -> None:
        import jax

        from repro.core.aggregation import ANOMALY_OUTLIER_FACTOR

        self.api = api
        self.optimizer = optimizer
        self.data = data
        self.cluster = cluster
        self.noise = noise
        self.injector = injector
        self.outlier_factor = (
            float(outlier_factor) if outlier_factor is not None
            else ANOMALY_OUTLIER_FACTOR
        )
        self.params = api.init(jax.random.PRNGKey(seed))
        self.opt_state = optimizer.init(self.params)
        self.gns = GNSState()
        self.gns_decay = gns_decay
        self.sim_time = 0.0
        self.steps_done = 0
        self.anomalous_steps = 0       # steps with >= 1 excluded node (lifetime)
        self.sharded = bool(sharded)
        self.transfers = TransferCounter()
        self._step_cache: Dict[int, Callable] = {}
        self._sharded_cache: Dict[Tuple[int, int], Callable] = {}
        self._fused_cache: Dict[Tuple[int, int], Callable] = {}
        self._meshes: Dict[int, Any] = {}        # shard count -> Mesh
        self._mesh_rules: Dict[int, Any] = {}    # shard count -> MeshRules
        self._mesh: Any = None                   # mesh for the configured node set
        self._job: Optional[str] = None
        self._node_ids: Tuple[int, ...] = ()

    # -- node-set binding ------------------------------------------------

    def configure(
        self, spec: JobSpec, node_ids: Sequence[int], *, seed: int = 0
    ) -> None:
        self.cluster = SimulatedCluster(
            _profiles_for(spec, node_ids), spec.comm, noise=self.noise, seed=seed
        )
        self._job = spec.name
        self._node_ids = tuple(int(n) for n in node_ids)
        if self.sharded and self._node_ids:
            # Rebuild the node mesh on node-set changes; compiled sharded
            # steps are keyed by (n, shard count) so a changed shard count
            # naturally re-traces while same-width reconfigurations reuse
            # the cached programs.
            self._mesh, _ = self._mesh_for(len(self._node_ids))

    def _mesh_for(self, n: int) -> Tuple[Any, Any]:
        """(Mesh, MeshRules) for an n-node sharded step, cached by shard
        count (the largest divisor of n that fits the local devices)."""
        from repro.launch.mesh import (
            make_node_mesh,
            mesh_axis_sizes,
            node_shard_count,
        )
        from repro.sharding.rules import MeshRules

        d = node_shard_count(n)
        mesh = self._meshes.get(d)
        if mesh is None:
            mesh = make_node_mesh(n)
            self._meshes[d] = mesh
            self._mesh_rules[d] = MeshRules(
                mesh_axes=mesh_axis_sizes(mesh), node_axis="nodes"
            )
        return mesh, self._mesh_rules[d]

    def _node_placer(self, n: int, *, stacked: bool = False) -> Callable:
        """device_put with the node-axis NamedSharding — the explicit h2d
        seam for sharded execution.  ``stacked`` handles the fused layout
        with a leading (steps,) scan dim before the node dim."""
        import jax
        from jax.sharding import NamedSharding

        mesh, rules = self._mesh_for(n)
        lead: List[Optional[str]] = [None] if stacked else []

        def place(arr: np.ndarray):
            axes = lead + ["nodes"] + [None] * (arr.ndim - len(lead) - 1)
            return jax.device_put(arr, NamedSharding(mesh, rules.spec(axes)))

        return place

    # -- gradient engine -------------------------------------------------

    def _node_grad_fn(self, b_max: int) -> Callable:
        """Jitted: per-node grads (× injected poison factors) + sq-norms +
        anomaly-guarded Eq.(9) aggregate + update.

        The guard is *always* compiled in — the same program runs with and
        without an injector, so the no-fault bit-identity guarantee holds
        by construction: healthy poison factors are exactly 1.0 (IEEE-exact
        multiply) and the all-valid guard branch selects the original
        weight vector bitwise.  Invalid nodes' gradients are zeroed
        *before* the tensordot (0 × NaN = NaN otherwise) and the surviving
        weights renormalized; with every node invalid the aggregate is
        zero and the update a no-op."""
        if b_max in self._step_cache:
            return self._step_cache[b_max]
        import jax

        fn = jax.jit(self._build_step_body())
        self._step_cache[b_max] = fn
        return fn

    def _build_step_body(self) -> Callable:
        """The un-jitted single-device step (vmapped per-node backward).
        Shared verbatim between :meth:`_node_grad_fn` (jitted directly) and
        the fused epoch program (scanned inside one jit)."""
        import jax
        import jax.numpy as jnp

        from repro.core.aggregation import guard_weights
        from repro.optim.optimizers import global_norm

        api, optimizer = self.api, self.optimizer
        outlier_factor = self.outlier_factor

        def node_loss(params, tokens, labels, mask):
            # mean over the node's real samples (pads weighted 0).
            loss, _ = api.loss(
                params,
                {"tokens": tokens, "labels": labels, "weights": mask},
            )
            return loss

        grad_fn = jax.grad(node_loss)

        def step(params, opt_state, tokens, labels, mask, r, lr_scale, poison):
            # tokens/labels: (n, b_max, S); mask: (n, b_max); r/poison: (n,)
            grads = jax.vmap(grad_fn, in_axes=(None, 0, 0, 0))(
                params, tokens, labels, mask
            )
            grads = jax.tree_util.tree_map(
                lambda g: g
                * poison.reshape((-1,) + (1,) * (g.ndim - 1)).astype(g.dtype),
                grads,
            )
            sq_i = jax.vmap(lambda g: global_norm(g) ** 2)(grads)
            w, valid = guard_weights(sq_i, r, outlier_factor=outlier_factor)
            agg = jax.tree_util.tree_map(
                lambda g: jnp.tensordot(
                    w.astype(jnp.float32),
                    jnp.where(
                        valid.reshape((-1,) + (1,) * (g.ndim - 1)), g, 0
                    ).astype(jnp.float32),
                    axes=1,
                ).astype(g.dtype),
                grads,
            )
            sq_g = global_norm(agg) ** 2
            loss, _ = api.loss(
                params,
                {
                    "tokens": tokens.reshape((-1,) + tokens.shape[2:]),
                    "labels": labels.reshape((-1,) + labels.shape[2:]),
                    "weights": mask.reshape(-1),
                },
            )
            new_params, new_opt = optimizer.update(agg, opt_state, params, lr_scale)
            return new_params, new_opt, loss, sq_i, sq_g, valid

        return step

    def _build_sharded_step_body(self, n: int) -> Callable:
        """The un-jitted multi-device step: the node axis split over the
        ``("nodes",)`` mesh via ``shard_map``, Eq. (9) aggregation as an
        on-device ``psum``.

        Each shard backprops its n/D nodes locally (the same vmapped
        per-node backward), all-gathers the (n,) gradient square-norms so
        every shard evaluates the *global* anomaly guard identically, then
        psums its weighted local partials into the replicated Eq. (9)
        aggregate.  The optimizer update runs outside shard_map on the
        replicated aggregate.  The global loss is composed from per-node
        means by their token-weight sums — algebraically equal to the vmap
        path's full-batch forward (see models.registry._token_loss), to
        float32 roundoff."""
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.core.aggregation import guard_weights
        from repro.optim.optimizers import global_norm
        from repro.train.step import node_step_specs

        mesh, rules = self._mesh_for(n)
        shard = n // int(mesh.devices.size)
        specs = node_step_specs(rules)
        api, optimizer = self.api, self.optimizer
        outlier_factor = self.outlier_factor

        def node_loss(params, tokens, labels, mask):
            loss, _ = api.loss(
                params,
                {"tokens": tokens, "labels": labels, "weights": mask},
            )
            return loss

        val_grad = jax.value_and_grad(node_loss)

        def shard_body(params, tokens, labels, mask, r, poison):
            # Local shapes: tokens/labels (n/D, b_max, S); mask (n/D, b_max);
            # params/r/poison replicated.
            losses, grads = jax.vmap(val_grad, in_axes=(None, 0, 0, 0))(
                params, tokens, labels, mask
            )
            lo = jax.lax.axis_index("nodes") * shard
            poison_local = jax.lax.dynamic_slice(poison, (lo,), (shard,))
            grads = jax.tree_util.tree_map(
                lambda g: g
                * poison_local.reshape((-1,) + (1,) * (g.ndim - 1)).astype(g.dtype),
                grads,
            )
            sq_local = jax.vmap(lambda g: global_norm(g) ** 2)(grads)
            sq_i = jax.lax.all_gather(sq_local, "nodes", tiled=True)  # (n,)
            w, valid = guard_weights(sq_i, r, outlier_factor=outlier_factor)
            w_local = jax.lax.dynamic_slice(w, (lo,), (shard,))
            valid_local = jax.lax.dynamic_slice(valid, (lo,), (shard,))
            agg = jax.tree_util.tree_map(
                lambda g: jax.lax.psum(
                    jnp.tensordot(
                        w_local.astype(jnp.float32),
                        jnp.where(
                            valid_local.reshape((-1,) + (1,) * (g.ndim - 1)), g, 0
                        ).astype(jnp.float32),
                        axes=1,
                    ),
                    "nodes",
                ).astype(g.dtype),
                grads,
            )
            sq_g = global_norm(agg) ** 2
            # Global mean loss from per-node means: node i's token-weight
            # sum is mask_i.sum() * S (registry._token_loss broadcasts the
            # per-sample mask over the sequence dim), and loss_i * w_sum_i
            # recovers its loss summand; all-padding nodes contribute 0.
            w_sum = mask.sum(axis=1) * jnp.float32(tokens.shape[-1])
            loss_num = jax.lax.psum((losses * w_sum).sum(), "nodes")
            loss_den = jax.lax.psum(w_sum.sum(), "nodes")
            loss = loss_num / jnp.maximum(loss_den, 1e-9)
            return agg, loss, sq_i, sq_g, valid

        smapped = shard_map(
            shard_body,
            mesh=mesh,
            in_specs=(
                P(),
                specs["tokens"],
                specs["labels"],
                specs["mask"],
                specs["replicated"],
                specs["replicated"],
            ),
            out_specs=(P(), P(), P(), P(), P()),
            check_rep=False,
        )

        def step(params, opt_state, tokens, labels, mask, r, lr_scale, poison):
            agg, loss, sq_i, sq_g, valid = smapped(
                params, tokens, labels, mask, r, poison
            )
            new_params, new_opt = optimizer.update(agg, opt_state, params, lr_scale)
            return new_params, new_opt, loss, sq_i, sq_g, valid

        return step

    def _node_grad_fn_sharded(self, n: int) -> Callable:
        """Jitted sharded step, cached by (n, shard count); padded-width
        shape changes retrace inside the cached jit wrapper as usual."""
        mesh, _ = self._mesh_for(n)
        key = (n, int(mesh.devices.size))
        if key in self._sharded_cache:
            return self._sharded_cache[key]
        import jax

        fn = jax.jit(self._build_sharded_step_body(n))
        self._sharded_cache[key] = fn
        return fn

    def execute(
        self, batches: Sequence[int], steps: int, *, lr_scale: float = 1.0
    ) -> ExecutionResult:
        if self.cluster is None:
            raise RuntimeError("RealBackend not configured with a cluster")
        import jax.numpy as jnp

        from repro.data.pipeline import HeteroBatchPartitioner

        batches = [int(b) for b in batches]
        b_arr = np.asarray(batches, np.int64)
        b_max = _quantize(int(b_arr.max()))
        n = len(batches)
        r = jnp.asarray(ratios(batches), jnp.float32)
        use_sharded = self.sharded and n > 0
        if use_sharded:
            step_fn = self._node_grad_fn_sharded(n)
            place = self._node_placer(n)
        else:
            step_fn = self._node_grad_fn(b_max)
            place = jnp.asarray
        self.transfers.count_h2d(2)  # r + poison, shipped once per epoch

        node_ids = self._node_ids if len(self._node_ids) == n else tuple(range(n))
        if self.injector is not None:
            poison_np = self.injector.poison_factors(node_ids)
        else:
            poison_np = np.ones(n, np.float32)
        poison = jnp.asarray(poison_np, jnp.float32)
        anomaly_counts = np.zeros(n, np.int64)

        losses: List[float] = []
        grad_obs: List[GradObservation] = []
        for _ in range(steps):
            raw = self.data.batch(self.steps_done, int(b_arr.sum()))
            self.steps_done += 1
            padded, _ = HeteroBatchPartitioner.padded(raw, batches)
            seq = padded["tokens"].shape[-1]
            tok = np.zeros((n, b_max, seq), np.int32)
            lab = np.zeros((n, b_max, seq), np.int32)
            msk = np.zeros((n, b_max), np.float32)
            w = padded["tokens"].shape[1]
            tok[:, :w], lab[:, :w] = padded["tokens"], padded["labels"]
            for i, b in enumerate(batches):
                msk[i, :b] = 1.0
            # 4 h2d per step (tok/lab/msk + lr scalar), 4 d2h pulls below —
            # the per-step host round-trips the fused path collapses.
            self.transfers.count_h2d(4)
            self.transfers.count_d2h(4)
            self.params, self.opt_state, loss, sq_i, sq_g, valid = step_fn(
                self.params,
                self.opt_state,
                place(tok),
                place(lab),
                place(msk),
                r,
                jnp.float32(lr_scale),
                poison,
            )
            valid_np = np.asarray(valid, bool)
            anomaly_counts += ~valid_np
            self.anomalous_steps += int(not valid_np.all())
            losses.append(float(loss))
            obs = GradObservation(
                local_sqnorms=tuple(float(x) for x in np.asarray(sq_i)),
                global_sqnorm=float(sq_g),
                batches=tuple(batches),
                valid=tuple(bool(v) for v in valid_np),
            )
            grad_obs.append(obs)
            if obs.all_valid:
                # Poisoned steps carry non-finite/outlier sq-norms: feeding
                # them to the Theorem-4.1 tracker would corrupt b_noise.
                self._track_gns(obs)

        epoch_seconds, measurements = self.cluster.run_epoch(batches, steps)
        measurements = list(measurements)
        if self.injector is not None:
            # Timing faults (slowdowns/flaps) route through the same seam as
            # the sim backend: pure post-transform of the measurement stream.
            epoch_seconds, measurements = self.injector.perturb(
                self._job or "?", node_ids, epoch_seconds, measurements
            )
        self.sim_time += epoch_seconds
        return ExecutionResult(
            epoch_seconds=epoch_seconds,
            measurements=tuple(measurements),
            losses=tuple(losses),
            grad_observations=tuple(grad_obs),
            b_noise=self.gns.b_noise,
            grad_anomalies=tuple(int(c) for c in anomaly_counts),
        )

    # -- fused on-device epoch -------------------------------------------

    def _fused_epoch_fn(self, n: int) -> Callable:
        """One jitted program for a whole adaptive epoch: lax.scan over the
        train steps (the same step body as the two-program path), an
        on-device Theorem-4.1 GNS EMA, and the OptPerf goodput sweep +
        Eq. (6) argmax + water-fill partition on the final state.  The host
        touches the device exactly twice per epoch: one stacked-batch
        shipment in, one telemetry-bundle pull out."""
        use_sharded = self.sharded and n > 0
        if use_sharded:
            mesh, _ = self._mesh_for(n)
            key = (n, int(mesh.devices.size))
        else:
            key = (n, 0)
        if key in self._fused_cache:
            return self._fused_cache[key]
        import jax
        import jax.numpy as jnp

        from repro.core import optperf_jax
        from repro.core.gns import local_estimates

        step_body = (
            self._build_sharded_step_body(n) if use_sharded
            else self._build_step_body()
        )
        decay = float(self.gns_decay)

        def epoch_fn(
            params, opt_state, toks, labs, msks, r, lr_scale, poison,
            b_vec, gns0, dc, cand, lo0, b0,
        ):
            total = b_vec.sum()

            def body(carry, xs):
                params, opt_state, (eg, es, cnt) = carry
                tok, lab, msk = xs
                params, opt_state, loss, sq_i, sq_g, valid = step_body(
                    params, opt_state, tok, lab, msk, r, lr_scale, poison
                )
                if n >= 2:
                    g_i, s_i = local_estimates(sq_i, sq_g, b_vec, total)
                    # Theorem 4.1 minimum-variance weights, corrected
                    # closed form (same as gns.estimate_gns): the host
                    # tracker skips guarded/degenerate steps, so gate the
                    # EMA on all-valid + finite estimates.
                    w = (total - b_vec) / ((n - 1) * total)
                    g_est = (w * g_i).sum()
                    s_est = (w * s_i).sum()
                    ok = valid.all() & jnp.isfinite(g_est) & jnp.isfinite(s_est)
                else:
                    g_est = jnp.float32(0.0)
                    s_est = jnp.float32(0.0)
                    ok = jnp.bool_(False)
                eg = jnp.where(ok, decay * eg + (1.0 - decay) * g_est, eg)
                es = jnp.where(ok, decay * es + (1.0 - decay) * s_est, es)
                cnt = jnp.where(ok, cnt + 1, cnt)
                return (params, opt_state, (eg, es, cnt)), (loss, sq_i, sq_g, valid)

            (params, opt_state, (eg, es, cnt)), ys = jax.lax.scan(
                body, (params, opt_state, gns0), (toks, labs, msks)
            )
            losses, sq_is, sq_gs, valids = ys
            b_noise = jnp.where(
                (cnt > 0) & (eg > 0.0),
                jnp.maximum(es / jnp.where(eg > 0.0, eg, 1.0), 0.0),
                jnp.inf,
            )
            t_stars, sweep_iters = optperf_jax.solve_optperf_sweep_device(
                dc, cand, lo0
            )
            # Realized per-candidate OptPerf: finalize each partition and
            # take the max node time — at small totals a clamped node's
            # fixed floor sits above the bisected water level, and the host
            # oracle's goodput uses the realized time.
            parts = optperf_jax.device_partition(dc, t_stars[:, None], cand)
            opt_perfs = optperf_jax.device_node_times(dc, parts).max(-1)
            bn = jnp.maximum(b_noise, 0.0)
            eff = jnp.where(jnp.isfinite(b_noise), (bn + b0) / (bn + cand), 1.0)
            goodputs = (cand / opt_perfs) * eff
            best = jnp.argmax(goodputs)
            telemetry = (
                losses, sq_is, sq_gs, valids, eg, es, cnt, b_noise,
                opt_perfs, goodputs, best, parts[best], sweep_iters,
            )
            return params, opt_state, telemetry

        fn = jax.jit(epoch_fn)
        self._fused_cache[key] = fn
        return fn

    def execute_fused(
        self,
        batches: Sequence[int],
        steps: int,
        *,
        lr_scale: float = 1.0,
        ctx: FusedSweepContext,
    ) -> Tuple[ExecutionResult, FusedProposal]:
        """Run one adaptive epoch as a single fused device program and
        return the on-device batch proposal for the *next* epoch alongside
        the usual :class:`ExecutionResult`.

        Exactly the same statistical semantics as :meth:`execute` (same
        step body scanned, same guard, same GNS gating — the EMA runs in
        float32 on device instead of float64 on host), plus the goodput
        sweep over ``ctx.candidates`` evaluated against the epoch-final
        noise scale.  The caller certifies the proposal off the critical
        path via :meth:`CannikinController.stage_fused_proposal`.
        """
        if self.cluster is None:
            raise RuntimeError("RealBackend not configured with a cluster")
        import jax
        import jax.numpy as jnp

        from repro.data.pipeline import HeteroBatchPartitioner

        batches = [int(b) for b in batches]
        b_arr = np.asarray(batches, np.int64)
        b_max = _quantize(int(b_arr.max()))
        n = len(batches)
        use_sharded = self.sharded and n > 0

        node_ids = self._node_ids if len(self._node_ids) == n else tuple(range(n))
        if self.injector is not None:
            poison_np = self.injector.poison_factors(node_ids)
        else:
            poison_np = np.ones(n, np.float32)

        # Stage the whole epoch's data host-side, ship it once.
        toks = labs = msks = None
        for s in range(steps):
            raw = self.data.batch(self.steps_done, int(b_arr.sum()))
            self.steps_done += 1
            padded, _ = HeteroBatchPartitioner.padded(raw, batches)
            seq = padded["tokens"].shape[-1]
            if toks is None:
                toks = np.zeros((steps, n, b_max, seq), np.int32)
                labs = np.zeros((steps, n, b_max, seq), np.int32)
                msks = np.zeros((steps, n, b_max), np.float32)
                for i, b in enumerate(batches):
                    msks[:, i, :b] = 1.0
            w = padded["tokens"].shape[1]
            toks[s, :, :w], labs[s, :, :w] = padded["tokens"], padded["labels"]

        if use_sharded:
            place = self._node_placer(n, stacked=True)
            toks_d, labs_d, msks_d = place(toks), place(labs), place(msks)
        else:
            toks_d, labs_d, msks_d = (
                jnp.asarray(toks), jnp.asarray(labs), jnp.asarray(msks)
            )
        gns0 = (
            jnp.float32(self.gns.ema_g),
            jnp.float32(self.gns.ema_s),
            jnp.int32(self.gns.count),
        )
        # 3 stacked arrays + r/poison/lr/b_vec + 3 GNS carry scalars +
        # lo0/b0 — every host value entering the program this epoch.
        self.transfers.count_h2d(12)

        fused_fn = self._fused_epoch_fn(n)
        self.params, self.opt_state, telemetry = fused_fn(
            self.params,
            self.opt_state,
            toks_d,
            labs_d,
            msks_d,
            jnp.asarray(ratios(batches), jnp.float32),
            jnp.float32(lr_scale),
            jnp.asarray(poison_np, jnp.float32),
            jnp.asarray(b_arr, jnp.float32),
            gns0,
            ctx.coeffs,
            ctx.candidates,
            float(ctx.lo0),
            float(ctx.ref_batch),
        )
        pulled = jax.device_get(telemetry)
        self.transfers.count_d2h(len(jax.tree_util.tree_leaves(telemetry)))
        (
            losses_a, sq_is, sq_gs, valids, eg, es, cnt, b_noise_dev,
            t_stars, goodputs, best, best_batches, sweep_iters,
        ) = pulled

        self.gns = GNSState(ema_g=float(eg), ema_s=float(es), count=int(cnt))
        anomaly_counts = (~np.asarray(valids, bool)).sum(axis=0)
        losses: List[float] = [float(x) for x in np.asarray(losses_a)]
        grad_obs: List[GradObservation] = []
        for s in range(steps):
            valid_np = np.asarray(valids[s], bool)
            self.anomalous_steps += int(not valid_np.all())
            grad_obs.append(
                GradObservation(
                    local_sqnorms=tuple(float(x) for x in np.asarray(sq_is[s])),
                    global_sqnorm=float(sq_gs[s]),
                    batches=tuple(batches),
                    valid=tuple(bool(v) for v in valid_np),
                )
            )

        epoch_seconds, measurements = self.cluster.run_epoch(batches, steps)
        measurements = list(measurements)
        if self.injector is not None:
            epoch_seconds, measurements = self.injector.perturb(
                self._job or "?", node_ids, epoch_seconds, measurements
            )
        self.sim_time += epoch_seconds

        result = ExecutionResult(
            epoch_seconds=epoch_seconds,
            measurements=tuple(measurements),
            losses=tuple(losses),
            grad_observations=tuple(grad_obs),
            b_noise=self.gns.b_noise,
            grad_anomalies=tuple(int(c) for c in anomaly_counts),
        )
        best_i = int(best)
        cand_np = np.asarray(ctx.candidates_np, np.float64)
        proposal = FusedProposal(
            best_index=best_i,
            total_batch=float(cand_np[best_i]),
            batches=np.asarray(best_batches, np.float64),
            t_star=float(np.asarray(t_stars)[best_i]),
            t_stars=np.asarray(t_stars, np.float64),
            goodputs=np.asarray(goodputs, np.float64),
            b_noise=float(b_noise_dev),
            sweep_iters=int(sweep_iters),
        )
        return result, proposal

    def _track_gns(self, obs: GradObservation) -> None:
        """Theorem-4.1 tracker (same guarded update the controller uses).

        Deliberately independent of any controller's tracker: the backend's
        ``b_noise`` serves baseline policies and standalone use, while a
        CannikinController re-ingests the same observations into its own
        state for planning.  The duplicate estimate is a host-side
        least-squares on an n-vector per step — noise next to the jitted
        training step."""
        try:
            _, g, s = estimate_gns(obs.local_sqnorms, obs.global_sqnorm, obs.batches)
        except (ValueError, np.linalg.LinAlgError):
            return
        self.gns = gns_update(self.gns, g, s, decay=self.gns_decay)

    # -- preemption state ------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """The checkpointable pytree: everything that must survive
        preemption (params, opt-state, GNS state, stream counters).

        Sharded mode gathers params/opt-state to host numpy first, so the
        snapshot (and the PR-7 checkpoint generations built from it) is
        byte-identical to the single-device layout and restores onto any
        later mesh."""
        params, opt_state = self.params, self.opt_state
        if self.sharded:
            import jax

            gather = lambda leaf: np.asarray(jax.device_get(leaf))  # noqa: E731
            params = jax.tree_util.tree_map(gather, params)
            opt_state = jax.tree_util.tree_map(gather, opt_state)
        return {
            "params": params,
            "opt_state": opt_state,
            "gns": {
                "ema_g": np.float64(self.gns.ema_g),
                "ema_s": np.float64(self.gns.ema_s),
                "count": np.int64(self.gns.count),
            },
            "counters": {
                "steps_done": np.int64(self.steps_done),
                "sim_time": np.float64(self.sim_time),
            },
        }

    def load_snapshot(self, state: Dict[str, Any]) -> None:
        self.params = state["params"]
        self.opt_state = state["opt_state"]
        g = state["gns"]
        self.gns = GNSState(
            ema_g=float(g["ema_g"]), ema_s=float(g["ema_s"]), count=int(g["count"])
        )
        c = state["counters"]
        self.steps_done = int(c["steps_done"])
        self.sim_time = float(c["sim_time"])

    def checkpoint(self, path: str) -> None:
        from repro.train import checkpoint as ckpt  # lazy: avoids import cycle

        ckpt.save(path, self.snapshot())

    def restore(self, path: str) -> None:
        from repro.train import checkpoint as ckpt  # lazy: avoids import cycle

        self.load_snapshot(ckpt.restore(path, self.snapshot()))


# ---------------------------------------------------------------------------
# backend factory
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RealBackendConfig:
    """Recipe the runtime uses to build a :class:`RealBackend` for each job
    whose :class:`JobSpec` names ``backend="real"`` (model/data/optimizer
    are runtime-level concerns, not per-job spec payload)."""

    arch: str = "olmo-1b"
    seq_len: int = 32
    lr: float = 0.3
    gns_decay: float = 0.9
    sharded: bool = False

    def build(
        self, *, noise: float = 0.0, seed: int = 0, injector: Any = None
    ) -> RealBackend:
        from repro.configs import get_api
        from repro.data.pipeline import SyntheticLM
        from repro.optim.optimizers import constant_schedule, sgd

        api = get_api(self.arch, reduced=True)
        data = SyntheticLM(vocab=api.cfg.vocab, seq_len=self.seq_len, seed=seed)
        return RealBackend(
            api,
            sgd(constant_schedule(self.lr)),
            data,
            noise=noise,
            seed=seed,
            gns_decay=self.gns_decay,
            injector=injector,
            sharded=self.sharded,
        )


BACKENDS = ("sim", "real")


def make_backend(
    kind: str,
    *,
    noise: float = 0.0,
    seed: int = 0,
    real_config: Optional[RealBackendConfig] = None,
    injector: Any = None,
) -> "ExecutionBackend":
    """Build an execution backend by the name a :class:`JobSpec` carries."""
    if kind == "sim":
        return SimBackend(noise=noise, injector=injector)
    if kind == "real":
        return (real_config or RealBackendConfig()).build(
            noise=noise, seed=seed, injector=injector
        )
    raise ValueError(f"unknown execution backend {kind!r}; choose from {BACKENDS}")


# ---------------------------------------------------------------------------
# the policy loop (runtime-owned)
# ---------------------------------------------------------------------------


def run_backend_epoch(
    policy: Any,
    backend: "ExecutionBackend",
    *,
    steps: int,
    epoch_index: int = 0,
    last_measurement: Optional[StepMeasurement] = None,
    fixed_total: Optional[int] = None,
    fused: bool = False,
) -> Tuple[EpochRecord, ExecutionResult]:
    """One plan → execute → observe cycle over any backend.

    ``policy`` is a :class:`CannikinController` (plans epochs, ingests
    measurement + gradient telemetry) or a baseline partition policy
    (``partition(total, epoch, last_measurement)``).  Returns the unified
    :class:`EpochRecord` plus the raw :class:`ExecutionResult` (callers that
    loop feed ``result.measurements[-1]`` back as ``last_measurement``).

    ``fused=True`` (CannikinController + a backend with ``execute_fused``)
    runs the epoch as one fused device program: the plan consumes the
    proposal the *previous* fused epoch staged on device, and this epoch's
    program stages the next one.  Whenever the controller cannot supply a
    fused context (bootstrap, jax missing, certification failure) the cycle
    is exactly the two-program path — bit-compatible fallback.
    """
    if isinstance(policy, CannikinController):
        plan = policy.plan_epoch(prefer_fused=fused)
        epoch = plan.epoch
        batches = list(plan.batches)
        total = plan.total_batch
        lr_scale = plan.lr_scale
        predicted = plan.predicted_batch_time
        phase = plan.phase
    else:
        plan = None
        epoch = epoch_index
        total = getattr(policy, "total_batch", None) or fixed_total or 64
        batches = policy.partition(total, epoch, last_measurement)
        lr_scale, predicted, phase = 1.0, None, policy.name
    fused_ctx = None
    if (
        fused
        and isinstance(policy, CannikinController)
        and hasattr(backend, "execute_fused")
    ):
        fused_ctx = policy.fused_context()
    if fused_ctx is not None:
        result, proposal = backend.execute_fused(
            batches, steps, lr_scale=lr_scale, ctx=fused_ctx
        )
    else:
        result, proposal = backend.execute(batches, steps, lr_scale=lr_scale), None
    if isinstance(policy, CannikinController):
        policy.observe_execution(result)
        if fused_ctx is not None and proposal is not None:
            policy.stage_fused_proposal(fused_ctx, proposal)
        b_noise = policy.gns.b_noise
    else:
        b_noise = result.b_noise
    record = EpochRecord(
        epoch=epoch,
        backend=getattr(backend, "kind", "?"),
        total_batch=int(total),
        batches=tuple(int(b) for b in batches),
        lr_scale=float(lr_scale),
        phase=phase,
        predicted_batch_time=predicted,
        measured_batch_time=result.epoch_seconds / max(steps, 1),
        epoch_seconds=result.epoch_seconds,
        mean_loss=result.mean_loss,
        b_noise=b_noise,
        plan=plan,
    )
    return record, result


class EpochLoop:
    """The standalone policy loop: drive one (policy, backend) pair epoch by
    epoch, accumulating :class:`EpochRecord` history.  ``HeteroTrainer`` and
    the launch CLI are shells over this; ``JobHandle.advance`` runs the same
    :func:`run_backend_epoch` cycle under runtime lifecycle control."""

    def __init__(
        self,
        policy: Any,
        backend: "ExecutionBackend",
        *,
        steps_per_epoch: int = 8,
        fixed_total: Optional[int] = None,
        fused: bool = False,
    ) -> None:
        self.policy = policy
        self.backend = backend
        self.steps_per_epoch = steps_per_epoch
        self.fixed_total = fixed_total
        self.fused = fused
        self.epoch = 0
        self.history: List[EpochRecord] = []
        self._last_measurement: Optional[StepMeasurement] = None

    @property
    def sim_time(self) -> float:
        return self.backend.sim_time  # type: ignore[attr-defined]

    def run_epoch(self) -> EpochRecord:
        record, result = run_backend_epoch(
            self.policy,
            self.backend,
            steps=self.steps_per_epoch,
            epoch_index=self.epoch,
            last_measurement=self._last_measurement,
            fixed_total=self.fixed_total,
            fused=self.fused,
        )
        self.epoch += 1
        if result.measurements:
            self._last_measurement = result.measurements[-1]
        self.history.append(record)
        return record

    def run(
        self, epochs: int, *, target_loss: Optional[float] = None
    ) -> List[EpochRecord]:
        for _ in range(epochs):
            record = self.run_epoch()
            if target_loss is not None and record.mean_loss <= target_loss:
                break
        return self.history

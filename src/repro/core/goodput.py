"""Goodput model and total-batch-size selection (§2.2, §4.1, Pollux-style).

goodput(B) = throughput(B) * efficiency(B)

  * throughput(B) = B / OptPerf(B)   — samples/sec at the *optimal* hetero
    partition for B (this is where Cannikin differs from Pollux: Pollux's
    throughput model assumes even shards).
  * efficiency(B) = (B_noise + B0) / (B_noise + B) — statistical efficiency
    relative to the user's reference batch size B0 (McCandlish/Pollux).

The whole candidate sweep is evaluated in one array pass via
:func:`goodput_curve` / :func:`repro.core.optperf.solve_optperf_batch`
(O(~200) NumPy broadcasts for any number of candidates); the scalar
:func:`goodput` remains for single-B queries and as the cross-check oracle.

Also provides the AdaScale learning-rate gain used by the SGD workloads and
the square-root scaling rule used by Adam-family workloads (Table 4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.optperf import (
    BatchedOptPerfSolution,
    OptPerfSolution,
    solve_optperf,
    solve_optperf_algorithm1_batch,
    solve_optperf_batch,
)
from repro.core.perf_model import ClusterPerfModel

__all__ = [
    "statistical_efficiency",
    "goodput",
    "goodput_curve",
    "GoodputCurve",
    "adascale_gain",
    "sqrt_lr_scale",
    "BatchSizeSelector",
]


def statistical_efficiency(b_noise: float, batch, ref_batch: float):
    """E(B) = (B_noise + B0) / (B_noise + B); E(B0) = 1, decreasing in B.

    ``batch`` may be a scalar (returns float) or an array (returns an array
    of the same shape).
    """
    b = np.asarray(batch, dtype=np.float64)
    if np.any(b <= 0) or ref_batch <= 0:
        raise ValueError("batch sizes must be positive")
    if not np.isfinite(b_noise):
        eff = np.ones_like(b)
    else:
        b_noise = max(b_noise, 0.0)
        eff = (b_noise + ref_batch) / (b_noise + b)
    return float(eff) if np.ndim(batch) == 0 else eff


def goodput(
    model: ClusterPerfModel,
    batch: float,
    b_noise: float,
    ref_batch: float,
    *,
    solver: str = "algorithm1",
    boundary_hint: Optional[int] = None,
) -> Tuple[float, OptPerfSolution]:
    """goodput(B) and the OptPerf partition that realizes it."""
    sol = solve_optperf(model, batch, method=solver, boundary_hint=boundary_hint)
    thr = batch / sol.opt_perf
    eff = statistical_efficiency(b_noise, batch, ref_batch)
    return thr * eff, sol


def _array_sweep_solver(engine: str):
    """The array-engine entry point for a full sweep: the jit on-device
    solver for ``engine == "jax"`` (silently falling back to the NumPy
    batched engine when JAX is unavailable), else the NumPy batched engine.
    Shared gating for :func:`goodput_curve` and :class:`BatchSizeSelector`.
    """
    if engine == "jax":
        try:
            from repro.core import optperf_jax

            if optperf_jax.HAS_JAX:
                return optperf_jax.solve_optperf_batch_jax
        except ImportError:  # pragma: no cover - jax present in CI image
            pass
    return solve_optperf_batch


@dataclasses.dataclass(frozen=True)
class GoodputCurve:
    """goodput(B) over a candidate vector, solved in one batched pass."""

    candidates: np.ndarray          # (C,) total batch sizes
    goodputs: np.ndarray            # (C,) samples/sec * efficiency
    throughputs: np.ndarray         # (C,) samples/sec at the OptPerf partition
    efficiencies: np.ndarray        # (C,) statistical efficiency
    solutions: BatchedOptPerfSolution

    def best_index(self) -> int:
        return int(np.argmax(self.goodputs))

    def best(self) -> Tuple[float, OptPerfSolution, float]:
        """(best B, its OptPerf solution, its goodput)."""
        j = self.best_index()
        return (
            float(self.candidates[j]),
            self.solutions.solution(j),
            float(self.goodputs[j]),
        )


def goodput_curve(
    model: ClusterPerfModel,
    candidates: Sequence[float],
    b_noise: float,
    ref_batch: float,
    *,
    engine: str = "batched",
    warm_start: Optional[np.ndarray] = None,
) -> GoodputCurve:
    """Vectorized goodput(B) for every candidate total batch size.

    One :func:`solve_optperf_batch` call (a ``(C,)``-bracket bisection against
    a ``(C, n)`` feasible-batch matrix) replaces the per-candidate scalar
    sweep; cost is independent of the candidate count up to the O(C*n) array
    arithmetic inside each of the ~50 bisection steps — or a handful with a
    ``warm_start`` (the previous epoch's ``curve.solutions.t_stars``).
    ``engine="jax"`` runs the sweep jit-compiled on-device.
    """
    cands = np.array(candidates, dtype=np.float64)  # copy: no aliasing
    cands.flags.writeable = False
    if engine not in ("batched", "jax"):
        raise ValueError(f"unknown goodput_curve engine {engine!r}")
    sols = _array_sweep_solver(engine)(model, cands, warm_start=warm_start)
    thr = cands / sols.opt_perfs
    eff = statistical_efficiency(b_noise, cands, ref_batch)
    return GoodputCurve(
        candidates=cands,
        goodputs=thr * eff,
        throughputs=thr,
        efficiencies=np.asarray(eff, dtype=np.float64),
        solutions=sols,
    )


def adascale_gain(b_noise: float, batch: float, ref_batch: float) -> float:
    """AdaScale gain r(B): the effective number of reference-size steps one
    big-batch step is worth;  r = (B_noise/B0 + 1) / (B_noise/B + 1) in the
    variance-dominated regime.  Clipped to [1, B/B0]."""
    if not np.isfinite(b_noise) or b_noise <= 0:
        return batch / ref_batch
    r = (b_noise / ref_batch + 1.0) / (b_noise / batch + 1.0)
    return float(np.clip(r, 1.0, batch / ref_batch))


def sqrt_lr_scale(batch: float, ref_batch: float) -> float:
    """Square-root LR scaling for Adam-family optimizers (Table 4)."""
    return float(np.sqrt(batch / ref_batch))


@dataclasses.dataclass
class BatchSizeSelector:
    """Enumerates total-batch-size candidates and picks argmax goodput.

    Implements the §4.5 "Total batch size selection" optimization: OptPerf is
    batch-size-dependent but *training-progress-independent*, so after the
    initial sweep the per-candidate OptPerf values (and their overlap
    states) are cached; subsequent epochs only recompute the candidate that
    wins under the updated GNS, unless its overlap state changed — then the
    full sweep re-runs.

    ``engine`` selects how a full sweep is executed: ``"batched"`` (default)
    solves every candidate in one :func:`solve_optperf_batch` array pass;
    ``"jax"`` runs the same sweep jit-compiled on-device
    (:mod:`repro.core.optperf_jax`; silently falls back to ``"batched"``
    when JAX is unavailable); ``"scalar"`` is the original per-candidate
    loop with §4.5 boundary-hint chaining, kept as the cross-check oracle.
    Either way the winning candidate is re-solved with the scalar
    ``solver``, so the emitted plan is identical across engines.

    Incremental re-bracketing: the array engines carry the previous sweep's
    ``t_stars`` vector and seed the next sweep's brackets from it, cutting a
    full bisection to a handful of array passes when the performance models
    drifted only a little between epochs.  The warm state is dropped —
    falling back to cold brackets — whenever the cluster membership (node
    count), the candidate set, or the coefficient regime changed (any
    coefficient moved by more than ``warm_drift_limit`` relative).
    """

    candidates: Tuple[int, ...]
    ref_batch: int
    solver: str = "algorithm1"
    engine: str = "batched"
    warm_drift_limit: float = 0.25
    # epoch -> cache
    _optperf_cache: Dict[int, OptPerfSolution] = dataclasses.field(default_factory=dict)
    _state_cache: Dict[int, Tuple[str, ...]] = dataclasses.field(default_factory=dict)
    full_sweeps: int = 0
    incremental_updates: int = 0
    warm_sweeps: int = 0
    cold_sweeps: int = 0
    _warm_t_stars: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    _warm_signature: Optional[Tuple[np.ndarray, ...]] = dataclasses.field(
        default=None, repr=False
    )

    def __post_init__(self) -> None:
        if self.engine not in ("batched", "scalar", "jax"):
            raise ValueError(f"unknown sweep engine {self.engine!r}")

    # -- warm-start state ----------------------------------------------

    @staticmethod
    def _model_signature(model: ClusterPerfModel) -> Tuple[np.ndarray, ...]:
        c = model.coeffs
        comm = np.asarray([model.comm.t_o, model.comm.t_u, model.comm.gamma])
        # ks/ms are part of the regime signature even though they do not
        # move t_star: they drive the overlap-state criterion, and a refit
        # that changes only the backprop split must still count as drift
        # (stale-regime brackets would otherwise be trusted blindly).
        return (c.alphas, c.cs, c.betas, c.ds, c.ks, c.ms, comm)

    def _warm_start_for(self, model: ClusterPerfModel) -> Optional[np.ndarray]:
        """Previous t_stars if they are still trustworthy seeds, else None."""
        if self._warm_t_stars is None or self._warm_signature is None:
            return None
        if self._warm_t_stars.shape[0] != len(self.candidates):
            return None
        sig = self._model_signature(model)
        for old, new in zip(self._warm_signature, sig):
            if old.shape != new.shape:   # cluster membership changed
                return None
            denom = np.maximum(np.abs(old), 1e-12)
            if float(np.max(np.abs(new - old) / denom)) > self.warm_drift_limit:
                return None              # coefficient regime changed
        return self._warm_t_stars

    def invalidate(self) -> None:
        """Drop every cached solution *and* the warm-start state (cluster
        membership changes route through here)."""
        self._optperf_cache.clear()
        self._state_cache.clear()
        self._warm_t_stars = None
        self._warm_signature = None

    def _sweep(self, model: ClusterPerfModel) -> None:
        self.full_sweeps += 1
        ordered = sorted(self.candidates)
        if self.engine in ("batched", "jax"):
            warm = self._warm_start_for(model)
            solver = _array_sweep_solver(self.engine)
            batch_sol = solver(
                model, np.asarray(ordered, dtype=np.float64), warm_start=warm
            )
            for j, b in enumerate(ordered):
                sol = batch_sol.solution(j)
                self._optperf_cache[b] = sol
                self._state_cache[b] = sol.bottleneck
            if warm is None:
                self.cold_sweeps += 1
            else:
                self.warm_sweeps += 1
            self._warm_t_stars = np.asarray(batch_sol.t_stars, dtype=np.float64)
            self._warm_signature = self._model_signature(model)
            return
        if self.solver == "algorithm1":
            # Batched boundary checks: Check 1/Check 2 vectorized across the
            # whole candidate vector, bit-equal per row to the scalar loop
            # below (which stays the oracle; see
            # :func:`repro.core.optperf.solve_optperf_algorithm1_batch`).
            for b, sol in zip(
                ordered, solve_optperf_algorithm1_batch(model, ordered)
            ):
                self._optperf_cache[b] = sol
                self._state_cache[b] = sol.bottleneck
            return
        hint: Optional[int] = None
        for b in ordered:
            sol = solve_optperf(model, b, method=self.solver, boundary_hint=hint)
            self._optperf_cache[b] = sol
            self._state_cache[b] = sol.bottleneck
            # §4.5 "Overlap state searching": warm-start the next (larger)
            # candidate from this one's boundary (count of compute nodes).
            hint = sum(1 for s in sol.bottleneck if s == "compute")

    def select(
        self, model: ClusterPerfModel, b_noise: float
    ) -> Tuple[int, OptPerfSolution, float]:
        """Return (best total batch, its OptPerf solution, its goodput)."""
        if not self._optperf_cache:
            self._sweep(model)

        def cached_goodput(b: int) -> float:
            sol = self._optperf_cache[b]
            eff = statistical_efficiency(b_noise, b, self.ref_batch)
            return (b / sol.opt_perf) * eff

        best = max(self.candidates, key=cached_goodput)
        # Re-solve only the winner with fresh performance models.
        fresh = solve_optperf(model, best, method=self.solver)
        if fresh.bottleneck != self._state_cache.get(best):
            # Overlap pattern changed -> cached landscape is stale: resweep.
            self._sweep(model)
            best = max(self.candidates, key=cached_goodput)
            # Re-solve the (possibly new) winner with the scalar solver so
            # the emitted plan is engine-independent on this path too.
            fresh = solve_optperf(model, best, method=self.solver)
            self._optperf_cache[best] = fresh
            self._state_cache[best] = fresh.bottleneck
        else:
            self.incremental_updates += 1
            self._optperf_cache[best] = fresh
        eff = statistical_efficiency(b_noise, best, self.ref_batch)
        return best, fresh, (best / fresh.opt_perf) * eff

"""ClusterRuntime acceptance tests: the event-driven front door must be a
faithful (and incremental) veneer over the scheduler/controller/simulator
machinery it wraps."""
import pytest

from repro.core.scheduler import Scheduler, allocate, random_jobs
from repro.runtime import (
    ClusterRuntime,
    JobState,
    ModelRefit,
    Trace,
    compare_policies,
    drift_spec,
    make_policy,
    replay,
    synthetic_trace,
)

N_NODES = 12


def _cold_solved_rows(jobs, n_nodes, down=()):
    """Marginal rows a COLD full re-allocation of this job set solves (fresh
    scheduler, no caches) — the baseline the incremental runtime must beat."""
    sched = Scheduler(n_nodes)
    for job in jobs:
        sched._jobs[job.name] = job  # noqa: SLF001 (install without allocating)
    sched._down = set(down)  # noqa: SLF001
    sched.reallocate()
    return sched.solved_rows


# ---------------------------------------------------------------------------
# acceptance: trace replay == hand-driven incremental Scheduler, warm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(3))
def test_trace_replay_matches_hand_driven_scheduler(seed):
    """The seeded 3-job trace (arrivals, one departure, one node leave)
    through ``policy="cannikin"`` produces allocations identical to driving
    the incremental Scheduler by hand — events map 1:1 onto its entry
    points, so goodputs and counters agree exactly."""
    trace, jobs = synthetic_trace(3, N_NODES, seed=seed)
    report = replay(trace, N_NODES, policy="cannikin")

    sched = Scheduler(N_NODES)
    hand = [
        sched.add_job(jobs[0]),
        sched.add_job(jobs[1]),
        sched.add_job(jobs[2]),
        sched.remove_job(jobs[0].name),
        sched.node_leave([N_NODES - 1]),
    ]
    assert len(report.records) == len(hand)
    for record, expected in zip(report.records, hand):
        assert record.allocation.assignment == expected.assignment
        assert record.allocation.goodputs == expected.goodputs
        assert record.allocation.fractions == expected.fractions
    rt_counters = report.runtime.counters()
    assert rt_counters["warm_rounds"] == sched.warm_rounds
    assert rt_counters["solved_rows"] == sched.solved_rows
    assert rt_counters["cached_rows"] == sched.cached_rows


def test_trace_replay_is_incremental_never_cold():
    """After the first event every reconcile reuses cached rows / warm
    seeds: each event solves strictly fewer rows than a cold full re-run of
    the same post-event job set would."""
    trace, jobs = synthetic_trace(3, N_NODES, seed=0)
    rt = ClusterRuntime(N_NODES, policy="cannikin")
    for event in trace:
        rt.post(event)

    live = []
    down = set()
    deltas = []
    colds = []
    prev_solved = 0
    while rt.pending_events:
        record = rt.step()
        label = record.label
        if label.startswith("arrive"):
            live.append(next(j for j in jobs if f"({j.name})" in label))
        elif label.startswith("complete"):
            live = [j for j in live if f"({j.name})" not in label]
        elif label.startswith("node_leave"):
            down.add(N_NODES - 1)
        solved = rt.counters()["solved_rows"]
        deltas.append(solved - prev_solved)
        prev_solved = solved
        colds.append(_cold_solved_rows(live, N_NODES, down))

    # Every post-first event re-solved fewer rows than a cold run would.
    for delta, cold in zip(deltas[1:], colds[1:]):
        assert delta < cold, (delta, cold)
    counters = rt.counters()
    assert counters["cached_rows"] > 0          # trajectories replayed
    assert counters["warm_rounds"] > 0          # diverged rounds warm-seeded
    # Cold block solves only ever happen for a job's *first* rows (no warm
    # seeds exist yet) — never once per event per job.
    assert counters["cold_rounds"] <= len(jobs)


def test_node_leave_keeps_caches_and_excludes_node():
    """Node churn must not cold-restart the scheduler: the row layout is
    preserved, the down node is simply never assigned, and a rejoin restores
    it — all incrementally."""
    jobs = random_jobs(3, 8, seed=3)
    rt = ClusterRuntime(8, policy="cannikin")
    for i, job in enumerate(jobs):
        rt.submit(job, at=float(i))
    rt.run()
    before = rt.counters()["solved_rows"]

    rt.node_leave([7], at=10.0)
    rt.run()
    assert all(7 not in ids for ids in rt.allocation.assignment.values())
    assert rt.down_nodes == {7}
    leave_delta = rt.counters()["solved_rows"] - before
    assert leave_delta < _cold_solved_rows(jobs, 8)

    rt.node_join([7], at=11.0)
    rt.run()
    assert rt.down_nodes == set()
    # Rejoin replays the original trajectory entirely from cache.
    assert rt.allocation.assignment == allocate(jobs, 8).assignment


# ---------------------------------------------------------------------------
# policy comparison
# ---------------------------------------------------------------------------


def test_policies_run_same_trace_comparably():
    trace, jobs = synthetic_trace(3, N_NODES, seed=0)
    reports = compare_policies(trace, N_NODES)
    assert set(reports) == {"cannikin", "static", "fair-share"}
    for name, rep in reports.items():
        assert rep.aggregate_goodput > 0, name
        assert rep.aggregate_fraction > 0, name
        # disjoint assignments, no down nodes
        assigned = [n for ids in rep.runtime.allocation.assignment.values() for n in ids]
        assert len(assigned) == len(set(assigned)), name
        assert N_NODES - 1 not in assigned, name  # left at the end of the trace
        assert rep.job_states[jobs[0].name] == JobState.DONE
        summary = rep.summary()
        assert summary["policy"] == name
    # The heterogeneity-aware allocator wins the fairness objective it
    # optimizes on this seeded mix.
    assert (
        reports["cannikin"].aggregate_fraction
        >= max(r.aggregate_fraction for r in reports.values()) - 1e-9
    )


def test_replay_is_deterministic():
    trace, _ = synthetic_trace(3, 10, seed=5)
    a = replay(trace, 10, policy="cannikin", epochs_per_event=1, steps=2)
    b = replay(trace, 10, policy="cannikin", epochs_per_event=1, steps=2)
    assert a.summary() == b.summary()


def test_static_and_fair_share_assignment_shapes():
    jobs = random_jobs(2, 8, seed=1)
    static = make_policy("static", 8)
    fair = make_policy("fair-share", 8)
    for job in jobs:
        s_alloc = static.add_job(job)
        f_alloc = fair.add_job(job)
    # static: contiguous equal blocks in arrival order
    assert s_alloc.assignment[jobs[0].name] == (0, 1, 2, 3)
    assert s_alloc.assignment[jobs[1].name] == (4, 5, 6, 7)
    # fair-share: round-robin deal across the id range
    assert f_alloc.assignment[jobs[0].name] == (0, 2, 4, 6)
    assert f_alloc.assignment[jobs[1].name] == (1, 3, 5, 7)
    # node churn respected by baselines too
    s_alloc = static.node_leave([0])
    assert all(0 not in ids for ids in s_alloc.assignment.values())
    with pytest.raises(ValueError):
        make_policy("optimal", 8)


# ---------------------------------------------------------------------------
# job lifecycle
# ---------------------------------------------------------------------------


def test_job_lifecycle_preempt_resume_complete():
    jobs = random_jobs(2, 6, seed=2)
    rt = ClusterRuntime(6, policy="cannikin")
    h0 = rt.submit(jobs[0], at=0.0)
    h1 = rt.submit(jobs[1], at=1.0)
    assert h0.state == JobState.PENDING  # not reconciled yet
    rt.run()
    assert h0.state == JobState.RUNNING and h1.state == JobState.RUNNING
    assert h0.nodes and h1.nodes

    rt.preempt(jobs[1].name, at=2.0)
    rt.run()
    assert h1.state == JobState.PREEMPTED
    assert h1.nodes == ()
    assert h1.preemptions == 1
    # Preempted job's nodes went back to the pool.
    assert len(rt.allocation.assignment[jobs[0].name]) == 6

    rt.submit(jobs[1], at=3.0)  # resume
    rt.run()
    assert h1.state == JobState.RUNNING
    assert rt.handles[jobs[1].name] is h1  # same handle, models retained

    rt.complete(jobs[1].name, at=4.0)
    rt.run()
    assert h1.state == JobState.DONE
    assert h1.finished_at == 4.0
    with pytest.raises(ValueError):
        rt.submit(jobs[1], at=5.0)
        rt.run()
    with pytest.raises(KeyError):
        rt.complete("ghost", at=6.0)
        rt.run()


def test_preempted_job_can_complete_refit_and_repreempt():
    """Lifecycle edges off the cluster: a preempted job is unknown to the
    policy, so completing (cancelling) it, refitting it, or preempting it
    again must not touch the policy — and must not crash or half-mutate."""
    jobs = random_jobs(3, 6, seed=12)
    rt = ClusterRuntime(6, policy="cannikin")
    for job in jobs:
        rt.submit(job)
    rt.run()

    rt.preempt(jobs[0].name, at=1.0)
    rt.run()
    h0 = rt.handles[jobs[0].name]
    assert h0.state == JobState.PREEMPTED
    alloc_after_preempt = rt.allocation

    # Refit while preempted: handle spec refreshed, allocation untouched.
    rt.refit(jobs[0].name, rel=0.3, seed=3, at=2.0)
    rt.run()
    assert h0.spec == drift_spec(jobs[0], 0.3, 3)
    assert rt.allocation is alloc_after_preempt

    # Double preemption is idempotent.
    rt.preempt(jobs[0].name, at=3.0)
    rt.run()
    assert h0.state == JobState.PREEMPTED and h0.preemptions == 2

    # Cancelling the preempted job closes the handle without a policy call.
    rt.complete(jobs[0].name, at=4.0)
    rt.run()
    assert h0.state == JobState.DONE
    assert rt.allocation is alloc_after_preempt
    # The remaining jobs' allocation is still the live two-job split.
    assert set(rt.allocation.assignment) == {jobs[1].name, jobs[2].name}


def test_advance_runs_controllers_to_optperf():
    """JobHandles own real CannikinControllers: epochs bootstrap, fit, and
    reach the optperf phase; stats and plans are surfaced."""
    jobs = random_jobs(2, 6, seed=4)
    rt = ClusterRuntime(6, policy="cannikin")
    for job in jobs:
        rt.submit(job)
    rt.run()
    rt.advance(epochs=3, steps=2)
    for handle in rt.jobs(JobState.RUNNING):
        assert handle.epochs_run == 3
        assert handle.sim_time > 0
        assert handle.last_plan is not None
        if len(handle.nodes) > 1:
            assert handle.last_plan.phase == "optperf"
        else:
            # A 1-node job can never observe two distinct batch sizes at a
            # fixed total, so it stays in bootstrap — whose plan (the whole
            # batch on the one node) is already optimal.
            assert handle.last_plan.batches == (handle.spec.total_batch,)
        assert handle.last_plan.total_batch == handle.spec.total_batch
        assert len(handle.last_plan.batches) == len(handle.nodes)
        assert handle.stats.epochs_planned == 3


def test_reallocation_resizes_controller_elastically():
    """When an event changes a job's node set, its controller keeps fitted
    models for surviving nodes (remove_nodes) and bootstraps new ones
    (add_nodes) — the paper's §6 elastic semantics, automated."""
    jobs = random_jobs(2, 6, seed=6)
    rt = ClusterRuntime(6, policy="cannikin")
    h0 = rt.submit(jobs[0], at=0.0)
    rt.run()
    rt.advance(epochs=3, steps=2)          # job0 alone: learn all 6 nodes
    assert h0.last_plan.phase == "optperf"
    assert len(h0.nodes) == 6

    rt.submit(jobs[1], at=1.0)             # arrival shrinks job0's set
    rt.run()
    assert 0 < len(h0.nodes) < 6
    rt.advance(epochs=1, steps=2)
    # Surviving nodes kept their models: no re-bootstrap for job0.
    assert h0.last_plan.phase == "optperf"
    assert len(h0.last_plan.batches) == len(h0.nodes)


def test_model_refit_event_matches_update_job():
    """ModelRefit drives Scheduler.update_job with a deterministic drifted
    spec: stale caches are dropped, and the post-event allocation equals a
    cold allocate over the refreshed specs."""
    jobs = random_jobs(3, 10, seed=8)
    rt = ClusterRuntime(10, policy="cannikin")
    for job in jobs:
        rt.submit(job)
    rt.run()
    rt.post(ModelRefit(time=5.0, job=jobs[0].name, rel=0.5, seed=9))
    rt.run()
    refitted = drift_spec(jobs[0], 0.5, 9)
    expected = allocate([refitted, jobs[1], jobs[2]], 10)
    assert rt.allocation.assignment == expected.assignment
    for name in expected.goodputs:
        assert rt.allocation.goodputs[name] == pytest.approx(
            expected.goodputs[name], rel=1e-12
        )
    assert rt.handles[jobs[0].name].spec == refitted


# ---------------------------------------------------------------------------
# trace builder
# ---------------------------------------------------------------------------


def test_trace_builder_event_order_and_reuse():
    jobs = random_jobs(2, 6, seed=10)
    trace = (
        Trace()
        .arrive(jobs[0], at=0.0)
        .arrive(jobs[1], at=1.0)
        .preempt(jobs[0].name, at=2.0)
        .arrive(jobs[0], at=3.0)
        .refit(jobs[1].name, at=4.0, rel=0.1, seed=1)
        .complete(jobs[0].name, at=5.0)
    )
    assert len(trace) == 6
    first = replay(trace, 6)
    second = replay(trace, 6)  # traces are stateless: reusable
    assert first.summary() == second.summary()
    assert first.job_states[jobs[0].name] == JobState.DONE
    assert first.job_states[jobs[1].name] == JobState.RUNNING

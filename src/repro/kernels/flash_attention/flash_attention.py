"""Pallas TPU flash attention (blockwise online softmax).

TPU-native tiling: the grid is (batch*heads, q_blocks, kv_blocks) with the
kv dimension innermost; the running (max, denom, accumulator) state lives in
VMEM scratch and is carried across kv iterations of the same q block (the
standard Pallas "revisiting" pattern).  Block sizes default to 128x128 —
MXU-aligned (the 128x128 systolic array) — and the full head_dim rides in
the minor-most dim so every dot hits the MXU without re-tiling.

Masking supports causal and sliding-window; masked-out blocks are computed
-but-masked (the grid is static).  `ops.py` skips fully-masked kv blocks by
clamping the kv grid when the window makes them dead.

VMEM footprint per program (defaults, D=128, f32 scratch):
  q (128x128 bf16) + k,v (128x128) + acc/m/l (128x128 + 2x128 f32) ~ 200 KiB
— comfortably inside the ~16 MiB/core VMEM budget, leaving room for
double-buffered pipelining.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, block_q: int, block_k: int, n_kv_blocks: int,
    causal: bool, window: Optional[int], softmax_scale: float, kv_len: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * softmax_scale     # (bq, D)
    k = k_ref[0].astype(jnp.float32)                     # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                    # (bq, bk)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, None])
    # Rows with no valid key yet: keep everything at zero.
    p = jnp.where((m_new == NEG_INF)[:, None], 0.0, p)
    alpha = jnp.where(m_new == NEG_INF, 1.0, alpha)
    l_ref[...] = l_prev * alpha + p.sum(axis=-1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ik == n_kv_blocks - 1)
    def finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,  # (BH, S, D)
    k: jax.Array,  # (BH, T, D)
    v: jax.Array,  # (BH, T, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
    kv_len: Optional[int] = None,
) -> jax.Array:
    bh, s, d = q.shape
    t = k.shape[1]
    if s % block_q or t % block_k:
        raise ValueError(f"S={s} / T={t} must be multiples of the block sizes")
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    n_kv_blocks = t // block_k

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_kv_blocks,
        causal=causal,
        window=window,
        softmax_scale=scale,
        kv_len=t if kv_len is None else kv_len,  # mask out padded keys
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, s // block_q, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max
            pltpu.VMEM((block_q,), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32), # accumulator
        ],
        interpret=interpret,
    )(q, k, v)

"""whisper-large-v3 [audio] — 32L (encoder + decoder) d_model=1280 20H (MHA)
d_ff=5120 vocab=51866 — enc-dec, conv frontend STUB [arXiv:2212.04356].

`input_specs` supplies post-conv frame embeddings (B, S_enc, d_model); the
decoder consumes text tokens of length S_enc/4.  20 heads and vocab 51866
are not 16-divisible — replicated dims recorded by MeshRules.fallbacks.
long_500k is SKIPPED for this arch (DESIGN.md §5).
"""
from repro.models.whisper import WhisperConfig

ARCH_ID = "whisper-large-v3"


def config() -> WhisperConfig:
    return WhisperConfig(
        name=ARCH_ID,
        n_enc_layers=32,
        n_dec_layers=32,
        d_model=1280,
        n_heads=20,
        d_ff=5120,
        vocab=51866,
        head_dim=64,
        enc_frames=1500,
    )


def reduced() -> WhisperConfig:
    return WhisperConfig(
        name=ARCH_ID + "-reduced",
        n_enc_layers=2,
        n_dec_layers=2,
        d_model=128,
        n_heads=4,
        d_ff=256,
        vocab=512,
        head_dim=32,
        enc_frames=64,
        remat=False,
    )

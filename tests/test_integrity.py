"""Integrity-hardened real path: anomaly guards, checkpoint generations,
watchdog, invariant checker.

Covers the PR-7 acceptance criteria: the Eq. (9) gradient anomaly guard
excludes non-finite/outlier nodes and is bitwise-invisible on clean steps;
checkpoint generations are checksummed, pruned, and roll back to the
newest valid file; the numerical-health channel quarantines through the
PR-6 state machine; the deadline watchdog feeds the solver degradation
chain; the runtime invariant checker flags hand-broken state; and the
quarantine state machine stays live under random seeded fault plans
(satellite 3).
"""
import math
import os

import numpy as np
import pytest

from repro.core.aggregation import ANOMALY_OUTLIER_FACTOR, guard_weights
from repro.core.perf_model import CommModel
from repro.core.scheduler import JobSpec, random_jobs
from repro.core.simulator import GPU_CATALOG
from repro.runtime import (
    CannikinPolicy,
    CheckpointCorruption,
    ClusterRuntime,
    DeadlineExceeded,
    FaultInjector,
    FaultPlan,
    GradientPoison,
    HealthConfig,
    HealthMonitor,
    NodeState,
    RealBackendConfig,
    SolverStall,
    Straggler,
    Watchdog,
)
from repro.runtime.trace import TraceReport
from repro.train import checkpoint as ckpt


# ---------------------------------------------------------------------------
# Eq. (9) gradient anomaly guard
# ---------------------------------------------------------------------------


def _jnp():
    jnp = pytest.importorskip("jax.numpy")
    return jnp


def test_guard_weights_clean_step_returns_weights_bitwise():
    jnp = _jnp()
    r = jnp.asarray([0.5, 0.3, 0.2], dtype=jnp.float32)
    sq = jnp.asarray([1.0, 1.3, 0.8], dtype=jnp.float32)
    w, valid = guard_weights(sq, r)
    assert bool(jnp.all(valid))
    # Bit-identity on the all-valid path: the ORIGINAL weights, not a
    # renormalized reconstruction of them.
    np.testing.assert_array_equal(np.asarray(w), np.asarray(r))


@pytest.mark.parametrize("bad", [float("nan"), float("inf")])
def test_guard_weights_excludes_nonfinite_and_renormalizes(bad):
    jnp = _jnp()
    r = jnp.asarray([0.5, 0.3, 0.2], dtype=jnp.float32)
    sq = jnp.asarray([1.0, bad, 0.8], dtype=jnp.float32)
    w, valid = guard_weights(sq, r)
    assert [bool(v) for v in valid] == [True, False, True]
    w = np.asarray(w)
    assert w[1] == 0.0
    # Surviving weights are renormalized to a convex combination.
    assert w.sum() == pytest.approx(1.0, abs=1e-6)
    assert w[0] / w[2] == pytest.approx(0.5 / 0.2, rel=1e-5)


def test_guard_weights_excludes_norm_outlier():
    jnp = _jnp()
    r = jnp.asarray([1 / 3, 1 / 3, 1 / 3], dtype=jnp.float32)
    huge = 10.0 * ANOMALY_OUTLIER_FACTOR**2  # median sq-norm is 1.0
    sq = jnp.asarray([1.0, huge, 1.0], dtype=jnp.float32)
    w, valid = guard_weights(sq, r)
    assert [bool(v) for v in valid] == [True, False, True]
    assert np.asarray(w)[1] == 0.0


def test_guard_weights_all_invalid_yields_zero_update():
    jnp = _jnp()
    r = jnp.asarray([0.5, 0.5], dtype=jnp.float32)
    sq = jnp.asarray([float("nan"), float("inf")], dtype=jnp.float32)
    w, valid = guard_weights(sq, r)
    assert not bool(jnp.any(valid))
    np.testing.assert_array_equal(np.asarray(w), np.zeros(2, np.float32))


def test_poison_factor_values():
    def poison(mode):
        return GradientPoison(node=0, at_epoch=0, duration=1, mode=mode)

    assert math.isnan(poison("nan").factor_value())
    assert math.isinf(poison("inf").factor_value())
    assert poison("scale").factor_value() == 1e6
    with pytest.raises(ValueError):
        poison("mayhem").factor_value()


def test_injector_poison_factors_window():
    plan = FaultPlan(
        poisons=(GradientPoison(node=1, at_epoch=1, duration=2, mode="nan"),)
    )
    inj = FaultInjector(plan)
    inj.begin_epoch(0)
    np.testing.assert_array_equal(
        inj.poison_factors((0, 1, 2)), np.ones(3, np.float32)
    )
    inj.begin_epoch(1)
    f = inj.poison_factors((0, 1, 2))
    assert f[0] == 1.0 and f[2] == 1.0 and np.isnan(f[1])
    inj.begin_epoch(3)  # window [1, 3) closed again
    np.testing.assert_array_equal(
        inj.poison_factors((0, 1, 2)), np.ones(3, np.float32)
    )


# ---------------------------------------------------------------------------
# checksummed checkpoint generations + rollback
# ---------------------------------------------------------------------------


def _tree(fill: float):
    return {
        "w": np.full(8, fill, dtype=np.float32),
        "step": np.int64(int(fill)),
    }


def _tamper(path: str) -> None:
    """Modify a payload entry while keeping the stored digest — the
    minimal silent-corruption model the digest must catch."""
    data = dict(np.load(path, allow_pickle=False))
    key = next(k for k in data if not k.startswith("__"))
    arr = np.asarray(data[key])
    data[key] = arr + (1 if np.issubdtype(arr.dtype, np.integer) else 1.0)
    with open(path, "wb") as f:
        np.savez(f, **data)


def test_checkpoint_digest_and_generation_roundtrip(tmp_path):
    path = str(tmp_path / "job.ckpt.npz")
    ckpt.save(path, _tree(7.0), generation=5)
    assert ckpt.verify_checkpoint(path)
    assert ckpt.checkpoint_generation(path) == 5
    restored = ckpt.restore(path, _tree(0.0))
    np.testing.assert_array_equal(restored["w"], _tree(7.0)["w"])
    assert restored["step"] == 7


def test_checkpoint_tamper_detected_and_restore_refuses(tmp_path):
    path = str(tmp_path / "job.ckpt.npz")
    ckpt.save(path, _tree(7.0))
    _tamper(path)
    assert not ckpt.verify_checkpoint(path)
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.restore(path, _tree(0.0))


def test_injector_byte_flips_invalidate_checkpoint(tmp_path):
    path = str(tmp_path / "job.ckpt.npz")
    ckpt.save(path, _tree(3.0))
    inj = FaultInjector(
        FaultPlan(corruptions=(CheckpointCorruption(write_index=1, n_bytes=24),))
    )
    assert inj.corrupt_checkpoint(path) is True
    assert inj.corrupted_paths == [path]
    assert not ckpt.verify_checkpoint(path)
    # Only the scheduled write is corrupted; later writes pass through.
    ckpt.save(path, _tree(4.0))
    assert inj.corrupt_checkpoint(path) is False
    assert ckpt.verify_checkpoint(path)


def test_checkpoint_manager_generations_prune_and_rollback(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path), "job", keep=3)
    assert mgr.latest_generation == 0 and mgr.latest_path is None
    paths = [mgr.save(_tree(float(g))) for g in range(1, 5)]
    # Retention: only the newest `keep` generations survive on disk.
    assert [g for g, _ in mgr.generations()] == [2, 3, 4]
    assert not os.path.exists(paths[0])
    assert mgr.latest_generation == 4 and mgr.latest_path == paths[3]
    assert ckpt.checkpoint_generation(paths[3]) == 4

    # Corrupt the newest generation: restore rolls back to gen 3.
    _tamper(paths[3])
    tree, gen, path = mgr.restore(_tree(0.0))
    assert gen == 3 and path == paths[2]
    np.testing.assert_array_equal(tree["w"], _tree(3.0)["w"])
    assert mgr.rollbacks == 1
    assert mgr.corrupt_generations == [paths[3]]


def test_checkpoint_manager_all_generations_corrupt_raises(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path), "job", keep=2)
    for g in (1.0, 2.0):
        _tamper(mgr.save(_tree(g)))
    with pytest.raises(ckpt.CheckpointCorruptError):
        mgr.restore(_tree(0.0))
    assert mgr.rollbacks == 2  # walked past both before giving up


# ---------------------------------------------------------------------------
# numerical-health channel (detection -> quarantine state machine)
# ---------------------------------------------------------------------------


def _mon():
    return HealthMonitor(
        HealthConfig(backoff_initial=2, numeric_suspect_epochs=2)
    )


def test_numeric_streak_trips_quarantine_and_clean_epoch_resets():
    mon = _mon()
    mon.observe_numerics("j", 0, [0, 1], [1, 0])   # breach #1 on node 0
    assert mon.node(0).state == NodeState.HEALTHY
    assert [d["kind"] for d in mon.detections] == ["numeric"]
    mon.observe_numerics("j", 1, [0, 1], [0, 0])   # clean epoch: streak reset
    mon.observe_numerics("j", 2, [0, 1], [2, 0])   # breach #1 again
    assert mon.node(0).state == NodeState.HEALTHY
    mon.observe_numerics("j", 3, [0, 1], [1, 0])   # consecutive -> trip
    assert mon.node(0).state == NodeState.QUARANTINED
    assert mon.node(1).state == NodeState.HEALTHY
    kinds = [d["kind"] for d in mon.detections]
    assert kinds.count("numeric") == 2 and kinds.count("quarantine") == 1
    actions = mon.poll()
    assert [type(a).__name__ for a in actions] == ["QuarantineNode"]


def test_numeric_probation_retrip_doubles_backoff():
    mon = _mon()
    for e in (0, 1):
        mon.observe_numerics("j", e, [0], [1])
    h = mon.node(0)
    assert h.state == NodeState.QUARANTINED and h.backoff == 2
    mon.tick(h.release_epoch)
    assert h.state == NodeState.PROBATION
    # One anomalous epoch during probation re-quarantines immediately.
    mon.observe_numerics("j", h.release_epoch, [0], [1])
    assert h.state == NodeState.QUARANTINED and h.backoff == 4


def test_numeric_quarantined_nodes_are_not_re_observed():
    mon = _mon()
    for e in (0, 1):
        mon.observe_numerics("j", e, [0], [3])
    n_detections = len(mon.detections)
    mon.observe_numerics("j", 2, [0], [3])  # still quarantined: ignored
    assert len(mon.detections) == n_detections
    assert mon.node(0).backoff == 2


# ---------------------------------------------------------------------------
# satellite 3: quarantine liveness under random seeded fault plans
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(15))
def test_quarantine_state_machine_liveness_under_random_plans(seed):
    """Property: whatever anomaly schedule a seeded plan (plus random
    flapping) produces, the state machine never deadlocks — a QUARANTINED
    node always has a future release epoch, backoff never exceeds the
    32-epoch cap, and once the faults stop every node leaves quarantine
    within one backoff window."""
    n = 8
    plan = FaultPlan.chaos_real(n, seed=seed)
    rng = np.random.default_rng(seed + 1000)
    mon = _mon()
    horizon = 30
    for epoch in range(horizon):
        counts = []
        for nid in range(n):
            poisoned = any(
                p.node == nid and p.at_epoch <= epoch < p.at_epoch + p.duration
                for p in plan.poisons
            )
            flap = int(rng.random() < 0.25) * int(rng.integers(1, 4))
            counts.append((2 if poisoned else 0) + flap)
        mon.observe_numerics("job", epoch, list(range(n)), counts)
        mon.tick(epoch)
        mon.poll()
        for nid in range(n):
            h = mon.node(nid)
            assert h.backoff <= mon.config.backoff_max
            if h.state == NodeState.QUARANTINED:
                assert h.release_epoch is not None
                assert h.release_epoch > epoch  # re-admission always pending
    # Faults stop: every quarantine must drain within backoff_max epochs.
    for epoch in range(horizon, horizon + mon.config.backoff_max + 2):
        mon.observe_numerics("job", epoch, list(range(n)), [0] * n)
        mon.tick(epoch)
        mon.poll()
    for nid in range(n):
        assert mon.node(nid).state != NodeState.QUARANTINED


# ---------------------------------------------------------------------------
# deadline watchdog -> solver degradation chain
# ---------------------------------------------------------------------------


def test_watchdog_passthrough_without_deadlines():
    wd = Watchdog()
    assert wd.guard_solve(lambda: 42) == 42
    assert wd.guard_execute(lambda: "ok") == "ok"
    assert wd.counters() == {
        "solver_timeouts": 0,
        "execute_deadline_misses": 0,
        "stalled_seconds": 0.0,
    }


def test_watchdog_stall_trips_solve_deadline():
    wd = Watchdog(solve_deadline=0.002, stall_hook=lambda: 0.02)
    with pytest.raises(DeadlineExceeded) as err:
        wd.guard_solve(lambda: 1)
    assert err.value.kind == "optperf-solve"
    assert wd.solver_timeouts == 1
    assert wd.stalled_seconds == pytest.approx(0.02)


def test_watchdog_execute_deadline_is_soft():
    import time

    wd = Watchdog(execute_deadline=0.001)
    out = wd.guard_execute(lambda: (time.sleep(0.01), "kept")[1])
    assert out == "kept"  # results preserved, breach only counted
    assert wd.execute_deadline_misses == 1


def test_policy_absorbs_solver_timeout_via_degradation_chain():
    calls = {"n": 0}

    def stall_once():
        calls["n"] += 1
        return 0.02 if calls["n"] == 1 else 0.0

    wd = Watchdog(solve_deadline=0.002, stall_hook=stall_once)
    pol = CannikinPolicy(8, engine="batched", watchdog=wd)
    spec = random_jobs(1, 8, seed=0)[0]
    alloc = pol.add_job(spec)
    assert wd.solver_timeouts == 1
    assert pol.engine_degradations >= 1        # timeout walked the chain
    assert alloc.assignment[spec.name]         # job still placed
    assert pol.counters()["solver_timeouts"] == 1


def test_runtime_builds_watchdog_from_stall_plan():
    plan = FaultPlan(solver_stalls=(SolverStall(at_epoch=0, delay=0.05),))
    rt = ClusterRuntime(4, faults=plan)
    assert rt.watchdog is not None
    assert rt.watchdog.solve_deadline == pytest.approx(0.025)
    # Explicit opt-out wins over the plan.
    assert ClusterRuntime(4, faults=plan, watchdog=False).watchdog is None
    # No stalls scheduled -> no watchdog by default.
    assert ClusterRuntime(4, faults=FaultPlan()).watchdog is None


# ---------------------------------------------------------------------------
# runtime invariant checker
# ---------------------------------------------------------------------------


def _sim_runtime():
    rt = ClusterRuntime(8, policy="cannikin", seed=0, health=True, invariants=True)
    for spec in random_jobs(2, 8, seed=0):
        rt.submit(spec, at=0.0)
    rt.run()
    rt.advance(epochs=1, steps=2)
    return rt


def test_invariant_checker_clean_on_healthy_runtime():
    rt = _sim_runtime()
    assert rt.invariant_checker is not None
    assert rt.invariant_checker.checks_run > 0
    rt.invariant_checker.assert_clean()
    assert rt.invariant_violations == []


def test_invariant_checker_flags_hand_broken_state():
    rt = _sim_runtime()
    checker = rt.invariant_checker
    names = list(rt.allocation.assignment)
    a, b = names[0], names[1]

    # Assign one of b's nodes to a as well: disjointness broken.
    stolen = rt.allocation.assignment[b][0]
    rt.allocation.assignment[a] = tuple(rt.allocation.assignment[a]) + (stolen,)
    found = checker.check("tamper:double-assign")
    assert any(v.invariant == "disjoint-assignment" for v in found)
    rt.allocation.assignment[a] = tuple(
        n for n in rt.allocation.assignment[a] if n != stolen
    )

    # Quarantine a node, then erase its re-admission: liveness broken.
    h = rt.health.node(0)
    h.transition(0, NodeState.QUARANTINED)
    h.release_epoch = None
    h.backoff = 2 * rt.health.config.backoff_max  # and blow the cap
    found = checker.check("tamper:quarantine")
    kinds = {v.invariant for v in found}
    assert "quarantine-liveness" in kinds and "backoff-cap" in kinds
    assert len(rt.invariant_violations) >= 3
    with pytest.raises(AssertionError):
        checker.assert_clean()


# ---------------------------------------------------------------------------
# NaN-safe goodput retention (satellite 2)
# ---------------------------------------------------------------------------


class _StubRuntime:
    def __init__(self, *sim_times):
        class _H:
            def __init__(self, t):
                self.sim_time = t

        self.handles = {f"j{i}": _H(t) for i, t in enumerate(sim_times)}


def _report(faulted_times, baseline_times):
    base = TraceReport(policy="p", records=[], runtime=_StubRuntime(*baseline_times))
    return TraceReport(
        policy="p", records=[], runtime=_StubRuntime(*faulted_times), baseline=base
    )


def test_goodput_retention_degenerate_traces_warn_not_nan():
    with pytest.warns(RuntimeWarning, match="0/0"):
        assert _report((), ()).goodput_retention == 1.0
    with pytest.warns(RuntimeWarning, match="faulted replay"):
        assert _report((0.0,), (5.0,)).goodput_retention == 0.0
    with pytest.warns(RuntimeWarning, match="fault-free twin"):
        assert _report((5.0,), (0.0,)).goodput_retention == 0.0
    # Healthy case: no warning, plain ratio.
    assert _report((10.0,), (8.0,)).goodput_retention == pytest.approx(0.8)
    # No baseline: undefined, not fabricated.
    assert TraceReport("p", [], _StubRuntime(1.0)).goodput_retention is None


# ---------------------------------------------------------------------------
# RealBackend integration (slow lane: compiles JAX steps)
# ---------------------------------------------------------------------------


def _tiny_real_spec():
    models = tuple(
        GPU_CATALOG[name].model() for name in ("a100", "v100", "rtx6000")
    )
    return JobSpec(
        name="rj",
        node_models=models,
        comm=CommModel(t_o=0.04, t_u=0.008, gamma=0.15),
        total_batch=12,
        b_noise=500.0,
        ref_batch=12,
        backend="real",
    )


def _real_config():
    return RealBackendConfig(arch="olmo-1b", seq_len=16, lr=0.3)


def _leaves(tree):
    import jax

    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


@pytest.mark.slow
def test_real_backend_poison_excluded_counted_and_contained():
    """A NaN-poisoned node's gradients never reach the Eq. (9) aggregate:
    losses and params stay finite, the anomaly is counted per node, and
    GradObservation.valid marks the exclusion."""
    pytest.importorskip("jax")
    plan = FaultPlan(
        poisons=(GradientPoison(node=1, at_epoch=0, duration=1, mode="nan"),)
    )
    inj = FaultInjector(plan)
    backend = _real_config().build(noise=0.0, seed=1, injector=inj)
    backend.configure(_tiny_real_spec(), (0, 1, 2), seed=1)
    inj.begin_epoch(0)
    res = backend.execute([4, 4, 4], steps=2)
    assert all(math.isfinite(x) for x in res.losses)
    assert all(np.isfinite(leaf).all() for leaf in _leaves(backend.params))
    assert res.grad_anomalies == (0, 2, 0)  # both steps excluded node 1
    for obs in res.grad_observations:
        assert obs.valid == (True, False, True)
        assert not obs.all_valid
    # Poison window closed: the guard re-admits the node.
    inj.begin_epoch(1)
    res2 = backend.execute([4, 4, 4], steps=2)
    assert res2.grad_anomalies == (0, 0, 0)
    assert all(o.all_valid for o in res2.grad_observations)


@pytest.mark.slow
def test_real_backend_idle_injector_is_bit_identical():
    """The guard + injector seam are always compiled in; with an empty
    plan the produced params are bitwise identical to a no-injector run."""
    pytest.importorskip("jax")
    spec = _tiny_real_spec()
    plain = _real_config().build(noise=0.0, seed=3)
    seamed = _real_config().build(
        noise=0.0, seed=3, injector=FaultInjector(FaultPlan())
    )
    plain.configure(spec, (0, 1, 2), seed=3)
    seamed.configure(spec, (0, 1, 2), seed=3)
    ra = plain.execute([4, 4, 4], steps=2)
    rb = seamed.execute([4, 4, 4], steps=2)
    assert ra.epoch_seconds == rb.epoch_seconds
    assert ra.measurements == rb.measurements
    assert ra.losses == rb.losses
    for a, b in zip(_leaves(plain.params), _leaves(seamed.params)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_real_backend_routes_timing_faults_through_injector():
    """Satellite 1 regression: timing faults (here a straggler) perturb the
    REAL backend's measurement stream through the same injector seam the
    simulator uses — before this PR they were silently ignored."""
    pytest.importorskip("jax")
    spec = _tiny_real_spec()
    # 30x: slow enough that the synchronous step actually waits on the
    # straggler (a mild slowdown hides inside the comm-bound batch time).
    inj = FaultInjector(
        FaultPlan(
            stragglers=(Straggler(node=0, at_epoch=0, duration=1, slowdown=30.0),)
        )
    )
    clean = _real_config().build(noise=0.0, seed=1)
    faulted = _real_config().build(noise=0.0, seed=1, injector=inj)
    clean.configure(spec, (0, 1, 2), seed=1)
    faulted.configure(spec, (0, 1, 2), seed=1)
    inj.begin_epoch(0)
    rc = clean.execute([4, 4, 4], steps=2)
    rf = faulted.execute([4, 4, 4], steps=2)
    assert rf.epoch_seconds > rc.epoch_seconds
    c0 = rc.measurements[0].observations[0]
    f0 = rf.measurements[0].observations[0]
    assert f0.a_time == pytest.approx(30.0 * c0.a_time)
    assert {f["kind"] for f in inj.injected} == {"straggler"}
    # Timing-only faults leave the gradients untouched.
    assert rf.losses == rc.losses
    assert rf.grad_anomalies == (0, 0, 0)

"""Model zoo: six architecture families, pure JAX."""
from repro.models.registry import ModelApi, build_api

__all__ = ["ModelApi", "build_api"]

"""Table 5 reproduction + the fused device hot-path lane.

``run()`` is the Table 5 lane (controller overhead per epoch relative to the
simulated epoch training time, per workload scale), unchanged.

``run_fused()`` (CLI: ``--fused``) benches the PR's fused on-device epoch
against the pre-fusion baseline at n=8 nodes: the two-program path (single
device vmap backward + host OptPerf sweep between epochs) vs the fused path
(shard_map multi-device backward + the goodput sweep inside the train jit).
It counts host<->device transfers per adaptive epoch at the backend seams
(see repro/runtime/transfers.py for the methodology) and wall-clock per
epoch, gating on

* >= 2x fewer transfers per adaptive epoch (holds at ~13x: the two-program
  path pays 8 per step + 2 per epoch, the fused path a flat ~25/epoch), and
* >= 1.5x epoch wall-clock speedup — asserted on the CI 8-virtual-device
  CPU mesh (``XLA_FLAGS=--xla_force_host_platform_device_count=8``); on
  smaller device counts the speedup is recorded but not gated, and
* fused-vs-host certification max relative error <= 1e-5 with zero
  certification failures.

Results merge into ``artifacts/bench/sweep.json`` under the ``"fused"`` key.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List

import numpy as np

from benchmarks.common import ARTIFACTS, Row, save_json
from repro.core.controller import CannikinController
from repro.core.simulator import SimulatedCluster, cluster_B
from benchmarks.bench_batchtime import WORKLOADS


def run() -> List[Row]:
    rows: List[Row] = []
    payload = {}
    for wl, (cscale, mscale) in WORKLOADS.items():
        profiles, comm = cluster_B(
            workload_scale=cscale, t_o=0.045 * mscale, t_u=0.009 * mscale
        )
        sim = SimulatedCluster(profiles, comm, noise=0.01, seed=0)
        ctrl = CannikinController(
            sim.n,
            batch_candidates=[128, 256, 512, 1024, 2048, 4096],
            ref_batch=128,
        )
        steps_per_epoch = 40
        sim_total = 0.0
        for _ in range(8):
            plan = ctrl.plan_epoch()
            t, ms = sim.run_epoch(list(plan.batches), steps_per_epoch)
            sim_total += t
            ctrl.observe_epoch(ms)
            ctrl.observe_gradients([4.0] * sim.n, 3.0, list(plan.batches))
        frac = ctrl.stats.overhead_fraction(sim_total)
        payload[wl] = {
            "controller_seconds": ctrl.stats.overhead_seconds,
            "sim_train_seconds": sim_total,
            "overhead_fraction": frac,
            "full_sweeps": ctrl.stats.full_sweeps,
            "incremental_updates": ctrl.stats.incremental_updates,
        }
        rows.append(
            Row(
                f"table5/{wl}",
                ctrl.stats.overhead_seconds / 8 * 1e6,
                f"overhead={frac:.2%}",
            )
        )
    save_json("overhead_table5", payload)
    return rows


# ---------------------------------------------------------------------------
# Fused device hot-path lane
# ---------------------------------------------------------------------------

FUSED_N_NODES = 8
TRANSFER_RATIO_GATE = 2.0
SPEEDUP_GATE = 1.5
CERT_TOL_GATE = 1e-5


def _fused_loop(*, fused: bool, sharded: bool, steps: int, seed: int = 0):
    from repro.core.perf_model import CommModel
    from repro.core.scheduler import JobSpec
    from repro.core.simulator import GPU_CATALOG
    from repro.runtime import EpochLoop, RealBackendConfig

    names = ("a100", "v100", "rtx6000", "a5000", "a4000", "p4000", "a100",
             "v100")
    spec = JobSpec(
        name="fused-bench",
        node_models=tuple(GPU_CATALOG[k].model() for k in names[:FUSED_N_NODES]),
        comm=CommModel(t_o=0.04, t_u=0.008, gamma=0.15),
        total_batch=32,
        b_noise=500.0,
        ref_batch=32,
        backend="real",
    )
    backend = RealBackendConfig(
        arch="olmo-1b", seq_len=16, lr=0.3, sharded=sharded
    ).build(noise=0.0, seed=seed)
    backend.configure(spec, tuple(range(FUSED_N_NODES)), seed=seed + 1)
    ctrl = CannikinController(
        FUSED_N_NODES, batch_candidates=[32, 64], ref_batch=32, adaptive=True
    )
    loop = EpochLoop(ctrl, backend, steps_per_epoch=steps, fused=fused)
    return ctrl, backend, loop


def run_fused(smoke: bool = False) -> List[Row]:
    """Fused-vs-two-program epoch bench at n=8 nodes (gated)."""
    import jax

    rows: List[Row] = []
    steps = 40
    warm_epochs, measured = (4, 2) if smoke else (4, 4)
    devices = jax.local_device_count()

    record = {
        "n_nodes": FUSED_N_NODES,
        "steps_per_epoch": steps,
        "devices": devices,
        "gates": {
            "transfer_ratio": TRANSFER_RATIO_GATE,
            "speedup": SPEEDUP_GATE,
            "cert_tol": CERT_TOL_GATE,
        },
    }
    lanes = {}
    for label, fused, sharded in (
        ("two_program", False, False),  # pre-fusion baseline: vmap + host sweep
        ("fused", True, True),          # shard_map mesh + sweep-in-jit
    ):
        ctrl, backend, loop = _fused_loop(fused=fused, sharded=sharded,
                                          steps=steps)
        loop.run(warm_epochs)  # bootstrap, model fit, compile
        backend.transfers.reset()
        t0 = time.perf_counter()
        for _ in range(measured):
            loop.run_epoch()
        dt = (time.perf_counter() - t0) / measured
        lanes[label] = {
            "epoch_seconds": dt,
            "transfers_per_epoch": backend.transfers.total / measured,
            "h2d_per_epoch": backend.transfers.h2d / measured,
            "d2h_per_epoch": backend.transfers.d2h / measured,
            "fused_plans": ctrl.stats.fused_plans,
            "fused_certifications": ctrl.stats.fused_certifications,
            "fused_cert_failures": ctrl.stats.fused_cert_failures,
            "fused_max_rel_err": ctrl.stats.fused_max_rel_err,
        }
        if fused:
            assert ctrl.stats.fused_plans >= 1, "fused mode never engaged"

    two, fus = lanes["two_program"], lanes["fused"]
    transfer_ratio = two["transfers_per_epoch"] / max(
        fus["transfers_per_epoch"], 1.0
    )
    speedup = two["epoch_seconds"] / fus["epoch_seconds"]
    record.update(lanes=lanes, transfer_ratio=transfer_ratio, speedup=speedup)

    # Gates ---------------------------------------------------------------
    assert transfer_ratio >= TRANSFER_RATIO_GATE, (
        f"transfer ratio {transfer_ratio:.2f} below gate {TRANSFER_RATIO_GATE}"
    )
    assert fus["fused_cert_failures"] == 0, "fused certification failed"
    assert fus["fused_max_rel_err"] <= CERT_TOL_GATE, (
        f"certification rel err {fus['fused_max_rel_err']:.2e} above "
        f"{CERT_TOL_GATE}"
    )
    # The wall-clock gate is stated for the CI 8-virtual-device CPU mesh;
    # smaller device counts record the measurement without gating it.
    record["speedup_gated"] = devices >= 8
    if devices >= 8:
        assert speedup >= SPEEDUP_GATE, (
            f"epoch speedup {speedup:.2f}x below gate {SPEEDUP_GATE}x"
        )

    rows.append(Row(
        "fused/two_program",
        two["epoch_seconds"] * 1e6,
        f"transfers={two['transfers_per_epoch']:.0f}/epoch",
    ))
    rows.append(Row(
        "fused/fused",
        fus["epoch_seconds"] * 1e6,
        f"transfers={fus['transfers_per_epoch']:.0f}/epoch "
        f"speedup={speedup:.2f}x ratio={transfer_ratio:.1f}x "
        f"cert_rel={fus['fused_max_rel_err']:.1e}",
    ))

    # Merge into the sweep artifact (keep every other lane's record).
    sweep_path = os.path.join(ARTIFACTS, "bench", "sweep.json")
    payload = {}
    if os.path.exists(sweep_path):
        try:
            with open(sweep_path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    payload["fused"] = record
    save_json("sweep", payload)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fused", action="store_true",
                    help="run the fused device hot-path lane instead of Table 5")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized fused lane (fewer measured epochs)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in (run_fused(smoke=args.smoke) if args.fused else run()):
        print(row.csv(), flush=True)


if __name__ == "__main__":
    main()

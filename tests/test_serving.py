"""Serving subsystem tests: load generator, scheduler lifecycle (including
randomized-interleaving property tests), allocator water-fill, metrics,
pipeline streaming, and the sim-engine acceptance gates (optperf >= 1.15x
uniform at equal-or-better p99; zero drops under churn; same-seed
bit-identity).  JAX-compiling prefill/real-engine tests are `slow`-marked.
"""
import hashlib

import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.data.pipeline import BoundedStream, SyntheticLM
from repro.runtime.events import NodeJoin, NodeLeave
from repro.serving import (
    BatchScheduler,
    NodeTickFitter,
    SchedulingError,
    ServingAllocator,
    ServingConfig,
    ServingMetrics,
    ServingRuntime,
    SimServingEngine,
    generate_requests,
    percentiles,
    prompts_from_stream,
    serving_node_model,
    uniform_split,
)
from repro.serving.request import Request

# ---------------------------------------------------------------------------
# request / load generator
# ---------------------------------------------------------------------------


def test_workload_same_seed_identical():
    a = generate_requests(50, seed=9, arrival="poisson")
    b = generate_requests(50, seed=9, arrival="poisson")
    assert tuple(a) == tuple(b)
    c = generate_requests(50, seed=10, arrival="poisson")
    assert tuple(a) != tuple(c)


def test_workload_laws_and_bounds():
    wl = generate_requests(
        200, seed=1, arrival="bursty", prompt_min=4, prompt_max=32,
        gen_min=2, gen_max=16, ttft_slack=1.0, token_budget=0.25,
    )
    arr = [r.arrival for r in wl]
    assert arr == sorted(arr) and arr[0] > 0
    for r in wl:
        assert 4 <= r.prompt_len <= 32
        assert 2 <= r.gen_len <= 16
        assert r.deadline == pytest.approx(r.arrival + 1.0 + r.gen_len * 0.25)
    assert wl.offered_load > 0


def test_workload_rejects_unknown_law():
    with pytest.raises(ValueError):
        generate_requests(1, arrival="adversarial")


def test_prompt_tokens_deterministic_and_bounded():
    r = Request(rid=3, arrival=0.0, prompt_len=16, gen_len=2, deadline=1.0, seed=5)
    t1, t2 = r.prompt_tokens(512), r.prompt_tokens(512)
    assert np.array_equal(t1, t2)
    assert t1.shape == (16,) and t1.dtype == np.int32
    assert t1.min() >= 0 and t1.max() < 512


# ---------------------------------------------------------------------------
# scheduler lifecycle
# ---------------------------------------------------------------------------


def _req(rid, gen_len=4):
    return Request(rid=rid, arrival=float(rid), prompt_len=4,
                   gen_len=gen_len, deadline=1e9)


def test_scheduler_admit_complete_cycle():
    s = BatchScheduler({0: 2, 1: 1})
    for rid in range(4):
        s.enqueue(_req(rid))
    a0 = s.admit(0, now=0.0)
    assert [ar.rid for ar in a0] == [0, 1]
    a1 = s.admit(1, now=0.0)
    assert [ar.rid for ar in a1] == [2]
    assert s.queue_depth() == 1 and s.in_flight() == 3
    s.complete(a0[0])
    assert s.free_slots(0) == 1
    assert [ar.rid for ar in s.admit(0, now=1.0)] == [3]
    s.check_invariants()


def test_scheduler_rejects_double_enqueue_and_unknown_node():
    s = BatchScheduler({0: 1})
    s.enqueue(_req(0))
    with pytest.raises(SchedulingError):
        s.enqueue(_req(0))
    with pytest.raises(SchedulingError):
        s.admit(7, now=0.0)
    with pytest.raises(SchedulingError):
        s.drain_node(7)


def test_scheduler_drain_requeues_in_arrival_order():
    s = BatchScheduler({0: 3, 1: 3})
    for rid in range(5):
        s.enqueue(_req(rid))
    s.admit(0, now=0.0)  # rids 0,1,2
    victims = s.drain_node(0)
    assert [ar.rid for ar in victims] == [0, 1, 2]
    assert 0 not in s.nodes()
    # Requeues go to the queue FRONT, oldest first.
    admitted = s.admit(1, now=1.0)
    assert [ar.rid for ar in admitted] == [0, 1, 2]
    assert all(ar.requeues == 1 for ar in admitted)
    s.check_invariants()


def test_scheduler_shrink_evicts_newest_keeps_tokens():
    s = BatchScheduler({0: 3})
    for rid in range(3):
        s.enqueue(_req(rid))
    actives = s.admit(0, now=0.0)
    actives[2].tokens.extend([7, 8])  # progress that must survive eviction
    evicted = s.set_allocations({0: 1})
    assert [ar.rid for ar in evicted] == [2, 1]
    assert s.active_count(0) == 1 and s.queue_depth() == 2
    assert evicted[0].tokens == [7, 8]
    s.check_invariants()
    with pytest.raises(SchedulingError):
        s.set_allocations({5: 1})


def _drive_random_interleaving(seed: int) -> None:
    """Random legal op sequences never drop, double-schedule, or overfill —
    `check_invariants` sweeps the full state map after every transition, and
    every request completes once the cluster quiesces."""
    rng = np.random.default_rng(seed)
    sched = BatchScheduler({0: 3, 1: 2, 2: 4})
    parked = []  # nodes currently out of the cluster
    next_rid = 0
    for _ in range(250):
        nodes = sched.nodes()
        busy = [n for n in nodes if sched.active_count(n)]
        ops = ["arrive"]
        if nodes:
            ops += ["admit", "shrink"]
        if busy:
            ops.append("complete")
        if len(nodes) > 1:
            ops.append("drain")
        if parked:
            ops.append("join")
        op = ops[rng.integers(len(ops))]
        if op == "arrive":
            sched.enqueue(_req(next_rid))
            next_rid += 1
        elif op == "admit":
            sched.admit(int(rng.choice(nodes)), now=0.0)
        elif op == "complete":
            node = int(rng.choice(busy))
            active = sched.active(node)
            sched.complete(active[rng.integers(len(active))])
        elif op == "drain":
            node = int(rng.choice(nodes))
            parked.append(node)
            sched.drain_node(node)
        elif op == "join":
            sched.join_node(parked.pop(), cap=int(rng.integers(0, 5)))
        elif op == "shrink":
            sched.set_allocations(
                {n: int(rng.integers(0, 5)) for n in nodes}
            )
        sched.check_invariants()
    # Quiesce: restore capacity and run every remaining request down.
    for node in parked:
        sched.join_node(node, cap=0)
    sched.set_allocations({n: 4 for n in sched.nodes()})
    while sched.pending():
        for node in sched.nodes():
            sched.admit(node, now=0.0)
            for ar in sched.active(node):
                sched.complete(ar)
        sched.check_invariants()
    assert sched.counters["completed"] == sched.counters["enqueued"] == next_rid


def test_scheduler_random_interleavings_deterministic_sweep():
    for seed in range(8):
        _drive_random_interleaving(seed)


@hypothesis.given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@hypothesis.settings(max_examples=30, deadline=None)
def test_scheduler_random_interleavings_property(seed):
    _drive_random_interleaving(seed)


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------


def test_serving_node_model_validates():
    m = serving_node_model(0.01, 0.05)
    assert m.q == m.k == pytest.approx(0.005)
    assert m.s == m.m == pytest.approx(0.025)
    with pytest.raises(ValueError):
        serving_node_model(0.0, 0.05)


def test_uniform_split_deterministic_remainder():
    assert uniform_split(10, [2, 0, 1]) == {0: 4, 1: 3, 2: 3}
    with pytest.raises(ValueError):
        uniform_split(4, [])


def test_waterfill_favors_fast_nodes_and_conserves_total():
    coeffs = {0: (0.004, 0.03), 1: (0.032, 0.03)}
    alloc = ServingAllocator(coeffs, total_slots=10).solve([0, 1])
    assert sum(alloc.values()) == 10
    assert alloc[0] > alloc[1]
    uni = ServingAllocator(coeffs, total_slots=10, mode="uniform").solve([0, 1])
    assert uni == {0: 5, 1: 5}


def test_min_slots_floor_taken_from_largest():
    coeffs = {0: (0.001, 0.05), 1: (0.05, 0.05), 2: (0.05, 0.05)}
    alloc = ServingAllocator(coeffs, total_slots=12, min_slots=1).solve([0, 1, 2])
    assert sum(alloc.values()) == 12
    assert min(alloc.values()) >= 1


def test_tick_fitter_recovers_linear_law():
    f = NodeTickFitter()
    for b in (1, 2, 4, 8):
        f.observe(b, 0.01 * b + 0.2)
    alpha, c = f.fit()
    assert alpha == pytest.approx(0.01, rel=1e-6)
    assert c == pytest.approx(0.2, rel=1e-6)
    # Non-physical fits (negative slope) are rejected.
    g = NodeTickFitter()
    g.observe(1, 1.0)
    g.observe(2, 0.5)
    assert g.fit() is None
    # One distinct batch size carries no slope information.
    h = NodeTickFitter()
    h.observe(4, 0.1)
    h.observe(4, 0.1)
    assert not h.can_fit()


def test_allocator_refit_updates_coefficients():
    alloc = ServingAllocator({0: (0.001, 0.0), 1: (0.001, 0.0)}, total_slots=8)
    for b in (1, 2, 4):
        alloc.observe(0, b, 0.05 * b + 0.1)
    assert alloc.refit() == 1
    a, c = alloc.coeffs(0)
    assert a == pytest.approx(0.05, rel=1e-6)
    assert alloc.predicted_tick(1, 4) == pytest.approx(0.004)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_percentiles_and_empty():
    p = percentiles([1.0, 2.0, 3.0, 4.0])
    assert p["p50"] == pytest.approx(2.5)
    assert np.isnan(percentiles([])["p99"])


def test_metrics_lifecycle_and_fingerprint():
    m = ServingMetrics()
    m.on_arrival(0, 0.0, 10.0, 4, 2)
    m.on_admit(0, 0.5)
    m.on_token(0, 1.0)
    m.on_token(0, 1.5)
    m.on_complete(0, 1.5, node=2, requeues=0)
    with pytest.raises(ValueError):
        m.on_complete(0, 2.0, node=2, requeues=0)
    with pytest.raises(ValueError):
        m.on_arrival(0, 0.0, 1.0, 1, 1)
    s = m.summary()
    assert s["completed"] == 1 and s["deadline_misses"] == 0
    assert s["token_latency"]["p50"] == pytest.approx(0.5)
    m2 = ServingMetrics()
    m2.on_arrival(0, 0.0, 10.0, 4, 2)
    assert m.fingerprint() != m2.fingerprint()


# ---------------------------------------------------------------------------
# sim runtime: the acceptance gates
# ---------------------------------------------------------------------------

# 2-speed-class cluster: 3 fast nodes, 5 nodes 8x slower, shared intercept
# (per-tick dispatch overhead is host-side and speed-independent).
TWO_CLASS = {i: (0.004, 0.03) for i in range(3)}
TWO_CLASS.update({i: (0.032, 0.03) for i in range(3, 8)})
GATE_WORKLOAD = dict(seed=7, rate=56.0, gen_mean=8, gen_max=64,
                     token_budget=0.12, ttft_slack=1.0)


def _run_two_class(mode, n=400, post=(), **cfg_kw):
    wl = generate_requests(n, **GATE_WORKLOAD)
    engine = SimServingEngine(dict(TWO_CLASS))
    alloc = ServingAllocator(dict(TWO_CLASS), total_slots=32, mode=mode)
    cfg = ServingConfig(total_slots=32, resolve_every=1.0, **cfg_kw)
    rt = ServingRuntime(engine, alloc, wl, nodes=list(range(8)), config=cfg)
    for ev in post:
        rt.post(ev)
    return rt.run()


def test_optperf_beats_uniform_by_15_percent_at_better_p99():
    opt = _run_two_class("optperf")
    uni = _run_two_class("uniform")
    assert opt.summary["dropped"] == 0 and uni.summary["dropped"] == 0
    assert opt.sustained_req_s >= 1.15 * uni.sustained_req_s
    assert opt.goodput_req_s >= 1.15 * uni.goodput_req_s
    assert (
        opt.summary["token_latency"]["p99"]
        <= uni.summary["token_latency"]["p99"]
    )


def test_same_seed_serving_runs_bit_identical():
    a = _run_two_class("optperf")
    b = _run_two_class("optperf")
    assert a.fingerprint == b.fingerprint
    assert a.summary == b.summary
    c = _run_two_class("uniform")
    assert a.fingerprint != c.fingerprint


def test_node_leave_mid_stream_zero_drops():
    rep = _run_two_class(
        "optperf",
        post=[NodeLeave(time=2.0, nodes=(0, 4)), NodeJoin(time=5.0, nodes=(0,))],
    )
    assert rep.summary["dropped"] == 0
    assert rep.summary["completed"] == rep.summary["requests"]
    assert rep.counters["leaves"] == 2 and rep.counters["joins"] == 1
    assert rep.counters["requeued"] > 0
    assert 4 not in rep.allocations  # never came back


def test_runtime_rejects_foreign_events():
    wl = generate_requests(2, seed=0)
    rt = ServingRuntime(
        SimServingEngine({0: (0.01, 0.01)}),
        ServingAllocator({0: (0.01, 0.01)}, total_slots=2),
        wl, nodes=[0],
    )
    with pytest.raises(TypeError):
        rt.post(object())


def test_batch_never_exceeds_allocation_during_run():
    class AssertingEngine(SimServingEngine):
        scheduler = None

        def decode(self, node, actives):
            cap = self.scheduler.allocation(node)
            assert len(actives) <= cap, (node, len(actives), cap)
            return super().decode(node, actives)

    wl = generate_requests(150, **GATE_WORKLOAD)
    engine = AssertingEngine(dict(TWO_CLASS))
    alloc = ServingAllocator(dict(TWO_CLASS), total_slots=32, mode="optperf")
    rt = ServingRuntime(
        engine, alloc, wl, nodes=list(range(8)),
        config=ServingConfig(total_slots=32, resolve_every=0.5),
    )
    engine.scheduler = rt.scheduler
    rt.post(NodeLeave(time=1.0, nodes=(2,)))
    rt.post(NodeJoin(time=2.5, nodes=(2,)))
    rep = rt.run()
    assert rep.summary["dropped"] == 0


def test_refit_tracks_capacity_drift():
    """Bootstrap lies (node 0 listed fast, actually 8x slower): telemetry
    refits recover the true law and the re-solve strips its slots."""
    boot = {i: (0.004, 0.03) for i in range(4)}
    truth = dict(boot)
    truth[0] = (0.032, 0.03)
    wl = generate_requests(200, seed=11, rate=30.0, gen_mean=8, gen_max=64)
    alloc = ServingAllocator(dict(boot), total_slots=16, mode="optperf")
    before = alloc.solve([0, 1, 2, 3])
    rt = ServingRuntime(
        SimServingEngine(truth), alloc, wl, nodes=[0, 1, 2, 3],
        config=ServingConfig(total_slots=16, resolve_every=0.5),
    )
    rep = rt.run()
    assert rep.summary["dropped"] == 0
    fitted_alpha, _ = alloc.coeffs(0)
    assert fitted_alpha == pytest.approx(0.032, rel=1e-3)
    assert rep.allocations[0] < before[0]


def test_quarantine_requeues_and_recovers():
    """A node whose ticks blow past factor*predicted gets quarantined
    (in-flight requeued) and rejoins later; nothing is dropped."""
    coeffs = {0: (0.01, 0.01), 1: (0.01, 0.01)}
    truth = dict(coeffs)
    truth[1] = (0.2, 0.2)  # 20x slower than the model claims
    wl = generate_requests(60, seed=3, rate=20.0, gen_mean=6, gen_max=32)
    rt = ServingRuntime(
        SimServingEngine(truth),
        ServingAllocator(dict(coeffs), total_slots=8, mode="uniform"),
        wl, nodes=[0, 1],
        config=ServingConfig(
            total_slots=8, quarantine_factor=3.0,
            quarantine_patience=2, rejoin_after=2.0,
        ),
    )
    rep = rt.run()
    assert rep.counters["quarantines"] >= 1
    assert rep.summary["dropped"] == 0
    assert rep.summary["completed"] == rep.summary["requests"]


def test_all_nodes_lost_strands_remainder_as_dropped():
    wl = generate_requests(40, seed=2, rate=50.0, gen_mean=8)
    rt = ServingRuntime(
        SimServingEngine({0: (0.01, 0.01)}),
        ServingAllocator({0: (0.01, 0.01)}, total_slots=4),
        wl, nodes=[0],
    )
    rt.post(NodeLeave(time=0.3, nodes=(0,)))
    rep = rt.run()
    assert rep.summary["dropped"] > 0
    assert rep.summary["completed"] + rep.summary["dropped"] == 40


# ---------------------------------------------------------------------------
# pipeline streaming (satellite: training path must be byte-identical)
# ---------------------------------------------------------------------------

# sha256 of SyntheticLM(vocab=512, seq_len=32, seed=3).batch(0, 8) — pins the
# training-path bytes the streaming refactor must not disturb.
_GOLDEN_BATCH0 = "af916a40aec843ca49b65724eaf41e4677626d127c32aac62a1f7442d931ba57"


def test_training_batch_bytes_unchanged():
    b = SyntheticLM(vocab=512, seq_len=32, seed=3).batch(0, 8)
    digest = hashlib.sha256(b["tokens"].tobytes() + b["labels"].tobytes())
    assert digest.hexdigest() == _GOLDEN_BATCH0


@pytest.mark.parametrize("threaded", [False, True])
def test_stream_matches_stepwise_batches(threaded):
    src = SyntheticLM(vocab=512, seq_len=32, seed=3)
    got = list(src.stream(8, steps=5, threaded=threaded, depth=2))
    assert len(got) == 5
    for step, b in enumerate(got):
        ref = src.batch(step, 8)
        assert np.array_equal(b["tokens"], ref["tokens"])
        assert np.array_equal(b["labels"], ref["labels"])


def test_stream_start_offset_and_close():
    src = SyntheticLM(vocab=64, seq_len=8, seed=1)
    with src.stream(4, start=10, steps=3, threaded=True, depth=1) as s:
        first = next(s)
        assert np.array_equal(first["tokens"], src.batch(10, 4)["tokens"])
    # closed: iteration ends
    assert list(s) == []


def test_stream_propagates_source_errors():
    def boom(step):
        raise RuntimeError("bad shard")

    s = BoundedStream(boom, steps=2, threaded=True)
    with pytest.raises(RuntimeError, match="bad shard"):
        next(s)


def test_prompts_from_stream_covers_all_requests():
    src = SyntheticLM(vocab=512, seq_len=16, seed=3)
    wl = generate_requests(25, seed=4, prompt_min=4, prompt_max=48)
    prompts = prompts_from_stream(src.stream(8, steps=100), wl.requests)
    assert set(prompts) == {r.rid for r in wl.requests}
    for r in wl.requests:
        assert prompts[r.rid].shape == (r.prompt_len,)
        assert prompts[r.rid].dtype == np.int32


# ---------------------------------------------------------------------------
# fused prefill + real engine (JAX-compiling: slow lane)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def olmo_api():
    jax = pytest.importorskip("jax")
    from repro.configs import get_api

    api = get_api("olmo-1b", reduced=True)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


@pytest.mark.slow
def test_fused_prefill_matches_stepped_loop(olmo_api):
    import jax
    import jax.numpy as jnp

    api, params = olmo_api
    B, S, T = 2, 12, 32
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (B, S), 0, api.cfg.vocab, jnp.int32
    )
    assert api.supports_prefill()
    fused_logits, fused = api.prefill(params, api.init_cache(B, T), toks)
    stepped = api.init_cache(B, T)
    rows = []
    for p in range(S):
        lg, stepped = api.decode_step(
            params, stepped, toks[:, p : p + 1], jnp.int32(p)
        )
        rows.append(lg)
    stepped_logits = jnp.concatenate(rows, axis=1)
    assert int(fused["pos"]) == int(stepped["pos"]) == S
    np.testing.assert_allclose(
        np.asarray(fused_logits), np.asarray(stepped_logits), atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(fused["k"][:, :, :S]), np.asarray(stepped["k"][:, :, :S]),
        atol=2e-5,
    )
    # Continuation from either cache produces matching next-token logits.
    n1, _ = api.decode_step(params, fused, toks[:, :1], jnp.int32(S))
    n2, _ = api.decode_step(params, stepped, toks[:, :1], jnp.int32(S))
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), atol=2e-4)


@pytest.mark.slow
def test_prefill_unsupported_family_raises():
    pytest.importorskip("jax")
    from repro.configs import get_api

    api = get_api("rwkv6-7b", reduced=True)
    assert not api.supports_prefill()
    with pytest.raises(NotImplementedError):
        api.prefill(None, None, None)


@pytest.mark.slow
def test_prefill_rejects_undersized_cache(olmo_api):
    import jax
    import jax.numpy as jnp

    api, params = olmo_api
    toks = jnp.zeros((1, 16), jnp.int32)
    with pytest.raises(ValueError):
        api.prefill(params, api.init_cache(1, 8), toks)
    del jax


@pytest.mark.slow
def test_real_engine_serves_with_churn_zero_drops(olmo_api):
    from repro.serving import RealServingEngine

    api, params = olmo_api
    wl = generate_requests(
        8, seed=5, rate=50.0, prompt_min=8, prompt_max=8,
        gen_min=2, gen_max=6, gen_mean=4, token_budget=10.0,
    )
    coeffs = {0: (0.01, 0.01), 1: (0.01, 0.01)}
    engine = RealServingEngine(api, params, max_len=32)
    rt = ServingRuntime(
        engine,
        ServingAllocator(dict(coeffs), total_slots=4),
        wl, nodes=[0, 1],
        config=ServingConfig(total_slots=4),
    )
    rt.post(NodeLeave(time=wl.requests[2].arrival, nodes=(1,)))
    rep = rt.run()
    assert rep.summary["dropped"] == 0
    assert rep.summary["completed"] == 8
    assert rep.counters["leaves"] == 1
    # Generated token streams are model outputs, bounded by the vocab.
    for rec in rt.metrics.records():
        assert len(rec.token_times) >= rec.gen_len

from repro.data.pipeline import HeteroBatchPartitioner, NodeBatch, SyntheticLM

__all__ = ["SyntheticLM", "HeteroBatchPartitioner", "NodeBatch"]

"""Real-backend cluster runtime with checkpointed preemption/resume.

    python examples/preempt_resume.py --backend real --epochs 2
    python examples/preempt_resume.py --backend real --faults chaos-real

Submits one job whose :class:`JobSpec` names the ``real`` execution backend
(real JAX gradients of a shrunk olmo-1b on this host, heterogeneous timing
simulated) to the event-driven ``ClusterRuntime``, trains ``--epochs``
epochs, injects a ``Preemption`` (the runtime checkpoints params/opt-state/
GNS state to checksummed generation files under ``<workdir>``), clobbers the
live state to prove the file matters, resumes via a fresh ``JobArrival``,
and trains ``--epochs`` more.  Asserts that the checkpoint file was written
and that resume restored the exact pre-preemption state, so CI can run it
as an end-to-end smoke.  Exits nonzero if any invariant breaks.

With ``--faults chaos-real`` the run instead exercises the integrity-
hardened real path end-to-end: a gradient-poisoned node must be excluded by
the anomaly guard and quarantined by the numerical-health channel, a
solver stall must trip the deadline watchdog into the engine-degradation
chain, and a corrupted checkpoint generation must roll back bit-exactly to
the newest valid generation on resume — all with the runtime invariant
checker on and reporting zero violations.
"""
import argparse
import math
import os
import tempfile

import _common  # noqa: F401  (sys.path bootstrap)

import numpy as np


def _make_spec(args):
    from repro.core.perf_model import CommModel
    from repro.core.scheduler import JobSpec
    from repro.core.simulator import GPU_CATALOG

    return JobSpec(
        name="job",
        node_models=tuple(
            GPU_CATALOG[n].model() for n in ("a100", "v100", "rtx6000")
        ),
        comm=CommModel(t_o=0.04, t_u=0.008, gamma=0.15),
        total_batch=args.total_batch,
        b_noise=500.0,
        ref_batch=args.total_batch,
        backend=args.backend,
    )


def run_chaos_real(args) -> None:
    """The real-path chaos gate (CI's chaos-smoke real-backend lane)."""
    from repro.runtime import (
        ClusterRuntime,
        JobState,
        NodeState,
        RealBackendConfig,
        make_fault_plan,
    )
    from repro.train import checkpoint as ckpt

    spec = _make_spec(args)
    plan = make_fault_plan("chaos-real", 3, seed=0)
    poison = plan.poisons[0]
    print("=== chaos-real (integrity-hardened real path) ===")
    for line in plan.describe():
        print(f"inject: {line}")

    with tempfile.TemporaryDirectory() as workdir:
        rt = ClusterRuntime(
            3,
            policy="cannikin",
            seed=0,
            real_backend=RealBackendConfig(arch=args.arch, seq_len=16, lr=0.3),
            checkpoint_dir=workdir,
            faults=plan,
            invariants=True,
        )
        handle = rt.submit(spec, at=0.0)
        rt.run()  # the arrival solve is stalled -> watchdog -> degradation

        # Phase A: ride through the poison window (epochs 0..2).
        rt.advance(epochs=3, steps=args.steps)
        assert rt.health is not None
        h1 = rt.health.nodes[poison.node]
        assert h1.state == NodeState.QUARANTINED, (
            f"poisoned node {poison.node} not quarantined: {h1.state}"
        )
        assert poison.node not in handle.nodes, "quarantined node still held"
        quar = next(
            r for r in rt.recovery_log
            if r["action"] == "quarantine" and r["node"] == poison.node
        )
        latency = int(quar["epoch"]) - poison.at_epoch
        assert 0 <= latency <= 2, f"quarantine latency {latency} epochs > 2"
        anomalies = handle.last_result.grad_anomalies
        assert any(anomalies), "anomaly guard never excluded the poisoned node"
        print(f"poisoned node {poison.node} quarantined "
              f"{latency} epoch(s) after onset; per-node anomalous steps "
              f"this epoch: {list(anomalies)}")
        assert rt.watchdog is not None and rt.watchdog.solver_timeouts >= 1, (
            "solver stall never tripped the deadline watchdog"
        )
        print(f"watchdog: {rt.watchdog.counters()}")

        # Generation 1: a clean preemption checkpoint.
        rt.preempt(spec.name, at=10.0)
        rt.run()
        assert handle.state == JobState.PREEMPTED, handle.state
        gen1 = handle.checkpoint_path
        assert gen1 is not None and os.path.exists(gen1)
        assert ckpt.verify_checkpoint(gen1), "generation 1 failed verification"
        assert ckpt.checkpoint_generation(gen1) == 1
        print(f"gen 1 written + verified: {os.path.basename(gen1)}")

        rt.submit(spec, at=11.0)
        rt.run()
        rt.advance(epochs=2, steps=args.steps)  # epochs 3..4 (poison over)

        # Generation 2: the injector flips bytes in this write.
        rt.preempt(spec.name, at=20.0)
        rt.run()
        gen2 = handle.checkpoint_path
        assert gen2 is not None and gen2 != gen1
        assert rt.injector.corrupted_paths == [gen2]
        assert not ckpt.verify_checkpoint(gen2), "corrupted gen 2 verified?!"
        print(f"gen 2 written + corrupted: {os.path.basename(gen2)}")

        # Rollback oracle: what a bit-exact restore of gen 1 must produce.
        oracle = ckpt.restore(gen1, handle.backend.snapshot())
        oracle_leaves = [np.asarray(x) for x in _leaves(oracle["params"])]

        # Clobber the live state: only a real on-disk restore can fix this.
        import jax

        handle.backend.params = jax.tree_util.tree_map(
            lambda x: x * 0.0, handle.backend.params
        )

        rt.submit(spec, at=21.0)
        rt.run()
        assert handle.state == JobState.RUNNING, handle.state
        assert handle.ckpt_rollbacks == 1, (
            f"expected exactly one rollback, got {handle.ckpt_rollbacks}"
        )
        post = [np.asarray(x) for x in _leaves(handle.backend.params)]
        for a, b in zip(oracle_leaves, post):
            np.testing.assert_array_equal(a, b)
        print("resume rolled back to gen 1 bit-exactly")

        # Phase C: train on after recovery; the quarantined node has been
        # re-admitted (backoff expired) and must not be CRASHED/lost.
        rt.advance(epochs=2, steps=args.steps)
        assert handle.state == JobState.RUNNING
        assert all(
            math.isfinite(r.mean_loss) for r in handle.records
        ), "non-finite loss"
        assert rt.health.nodes[poison.node].state in (
            NodeState.PROBATION, NodeState.HEALTHY,
        ), f"poisoned node never re-admitted: {rt.health.nodes[poison.node].state}"

        assert rt.invariant_checker is not None
        assert rt.invariant_checker.checks_run > 0
        rt.invariant_checker.assert_clean()
        telemetry = rt.fault_telemetry()
        print(f"detected={telemetry['detected']} "
              f"recoveries={telemetry['recoveries']} "
              f"rollbacks={telemetry['checkpoint_rollbacks']} "
              f"invariants={telemetry['invariants']}")
        print(f"\nepochs={handle.epochs_run} preemptions={handle.preemptions} "
              f"— chaos-real invariants OK")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="real", choices=["sim", "real"])
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--total-batch", type=int, default=12)
    ap.add_argument("--faults", default="none", choices=["none", "chaos-real"],
                    help="chaos-real: gradient poison + checkpoint corruption "
                         "+ solver stall with invariant checking")
    args = ap.parse_args()

    if args.faults == "chaos-real":
        if args.backend != "real":
            raise SystemExit("--faults chaos-real requires --backend real")
        run_chaos_real(args)
        return

    from repro.runtime import ClusterRuntime, JobState, RealBackendConfig

    spec = _make_spec(args)

    with tempfile.TemporaryDirectory() as workdir:
        rt = ClusterRuntime(
            3,
            policy="cannikin",
            seed=0,
            real_backend=RealBackendConfig(arch=args.arch, seq_len=16, lr=0.3),
            checkpoint_dir=workdir,
        )
        handle = rt.submit(spec, at=0.0)
        rt.run()
        rt.advance(epochs=args.epochs, steps=args.steps)
        for r in handle.records:
            loss = "nan" if math.isnan(r.mean_loss) else f"{r.mean_loss:.4f}"
            print(f"epoch {r.epoch} [{r.phase:9s}] backend={r.backend} "
                  f"B={r.total_batch} split={list(r.batches)} loss={loss}")

        real = args.backend == "real"
        if real:
            pre_params = [np.asarray(x) for x in _leaves(handle.backend.params)]
            pre_steps = handle.backend.steps_done

        print("\n-- injecting Preemption --")
        rt.preempt(spec.name, at=10.0)
        rt.run()
        assert handle.state == JobState.PREEMPTED, handle.state
        if real:
            assert handle.checkpoint_path is not None, "no checkpoint path"
            assert os.path.exists(handle.checkpoint_path), "checkpoint not written"
            size = os.path.getsize(handle.checkpoint_path) / 1e6
            print(f"checkpoint written: {handle.checkpoint_path} ({size:.1f} MB)")
            # Clobber the live state: only a real restore can fix this.
            import jax

            handle.backend.params = jax.tree_util.tree_map(
                lambda x: x * 0.0, handle.backend.params
            )
            handle.backend.steps_done = 0

        print("-- resuming (JobArrival) --")
        rt.submit(spec, at=11.0)
        rt.run()
        assert handle.state == JobState.RUNNING, handle.state
        if real:
            post_params = [np.asarray(x) for x in _leaves(handle.backend.params)]
            for a, b in zip(pre_params, post_params):
                np.testing.assert_array_equal(a, b)
            assert handle.backend.steps_done == pre_steps
            print("restore verified: params + stream counters bit-exact")

        rt.advance(epochs=args.epochs, steps=args.steps)
        assert handle.epochs_run == 2 * args.epochs
        if real:
            assert all(
                np.isfinite(r.mean_loss) for r in handle.records
            ), "non-finite loss"
        print(f"\nepochs={handle.epochs_run} preemptions={handle.preemptions} "
              f"sim_time={handle.sim_time:.2f}s — all invariants OK")


def _leaves(tree):
    import jax

    return jax.tree_util.tree_leaves(tree)


if __name__ == "__main__":
    main()

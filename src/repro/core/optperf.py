"""OptPerf: the optimal batch-partition / batch-time solver (§3.3, §4.2, App. A).

Three solvers are provided:

``solve_optperf_algorithm1``
    Paper-faithful Algorithm 1: closed-form Check 1 (all compute-bottleneck),
    Check 2 (all communication-bottleneck), then a binary search over the
    bottleneck boundary for the mixed case.  O(n) per candidate boundary
    (the "linear system" of the paper is diagonal once the partition is
    fixed, so we solve it directly rather than with a generic O(n^3) solve).
    Kept as the independent cross-check oracle for the array engine below.

``solve_optperf_waterfill``
    Beyond-paper oracle: the node batch time
        T_i(b) = max(t_compute_i(b) + T_u, syncStart_i(b) + T_comm)
    is strictly increasing in b, so for a target cluster time T each node has
    a maximal feasible batch
        b_i(T) = min((T - T_u - c_i)/alpha_i, (T - T_comm - d_i)/beta_i)
    and Sum_i max(b_i(T), 0) is continuous and nondecreasing in T.  Bisection
    on T yields the exact optimum including b_i >= 0 clamping that
    Algorithm 1's linear solves ignore.  Implemented as the single-candidate
    special case of the batched engine.

``solve_optperf_batch``
    The batched water-fill engine: solves OptPerf for *all* candidate total
    batch sizes of a goodput sweep simultaneously.  The bisection state is a
    ``(num_candidates,)`` vector of ``[lo, hi]`` brackets refined against a
    ``(num_candidates, n)`` feasible-batch matrix, so the whole sweep costs
    O(max_iter) NumPy broadcasts — ~200 array ops total regardless of the
    candidate count — instead of ``num_candidates * max_iter * n``
    Python-level evaluations.  Complexity: O(max_iter * C * n) flops, O(C * n)
    memory, zero Python-level per-node or per-candidate work in the hot loop.

All coefficient access goes through :attr:`ClusterPerfModel.coeffs`, the
cached array view (precomputed alphas/cs/betas/ds/backprop vectors; the model
dataclass is frozen so the cache can never go stale).

Scalar solvers return an :class:`OptPerfSolution`; the batched engine returns
a :class:`BatchedOptPerfSolution`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.perf_model import ClusterPerfModel

__all__ = [
    "OptPerfSolution",
    "BatchedOptPerfSolution",
    "solve_optperf_algorithm1",
    "solve_optperf_waterfill",
    "solve_optperf_batch",
    "solve_optperf",
    "round_batches",
]


@dataclasses.dataclass(frozen=True)
class OptPerfSolution:
    """Solution of the OptPerf problem for one total batch size."""

    total_batch: float
    opt_perf: float                    # minimized cluster batch time (seconds)
    batches: Tuple[float, ...]         # optimal (real-valued) local batches
    bottleneck: Tuple[str, ...]        # per node: "compute" | "comm"
    method: str                        # solver that produced this

    @property
    def ratios(self) -> Tuple[float, ...]:
        return tuple(b / self.total_batch for b in self.batches)

    def summary(self) -> str:
        parts = ", ".join(
            f"b{i}={b:.1f}({s[:4]})" for i, (b, s) in enumerate(zip(self.batches, self.bottleneck))
        )
        return f"OptPerf={self.opt_perf * 1e3:.3f}ms B={self.total_batch:g} [{parts}]"


@dataclasses.dataclass(frozen=True)
class BatchedOptPerfSolution:
    """OptPerf solutions for a whole vector of candidate total batch sizes.

    ``batches`` is ``(C, n)``; ``total_batches``/``opt_perfs`` are ``(C,)``;
    ``compute_mask`` is the ``(C, n)`` boolean overlap state (True = the node
    is compute-bottleneck at that candidate's optimum).
    """

    total_batches: np.ndarray
    opt_perfs: np.ndarray
    batches: np.ndarray
    compute_mask: np.ndarray
    method: str

    def __len__(self) -> int:
        return int(self.total_batches.shape[0])

    def bottleneck(self, j: int) -> Tuple[str, ...]:
        return tuple("compute" if c else "comm" for c in self.compute_mask[j])

    def solution(self, j: int, *, method: Optional[str] = None) -> OptPerfSolution:
        """Extract candidate ``j`` as a scalar :class:`OptPerfSolution`."""
        return OptPerfSolution(
            total_batch=float(self.total_batches[j]),
            opt_perf=float(self.opt_perfs[j]),
            batches=tuple(float(b) for b in self.batches[j]),
            bottleneck=self.bottleneck(j),
            method=method or self.method,
        )

    def solutions(self) -> List[OptPerfSolution]:
        return [self.solution(j) for j in range(len(self))]


# ---------------------------------------------------------------------------
# helpers (all pure NumPy over the cached coefficient view)
# ---------------------------------------------------------------------------


def _solve_equal_compute(model: ClusterPerfModel, total_batch: float) -> Tuple[float, np.ndarray]:
    """Check 1: equalize t_compute across all nodes.  mu is the common
    t_compute; b_i = (mu - c_i)/alpha_i."""
    c = model.coeffs
    inv = 1.0 / c.alphas
    mu = (total_batch + (c.cs * inv).sum()) / inv.sum()
    return float(mu), (mu - c.cs) * inv


def _solve_equal_syncstart(model: ClusterPerfModel, total_batch: float) -> Tuple[float, np.ndarray]:
    """Check 2: equalize syncStart across all nodes."""
    c = model.coeffs
    inv = 1.0 / c.betas
    mu = (total_batch + (c.ds * inv).sum()) / inv.sum()
    return float(mu), (mu - c.ds) * inv


def _solve_mixed(
    model: ClusterPerfModel,
    total_batch: float,
    compute_mask: np.ndarray,
) -> Tuple[float, np.ndarray]:
    """Mixed case (App. A.3): compute nodes satisfy t_compute_i = mu, comm
    nodes satisfy syncStart_i + T_o = mu; Sum b = B.  One masked reduction."""
    c = model.coeffs
    t_o = model.comm.t_o
    slope = np.where(compute_mask, c.alphas, c.betas)
    offset = np.where(compute_mask, c.cs, t_o + c.ds)
    inv = 1.0 / slope
    mu = (total_batch + (offset * inv).sum()) / inv.sum()
    return float(mu), (mu - offset) * inv


def _partition_valid(
    model: ClusterPerfModel,
    batches: np.ndarray,
    compute_mask: np.ndarray,
) -> bool:
    """The hypothesized overlap state must match the realized one, and all
    batches must be physically valid (>= 0)."""
    if batches.min() < 0:
        return False
    return bool(np.array_equal(model.compute_bottleneck_mask(batches), compute_mask))


# ---------------------------------------------------------------------------
# Algorithm 1 — paper-faithful (scalar cross-check oracle)
# ---------------------------------------------------------------------------


def solve_optperf_algorithm1(
    model: ClusterPerfModel,
    total_batch: float,
    *,
    boundary_hint: Optional[int] = None,
) -> OptPerfSolution:
    """Paper Algorithm 1: overlap-state determination + OptPerf configuration.

    ``boundary_hint`` seeds the mixed-case search with the boundary found for a
    neighbouring total batch size (§4.5 "Overlap state searching"): candidates
    are probed outward from the hint, which makes the epoch-over-epoch resolve
    O(1) boundary probes in the common case.
    """
    if total_batch <= 0:
        raise ValueError("total batch must be positive")
    model.validate()
    n = model.n
    t_u = model.comm.t_u

    # ---- Check 1: all nodes compute-bottleneck --------------------------
    # The paper's linear solves do not enforce b_i >= 0; with small total
    # batches a hopeless straggler can be assigned a negative batch.  Any
    # negative assignment invalidates the closed form and we fall through
    # to the clamped water-fill oracle (beyond-paper robustness; recorded
    # in EXPERIMENTS.md).
    mu_c, batches_c = _solve_equal_compute(model, total_batch)
    mask_c = model.compute_bottleneck_mask(batches_c)
    if batches_c.min() >= 0 and mask_c.all():
        return OptPerfSolution(
            total_batch=total_batch,
            opt_perf=mu_c + t_u,
            batches=tuple(float(b) for b in batches_c),
            bottleneck=("compute",) * n,
            method="algorithm1/check1",
        )

    # ---- Check 2: all nodes communication-bottleneck --------------------
    mu_s, batches_s = _solve_equal_syncstart(model, total_batch)
    mask_s = model.compute_bottleneck_mask(batches_s)
    if batches_s.min() >= 0 and not mask_s.any():
        return OptPerfSolution(
            total_batch=total_batch,
            opt_perf=mu_s + model.comm.t_comm,
            batches=tuple(float(b) for b in batches_s),
            bottleneck=("comm",) * n,
            method="algorithm1/check2",
        )

    # ---- Mixed bottleneck ------------------------------------------------
    # Nodes that are compute-bound under BOTH checks are certainly compute-
    # bound at the optimum; likewise for comm-bound.  The remaining
    # "outliers" are ordered and a boundary is binary-searched (§4.2).
    certain_compute = mask_c & mask_s
    certain_comm = ~mask_c & ~mask_s
    outliers = np.flatnonzero(~certain_compute & ~certain_comm)

    # Rank outliers by fixed processing time (the batch-independent part of
    # the node time); larger fixed time => more likely comm-bottleneck.
    fixed_times = model.coeffs.ds + model.comm.t_comm
    outliers = outliers[np.argsort(fixed_times[outliers], kind="stable")]

    def split_mask(split: int) -> np.ndarray:
        mask = certain_compute.copy()
        mask[outliers[:split]] = True
        return mask

    def try_boundary(split: int) -> Optional[Tuple[float, np.ndarray, np.ndarray]]:
        mask = split_mask(split)
        mu, batches = _solve_mixed(model, total_batch, mask)
        if _partition_valid(model, batches, mask):
            return mu, batches, mask
        return None

    # Probe order: hint (if any) first, then binary search, then exhaustive
    # fallback (robustness beyond the paper; n is small so this is cheap).
    candidates: List[int] = []
    if boundary_hint is not None:
        candidates.append(max(0, min(len(outliers), boundary_hint)))
    lo, hi = 0, len(outliers)
    while lo <= hi:
        mid = (lo + hi) // 2
        candidates.append(mid)
        # Direction: if solving with `mid` makes some hypothesized comm node
        # actually compute-bound, we put too few nodes on the compute side.
        mask = split_mask(mid)
        mu, batches = _solve_mixed(model, total_batch, mask)
        realized = model.compute_bottleneck_mask(batches)
        too_few_compute = bool(np.any(realized & ~mask))
        if too_few_compute:
            lo = mid + 1
        else:
            hi = mid - 1
    candidates.extend(range(len(outliers) + 1))

    seen = set()
    for split in candidates:
        if split in seen:
            continue
        seen.add(split)
        result = try_boundary(split)
        if result is None:
            continue
        mu, batches, mask = result
        return OptPerfSolution(
            total_batch=total_batch,
            opt_perf=mu + t_u,
            batches=tuple(float(b) for b in batches),
            bottleneck=tuple("compute" if c else "comm" for c in mask),
            method=f"algorithm1/mixed(split={split})",
        )

    # No consistent partition (can happen when the unconstrained solve drives
    # some b_i < 0): fall back to the clamped water-fill oracle.
    return solve_optperf_waterfill(model, total_batch)


# ---------------------------------------------------------------------------
# Batched water-fill bisection — the array engine
# ---------------------------------------------------------------------------


def _max_batches_at_times(model: ClusterPerfModel, ts: np.ndarray) -> np.ndarray:
    """Largest feasible batch per node at cluster times ``ts``.

    ``ts`` has shape ``(...,)``; the result broadcasts to ``(..., n)``.  A
    node whose syncStart does not grow with b (beta == 0, i.e. q = gamma = 0)
    is never comm-constrained once t clears its fixed comm time.
    """
    c = model.coeffs
    comm = model.comm
    t = np.asarray(ts, dtype=np.float64)[..., None]
    b_compute = (t - comm.t_u - c.cs) / c.alphas
    slack = t - comm.t_comm - c.ds
    degenerate = c.betas <= 0.0
    b_comm = slack / np.where(degenerate, 1.0, c.betas)
    if degenerate.any():
        b_comm = np.where(
            degenerate, np.where(slack >= 0.0, np.inf, -np.inf), b_comm
        )
    return np.minimum(b_compute, b_comm)


def _finalize_batches(
    model: ClusterPerfModel,
    totals: np.ndarray,
    t_star: np.ndarray,
    *,
    tol: float,
) -> np.ndarray:
    """Turn the bisected time bounds into exact-sum batch vectors.

    Bisection leaves Sum_i max(b_i(t_star), 0) >= B (up to float residue).
    The excess is removed *proportionally from the positive (binding) nodes
    only* — shrinking a binding node keeps it under its time bound, whereas
    the old whole-vector rescale could inflate a binding node past ``t_star``
    whenever float residue left the sum a hair under B.  Clamped nodes (b=0,
    fixed time already at/above ``t_star``) are never touched.
    """
    raw = _max_batches_at_times(model, t_star)          # (..., n)
    batches = np.maximum(raw, 0.0)
    sums = batches.sum(axis=-1)
    # Invariant: the bisection keeps assigned(hi) >= B, and this recomputes
    # the identical expression at t_star = hi, so sums >= totals exactly.
    if not bool(np.all(sums >= totals)):
        raise AssertionError("water-fill bisection lost its upper-bracket invariant")
    pos_sums = np.where(sums > 0.0, sums, 1.0)
    shrink = sums > totals
    if np.any(shrink):
        # Proportional removal from positive nodes == multiplicative rescale
        # with factor <= 1: every touched node stays below its t_star bound.
        factor = np.where(shrink, totals / pos_sums, 1.0)
        batches = batches * factor[..., None]
    # Internal consistency: no positive node may exceed its bisected time
    # bound (clamped stragglers sit at their fixed floor, which can lie above
    # t_star and is unavoidable at any partition).
    node_times = model.node_times(batches)
    positive = batches > 0.0
    bound = t_star[..., None] * (1.0 + max(tol * 16.0, 1e-8)) + 1e-12
    if not bool(np.all(np.where(positive, node_times, -np.inf) <= bound)):
        raise AssertionError("water-fill finalization exceeded the bisected time bound")
    return batches


def solve_optperf_batch(
    model: ClusterPerfModel,
    total_batches: Sequence[float],
    *,
    tol: float = 1e-10,
    max_iter: int = 200,
) -> BatchedOptPerfSolution:
    """Solve OptPerf for every candidate total batch size in one array pass.

    All candidates are bisected *simultaneously*: the bracket state is a
    ``(C,)`` vector and each iteration evaluates one ``(C, n)`` feasible-batch
    broadcast, so the whole sweep is ~``max_iter`` NumPy ops regardless of C.

    Monotonicity argument (per candidate, same as the scalar water-fill):
    each node's feasible batch b_i(T) is affine increasing in T, so
    g(T) = Sum_i max(b_i(T), 0) is continuous, nondecreasing, and unbounded;
    bisection on g(T) = B converges geometrically.
    """
    totals = np.array(total_batches, dtype=np.float64)  # copy: no aliasing
    if totals.ndim != 1:
        raise ValueError("total_batches must be a 1-D sequence")
    if totals.size == 0:
        raise ValueError("total_batches must be non-empty")
    if np.any(totals <= 0):
        raise ValueError("total batch must be positive")
    model.validate()
    c = model.coeffs
    comm = model.comm

    def assigned(t: np.ndarray) -> np.ndarray:
        return np.maximum(_max_batches_at_times(model, t), 0.0).sum(axis=-1)

    # Bracket every candidate.  At lo0 (the smallest fixed node time) no node
    # can take positive batch, so assigned(lo0) == 0 < B for all candidates.
    lo0 = float(min((c.cs + comm.t_u).min(), (c.ds + comm.t_comm).min()))
    lo = np.full(totals.shape, lo0)
    hi = lo + 1.0
    for _ in range(64):
        short = assigned(hi) < totals
        if not short.any():
            break
        hi = np.where(short, lo0 + (hi - lo0) * 2.0, hi)
    else:
        raise RuntimeError("water-fill failed to bracket optimum")

    for _ in range(max_iter):
        mid = 0.5 * (lo + hi)
        ge = assigned(mid) >= totals
        hi = np.where(ge, mid, hi)
        lo = np.where(ge, lo, mid)
        if np.all(hi - lo <= tol * np.maximum(1.0, np.abs(hi))):
            break
    t_star = hi

    batches = _finalize_batches(model, totals, t_star, tol=tol)
    opt_perfs = model.node_times(batches).max(axis=-1)
    compute_mask = model.compute_bottleneck_mask(batches)
    for arr in (totals, opt_perfs, batches, compute_mask):
        arr.flags.writeable = False
    return BatchedOptPerfSolution(
        total_batches=totals,
        opt_perfs=opt_perfs,
        batches=batches,
        compute_mask=compute_mask,
        method="waterfill/batched",
    )


def solve_optperf_waterfill(
    model: ClusterPerfModel,
    total_batch: float,
    *,
    tol: float = 1e-10,
    max_iter: int = 200,
) -> OptPerfSolution:
    """Exact OptPerf via bisection on the cluster batch time T.

    Single-candidate specialization of :func:`solve_optperf_batch` (identical
    numerics, so the scalar oracle and the batched engine can never drift).
    """
    batch = solve_optperf_batch(
        model, np.asarray([total_batch], dtype=np.float64), tol=tol, max_iter=max_iter
    )
    return batch.solution(0, method="waterfill")


def solve_optperf(
    model: ClusterPerfModel,
    total_batch: float,
    *,
    method: str = "algorithm1",
    boundary_hint: Optional[int] = None,
) -> OptPerfSolution:
    """Dispatch helper. ``method`` in {"algorithm1", "waterfill"}."""
    if method == "algorithm1":
        return solve_optperf_algorithm1(model, total_batch, boundary_hint=boundary_hint)
    if method == "waterfill":
        return solve_optperf_waterfill(model, total_batch)
    raise ValueError(f"unknown method {method!r}")


# ---------------------------------------------------------------------------
# Integer rounding (§4.5 "Integer batch sizes")
# ---------------------------------------------------------------------------


def round_batches(batches: Sequence[float], total_batch: int) -> List[int]:
    """Round real batches to integers summing exactly to ``total_batch``.

    The paper rounds and accepts the (insignificant) error; we use
    largest-remainder rounding so the sum constraint holds exactly and the
    rounding error per node is < 1 sample.  When float residue leaves the
    real batches summing a hair *above* ``total_batch`` (so the floors
    already overshoot), the deficit is taken from the entries with the
    smallest fractional parts instead of raising; overshoot of a sample per
    node or more still raises (that is a wrong-total caller bug).
    """
    if total_batch != int(total_batch):
        raise ValueError("total batch must be an integer")
    floors = [int(math.floor(b)) for b in batches]
    remainder = int(total_batch) - sum(floors)
    out = list(floors)
    if remainder < 0:
        if sum(batches) - total_batch >= len(batches):
            # Overshoot of a sample per node or more is a caller bug (a
            # partition computed for a different total), not float residue.
            raise ValueError("batches sum above total")
        # Decrement the smallest fractional parts (they lose the least mass),
        # skipping entries already at zero; raise only when the total is
        # unreachable even with every batch driven to zero.
        order = sorted(range(len(batches)), key=lambda i: batches[i] - floors[i])
        need = -remainder
        while need:
            progressed = False
            for i in order:
                if out[i] > 0:
                    out[i] -= 1
                    need -= 1
                    progressed = True
                    if need == 0:
                        break
            if not progressed:
                raise ValueError("batches sum above total")
        return out
    # Assign leftover samples to the largest fractional parts.
    fracs = sorted(
        range(len(batches)), key=lambda i: batches[i] - floors[i], reverse=True
    )
    for i in fracs[:remainder]:
        out[i] += 1
    return out

"""Cannikin controller end-to-end over the simulator: bootstrap -> learned
models -> OptPerf plans; baseline policies; convergence-speed ordering
(Fig. 9 analogue: Cannikin reaches near-OptPerf by epoch 3, LB-BSP needs
many epochs)."""
import numpy as np
import pytest

from repro.core.baselines import EvenPartition, LBBSPPartition
from repro.core.controller import CannikinController
from repro.core.optperf import solve_optperf_algorithm1
from repro.core.simulator import SimulatedCluster, cluster_A, cluster_B


def drive(policy, sim, total_batch, epochs, steps=5):
    """Run a partition policy against the simulator; returns per-epoch batch
    times."""
    times = []
    last = None
    for epoch in range(epochs):
        if isinstance(policy, CannikinController):
            plan = policy.plan_epoch()
            batches = list(plan.batches)
        else:
            batches = policy.partition(total_batch, epoch, last)
        t, ms = sim.run_epoch(batches, steps)
        last = ms[-1]
        if isinstance(policy, CannikinController):
            policy.observe_epoch(ms)
        times.append(t / steps)
    return times


def test_cannikin_reaches_optperf_by_epoch_3():
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.005, seed=0)
    ctrl = CannikinController(
        sim.n, batch_candidates=[128], ref_batch=128, adaptive=False
    )
    times = drive(ctrl, sim, 128, epochs=6)
    best = solve_optperf_algorithm1(sim.true_model(), 128).opt_perf
    # Paper Fig. 9: OptPerf reached at the 3rd epoch (two learning epochs).
    assert times[2] <= best * 1.08
    assert times[-1] <= best * 1.05


def test_cannikin_beats_even_and_lbbsp_early():
    profiles, comm = cluster_B()
    for seed in (0, 1):
        sims = [
            SimulatedCluster(profiles, comm, noise=0.01, seed=seed) for _ in range(3)
        ]
        ctrl = CannikinController(
            sims[0].n, batch_candidates=[512], ref_batch=512, adaptive=False
        )
        t_cannikin = drive(ctrl, sims[0], 512, epochs=6)
        t_even = drive(EvenPartition(sims[1].n), sims[1], 512, epochs=6)
        t_lbbsp = drive(LBBSPPartition(sims[2].n, delta=5), sims[2], 512, epochs=6)
        # After learning, Cannikin is much faster than even split and faster
        # than LB-BSP at epoch 6 (LB-BSP moves only delta samples/epoch).
        assert t_cannikin[-1] < 0.8 * t_even[-1]
        assert t_cannikin[-1] < t_lbbsp[-1]


def test_lbbsp_restarts_on_batch_change():
    lb = LBBSPPartition(4, delta=5)
    b1 = lb.partition(64, 0, None)
    assert b1 == [16, 16, 16, 16]
    lb._batches = [10, 20, 20, 14]
    b2 = lb.partition(128, 1, None)  # total changed -> even restart
    assert b2 == [32, 32, 32, 32]


def test_adaptive_total_batch_increases_with_low_noise():
    """With B_noise large, goodput favors bigger batches; the controller
    should move above the reference batch once models are learned."""
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.005, seed=0)
    ctrl = CannikinController(
        sim.n, batch_candidates=[64, 128, 256, 512], ref_batch=64
    )
    for _ in range(4):
        plan = ctrl.plan_epoch()
        _, ms = sim.run_epoch(list(plan.batches), 4)
        ctrl.observe_epoch(ms)
        # Feed a large, constant gradient-noise observation.
        ctrl.observe_gradients([10.0] * sim.n, 2.0, list(plan.batches))
    final = ctrl.last_plan
    assert final.phase == "optperf"
    assert final.total_batch > 64


def test_plan_respects_local_bounds():
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.0, seed=0)
    ctrl = CannikinController(
        sim.n, batch_candidates=[90], ref_batch=90, adaptive=False,
        min_local=10, max_local=50,
    )
    for _ in range(4):
        plan = ctrl.plan_epoch()
        assert sum(plan.batches) == plan.total_batch
        assert all(10 <= b <= 50 for b in plan.batches)
        _, ms = sim.run_epoch(list(plan.batches), 3)
        ctrl.observe_epoch(ms)


def test_controller_overhead_tracked():
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.0, seed=0)
    ctrl = CannikinController(sim.n, batch_candidates=[64, 128], ref_batch=64)
    for _ in range(3):
        plan = ctrl.plan_epoch()
        _, ms = sim.run_epoch(list(plan.batches), 3)
        ctrl.observe_epoch(ms)
    assert ctrl.stats.epochs_planned == 3
    assert ctrl.stats.overhead_seconds > 0
    # Overhead must be insignificant relative to even 1s of training.
    assert ctrl.stats.overhead_fraction(1.0) < 0.5

"""OptPerf: the optimal batch-partition / batch-time solver (§3.3, §4.2, App. A).

Two solvers are provided:

``solve_optperf_algorithm1``
    Paper-faithful Algorithm 1: closed-form Check 1 (all compute-bottleneck),
    Check 2 (all communication-bottleneck), then a binary search over the
    bottleneck boundary for the mixed case.  O(n) per candidate boundary
    (the "linear system" of the paper is diagonal once the partition is
    fixed, so we solve it directly rather than with a generic O(n^3) solve).

``solve_optperf_waterfill``
    Beyond-paper oracle: the node batch time
        T_i(b) = max(t_compute_i(b) + T_u, syncStart_i(b) + T_comm)
    is strictly increasing in b, so for a target cluster time T each node has
    a maximal feasible batch
        b_i(T) = min((T - T_u - c_i)/alpha_i, (T - T_comm - d_i)/beta_i)
    and Sum_i max(b_i(T), 0) is continuous and nondecreasing in T.  Bisection
    on T yields the exact optimum including b_i >= 0 clamping that
    Algorithm 1's linear solves ignore.  Used as the property-test oracle and
    as the production solver when clamping binds.

Both return an :class:`OptPerfSolution`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.perf_model import ClusterPerfModel

__all__ = [
    "OptPerfSolution",
    "solve_optperf_algorithm1",
    "solve_optperf_waterfill",
    "solve_optperf",
    "round_batches",
]


@dataclasses.dataclass(frozen=True)
class OptPerfSolution:
    """Solution of the OptPerf problem for one total batch size."""

    total_batch: float
    opt_perf: float                    # minimized cluster batch time (seconds)
    batches: Tuple[float, ...]         # optimal (real-valued) local batches
    bottleneck: Tuple[str, ...]        # per node: "compute" | "comm"
    method: str                        # solver that produced this

    @property
    def ratios(self) -> Tuple[float, ...]:
        return tuple(b / self.total_batch for b in self.batches)

    def summary(self) -> str:
        parts = ", ".join(
            f"b{i}={b:.1f}({s[:4]})" for i, (b, s) in enumerate(zip(self.batches, self.bottleneck))
        )
        return f"OptPerf={self.opt_perf * 1e3:.3f}ms B={self.total_batch:g} [{parts}]"


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _node_time(model: ClusterPerfModel, i: int, b: float) -> float:
    return model.node_time(i, b)


def _bottleneck_labels(model: ClusterPerfModel, batches: Sequence[float]) -> Tuple[str, ...]:
    return tuple(
        "compute" if model.is_compute_bottleneck(i, b) else "comm"
        for i, b in enumerate(batches)
    )


def _solve_equal_compute(model: ClusterPerfModel, total_batch: float) -> Tuple[float, List[float]]:
    """Check 1: equalize t_compute across all nodes.  mu is the common
    t_compute; b_i = (mu - c_i)/alpha_i."""
    alphas = np.array([n.alpha for n in model.nodes])
    cs = np.array([n.c for n in model.nodes])
    inv = 1.0 / alphas
    mu = (total_batch + (cs * inv).sum()) / inv.sum()
    batches = (mu - cs) * inv
    return float(mu), [float(b) for b in batches]


def _solve_equal_syncstart(model: ClusterPerfModel, total_batch: float) -> Tuple[float, List[float]]:
    """Check 2: equalize syncStart across all nodes."""
    gamma = model.comm.gamma
    betas = np.array([n.beta(gamma) for n in model.nodes])
    ds = np.array([n.d(gamma) for n in model.nodes])
    inv = 1.0 / betas
    mu = (total_batch + (ds * inv).sum()) / inv.sum()
    batches = (mu - ds) * inv
    return float(mu), [float(b) for b in batches]


def _solve_mixed(
    model: ClusterPerfModel,
    total_batch: float,
    compute_set: Sequence[int],
    comm_set: Sequence[int],
) -> Tuple[float, List[float]]:
    """Mixed case (App. A.3): compute nodes satisfy t_compute_i = mu,
    comm nodes satisfy syncStart_i + T_o = mu; Sum b = B."""
    gamma = model.comm.gamma
    t_o = model.comm.t_o
    num = total_batch
    den = 0.0
    for i in compute_set:
        node = model.nodes[i]
        num += node.c / node.alpha
        den += 1.0 / node.alpha
    for i in comm_set:
        node = model.nodes[i]
        num += (t_o + node.d(gamma)) / node.beta(gamma)
        den += 1.0 / node.beta(gamma)
    mu = num / den
    batches = [0.0] * model.n
    for i in compute_set:
        node = model.nodes[i]
        batches[i] = (mu - node.c) / node.alpha
    for i in comm_set:
        node = model.nodes[i]
        batches[i] = (mu - t_o - node.d(gamma)) / node.beta(gamma)
    return float(mu), batches


def _partition_valid(
    model: ClusterPerfModel,
    batches: Sequence[float],
    compute_set: Sequence[int],
    comm_set: Sequence[int],
) -> bool:
    """The hypothesized overlap state must match the realized one, and all
    batches must be physically valid (>= 0)."""
    if min(batches) < 0:
        return False
    for i in compute_set:
        if not model.is_compute_bottleneck(i, batches[i]):
            return False
    for i in comm_set:
        if model.is_compute_bottleneck(i, batches[i]):
            return False
    return True


# ---------------------------------------------------------------------------
# Algorithm 1 — paper-faithful
# ---------------------------------------------------------------------------


def solve_optperf_algorithm1(
    model: ClusterPerfModel,
    total_batch: float,
    *,
    boundary_hint: Optional[int] = None,
) -> OptPerfSolution:
    """Paper Algorithm 1: overlap-state determination + OptPerf configuration.

    ``boundary_hint`` seeds the mixed-case search with the boundary found for a
    neighbouring total batch size (§4.5 "Overlap state searching"): candidates
    are probed outward from the hint, which makes the epoch-over-epoch resolve
    O(1) boundary probes in the common case.
    """
    if total_batch <= 0:
        raise ValueError("total batch must be positive")
    model.validate()
    n = model.n
    gamma = model.comm.gamma
    t_o, t_u = model.comm.t_o, model.comm.t_u

    # ---- Check 1: all nodes compute-bottleneck --------------------------
    # The paper's linear solves do not enforce b_i >= 0; with small total
    # batches a hopeless straggler can be assigned a negative batch.  Any
    # negative assignment invalidates the closed form and we fall through
    # to the clamped water-fill oracle (beyond-paper robustness; recorded
    # in EXPERIMENTS.md).
    mu_c, batches_c = _solve_equal_compute(model, total_batch)
    if min(batches_c) >= 0 and all(
        (1.0 - gamma) * model.nodes[i].backprop(batches_c[i]) >= t_o for i in range(n)
    ):
        return OptPerfSolution(
            total_batch=total_batch,
            opt_perf=mu_c + t_u,
            batches=tuple(batches_c),
            bottleneck=("compute",) * n,
            method="algorithm1/check1",
        )

    # ---- Check 2: all nodes communication-bottleneck --------------------
    mu_s, batches_s = _solve_equal_syncstart(model, total_batch)
    if min(batches_s) >= 0 and all(
        (1.0 - gamma) * model.nodes[i].backprop(batches_s[i]) < t_o for i in range(n)
    ):
        return OptPerfSolution(
            total_batch=total_batch,
            opt_perf=mu_s + model.comm.t_comm,
            batches=tuple(batches_s),
            bottleneck=("comm",) * n,
            method="algorithm1/check2",
        )

    # ---- Mixed bottleneck ------------------------------------------------
    # Nodes that are compute-bound under BOTH checks are certainly compute-
    # bound at the optimum; likewise for comm-bound.  The remaining
    # "outliers" are ordered and a boundary is binary-searched (§4.2).
    compute_certain: List[int] = []
    comm_certain: List[int] = []
    outliers: List[int] = []
    for i in range(n):
        cb1 = (1.0 - gamma) * model.nodes[i].backprop(batches_c[i]) >= t_o
        cb2 = (1.0 - gamma) * model.nodes[i].backprop(batches_s[i]) >= t_o
        if cb1 and cb2:
            compute_certain.append(i)
        elif not cb1 and not cb2:
            comm_certain.append(i)
        else:
            outliers.append(i)

    # Rank outliers by fixed processing time (the batch-independent part of
    # the node time); larger fixed time => more likely comm-bottleneck.
    def fixed_time(i: int) -> float:
        node = model.nodes[i]
        return node.d(gamma) + model.comm.t_comm

    outliers.sort(key=fixed_time)

    def try_boundary(split: int) -> Optional[Tuple[float, List[float], List[int], List[int]]]:
        compute_set = compute_certain + outliers[:split]
        comm_set = comm_certain + outliers[split:]
        if not compute_set and not comm_set:
            return None
        mu, batches = _solve_mixed(model, total_batch, compute_set, comm_set)
        if _partition_valid(model, batches, compute_set, comm_set):
            return mu, batches, compute_set, comm_set
        return None

    # Probe order: hint (if any) first, then binary search, then exhaustive
    # fallback (robustness beyond the paper; n is small so this is cheap).
    candidates: List[int] = []
    if boundary_hint is not None:
        candidates.append(max(0, min(len(outliers), boundary_hint)))
    lo, hi = 0, len(outliers)
    while lo <= hi:
        mid = (lo + hi) // 2
        candidates.append(mid)
        # Direction: if solving with `mid` makes some hypothesized comm node
        # actually compute-bound, we put too few nodes on the compute side.
        compute_set = compute_certain + outliers[:mid]
        comm_set = comm_certain + outliers[mid:]
        mu, batches = _solve_mixed(model, total_batch, compute_set, comm_set)
        too_few_compute = any(model.is_compute_bottleneck(i, batches[i]) for i in comm_set)
        if too_few_compute:
            lo = mid + 1
        else:
            hi = mid - 1
    candidates.extend(range(len(outliers) + 1))

    seen = set()
    for split in candidates:
        if split in seen:
            continue
        seen.add(split)
        result = try_boundary(split)
        if result is None:
            continue
        mu, batches, compute_set, comm_set = result
        bottleneck = ["comm"] * n
        for i in compute_set:
            bottleneck[i] = "compute"
        return OptPerfSolution(
            total_batch=total_batch,
            opt_perf=mu + t_u,
            batches=tuple(batches),
            bottleneck=tuple(bottleneck),
            method=f"algorithm1/mixed(split={split})",
        )

    # No consistent partition (can happen when the unconstrained solve drives
    # some b_i < 0): fall back to the clamped water-fill oracle.
    return solve_optperf_waterfill(model, total_batch)


# ---------------------------------------------------------------------------
# Water-fill bisection — beyond-paper exact oracle
# ---------------------------------------------------------------------------


def _max_batch_at_time(model: ClusterPerfModel, i: int, t: float) -> float:
    """Largest b such that node i's batch time <= t (may be negative)."""
    node = model.nodes[i]
    comm = model.comm
    b_compute = (t - comm.t_u - node.c) / node.alpha
    beta = node.beta(comm.gamma)
    if beta <= 0.0:
        # syncStart does not grow with b (q=0, gamma=0): the comm path never
        # constrains the batch once t clears the fixed comm time.
        slack = t - comm.t_comm - node.d(comm.gamma)
        b_comm = math.inf if slack >= 0 else -math.inf
    else:
        b_comm = (t - comm.t_comm - node.d(comm.gamma)) / beta
    return min(b_compute, b_comm)


def solve_optperf_waterfill(
    model: ClusterPerfModel,
    total_batch: float,
    *,
    tol: float = 1e-10,
    max_iter: int = 200,
) -> OptPerfSolution:
    """Exact OptPerf via bisection on the cluster batch time T.

    Monotonicity: each node's feasible batch b_i(T) is affine increasing in T,
    so g(T) = Sum_i max(b_i(T), 0) is continuous, nondecreasing, and
    unbounded; bisection on g(T) = B converges geometrically.
    """
    if total_batch <= 0:
        raise ValueError("total batch must be positive")
    model.validate()
    n = model.n

    def assigned(t: float) -> float:
        return sum(max(_max_batch_at_time(model, i, t), 0.0) for i in range(n))

    # Bracket the optimum.
    lo = min(
        min(node.c + model.comm.t_u for node in model.nodes),
        min(node.d(model.comm.gamma) + model.comm.t_comm for node in model.nodes),
    )
    hi = lo + 1.0
    while assigned(hi) < total_batch:
        hi = lo + (hi - lo) * 2.0
        if hi - lo > 1e15:
            raise RuntimeError("water-fill failed to bracket optimum")

    for _ in range(max_iter):
        mid = 0.5 * (lo + hi)
        if assigned(mid) >= total_batch:
            hi = mid
        else:
            lo = mid
        if hi - lo <= tol * max(1.0, abs(hi)):
            break
    t_star = hi

    raw = np.array([_max_batch_at_time(model, i, t_star) for i in range(n)])
    batches = np.maximum(raw, 0.0)
    # Remove bisection residue: rescale the positive batches to hit B exactly.
    pos = batches > 0
    if batches[pos].sum() > 0:
        batches[pos] *= total_batch / batches[pos].sum()
    return OptPerfSolution(
        total_batch=total_batch,
        opt_perf=float(model.cluster_time(list(batches))),
        batches=tuple(float(b) for b in batches),
        bottleneck=_bottleneck_labels(model, batches),
        method="waterfill",
    )


def solve_optperf(
    model: ClusterPerfModel,
    total_batch: float,
    *,
    method: str = "algorithm1",
    boundary_hint: Optional[int] = None,
) -> OptPerfSolution:
    """Dispatch helper. ``method`` in {"algorithm1", "waterfill"}."""
    if method == "algorithm1":
        return solve_optperf_algorithm1(model, total_batch, boundary_hint=boundary_hint)
    if method == "waterfill":
        return solve_optperf_waterfill(model, total_batch)
    raise ValueError(f"unknown method {method!r}")


# ---------------------------------------------------------------------------
# Integer rounding (§4.5 "Integer batch sizes")
# ---------------------------------------------------------------------------


def round_batches(batches: Sequence[float], total_batch: int) -> List[int]:
    """Round real batches to integers summing exactly to ``total_batch``.

    The paper rounds and accepts the (insignificant) error; we use
    largest-remainder rounding so the sum constraint holds exactly and the
    rounding error per node is < 1 sample.
    """
    if total_batch != int(total_batch):
        raise ValueError("total batch must be an integer")
    floors = [int(math.floor(b)) for b in batches]
    remainder = int(total_batch) - sum(floors)
    if remainder < 0:
        raise ValueError("batches sum above total")
    # Assign leftover samples to the largest fractional parts.
    fracs = sorted(
        range(len(batches)), key=lambda i: batches[i] - floors[i], reverse=True
    )
    out = list(floors)
    for i in fracs[:remainder]:
        out[i] += 1
    return out

"""Shared building blocks for the model zoo (pure JAX, no flax).

Conventions:
  * Parameters live in nested dicts; their *structure* is declared once as a
    schema (`Param` leaves) from which both initialization and PartitionSpecs
    derive — a single source of truth for shapes and sharding.
  * All per-layer parameters are stacked along a leading L dim and the layer
    stack runs under `jax.lax.scan` (+ optional remat), so HLO size is
    depth-independent.
  * Attention uses a chunked online-softmax formulation (flash-style in plain
    jnp) for full-sequence passes — O(S·chunk) score memory — and a masked
    dot for single-token decode.  Sliding-window attention uses exact
    block-local attention (2-block keys), giving window-linear FLOPs.
  * Mixed precision: parameters are stored in ``param_dtype`` and cast to
    ``compute_dtype`` on use; softmax/norm statistics in float32.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.context import constrain
from repro.sharding.rules import MeshRules

PyTree = Any

# ---------------------------------------------------------------------------
# Parameter schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Param:
    """Declares one parameter: shape, logical sharding axes, initializer."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: Optional[float] = None  # overrides fan-in scaling

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape/axes rank mismatch: {self.shape} vs {self.axes}")


def _init_leaf(rng: jax.Array, p: Param, dtype: jnp.dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "embed":
        scale = p.scale if p.scale is not None else 0.02
        return (jax.random.normal(rng, p.shape) * scale).astype(dtype)
    if p.init == "normal":
        # Fan-in scaled: last axis is output for our (in..., out) or the
        # contraction structure declared by the model; use 1/sqrt(prod(all
        # but last)) which matches truncated-lecun for 2-3D weights.
        fan_in = int(np.prod(p.shape[:-1])) if len(p.shape) > 1 else p.shape[0]
        scale = p.scale if p.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(rng, p.shape) * scale).astype(dtype)
    raise ValueError(f"unknown init {p.init!r}")


def init_from_schema(rng: jax.Array, schema: PyTree, dtype: jnp.dtype) -> PyTree:
    """Initialize a parameter pytree from a schema pytree of Param leaves."""
    leaves, treedef = jax.tree_util.tree_flatten(
        schema, is_leaf=lambda x: isinstance(x, Param)
    )
    rngs = jax.random.split(rng, len(leaves))
    arrays = [_init_leaf(r, p, dtype) for r, p in zip(rngs, leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrays)


def specs_from_schema(schema: PyTree, rules: MeshRules) -> PyTree:
    """PartitionSpec pytree matching the schema structure."""

    def leaf_spec(path, p: Param):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        return rules.spec(p.axes, p.shape, path=name)

    return jax.tree_util.tree_map_with_path(
        leaf_spec, schema, is_leaf=lambda x: isinstance(x, Param)
    )


def stacked(schema: PyTree, n_layers: int) -> PyTree:
    """Prepend a stacked-layer dim (replicated) to every Param in a schema."""

    def wrap(p: Param) -> Param:
        return Param(
            shape=(n_layers,) + p.shape,
            axes=(None,) + p.axes,
            init=p.init,
            scale=p.scale,
        )

    return jax.tree_util.tree_map(wrap, schema, is_leaf=lambda x: isinstance(x, Param))


def param_count(schema: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(schema, is_leaf=lambda x: isinstance(x, Param))
    return int(sum(np.prod(p.shape) for p in leaves))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    if weight is not None:
        out = out * weight.astype(jnp.float32)
    return out.astype(dtype)


def layer_norm(
    x: jax.Array,
    weight: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    eps: float = 1e-5,
) -> jax.Array:
    """LayerNorm; with weight=bias=None this is OLMo's non-parametric LN."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        out = out * weight.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim//2,)."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """Rotate pairs: x (..., S, H, D), positions broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)
    angles = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    out = jnp.stack([out1, out2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


# When True, GQA attention physically repeats K/V to the full head count
# before the score einsum.  The grouped (KV, R) reshape hides the head
# sharding from GSPMD whenever KV is not divisible by the model axis
# (llama3: KV=8 on a 16-way axis); repeating costs R x K/V bytes but keeps
# the score computation sharded over heads.  §Perf variant "gqa_repeat".
GQA_REPEAT = True


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,S,KV,R,D) x k (B,T,KV,D) -> scores (B,KV,R,S,T), float32."""
    return jnp.einsum(
        "bskrd,btkd->bkrst", q, k, preferred_element_type=jnp.float32
    )


def _gqa_combine(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs (B,KV,R,S,T) x v (B,T,KV,D) -> (B,S,KV,R,D)."""
    return jnp.einsum("bkrst,btkd->bskrd", probs.astype(v.dtype), v)


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: Union[int, jax.Array] = 0,
    kv_chunk: int = 512,
    softmax_scale: Optional[float] = None,
    bidirectional: bool = False,
) -> jax.Array:
    """Chunked online-softmax attention.

    q: (B, S, H, D); k, v: (B, T, KV, D) with H = KV * R.
    Scans over KV chunks carrying (max, denom, acc); O(S * kv_chunk) score
    memory instead of O(S*T).  Causal mask uses absolute positions
    ``q_offset + arange(S)`` vs ``arange(T)``.
    """
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # value head dim may differ from qk dim (MLA)
    if h % kv != 0:
        raise ValueError(f"heads {h} not multiple of kv heads {kv}")
    # Pin the batch/head sharding of the attention operands: GSPMD loses it
    # across the kv-chunk scan (observed 16x replicated attention FLOPs).
    q = constrain(q, ("batch", None, "heads", None))
    if GQA_REPEAT and kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
        kv = h
        k = constrain(k, ("batch", None, "heads", None))
        v = constrain(v, ("batch", None, "heads", None))
    else:
        k = constrain(k, ("batch", None, "kv_heads", None))
        v = constrain(v, ("batch", None, "kv_heads", None))
    r = h // kv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    qg = (q * scale).reshape(b, s, kv, r, d)

    chunk = min(kv_chunk, t)
    if t % chunk != 0:
        # Pad T to a chunk multiple with masked-out keys.
        pad = chunk - t % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t_pad = t + pad
    else:
        t_pad = t
    n_chunks = t_pad // chunk

    q_pos = jnp.arange(s) + q_offset  # (S,)

    def body(carry, idx):
        m_prev, l_prev, acc_prev = carry
        start = idx * chunk
        k_c = jax.lax.dynamic_slice_in_dim(k, start, chunk, axis=1)
        v_c = jax.lax.dynamic_slice_in_dim(v, start, chunk, axis=1)
        scores = _gqa_scores(qg, k_c)  # (B,KV,R,S,chunk) f32
        kv_pos = start + jnp.arange(chunk)
        mask = kv_pos[None, :] < t  # padding mask (S broadcast later)
        if causal and not bidirectional:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
        m_new = jnp.maximum(m_prev, scores.max(axis=-1))
        # Guard fully-masked rows (m = -inf): exp(-inf - -inf) -> nan.
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.exp(jnp.where(jnp.isfinite(m_prev), m_prev - m_safe, -jnp.inf))
        p = jnp.exp(scores - m_safe[..., None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc_new = acc_prev * alpha[..., None].astype(acc_prev.dtype) + _gqa_combine(
            p, v_c
        ).transpose(0, 2, 3, 1, 4)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, r, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, r, s), jnp.float32)
    a0 = jnp.zeros((b, kv, r, s, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # (B,KV,R,S,Dv) -> (B,S,KV,R,Dv) -> (B,S,H,Dv)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dv)
    return out.astype(q.dtype)


def local_window_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """Exact causal sliding-window attention via 2-block-local attention.

    Blocks of size ``window``; query block i attends to key blocks {i-1, i}
    with the exact causal+window mask, so FLOPs are O(S * 2W) not O(S^2).
    Requires q and k from the same sequence (self-attention, q_offset 0).
    """
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    if s != t:
        raise ValueError("local attention expects self-attention (S == T)")
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    r = h // kv
    w = window
    pad = (-s) % w
    s_pad = s + pad
    nb = s_pad // w
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)

    def blockify(x):
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x.reshape(b, nb, w, x.shape[2], d)

    qb = blockify(q * scale).reshape(b, nb, w, kv, r, d)
    kb = blockify(k)
    vb = blockify(v)
    # Previous block (zeros for block 0).
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)  # (B,nb,2W,KV,D)
    v2 = jnp.concatenate([v_prev, vb], axis=2)

    scores = jnp.einsum(
        "bnskrd,bntkd->bnkrst", qb, k2, preferred_element_type=jnp.float32
    )  # (B,nb,KV,R,W,2W)
    # Mask: global positions. q position inside block = i*w + a; key position
    # = (i-1)*w + t for t<w else i*w + (t-w).  Causality: key <= query;
    # window: key > query - w.
    a_idx = jnp.arange(w)[:, None]           # query offset in block
    t_idx = jnp.arange(2 * w)[None, :] - w   # key offset relative to block start
    rel = a_idx - t_idx                      # query_pos - key_pos
    mask = (rel >= 0) & (rel < w)
    # Block 0 has no previous block; also mask padded tail positions.
    block_ids = jnp.arange(nb)
    first_block = block_ids[:, None, None] == 0
    prev_key = t_idx < 0
    mask_b = mask[None] & ~(first_block & prev_key[None])  # (nb,W,2W)
    q_global = block_ids[:, None] * w + jnp.arange(w)[None]  # (nb,W)
    valid_q = q_global < s
    k_global = block_ids[:, None] * w + t_idx  # (nb, 2W)
    valid_k = (k_global >= 0) & (k_global < s)
    mask_b = mask_b & valid_k[:, None, :] & valid_q[..., None]
    scores = jnp.where(mask_b[None, :, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bnkrst,bntkd->bnskrd", probs.astype(v2.dtype), v2)
    out = out.reshape(b, s_pad, h, d)[:, :s]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    pos: jax.Array,
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against a KV cache.

    q: (B, 1, H, D); caches: (B, T, KV, D); ``pos`` — current position
    (scalar int32): cache entries at indices <= pos are valid.  ``window``
    masks entries older than pos - window + 1 (ring-buffer caches pass the
    physical layout; masking is on logical positions stored alongside).
    """
    b, _, h, d = q.shape
    t, kv = k_cache.shape[1], k_cache.shape[2]
    q = constrain(q, ("batch", None, "heads", None))
    k_cache = constrain(k_cache, ("batch", "cache_seq", "kv_heads", None))
    v_cache = constrain(v_cache, ("batch", "cache_seq", "kv_heads", None))
    r = h // kv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    qg = (q * scale).reshape(b, 1, kv, r, d)
    scores = _gqa_scores(qg, k_cache)[..., 0, :]  # (B,KV,R,T)
    kv_pos = jnp.arange(t)
    mask = kv_pos <= pos
    if window is not None:
        mask = mask & (kv_pos > pos - window)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrt,btkd->bkrd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Feed-forward activations
# ---------------------------------------------------------------------------


def swiglu(x_gate: jax.Array, x_up: jax.Array) -> jax.Array:
    return jax.nn.silu(x_gate) * x_up


def relu2(x: jax.Array) -> jax.Array:
    """Squared ReLU (Minitron/Nemotron)."""
    y = jax.nn.relu(x)
    return y * y


ACTIVATIONS: Dict[str, Callable] = {
    "gelu": jax.nn.gelu,
    "relu2": relu2,
    "silu": jax.nn.silu,
}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def weighted_cross_entropy(
    logits: jax.Array, labels: jax.Array, weights: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """Token-level CE.  ``weights`` (same shape as labels) realizes Eq. (9)
    weighted gradient aggregation: pass per-sample weights broadcast over the
    sequence dim; pads get 0.  Returns (scalar weighted-SUM loss, total
    weight) — divide outside if a mean is wanted.
    """
    logits_f = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits_f, axis=-1)
    gold = jnp.take_along_axis(logits_f, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if weights is None:
        weights = jnp.ones_like(nll)
    return (nll * weights).sum(), weights.sum()


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------


def make_kv_cache(
    n_layers: int,
    batch: int,
    length: int,
    kv_heads: int,
    head_dim: int,
    dtype: jnp.dtype = jnp.bfloat16,
) -> Dict[str, jax.Array]:
    """Stacked-over-layers KV cache + scalar position."""
    shape = (n_layers, batch, length, kv_heads, head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_update(
    cache_layer_k: jax.Array,
    cache_layer_v: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    pos: jax.Array,
    *,
    ring: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Insert one token's K/V at position ``pos`` (mod length if ring)."""
    length = cache_layer_k.shape[1]
    idx = jnp.where(ring, pos % length, pos) if ring else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache_layer_k, k_new, idx, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache_layer_v, v_new, idx, axis=1)
    return k, v

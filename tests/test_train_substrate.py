"""Substrate tests: optimizers, gradient-accumulation exactness, data
pipeline invariants, checkpoint round-trip, hetero trainer epoch."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st

pytestmark = pytest.mark.slow  # JAX-compiling substrate tests

from repro.configs import get_api
from repro.core import CannikinController, SimulatedCluster, cluster_A
from repro.data import HeteroBatchPartitioner, SyntheticLM
from repro.optim import adamw, constant_schedule, cosine_schedule, global_norm, sgd
from repro.train import HeteroTrainer, restore, save
from repro.train.step import build_train_step


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def quad_params():
    return {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}


def quad_loss(p):
    return jnp.sum(p["w"] ** 2) + p["b"] ** 2


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(constant_schedule(0.1), momentum=0.9),
    lambda: adamw(constant_schedule(0.05), weight_decay=0.0),
])
def test_optimizers_converge_on_quadratic(make_opt):
    opt = make_opt()
    p = quad_params()
    state = opt.init(p)
    for _ in range(200):
        g = jax.grad(quad_loss)(p)
        p, state = opt.update(g, state, p)
    assert quad_loss(p) < 1e-3


def test_cosine_schedule_shape():
    sched = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.int32(0))) == pytest.approx(0.0)
    assert float(sched(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(sched(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


def test_lr_scale_applied():
    opt = sgd(constant_schedule(0.1), momentum=0.0)
    p = {"w": jnp.array(1.0)}
    s = opt.init(p)
    p1, _ = opt.update({"w": jnp.array(1.0)}, s, p, jnp.float32(1.0))
    p2, _ = opt.update({"w": jnp.array(1.0)}, s, p, jnp.float32(3.0))
    assert float(p["w"] - p2["w"]) == pytest.approx(3 * float(p["w"] - p1["w"]))


# ---------------------------------------------------------------------------
# gradient accumulation
# ---------------------------------------------------------------------------


def test_microbatch_accumulation_matches_full_batch():
    api = get_api("olmo-1b", reduced=True)
    rng = jax.random.PRNGKey(0)
    params = api.init(rng)
    B, S = 8, 16
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, api.cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, api.cfg.vocab),
        "weights": jnp.array([1.0, 2.0, 1.0, 0.5, 1.0, 1.0, 3.0, 1.0], jnp.float32),
    }
    opt = sgd(constant_schedule(0.5), momentum=0.0, max_grad_norm=None)
    step1 = jax.jit(build_train_step(api, opt, microbatches=1))
    step4 = jax.jit(build_train_step(api, opt, microbatches=4))
    s0 = opt.init(params)
    p1, _, m1 = step1(params, s0, batch)
    p4, _, m4 = step4(params, s0, batch)
    assert float(m1["loss"]) == pytest.approx(float(jnp.sum(m4["loss"])), rel=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=3e-3
        )


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_synthetic_lm_deterministic_and_learnable():
    data = SyntheticLM(vocab=64, seq_len=16, seed=3)
    b1 = data.batch(5, 8)
    b2 = data.batch(5, 8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels mostly follow the rule: next token = rule[token]
    match = (data.rule[b1["tokens"]] == b1["labels"]).mean()
    assert match > 0.5


@hypothesis.given(st.lists(st.integers(1, 40), min_size=2, max_size=6))
@hypothesis.settings(max_examples=50, deadline=None)
def test_partitioner_invariants(sizes):
    total = sum(sizes)
    data = SyntheticLM(vocab=32, seq_len=8, seed=0)
    batch = data.batch(0, total)
    nodes = HeteroBatchPartitioner.split(batch, sizes)
    assert [n.size for n in nodes] == sizes
    # Concatenation reconstructs the global batch exactly.
    recon = np.concatenate([n.tokens for n in nodes], axis=0)
    np.testing.assert_array_equal(recon, batch["tokens"])
    padded, weights = HeteroBatchPartitioner.padded(batch, sizes)
    assert padded["tokens"].shape[0] == len(sizes)
    assert weights.sum() == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    api = get_api("rwkv6-7b", reduced=True)
    params = api.init(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ckpt.npz")
    save(path, params)
    restored = restore(path, params)
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    api = get_api("olmo-1b", reduced=True)
    params = api.init(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ckpt.npz")
    save(path, params)
    other = get_api("llama3-8b", reduced=True).init(jax.random.PRNGKey(0))
    with pytest.raises((ValueError, KeyError)):
        restore(path, other)


# ---------------------------------------------------------------------------
# hetero trainer end-to-end
# ---------------------------------------------------------------------------


def test_hetero_trainer_loss_decreases_and_predicts():
    api = get_api("olmo-1b", reduced=True)
    profiles, comm = cluster_A()
    sim = SimulatedCluster(profiles, comm, noise=0.01, seed=0)
    data = SyntheticLM(vocab=api.cfg.vocab, seq_len=24, seed=0)
    ctrl = CannikinController(sim.n, batch_candidates=[24, 48], ref_batch=24)
    tr = HeteroTrainer(
        api, sgd(constant_schedule(0.3)), sim, ctrl, data, steps_per_epoch=4
    )
    hist = tr.run(6)
    assert hist[-1].mean_loss < hist[0].mean_loss
    optperf_epochs = [h for h in hist if h.phase == "optperf"]
    assert optperf_epochs, "controller never left bootstrap"
    for h in optperf_epochs:
        err = abs(h.predicted_batch_time - h.measured_batch_time) / h.measured_batch_time
        assert err < 0.07, f"epoch {h.epoch}: OptPerf prediction error {err:.1%}"

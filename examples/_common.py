"""Shared example bootstrap: make ``repro`` importable when an example is
run straight from a checkout (``python examples/<name>.py``) without
installing the package or exporting PYTHONPATH."""
import os
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

"""olmo-1b [dense] — 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304,
non-parametric LayerNorm [arXiv:2402.00838]."""
import jax.numpy as jnp

from repro.models.dense import DenseConfig

ARCH_ID = "olmo-1b"


def config() -> DenseConfig:
    return DenseConfig(
        name=ARCH_ID,
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab=50304,
        head_dim=128,
        rope_theta=10000.0,
        act="swiglu",
        norm="nonparam_ln",
        decode_window=8192,
    )


def reduced() -> DenseConfig:
    return DenseConfig(
        name=ARCH_ID + "-reduced",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab=512,
        head_dim=32,
        norm="nonparam_ln",
        decode_window=64,
        remat=False,
        # The reduced config is the numerics-equivalence vehicle (Eq. 9
        # aggregation, grad-accumulation identities): verify in float32 so
        # mathematically exact identities are assertable; bf16 rounding of
        # the full-scale config is exercised by the other arch configs.
        param_dtype=jnp.float32,
        compute_dtype=jnp.float32,
    )

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing harness: lower+compile one (arch x shape) pair under a
named variant, record the roofline terms, and append to the iteration log.

Run each variant in a FRESH process (device count is locked at jax init):

  PYTHONPATH=src:. python -m benchmarks.hillclimb --arch deepseek-v2-236b \
      --shape train_4k --variant ep_experts

Variants are small, surgical configuration changes (sharding axis, chunk
size, optimizer-state sharding, microbatch count) — the §Perf methodology's
"candidate changes".  Results: artifacts/perf/<arch>__<shape>__<variant>.json
"""
import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.configs import SHAPES, get_api
from repro.launch import dryrun as dr
from repro.launch.hlo_stats import analyze_hlo
from repro.launch.mesh import make_production_mesh, make_rules
from repro.sharding.context import sharding_context

ARTDIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                      "artifacts", "perf")


def apply_variant(name: str, api, rules, mesh_kind: str):
    """Mutates api.cfg / rules / launch knobs per variant; returns notes.
    Compound variants compose with "+": e.g. "ep_experts+kvchunk2048"."""
    import dataclasses

    if "+" in name:
        notes = {}
        for part in name.split("+"):
            api, rules, n = apply_variant(part, api, rules, mesh_kind)
            for k, v in n.items():
                notes[k] = (notes.get(k, "") + " | " + str(v)) if k == "change" and k in notes else v
        return api, rules, notes

    notes = {}
    if name == "baseline":
        return api, rules, notes
    if name == "kvchunk2048":
        api = dataclasses.replace(api, cfg=dataclasses.replace(api.cfg, kv_chunk=2048))
        notes["change"] = "attention kv-chunk 512 -> 2048 (4x fewer acc re-streams)"
        return api, rules, notes
    if name == "kvchunk4096":
        api = dataclasses.replace(api, cfg=dataclasses.replace(api.cfg, kv_chunk=4096))
        notes["change"] = "attention kv-chunk 512 -> 4096"
        return api, rules, notes
    if name == "zero1":
        notes["change"] = "optimizer moments + f32 accum sharded over data (ZeRO-1)"
        notes["opt_zero1"] = True
        return api, rules, notes
    if name == "ep_experts":
        rules = dataclasses.replace(rules, experts_axis="model", fallbacks=[])
        notes["change"] = (
            "expert-parallel: experts sharded over model axis; expert weights "
            "(E@model, d, ff); dispatch crosses model instead of re-sharding "
            "capacity over data"
        )
        return api, rules, notes
    if name == "mb8":
        notes["change"] = "microbatches 16 -> 8"
        notes["microbatches"] = 8
        return api, rules, notes
    if name == "mb32":
        notes["change"] = "microbatches 16 -> 32"
        notes["microbatches"] = 32
        return api, rules, notes
    if name == "cap1.0":
        import repro.models.deepseek  # noqa
        api = dataclasses.replace(
            api, cfg=dataclasses.replace(api.cfg, capacity_factor=1.0)
        )
        notes["change"] = "MoE capacity factor 1.25 -> 1.0"
        return api, rules, notes
    if name in ("ssmchunk32", "ssmchunk128"):
        import dataclasses as dc
        c = int(name.replace("ssmchunk", ""))
        api = dc.replace(api, cfg=dc.replace(api.cfg, ssm_chunk=c))
        notes["change"] = f"selective-scan chunk 64 -> {c}"
        return api, rules, notes
    if name == "wkvchunk64":
        import dataclasses as dc
        api = dc.replace(api, cfg=dc.replace(api.cfg, wkv_chunk=64))
        notes["change"] = "WKV chunk 32 -> 64"
        return api, rules, notes
    if name == "no_moe_constrain":
        from repro.models import moe as _m
        _m.CONSTRAIN_DISPATCH = False
        notes["change"] = "drop expert-buffer sharding constraints (GSPMD chooses)"
        return api, rules, notes
    if name == "gqa_repeat":
        from repro.models import common as _c
        _c.GQA_REPEAT = True
        notes["change"] = "repeat KV to full heads before scores (keeps head sharding)"
        return api, rules, notes
    if name == "fsdp":
        import dataclasses as dc
        rules = dc.replace(rules, fsdp_axis="data", fallbacks=[])
        notes["change"] = "FSDP: d_model dim of large params sharded over data"
        return api, rules, notes
    if name == "mb4":
        notes["change"] = "microbatches -> 4"
        notes["microbatches"] = 4
        return api, rules, notes
    if name == "mb2":
        notes["change"] = "microbatches -> 2"
        notes["microbatches"] = 2
        return api, rules, notes
    if name == "cache_model_only":
        rules = dataclasses.replace(rules, cache_seq_axes=("model",), fallbacks=[])
        notes["change"] = "decode cache seq sharded over model only (not data)"
        return api, rules, notes
    if name == "cache_data_only":
        rules = dataclasses.replace(rules, cache_seq_axes=("data",), fallbacks=[])
        notes["change"] = "decode cache seq sharded over data only"
        return api, rules, notes
    raise ValueError(f"unknown variant {name!r}")


def run_pair(arch: str, shape_name: str, variant: str, mesh_kind: str = "single") -> Dict:
    shape = SHAPES[shape_name]
    api = get_api(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = make_rules(mesh, arch, kind=shape.kind, global_batch=shape.global_batch)
    api, rules, notes = apply_variant(variant, api, rules, mesh_kind)

    # Optional launch-knob overrides.
    if "microbatches" in notes:
        dr_mb = dr.train_microbatches
        dr.train_microbatches = lambda a: notes["microbatches"]  # type: ignore
    if notes.get("opt_zero1"):
        orig_opt_specs = dr._opt_specs

        def zero1_specs(opt_sds, pspecs):
            from jax.sharding import PartitionSpec as P

            base = orig_opt_specs(opt_sds, pspecs)

            def shard_over_data(spec, sds):
                if not hasattr(sds, "shape") or sds.shape == ():
                    return spec
                parts = list(spec) + [None] * (len(sds.shape) - len(spec))
                for i, (p, dim) in enumerate(zip(parts, sds.shape)):
                    if p is None and dim % rules.mesh_axes["data"] == 0:
                        parts[i] = "data"
                        break
                return P(*parts)

            return jax.tree_util.tree_map(
                shard_over_data, base, opt_sds,
                is_leaf=lambda x: isinstance(x, P),
            )

        dr._opt_specs = zero1_specs  # type: ignore

    t0 = time.time()
    fn, args, in_sh, out_sh = dr.build_dryrun(api, shape, mesh, rules)
    with jax.set_mesh(mesh), sharding_context(mesh, rules):
        compiled = (
            jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            .lower(*args)
            .compile()
        )
    stats = analyze_hlo(compiled.as_text())
    mem = dr._memory_analysis_dict(compiled)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "variant": variant,
        "notes": notes,
        "compile_seconds": round(time.time() - t0, 1),
        "hlo": stats.as_dict(),
        "memory": mem,
        "terms": {
            "compute_s": stats.flops / PEAK_FLOPS,
            "memory_s": stats.bytes_accessed / HBM_BW,
            "collective_s": stats.collective_bytes / ICI_BW,
        },
    }
    os.makedirs(ARTDIR, exist_ok=True)
    out = os.path.join(ARTDIR, f"{arch}__{shape_name}__{variant}.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    t = rec["terms"]
    print(
        f"[{variant:16s}] {arch} {shape_name}: compute={t['compute_s']:.3e}s "
        f"memory={t['memory_s']:.3e}s collective={t['collective_s']:.3e}s "
        f"(compile {rec['compile_seconds']}s)",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    run_pair(args.arch, args.shape, args.variant, args.mesh)


if __name__ == "__main__":
    main()

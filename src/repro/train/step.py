"""Train / serve step builders.

`build_train_step` produces the function the launcher pjit-compiles:
forward + backward (+ optional gradient accumulation over microbatches) +
optimizer update.  Per-sample weights flow through the loss so a single
SPMD step over the padded-uneven global batch realizes the paper's Eq. (9)
weighted gradient aggregation exactly (see core/aggregation.py).

Gradient accumulation normalizes every microbatch by the *global* weight
sum, so the accumulated gradient equals the unaccumulated one bit-for-bit
in exact arithmetic (tests/test_train_step.py checks this numerically).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import ModelApi
from repro.optim.optimizers import Optimizer, global_norm

PyTree = Any

__all__ = [
    "build_train_step",
    "build_serve_step",
    "build_prefill_step",
    "node_step_specs",
]


def node_step_specs(rules) -> Dict[str, Any]:
    """PartitionSpecs for the RealBackend's padded per-node batch layout.

    The sharded per-node step lays data out as (n, b_max, seq) with the
    leading node dim split over the ``nodes`` mesh axis; params and the
    per-node ratio/validity vectors that feed ``guard_weights`` stay
    replicated (the guard needs the full (n,) view on every shard).
    """
    return {
        "tokens": rules.spec(["nodes", None, None]),
        "labels": rules.spec(["nodes", None, None]),
        "mask": rules.spec(["nodes", None]),
        "node_vec": rules.spec(["nodes"]),
        "replicated": rules.spec([]),
    }


def _global_denom(batch: Dict[str, jax.Array]) -> jax.Array:
    labels = batch["labels"]
    if "weights" in batch and batch["weights"] is not None:
        return jnp.maximum(batch["weights"].sum().astype(jnp.float32), 1e-9)
    return jnp.float32(labels.size / labels.shape[-1])


def build_train_step(
    api: ModelApi,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
    with_metrics: bool = True,
    microbatch_shardings: Optional[Dict[str, Any]] = None,
) -> Callable:
    """Returns step(params, opt_state, batch, lr_scale) ->
    (params, opt_state, metrics).

    ``microbatch_shardings``: {input name: NamedSharding} applied to every
    microbatch inside the accumulation scan.  Without it GSPMD loses the
    batch-axis sharding through the (B,) -> (M, B/M) reshape and re-shards
    activations onto far fewer devices (observed: 8x FLOPs/device on the
    dry-run) — see EXPERIMENTS.md §Perf iteration 0.
    """

    def loss_fn(params, mb, denom):
        loss, aux = api.loss(params, mb, denom=denom)
        return loss, aux

    def step(params, opt_state, batch, lr_scale=jnp.float32(1.0)):
        seq = batch["labels"].shape[-1]
        denom = _global_denom(batch) * seq

        if microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, denom
            )
        else:
            def reshape(x):
                b = x.shape[0]
                if b % microbatches:
                    raise ValueError(
                        f"batch {b} not divisible by microbatches {microbatches}"
                    )
                return x.reshape((microbatches, b // microbatches) + x.shape[1:])

            mbs = {k: reshape(v) for k, v in batch.items()}
            zero_grads = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def accum(carry, mb):
                g_acc, l_acc = carry
                if microbatch_shardings is not None:
                    mb = {
                        k: (
                            jax.lax.with_sharding_constraint(v, microbatch_shardings[k])
                            if k in microbatch_shardings
                            else v
                        )
                        for k, v in mb.items()
                    }
                (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb, denom
                )
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + loss), aux

            (grads, loss), auxs = jax.lax.scan(accum, (zero_grads, 0.0), mbs)
            aux = {k: v.mean() for k, v in auxs.items()}

        new_params, new_opt = optimizer.update(grads, opt_state, params, lr_scale)
        metrics = {"loss": loss}
        if with_metrics:
            metrics["grad_norm"] = global_norm(grads)
            metrics.update({f"aux/{k}": v for k, v in aux.items()})
        return new_params, new_opt, metrics

    return step


def build_serve_step(api: ModelApi) -> Callable:
    """One-token decode: step(params, cache, tokens, pos) -> (logits, cache)."""

    def step(params, cache, tokens, pos):
        return api.decode_step(params, cache, tokens, pos)

    return step


def build_prefill_step(api: ModelApi) -> Callable:
    """Full-sequence forward (no loss): step(params, batch) -> logits."""

    def step(params, batch):
        return api.logits(params, batch)

    return step

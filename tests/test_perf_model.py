"""Performance-model learning: OLS fits, inverse-variance gamma weighting,
T_comm min-aggregation, Eq. (8) bootstrap, and end-to-end model recovery
from noisy simulated measurements (§4.5 / §5.3)."""
import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st

from repro.core.perf_model import (
    GammaAggregator,
    NodeObservation,
    OnlineNodeFitter,
    bootstrap_partition,
    fit_linear,
    inverse_variance_weight,
)
from repro.core.optperf import solve_optperf_algorithm1
from repro.core.simulator import SimulatedCluster, cluster_A, cluster_B


def test_fit_linear_exact():
    slope, intercept = fit_linear([1, 2, 3, 4], [3, 5, 7, 9])
    assert slope == pytest.approx(2.0)
    assert intercept == pytest.approx(1.0)
    with pytest.raises(ValueError):
        fit_linear([2, 2], [1, 2])


@hypothesis.given(
    st.lists(st.floats(-5, 5), min_size=2, max_size=6),
    st.lists(st.floats(0.01, 10.0), min_size=2, max_size=6),
)
@hypothesis.settings(max_examples=80, deadline=None)
def test_ivw_bounds_and_optimality(means, variances):
    n = min(len(means), len(variances))
    means, variances = means[:n], variances[:n]
    est = inverse_variance_weight(means, variances)
    assert min(means) - 1e-9 <= est <= max(means) + 1e-9
    # IVW leans toward the lowest-variance observation.
    best = int(np.argmin(variances))
    naive = float(np.mean(means))
    if variances[best] * 10 < min(v for i, v in enumerate(variances) if i != best):
        assert abs(est - means[best]) <= abs(naive - means[best]) + 1e-9


def test_ivw_infinite_variance_ignored():
    est = inverse_variance_weight([1.0, 100.0], [0.1, float("inf")])
    assert est == pytest.approx(1.0)
    # all-infinite falls back to the mean
    est = inverse_variance_weight([1.0, 3.0], [float("inf"), float("inf")])
    assert est == pytest.approx(2.0)


def test_bootstrap_partition_inverse_proportional():
    b = bootstrap_partition([1.0, 2.0, 4.0], 70)
    assert sum(b) == pytest.approx(70)
    assert b[0] == pytest.approx(40)
    assert b[1] == pytest.approx(20)
    assert b[2] == pytest.approx(10)


def test_fitter_recovers_linear_model():
    fitter = OnlineNodeFitter()
    q, s, k, m = 2e-3, 0.01, 3e-3, 0.008
    for b in (8, 16, 32, 64):
        fitter.add(
            NodeObservation(
                batch_size=b, a_time=q * b + s, backprop_time=k * b + m,
                gamma=0.15, comm_time=0.05,
            )
        )
    model = fitter.fit()
    assert model.q == pytest.approx(q, rel=1e-6)
    assert model.k == pytest.approx(k, rel=1e-6)
    assert model.s == pytest.approx(s, rel=1e-6)
    assert model.m == pytest.approx(m, rel=1e-6)


def test_gamma_aggregator_prefers_stable_nodes():
    noisy, stable = OnlineNodeFitter(), OnlineNodeFitter()
    rng = np.random.default_rng(0)
    for i, b in enumerate((8, 16, 32, 64, 128)):
        noisy.add(NodeObservation(b, 0.01 * b, 0.02 * b, 0.15 + rng.normal(0, 0.1), 0.05))
        stable.add(NodeObservation(b, 0.01 * b, 0.02 * b, 0.15 + rng.normal(0, 0.002), 0.05))
    agg = GammaAggregator({0: noisy, 1: stable})
    gamma = agg.gamma()
    assert abs(gamma - 0.15) < 0.02  # dominated by the stable node


def test_prediction_error_with_learned_models():
    """§5.3 analogue: learn models from noisy measurements over a few epochs,
    then check the OptPerf prediction error against the noise-free cluster."""
    profiles, comm = cluster_B()
    sim = SimulatedCluster(profiles, comm, noise=0.02, seed=1)
    fitters = {i: OnlineNodeFitter() for i in range(sim.n)}
    rng = np.random.default_rng(0)
    for epoch in range(5):
        batches = [int(rng.integers(8, 64)) for _ in range(sim.n)]
        _, ms = sim.run_epoch(batches, steps=6)
        for i in range(sim.n):
            obs = [m.observations[i] for m in ms]
            fitters[i].add(
                NodeObservation(
                    batch_size=batches[i],
                    a_time=float(np.mean([o.a_time for o in obs])),
                    backprop_time=float(np.mean([o.backprop_time for o in obs])),
                    gamma=float(np.mean([o.gamma for o in obs])),
                    comm_time=float(np.min([o.comm_time for o in obs])),
                )
            )
    from repro.core.perf_model import ClusterPerfModel, CommModel

    agg = GammaAggregator(fitters)
    learned = ClusterPerfModel(
        nodes=tuple(fitters[i].fit() for i in range(sim.n)),
        comm=CommModel(t_o=comm.t_o, t_u=comm.t_u, gamma=agg.gamma()),
    )
    truth = sim.true_model()
    for B in (256, 512, 1024):
        pred = solve_optperf_algorithm1(learned, B)
        actual = truth.cluster_time(list(pred.batches))
        best = solve_optperf_algorithm1(truth, B).opt_perf
        # Prediction within 7% of realized time (paper §5.3), and the
        # realized time within 7% of the true optimum.
        assert abs(pred.opt_perf - actual) / actual < 0.07
        assert (actual - best) / best < 0.07

"""Fig. 10 reproduction: normalized batch processing time vs total batch
size on cluster B — OptPerf (Cannikin) vs LB-BSP-converged vs DDP-even, in
fixed and adaptive-batch regimes.

The five Table-4 workloads are modeled as workload_scale multipliers on the
per-sample compute coefficients (model size drives compute/comm balance);
T_comm scales with model parameter size.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import Row, save_json
from repro.core.optperf import solve_optperf_batch
from repro.core.simulator import SimulatedCluster, cluster_B

# (workload, compute scale, comm scale) — relative to ResNet-50 defaults.
WORKLOADS = {
    "resnet50-imagenet": (1.0, 1.0),
    "resnet18-cifar10": (0.12, 0.45),
    "deepspeech2-librispeech": (1.6, 2.0),
    "bert-squad": (2.2, 4.3),
    "neumf-movielens": (0.05, 0.2),
}


def lbbsp_converged(model, total):
    """LB-BSP's fixed point equalizes *compute* times (ignores overlap)."""
    alphas = np.array([n.alpha for n in model.nodes])
    cs = np.array([n.c for n in model.nodes])
    inv = 1.0 / alphas
    mu = (total + (cs * inv).sum()) / inv.sum()
    batches = np.maximum((mu - cs) * inv, 0)
    batches *= total / batches.sum()
    return [float(b) for b in batches]


def run() -> List[Row]:
    rows: List[Row] = []
    payload: Dict = {}
    for wl, (cscale, mscale) in WORKLOADS.items():
        profiles, comm = cluster_B(
            workload_scale=cscale, t_o=0.045 * mscale, t_u=0.009 * mscale
        )
        sim = SimulatedCluster(profiles, comm, noise=0.0, seed=0)
        truth = sim.true_model()
        curve = {}
        batch_sizes = (128, 256, 512, 1024, 2048)
        # One array pass solves OptPerf for the whole batch-size curve.
        opts = solve_optperf_batch(truth, [float(B) for B in batch_sizes])
        for j, B in enumerate(batch_sizes):
            t_opt = truth.cluster_time(list(opts.batches[j]))
            t_even = truth.cluster_time([B / sim.n] * sim.n)
            t_lb = truth.cluster_time(lbbsp_converged(truth, B))
            # Adaptive regime: LB-BSP re-tunes from even after a batch change
            # and has moved only delta*1 samples — approximately even.
            t_lb_adaptive = truth.cluster_time(
                [b + (e - b) * 0.9 for b, e in zip(lbbsp_converged(truth, B), [B / sim.n] * sim.n)]
            )
            curve[B] = {
                "optperf": t_opt,
                "even": t_even,
                "lbbsp_fixed": t_lb,
                "lbbsp_adaptive": t_lb_adaptive,
            }
        payload[wl] = curve
        gains_lb = [1 - c["optperf"] / c["lbbsp_fixed"] for c in curve.values()]
        gains_even = [1 - c["optperf"] / c["even"] for c in curve.values()]
        rows.append(
            Row(
                f"fig10/{wl}",
                0.0,
                f"vs_lbbsp_max={max(gains_lb):.1%};vs_even_max={max(gains_even):.1%}",
            )
        )
    save_json("batchtime_fig10", payload)
    return rows

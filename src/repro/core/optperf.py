"""OptPerf: the optimal batch-partition / batch-time solver (§3.3, §4.2, App. A).

Three solvers are provided:

``solve_optperf_algorithm1``
    Paper-faithful Algorithm 1: closed-form Check 1 (all compute-bottleneck),
    Check 2 (all communication-bottleneck), then a binary search over the
    bottleneck boundary for the mixed case.  O(n) per candidate boundary
    (the "linear system" of the paper is diagonal once the partition is
    fixed, so we solve it directly rather than with a generic O(n^3) solve).
    Kept as the independent cross-check oracle for the array engine below.

``solve_optperf_waterfill``
    Beyond-paper oracle: the node batch time
        T_i(b) = max(t_compute_i(b) + T_u, syncStart_i(b) + T_comm)
    is strictly increasing in b, so for a target cluster time T each node has
    a maximal feasible batch
        b_i(T) = min((T - T_u - c_i)/alpha_i, (T - T_comm - d_i)/beta_i)
    and Sum_i max(b_i(T), 0) is continuous and nondecreasing in T.  Bisection
    on T yields the exact optimum including b_i >= 0 clamping that
    Algorithm 1's linear solves ignore.  Implemented as the single-candidate
    special case of the batched engine.

``solve_optperf_batch``
    The batched water-fill engine: solves OptPerf for *all* candidate total
    batch sizes of a goodput sweep simultaneously.  The bisection state is a
    ``(num_candidates,)`` vector of ``[lo, hi]`` brackets refined against a
    ``(num_candidates, n)`` feasible-batch matrix, so the whole sweep costs
    O(max_iter) NumPy broadcasts — ~200 array ops total regardless of the
    candidate count — instead of ``num_candidates * max_iter * n``
    Python-level evaluations.  Complexity: O(max_iter * C * n) flops, O(C * n)
    memory, zero Python-level per-node or per-candidate work in the hot loop.

    ``warm_start`` (incremental re-bracketing): pass the previous epoch's
    ``t_stars`` vector and the solver runs a *safeguarded Newton* refinement
    from it instead of cold bracketing + full bisection.  g(T) is monotone
    piecewise-affine, so when perf-model drift is small the active set is
    unchanged and the first Newton step lands on the new optimum exactly —
    a handful of array passes replace ~50.  Every Newton iterate also tightens
    a true [lo, hi] bracket, and anything not converged falls through to
    standard bisection, so a stale or even garbage warm start still converges
    to the identical solution.

``solve_optperf_stacked``
    The same engine over a :class:`~repro.core.perf_model.StackedClusterModel`
    — C *independent* problem rows (each row its own node subset + comm
    model, padded to a common width).  This is what lets the multi-job
    scheduler evaluate every (job, candidate-node) marginal goodput of a
    greedy round in one array pass.

All coefficient access goes through :attr:`ClusterPerfModel.coeffs`, the
cached array view (precomputed alphas/cs/betas/ds/backprop vectors; the model
dataclass is frozen so the cache can never go stale).  A third, jit-compiled
engine that runs the same bisection on-device lives in
:mod:`repro.core.optperf_jax`.

Scalar solvers return an :class:`OptPerfSolution`; the batched/stacked
engines return a :class:`BatchedOptPerfSolution`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, NamedTuple, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.perf_model import ClusterPerfModel, StackedClusterModel

__all__ = [
    "OptPerfSolution",
    "BatchedOptPerfSolution",
    "solve_optperf_algorithm1",
    "solve_optperf_algorithm1_batch",
    "solve_optperf_waterfill",
    "solve_optperf_waterfill_subset",
    "solve_optperf_waterfill_subsets",
    "solve_optperf_batch",
    "solve_optperf_stacked",
    "solve_optperf",
    "round_batches",
]


@dataclasses.dataclass(frozen=True)
class OptPerfSolution:
    """Solution of the OptPerf problem for one total batch size."""

    total_batch: float
    opt_perf: float                    # minimized cluster batch time (seconds)
    batches: Tuple[float, ...]         # optimal (real-valued) local batches
    bottleneck: Tuple[str, ...]        # per node: "compute" | "comm"
    method: str                        # solver that produced this

    @property
    def ratios(self) -> Tuple[float, ...]:
        return tuple(b / self.total_batch for b in self.batches)

    def summary(self) -> str:
        parts = ", ".join(
            f"b{i}={b:.1f}({s[:4]})" for i, (b, s) in enumerate(zip(self.batches, self.bottleneck))
        )
        return f"OptPerf={self.opt_perf * 1e3:.3f}ms B={self.total_batch:g} [{parts}]"


@dataclasses.dataclass(frozen=True)
class BatchedOptPerfSolution:
    """OptPerf solutions for a whole vector of candidate total batch sizes.

    ``batches`` is ``(C, n)``; ``total_batches``/``opt_perfs`` are ``(C,)``;
    ``compute_mask`` is the ``(C, n)`` boolean overlap state (True = the node
    is compute-bottleneck at that candidate's optimum).

    ``t_stars`` is the bisected cluster-time bound per candidate — the warm
    start for the next epoch's solve.  ``iterations`` counts feasible-batch
    array passes spent (observability: warm-started solves should use a
    handful where cold ones use ~50).  ``node_mask`` is ``None`` for the
    single-model engines; for stacked solves it marks real (non-padding)
    slots per row and extraction respects it.
    """

    total_batches: np.ndarray
    opt_perfs: np.ndarray
    batches: np.ndarray
    compute_mask: np.ndarray
    method: str
    t_stars: Optional[np.ndarray] = None
    iterations: int = 0
    node_mask: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return int(self.total_batches.shape[0])

    def _valid(self, j: int) -> np.ndarray:
        if self.node_mask is None:
            return np.arange(self.batches.shape[1])
        return np.flatnonzero(self.node_mask[j])

    def bottleneck(self, j: int) -> Tuple[str, ...]:
        return tuple(
            "compute" if self.compute_mask[j, i] else "comm" for i in self._valid(j)
        )

    def solution(self, j: int, *, method: Optional[str] = None) -> OptPerfSolution:
        """Extract candidate/row ``j`` as a scalar :class:`OptPerfSolution`
        (padding slots of stacked solves are dropped)."""
        return OptPerfSolution(
            total_batch=float(self.total_batches[j]),
            opt_perf=float(self.opt_perfs[j]),
            batches=tuple(float(self.batches[j, i]) for i in self._valid(j)),
            bottleneck=self.bottleneck(j),
            method=method or self.method,
        )

    def solutions(self) -> List[OptPerfSolution]:
        return [self.solution(j) for j in range(len(self))]


# ---------------------------------------------------------------------------
# helpers (all pure NumPy over the cached coefficient view)
# ---------------------------------------------------------------------------


def _solve_equal_compute(model: ClusterPerfModel, total_batch: float) -> Tuple[float, np.ndarray]:
    """Check 1: equalize t_compute across all nodes.  mu is the common
    t_compute; b_i = (mu - c_i)/alpha_i."""
    c = model.coeffs
    inv = 1.0 / c.alphas
    mu = (total_batch + (c.cs * inv).sum()) / inv.sum()
    return float(mu), (mu - c.cs) * inv


def _solve_equal_syncstart(model: ClusterPerfModel, total_batch: float) -> Tuple[float, np.ndarray]:
    """Check 2: equalize syncStart across all nodes."""
    c = model.coeffs
    inv = 1.0 / c.betas
    mu = (total_batch + (c.ds * inv).sum()) / inv.sum()
    return float(mu), (mu - c.ds) * inv


def _solve_mixed(
    model: ClusterPerfModel,
    total_batch: float,
    compute_mask: np.ndarray,
) -> Tuple[float, np.ndarray]:
    """Mixed case (App. A.3): compute nodes satisfy t_compute_i = mu, comm
    nodes satisfy syncStart_i + T_o = mu; Sum b = B.  One masked reduction."""
    c = model.coeffs
    t_o = model.comm.t_o
    slope = np.where(compute_mask, c.alphas, c.betas)
    offset = np.where(compute_mask, c.cs, t_o + c.ds)
    inv = 1.0 / slope
    mu = (total_batch + (offset * inv).sum()) / inv.sum()
    return float(mu), (mu - offset) * inv


def _partition_valid(
    model: ClusterPerfModel,
    batches: np.ndarray,
    compute_mask: np.ndarray,
) -> bool:
    """The hypothesized overlap state must match the realized one, and all
    batches must be physically valid (>= 0)."""
    if batches.min() < 0:
        return False
    return bool(np.array_equal(model.compute_bottleneck_mask(batches), compute_mask))


# ---------------------------------------------------------------------------
# Algorithm 1 — paper-faithful (scalar cross-check oracle)
# ---------------------------------------------------------------------------


def solve_optperf_algorithm1(
    model: ClusterPerfModel,
    total_batch: float,
    *,
    boundary_hint: Optional[int] = None,
) -> OptPerfSolution:
    """Paper Algorithm 1: overlap-state determination + OptPerf configuration.

    ``boundary_hint`` seeds the mixed-case search with the boundary found for a
    neighbouring total batch size (§4.5 "Overlap state searching"): candidates
    are probed outward from the hint, which makes the epoch-over-epoch resolve
    O(1) boundary probes in the common case.
    """
    if total_batch <= 0:
        raise ValueError("total batch must be positive")
    model.validate()
    n = model.n
    t_u = model.comm.t_u

    # ---- Check 1: all nodes compute-bottleneck --------------------------
    # The paper's linear solves do not enforce b_i >= 0; with small total
    # batches a hopeless straggler can be assigned a negative batch.  Any
    # negative assignment invalidates the closed form and we fall through
    # to the clamped water-fill oracle (beyond-paper robustness; recorded
    # in EXPERIMENTS.md).
    mu_c, batches_c = _solve_equal_compute(model, total_batch)
    mask_c = model.compute_bottleneck_mask(batches_c)
    if batches_c.min() >= 0 and mask_c.all():
        return OptPerfSolution(
            total_batch=total_batch,
            opt_perf=mu_c + t_u,
            batches=tuple(float(b) for b in batches_c),
            bottleneck=("compute",) * n,
            method="algorithm1/check1",
        )

    # ---- Check 2: all nodes communication-bottleneck --------------------
    mu_s, batches_s = _solve_equal_syncstart(model, total_batch)
    mask_s = model.compute_bottleneck_mask(batches_s)
    if batches_s.min() >= 0 and not mask_s.any():
        return OptPerfSolution(
            total_batch=total_batch,
            opt_perf=mu_s + model.comm.t_comm,
            batches=tuple(float(b) for b in batches_s),
            bottleneck=("comm",) * n,
            method="algorithm1/check2",
        )

    # ---- Mixed bottleneck ------------------------------------------------
    # Nodes that are compute-bound under BOTH checks are certainly compute-
    # bound at the optimum; likewise for comm-bound.  The remaining
    # "outliers" are ordered and a boundary is binary-searched (§4.2).
    certain_compute = mask_c & mask_s
    certain_comm = ~mask_c & ~mask_s
    outliers = np.flatnonzero(~certain_compute & ~certain_comm)

    # Rank outliers by fixed processing time (the batch-independent part of
    # the node time); larger fixed time => more likely comm-bottleneck.
    fixed_times = model.coeffs.ds + model.comm.t_comm
    outliers = outliers[np.argsort(fixed_times[outliers], kind="stable")]

    def split_mask(split: int) -> np.ndarray:
        mask = certain_compute.copy()
        mask[outliers[:split]] = True
        return mask

    def try_boundary(split: int) -> Optional[Tuple[float, np.ndarray, np.ndarray]]:
        mask = split_mask(split)
        mu, batches = _solve_mixed(model, total_batch, mask)
        if _partition_valid(model, batches, mask):
            return mu, batches, mask
        return None

    # Probe order: hint (if any) first, then binary search, then exhaustive
    # fallback (robustness beyond the paper; n is small so this is cheap).
    candidates: List[int] = []
    if boundary_hint is not None:
        candidates.append(max(0, min(len(outliers), boundary_hint)))
    lo, hi = 0, len(outliers)
    while lo <= hi:
        mid = (lo + hi) // 2
        candidates.append(mid)
        # Direction: if solving with `mid` makes some hypothesized comm node
        # actually compute-bound, we put too few nodes on the compute side.
        mask = split_mask(mid)
        mu, batches = _solve_mixed(model, total_batch, mask)
        realized = model.compute_bottleneck_mask(batches)
        too_few_compute = bool(np.any(realized & ~mask))
        if too_few_compute:
            lo = mid + 1
        else:
            hi = mid - 1
    candidates.extend(range(len(outliers) + 1))

    seen = set()
    for split in candidates:
        if split in seen:
            continue
        seen.add(split)
        result = try_boundary(split)
        if result is None:
            continue
        mu, batches, mask = result
        return OptPerfSolution(
            total_batch=total_batch,
            opt_perf=mu + t_u,
            batches=tuple(float(b) for b in batches),
            bottleneck=tuple("compute" if c else "comm" for c in mask),
            method=f"algorithm1/mixed(split={split})",
        )

    # No consistent partition (can happen when the unconstrained solve drives
    # some b_i < 0): fall back to the clamped water-fill oracle.
    return solve_optperf_waterfill(model, total_batch)


def solve_optperf_algorithm1_batch(
    model: ClusterPerfModel,
    total_batches: Sequence[float],
    *,
    boundary_hint: Optional[int] = None,
) -> List[OptPerfSolution]:
    """Algorithm 1 over a whole candidate vector, closed forms vectorized.

    The batched ``boundary_hint`` analogue: Check 1 and Check 2 — the two
    closed-form boundary checks that resolve the overwhelming majority of a
    goodput sweep's candidates — are evaluated for *all* candidates in one
    array pass (the per-candidate scalar arithmetic is reproduced exactly:
    the reduction constants ``K = sum(offset/slope)`` and ``S = sum(1/slope)``
    are computed once and each candidate's ``mu = (B + K)/S`` and
    ``b = (mu - offset)/slope`` use the identical float operations, so every
    row is bit-equal to :func:`solve_optperf_algorithm1` on that candidate).
    Only the candidates both checks reject fall back to the scalar mixed-case
    search, chained through §4.5 boundary hints exactly like the scalar
    sweep: each candidate (closed-form or mixed) updates the hint for the
    next with its compute-node count.

    The scalar path stays the bit-exactness oracle — a seeded equivalence
    test pins ``solution_batch[i] == solution_scalar[i]`` field-for-field.
    """
    totals = [float(b) for b in total_batches]
    if any(b <= 0 for b in totals):
        raise ValueError("total batch must be positive")
    model.validate()
    n = model.n
    c = model.coeffs
    t_u = model.comm.t_u
    t_comm = model.comm.t_comm
    totals_arr = np.asarray(totals, dtype=np.float64)

    # Check 1 for every candidate: the scalar path computes
    # mu = (B + (cs*inv).sum()) / inv.sum() with inv = 1/alphas; hoisting the
    # two reductions out of the candidate loop leaves per-candidate work at
    # exactly one add, one divide, and one (mu - cs)*inv row — the same float
    # ops, now broadcast.
    inv_c = 1.0 / c.alphas
    k_c = (c.cs * inv_c).sum()
    s_c = inv_c.sum()
    mus_c = (totals_arr + k_c) / s_c
    batches_c = (mus_c[:, None] - c.cs) * inv_c
    mask_c = model.compute_bottleneck_mask(batches_c)
    ok_c = (batches_c.min(axis=1) >= 0) & mask_c.all(axis=1)

    # Check 2, identically vectorized.
    inv_s = 1.0 / c.betas
    k_s = (c.ds * inv_s).sum()
    s_s = inv_s.sum()
    mus_s = (totals_arr + k_s) / s_s
    batches_s = (mus_s[:, None] - c.ds) * inv_s
    mask_s = model.compute_bottleneck_mask(batches_s)
    ok_s = (batches_s.min(axis=1) >= 0) & (~mask_s.any(axis=1))

    solutions: List[OptPerfSolution] = []
    hint = boundary_hint
    for j, total in enumerate(totals):
        if ok_c[j]:
            sol = OptPerfSolution(
                total_batch=total,
                opt_perf=float(mus_c[j]) + t_u,
                batches=tuple(float(b) for b in batches_c[j]),
                bottleneck=("compute",) * n,
                method="algorithm1/check1",
            )
        elif ok_s[j]:
            sol = OptPerfSolution(
                total_batch=total,
                opt_perf=float(mus_s[j]) + t_comm,
                batches=tuple(float(b) for b in batches_s[j]),
                bottleneck=("comm",) * n,
                method="algorithm1/check2",
            )
        else:
            sol = solve_optperf_algorithm1(model, total, boundary_hint=hint)
        solutions.append(sol)
        # §4.5 hint chaining, identical to the scalar sweep: every candidate
        # (closed-form rows included) reseeds the next mixed search.
        hint = sum(1 for s in sol.bottleneck if s == "compute")
    return solutions


# ---------------------------------------------------------------------------
# Batched water-fill bisection — the array engine
# ---------------------------------------------------------------------------
#
# A _Problem is the engine's uniform array view of either
#   * one ClusterPerfModel shared by all C candidates (coeffs (n,), comm
#     scalars, mask None — NumPy broadcasting does the (C, n) lift), or
#   * a StackedClusterModel of C independent rows (coeffs (C, n), comm
#     (C, 1), boolean mask for padding slots).
# Every solver below (cold bisection, warm-start Newton, finalization, the
# on-device port in optperf_jax) is written against this view, so the
# single-model and stacked paths can never drift numerically.


class _Problem(NamedTuple):
    alphas: np.ndarray            # (n,) or (C, n)
    cs: np.ndarray
    betas: np.ndarray
    ds: np.ndarray
    ks: np.ndarray
    ms: np.ndarray
    t_o: Union[float, np.ndarray]     # scalar or (C, 1)
    t_u: Union[float, np.ndarray]
    t_comm: Union[float, np.ndarray]
    gamma: Union[float, np.ndarray]
    mask: Optional[np.ndarray]        # None (all valid) or (C, n) bool
    # t-independent precomputes, hoisted out of the per-iteration evals:
    safe_betas: np.ndarray            # betas with 1.0 at degenerate slots
    degenerate: np.ndarray            # betas <= 0 (syncStart flat in b)
    any_degenerate: bool
    inv_alphas: np.ndarray            # Newton slope ingredients
    inv_betas: np.ndarray             # 0 at degenerate slots


def _make_problem(alphas, cs, betas, ds, ks, ms, t_o, t_u, t_comm, gamma, mask):
    degenerate = betas <= 0.0
    safe_betas = np.where(degenerate, 1.0, betas)
    return _Problem(
        alphas=alphas, cs=cs, betas=betas, ds=ds, ks=ks, ms=ms,
        t_o=t_o, t_u=t_u, t_comm=t_comm, gamma=gamma, mask=mask,
        safe_betas=safe_betas,
        degenerate=degenerate,
        any_degenerate=bool(degenerate.any()),
        inv_alphas=1.0 / alphas,
        inv_betas=np.where(degenerate, 0.0, 1.0 / safe_betas),
    )


def _problem_from_model(model: ClusterPerfModel) -> Tuple[_Problem, float]:
    """(problem view, lo0) — memoized on the frozen model like ``coeffs``,
    so per-epoch re-solves pay the precompute once."""
    cached = model.__dict__.get("_optperf_problem")
    if cached is None:
        c = model.coeffs
        comm = model.comm
        p = _make_problem(
            c.alphas, c.cs, c.betas, c.ds, c.ks, c.ms,
            comm.t_o, comm.t_u, comm.t_comm, comm.gamma, None,
        )
        cached = (p, _p_lo0(p))
        model.__dict__["_optperf_problem"] = cached
    return cached


def _problem_from_stack(stack: StackedClusterModel) -> Tuple[_Problem, np.ndarray]:
    """(problem view, per-row lo0) — memoized on the stack instance exactly
    like :func:`_problem_from_model`.  Stacks must be treated as immutable
    once solved (mutating their arrays in place would leave the cached
    derived arrays — ``safe_betas``/``inv_alphas``/… — stale)."""
    cached = stack.__dict__.get("_optperf_problem")
    if cached is None:
        col = lambda v: v[:, None]  # noqa: E731 — broadcast against (C, n)
        p = _make_problem(
            stack.alphas, stack.cs, stack.betas, stack.ds, stack.ks, stack.ms,
            col(stack.t_o), col(stack.t_u), col(stack.t_comm), col(stack.gamma),
            stack.mask,
        )
        cached = (p, _p_lo0(p))
        stack.__dict__["_optperf_problem"] = cached
    return cached


def _p_lo0(p: _Problem) -> Union[float, np.ndarray]:
    """Per-problem lower time bound: below the smallest fixed node time no
    node can take positive batch, so assigned(lo0) == 0 < B always."""
    fixed = np.minimum(p.cs + p.t_u, p.ds + p.t_comm)
    if p.mask is not None:
        fixed = np.where(p.mask, fixed, np.inf)
    out = fixed.min(axis=-1)
    return float(out) if np.ndim(out) == 0 else out


def _p_feasible(
    p: _Problem, ts: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Shared feasible-batch kernel: (b, b_compute, b_comm).

    Every consumer (bracketing, bisection, Newton, finalization) goes through
    this single expression, so recomputing feasible batches at an emitted
    t_star is *bit-identical* to the evaluation that certified it — the
    upper-bracket invariant in :func:`_finalize_batches` depends on that.
    """
    t = np.asarray(ts, dtype=np.float64)[..., None]
    b_compute = (t - p.t_u - p.cs) / p.alphas
    slack = t - p.t_comm - p.ds
    b_comm = slack / p.safe_betas
    if p.any_degenerate:
        # A node whose syncStart does not grow with b (beta == 0, i.e.
        # q = gamma = 0) is never comm-constrained once t clears its fixed
        # comm time.
        b_comm = np.where(
            p.degenerate, np.where(slack >= 0.0, np.inf, -np.inf), b_comm
        )
    b = np.minimum(b_compute, b_comm)
    if p.mask is not None:
        b = np.where(p.mask, b, -np.inf)
    return b, b_compute, b_comm


def _p_best_single_node_time(p: _Problem, totals: np.ndarray) -> np.ndarray:
    """Upper bound on the optimum per row: the best *single* node processing
    the whole batch.  Mask-aware (padding slots are +inf, never the min), so
    the jax engines can clamp stale-high warm seeds on stacked problems too."""
    t = np.asarray(totals, dtype=np.float64)[..., None]
    nt = np.maximum(
        p.alphas * t + p.cs + p.t_u, p.betas * t + p.ds + p.t_comm
    )
    if p.mask is not None:
        nt = np.where(p.mask, nt, np.inf)
    return nt.min(axis=-1)


def _p_max_batches(p: _Problem, ts: np.ndarray) -> np.ndarray:
    """Largest feasible batch per node at cluster times ``ts``; shape
    ``(...,)`` -> ``(..., n)``.  Padding slots (mask False) are forced to
    -inf, i.e. contribute nothing."""
    return _p_feasible(p, ts)[0]


def _p_max_batches_and_slope(
    p: _Problem, ts: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """(feasible batches, d feasible/dT) — the Newton ingredients.

    The slope of min(b_compute, b_comm) is 1/alpha on the compute branch and
    1/beta on the comm branch (0 where beta is degenerate: that branch is a
    constant ±inf)."""
    b, b_compute, b_comm = _p_feasible(p, ts)
    slope = np.where(b_compute <= b_comm, p.inv_alphas, p.inv_betas)
    return b, slope


def _p_assigned(p: _Problem, ts: np.ndarray) -> np.ndarray:
    return np.maximum(_p_max_batches(p, ts), 0.0).sum(axis=-1)


def _p_rows(p: _Problem, rows: np.ndarray) -> _Problem:
    """Row-subset view of a stacked problem (single-model problems broadcast
    over candidates, so they are returned unchanged)."""
    if p.mask is None:
        return p
    take = lambda v: v[rows]  # noqa: E731
    return p._replace(
        alphas=take(p.alphas), cs=take(p.cs), betas=take(p.betas),
        ds=take(p.ds), ks=take(p.ks), ms=take(p.ms),
        t_o=take(p.t_o), t_u=take(p.t_u), t_comm=take(p.t_comm),
        gamma=take(p.gamma), mask=take(p.mask),
        safe_betas=take(p.safe_betas), degenerate=take(p.degenerate),
        inv_alphas=take(p.inv_alphas), inv_betas=take(p.inv_betas),
    )


def _p_node_times(p: _Problem, batches: np.ndarray) -> np.ndarray:
    """Per-node batch times (max form); padding slots get -inf so row maxima
    see only real nodes."""
    b = np.asarray(batches, dtype=np.float64)
    out = np.maximum(
        p.alphas * b + p.cs + p.t_u, p.betas * b + p.ds + p.t_comm
    )
    if p.mask is not None:
        out = np.where(p.mask, out, -np.inf)
    return out


def _p_compute_mask(p: _Problem, batches: np.ndarray) -> np.ndarray:
    """Overlap-state criterion (1-gamma) P_i >= T_o; padding slots False."""
    b = np.asarray(batches, dtype=np.float64)
    out = (1.0 - p.gamma) * (p.ks * b + p.ms) >= p.t_o
    if p.mask is not None:
        out = out & p.mask
    return out


def _grow_bracket(
    p: _Problem,
    totals: np.ndarray,
    lo0: Union[float, np.ndarray],
    hi: np.ndarray,
) -> Tuple[np.ndarray, int]:
    """Geometrically expand ``hi`` until assigned(hi) >= B on every row."""
    evals = 0
    for _ in range(64):
        short = _p_assigned(p, hi) < totals
        evals += 1
        if not short.any():
            return hi, evals
        hi = np.where(short, lo0 + (hi - lo0) * 2.0, hi)
    raise RuntimeError("water-fill failed to bracket optimum")


def _bisect(
    p: _Problem,
    totals: np.ndarray,
    lo: np.ndarray,
    hi: np.ndarray,
    *,
    tol: float,
    max_iter: int,
    freeze: bool = False,
) -> Tuple[np.ndarray, int]:
    """Standard simultaneous bisection; returns (t_star = hi, eval count).
    The upper-bracket invariant assigned(hi) >= B holds throughout: hi only
    ever moves to midpoints verified >= B.

    ``freeze=True`` stops updating a row once *it* has converged instead of
    halving it until every row converges.  Each frozen row then traces
    exactly the (lo, hi) sequence a solo single-row solve of that row would
    — the bit-identity contract of :func:`solve_optperf_waterfill_subsets`
    rests on this.  The default (shared halving) is kept for the engines
    whose emitted t_stars existing callers already depend on.
    """
    evals = 0
    for _ in range(max_iter):
        done = hi - lo <= tol * np.maximum(1.0, np.abs(hi))
        if done.all():
            break
        mid = 0.5 * (lo + hi)
        ge = _p_assigned(p, mid) >= totals
        evals += 1
        if freeze:
            hi = np.where(~done & ge, mid, hi)
            lo = np.where(~done & ~ge, mid, lo)
        else:
            hi = np.where(ge, mid, hi)
            lo = np.where(ge, lo, mid)
    return hi, evals


_WARM_NEWTON_ITER = 16


def _warm_refine(
    p: _Problem,
    totals: np.ndarray,
    lo0: Union[float, np.ndarray],
    warm_start: np.ndarray,
    *,
    tol: float,
) -> Tuple[np.ndarray, np.ndarray, int, np.ndarray, Optional[np.ndarray]]:
    """Safeguarded-Newton refinement from a previous t_star vector.

    g(T) = Sum_i max(b_i(T), 0) is monotone piecewise-affine, so inside one
    affine segment a single Newton step is exact — under small perf-model
    drift the active set is unchanged and the solve needs ~2-3 array passes.
    Every iterate also updates a certified [lo, hi] bracket (any evaluated t
    with g >= B is an upper bound, g < B a lower bound), and proposals
    leaving the bracket fall back to its midpoint (or geometric growth while
    no upper bound is known), so arbitrary warm starts remain correct.

    Returns (lo, hi, evals, t_last, raw_last): the brackets ready for
    :func:`_bisect` (which exits immediately on already-converged rows) plus
    the final evaluation point and its feasible-batch matrix — when
    ``t_star == t_last`` finalization reuses ``raw_last`` instead of paying
    another array pass.
    """
    w = np.asarray(warm_start, dtype=np.float64)
    if w.shape != totals.shape:
        raise ValueError("warm_start shape must match total_batches")
    lo = np.broadcast_to(np.asarray(lo0, dtype=np.float64), totals.shape).copy()
    hi = np.full(totals.shape, np.inf)
    usable = np.isfinite(w) & (w > lo)
    t = np.where(usable, w, lo + 1.0)
    close_rel = max(tol, 1e-14)
    evals = 0
    raw = None
    for _ in range(_WARM_NEWTON_ITER):
        raw, slope_elem = _p_max_batches_and_slope(p, t)
        evals += 1
        g = np.maximum(raw, 0.0).sum(axis=-1)
        ge = g >= totals
        hi = np.where(ge, np.minimum(hi, t), hi)
        lo = np.where(~ge, np.maximum(lo, t), lo)
        # Residual acceptance: an evaluated point whose residual is within
        # tolerance IS the answer (|t - t*| <= tol*B/slope <= tol*t* since g
        # passes through ~B*t/t*); collapse the bracket onto it so the
        # trailing bisection skips the row.  Acceptance works from *either*
        # side — finalization turns a tol-sized deficit into a proportional
        # inflation the same way it removes overshoot.  Width-based
        # convergence alone never fires here: Newton lands *on* the root
        # instead of squeezing a bracket around it.
        close = np.abs(g - totals) <= close_rel * totals
        lo = np.where(close, t, lo)
        hi = np.where(close, t, hi)
        done = np.isfinite(hi) & (hi - lo <= tol * np.maximum(1.0, np.abs(hi)))
        if done.all():
            break
        slope = np.where(raw > 0.0, slope_elem, 0.0).sum(axis=-1)
        ok = slope > 0.0
        t_newton = t - (g - totals) / np.where(ok, slope, 1.0)
        # A float-stuck proposal (residual below one step of representable
        # progress) gets a tol-sized upward bump: the next evaluation then
        # certifies it as an exact upper point.
        t_newton = np.where(
            (t_newton == t) & ~done, t * (1.0 + close_rel) + 1e-300, t_newton
        )
        # Safeguard: the proposal must fall strictly inside the certified
        # bracket; otherwise bisect it (or keep growing while unbounded).
        bounded = np.isfinite(hi)
        fallback = np.where(bounded, 0.5 * (lo + hi), lo0 + (lo - lo0) * 2.0 + 1.0)
        bad = ~ok | ~(t_newton > lo) | ~(t_newton < hi)
        t = np.where(done, t, np.where(bad, fallback, t_newton))
    # Rows still unbounded above (warm start far below the new optimum and
    # Newton ran out of iterations) get the cold geometric growth.
    missing = ~np.isfinite(hi)
    if missing.any():
        seed = np.where(missing, np.maximum(lo, lo0) + 1.0, hi)
        grown, grow_evals = _grow_bracket(p, totals, lo0, seed)
        evals += grow_evals
        hi = np.where(missing, grown, hi)
        raw = None  # t no longer matches the last evaluation
    return lo, hi, evals, t, raw


def _finalize_batches(
    p: _Problem,
    totals: np.ndarray,
    t_star: np.ndarray,
    *,
    tol: float,
    raw: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Turn the bisected time bounds into exact-sum batch vectors.

    Returns ``(batches, node_times)`` — the realized per-node times fall out
    of the internal bound check, so callers reuse them for ``opt_perfs``
    instead of paying another array pass.  ``raw`` may carry a feasible-batch
    matrix already evaluated *at* ``t_star`` (warm solves end on one); it
    must be the output of :func:`_p_max_batches` at exactly ``t_star``.

    Bisection leaves Sum_i max(b_i(t_star), 0) >= B (up to float residue);
    warm Newton acceptance may instead leave a deficit of at most ~tol*B.
    Either way the residual is removed *proportionally over the positive
    (binding) nodes* — shrinking keeps every touched node under its time
    bound, and the tol-sized inflation of the deficit case stays inside the
    bound tolerance below.  (A whole-vector rescale would be wrong: it could
    inflate a binding node past ``t_star`` whenever float residue left the
    sum a hair under B.)  Clamped nodes (b=0, fixed time already at/above
    ``t_star``) are never touched.
    """
    if raw is None:
        raw = _p_max_batches(p, t_star)                 # (..., n)
    batches = np.maximum(raw, 0.0)
    sums = batches.sum(axis=-1)
    # Invariant: the solvers only emit t_star values verified (by the
    # identical expression) to overshoot, or to undershoot by at most the
    # residual-acceptance tolerance.  Anything worse is a bracket-logic bug.
    if not bool(np.all(totals - sums <= 4.0 * max(tol, 1e-14) * totals)):
        raise AssertionError("water-fill bisection lost its upper-bracket invariant")
    pos_sums = np.where(sums > 0.0, sums, 1.0)
    off = sums != totals
    if np.any(off):
        # Proportional rescale over positive nodes; factor <= 1 for
        # overshoot, <= 1 + O(tol) for the warm-acceptance deficit.
        factor = np.where(off, totals / pos_sums, 1.0)
        batches = batches * factor[..., None]
    # Internal consistency: no positive node may exceed its bisected time
    # bound (clamped stragglers sit at their fixed floor, which can lie above
    # t_star and is unavoidable at any partition).
    node_times = _p_node_times(p, batches)
    positive = batches > 0.0
    bound = t_star[..., None] * (1.0 + max(tol * 16.0, 1e-8)) + 1e-12
    if not bool(np.all(np.where(positive, node_times, -np.inf) <= bound)):
        raise AssertionError("water-fill finalization exceeded the bisected time bound")
    return batches, node_times


def _solve_problem(
    p: _Problem,
    lo0: Union[float, np.ndarray],
    totals: np.ndarray,
    *,
    tol: float,
    max_iter: int,
    warm_start: Optional[np.ndarray],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, int]:
    """Shared engine body: bracket (cold or warm), bisect, finalize.

    Returns (t_star, batches, opt_perfs, compute_mask, evals)."""
    raw_last = None
    if warm_start is None:
        lo = np.broadcast_to(np.asarray(lo0, dtype=np.float64), totals.shape).copy()
        hi, evals = _grow_bracket(p, totals, lo0, lo + 1.0)
    else:
        lo, hi, evals, t_last, raw_last = _warm_refine(
            p, totals, lo0, warm_start, tol=tol
        )
    t_star, bisect_evals = _bisect(p, totals, lo, hi, tol=tol, max_iter=max_iter)
    evals += bisect_evals
    # Warm solves typically end with (almost) every row accepted at its final
    # evaluation point — finalization then reuses that feasible-batch matrix.
    # The few rows that converged by bracket width instead (t_star = an older
    # upper point) get a cheap subset re-evaluation.
    reuse = None
    if raw_last is not None and bisect_evals == 0:
        mismatch = t_star != t_last
        n_mismatch = int(np.count_nonzero(mismatch))
        if n_mismatch == 0:
            reuse = raw_last
        elif n_mismatch <= max(4, totals.shape[0] // 8):
            reuse = raw_last.copy()
            reuse[mismatch] = _p_max_batches(
                _p_rows(p, mismatch), t_star[mismatch]
            )
    batches, node_times = _finalize_batches(p, totals, t_star, tol=tol, raw=reuse)
    opt_perfs = node_times.max(axis=-1)
    compute_mask = _p_compute_mask(p, batches)
    return t_star, batches, opt_perfs, compute_mask, evals


def _validated_totals(total_batches: Sequence[float]) -> np.ndarray:
    totals = np.array(total_batches, dtype=np.float64)  # copy: no aliasing
    if totals.ndim != 1:
        raise ValueError("total_batches must be a 1-D sequence")
    if totals.size == 0:
        raise ValueError("total_batches must be non-empty")
    if np.any(totals <= 0):
        raise ValueError("total batch must be positive")
    return totals


def solve_optperf_batch(
    model: ClusterPerfModel,
    total_batches: Sequence[float],
    *,
    tol: float = 1e-10,
    max_iter: int = 200,
    warm_start: Optional[np.ndarray] = None,
) -> BatchedOptPerfSolution:
    """Solve OptPerf for every candidate total batch size in one array pass.

    All candidates are bisected *simultaneously*: the bracket state is a
    ``(C,)`` vector and each iteration evaluates one ``(C, n)`` feasible-batch
    broadcast, so the whole sweep is ~``max_iter`` NumPy ops regardless of C.

    Monotonicity argument (per candidate, same as the scalar water-fill):
    each node's feasible batch b_i(T) is affine increasing in T, so
    g(T) = Sum_i max(b_i(T), 0) is continuous, nondecreasing, and unbounded;
    bisection on g(T) = B converges geometrically.

    ``warm_start``: previous ``t_stars`` vector (aligned with
    ``total_batches``); enables the safeguarded-Newton incremental re-solve
    (see module docstring).  The answer is identical with or without it.
    """
    totals = _validated_totals(total_batches)
    model.validate()
    p, lo0 = _problem_from_model(model)
    t_star, batches, opt_perfs, compute_mask, evals = _solve_problem(
        p, lo0, totals, tol=tol, max_iter=max_iter, warm_start=warm_start
    )
    for arr in (totals, t_star, opt_perfs, batches, compute_mask):
        arr.flags.writeable = False
    return BatchedOptPerfSolution(
        total_batches=totals,
        opt_perfs=opt_perfs,
        batches=batches,
        compute_mask=compute_mask,
        method="waterfill/batched" if warm_start is None else "waterfill/batched+warm",
        t_stars=t_star,
        iterations=evals,
    )


def solve_optperf_stacked(
    stack: StackedClusterModel,
    total_batches: Sequence[float],
    *,
    tol: float = 1e-10,
    max_iter: int = 200,
    warm_start: Optional[np.ndarray] = None,
) -> BatchedOptPerfSolution:
    """Water-fill C *independent* problem rows simultaneously.

    Each row of the :class:`StackedClusterModel` is its own cluster (node
    subset + comm model) with its own total batch ``total_batches[r]``; all
    rows share the bisection loop, so a whole scheduler round costs the same
    ~50 array passes as a single solve.  Padding slots never receive batch
    and never contribute to row times."""
    totals = _validated_totals(total_batches)
    if totals.shape[0] != stack.shape[0]:
        raise ValueError("total_batches length must match stack rows")
    stack.validate()
    p, lo0 = _problem_from_stack(stack)
    t_star, batches, opt_perfs, compute_mask, evals = _solve_problem(
        p, lo0, totals, tol=tol, max_iter=max_iter, warm_start=warm_start
    )
    for arr in (totals, t_star, opt_perfs, batches, compute_mask):
        arr.flags.writeable = False
    return BatchedOptPerfSolution(
        total_batches=totals,
        opt_perfs=opt_perfs,
        batches=batches,
        compute_mask=compute_mask,
        method="waterfill/stacked",
        t_stars=t_star,
        iterations=evals,
        node_mask=stack.mask,
    )


def solve_optperf_waterfill(
    model: ClusterPerfModel,
    total_batch: float,
    *,
    tol: float = 1e-10,
    max_iter: int = 200,
) -> OptPerfSolution:
    """Exact OptPerf via bisection on the cluster batch time T.

    Single-candidate specialization of :func:`solve_optperf_batch` (identical
    numerics, so the scalar oracle and the batched engine can never drift).
    """
    batch = solve_optperf_batch(
        model, np.asarray([total_batch], dtype=np.float64), tol=tol, max_iter=max_iter
    )
    return batch.solution(0, method="waterfill")


def solve_optperf_waterfill_subset(
    model: ClusterPerfModel,
    node_ids: Sequence[int],
    total_batch: float,
    *,
    tol: float = 1e-10,
    max_iter: int = 200,
) -> OptPerfSolution:
    """Water-fill over a *node subset* of ``model``.

    Bit-identical to building the subset :class:`ClusterPerfModel` and
    calling :func:`solve_optperf_waterfill` — the per-node coefficients are
    elementwise, so gathering rows of the full model's cached ``coeffs``
    yields the exact same floats — but without the per-call model
    construction and re-validation.  This is the multi-job scheduler's
    chosen-set re-solve in a loop (one call per greedy round), where the
    construction overhead dominated the solve.
    """
    if total_batch <= 0:
        raise ValueError("total batch must be positive")
    ids = np.asarray(node_ids, dtype=np.intp)
    if ids.size == 0:
        raise ValueError("need at least one node")
    comm = model.comm
    comm.validate()
    p = _make_problem(
        *_subset_problem_row(model, ids),
        comm.t_o, comm.t_u, comm.t_comm, comm.gamma, None,
    )
    totals = np.asarray([float(total_batch)])
    t_star, batches, opt_perfs, compute_mask, _ = _solve_problem(
        p, _p_lo0(p), totals, tol=tol, max_iter=max_iter, warm_start=None
    )
    return OptPerfSolution(
        total_batch=float(total_batch),
        opt_perf=float(opt_perfs[0]),
        batches=tuple(float(b) for b in batches[0]),
        bottleneck=tuple("compute" if m else "comm" for m in compute_mask[0]),
        method="waterfill",
    )


def _subset_problem_row(
    model: ClusterPerfModel, ids: np.ndarray
) -> Tuple[np.ndarray, ...]:
    """Gathered (alphas, cs, betas, ds, ks, ms) rows for one node subset —
    THE shared gather+validation behind the solo and stacked subset solvers
    (comm is validated by the caller, once per distinct model).

    Validation applies the same vectorized k > 0, q >= 0 semantics as
    ``ClusterPerfModel.validate`` to the subset only: a bad node outside
    the subset must not reject an otherwise valid sub-cluster — and vice
    versa."""
    c = model.coeffs
    ks = c.ks[ids]
    alphas = c.alphas[ids]
    if not (bool(np.all(ks > 0)) and bool(np.all(alphas - ks >= 0))):
        raise ValueError("ill-posed node model")
    return alphas, c.cs[ids], c.betas[ids], c.ds[ids], ks, c.ms[ids]


def solve_optperf_waterfill_subsets(
    models: Sequence[ClusterPerfModel],
    node_id_sets: Sequence[Sequence[int]],
    total_batches: Sequence[float],
    *,
    tol: float = 1e-10,
    max_iter: int = 200,
) -> List[OptPerfSolution]:
    """Batch of :func:`solve_optperf_waterfill_subset` calls as stacked
    array solves — **bit-identical** to the scalar per-subset loop.

    ``models[r]`` / ``node_id_sets[r]`` / ``total_batches[r]`` describe row
    ``r`` (models may repeat; each row carries its model's own comm model as
    a per-row column).  Rows are grouped by subset size and each group is
    solved as one stacked water-fill — *without padding*, so every row's
    feasible-batch reductions see exactly the floats the solo solve sees,
    and with per-row frozen bisection (see :func:`_bisect`), so every row
    traces the solo solve's bracket sequence exactly.  This is the
    scheduler's chosen-set re-solve path: one stacked call per distinct
    chosen-set size per ``allocate`` instead of one scalar solve per greedy
    round, with the oracle-parity contract preserved bit-for-bit.

    Raises :class:`ValueError` on any ill-posed row, exactly like the
    scalar subset solver does for that row.
    """
    rows = len(node_id_sets)
    if not (len(models) == rows == len(total_batches)):
        raise ValueError("models, node_id_sets, total_batches length mismatch")
    out: List[Optional[OptPerfSolution]] = [None] * rows
    validated = set()
    groups: dict = {}
    for r in range(rows):
        ids = np.asarray(node_id_sets[r], dtype=np.intp)
        if ids.size == 0:
            raise ValueError("need at least one node")
        if float(total_batches[r]) <= 0:
            raise ValueError("total batch must be positive")
        if id(models[r].comm) not in validated:
            models[r].comm.validate()
            validated.add(id(models[r].comm))
        groups.setdefault(int(ids.size), []).append((r, ids))
    for m, members in groups.items():
        g = len(members)
        coeff_rows = [_subset_problem_row(models[r], ids) for r, ids in members]
        stacked = [np.stack([cr[i] for cr in coeff_rows]) for i in range(6)]
        col = lambda vals: np.asarray(vals, dtype=np.float64)[:, None]  # noqa: E731
        comms = [models[r].comm for r, _ in members]
        p = _make_problem(
            *stacked,
            col([cm.t_o for cm in comms]),
            col([cm.t_u for cm in comms]),
            col([cm.t_comm for cm in comms]),
            col([cm.gamma for cm in comms]),
            None,
        )
        totals = np.asarray([float(total_batches[r]) for r, _ in members])
        lo0 = _p_lo0(p)
        lo = np.broadcast_to(np.asarray(lo0, dtype=np.float64), totals.shape).copy()
        hi, _ = _grow_bracket(p, totals, lo0, lo + 1.0)
        t_star, _ = _bisect(p, totals, lo, hi, tol=tol, max_iter=max_iter, freeze=True)
        batches, node_times = _finalize_batches(p, totals, t_star, tol=tol)
        opt_perfs = node_times.max(axis=-1)
        compute_mask = _p_compute_mask(p, batches)
        for gi, (r, _) in enumerate(members):
            out[r] = OptPerfSolution(
                total_batch=float(totals[gi]),
                opt_perf=float(opt_perfs[gi]),
                batches=tuple(float(b) for b in batches[gi]),
                bottleneck=tuple(
                    "compute" if mk else "comm" for mk in compute_mask[gi]
                ),
                method="waterfill",
            )
    assert all(s is not None for s in out)
    return out  # type: ignore[return-value]


def solve_optperf(
    model: ClusterPerfModel,
    total_batch: float,
    *,
    method: str = "algorithm1",
    boundary_hint: Optional[int] = None,
) -> OptPerfSolution:
    """Dispatch helper. ``method`` in {"algorithm1", "waterfill"}."""
    if method == "algorithm1":
        return solve_optperf_algorithm1(model, total_batch, boundary_hint=boundary_hint)
    if method == "waterfill":
        return solve_optperf_waterfill(model, total_batch)
    raise ValueError(f"unknown method {method!r}")


# ---------------------------------------------------------------------------
# Integer rounding (§4.5 "Integer batch sizes")
# ---------------------------------------------------------------------------


def round_batches(batches: Sequence[float], total_batch: int) -> List[int]:
    """Round real batches to integers summing exactly to ``total_batch``.

    The paper rounds and accepts the (insignificant) error; we use
    largest-remainder rounding so the sum constraint holds exactly and the
    rounding error per node is < 1 sample.  When float residue leaves the
    real batches summing a hair *above* ``total_batch`` (so the floors
    already overshoot), the deficit is taken from the entries with the
    smallest fractional parts instead of raising; overshoot of a sample per
    node or more still raises (that is a wrong-total caller bug).
    """
    if total_batch != int(total_batch):
        raise ValueError("total batch must be an integer")
    floors = [int(math.floor(b)) for b in batches]
    remainder = int(total_batch) - sum(floors)
    out = list(floors)
    if remainder < 0:
        if sum(batches) - total_batch >= len(batches):
            # Overshoot of a sample per node or more is a caller bug (a
            # partition computed for a different total), not float residue.
            raise ValueError("batches sum above total")
        # Decrement the smallest fractional parts (they lose the least mass),
        # skipping entries already at zero; raise only when the total is
        # unreachable even with every batch driven to zero.
        order = sorted(range(len(batches)), key=lambda i: batches[i] - floors[i])
        need = -remainder
        while need:
            progressed = False
            for i in order:
                if out[i] > 0:
                    out[i] -= 1
                    need -= 1
                    progressed = True
                    if need == 0:
                        break
            if not progressed:
                raise ValueError("batches sum above total")
        return out
    # Assign leftover samples to the largest fractional parts.
    fracs = sorted(
        range(len(batches)), key=lambda i: batches[i] - floors[i], reverse=True
    )
    for i in fracs[:remainder]:
        out[i] += 1
    return out

"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, rope theta 5e5, 128k vocab [arXiv:2407.21783]."""
from repro.models.dense import DenseConfig

ARCH_ID = "llama3-8b"


def config() -> DenseConfig:
    return DenseConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=128256,
        head_dim=128,
        rope_theta=500000.0,
        act="swiglu",
        norm="rmsnorm",
        # Sliding-window decode variant qualifies this dense arch for
        # long_500k (DESIGN.md §5); full-cache decode is used when the cache
        # fits (decode_32k).
        decode_window=8192,
    )


def reduced() -> DenseConfig:
    return DenseConfig(
        name=ARCH_ID + "-reduced",
        n_layers=2,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        d_ff=512,
        vocab=512,
        head_dim=32,
        rope_theta=500000.0,
        decode_window=64,
        remat=False,
    )

"""Goodput-aware serving: the OptPerf water-fill under live inference traffic.

The serving subsystem reuses the trainer's allocation engine — per-node
decode batches are sized by the same :func:`repro.core.optperf.
solve_optperf_batch` water-fill that sizes training micro-batches — and the
trainer's churn alphabet (:class:`repro.runtime.events.NodeJoin` /
``NodeLeave``), under a continuous-batching admission scheduler with
per-request deadlines and goodput accounting.

Layers (each importable on its own):

* :mod:`repro.serving.request`   — seeded load generator (Poisson / bursty)
* :mod:`repro.serving.queue`     — admission + continuous batching
* :mod:`repro.serving.allocator` — telemetry -> refit -> water-fill solve
* :mod:`repro.serving.engines`   — simulated and real decode engines
* :mod:`repro.serving.metrics`   — latency/throughput/goodput accounting
* :mod:`repro.serving.server`    — the deterministic event loop
"""
from repro.serving.allocator import (
    NodeTickFitter,
    ServingAllocator,
    serving_cluster_model,
    serving_node_model,
    uniform_split,
)
from repro.serving.engines import (
    RealServingEngine,
    ServingEngine,
    SimServingEngine,
    prefill_cache,
)
from repro.serving.metrics import RequestRecord, ServingMetrics, percentiles
from repro.serving.queue import ActiveRequest, BatchScheduler, SchedulingError
from repro.serving.request import (
    Request,
    Workload,
    generate_requests,
    prompts_from_stream,
)
from repro.serving.server import ServingConfig, ServingReport, ServingRuntime

__all__ = [
    "ActiveRequest",
    "BatchScheduler",
    "NodeTickFitter",
    "RealServingEngine",
    "Request",
    "RequestRecord",
    "SchedulingError",
    "ServingAllocator",
    "ServingConfig",
    "ServingEngine",
    "ServingMetrics",
    "ServingReport",
    "ServingRuntime",
    "SimServingEngine",
    "Workload",
    "generate_requests",
    "percentiles",
    "prefill_cache",
    "prompts_from_stream",
    "serving_cluster_model",
    "serving_node_model",
    "uniform_split",
]

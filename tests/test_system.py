"""End-to-end system behaviour: the paper's headline comparisons, executed
at reduced scale — Cannikin vs DDP-even vs LB-BSP on a simulated
heterogeneous cluster with real JAX training underneath (Fig. 8/9/10
analogues), plus a small multi-device SPMD check via subprocess."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX-compiling; excluded from the fast lane

from repro.configs import get_api
from repro.core import CannikinController, SimulatedCluster, cluster_B
from repro.core.baselines import EvenPartition, LBBSPPartition
from repro.data import SyntheticLM
from repro.optim import constant_schedule, sgd
from repro.train import HeteroTrainer


def make_trainer(policy_name, seed=0, adaptive=False, ref_batch=64):
    api = get_api("olmo-1b", reduced=True)
    profiles, comm = cluster_B()
    sim = SimulatedCluster(profiles, comm, noise=0.01, seed=seed)
    data = SyntheticLM(vocab=api.cfg.vocab, seq_len=24, seed=seed)
    if policy_name == "cannikin":
        policy = CannikinController(
            sim.n,
            batch_candidates=[ref_batch, ref_batch * 2, ref_batch * 4],
            ref_batch=ref_batch,
            adaptive=adaptive,
        )
    elif policy_name == "even":
        policy = EvenPartition(sim.n)
    else:
        policy = LBBSPPartition(sim.n, delta=5)
    tr = HeteroTrainer(
        api, sgd(constant_schedule(0.3)), sim, policy, data, steps_per_epoch=4,
        seed=seed,
    )
    tr.set_fixed_total(ref_batch)
    return tr


def test_cannikin_fastest_batch_time_fixed_total():
    """Fig. 10 analogue (fixed total batch): after learning, Cannikin's batch
    processing time beats DDP-even and LB-BSP-at-epoch-6."""
    results = {}
    for name in ("cannikin", "even", "lb-bsp"):
        tr = make_trainer(name)
        tr.run(6)
        results[name] = tr.history[-1].measured_batch_time
    assert results["cannikin"] < results["even"]
    assert results["cannikin"] < results["lb-bsp"]


def test_convergence_wallclock_ordering():
    """Fig. 8 analogue: simulated wall-clock to reach a fixed loss —
    Cannikin (adaptive) <= even split."""
    target = 4.0
    wall = {}
    for name in ("cannikin", "even"):
        tr = make_trainer(name, adaptive=(name == "cannikin"))
        for _ in range(20):
            r = tr.run_epoch()
            if r.mean_loss <= target:
                break
        wall[name] = tr.sim_time
    assert wall["cannikin"] <= wall["even"] * 1.02


def test_spmd_multi_device_hetero_weights():
    """Runs a pjit weighted-loss step on 8 fake devices in a subprocess and
    checks the Eq. (9) gradient matches the single-device computation."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_api

api = get_api("olmo-1b", reduced=True)
params = api.init(jax.random.PRNGKey(0))
B, S = 16, 16
rng = jax.random.PRNGKey(1)
tokens = jax.random.randint(rng, (B, S), 0, api.cfg.vocab)
labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, api.cfg.vocab)
weights = jnp.linspace(0.5, 2.0, B)

def loss(params, batch):
    l, _ = api.loss(params, batch)
    return l

g1 = jax.grad(loss)(params, {"tokens": tokens, "labels": labels, "weights": weights})

mesh = jax.make_mesh((8,), ("data",))
bs = NamedSharding(mesh, P("data"))
batch = {
    "tokens": jax.device_put(tokens, NamedSharding(mesh, P("data", None))),
    "labels": jax.device_put(labels, NamedSharding(mesh, P("data", None))),
    "weights": jax.device_put(weights, bs),
}
g2 = jax.jit(jax.grad(loss))(params, batch)
for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               rtol=2e-2, atol=2e-3)
print("SPMD-OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )
    assert "SPMD-OK" in out.stdout, out.stderr[-2000:]


def test_enter_mesh_portable_context():
    """enter_mesh works on jax versions without jax.set_mesh / use_mesh:
    inside the context, bare-PartitionSpec sharding constraints resolve
    against the active mesh."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import enter_mesh

    mesh = jax.make_mesh((1,), ("data",))
    with enter_mesh(mesh):
        y = jax.jit(
            lambda x: jax.lax.with_sharding_constraint(x, P("data"))
        )(jnp.arange(8.0))
    assert float(y.sum()) == 28.0


def test_dryrun_subprocess_tiny_mesh():
    """A miniature dry-run (4x4 mesh) in a subprocess: lower+compile the
    llama3 reduced train step with the production sharding rules."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_api
from repro.sharding.rules import MeshRules
from repro.optim import adamw, constant_schedule
from repro.train.step import build_train_step

api = get_api("llama3-8b", reduced=True)
mesh = jax.make_mesh((4, 4), ("data", "model"))
rules = MeshRules(mesh_axes={"data": 4, "model": 4}, batch_axes=("data",))
opt = adamw(constant_schedule(1e-3))
step = build_train_step(api, opt, microbatches=2, with_metrics=False)
batch_sds = api.train_batch_specs(8, 32)
params_sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
opt_sds = jax.eval_shape(opt.init, params_sds)
pspecs = api.specs(rules)
named = lambda t: jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), t,
                                         is_leaf=lambda x: isinstance(x, P))
bspecs = {k: NamedSharding(mesh, rules.batch_spec(extra_dims=len(v.shape)-1))
          for k, v in batch_sds.items()}
from repro.launch.dryrun import _opt_specs
from repro.launch.hlo_stats import raw_cost_analysis
from repro.launch.mesh import enter_mesh
ospecs = _opt_specs(opt_sds, pspecs)
with enter_mesh(mesh):
    compiled = jax.jit(
        lambda p, o, b: step(p, o, b),
        in_shardings=(named(pspecs), named(ospecs), bspecs),
    ).lower(params_sds, opt_sds, batch_sds).compile()
print("DRYRUN-OK", raw_cost_analysis(compiled)["flops"] > 0)
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )
    assert "DRYRUN-OK True" in out.stdout, out.stderr[-2000:]


def test_dryrun_artifacts_complete_if_present():
    """If the full dry-run has been executed, every (arch x shape x mesh)
    must be ok or a documented skip."""
    import glob

    files = glob.glob("artifacts/dryrun/*.json")
    if len(files) < 80:
        pytest.skip("full dry-run artifacts not present")
    bad = []
    for f in files:
        rec = json.load(open(f))
        if rec["status"] == "error":
            bad.append((rec["arch"], rec["shape"], rec["mesh"], rec["error"]))
        elif rec["status"] == "skipped":
            assert rec["arch"].startswith("whisper"), rec
    assert not bad, bad

"""Fault-recovery overhead lane: chaos replay vs its fault-free twin.

    PYTHONPATH=src:. python -m benchmarks.bench_faults [--smoke]

Replays the standard 3-job synthetic trace under the default seeded chaos
plan (one crash, one transient straggler, one flapping node, one noise
spike — see ``FaultPlan.chaos``) with the HealthMonitor detecting and the
runtime self-healing, then measures what the faults cost:

* ``goodput_retention`` — fault-free sim-time / faulted sim-time (gate:
  >= 0.8 — detection plus recovery must keep at least 80% of throughput);
* ``detection_latency_epochs`` / ``mttr_epochs`` — how fast faults are
  caught and repaired;
* wall-clock replay overhead of the fault-tolerance layer itself on a
  fault-free trace (injector + monitor present but idle).

A second, real-backend lane replays a single real-gradient job under the
``chaos-real`` integrity plan (gradient poison + solver stall) with the
runtime invariant checker on: the anomaly guard must contain the poison,
the numerical-health channel must quarantine the node, the watchdog must
absorb the stall, the final loss must be finite, and goodput retention
must clear the same gate — all with zero invariant violations.

Results merge into ``artifacts/bench/sweep.json`` under the ``"faults"``
key (real-backend lane under ``"faults"."real"``) so the sweep artifact
stays the one-stop perf record.
"""
import argparse
import json
import math
import os
import tempfile
import time

from benchmarks.common import ARTIFACTS, Row, save_json

from repro.runtime import (
    FaultPlan,
    RealBackendConfig,
    Trace,
    make_fault_plan,
    replay,
    synthetic_trace,
)

N_JOBS, N_NODES, SEED = 3, 12, 0
EPOCHS_PER_EVENT, STEPS, NOISE = 6, 2, 0.01
RETENTION_GATE = 0.8
REAL_NODES, REAL_EPOCHS = 3, 6


def _replay(faults=None, health=None, checkpoint_dir=None):
    trace, _ = synthetic_trace(N_JOBS, N_NODES, seed=SEED)
    return replay(
        trace, N_NODES, policy="cannikin", epochs_per_event=EPOCHS_PER_EVENT,
        steps=STEPS, noise=NOISE, seed=SEED, faults=faults, health=health,
        checkpoint_dir=checkpoint_dir,
    )


def _real_spec():
    from repro.core.perf_model import CommModel
    from repro.core.scheduler import JobSpec
    from repro.core.simulator import GPU_CATALOG

    return JobSpec(
        name="real-job",
        node_models=tuple(
            GPU_CATALOG[n].model() for n in ("a100", "v100", "rtx6000")
        ),
        comm=CommModel(t_o=0.04, t_u=0.008, gamma=0.15),
        total_batch=12,
        b_noise=500.0,
        ref_batch=12,
        backend="real",
    )


def _run_real_lane(rows):
    """chaos-real on a single real-gradient job: poison + stall contained."""
    plan = make_fault_plan("chaos-real", REAL_NODES, seed=SEED)
    trace = Trace().arrive(_real_spec(), at=0.0)
    t0 = time.perf_counter()
    rep = replay(
        trace, REAL_NODES, policy="cannikin", epochs_per_event=REAL_EPOCHS,
        steps=STEPS, seed=SEED,
        real_backend=RealBackendConfig(arch="olmo-1b", seq_len=16, lr=0.3),
        faults=plan, invariants=True,
    )
    elapsed = time.perf_counter() - t0
    telemetry = rep.runtime.fault_telemetry()
    assert telemetry is not None
    retention = rep.goodput_retention
    assert retention is not None

    # The integrity gates (deterministic, so they hold in smoke runs too).
    handle = rep.runtime.handles["real-job"]
    assert all(
        math.isfinite(r.mean_loss) for r in handle.records
    ), "non-finite loss under gradient poison"
    assert telemetry["detected"]["numeric"] >= 1, "poison never detected"
    assert telemetry["recoveries"]["quarantine"] >= 1, "poison never quarantined"
    assert telemetry["watchdog"]["solver_timeouts"] >= 1, "stall never caught"
    assert telemetry["invariants"]["violations"] == 0, "invariant violations"
    assert retention >= RETENTION_GATE, (
        f"real-lane retention {retention:.3f} below gate {RETENTION_GATE}"
    )

    record = {
        "n_nodes": REAL_NODES,
        "epochs_per_event": REAL_EPOCHS,
        "plan": plan.describe(),
        "goodput_retention": retention,
        "retention_gate": RETENTION_GATE,
        "detection_latency_epochs": telemetry["detection_latency_epochs"],
        "detected": telemetry["detected"],
        "recoveries": telemetry["recoveries"],
        "watchdog": telemetry["watchdog"],
        "invariants": telemetry["invariants"],
        "checkpoint_rollbacks": telemetry["checkpoint_rollbacks"],
        "replay_s": elapsed,
    }
    rows.append(
        Row(
            f"faults/chaos_real/j1xn{REAL_NODES}",
            elapsed * 1e6,
            f"retention={retention:.3f};"
            f"numeric={telemetry['detected']['numeric']};"
            f"viol={telemetry['invariants']['violations']}",
        )
    )
    return record


def run(smoke: bool = False):
    rows = []
    plan = FaultPlan.chaos(N_NODES, seed=SEED)

    # Chaos lane: the default plan on the standard trace -----------------
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        rep = _replay(faults=plan, checkpoint_dir=ckpt_dir)
    chaos_s = time.perf_counter() - t0
    telemetry = rep.runtime.fault_telemetry()
    assert telemetry is not None
    retention = rep.goodput_retention
    assert retention is not None

    # Overhead lane: injector + monitor present but idle (no faults) -----
    t0 = time.perf_counter()
    base = _replay()
    plain_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    monitored = _replay(health=True)
    monitored_s = time.perf_counter() - t0
    overhead = (monitored_s - plain_s) / plain_s if plain_s > 0 else 0.0
    # The observation-only guarantee: an idle monitor changes nothing.
    assert monitored.runtime.allocation.assignment == base.runtime.allocation.assignment

    record = {
        "n_jobs": N_JOBS,
        "n_nodes": N_NODES,
        "seed": SEED,
        "epochs_per_event": EPOCHS_PER_EVENT,
        "plan": plan.describe(),
        "goodput_retention": retention,
        "retention_gate": RETENTION_GATE,
        "detection_latency_epochs": telemetry["detection_latency_epochs"],
        "mttr_epochs": telemetry["mttr_epochs"],
        "mttr_sim_seconds": telemetry["mttr_sim_seconds"],
        "detected": telemetry["detected"],
        "recoveries": telemetry["recoveries"],
        "faulted_sim_time": rep.total_sim_time,
        "fault_free_sim_time": rep.baseline.total_sim_time,
        "chaos_replay_s": chaos_s,
        "monitor_overhead_frac": overhead,
    }
    rows.append(
        Row(
            f"faults/chaos/j{N_JOBS}xn{N_NODES}",
            chaos_s * 1e6,
            f"retention={retention:.3f};lat={telemetry['detection_latency_epochs']}ep;"
            f"mttr={telemetry['mttr_epochs']}ep",
        )
    )
    rows.append(
        Row(
            f"faults/monitor_idle/j{N_JOBS}xn{N_NODES}",
            monitored_s * 1e6,
            f"overhead={overhead * 100:.1f}%",
        )
    )

    # Gate: detection + recovery must retain >= 80% of fault-free
    # throughput on the standard trace under the default chaos plan.
    # The replay is deterministic, so the gate holds in smoke runs too.
    del smoke
    assert retention >= RETENTION_GATE, (
        f"goodput retention {retention:.3f} below gate {RETENTION_GATE}"
    )

    # Real-backend integrity lane ----------------------------------------
    record["real"] = _run_real_lane(rows)

    # Merge into the sweep artifact (keep every other lane's record).
    sweep_path = os.path.join(ARTIFACTS, "bench", "sweep.json")
    payload = {}
    if os.path.exists(sweep_path):
        try:
            with open(sweep_path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    payload["faults"] = record
    save_json("sweep", payload)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for lane-runner symmetry (already CI-sized)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        print(row.csv(), flush=True)


if __name__ == "__main__":
    main()

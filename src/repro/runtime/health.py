"""Telemetry-driven failure detection: the HealthMonitor.

The runtime already collects per-node :class:`~repro.core.perf_model.
NodeObservation` telemetry every epoch — the same stream the performance-
model fitters consume.  This module turns that stream into failure
detection, the second third of the fault-tolerance layer (injection:
:mod:`repro.runtime.faults`; recovery: the
:class:`~repro.runtime.runtime.ClusterRuntime` reconcile loop):

* **Stragglers** are flagged from the residual between each node's
  *observed* per-batch compute time (a-part + backprop, averaged over the
  epoch's steps) and the :class:`~repro.core.perf_model.ClusterPerfModel`
  *prediction* for the same local batch size.  The log-residual is tracked
  per node with an EWMA + EWMA-variance filter; a breach is a z-score
  above ``z_threshold`` or a raw ratio above ``ratio_threshold`` (the hard
  trip for gross degradation), sustained for ``suspect_epochs``
  consecutive epochs so a single noisy epoch never quarantines a node.
* **Crashes** are flagged from *missing* observations: a node a running
  job holds that reports nothing for ``crash_epochs`` consecutive epochs
  is declared crashed (a silent stop produces no NodeLeave — absence of
  telemetry is the only signal).
* **Numeric anomalies** are a separate channel (:meth:`HealthMonitor.
  observe_numerics`): the real backend's gradient anomaly guard reports,
  per node, how many steps it excluded from Eq. (9) aggregation; a node
  anomalous for ``numeric_suspect_epochs`` consecutive epochs is
  quarantined through the same state machine, so a persistently poisoned
  node is contained exactly like a persistent straggler.
* **Quarantine state machine** with exponential-backoff re-admission:
  ``healthy → quarantined → probation → healthy``, where a breach during
  probation re-quarantines with a *doubled* backoff (capped at
  ``backoff_max``) so a flapping node cannot thrash the scheduler's warm
  caches.
* **Drift** — a sustained mild residual across a job's whole node set
  (``drift_ratio`` for ``drift_epochs`` epochs, below the straggler
  threshold) requests a forced :class:`~repro.runtime.events.ModelRefit`
  so the controller re-learns instead of planning on stale coefficients.

The monitor is *observation-only until it fires*: it consumes telemetry
and emits :class:`HealthAction` values from :meth:`poll`; the runtime
decides how to act on them.  With no faults present it emits nothing and
the replay is bit-identical to a monitor-free run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "HealthConfig",
    "NodeState",
    "HealthAction",
    "QuarantineNode",
    "ReadmitNode",
    "CrashDetected",
    "RefitRequested",
    "HealthMonitor",
]


class NodeState:
    """The quarantine state machine's alphabet."""

    HEALTHY = "healthy"
    QUARANTINED = "quarantined"
    PROBATION = "probation"
    CRASHED = "crashed"


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Detection thresholds and backoff schedule (all documented in
    benchmarks/README.md §Fault tolerance)."""

    z_threshold: float = 4.0        # EWMA z-score that counts as a breach
    ratio_threshold: float = 1.5    # hard observed/predicted trip
    ewma_decay: float = 0.5         # residual EWMA decay (higher = slower)
    sigma_floor: float = 0.05       # log-residual stddev floor for the z-score
    suspect_epochs: int = 2         # consecutive breaches before quarantine
    crash_epochs: int = 2           # consecutive missing epochs before crash
    backoff_initial: int = 2        # epochs quarantined before probation
    backoff_max: int = 32           # backoff doubling cap
    probation_epochs: int = 2       # clean probation epochs before healthy
    drift_ratio: float = 1.10       # job-mean residual that counts as drift
    drift_epochs: int = 4           # sustained drift epochs before a refit
    numeric_suspect_epochs: int = 2  # consecutive anomalous-gradient epochs
                                     # before quarantine (numeric channel)


# -- actions the runtime reconciles ------------------------------------------


@dataclasses.dataclass(frozen=True)
class HealthAction:
    epoch: int


@dataclasses.dataclass(frozen=True)
class QuarantineNode(HealthAction):
    node: int
    job: str
    backoff: int


@dataclasses.dataclass(frozen=True)
class ReadmitNode(HealthAction):
    node: int


@dataclasses.dataclass(frozen=True)
class CrashDetected(HealthAction):
    node: int
    job: str


@dataclasses.dataclass(frozen=True)
class RefitRequested(HealthAction):
    job: str


class _NodeHealth:
    """Per-node filter + state machine state."""

    __slots__ = (
        "state", "ewma", "var", "count", "breaches", "missing",
        "backoff", "release_epoch", "probation_left", "quarantines",
        "transitions", "numeric_breaches",
    )

    def __init__(self) -> None:
        self.state = NodeState.HEALTHY
        self.ewma = 0.0          # EWMA of the log residual
        self.var = 0.0           # EWMA of its squared deviation
        self.count = 0
        self.breaches = 0
        self.missing = 0
        self.backoff = 0
        self.release_epoch: Optional[int] = None
        self.probation_left = 0
        self.quarantines = 0
        self.transitions: List[Tuple[int, str]] = []
        self.numeric_breaches = 0

    def transition(self, epoch: int, state: str) -> None:
        self.state = state
        self.transitions.append((epoch, state))


class HealthMonitor:
    """Consumes per-epoch node telemetry; emits recovery actions.

    Drive with one :meth:`observe_job` call per running job per epoch,
    then one :meth:`tick` per epoch (quarantine-release bookkeeping),
    then drain :meth:`poll`.  ``detections`` is the append-only log the
    fault-telemetry accounting reads (``{"kind", "node", "job", "epoch"}``).
    """

    def __init__(self, config: Optional[HealthConfig] = None) -> None:
        self.config = config or HealthConfig()
        self.nodes: Dict[int, _NodeHealth] = {}
        self.detections: List[Dict[str, object]] = []
        self._drift: Dict[str, int] = {}
        self._pending: List[HealthAction] = []

    # -- observability ---------------------------------------------------

    def node(self, node_id: int) -> _NodeHealth:
        if node_id not in self.nodes:
            self.nodes[node_id] = _NodeHealth()
        return self.nodes[node_id]

    def state(self, node_id: int) -> str:
        return self.nodes[node_id].state if node_id in self.nodes else NodeState.HEALTHY

    def states(self) -> Dict[int, str]:
        return {nid: h.state for nid, h in sorted(self.nodes.items())}

    def transitions(self, node_id: int) -> List[Tuple[int, str]]:
        return list(self.node(node_id).transitions)

    # -- ingestion -------------------------------------------------------

    def observe_job(
        self,
        job: str,
        epoch: int,
        node_ids: Sequence[int],
        observed_times: Sequence[Optional[float]],
        predicted_times: Sequence[float],
    ) -> None:
        """One job's epoch telemetry: per held node, the observed mean
        compute time (``None`` if the node reported nothing this epoch)
        and the model-predicted time for the same local batch."""
        cfg = self.config
        residuals: List[float] = []
        for nid, obs, pred in zip(node_ids, observed_times, predicted_times):
            h = self.node(int(nid))
            if h.state in (NodeState.QUARANTINED, NodeState.CRASHED):
                continue  # holds no work we trust; nothing to ingest
            if obs is None:
                h.missing += 1
                h.breaches = 0
                if h.missing >= cfg.crash_epochs:
                    h.transition(epoch, NodeState.CRASHED)
                    self.detections.append(
                        {"kind": "crash", "node": int(nid), "job": job, "epoch": epoch}
                    )
                    self._pending.append(
                        CrashDetected(epoch=epoch, node=int(nid), job=job)
                    )
                continue
            h.missing = 0
            if pred <= 0.0 or obs <= 0.0:
                continue
            x = math.log(obs / pred)
            residuals.append(x)
            sigma = max(math.sqrt(h.var), cfg.sigma_floor)
            breach = (
                (x - h.ewma) / sigma > cfg.z_threshold
                or obs / pred > cfg.ratio_threshold * math.exp(h.ewma)
            ) and h.count >= 1
            if breach:
                h.breaches += 1
                trip = (
                    1 if h.state == NodeState.PROBATION else cfg.suspect_epochs
                )  # a flap re-quarantines on the first probation breach
                if h.breaches >= trip:
                    self._quarantine(h, int(nid), job, epoch)
            else:
                h.breaches = 0
                if h.state == NodeState.PROBATION:
                    h.probation_left -= 1
                    if h.probation_left <= 0:
                        h.transition(epoch, NodeState.HEALTHY)
                # The filter only learns from non-breach epochs, so a
                # straggler cannot drag its own baseline up and escape.
                d = cfg.ewma_decay
                if h.count == 0:
                    h.ewma, h.var = x, 0.0
                else:
                    h.var = d * h.var + (1 - d) * (x - h.ewma) ** 2
                    h.ewma = d * h.ewma + (1 - d) * x
                h.count += 1
        self._observe_drift(job, epoch, residuals)

    def observe_numerics(
        self,
        job: str,
        epoch: int,
        node_ids: Sequence[int],
        anomaly_counts: Sequence[int],
    ) -> None:
        """The numerical-health channel: per held node, how many of this
        epoch's steps the gradient anomaly guard excluded the node from
        Eq. (9) aggregation (non-finite or norm-outlier contribution).

        A node anomalous for ``numeric_suspect_epochs`` consecutive epochs
        is quarantined through the same state machine timing faults use —
        and, as with timing breaches, a single anomalous epoch during
        probation re-quarantines immediately (a numerically flapping node
        doubles its backoff).  Clean epochs reset the streak.
        """
        cfg = self.config
        for nid, count in zip(node_ids, anomaly_counts):
            h = self.node(int(nid))
            if h.state in (NodeState.QUARANTINED, NodeState.CRASHED):
                continue
            if int(count) <= 0:
                h.numeric_breaches = 0
                continue
            h.numeric_breaches += 1
            if h.numeric_breaches == 1:
                self.detections.append(
                    {"kind": "numeric", "node": int(nid), "job": job, "epoch": epoch}
                )
            trip = 1 if h.state == NodeState.PROBATION else cfg.numeric_suspect_epochs
            if h.numeric_breaches >= trip:
                h.numeric_breaches = 0
                self._quarantine(h, int(nid), job, epoch)

    def _quarantine(self, h: _NodeHealth, nid: int, job: str, epoch: int) -> None:
        h.quarantines += 1
        h.backoff = (
            self.config.backoff_initial
            if h.quarantines == 1
            else min(h.backoff * 2, self.config.backoff_max)
        )
        h.release_epoch = epoch + h.backoff
        h.breaches = 0
        h.transition(epoch, NodeState.QUARANTINED)
        self.detections.append(
            {"kind": "quarantine", "node": nid, "job": job, "epoch": epoch}
        )
        self._pending.append(
            QuarantineNode(epoch=epoch, node=nid, job=job, backoff=h.backoff)
        )

    def _observe_drift(self, job: str, epoch: int, residuals: List[float]) -> None:
        """Sustained mild whole-job drift (below the straggler trip) means
        the performance model is stale, not that a node is sick."""
        cfg = self.config
        if residuals and (
            sum(residuals) / len(residuals) > math.log(cfg.drift_ratio)
        ):
            self._drift[job] = self._drift.get(job, 0) + 1
            if self._drift[job] >= cfg.drift_epochs:
                self._drift[job] = 0
                self.detections.append(
                    {"kind": "drift", "node": None, "job": job, "epoch": epoch}
                )
                self._pending.append(RefitRequested(epoch=epoch, job=job))
        else:
            self._drift[job] = 0

    def tick(self, epoch: int) -> None:
        """End-of-epoch bookkeeping: release quarantined nodes whose
        backoff expired into probation."""
        for nid, h in sorted(self.nodes.items()):
            if (
                h.state == NodeState.QUARANTINED
                and h.release_epoch is not None
                and epoch >= h.release_epoch
            ):
                h.probation_left = self.config.probation_epochs
                h.breaches = 0
                h.transition(epoch, NodeState.PROBATION)
                self._pending.append(ReadmitNode(epoch=epoch, node=nid))

    def poll(self) -> List[HealthAction]:
        """Drain pending actions (deterministic order: ingestion order)."""
        out, self._pending = self._pending, []
        return out

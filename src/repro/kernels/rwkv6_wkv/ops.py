"""Public entry for the WKV kernel: model layout (B, T, H, K) + u (H, K)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.rwkv6_wkv import wkv_kernel


def wkv(r, k, v, log_w, u, *, chunk: int = 64, interpret: bool = True):
    """r/k/v/log_w: (B, T, H, K); u: (H, K).
    Returns (out (B, T, H, K), final state (B, H, K, K))."""
    b, t, h, kk = r.shape
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, t, kk)
    uu = jnp.broadcast_to(u[None], (b, h, kk)).reshape(b * h, kk)
    pad = (-t) % chunk
    args = [fold(r), fold(k), fold(v), fold(log_w)]
    if pad:
        args = [jnp.pad(x, ((0, 0), (0, pad), (0, 0))) for x in args]
    out, s = wkv_kernel(*args, uu, chunk=min(chunk, t + pad), interpret=interpret)
    out = out[:, :t]
    return (
        out.reshape(b, h, t, kk).transpose(0, 2, 1, 3),
        s.reshape(b, h, kk, kk),
    )

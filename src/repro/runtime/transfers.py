"""Host<->device transfer accounting for the runtime hot path.

The fused-epoch perf gate (benchmarks/bench_overhead.py) is stated in
host<->device transfers per adaptive epoch, so the counting has to live at
the seams where `RealBackend` actually ships or fetches arrays — not be
inferred from jit internals.  Methodology (also in benchmarks/README.md):

* every `jnp.asarray` / `jax.device_put` of host data the backend performs
  counts as one h2d transfer (scalars included: a shipped scalar is still
  a host->device round trip in the dispatch path);
* every `float(...)` / `np.asarray(...)` / `jax.device_get` pull of a
  device value counts as one d2h transfer per fetched leaf — these are the
  synchronization points the fused path exists to eliminate.

The count is deliberately conservative for the two-program baseline: the
separate OptPerf sweep jit's own transfers are *not* counted, so the
fused/two-program ratio reported by the bench is a lower bound.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

__all__ = ["TransferCounter"]


@dataclasses.dataclass
class TransferCounter:
    """Counts host->device (h2d) and device->host (d2h) array transfers."""

    h2d: int = 0
    d2h: int = 0

    def count_h2d(self, n: int = 1) -> None:
        self.h2d += int(n)

    def count_d2h(self, n: int = 1) -> None:
        self.d2h += int(n)

    @property
    def total(self) -> int:
        return self.h2d + self.d2h

    def reset(self) -> None:
        self.h2d = 0
        self.d2h = 0

    def snapshot(self) -> Dict[str, int]:
        return {"h2d": self.h2d, "d2h": self.d2h, "total": self.total}

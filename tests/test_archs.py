"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED variant of the same family, runs one forward/train step and one
decode step on CPU with shape and finiteness checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX-compiling; excluded from the fast lane

from repro.configs import REDUCED_SHAPES, arch_ids, get_api
from repro.optim import constant_schedule, sgd
from repro.train.step import build_train_step

ARCHS = arch_ids()


def _batch(api, rng, B, S):
    if api.is_encoder_decoder:
        st = max(S // 4, 4)
        return {
            "audio_embed": jax.random.normal(rng, (B, S, api.cfg.d_model), jnp.bfloat16),
            "tokens": jax.random.randint(rng, (B, st), 0, api.cfg.vocab),
            "labels": jax.random.randint(rng, (B, st), 0, api.cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(rng, (B, S), 0, api.cfg.vocab),
        "labels": jax.random.randint(rng, (B, S), 0, api.cfg.vocab),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    api = get_api(arch, reduced=True)
    rng = jax.random.PRNGKey(0)
    shape = REDUCED_SHAPES["train_4k"]
    params = api.init(rng)
    batch = _batch(api, rng, shape.global_batch, shape.seq_len)

    logits = api.logits(params, batch)
    label_seq = batch["labels"].shape[1]
    assert logits.shape == (shape.global_batch, label_seq, api.cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    opt = sgd(constant_schedule(0.1))
    step = jax.jit(build_train_step(api, opt))
    opt_state = opt.init(params)
    p2, _, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # Parameters actually changed.
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))
    )
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    api = get_api(arch, reduced=True)
    rng = jax.random.PRNGKey(0)
    shape = REDUCED_SHAPES["decode_32k"]
    params = api.init(rng)
    cache = api.init_cache(shape.global_batch, shape.seq_len)
    tok = jnp.zeros((shape.global_batch, 1), jnp.int32)
    step = jax.jit(api.decode_step)
    logits, cache2 = step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (shape.global_batch, 1, api.cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    logits, _ = step(params, cache2, tok, jnp.int32(1))
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize(
    "arch",
    [a for a in ARCHS if get_api(a, reduced=True).supports_long_context()],
)
def test_long_context_decode_reduced(arch):
    """long_500k analogue at reduced scale: cache stays bounded / ring."""
    api = get_api(arch, reduced=True)
    rng = jax.random.PRNGKey(0)
    shape = REDUCED_SHAPES["long_500k"]
    params = api.init(rng)
    cache = api.init_cache(shape.global_batch, shape.seq_len)
    step = jax.jit(api.decode_step)
    tok = jnp.zeros((shape.global_batch, 1), jnp.int32)
    pos_total = shape.seq_len
    # Step a few positions deep into the (reduced) long context.
    for pos in (0, 1, pos_total // 2, pos_total - 2):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        assert bool(jnp.isfinite(logits).all())


def test_whisper_skips_long_context():
    api = get_api("whisper-large-v3", reduced=True)
    assert not api.supports_long_context()


@pytest.mark.parametrize("arch", ["llama3-8b", "rwkv6-7b", "mixtral-8x7b", "hymba-1.5b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full forward logits."""
    api = get_api(arch, reduced=True)
    rng = jax.random.PRNGKey(0)
    params = api.init(rng)
    B, S = 2, 8
    toks = jax.random.randint(rng, (B, S), 0, api.cfg.vocab)
    full = api.logits(params, {"tokens": toks})
    cache = api.init_cache(B, 16)
    step = jax.jit(api.decode_step)
    outs = []
    for i in range(S):
        lg, cache = step(params, cache, toks[:, i : i + 1], jnp.int32(i))
        outs.append(np.asarray(lg[:, 0]))
    outs = np.stack(outs, axis=1)
    full = np.asarray(full)
    # bf16 compute: compare argmax agreement + loose numeric tolerance.
    scale = np.maximum(np.abs(full).max(), 1.0)
    np.testing.assert_allclose(outs / scale, full / scale, atol=0.08)


def test_param_counts_full_configs():
    """Full configs instantiate (abstractly) with plausible parameter counts."""
    expect = {
        "llama3-8b": (7.5e9, 9.0e9),
        "minitron-4b": (3.5e9, 5.5e9),
        "olmo-1b": (1.0e9, 1.6e9),
        "internlm2-20b": (18e9, 23e9),
        "chameleon-34b": (32e9, 37e9),
        "mixtral-8x7b": (45e9, 48e9),
        "deepseek-v2-236b": (2.0e11, 2.6e11),
        "rwkv6-7b": (6e9, 9e9),
        "hymba-1.5b": (1.2e9, 2.2e9),
        "whisper-large-v3": (1.4e9, 2.1e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_api(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3g} params outside [{lo:.3g}, {hi:.3g}]"

"""Eq. (9) weighted gradient aggregation: the explicit per-node combination,
the padded-shard + sample-weight pjit realization, and their exact
equivalence to the single-worker union-batch gradient."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX-compiling; excluded from the fast lane

from repro.core.aggregation import (
    padded_batch_layout,
    ratios,
    sample_weights,
    weighted_aggregate,
)
from repro.configs import get_api


def test_ratios():
    r = ratios([10, 30, 60])
    assert np.allclose(r, [0.1, 0.3, 0.6])
    with pytest.raises(ValueError):
        ratios([0, 0])


def test_weighted_aggregate_pytree():
    g1 = {"a": jnp.ones(3), "b": jnp.full((2, 2), 2.0)}
    g2 = {"a": jnp.zeros(3), "b": jnp.full((2, 2), 4.0)}
    agg = weighted_aggregate([g1, g2], [1, 3])
    assert np.allclose(agg["a"], 0.25)
    assert np.allclose(agg["b"], 0.25 * 2 + 0.75 * 4)


def test_padded_layout_and_weights():
    b_max, mask = padded_batch_layout([2, 5, 3])
    assert b_max == 5
    assert mask.shape == (3, 5)
    assert mask.sum() == 10
    w = sample_weights([2, 5, 3])
    assert w.shape == (3, 5)
    assert w.sum() == pytest.approx(1.0)
    # row sums are r_i
    assert np.allclose(w.sum(axis=1), np.array([2, 5, 3]) / 10)


def _grad_mean(api, params, tokens, labels):
    def f(p):
        loss, _ = api.loss(p, {"tokens": tokens, "labels": labels})
        return loss

    return jax.grad(f)(params)


def test_eq9_equivalence_with_union_batch():
    """sum_i r_i g_i == gradient of the per-sample-mean loss over the union
    batch == weighted-sum loss over the padded layout."""
    api = get_api("olmo-1b", reduced=True)
    rng = jax.random.PRNGKey(0)
    params = api.init(rng)
    batches = [2, 5, 3]
    B = sum(batches)
    S = 16
    tokens = jax.random.randint(rng, (B, S), 0, api.cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, api.cfg.vocab)

    # (a) union-batch gradient
    g_union = _grad_mean(api, params, tokens, labels)

    # (b) per-node gradients combined with Eq. (9)
    grads = []
    ofs = 0
    for b in batches:
        g = _grad_mean(api, params, tokens[ofs : ofs + b], labels[ofs : ofs + b])
        grads.append(g)
        ofs += b
    g_eq9 = weighted_aggregate(grads, batches)

    # (c) padded layout + per-sample weights, single loss call
    b_max, mask = padded_batch_layout(batches)
    tok_p = np.zeros((len(batches), b_max, S), np.int32)
    lab_p = np.zeros((len(batches), b_max, S), np.int32)
    ofs = 0
    for i, b in enumerate(batches):
        tok_p[i, :b] = tokens[ofs : ofs + b]
        lab_p[i, :b] = labels[ofs : ofs + b]
        ofs += b
    w = sample_weights(batches).reshape(-1)  # flat (n*b_max,)

    def padded_loss(params):
        loss, _ = api.loss(
            params,
            {
                "tokens": jnp.asarray(tok_p).reshape(-1, S),
                "labels": jnp.asarray(lab_p).reshape(-1, S),
                "weights": jnp.asarray(w),
            },
        )
        return loss

    g_padded = jax.grad(padded_loss)(params)

    for ga, gb in zip(jax.tree_util.tree_leaves(g_union), jax.tree_util.tree_leaves(g_eq9)):
        np.testing.assert_allclose(
            np.asarray(ga, np.float32), np.asarray(gb, np.float32), rtol=2e-2, atol=2e-3
        )
    for ga, gb in zip(jax.tree_util.tree_leaves(g_union), jax.tree_util.tree_leaves(g_padded)):
        np.testing.assert_allclose(
            np.asarray(ga, np.float32), np.asarray(gb, np.float32), rtol=2e-2, atol=2e-3
        )

"""The ClusterRuntime event alphabet.

Every change to the cluster state — jobs coming and going, nodes failing
and returning, performance-model refreshes, policy preemptions — is an
immutable, timestamped :class:`Event`.  The runtime consumes them from a
single queue in ``(time, post-order)`` order, so a trace replays
deterministically: same events in, same reconcile decisions out.

The alphabet is intentionally small (the Pollux/Sia-style cluster
simulation needs exactly these six):

* :class:`JobArrival`    — a job enters the queue (or a preempted job
  resumes: arrivals are idempotent on the handle, keyed by job name).
* :class:`JobCompletion` — a job finishes and releases its nodes.
* :class:`Preemption`    — the operator/policy pulls a job off the cluster;
  its handle survives (models retained), its execution backend's
  statistical state is checkpointed (params/opt-state/GNS for a real
  backend — to ``<checkpoint_dir>/<job>.ckpt.npz`` when the runtime has
  one), and a later arrival resumes it with that state restored bit-exactly.
* :class:`NodeJoin` / :class:`NodeLeave` — cluster membership churn.  Node
  ids are stable: a leave marks the id unavailable, a join brings it back.
* :class:`ModelRefit`    — a job's per-node performance coefficients were
  re-fitted (the per-epoch OLS path); carries either an explicit refreshed
  :class:`~repro.core.scheduler.JobSpec` or a seeded drift to apply.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.scheduler import JobSpec

__all__ = [
    "Event",
    "JobArrival",
    "JobCompletion",
    "Preemption",
    "NodeJoin",
    "NodeLeave",
    "ModelRefit",
    "describe",
]


@dataclasses.dataclass(frozen=True)
class Event:
    """Base event: ``time`` is the simulated timestamp the event fires at.
    Ties are broken by post order (the runtime's queue sequence number)."""

    time: float


@dataclasses.dataclass(frozen=True)
class JobArrival(Event):
    spec: JobSpec

    @property
    def job(self) -> str:
        return self.spec.name


@dataclasses.dataclass(frozen=True)
class JobCompletion(Event):
    job: str


@dataclasses.dataclass(frozen=True)
class Preemption(Event):
    job: str


@dataclasses.dataclass(frozen=True)
class NodeJoin(Event):
    nodes: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class NodeLeave(Event):
    nodes: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class ModelRefit(Event):
    """Per-epoch OLS-refit of one job's performance models.

    If ``spec`` is given it replaces the job's spec verbatim; otherwise the
    current spec's node coefficients are drifted by the seeded lognormal
    jitter of :func:`repro.core.simulator.drift_model` (``rel``/``seed``) —
    the same drift vehicle the warm-start benchmarks use, so refit traces
    are reproducible without carrying model payloads around.
    """

    job: str = ""
    rel: float = 0.1
    seed: int = 0
    spec: Optional[JobSpec] = None


def describe(event: Event) -> str:
    """One-line human description (trace logs and reconcile records)."""
    if isinstance(event, JobArrival):
        return f"arrive({event.spec.name})"
    if isinstance(event, JobCompletion):
        return f"complete({event.job})"
    if isinstance(event, Preemption):
        return f"preempt({event.job})"
    if isinstance(event, NodeJoin):
        return f"node_join{tuple(event.nodes)}"
    if isinstance(event, NodeLeave):
        return f"node_leave{tuple(event.nodes)}"
    if isinstance(event, ModelRefit):
        return f"refit({event.job}, rel={event.rel})"
    return type(event).__name__

"""Stacked on-device OptPerf engine: stacked-jax vs NumPy-stacked vs the
scalar water-fill oracle across seeded ragged padded clusters, warm-seeded
device brackets, device-export caching/invalidation on in-place coefficient
refresh, and the jax scheduler engine."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX-compiling; excluded from the fast lane

jax = pytest.importorskip("jax")

from repro.core.optperf import (  # noqa: E402
    solve_optperf_stacked,
    solve_optperf_waterfill,
)
from repro.core.optperf_jax import (  # noqa: E402
    HAS_JAX,
    solve_optperf_stacked_jax,
    stacked_device_coeffs,
)
from repro.core.perf_model import (  # noqa: E402
    ClusterPerfModel,
    CommModel,
    NodePerfModel,
    StackedClusterModel,
)
from repro.core.scheduler import Scheduler, allocate, random_jobs  # noqa: E402


def random_cluster(rng: np.random.Generator, n: int) -> ClusterPerfModel:
    nodes = tuple(
        NodePerfModel(
            q=float(rng.uniform(1e-4, 8e-3)),
            s=float(rng.uniform(0.0, 0.02)),
            k=float(rng.uniform(1e-4, 8e-3)),
            m=float(rng.uniform(0.0, 0.02)),
        )
        for _ in range(n)
    )
    comm = CommModel(
        t_o=float(10.0 ** rng.uniform(-4, -1)),
        t_u=float(rng.uniform(0.0, 0.02)),
        gamma=float(rng.uniform(0.02, 0.6)),
    )
    return ClusterPerfModel(nodes=nodes, comm=comm)


def ragged_stack(rng: np.random.Generator, rows: int):
    """A padded stack of ``rows`` independent clusters with ragged sizes
    drawn log-uniformly from [2, 256]."""
    sizes = np.unique(
        np.round(2.0 ** rng.uniform(1.0, 8.0, size=rows)).astype(int)
    )
    rng.shuffle(sizes)
    models = [random_cluster(rng, int(n)) for n in sizes[:rows]]
    while len(models) < rows:
        models.append(random_cluster(rng, int(rng.integers(2, 17))))
    totals = rng.uniform(32.0, 8192.0, size=rows)
    return models, StackedClusterModel.from_models(models), totals


def test_has_jax_in_test_image():
    assert HAS_JAX


def test_stacked_jax_parity_100_seeded_ragged_clusters():
    """Acceptance: over 100 seeded padded clusters (ragged row sizes,
    n in [2, 256]) the stacked jax engine matches the NumPy stacked path and
    the scalar water-fill oracle to <= 1e-5 relative opt_perf in the default
    float32 device sweep, with exact-sum padded partitions."""
    checked = 0
    for seed in range(10):
        rng = np.random.default_rng(9000 + seed)
        models, stack, totals = ragged_stack(rng, rows=10)
        jx = solve_optperf_stacked_jax(stack, totals)
        np_sol = solve_optperf_stacked(stack, totals)
        rel = np.abs(jx.opt_perfs - np_sol.opt_perfs) / np_sol.opt_perfs
        assert float(rel.max()) <= 1e-5
        for r, model in enumerate(models):
            wf = solve_optperf_waterfill(model, float(totals[r]))
            assert jx.opt_perfs[r] == pytest.approx(wf.opt_perf, rel=1e-5)
            sol = jx.solution(r)
            assert len(sol.batches) == model.n  # padding dropped
            assert sum(sol.batches) == pytest.approx(totals[r], rel=1e-9)
            assert min(sol.batches) >= 0.0
            checked += 1
    assert checked == 100


def test_stacked_jax_warm_start_matches_cold():
    rng = np.random.default_rng(77)
    _, stack, totals = ragged_stack(rng, rows=8)
    cold = solve_optperf_stacked_jax(stack, totals)
    warm = solve_optperf_stacked_jax(stack, totals, warm_start=cold.t_stars)
    np.testing.assert_allclose(warm.opt_perfs, cold.opt_perfs, rtol=1e-5)
    assert warm.method == "waterfill/stacked-jax+warm"
    # Stale/garbage seeds are re-validated on device (lows reset, highs
    # clamped to the masked best-single-node ceiling) and stay correct.
    for garbage in (np.zeros(totals.shape), np.full(totals.shape, 1e9)):
        stale = solve_optperf_stacked_jax(stack, totals, warm_start=garbage)
        np.testing.assert_allclose(stale.opt_perfs, cold.opt_perfs, rtol=1e-4)


def test_stacked_jax_warm_shape_mismatch_raises():
    rng = np.random.default_rng(78)
    _, stack, totals = ragged_stack(rng, rows=4)
    with pytest.raises(ValueError):
        solve_optperf_stacked_jax(stack, totals, warm_start=np.zeros(3))


def test_stacked_device_coeffs_cached_and_invalidated():
    """The device export is cached per stack instance; an in-place
    coefficient refresh (the scheduler's OLS-refit path) must route through
    ``invalidate_device_cache`` or the solvers keep reading the old regime —
    the regression this guards: a stale export after refresh silently
    reusing old-regime brackets."""
    rng = np.random.default_rng(79)
    _, stack, totals = ragged_stack(rng, rows=4)
    a = stacked_device_coeffs(stack)
    assert stacked_device_coeffs(stack) is a  # cached per instance
    before = solve_optperf_stacked_jax(stack, totals)

    # Refresh coefficients in place (every node 2x slower) WITHOUT copying
    # the stack, as a per-epoch refit over persistent buffers would.
    mutable = {
        name: np.array(getattr(stack, name))
        for name in ("alphas", "cs", "betas", "ds", "ks", "ms")
    }
    refreshed = StackedClusterModel(
        t_o=stack.t_o, t_u=stack.t_u, gamma=stack.gamma, mask=stack.mask,
        **mutable,
    )
    ref_before = solve_optperf_stacked_jax(refreshed, totals)
    np.testing.assert_allclose(ref_before.opt_perfs, before.opt_perfs, rtol=1e-6)
    for name in ("alphas", "cs", "betas", "ds"):
        mutable[name] *= 2.0
    refreshed.invalidate_device_cache()
    after = solve_optperf_stacked_jax(refreshed, totals)
    fresh = solve_optperf_stacked_jax(
        StackedClusterModel(
            t_o=stack.t_o, t_u=stack.t_u, gamma=stack.gamma, mask=stack.mask,
            **mutable,
        ),
        totals,
    )
    np.testing.assert_allclose(after.opt_perfs, fresh.opt_perfs, rtol=1e-6)
    # The refresh really changed the answers (the old export would not).
    assert float(np.min(after.opt_perfs / before.opt_perfs)) > 1.5


@pytest.mark.parametrize("seed", range(4))
def test_jax_scheduler_engine_matches_scalar_oracle(seed):
    jobs = random_jobs(4, 12, seed)
    a_j = allocate(jobs, 12, engine="jax")
    a_s = allocate(jobs, 12, engine="scalar")
    assert a_j.assignment == a_s.assignment
    for name in a_j.goodputs:
        assert a_j.goodputs[name] == pytest.approx(a_s.goodputs[name], rel=1e-12)


def test_jax_scheduler_incremental_matches_full():
    jobs = random_jobs(5, 12, 3)
    sched = Scheduler(12, engine="jax")
    for job in jobs[:4]:
        sched.add_job(job)
    inc = sched.add_job(jobs[4])
    full = allocate(jobs, 12, engine="jax")
    assert inc.assignment == full.assignment
    for name in full.goodputs:
        assert inc.goodputs[name] == pytest.approx(full.goodputs[name], rel=1e-12)


def test_inplace_refresh_without_invalidate_trips_stack_stamp():
    """Regression (stale-cache fix): an in-place coefficient refresh that
    forgets ``invalidate_device_cache()`` must no longer serve stale device
    coefficients -- the content stamp recorded at export time is re-checked
    on every solve."""
    rng = np.random.default_rng(83)
    _, stack, totals = ragged_stack(rng, rows=4)
    mutable = {
        name: np.array(getattr(stack, name))
        for name in ("alphas", "cs", "betas", "ds", "ks", "ms")
    }
    refreshed = StackedClusterModel(
        t_o=stack.t_o, t_u=stack.t_u, gamma=stack.gamma, mask=stack.mask,
        **mutable,
    )
    before = solve_optperf_stacked_jax(refreshed, totals)
    stale = stacked_device_coeffs(refreshed)
    for name in ("alphas", "cs", "betas", "ds"):
        mutable[name] *= 2.0
    # NO invalidate_device_cache() here -- the stamp must catch it.
    after = solve_optperf_stacked_jax(refreshed, totals)
    assert stacked_device_coeffs(refreshed) is not stale
    fresh = solve_optperf_stacked_jax(
        StackedClusterModel(
            t_o=stack.t_o, t_u=stack.t_u, gamma=stack.gamma, mask=stack.mask,
            **{k: np.array(v) for k, v in mutable.items()},
        ),
        totals,
    )
    np.testing.assert_allclose(after.opt_perfs, fresh.opt_perfs, rtol=1e-6)
    assert float(np.min(after.opt_perfs / before.opt_perfs)) > 1.5

"""BatchSizePolicy: pluggable total-batch-size adaptation laws.

Cannikin's contribution is *adaptive batch size* training over heterogeneous
clusters, but GNS-driven goodput selection is only one point in the design
space the paper argues over.  This module extracts the total-batch decision
into a protocol the controller (and through it the runtime, per
:attr:`~repro.core.scheduler.JobSpec.batch_policy`) can swap per job:

* ``observe(telemetry)`` ingests EpochRecord-like telemetry (duck-typed:
  anything carrying ``epoch`` / ``total_batch`` / ``mean_loss`` /
  ``b_noise`` attributes — :class:`PolicyTelemetry` is the canonical
  shape);
* ``propose(model, bounds)`` returns a :class:`BatchProposal` — the next
  total batch size *and* the learning-rate scale that goes with it (each
  policy pins its own LR-scaling rule explicitly; see :func:`lr_scale_for`);
* ``state()`` / ``load_state()`` round-trip the policy's adaptation state
  bit-exactly as a checkpointable pytree of numpy scalars, so it rides the
  runtime's existing preemption checkpoint path.

Registered implementations (``BATCH_POLICIES``):

===============  ========================================================
``cannikin-gns``  the paper's law: :class:`~repro.core.goodput.
                  BatchSizeSelector` sweep + AdaScale gain, driven by the
                  Theorem-4.1 gradient-noise scale.  Bit-identical to the
                  pre-protocol controller path (golden-pinned).  Requires
                  gradient telemetry (``requires={"gns"}``).
``adadamp``       loss-ratio damper: B_k = ceil(B_0 * L_0 / L_k) — batch
                  grows as the loss falls (Sievert's AdaDamp).  Requires
                  loss telemetry (``requires={"loss"}``).
``padadamp``      practical/linear-ramp damper: B_k = B_0 + ceil(r * k).
                  Schedule-driven — no gradient or loss telemetry needed.
``geodamp``       geometric damper: B_k = B_0 * f^(k // d) (AdaBatch's
                  batch-doubling schedule).  Schedule-driven.
``fixed``         always the reference batch (the §5.2.2 fixed-batch mode
                  behind the protocol, so provenance is uniform).
===============  ========================================================

Schedule-driven dampers need no gradient telemetry, which makes adaptive
batch sizes meaningful on :class:`~repro.runtime.backend.SimBackend` — not
just the real-gradient backend.  The protocol is observation-driven on
purpose (DYNAMIX-style learned/RL policies plug in via
:func:`register_batch_policy` without another refactor).
"""
from __future__ import annotations

import dataclasses
import inspect
import math
from typing import Any, Dict, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro.core.goodput import BatchSizeSelector, adascale_gain, sqrt_lr_scale
from repro.core.optperf import OptPerfSolution
from repro.core.perf_model import ClusterPerfModel

__all__ = [
    "BatchBounds",
    "BatchProposal",
    "PolicyTelemetry",
    "BatchSizePolicy",
    "CannikinGNSPolicy",
    "FixedPolicy",
    "AdaDampPolicy",
    "PadaDampPolicy",
    "GeoDampPolicy",
    "BATCH_POLICIES",
    "LR_RULES",
    "lr_scale_for",
    "make_batch_policy",
    "register_batch_policy",
    "policy_requirements",
]


# ---------------------------------------------------------------------------
# protocol shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BatchBounds:
    """Total-batch bounds the controller derives from its candidate set
    (always containing the reference batch)."""

    min_total: int
    max_total: int

    def clamp(self, total: float) -> int:
        return int(min(max(int(round(total)), self.min_total), self.max_total))


@dataclasses.dataclass(frozen=True)
class BatchProposal:
    """What a policy proposes for the next epoch.

    ``lr_scale`` is part of the proposal on purpose: the LR-scaling rule is
    each policy's explicit choice (AdaScale for GNS-driven selection, linear
    or sqrt for AdaBatch-style schedules), never an implicit controller
    default.  ``solution``/``goodput`` are set only by policies that already
    solved OptPerf for the proposed total (the controller reuses the
    solution instead of re-solving).
    """

    total_batch: int
    lr_scale: float
    solution: Optional[OptPerfSolution] = None
    goodput: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class PolicyTelemetry:
    """EpochRecord-like observation fed to ``observe`` once per planned
    epoch: the previous epoch's total batch and mean loss (NaN for
    gradient-free backends) plus the controller's current GNS estimate."""

    epoch: int
    total_batch: int
    mean_loss: float
    b_noise: float
    phase: str = ""


@runtime_checkable
class BatchSizePolicy(Protocol):
    """The total-batch-size adaptation seam.

    ``requires`` names the telemetry channels the policy cannot function
    without (``"gns"`` — gradient-noise scale, ``"loss"`` — training loss);
    an empty set marks a schedule-driven policy that adapts on any backend.
    """

    name: str
    requires: frozenset
    lr_rule: str

    def observe(self, telemetry: Any) -> None: ...

    def propose(
        self, model: ClusterPerfModel, bounds: BatchBounds
    ) -> BatchProposal: ...

    def state(self) -> Dict[str, Any]: ...

    def load_state(self, state: Dict[str, Any]) -> None: ...


# ---------------------------------------------------------------------------
# LR-scaling rules (satellite: explicit, tested coupling)
# ---------------------------------------------------------------------------

LR_RULES = ("adascale", "sqrt", "linear", "none")


def lr_scale_for(
    rule: str, *, batch: float, ref_batch: float, b_noise: float = float("inf")
) -> float:
    """The learning-rate scale a policy's rule assigns to ``batch``.

    ``adascale`` — the AdaScale gain (GNS-aware; degrades to linear B/B0
    when ``b_noise`` is unknown); ``sqrt`` — square-root scaling (Adam
    workloads, Table 4); ``linear`` — B/B0 (AdaBatch scales LR by the same
    factor as the batch at each schedule step); ``none`` — 1.0 (AdaDamp's
    position: the growing batch itself substitutes for LR decay).
    """
    if rule == "adascale":
        return adascale_gain(b_noise, batch, ref_batch)
    if rule == "sqrt":
        return sqrt_lr_scale(batch, ref_batch)
    if rule == "linear":
        return float(batch / ref_batch)
    if rule == "none":
        return 1.0
    raise ValueError(f"unknown lr rule {rule!r}; choose from {LR_RULES}")


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------


BATCH_POLICIES: Dict[str, type] = {}


def register_batch_policy(cls: type) -> type:
    """Class decorator: register a policy under ``cls.name`` (the RL/learned
    policy hook — new laws plug in without touching the controller)."""
    BATCH_POLICIES[cls.name] = cls
    return cls


def policy_requirements(name: str) -> frozenset:
    """The telemetry channels the named policy requires (``"gns"``,
    ``"loss"``; empty for schedule-driven policies)."""
    try:
        return BATCH_POLICIES[name].requires
    except KeyError:
        raise ValueError(
            f"unknown batch policy {name!r}; choose from {sorted(BATCH_POLICIES)}"
        ) from None


def make_batch_policy(
    name: str, *, candidates: Sequence[int], ref_batch: int, **kwargs: Any
) -> "BatchSizePolicy":
    """Build a registered policy by name.

    ``selector`` (a shared :class:`BatchSizeSelector`) is forwarded only to
    policies whose constructor accepts it; any other unexpected keyword is
    an error (typos must not silently disappear).
    """
    try:
        cls = BATCH_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown batch policy {name!r}; choose from {sorted(BATCH_POLICIES)}"
        ) from None
    params = inspect.signature(cls.__init__).parameters
    accepted = {k: v for k, v in kwargs.items() if k in params}
    rejected = set(kwargs) - set(accepted) - {"selector"}
    if rejected:
        raise TypeError(
            f"batch policy {name!r} does not accept {sorted(rejected)}"
        )
    return cls(
        candidates=tuple(int(b) for b in candidates),
        ref_batch=int(ref_batch),
        **accepted,
    )


class _PolicyBase:
    """Shared constructor surface: every policy takes the candidate set and
    the reference batch; ``lr_rule=None`` resolves to the class default."""

    name = "base"
    requires: frozenset = frozenset()
    default_lr_rule = "none"

    def __init__(
        self,
        *,
        candidates: Sequence[int],
        ref_batch: int,
        lr_rule: Optional[str] = None,
    ) -> None:
        self.candidates: Tuple[int, ...] = tuple(
            sorted(set(int(b) for b in candidates))
        )
        self.ref_batch = int(ref_batch)
        self.lr_rule = self.default_lr_rule if lr_rule is None else str(lr_rule)
        if self.lr_rule not in LR_RULES:
            raise ValueError(
                f"unknown lr rule {self.lr_rule!r}; choose from {LR_RULES}"
            )

    # default no-op surface; subclasses override what they use
    def observe(self, telemetry: Any) -> None:
        del telemetry

    def state(self) -> Dict[str, Any]:
        return {}

    def load_state(self, state: Dict[str, Any]) -> None:
        del state

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}(ref_batch={self.ref_batch}, lr_rule={self.lr_rule!r})"


@register_batch_policy
class CannikinGNSPolicy(_PolicyBase):
    """The paper's law behind the protocol: the §4.5 cached candidate sweep
    (:class:`BatchSizeSelector`) picks argmax goodput under the tracked
    gradient-noise scale; LR scale is the AdaScale gain.  Plans are
    bit-identical to the pre-protocol ``CannikinController.plan_epoch``
    path (golden-pinned in tests): ``observe`` syncs the controller's live
    ``b_noise`` immediately before every ``propose``, and the selector —
    caches, warm brackets, counters — is the same object the controller
    always owned."""

    name = "cannikin-gns"
    requires = frozenset({"gns"})
    default_lr_rule = "adascale"

    def __init__(
        self,
        *,
        candidates: Sequence[int],
        ref_batch: int,
        lr_rule: Optional[str] = None,
        selector: Optional[BatchSizeSelector] = None,
        solver: str = "algorithm1",
        engine: str = "batched",
    ) -> None:
        super().__init__(candidates=candidates, ref_batch=ref_batch, lr_rule=lr_rule)
        self.selector = selector if selector is not None else BatchSizeSelector(
            candidates=self.candidates,
            ref_batch=self.ref_batch,
            solver=solver,
            engine=engine,
        )
        self.b_noise = float("inf")

    def observe(self, telemetry: Any) -> None:
        b = getattr(telemetry, "b_noise", None)
        if b is not None:
            self.b_noise = float(b)

    def propose(
        self, model: ClusterPerfModel, bounds: BatchBounds
    ) -> BatchProposal:
        del bounds  # the candidate grid already lives within the bounds
        best, sol, gp = self.selector.select(model, self.b_noise)
        return BatchProposal(
            total_batch=int(best),
            lr_scale=lr_scale_for(
                self.lr_rule,
                batch=best,
                ref_batch=self.ref_batch,
                b_noise=self.b_noise,
            ),
            solution=sol,
            goodput=gp,
        )

    def invalidate(self) -> None:
        self.selector.invalidate()

    def state(self) -> Dict[str, Any]:
        return {"b_noise": np.float64(self.b_noise)}

    def load_state(self, state: Dict[str, Any]) -> None:
        self.b_noise = float(state["b_noise"])


@register_batch_policy
class FixedPolicy(_PolicyBase):
    """Always the reference batch — the §5.2.2 fixed-batch evaluation mode
    expressed through the protocol, so non-adaptive plans carry the same
    provenance field as adaptive ones.  Stateless: its checkpoint payload
    is empty, keeping legacy sim-job preemption snapshots byte-identical."""

    name = "fixed"
    requires: frozenset = frozenset()
    default_lr_rule = "adascale"

    def __init__(
        self,
        *,
        candidates: Sequence[int],
        ref_batch: int,
        lr_rule: Optional[str] = None,
    ) -> None:
        super().__init__(candidates=candidates, ref_batch=ref_batch, lr_rule=lr_rule)
        self.b_noise = float("inf")

    def observe(self, telemetry: Any) -> None:
        b = getattr(telemetry, "b_noise", None)
        if b is not None:
            self.b_noise = float(b)

    def propose(
        self, model: ClusterPerfModel, bounds: BatchBounds
    ) -> BatchProposal:
        del model
        total = bounds.clamp(self.ref_batch)
        return BatchProposal(
            total_batch=total,
            lr_scale=lr_scale_for(
                self.lr_rule,
                batch=total,
                ref_batch=self.ref_batch,
                b_noise=self.b_noise,
            ),
        )


class _DamperBase(_PolicyBase):
    """Shared shape for the ported damper family (AdaBatch / adadamp):
    ``start`` defaults to the reference batch; state is numpy scalars so it
    round-trips bit-exactly through the npz checkpoint path."""

    def __init__(
        self,
        *,
        candidates: Sequence[int],
        ref_batch: int,
        lr_rule: Optional[str] = None,
        start: Optional[int] = None,
    ) -> None:
        super().__init__(candidates=candidates, ref_batch=ref_batch, lr_rule=lr_rule)
        self.start = int(start) if start is not None else self.ref_batch
        self.updates = 0

    def observe(self, telemetry: Any) -> None:
        del telemetry
        self.updates += 1

    def _target(self) -> int:
        raise NotImplementedError

    def propose(
        self, model: ClusterPerfModel, bounds: BatchBounds
    ) -> BatchProposal:
        del model  # schedule-driven: the split is the controller's job
        total = bounds.clamp(self._target())
        return BatchProposal(
            total_batch=total,
            lr_scale=lr_scale_for(
                self.lr_rule, batch=total, ref_batch=self.ref_batch
            ),
        )

    def state(self) -> Dict[str, Any]:
        return {"updates": np.int64(self.updates)}

    def load_state(self, state: Dict[str, Any]) -> None:
        self.updates = int(state["updates"])


@register_batch_policy
class GeoDampPolicy(_DamperBase):
    """Geometric schedule: B_k = start * factor^(k // delay) — AdaBatch's
    batch-doubling law (double every ``delay`` observed epochs).  LR scales
    linearly with the batch at each step (AdaBatch pairs each doubling with
    an equivalent LR increase).  Monotone non-decreasing by construction:
    the exponent only grows with the observation count."""

    name = "geodamp"
    requires: frozenset = frozenset()
    default_lr_rule = "linear"

    def __init__(
        self,
        *,
        candidates: Sequence[int],
        ref_batch: int,
        lr_rule: Optional[str] = None,
        start: Optional[int] = None,
        factor: float = 2.0,
        delay: int = 4,
    ) -> None:
        super().__init__(
            candidates=candidates, ref_batch=ref_batch, lr_rule=lr_rule, start=start
        )
        if factor < 1.0:
            raise ValueError("geodamp factor must be >= 1 (monotone schedule)")
        if delay < 1:
            raise ValueError("geodamp delay must be >= 1")
        self.factor = float(factor)
        self.delay = int(delay)

    def _target(self) -> int:
        return int(math.ceil(self.start * self.factor ** (self.updates // self.delay)))


@register_batch_policy
class PadaDampPolicy(_DamperBase):
    """Practical AdaDamp: the linear ramp B_k = start + ceil(rate * k)
    (adadamp's ``ceil(base + increase * updates)`` law).  ``rate`` defaults
    to ``start / 8`` per observed epoch — a doubling over eight epochs.
    Monotone non-decreasing by construction.  LR follows sqrt scaling (the
    gentle rule matching the gradual ramp)."""

    name = "padadamp"
    requires: frozenset = frozenset()
    default_lr_rule = "sqrt"

    def __init__(
        self,
        *,
        candidates: Sequence[int],
        ref_batch: int,
        lr_rule: Optional[str] = None,
        start: Optional[int] = None,
        rate: Optional[float] = None,
    ) -> None:
        super().__init__(
            candidates=candidates, ref_batch=ref_batch, lr_rule=lr_rule, start=start
        )
        self.rate = float(rate) if rate is not None else max(1.0, self.start / 8.0)
        if self.rate < 0:
            raise ValueError("padadamp rate must be >= 0 (monotone schedule)")

    def _target(self) -> int:
        return self.start + int(math.ceil(self.rate * self.updates))


@register_batch_policy
class AdaDampPolicy(_DamperBase):
    """Loss-ratio damper: B_k = ceil(start * L_0 / L_k), floored at
    ``start`` (a loss *increase* never shrinks the batch below the start).
    With no loss telemetry (NaN — e.g. the sim backend) the batch holds at
    ``start``: graceful degradation instead of blow-up.  LR rule ``none``:
    AdaDamp's position is that the growing batch substitutes for LR decay."""

    name = "adadamp"
    requires = frozenset({"loss"})
    default_lr_rule = "none"

    def __init__(
        self,
        *,
        candidates: Sequence[int],
        ref_batch: int,
        lr_rule: Optional[str] = None,
        start: Optional[int] = None,
    ) -> None:
        super().__init__(
            candidates=candidates, ref_batch=ref_batch, lr_rule=lr_rule, start=start
        )
        self.initial_loss = float("nan")
        self.last_loss = float("nan")

    def observe(self, telemetry: Any) -> None:
        super().observe(telemetry)
        loss = getattr(telemetry, "mean_loss", None)
        if loss is None:
            return
        loss = float(loss)
        if not math.isfinite(loss) or loss <= 0:
            return
        if not math.isfinite(self.initial_loss):
            self.initial_loss = loss
        self.last_loss = loss

    def _target(self) -> int:
        if (
            math.isfinite(self.initial_loss)
            and math.isfinite(self.last_loss)
            and self.last_loss > 0
        ):
            ratio = max(1.0, self.initial_loss / self.last_loss)
            return int(math.ceil(self.start * ratio))
        return self.start

    def state(self) -> Dict[str, Any]:
        return {
            "updates": np.int64(self.updates),
            "initial_loss": np.float64(self.initial_loss),
            "last_loss": np.float64(self.last_loss),
        }

    def load_state(self, state: Dict[str, Any]) -> None:
        self.updates = int(state["updates"])
        self.initial_loss = float(state["initial_loss"])
        self.last_loss = float(state["last_loss"])

"""Data pipeline: synthetic LM streams + the heterogeneous batch loader.

`SyntheticLM` produces deterministic pseudo-random token batches (seeded per
step) with a learnable structure (a hidden Markov-ish next-token rule) so
losses actually *decrease* during the example runs — pure-noise tokens would
make convergence-time comparisons meaningless.

`HeteroBatchPartitioner` is the HeteroDataLoader of the paper (§4.5): given
the controller's per-node batch sizes it emits, per node, a contiguous index
range of the global batch; for the single-pjit-step realization it emits the
padded (n, b_max) layout plus the per-sample weight vector of
core/aggregation.sample_weights, which makes one weighted-loss step
equivalent to Eq. (9).

`BoundedStream` is the streaming face of the same sources: a bounded-buffer
iterator over step-indexed batches (optionally produced by a background
thread) that the serving request layer consumes for prompt tokens
(:func:`repro.serving.request.prompts_from_stream`) — batch *content* is a
pure function of the step index, so the streaming view is byte-identical to
calling ``batch(step)`` directly, threaded or not.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import padded_batch_layout, sample_weights

__all__ = ["SyntheticLM", "HeteroBatchPartitioner", "NodeBatch", "BoundedStream"]


class SyntheticLM:
    """Deterministic synthetic token stream with learnable bigram structure."""

    def __init__(self, vocab: int, seq_len: int, seed: int = 0, order: int = 3):
        self.vocab = vocab
        self.seq_len = seq_len
        self.seed = seed
        rng = np.random.default_rng(seed)
        # A fixed permutation defines the "true" next token; corruption adds
        # irreducible entropy.
        self.rule = rng.permutation(vocab)
        self.noise = 0.3

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((batch_size, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch_size)
        for t in range(1, self.seq_len + 1):
            nxt = self.rule[toks[:, t - 1]]
            corrupt = rng.random(batch_size) < self.noise
            nxt = np.where(corrupt, rng.integers(0, self.vocab, batch_size), nxt)
            toks[:, t] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def stream(
        self,
        batch_size: int,
        *,
        start: int = 0,
        steps: Optional[int] = None,
        depth: int = 4,
        threaded: bool = False,
    ) -> "BoundedStream":
        """Streaming view over :meth:`batch`: yields ``batch(start)``,
        ``batch(start + 1)``, ... through a bounded buffer.  Content is
        byte-identical to the step-indexed calls (regression-tested)."""
        return BoundedStream(
            lambda step: self.batch(step, batch_size),
            start=start,
            steps=steps,
            depth=depth,
            threaded=threaded,
        )


class BoundedStream:
    """Bounded-buffer iterator over a step-indexed batch source.

    ``source(step)`` must be a pure function of ``step`` — that is what makes
    the streaming view reproducible and lets the threaded mode exist at all:
    the producer thread only changes *when* batches are computed, never what
    they contain.  ``depth`` bounds the number of precomputed batches held in
    memory (backpressure); ``steps=None`` streams forever.

    Threaded mode prefetches from a daemon producer thread through a
    ``queue.Queue(maxsize=depth)``; ``close()`` (or exhaustion, or the
    context manager) shuts it down.  Unthreaded mode computes lazily on
    ``next()`` — same contents, no concurrency.
    """

    _END = object()

    def __init__(
        self,
        source: Callable[[int], Dict[str, np.ndarray]],
        *,
        start: int = 0,
        steps: Optional[int] = None,
        depth: int = 4,
        threaded: bool = False,
    ):
        if depth < 1:
            raise ValueError("buffer depth must be >= 1")
        if steps is not None and steps < 0:
            raise ValueError("steps must be >= 0")
        self._source = source
        self._step = int(start)
        self._end = None if steps is None else int(start) + int(steps)
        self._closed = False
        self._queue: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        if threaded:
            self._queue = queue.Queue(maxsize=int(depth))
            self._thread = threading.Thread(target=self._produce, daemon=True)
            self._thread.start()

    def _put(self, item) -> bool:
        """Blocking put that gives up promptly once the stream is closed."""
        while not self._closed:
            try:
                self._queue.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self) -> None:
        step = self._step
        try:
            while not self._closed and (self._end is None or step < self._end):
                if not self._put(self._source(step)):
                    return
                step += 1
            self._put(self._END)
        except BaseException as exc:  # surface in the consumer, not the thread
            self._put(exc)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._closed:
            raise StopIteration
        if self._queue is not None:
            item = self._queue.get()
            if item is self._END:
                self.close()
                raise StopIteration
            if isinstance(item, BaseException):
                self.close()
                raise item
            return item
        if self._end is not None and self._step >= self._end:
            raise StopIteration
        batch = self._source(self._step)
        self._step += 1
        return batch

    def close(self) -> None:
        """Stop the producer (if any) and drop buffered batches."""
        self._closed = True
        if self._queue is not None:
            # Unblock a producer waiting on a full queue, then drain.
            while True:
                try:
                    self._queue.get_nowait()
                except queue.Empty:
                    break
            if self._thread is not None and self._thread.is_alive():
                self._thread.join(timeout=1.0)

    def __enter__(self) -> "BoundedStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


@dataclasses.dataclass(frozen=True)
class NodeBatch:
    """One node's share of a global batch."""

    node: int
    start: int              # global-batch row offset
    size: int               # b_i
    tokens: np.ndarray      # (b_i, S)
    labels: np.ndarray
    ratio: float            # r_i = b_i / B


class HeteroBatchPartitioner:
    """Splits a global batch into uneven per-node local batches.

    Two views:
      * `split(batch, sizes)` — list of NodeBatch (per-node runtime view,
        used by the simulator/examples).
      * `padded(batch, sizes)` — (stacked (n, b_max, S) arrays, per-sample
        weights (n, b_max)) — the single-pjit-step view; the weight vector
        makes a weighted-SUM loss equal to Eq. (9).
    """

    @staticmethod
    def split(batch: Dict[str, np.ndarray], sizes: Sequence[int]) -> List[NodeBatch]:
        total = int(sum(sizes))
        if total != batch["tokens"].shape[0]:
            raise ValueError(
                f"partition sizes sum {total} != global batch {batch['tokens'].shape[0]}"
            )
        out, ofs = [], 0
        for i, b in enumerate(sizes):
            out.append(
                NodeBatch(
                    node=i,
                    start=ofs,
                    size=int(b),
                    tokens=batch["tokens"][ofs : ofs + b],
                    labels=batch["labels"][ofs : ofs + b],
                    ratio=b / total,
                )
            )
            ofs += b
        return out

    @staticmethod
    def padded(
        batch: Dict[str, np.ndarray], sizes: Sequence[int]
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        total = int(sum(sizes))
        if total != batch["tokens"].shape[0]:
            raise ValueError("partition sizes do not sum to the global batch")
        b_max, mask = padded_batch_layout(sizes)
        n = len(sizes)
        seq = batch["tokens"].shape[1]
        tok = np.zeros((n, b_max, seq), np.int32)
        lab = np.zeros((n, b_max, seq), np.int32)
        ofs = 0
        for i, b in enumerate(sizes):
            tok[i, :b] = batch["tokens"][ofs : ofs + b]
            lab[i, :b] = batch["labels"][ofs : ofs + b]
            ofs += b
        weights = sample_weights(sizes)  # (n, b_max), rows sum to b_i/B
        return {"tokens": tok, "labels": lab}, weights

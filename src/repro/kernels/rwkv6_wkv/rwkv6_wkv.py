"""Pallas TPU kernel for the RWKV6 WKV recurrence (chunked-parallel form).

Recurrence per head (state S in R^{K x K}, data-dependent decay w_t):
    out_t = r_t . (diag(u) k_t^T v_t + S_{t-1})
    S_t   = diag(w_t) S_{t-1} + k_t^T v_t

TPU adaptation (vs. the CUDA kernel of the RWKV authors, which assigns one
thread per channel and steps sequentially): we use the chunked-parallel
formulation — within a chunk of C tokens the recurrence collapses into two
MXU matmuls on decay-scaled r/k plus a (C x C) masked score matrix, and only
the (K x K) state crosses chunk boundaries.  The grid is (B*H, n_chunks)
with the chunk dim innermost ("revisiting" pattern: the state scratch lives
in VMEM across chunk iterations).  C defaults to 64 and K = 64, so every
matmul is (64 x 64) x (64 x 64) — half-MXU tiles; K=128 heads would fill it.

Log-decays are clamped to [LOG_DECAY_MIN, 0] like the jnp reference
(models/rwkv6.py): the scaled-GEMM form computes k .* exp(-L) which would
overflow for unbounded decay.

VMEM per program: r/k/v/lw chunks 4x(64x64x4B) + state (64x64x4B) + score
(64x64) ~ 120 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

LOG_DECAY_MIN = -4.6  # matches models/rwkv6.py (see stability note there)


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_final_ref, state_ref,
                *, chunk: int, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = jnp.clip(lw_ref[0].astype(jnp.float32), LOG_DECAY_MIN, 0.0)
    u = u_ref[0].astype(jnp.float32)          # (1, K) block of (H, K)

    l_inc = jnp.cumsum(lw, axis=0)            # L_t inclusive
    l_prev = l_inc - lw                       # L_{t-1}
    l_end = l_inc[-1:, :]                     # (1, K)

    # Mid-point-normalized factored form (see models/rwkv6.py): bounds both
    # GEMM factors by exp(chunk*|LOG_DECAY_MIN|/2) — float32-safe.
    l_mid = 0.5 * l_end
    rr = r * jnp.exp(l_prev - l_mid)          # (C, K)
    kk = k * jnp.exp(l_mid - l_inc)
    scores = jax.lax.dot_general(
        rr, kk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (C, C): scores[t, s]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(s_idx < t_idx, scores, 0.0)  # strictly lower triangular

    diag = jnp.sum(r * u * k, axis=1)         # bonus term: r_t . (u . k_t)
    out = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    out = out + diag[:, None] * v
    out = out + jax.lax.dot_general(
        rr * jnp.exp(l_mid), state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0] = out.astype(o_ref.dtype)

    k_dec = k * jnp.exp(l_end - l_inc)        # (C, K)
    state_ref[...] = jnp.exp(l_end[0])[:, None] * state_ref[...] + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ic == n_chunks - 1)
    def finalize():
        s_final_ref[0] = state_ref[...].astype(s_final_ref.dtype)


def wkv_kernel(
    r: jax.Array,   # (BH, T, K)
    k: jax.Array,
    v: jax.Array,
    log_w: jax.Array,
    u: jax.Array,   # (BH, K) per-head bonus (broadcast over batch upstream)
    *,
    chunk: int = 64,
    interpret: bool = True,
):
    """Returns (out (BH, T, K), final state (BH, K, K))."""
    bh, t, kk = r.shape
    if t % chunk:
        raise ValueError(f"T={t} must be a multiple of chunk={chunk}")
    n_chunks = t // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, kk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, kk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, kk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, kk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, kk), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, kk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, kk, kk), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, kk), r.dtype),
            jax.ShapeDtypeStruct((bh, kk, kk), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kk, kk), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u)

"""On-device OptPerf: the jit-compiled batched water-fill sweep engine.

Third engine next to the scalar oracle and the NumPy batched engine of
:mod:`repro.core.optperf`.  The whole goodput sweep — a ``(C,)`` bracket
vector bisected against the ``(C, n)`` feasible-batch matrix — runs inside
one ``jax.jit`` with

  * :func:`device_coeffs`: :attr:`ClusterPerfModel.coeffs` exported once as
    stacked device arrays (cached per (model, dtype); the model is frozen so
    the export can never go stale),
  * a bounded ``lax.while_loop`` for geometric bracket growth,
  * a fixed-trip-count ``lax.fori_loop`` for the bisection itself (no
    per-iteration host round-trip, no data-dependent control flow), and
  * donate-friendly bracket state: the ``(lo, hi)`` vectors are donated to
    the jitted sweep where the backend supports donation, so epoch-over-epoch
    re-solves reuse the same device buffers.

This lets the controller re-solve OptPerf on-device beside the training step
(§4–5 of the paper re-solve continuously as the gradient-noise scale drifts)
with zero host work inside the loop.

:func:`solve_optperf_stacked_jax` is the cluster-scale variant: C
*independent* stacked rows (each row its own node subset + comm model,
padded to a common width and masked) bisected in one jit call — the
multi-job scheduler's per-round kernel, where J x N (job, candidate-node)
marginal problems solve simultaneously.  Its coefficient export is cached
on the :class:`~repro.core.perf_model.StackedClusterModel` instance
(``stacked_device_coeffs``).  Every cached export carries a *content stamp*
(a checksum of the live coefficient arrays) that is re-checked at solve
time: an in-place coefficient refresh that forgot to call
``invalidate_device_cache()`` is detected and the stale export (plus every
derived cache) is dropped and rebuilt — the kernel can no longer silently
solve the old regime.

:func:`solve_optperf_sweep_device` is the *trace-compatible* entry: the
same bracket-growth + bisection kernel as the jitted standalone sweep, but
callable from inside another ``jax.jit`` (no host work, no jit boundary of
its own).  :class:`RealBackend <repro.runtime.backend.RealBackend>` uses it
to fuse train-step + GNS statistics + the goodput sweep into one compiled
epoch program; :func:`device_partition` is its on-device analogue of the
host finalizer (clamp + proportional rescale, no float64 certification —
certification stays a host-side, off-critical-path check).

Warm starts seed the device brackets from the previous epoch's ``t_stars``
(±``warm_delta`` relative) with on-device validation: a seeded bracket whose
lower edge already over-assigns is reset to the cold lower bound, so stale
warm starts stay correct while valid ones cut the fixed trip count from
``max_iter`` to ``warm_max_iter``.

Precision: the device sweep runs in float32 unless x64 is enabled (pass
``dtype`` or run under ``jax.experimental.enable_x64``).  The emitted
``t_stars`` are certified and finalized *on the host in float64* through the
exact same :func:`repro.core.optperf._finalize_batches` path as the NumPy
engine, so partitions sum exactly and the two engines agree to the device
dtype's resolution (<= 1e-5 relative for float32, ~1e-10 for float64);
winners re-solved by the scalar oracle are identical across all engines.

JAX is an optional dependency of the core: when it is missing ``HAS_JAX`` is
False and :class:`~repro.core.goodput.BatchSizeSelector` silently falls back
to the NumPy batched engine.
"""
from __future__ import annotations

import collections
import functools
import zlib
from typing import NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.optperf import (
    BatchedOptPerfSolution,
    _finalize_batches,
    _p_assigned,
    _p_best_single_node_time,
    _p_compute_mask,
    _problem_from_model,
    _problem_from_stack,
    _validated_totals,
)
from repro.core.perf_model import ClusterPerfModel, StackedClusterModel

try:  # pragma: no cover - import success is the covered path in this image
    import jax
    import jax.numpy as jnp
    from jax import lax

    HAS_JAX = True
except Exception:  # pragma: no cover - gated fallback for jax-less installs
    jax = None  # type: ignore[assignment]
    jnp = None  # type: ignore[assignment]
    lax = None  # type: ignore[assignment]
    HAS_JAX = False

__all__ = [
    "HAS_JAX",
    "DeviceCoeffs",
    "StackedDeviceCoeffs",
    "device_coeffs",
    "evict_device_coeffs",
    "model_stamp",
    "stack_stamp",
    "stacked_device_coeffs",
    "device_partition",
    "device_node_times",
    "solve_optperf_sweep_device",
    "solve_optperf_batch_jax",
    "solve_optperf_stacked_jax",
]

_GROWTH_ITERS = 64


def model_stamp(model: ClusterPerfModel) -> int:
    """Content checksum of the coefficient numbers a solve would consume.

    Computed over the (memoized) ``coeffs`` view plus the comm model — the
    exact arrays every solver reads — so a model whose coefficient arrays
    were refreshed in place, bypassing the frozen-dataclass contract,
    produces a different stamp than the one recorded at device-export
    time."""
    acc = 0
    c = model.coeffs
    for arr in (c.alphas, c.cs, c.betas, c.ds, c.ks, c.ms):
        acc = zlib.crc32(np.ascontiguousarray(arr).tobytes(), acc)
    comm = np.array([model.comm.t_o, model.comm.t_u, model.comm.gamma], np.float64)
    return zlib.crc32(comm.tobytes(), acc)


def stack_stamp(stack: StackedClusterModel) -> int:
    """Content checksum of a stack's *live* coefficient arrays.

    The scheduler refreshes stacked rows in place between reconcile rounds;
    the stamp recorded at export time is re-checked on every solve so a
    refresh that forgot ``invalidate_device_cache()`` can no longer serve
    stale device coefficients."""
    acc = 0
    for arr in (stack.alphas, stack.cs, stack.betas, stack.ds, stack.ks,
                stack.ms, stack.t_o, stack.t_u, stack.gamma, stack.mask):
        acc = zlib.crc32(np.ascontiguousarray(arr).tobytes(), acc)
    return acc


class DeviceCoeffs(NamedTuple):
    """Stacked device-array view of one cluster's OptPerf coefficients."""

    alphas: "jax.Array"       # (n,)
    cs: "jax.Array"           # (n,)
    safe_betas: "jax.Array"   # (n,) betas with 1.0 at degenerate slots
    degenerate: "jax.Array"   # (n,) bool: beta <= 0 (syncStart flat in b)
    ds: "jax.Array"           # (n,)
    t_u: "jax.Array"          # scalar
    t_comm: "jax.Array"       # scalar


# LRU-bounded single-model export cache.  A plain dict (not functools.
# lru_cache) so that membership changes can *evict* a model's entries —
# an elastic controller that drops/adds nodes must not leave the dead
# cluster's coefficient stack pinned on the device (see
# CannikinController.add_nodes/remove_nodes).  Entries are
# (content_stamp, DeviceCoeffs): the stamp recorded at export time is
# compared against the model's live stamp on every lookup, so an in-place
# coefficient refresh can never be served a stale export.
_DEVICE_COEFFS_LIMIT = 128
_DEVICE_COEFFS: "collections.OrderedDict[Tuple[ClusterPerfModel, str], Tuple[int, DeviceCoeffs]]" = (
    collections.OrderedDict()
)


def _device_coeffs_cached(model: ClusterPerfModel, dtype_name: str) -> DeviceCoeffs:
    key = (model, dtype_name)
    stamp = model_stamp(model)
    hit = _DEVICE_COEFFS.get(key)
    if hit is not None:
        if hit[0] == stamp:
            _DEVICE_COEFFS.move_to_end(key)
            return hit[1]
        # The model's numbers changed under a cached export: drop every
        # device export of this model *and* the memoized host views derived
        # from the old numbers (coeffs / problem / validation), then rebuild
        # and re-stamp from the clean views.
        evict_device_coeffs(model)
        for slot in ("coeffs", "_optperf_problem", "_validated"):
            model.__dict__.pop(slot, None)
        stamp = model_stamp(model)
    c = model.coeffs
    dt = jnp.dtype(dtype_name)
    degenerate = c.betas <= 0.0
    dc = DeviceCoeffs(
        alphas=jnp.asarray(c.alphas, dt),
        cs=jnp.asarray(c.cs, dt),
        safe_betas=jnp.asarray(np.where(degenerate, 1.0, c.betas), dt),
        degenerate=jnp.asarray(degenerate),
        ds=jnp.asarray(c.ds, dt),
        t_u=jnp.asarray(model.comm.t_u, dt),
        t_comm=jnp.asarray(model.comm.t_comm, dt),
    )
    _DEVICE_COEFFS[key] = (stamp, dc)
    while len(_DEVICE_COEFFS) > _DEVICE_COEFFS_LIMIT:
        _DEVICE_COEFFS.popitem(last=False)
    return dc


def evict_device_coeffs(model: ClusterPerfModel) -> int:
    """Drop every cached device export of ``model`` (all dtypes).

    Called on cluster-membership changes: the old membership's coefficient
    stack must neither stay pinned in device memory nor be served to a
    later sweep over the same (stale) model object.  Returns the number of
    entries evicted."""
    stale = [key for key in _DEVICE_COEFFS if key[0] == model]
    for key in stale:
        del _DEVICE_COEFFS[key]
    return len(stale)


def device_coeffs(model: ClusterPerfModel, dtype=None) -> DeviceCoeffs:
    """Export (and cache) a model's coefficient arrays on the device.

    ``dtype`` defaults to float64 under x64 and float32 otherwise.  The cache
    is keyed on the frozen model *and* the dtype, so flipping x64 mid-process
    (e.g. ``jax.experimental.enable_x64``) never serves stale-width arrays.
    """
    if not HAS_JAX:
        raise RuntimeError("jax is not available; use the NumPy batched engine")
    if dtype is None:
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    return _device_coeffs_cached(model, np.dtype(dtype).name)


@functools.lru_cache(maxsize=1)
def _donate_argnums() -> Tuple[int, ...]:
    # Donation is a no-op (with a warning per call site) on backends that do
    # not support it; keep the sweep warning-free on CPU.
    return () if jax.default_backend() == "cpu" else (0, 1)


def _device_feasible(tt, alphas, cs, safe_betas, degenerate, ds, t_u, t_comm):
    """Clamped per-node feasible batch at cluster time(s) ``tt``.

    Trace-compatible transliteration of :func:`repro.core.optperf._p_feasible`
    + the zero clamp; ``tt`` must already be broadcast-shaped against the
    ``(..., n)`` coefficient arrays."""
    b_compute = (tt - t_u - cs) / alphas
    slack = tt - t_comm - ds
    b_comm = jnp.where(
        degenerate,
        jnp.where(slack >= 0.0, jnp.inf, -jnp.inf),
        slack / safe_betas,
    )
    return jnp.maximum(jnp.minimum(b_compute, b_comm), 0.0)


def _sweep_body(
    lo, hi, lo0, totals, tol,
    alphas, cs, safe_betas, degenerate, ds, t_u, t_comm, mask,
    *, max_iter: int, warm: bool,
):
    """The bracket-growth + bisection kernel, shared by every device sweep.

    Pure jnp/lax — traceable from inside another jit (the fused epoch
    program) as well as under the standalone jitted wrappers built by
    :func:`_device_sweep` / :func:`_device_stacked_sweep`.  ``mask`` is
    ``None`` for single-model sweeps and the ``(C, n)`` padding mask for
    stacked rows; ``lo0`` may be a scalar (single model) or a per-row
    vector (stacked) — both broadcast identically.

    Cold sweeps (``warm=False``) use a fixed-trip ``lax.fori_loop`` of
    ``max_iter`` steps — iterating past float convergence is harmless (the
    midpoint rounds onto an endpoint and the state is a fixed point), so no
    per-iteration convergence predicate — and therefore no host
    synchronization — is needed.  Warm sweeps instead validate the seeded
    lower edge (a stale lo that already over-assigns is reset to the
    certified cold bound) and run a convergence-checked ``lax.while_loop``
    bounded by ``max_iter``: a valid ±delta seed exits after
    ~log2(2*delta/tol) steps, while a stale bracket that snapped open keeps
    halving until it converges anyway.
    """

    def assigned(t):
        b = _device_feasible(
            t[:, None], alphas, cs, safe_betas, degenerate, ds, t_u, t_comm
        )
        if mask is not None:
            b = jnp.where(mask, b, 0.0)
        return b.sum(axis=-1)

    if warm:
        # Warm-seeded lower edges must strictly under-assign; reset any
        # that do not (stale warm start) to the certified cold bound.
        lo = jnp.where(assigned(lo) >= totals, lo0, lo)

    def grow_cond(state):
        i, h = state
        return (i < _GROWTH_ITERS) & jnp.any(assigned(h) < totals)

    def grow_body(state):
        i, h = state
        h = jnp.where(assigned(h) < totals, lo0 + (h - lo0) * 2.0, h)
        return i + 1, h

    _, hi_grown = lax.while_loop(grow_cond, grow_body, (jnp.int32(0), hi))

    def bisect_step(lo, hi):
        mid = 0.5 * (lo + hi)
        ge = assigned(mid) >= totals
        return jnp.where(ge, lo, mid), jnp.where(ge, mid, hi)

    if warm:
        def cond(state):
            i, lo, hi = state
            unconverged = jnp.any(hi - lo > tol * jnp.maximum(1.0, jnp.abs(hi)))
            return (i < max_iter) & unconverged

        def body(state):
            i, lo, hi = state
            lo, hi = bisect_step(lo, hi)
            return i + 1, lo, hi

        iters, lo, hi = lax.while_loop(cond, body, (jnp.int32(0), lo, hi_grown))
    else:
        lo, hi = lax.fori_loop(
            0, max_iter, lambda _, s: bisect_step(*s), (lo, hi_grown)
        )
        iters = jnp.int32(max_iter)
    return lo, hi, iters


@functools.lru_cache(maxsize=8)
def _device_sweep(max_iter: int, warm: bool):
    """Build (and cache) the jitted sweep for a static trip count.

    A thin jitted wrapper over :func:`_sweep_body` mapping donated
    ``(lo, hi)`` bracket state plus the stacked coefficients to the refined
    ``(lo, hi)``.
    """

    def sweep(
        lo, hi, lo0, totals, tol, alphas, cs, safe_betas, degenerate, ds, t_u, t_comm
    ):
        return _sweep_body(
            lo, hi, lo0, totals, tol,
            alphas, cs, safe_betas, degenerate, ds, t_u, t_comm, None,
            max_iter=max_iter, warm=warm,
        )

    return jax.jit(sweep, donate_argnums=_donate_argnums())


def solve_optperf_sweep_device(
    coeffs: DeviceCoeffs,
    total_batches,
    lo0,
    *,
    tol=None,
    max_iter: int = 64,
    lo=None,
    hi=None,
    warm: bool = False,
):
    """Trace-compatible candidate sweep: ``(t_stars, iters)`` on device.

    The same kernel as :func:`solve_optperf_batch_jax`'s jitted sweep, but
    with no jit boundary of its own — callable from *inside* another
    ``jax.jit`` (the fused epoch program runs train-step + GNS statistics +
    this sweep as one compiled program).  No host work happens here: the
    float64 certification + exact-sum finalization that
    :func:`solve_optperf_batch_jax` performs on the host become the
    caller's responsibility, as an async off-critical-path check (see
    ``CannikinController.consume_fused_plan``).

    ``total_batches`` may be a tracer; ``lo0`` is the cold lower bracket
    bound (host float or tracer); ``warm``/``max_iter`` must be static.
    Returns the ``(C,)`` refined upper bracket edge ``t_stars`` — each
    entry a device-dtype OptPerf estimate for its candidate total — and the
    bisection trip count actually spent.
    """
    if not HAS_JAX:
        raise RuntimeError("jax is not available; use the NumPy batched engine")
    dt = coeffs.alphas.dtype
    totals = jnp.asarray(total_batches, dt)
    lo0_dev = jnp.asarray(lo0, dt)
    if tol is None:
        tol = 8.0 * float(jnp.finfo(dt).eps)
    tol_dev = jnp.asarray(tol, dt)
    if lo is None:
        lo = jnp.full(totals.shape, lo0_dev, dt)
    if hi is None:
        hi = lo + 1.0
    _, hi_out, iters = _sweep_body(
        lo, hi, lo0_dev, totals, tol_dev,
        coeffs.alphas, coeffs.cs, coeffs.safe_betas, coeffs.degenerate,
        coeffs.ds, coeffs.t_u, coeffs.t_comm, None,
        max_iter=int(max_iter), warm=warm,
    )
    return hi_out, iters


def device_partition(coeffs: DeviceCoeffs, t_star, total):
    """On-device batch partition at cluster time ``t_star``: clamp + rescale.

    Trace-compatible analogue of the host finalizer's rescale step: the
    clamped feasible batches at ``t_star`` are proportionally scaled so they
    sum exactly (to device precision) to ``total``.  Zero rows stay zero;
    the float64 certification of the host path is deliberately absent —
    callers certify asynchronously against the host engines."""
    b = _device_feasible(
        t_star, coeffs.alphas, coeffs.cs, coeffs.safe_betas,
        coeffs.degenerate, coeffs.ds, coeffs.t_u, coeffs.t_comm,
    )
    s = b.sum(axis=-1, keepdims=True)
    total = jnp.asarray(total, b.dtype)
    scale = jnp.where(s > 0.0, total[..., None] / s, 0.0)
    return b * scale


def device_node_times(coeffs: DeviceCoeffs, batches):
    """Per-node batch times ``max(alpha b + c + t_u, beta b + d + t_comm)``
    — the trace-compatible analogue of the host finalizer's node-time pass.

    The row maximum over a finalized partition is the candidate's realized
    OptPerf: at small totals the water level can sit *below* a clamped
    node's fixed floor, so the bisected bracket alone understates the batch
    time (the host engines finalize the same way)."""
    betas = jnp.where(coeffs.degenerate, 0.0, coeffs.safe_betas)
    return jnp.maximum(
        coeffs.alphas * batches + coeffs.cs + coeffs.t_u,
        betas * batches + coeffs.ds + coeffs.t_comm,
    )


def solve_optperf_batch_jax(
    model: ClusterPerfModel,
    total_batches: Sequence[float],
    *,
    tol: float = 1e-10,
    max_iter: int = 64,
    warm_start: Optional[np.ndarray] = None,
    warm_delta: float = 1e-3,
    dtype=None,
) -> BatchedOptPerfSolution:
    """Solve the whole candidate sweep on-device; finalize on host in f64.

    Contract-compatible with :func:`repro.core.optperf.solve_optperf_batch`:
    same solution type, exact-sum partitions, ``t_stars`` usable as the next
    epoch's ``warm_start``.  ``max_iter`` bounds the bisection: the cold
    sweep runs it as a fixed trip count (64 trips reach float32 resolution
    from any practical bracket); the warm sweep exits early on convergence
    (~log2(2*delta/tol) steps for a valid ±delta seed) and only spends the
    full budget when a stale seed forced the bracket open.
    """
    if not HAS_JAX:
        raise RuntimeError("jax is not available; use the NumPy batched engine")
    totals_np = _validated_totals(total_batches)
    model.validate()
    dc = device_coeffs(model, dtype)
    dt = dc.alphas.dtype
    p, lo0 = _problem_from_model(model)

    totals_dev = jnp.asarray(totals_np, dt)
    lo0_dev = jnp.asarray(lo0, dt)
    tol_dev = jnp.asarray(max(tol, 8.0 * float(jnp.finfo(dt).eps)), dt)
    if warm_start is None:
        lo = jnp.full(totals_np.shape, lo0, dt)
        hi = lo + 1.0
        sweep = _device_sweep(int(max_iter), False)
    else:
        w = np.asarray(warm_start, dtype=np.float64)
        if w.shape != totals_np.shape:
            raise ValueError("warm_start shape must match total_batches")
        # Clamp seeds to a computable optimum ceiling — the best *single*
        # node processing the whole batch — so a stale-high seed cannot
        # open an astronomically wide bracket the iteration bound cannot
        # close (the while_loop still converges any bracket this wide).
        t_ub = _p_best_single_node_time(p, totals_np)
        w = np.where(np.isfinite(w) & (w > lo0), np.minimum(w, t_ub), lo0 + 1.0)
        lo = jnp.maximum(jnp.asarray(w * (1.0 - warm_delta), dt), lo0_dev)
        hi = jnp.maximum(jnp.asarray(w * (1.0 + warm_delta), dt), lo0_dev)
        sweep = _device_sweep(int(max_iter), True)
    _, hi_out, sweep_iters = sweep(
        lo, hi, lo0_dev, totals_dev, tol_dev,
        dc.alphas, dc.cs, dc.safe_betas, dc.degenerate, dc.ds, dc.t_u, dc.t_comm,
    )

    # Host float64 certification: the device ran in its own dtype (and XLA's
    # own reduction order), so its hi may sit a rounding error *below* the
    # true optimum.  Nudge up by dtype-epsilon-scaled steps until the float64
    # upper invariant holds, then reuse the exact shared finalizer.
    t_star = np.asarray(hi_out, dtype=np.float64)
    nudge = 8.0 * float(np.finfo(np.dtype(dt.name)).eps)
    polish = 0
    for _ in range(64):
        deficit = _p_assigned(p, t_star) < totals_np
        polish += 1
        if not deficit.any():
            break
        t_star = np.where(deficit, t_star * (1.0 + nudge) + 1e-300, t_star)
    else:
        raise RuntimeError("jax sweep t_star failed float64 certification")

    batches, node_times = _finalize_batches(p, totals_np, t_star, tol=tol)
    opt_perfs = node_times.max(axis=-1)
    compute_mask = _p_compute_mask(p, batches)
    for arr in (totals_np, t_star, opt_perfs, batches, compute_mask):
        arr.flags.writeable = False
    return BatchedOptPerfSolution(
        total_batches=totals_np,
        opt_perfs=opt_perfs,
        batches=batches,
        compute_mask=compute_mask,
        method="waterfill/jax" if warm_start is None else "waterfill/jax+warm",
        t_stars=t_star,
        iterations=int(sweep_iters) + polish,
    )


# ---------------------------------------------------------------------------
# Stacked rows on device — the cluster-scale scheduler kernel
# ---------------------------------------------------------------------------


class StackedDeviceCoeffs(NamedTuple):
    """Device-array view of a :class:`StackedClusterModel`: C independent
    padded problem rows, each with its own node subset (``mask``) and its
    own communication model (the ``(C, 1)`` comm columns broadcast against
    the ``(C, n)`` coefficient arrays)."""

    alphas: "jax.Array"       # (C, n)
    cs: "jax.Array"           # (C, n)
    safe_betas: "jax.Array"   # (C, n) betas with 1.0 at degenerate slots
    degenerate: "jax.Array"   # (C, n) bool: beta <= 0
    ds: "jax.Array"           # (C, n)
    t_u: "jax.Array"          # (C, 1)
    t_comm: "jax.Array"       # (C, 1)
    mask: "jax.Array"         # (C, n) bool; False = padding slot


def stacked_device_coeffs(stack: StackedClusterModel, dtype=None) -> StackedDeviceCoeffs:
    """Export (and cache) a stack's coefficient arrays on the device.

    Cached in the stack's :meth:`~StackedClusterModel.device_cache` slot
    keyed by dtype, so repeated solves of a persistent stack (the scheduler
    re-runs the same seed stack on every reconcile) ship arrays once.  Each
    cached export records a content stamp of the live coefficient arrays,
    re-checked here on every call: a stack whose arrays were refreshed in
    place *without* ``invalidate_device_cache()`` trips the stamp, every
    derived cache (device exports, solver problem view, validation memo) is
    dropped, and a fresh export of the refreshed numbers is shipped.
    """
    if not HAS_JAX:
        raise RuntimeError("jax is not available; use the NumPy stacked engine")
    if dtype is None:
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    key = np.dtype(dtype).name
    stamp = stack_stamp(stack)
    cache = stack.device_cache()
    hit = cache.get(key)
    if hit is not None and hit[0] != stamp:
        # In-place refresh under a live export: nuke the device exports AND
        # the host-side derived views memoized from the old numbers.
        stack.invalidate_device_cache()
        cache = stack.device_cache()
        hit = None
    if hit is None:
        dt = jnp.dtype(key)
        degenerate = stack.betas <= 0.0
        col = lambda v: v[:, None]  # noqa: E731
        sdc = StackedDeviceCoeffs(
            alphas=jnp.asarray(stack.alphas, dt),
            cs=jnp.asarray(stack.cs, dt),
            safe_betas=jnp.asarray(np.where(degenerate, 1.0, stack.betas), dt),
            degenerate=jnp.asarray(degenerate),
            ds=jnp.asarray(stack.ds, dt),
            t_u=jnp.asarray(col(stack.t_u), dt),
            t_comm=jnp.asarray(col(stack.t_comm), dt),
            mask=jnp.asarray(stack.mask),
        )
        cache[key] = (stamp, sdc)
        return sdc
    return hit[1]


@functools.lru_cache(maxsize=8)
def _device_stacked_sweep(max_iter: int, warm: bool):
    """Jitted stacked sweep for a static trip count (cached per
    (max_iter, warm); XLA re-specializes per (C, n) shape inside the jit).

    The same :func:`_sweep_body` kernel as :func:`_device_sweep` with three
    stacked generalizations flowing through its arguments: the
    feasible-batch kernel masks padding slots out of every row sum, the
    comm scalars are per-row ``(C, 1)`` columns, and the cold lower bound
    ``lo0`` is a per-row vector.
    """

    def sweep(
        lo, hi, lo0, totals, tol,
        alphas, cs, safe_betas, degenerate, ds, t_u, t_comm, mask,
    ):
        return _sweep_body(
            lo, hi, lo0, totals, tol,
            alphas, cs, safe_betas, degenerate, ds, t_u, t_comm, mask,
            max_iter=max_iter, warm=warm,
        )

    return jax.jit(sweep, donate_argnums=_donate_argnums())


def solve_optperf_stacked_jax(
    stack: StackedClusterModel,
    total_batches: Sequence[float],
    *,
    tol: float = 1e-10,
    max_iter: int = 64,
    warm_start: Optional[np.ndarray] = None,
    warm_delta: float = 1e-3,
    dtype=None,
) -> BatchedOptPerfSolution:
    """Water-fill C independent stacked rows on-device; finalize on host.

    Contract-compatible with :func:`repro.core.optperf.solve_optperf_stacked`
    (same solution type, exact-sum partitions, padding-aware extraction,
    ``t_stars`` usable as the next round's ``warm_start``).  The whole
    scheduler round — every (job, candidate-node) marginal problem — bisects
    as one jit call; host float64 certification and finalization go through
    the exact shared :func:`_finalize_batches` path, so the jax and NumPy
    stacked engines agree to the device dtype's resolution (<= 1e-5 relative
    in float32).
    """
    if not HAS_JAX:
        raise RuntimeError("jax is not available; use the NumPy stacked engine")
    totals_np = _validated_totals(total_batches)
    if totals_np.shape[0] != stack.shape[0]:
        raise ValueError("total_batches length must match stack rows")
    stack.validate()
    sdc = stacked_device_coeffs(stack, dtype)
    dt = sdc.alphas.dtype
    p, lo0 = _problem_from_stack(stack)

    totals_dev = jnp.asarray(totals_np, dt)
    lo0_dev = jnp.asarray(lo0, dt)
    tol_dev = jnp.asarray(max(tol, 8.0 * float(jnp.finfo(dt).eps)), dt)
    if warm_start is None:
        lo = jnp.asarray(lo0, dt)
        hi = lo + 1.0
        sweep = _device_stacked_sweep(int(max_iter), False)
    else:
        w = np.asarray(warm_start, dtype=np.float64)
        if w.shape != totals_np.shape:
            raise ValueError("warm_start shape must match total_batches")
        # Same stale-seed safeguards as the single-model engine: clamp to the
        # per-row best-single-node ceiling (mask-aware) and reset unusable
        # seeds to just above the cold lower bound.
        t_ub = _p_best_single_node_time(p, totals_np)
        w = np.where(np.isfinite(w) & (w > lo0), np.minimum(w, t_ub), lo0 + 1.0)
        lo = jnp.maximum(jnp.asarray(w * (1.0 - warm_delta), dt), lo0_dev)
        hi = jnp.maximum(jnp.asarray(w * (1.0 + warm_delta), dt), lo0_dev)
        sweep = _device_stacked_sweep(int(max_iter), True)
    _, hi_out, sweep_iters = sweep(
        lo, hi, lo0_dev, totals_dev, tol_dev,
        sdc.alphas, sdc.cs, sdc.safe_betas, sdc.degenerate, sdc.ds,
        sdc.t_u, sdc.t_comm, sdc.mask,
    )

    # Host float64 certification — identical to the single-model jax path.
    t_star = np.asarray(hi_out, dtype=np.float64)
    nudge = 8.0 * float(np.finfo(np.dtype(dt.name)).eps)
    polish = 0
    for _ in range(64):
        deficit = _p_assigned(p, t_star) < totals_np
        polish += 1
        if not deficit.any():
            break
        t_star = np.where(deficit, t_star * (1.0 + nudge) + 1e-300, t_star)
    else:
        raise RuntimeError("stacked jax sweep t_star failed float64 certification")

    batches, node_times = _finalize_batches(p, totals_np, t_star, tol=tol)
    opt_perfs = node_times.max(axis=-1)
    compute_mask = _p_compute_mask(p, batches)
    node_mask = np.array(stack.mask, dtype=bool)  # copy: stacks may be reused
    for arr in (totals_np, t_star, opt_perfs, batches, compute_mask, node_mask):
        arr.flags.writeable = False
    return BatchedOptPerfSolution(
        total_batches=totals_np,
        opt_perfs=opt_perfs,
        batches=batches,
        compute_mask=compute_mask,
        method=(
            "waterfill/stacked-jax"
            if warm_start is None
            else "waterfill/stacked-jax+warm"
        ),
        t_stars=t_star,
        iterations=int(sweep_iters) + polish,
        node_mask=node_mask,
    )

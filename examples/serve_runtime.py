"""Goodput-aware serving end to end: the OptPerf water-fill under live
inference traffic, with churn.

Three lanes, each asserting its own invariants (nonzero exit on violation —
this is the serving-smoke CI entrypoint):

1. **Water-fill vs uniform (sim)** — a seeded Poisson workload over a
   2-speed-class cluster (3 fast nodes, 5 nodes 8x slower, shared per-tick
   overhead).  The OptPerf-driven slot allocation must sustain >= 15%
   higher req/s than the uniform split at equal-or-better p99 token
   latency, and same-seed runs must be bit-identical (fingerprint match).
2. **Churn (sim)** — the same workload with one NodeLeave mid-stream and a
   later NodeJoin: every in-flight request on the lost node requeues (tokens
   kept, caches rebuilt elsewhere) and the run completes with ZERO drops.
3. **Real engine** — the reduced olmo-1b zoo model decoding real tokens
   (fused prefill + jitted decode, batch-1 slot caches), prompts streamed
   from the training data pipeline, with one NodeLeave mid-stream.  Zero
   drops, every request completes.

    python examples/serve_runtime.py [--requests N] [--skip-real]
"""
import argparse
import time

import _common  # noqa: F401  (sys.path bootstrap)

from repro.data.pipeline import SyntheticLM
from repro.runtime.events import NodeJoin, NodeLeave
from repro.serving import (
    ServingAllocator,
    ServingConfig,
    ServingRuntime,
    SimServingEngine,
    generate_requests,
    prompts_from_stream,
)

# 2-speed-class cluster: 3 fast, 5 at 8x the per-token cost, shared
# per-tick dispatch overhead (host-side, speed-independent).
FAST, SLOW, INTERCEPT = 0.004, 0.032, 0.03
COEFFS = {i: (FAST, INTERCEPT) for i in range(3)}
COEFFS.update({i: (SLOW, INTERCEPT) for i in range(3, 8)})
SLOTS = 32
WORKLOAD = dict(seed=7, rate=56.0, gen_mean=8, gen_max=64,
                token_budget=0.12, ttft_slack=1.0)


def _sim_run(n_requests, mode, post=()):
    wl = generate_requests(n_requests, **WORKLOAD)
    rt = ServingRuntime(
        SimServingEngine(dict(COEFFS)),
        ServingAllocator(dict(COEFFS), total_slots=SLOTS, mode=mode),
        wl,
        nodes=list(range(8)),
        config=ServingConfig(total_slots=SLOTS, resolve_every=1.0),
    )
    for ev in post:
        rt.post(ev)
    return rt.run()


def _show(name, rep):
    s = rep.summary
    print(
        f"  {name:10s} sustained {rep.sustained_req_s:6.2f} req/s  "
        f"goodput {rep.goodput_req_s:6.2f} req/s  "
        f"p99 token {s['token_latency']['p99'] * 1e3:6.1f} ms  "
        f"dropped {s['dropped']}  requeues {s['requeues']}"
    )


def lane_waterfill_vs_uniform(n_requests):
    print(f"[1] water-fill vs uniform on the 2-speed-class cluster "
          f"({n_requests} requests, {SLOTS} slots)")
    opt = _sim_run(n_requests, "optperf")
    uni = _sim_run(n_requests, "uniform")
    _show("optperf", opt)
    _show("uniform", uni)
    print(f"  optperf allocation: {opt.allocations}")
    ratio = opt.sustained_req_s / uni.sustained_req_s
    print(f"  sustained ratio {ratio:.3f} (gate >= 1.15), "
          f"goodput ratio {opt.goodput_req_s / uni.goodput_req_s:.3f}")
    assert opt.summary["dropped"] == 0 and uni.summary["dropped"] == 0
    assert ratio >= 1.15, f"water-fill advantage {ratio:.3f} below 1.15x"
    assert (
        opt.summary["token_latency"]["p99"]
        <= uni.summary["token_latency"]["p99"]
    ), "water-fill must not regress p99 token latency"
    rerun = _sim_run(n_requests, "optperf")
    assert rerun.fingerprint == opt.fingerprint, "same-seed run not bit-identical"
    print(f"  same-seed fingerprint match: {opt.fingerprint[:16]}…")


def lane_churn(n_requests):
    print("[2] churn: NodeLeave mid-stream (+ a later rejoin), zero drops")
    rep = _sim_run(
        n_requests, "optperf",
        post=[NodeLeave(time=2.0, nodes=(0, 4)), NodeJoin(time=5.0, nodes=(0,))],
    )
    _show("churn", rep)
    assert rep.summary["dropped"] == 0, "requests lost under churn"
    assert rep.summary["completed"] == rep.summary["requests"]
    assert rep.counters["requeued"] > 0, "drain should have requeued in-flight work"
    print(f"  leaves {rep.counters['leaves']}  joins {rep.counters['joins']}  "
          f"requeued {rep.counters['requeued']}  final alloc {rep.allocations}")


def lane_real_engine(n_requests):
    import jax

    from repro.configs import get_api
    from repro.serving import RealServingEngine

    print(f"[3] real engine: reduced olmo-1b, {n_requests} requests, "
          "NodeLeave mid-stream")
    api = get_api("olmo-1b", reduced=True)
    params = api.init(jax.random.PRNGKey(0))
    wl = generate_requests(
        n_requests, seed=5, rate=50.0, prompt_min=16, prompt_max=16,
        gen_min=2, gen_max=8, gen_mean=4, token_budget=10.0,
    )
    # Prompts stream out of the training data pipeline (bounded buffer).
    src = SyntheticLM(vocab=api.cfg.vocab, seq_len=32, seed=3)
    prompts = prompts_from_stream(
        src.stream(8, steps=4 * n_requests, threaded=True), wl.requests
    )
    coeffs = {0: (0.01, 0.01), 1: (0.01, 0.01)}
    engine = RealServingEngine(api, params, max_len=32, prompts=prompts)
    rt = ServingRuntime(
        engine,
        ServingAllocator(dict(coeffs), total_slots=4),
        wl,
        nodes=[0, 1],
        config=ServingConfig(total_slots=4),
    )
    rt.post(NodeLeave(time=wl.requests[len(wl) // 3].arrival, nodes=(1,)))
    t0 = time.perf_counter()
    rep = rt.run()
    wall = time.perf_counter() - t0
    _show("real", rep)
    assert rep.summary["dropped"] == 0, "real engine dropped requests"
    assert rep.summary["completed"] == len(wl)
    assert rep.counters["leaves"] == 1
    toks = sum(len(r.token_times) for r in rt.metrics.records())
    print(f"  {toks} tokens in {wall:.1f}s wall "
          f"({toks / wall:.1f} tok/s incl. compile)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400,
                    help="sim-lane request count")
    ap.add_argument("--real-requests", type=int, default=8,
                    help="real-lane request count")
    ap.add_argument("--skip-real", action="store_true",
                    help="skip the JAX real-engine lane")
    args = ap.parse_args()
    lane_waterfill_vs_uniform(args.requests)
    lane_churn(args.requests)
    if not args.skip_real:
        lane_real_engine(args.real_requests)
    print("serving runtime demo OK")


if __name__ == "__main__":
    main()

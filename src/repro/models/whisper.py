"""Whisper-large-v3 backbone (arXiv:2212.04356) — encoder-decoder.

Per the assignment, the modality frontend (log-mel spectrogram + the two
conv layers) is a STUB: `input_specs()` supplies post-conv frame embeddings
(B, S_enc, d_model) directly.  This module implements the transformer
backbone: a bidirectional encoder and a causal decoder with cross-attention.

Deviations (recorded in DESIGN.md): sinusoidal positions on both sides
(the real decoder uses a 448-entry learned table, which cannot cover the
assigned 32k-cache decode shape); no attention biases.

Decode: the decoder self-attention KV cache has the assigned seq_len;
cross-attention K/V are precomputed from the encoder output at prefill and
live in the cache.  long_500k is skipped for this arch (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Param

__all__ = [
    "WhisperConfig",
    "schema",
    "init",
    "forward",
    "encode",
    "init_cache",
    "decode_step",
]


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 64
    enc_frames: int = 1500        # encoder length used for decode shapes
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    kv_chunk: int = 2048

    @property
    def family(self) -> str:
        return "audio"

    @property
    def n_kv_heads(self) -> int:
        return self.n_heads  # MHA


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


def _attn_schema(cfg: WhisperConfig) -> Dict[str, Any]:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": Param((d, h, dh), ("embed", "heads", None)),
        "wk": Param((d, h, dh), ("embed", "heads", None)),
        "wv": Param((d, h, dh), ("embed", "heads", None)),
        "wo": Param((h, dh, d), ("heads", None, "embed")),
    }


def _mlp_schema(cfg: WhisperConfig) -> Dict[str, Any]:
    return {
        "w_in": Param((cfg.d_model, cfg.d_ff), ("embed", "ff")),
        "w_out": Param((cfg.d_ff, cfg.d_model), ("ff", "embed")),
    }


def enc_layer_schema(cfg: WhisperConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "attn": _attn_schema(cfg),
        "attn_norm_w": Param((d,), (None,), init="ones"),
        "attn_norm_b": Param((d,), (None,), init="zeros"),
        "mlp": _mlp_schema(cfg),
        "mlp_norm_w": Param((d,), (None,), init="ones"),
        "mlp_norm_b": Param((d,), (None,), init="zeros"),
    }


def dec_layer_schema(cfg: WhisperConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "self_attn": _attn_schema(cfg),
        "self_norm_w": Param((d,), (None,), init="ones"),
        "self_norm_b": Param((d,), (None,), init="zeros"),
        "cross_attn": _attn_schema(cfg),
        "cross_norm_w": Param((d,), (None,), init="ones"),
        "cross_norm_b": Param((d,), (None,), init="zeros"),
        "mlp": _mlp_schema(cfg),
        "mlp_norm_w": Param((d,), (None,), init="ones"),
        "mlp_norm_b": Param((d,), (None,), init="zeros"),
    }


def schema(cfg: WhisperConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "embed": Param((cfg.vocab, d), ("vocab", None), init="embed"),
        "enc_layers": common.stacked(enc_layer_schema(cfg), cfg.n_enc_layers),
        "dec_layers": common.stacked(dec_layer_schema(cfg), cfg.n_dec_layers),
        "enc_norm_w": Param((d,), (None,), init="ones"),
        "enc_norm_b": Param((d,), (None,), init="zeros"),
        "dec_norm_w": Param((d,), (None,), init="ones"),
        "dec_norm_b": Param((d,), (None,), init="zeros"),
    }


def init(rng: jax.Array, cfg: WhisperConfig):
    return common.init_from_schema(rng, schema(cfg), cfg.param_dtype)


# ---------------------------------------------------------------------------
# Attention helpers
# ---------------------------------------------------------------------------


def _proj_qkv(ap, xq, xkv):
    q = jnp.einsum("bsd,dhk->bshk", xq, ap["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, ap["wv"])
    return q, k, v


def _ln(x, w, b):
    return common.layer_norm(x, w, b)


# ---------------------------------------------------------------------------
# Encoder / decoder
# ---------------------------------------------------------------------------


def encode(params: Dict[str, Any], cfg: WhisperConfig, audio_embed: jax.Array) -> jax.Array:
    """audio_embed (B, S_enc, d) — post-conv frames from the stub frontend."""
    b, s, _ = audio_embed.shape
    x = audio_embed.astype(cfg.compute_dtype)
    x = x + _sinusoid(jnp.arange(s), cfg.d_model)[None].astype(cfg.compute_dtype)

    def body(x, lp):
        h = _ln(x, lp["attn_norm_w"], lp["attn_norm_b"])
        q, k, v = _proj_qkv(lp["attn"], h, h)
        attn = common.full_attention(
            q, k, v, causal=False, bidirectional=True, kv_chunk=cfg.kv_chunk
        )
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["attn"]["wo"])
        h = _ln(x, lp["mlp_norm_w"], lp["mlp_norm_b"])
        x = x + jnp.einsum(
            "bsf,fd->bsd", jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, lp["mlp"]["w_in"])),
            lp["mlp"]["w_out"],
        )
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return _ln(x, params["enc_norm_w"], params["enc_norm_b"])


def _decoder(params, cfg: WhisperConfig, tokens, enc_out):
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = x + _sinusoid(jnp.arange(s), cfg.d_model)[None].astype(cfg.compute_dtype)

    def body(x, lp):
        h = _ln(x, lp["self_norm_w"], lp["self_norm_b"])
        q, k, v = _proj_qkv(lp["self_attn"], h, h)
        attn = common.full_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["self_attn"]["wo"])
        h = _ln(x, lp["cross_norm_w"], lp["cross_norm_b"])
        q, k, v = _proj_qkv(lp["cross_attn"], h, enc_out)
        attn = common.full_attention(
            q, k, v, causal=False, bidirectional=True, kv_chunk=cfg.kv_chunk
        )
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["cross_attn"]["wo"])
        h = _ln(x, lp["mlp_norm_w"], lp["mlp_norm_b"])
        x = x + jnp.einsum(
            "bsf,fd->bsd", jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, lp["mlp"]["w_in"])),
            lp["mlp"]["w_out"],
        )
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = _ln(x, params["dec_norm_w"], params["dec_norm_b"])
    # Tied embedding head (Whisper ties decoder embedding and output).
    return jnp.einsum(
        "bsd,vd->bsv", x, params["embed"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)


def forward(
    params: Dict[str, Any], cfg: WhisperConfig, audio_embed: jax.Array, tokens: jax.Array
) -> jax.Array:
    """Training forward: (audio frames, text tokens) -> decoder logits."""
    enc_out = encode(params, cfg, audio_embed)
    return _decoder(params, cfg, tokens, enc_out)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(cfg: WhisperConfig, batch: int, seq_len: int, dtype=None):
    """Self-attn KV cache (seq_len) + cross-attn K/V (enc_frames), which the
    serve path fills once from `encode` output via `prime_cache`."""
    if dtype is None:
        dtype = cfg.compute_dtype  # cache dtype must match decode K/V
    L, h, dh = cfg.n_dec_layers, cfg.n_heads, cfg.head_dim
    return {
        "k": jnp.zeros((L, batch, seq_len, h, dh), dtype),
        "v": jnp.zeros((L, batch, seq_len, h, dh), dtype),
        "cross_k": jnp.zeros((L, batch, cfg.enc_frames, h, dh), dtype),
        "cross_v": jnp.zeros((L, batch, cfg.enc_frames, h, dh), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prime_cache(params, cfg: WhisperConfig, cache, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output."""

    def per_layer(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
        return k, v

    k, v = jax.vmap(per_layer)(params["dec_layers"])
    return {**cache, "cross_k": k.astype(cache["cross_k"].dtype), "cross_v": v.astype(cache["cross_v"].dtype)}


def decode_step(
    params: Dict[str, Any],
    cfg: WhisperConfig,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,
    pos: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = x + _sinusoid(jnp.full((1,), pos, jnp.int32), cfg.d_model)[None].astype(cfg.compute_dtype)
    enc_len = cache["cross_k"].shape[2]

    def body(x, layer):
        lp, k_c, v_c, ck, cv = layer
        h = _ln(x, lp["self_norm_w"], lp["self_norm_b"])
        q, k, v = _proj_qkv(lp["self_attn"], h, h)
        k_c, v_c = common.cache_update(k_c, v_c, k, v, pos)
        attn = common.decode_attention(q, k_c, v_c, pos=pos)
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["self_attn"]["wo"])
        h = _ln(x, lp["cross_norm_w"], lp["cross_norm_b"])
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"])
        attn = common.decode_attention(q, ck, cv, pos=jnp.int32(enc_len - 1))
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["cross_attn"]["wo"])
        h = _ln(x, lp["mlp_norm_w"], lp["mlp_norm_b"])
        x = x + jnp.einsum(
            "bsf,fd->bsd", jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, lp["mlp"]["w_in"])),
            lp["mlp"]["w_out"],
        )
        return x, (k_c, v_c)

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"])
    )
    x = _ln(x, params["dec_norm_w"], params["dec_norm_b"])
    logits = jnp.einsum(
        "bsd,vd->bsv", x, params["embed"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)
    return logits, {**{"k": k_c, "v": v_c}, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"], "pos": pos + 1}

"""Table 5 reproduction: Cannikin controller overhead per epoch relative to
the simulated epoch training time, per workload scale."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, save_json
from repro.core.controller import CannikinController
from repro.core.simulator import SimulatedCluster, cluster_B
from benchmarks.bench_batchtime import WORKLOADS


def run() -> List[Row]:
    rows: List[Row] = []
    payload = {}
    for wl, (cscale, mscale) in WORKLOADS.items():
        profiles, comm = cluster_B(
            workload_scale=cscale, t_o=0.045 * mscale, t_u=0.009 * mscale
        )
        sim = SimulatedCluster(profiles, comm, noise=0.01, seed=0)
        ctrl = CannikinController(
            sim.n,
            batch_candidates=[128, 256, 512, 1024, 2048, 4096],
            ref_batch=128,
        )
        steps_per_epoch = 40
        sim_total = 0.0
        for _ in range(8):
            plan = ctrl.plan_epoch()
            t, ms = sim.run_epoch(list(plan.batches), steps_per_epoch)
            sim_total += t
            ctrl.observe_epoch(ms)
            ctrl.observe_gradients([4.0] * sim.n, 3.0, list(plan.batches))
        frac = ctrl.stats.overhead_fraction(sim_total)
        payload[wl] = {
            "controller_seconds": ctrl.stats.overhead_seconds,
            "sim_train_seconds": sim_total,
            "overhead_fraction": frac,
            "full_sweeps": ctrl.stats.full_sweeps,
            "incremental_updates": ctrl.stats.incremental_updates,
        }
        rows.append(
            Row(
                f"table5/{wl}",
                ctrl.stats.overhead_seconds / 8 * 1e6,
                f"overhead={frac:.2%}",
            )
        )
    save_json("overhead_table5", payload)
    return rows

"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + Mamba heads
[arXiv:2411.13676].

Notes: 25 heads and vocab 32001 are not divisible by the 16-way model axis;
the sharding rules replicate those dims (TP stays on FFN / SSM inner dims)
— recorded via MeshRules.fallbacks and DESIGN.md §5.  Meta tokens are
supported by the module but set to 0 here to keep train/decode shapes
uniform with the assigned input shapes.
"""
from repro.models.hymba import HymbaConfig

ARCH_ID = "hymba-1.5b"


def config() -> HymbaConfig:
    return HymbaConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab=32001,
        head_dim=64,
        ssm_state=16,
        conv_kernel=4,
        window=1024,
        n_meta_tokens=0,
        ssm_chunk=128,  # §Perf: -5% HBM streaming vs 64 (artifacts/perf)
    )


def reduced() -> HymbaConfig:
    return HymbaConfig(
        name=ARCH_ID + "-reduced",
        n_layers=2,
        d_model=128,
        n_heads=5,
        n_kv_heads=1,
        d_ff=256,
        vocab=512,
        head_dim=16,
        ssm_state=8,
        d_inner=128,
        conv_kernel=4,
        window=16,
        ssm_chunk=8,
        remat=False,
    )

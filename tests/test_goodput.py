"""Goodput / batch-size selection tests (§2.2, §4.5)."""
import numpy as np
import pytest

from repro.core.goodput import (
    BatchSizeSelector,
    adascale_gain,
    goodput,
    sqrt_lr_scale,
    statistical_efficiency,
)
from repro.core.simulator import cluster_A
from repro.core.perf_model import ClusterPerfModel


def _model():
    profiles, comm = cluster_A()
    return ClusterPerfModel(nodes=tuple(p.model() for p in profiles), comm=comm)


def test_efficiency_monotone_decreasing_in_batch():
    effs = [statistical_efficiency(100.0, b, 32) for b in (32, 64, 128, 512)]
    assert effs[0] == pytest.approx(1.0)
    assert all(a > b for a, b in zip(effs, effs[1:]))


def test_efficiency_high_noise_tolerates_big_batches():
    lo = statistical_efficiency(10.0, 512, 32)
    hi = statistical_efficiency(10000.0, 512, 32)
    assert hi > lo


def test_goodput_interior_optimum():
    """Throughput rises sublinearly with B while efficiency falls — goodput
    has an interior optimum over a wide candidate range."""
    model = _model()
    b_noise = 500.0
    gps = {b: goodput(model, b, b_noise, 32)[0] for b in (8, 32, 128, 512, 4096)}
    best = max(gps, key=gps.get)
    assert best not in (8, 4096)
    # Higher noise shifts the optimum to larger batches (never smaller).
    gps_hi = {b: goodput(model, b, 5000.0, 32)[0] for b in (8, 32, 128, 512, 4096)}
    assert max(gps_hi, key=gps_hi.get) >= best


def test_adascale_gain_bounds():
    assert adascale_gain(1e9, 256, 32) == pytest.approx(8.0, rel=1e-3)
    assert adascale_gain(1e-9, 256, 32) == pytest.approx(1.0, abs=1e-6)
    g = adascale_gain(100.0, 256, 32)
    assert 1.0 < g < 8.0
    assert sqrt_lr_scale(256, 64) == pytest.approx(2.0)


def test_selector_caches_and_invalidates():
    model = _model()
    sel = BatchSizeSelector(candidates=(64, 128, 256, 512), ref_batch=64)
    b1, sol1, _ = sel.select(model, b_noise=150.0)
    assert sel.full_sweeps == 1
    b2, _, _ = sel.select(model, b_noise=150.0)
    assert b2 == b1
    # Second select with unchanged models reuses the cache (no resweep).
    assert sel.full_sweeps == 1
    assert sel.incremental_updates >= 1


def test_selector_tracks_noise():
    model = _model()
    sel = BatchSizeSelector(candidates=(64, 128, 256, 512, 1024), ref_batch=64)
    b_low, _, _ = sel.select(model, b_noise=5.0)
    b_high, _, _ = sel.select(model, b_noise=5000.0)
    assert b_high >= b_low

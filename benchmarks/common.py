"""Shared benchmark helpers: timing + result rows."""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

ARTIFACTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "artifacts")


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def time_call(fn: Callable, *args, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def save_json(name: str, payload: Any) -> str:
    out = os.path.join(ARTIFACTS, "bench")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path
